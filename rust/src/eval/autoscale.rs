//! `eval autoscale` — the accuracy/energy/latency Pareto across a
//! precision-variant set (DESIGN.md §13).
//!
//! One variant set per workload — the matched-filter MLP
//! ([`synth_mlp_stack`], where absolute classification accuracy is
//! meaningful) and the synthetic CNN ([`synth_cnn_stack`], judged by
//! fidelity to the hi-fi variant) — is compiled once (one shared CSD
//! plan arena) and the same reference-precision sample batch is pushed
//! through **every** variant, exactly as the serving loop would
//! (requantization by the variant's `in_shift`, packed execution
//! oracle-checked bit-exact first). Each row of the table is one
//! operating point the governor trades between: accuracy and hi-fi
//! agreement against exact Stage-1/Stage-2 work, pre-characterized
//! energy and the cycle-time latency estimate at the deployment clock.

use std::sync::Arc;

use crate::anyhow;
use crate::coordinator::cost::CostTable;
use crate::coordinator::engine::PackedEngine;
use crate::coordinator::model::{CompiledModel, VariantSpec};
use crate::energy::report::table;
use crate::nn::conv::LayerOp;
use crate::nn::exec::{argmax_class, stack_forward_row};
use crate::nn::weights::LayerPrecision;
use crate::workload::synth::{synth_cnn_stack, synth_mlp_stack, Digits, ImageSet};

/// Samples per workload (a multiple of every variant's batch quantum).
pub const SAMPLES: usize = 96;

/// One Pareto point: a (workload, variant) cell.
#[derive(Debug, Clone)]
pub struct ParetoRow {
    pub workload: &'static str,
    pub variant: String,
    /// Top-1 accuracy against the workload's labels.
    pub accuracy: f64,
    /// Top-1 agreement with the reference (hi-fi) variant.
    pub fidelity: f64,
    pub s1_cycles_per_row: f64,
    pub s2_passes_per_row: f64,
    pub pj_per_row: f64,
    /// Energy per row the static cost certificate predicted for the
    /// same batch (DESIGN.md §15) — must equal `pj_per_row` to the
    /// attojoule.
    pub predicted_pj_per_row: f64,
    /// Measured-minus-predicted batch energy in attojoules, after the
    /// metrics pipeline's rounding. Always 0 for a correct certificate.
    pub delta_aj: i64,
    /// Observed activation sparsity: the cycle-weighted fraction of
    /// dense Stage-1 work that zero-skipping elided on this batch
    /// (DESIGN.md §18).
    pub sparsity: f64,
    /// Datapath-cycle latency estimate per row at the cost table's
    /// clock (Stage-1 + Stage-2 cycles, serial execution).
    pub est_us_per_row: f64,
}

/// The MLP's variant list: a 6-bit middle step makes all three
/// operating points distinct on a 2-layer stack (the standard trio's
/// balanced/turbo coincide there).
pub(crate) fn mlp_specs() -> Vec<VariantSpec> {
    vec![
        VariantSpec::new(
            "hifi-8",
            vec![LayerPrecision::new(8, 16), LayerPrecision::new(8, 16)],
        ),
        VariantSpec::new(
            "balanced-6",
            vec![LayerPrecision::new(6, 12), LayerPrecision::new(8, 16)],
        ),
        VariantSpec::new(
            "turbo-4",
            vec![LayerPrecision::new(4, 8), LayerPrecision::new(8, 16)],
        ),
    ]
}

#[allow(clippy::too_many_arguments)]
fn run_workload(
    workload: &'static str,
    stack: &[LayerOp],
    model: &Arc<CompiledModel>,
    xs: &[Vec<i64>],
    ys: &[usize],
    classes: usize,
    cost: &CostTable,
    out: &mut Vec<ParetoRow>,
) -> anyhow::Result<()> {
    let engine = PackedEngine::new(Arc::clone(model));
    let n = xs.len();
    let mut ref_preds: Vec<usize> = vec![];
    for v in 0..model.n_variants() {
        let var = model.variant(v);
        let batch: Vec<Vec<i64>> = xs.iter().map(|r| var.quantize_row(r)).collect();
        let (got, stats) = engine.forward_batch_variant(&batch, v);
        // Bit-exactness before pricing: the packed result must equal
        // the per-variant scalar oracle on every sampled row.
        for (b, row) in batch.iter().enumerate() {
            let want = stack_forward_row(row, stack, var.schedule());
            anyhow::ensure!(
                got[b] == want,
                "{workload}/{}: row {b} diverges from the scalar oracle",
                var.name()
            );
        }
        let preds: Vec<usize> = got.iter().map(|l| argmax_class(l, classes)).collect();
        if v == 0 {
            ref_preds = preds.clone();
        }
        let accuracy =
            preds.iter().zip(ys).filter(|(p, y)| p == y).count() as f64 / n as f64;
        let fidelity =
            preds.iter().zip(&ref_preds).filter(|(p, r)| p == r).count() as f64 / n as f64;
        let cycles = (stats.s1_cycles + stats.s2_passes) as f64;
        // Predicted-vs-measured energy: the static cost certificate
        // (DESIGN.md §15), evaluated at this batch's row count,
        // conditioned on the batch's own skip counters (DESIGN.md §18)
        // and priced through the same table, must reproduce the
        // measured bill exactly — field-exact stats, attojoule-exact
        // energy.
        let cert = model.cost_certificate(v);
        let conditioned = cert.eval_stats_with_skips(n, &stats);
        anyhow::ensure!(
            conditioned == stats,
            "{workload}/{}: certificate stats diverge from the engine",
            var.name()
        );
        let pj = cost.batch_energy_pj(&stats);
        let predicted_pj = cost.batch_energy_pj(&conditioned);
        let aj = |p: f64| (p.max(0.0) * 1e6).round() as i64;
        let delta_aj = aj(pj) - aj(predicted_pj);
        anyhow::ensure!(
            delta_aj == 0,
            "{workload}/{}: predicted energy off by {delta_aj} aJ \
             (measured {pj} pJ, predicted {predicted_pj} pJ)",
            var.name()
        );
        out.push(ParetoRow {
            workload,
            variant: var.name().to_string(),
            accuracy,
            fidelity,
            s1_cycles_per_row: stats.s1_cycles as f64 / n as f64,
            s2_passes_per_row: stats.s2_passes as f64 / n as f64,
            pj_per_row: pj / n as f64,
            predicted_pj_per_row: predicted_pj / n as f64,
            delta_aj,
            sparsity: stats.skip_fraction().unwrap_or(0.0),
            est_us_per_row: cycles / n as f64 / cost.mhz,
        });
    }
    Ok(())
}

/// Every (workload, variant) Pareto point, oracle-verified then priced.
pub fn rows(cost: &CostTable) -> anyhow::Result<Vec<ParetoRow>> {
    let mut out = vec![];

    let mlp = synth_mlp_stack(8);
    let model = CompiledModel::compile_variants(mlp.clone(), mlp_specs())?;
    let digits = Digits::standard();
    let (xs, ys) = digits.sample(SAMPLES, 0.3, 0xA07A5);
    run_workload("mlp-digits", &mlp, &model, &xs, &ys, 10, cost, &mut out)?;

    let cnn = synth_cnn_stack(0xA07A6, 8);
    let model = CompiledModel::compile_variants(cnn.clone(), VariantSpec::standard_trio(3))?;
    let images = ImageSet::standard();
    let (xs, ys) = images.sample(SAMPLES, 0.3, 0xA07A7, 8);
    run_workload("cnn-synth", &cnn, &model, &xs, &ys, 10, cost, &mut out)?;

    Ok(out)
}

pub fn run() -> anyhow::Result<()> {
    println!(
        "== autoscale sweep: the variant-set Pareto the precision governor \
         trades across ({SAMPLES} samples per workload, @1GHz) =="
    );
    let cost = CostTable::characterize(1000.0);
    let rs = rows(&cost)?;
    let trows: Vec<Vec<String>> = rs
        .iter()
        .map(|r| {
            vec![
                r.workload.to_string(),
                r.variant.clone(),
                format!("{:.1}%", r.accuracy * 100.0),
                format!("{:.1}%", r.fidelity * 100.0),
                format!("{:.1}", r.s1_cycles_per_row),
                format!("{:.1}", r.s2_passes_per_row),
                format!("{:.2}", r.pj_per_row),
                format!("{:.2}", r.predicted_pj_per_row),
                format!("{}", r.delta_aj),
                format!("{:.1}%", r.sparsity * 100.0),
                format!("{:.3}", r.est_us_per_row),
            ]
        })
        .collect();
    println!(
        "{}",
        table(
            &[
                "workload",
                "variant",
                "top-1 acc",
                "vs hi-fi",
                "S1 cyc/row",
                "S2 pass/row",
                "pJ/row",
                "pred pJ/row",
                "Δ aJ",
                "sparsity",
                "est us/row",
            ],
            &trows
        )
    );
    let hifi = &rs[0];
    let turbo = &rs[2];
    println!(
        "(every cell bit-exact vs the per-variant scalar oracle; on the MLP the \
         turbo variant keeps {:.1}% top-1 at {:.1}% of the hi-fi variant's \
         energy per row — the spread `eval` prices and the serving governor \
         exploits under load)\n",
        turbo.accuracy * 100.0,
        turbo.pj_per_row / hifi.pj_per_row * 100.0
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pareto_orders_work_and_keeps_mlp_accuracy() {
        let cost = CostTable::characterize(1000.0);
        let rs = rows(&cost).unwrap();
        // Certificate predictions are attojoule-exact on every cell
        // (rows() already errors otherwise; pin the surfaced figure).
        for r in &rs {
            assert_eq!(r.delta_aj, 0, "{}/{}", r.workload, r.variant);
            assert!(r.predicted_pj_per_row > 0.0);
            // Sparsity is a proper fraction; the sample count is a
            // multiple of every quantum, so no pad-word inflation.
            assert!((0.0..1.0).contains(&r.sparsity), "{}", r.sparsity);
        }
        let mlp: Vec<&ParetoRow> =
            rs.iter().filter(|r| r.workload == "mlp-digits").collect();
        let cnn: Vec<&ParetoRow> =
            rs.iter().filter(|r| r.workload == "cnn-synth").collect();
        assert_eq!(mlp.len(), 3);
        assert_eq!(cnn.len(), 3);
        for set in [&mlp, &cnn] {
            // The reference variant agrees with itself by definition.
            assert_eq!(set[0].fidelity, 1.0);
            // Exact work strictly decreases as precision drops: fewer
            // words per packed column at every shed step.
            assert!(
                set[2].s1_cycles_per_row < set[1].s1_cycles_per_row
                    && set[1].s1_cycles_per_row < set[0].s1_cycles_per_row,
                "{}: S1 cycles must strictly decrease across the trio",
                set[0].workload
            );
            // And the cheapest variant is cheaper in billed energy too.
            assert!(
                set[2].pj_per_row < set[0].pj_per_row,
                "{}: turbo must undercut hi-fi pJ/row",
                set[0].workload
            );
        }
        // The matched-filter MLP keeps meaningful accuracy at every
        // operating point (96/96, 96/96, 87/96 at these seeds).
        assert!(mlp[0].accuracy >= 0.9, "hi-fi accuracy {}", mlp[0].accuracy);
        assert!(mlp[1].accuracy >= 0.9, "balanced accuracy {}", mlp[1].accuracy);
        assert!(
            mlp[2].accuracy >= 0.75,
            "turbo must degrade gracefully, got {}",
            mlp[2].accuracy
        );
        assert!(mlp[2].fidelity >= 0.75, "turbo fidelity {}", mlp[2].fidelity);
    }

    #[test]
    fn mlp_variant_list_is_three_distinct_operating_points() {
        let specs = mlp_specs();
        assert_eq!(specs.len(), 3);
        let first_layer: Vec<u32> = specs.iter().map(|s| s.schedule[0].in_bits).collect();
        assert_eq!(first_layer, vec![8, 6, 4]);
        // Compiles as one variant set over the matched-filter stack.
        let model =
            CompiledModel::compile_variants(synth_mlp_stack(8), specs).unwrap();
        assert_eq!(model.n_variants(), 3);
        assert_eq!(model.variant(2).in_shift(), 4);
    }
}
