//! Pre-characterized energy costs for the request path.
//!
//! Driving the gate-level simulator inside the serving loop would put
//! the cost model on the hot path; instead the coordinator characterizes
//! each pipeline block once at startup (random-operand runs at the
//! deployment frequency) and charges per-cycle averages thereafter.

use crate::bits::format::SimdFormat;
use crate::energy::model::SynthesizedSoftPipeline;
use crate::rtl::crossbar::config_table;
use crate::workload::synth::XorShift64;

/// Per-format average energies (pJ) at a fixed clock.
#[derive(Debug, Clone)]
pub struct CostTable {
    pub mhz: f64,
    /// pJ per Stage-1 multiply cycle, indexed by format bits.
    pub s1_cycle_pj: Vec<(u32, f64)>,
    /// pJ per Stage-2 crossbar pass (averaged over conversions).
    pub s2_pass_pj: f64,
    /// Pipeline area (µm²) for reporting.
    pub area_um2: f64,
}

impl CostTable {
    /// Characterize at `mhz` (a few hundred random words per format).
    pub fn characterize(mhz: f64) -> CostTable {
        let mut pipe = SynthesizedSoftPipeline::new(mhz);
        let mut rng = XorShift64::new(0xC057);
        let mut s1 = vec![];
        for fmt in SimdFormat::all() {
            let n = 60;
            let (pj, cycles) = pipe.word_mult_energy_pj(fmt.bits, fmt.bits, fmt.bits, n, &mut rng);
            s1.push((fmt.bits, pj / cycles.max(1) as f64));
        }
        // Average crossbar pass cost across a few conversions.
        let cfgs = config_table();
        let mut total = 0.0;
        let mut count = 0;
        for cfg in cfgs.iter().take(6) {
            total += pipe.repack_energy_pj(cfg, 40, &mut rng);
            count += 40;
        }
        let area = pipe.area().total();
        CostTable {
            mhz,
            s1_cycle_pj: s1,
            s2_pass_pj: total / count as f64,
            area_um2: area,
        }
    }

    /// pJ per Stage-1 cycle at `fmt`. An uncharacterized format is a
    /// deployment bug (silently billing a placeholder would corrupt
    /// every downstream energy figure), so it is a hard error.
    pub fn s1_pj(&self, fmt: SimdFormat) -> f64 {
        self.s1_cycle_pj
            .iter()
            .find(|&&(b, _)| b == fmt.bits)
            .map(|&(_, v)| v)
            .unwrap_or_else(|| {
                panic!(
                    "CostTable has no Stage-1 characterization for format {fmt} \
                     (characterized: {:?}); refusing to guess",
                    self.s1_cycle_pj.iter().map(|&(b, _)| b).collect::<Vec<_>>()
                )
            })
    }

    /// Energy of a single-format workload expressed in cycles.
    pub fn energy_pj(&self, s1_cycles: u64, fmt: SimdFormat, s2_passes: u64) -> f64 {
        s1_cycles as f64 * self.s1_pj(fmt) + s2_passes as f64 * self.s2_pass_pj
    }

    /// Stage-1 energy of one engine run, each format's cycles billed at
    /// its own characterized rate — with a mixed-precision schedule each
    /// layer runs at its own width and a single-format average would
    /// misprice the batch.
    pub fn s1_energy_pj(&self, stats: &crate::coordinator::engine::EngineStats) -> f64 {
        let mut pj = 0.0;
        for (&bits, &cycles) in crate::bits::format::FORMATS
            .iter()
            .zip(&stats.s1_cycles_by_fmt)
        {
            if cycles > 0 {
                pj += cycles as f64 * self.s1_pj(SimdFormat::new(bits));
            }
        }
        pj
    }

    /// Energy of one engine run (the worker hot path's single call).
    pub fn batch_energy_pj(&self, stats: &crate::coordinator::engine::EngineStats) -> f64 {
        self.s1_energy_pj(stats) + stats.s2_passes as f64 * self.s2_pass_pj
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn characterization_produces_positive_costs() {
        let t = CostTable::characterize(1000.0);
        for &(b, pj) in &t.s1_cycle_pj {
            assert!(pj > 0.0, "format {b}");
            assert!(pj < 10.0, "format {b}: {pj} pJ/cycle implausible");
        }
        assert!(t.s2_pass_pj > 0.0);
        assert!(t.area_um2 > 100.0);
    }

    #[test]
    #[should_panic(expected = "no Stage-1 characterization")]
    fn uncharacterized_format_is_a_hard_error() {
        // Regression (the silent 1.0 pJ fallback): a table missing a
        // format must refuse to price it, not invent a number.
        let t = CostTable {
            mhz: 1000.0,
            s1_cycle_pj: vec![(8, 1.0)],
            s2_pass_pj: 0.5,
            area_um2: 1000.0,
        };
        let _ = t.s1_pj(SimdFormat::new(4));
    }

    #[test]
    fn batch_energy_bills_each_format_at_its_own_rate() {
        let t = CostTable {
            mhz: 1000.0,
            s1_cycle_pj: vec![(4, 0.25), (8, 1.0)],
            s2_pass_pj: 0.5,
            area_um2: 1000.0,
        };
        let mut by_fmt = [0u64; crate::bits::format::FORMATS.len()];
        by_fmt[crate::bits::format::format_index(4)] = 20;
        by_fmt[crate::bits::format::format_index(8)] = 10;
        let stats = crate::coordinator::engine::EngineStats {
            s1_cycles: 30,
            s2_passes: 4,
            s1_cycles_by_fmt: by_fmt,
            ..Default::default()
        };
        // 20·0.25 + 10·1.0 + 4·0.5 = 17 pJ — not 30·(any single rate).
        assert!((t.batch_energy_pj(&stats) - 17.0).abs() < 1e-9);
    }
}
