//! Synthesized-block and pipeline cost models.
//!
//! A [`SynthBlock`] wraps a netlist with: structural depth, NAND2-eq
//! area, a weighted-toggle simulator, and a glitch class. Area and
//! energy are functions of the synthesis timing constraint through the
//! sizing model in [`super::tech`]. Register banks are costed
//! analytically (clock + write energy per bit) from the activity the
//! architecture model reports.

use crate::rtl::gate::Netlist;
use crate::rtl::sim::Simulator;
use crate::rtl::timing::depth;

use super::tech::{cell_costs, energy_factor, sizing, GlitchClass, TechParams, TECH28};

/// A synthesized combinational block.
pub struct SynthBlock {
    pub net: Netlist,
    pub glitch: GlitchClass,
    pub depth_levels: u32,
    pub area_eq: f64,
    pub sim: Simulator,
    pub tech: TechParams,
}

impl SynthBlock {
    pub fn new(net: Netlist, glitch: GlitchClass) -> Self {
        let depth_levels = depth(&net);
        let area_eq: f64 = net.cells.iter().map(|c| cell_costs(c.kind).area_eq).sum();
        let weights: Vec<f32> = net
            .cells
            .iter()
            .map(|c| cell_costs(c.kind).toggle_fj as f32)
            .collect();
        let sim = Simulator::with_weights(&net, weights);
        SynthBlock { net, glitch, depth_levels, area_eq, sim, tech: TECH28 }
    }

    /// Synthesis up-sizing factor at `mhz`.
    pub fn sigma(&self, mhz: f64) -> f64 {
        sizing(self.depth_levels, mhz, &self.tech)
    }

    /// Block area (µm²) under the timing constraint.
    pub fn area_um2(&self, mhz: f64) -> f64 {
        self.area_eq * self.sigma(mhz) * self.tech.nand2_um2
    }

    /// Nominal (unsized) critical path, ps.
    pub fn path_ps(&self) -> f64 {
        self.depth_levels as f64 * self.tech.gate_delay_ps
    }

    /// Drain the simulator's accumulated weighted energy into pJ at the
    /// given constraint (applies glitch + sizing energy factors).
    pub fn take_energy_pj(&mut self, mhz: f64) -> f64 {
        let fj = self.sim.energy_fj;
        self.sim.reset_counters();
        fj * self.glitch.factor() * energy_factor(self.sigma(mhz)) * self.tech.energy_scale
            / 1000.0
    }

    /// Leakage energy per cycle at `mhz`, pJ.
    pub fn leak_pj_per_cycle(&self, mhz: f64) -> f64 {
        // nW × ns = 1e-18 J = 1e-6 pJ
        let period_ns = 1000.0 / mhz;
        self.area_eq * self.sigma(mhz) * self.tech.leak_nw_per_eq * period_ns * 1e-6
    }
}

/// Analytic register-bank cost.
#[derive(Debug, Clone, Copy)]
pub struct RegBank {
    pub bits: u32,
}

impl RegBank {
    pub fn area_um2(&self, _mhz: f64) -> f64 {
        // Registers are sized for hold/clock, not logic depth.
        self.bits as f64 * TECH28.dff_area_eq * TECH28.nand2_um2
    }

    /// Energy for one clocked cycle with `written` toggled bits, pJ.
    pub fn cycle_pj(&self, written: u32) -> f64 {
        (self.bits as f64 * TECH28.dff_clk_fj + written as f64 * TECH28.dff_write_fj) / 1000.0
    }

    pub fn leak_pj_per_cycle(&self, mhz: f64) -> f64 {
        let period_ns = 1000.0 / mhz;
        self.bits as f64 * TECH28.dff_area_eq * TECH28.leak_nw_per_eq * period_ns * 1e-6
    }
}

/// Area breakdown of a pipeline (the Fig. 6 / Fig. 7 rows).
#[derive(Debug, Clone)]
pub struct PipelineArea {
    pub name: String,
    pub mhz: f64,
    pub stage1_um2: f64,
    pub stage2_um2: f64,
    pub regs_um2: f64,
}

impl PipelineArea {
    pub fn total(&self) -> f64 {
        self.stage1_um2 + self.stage2_um2 + self.regs_um2
    }
}

/// The Soft SIMD pipeline, synthesized at a timing constraint:
/// Stage-1 datapath (adder variant picked by timing), Stage-2 crossbar,
/// and the architectural registers of Fig. 2.
pub struct SynthesizedSoftPipeline {
    pub mhz: f64,
    pub stage1: SynthBlock,
    pub stage2: SynthBlock,
    /// Stage-1 registers: Acc(48) + X(48) + V_x/ctrl(20).
    pub s1_regs: RegBank,
    /// Stage-2 registers: R2/R3/R4 (144) + config (8).
    pub s2_regs: RegBank,
    /// True when timing forced the carry-select adder.
    pub restructured: bool,
}

impl SynthesizedSoftPipeline {
    pub fn new(mhz: f64) -> Self {
        // Synthesis decision: ripple if it fits in ~90% of the period
        // after up-sizing headroom, else restructure to carry-select.
        let ripple = crate::rtl::shifter::stage1_datapath(false);
        let period_ps = 1.0e6 / mhz;
        let ripple_path = depth(&ripple) as f64 * TECH28.gate_delay_ps;
        // Up-sizing can close ~35% of negative slack; past that the flow
        // restructures the carry (carry-select), trading area for depth.
        let restructured = ripple_path > 1.35 * period_ps;
        let stage1 = if restructured {
            SynthBlock::new(
                crate::rtl::shifter::stage1_datapath(true),
                GlitchClass::AdderChain,
            )
        } else {
            SynthBlock::new(ripple, GlitchClass::AdderChain)
        };
        let (xbar, _) = crate::rtl::crossbar::crossbar_netlist();
        let stage2 = SynthBlock::new(xbar, GlitchClass::MuxNetwork);
        SynthesizedSoftPipeline {
            mhz,
            stage1,
            stage2,
            s1_regs: RegBank { bits: 48 + 48 + 20 },
            s2_regs: RegBank { bits: 144 + 8 },
            restructured,
        }
    }

    pub fn area(&self) -> PipelineArea {
        PipelineArea {
            name: "Soft SIMD".into(),
            mhz: self.mhz,
            stage1_um2: self.stage1.area_um2(self.mhz),
            stage2_um2: self.stage2.area_um2(self.mhz),
            regs_um2: self.s1_regs.area_um2(self.mhz) + self.s2_regs.area_um2(self.mhz),
        }
    }

    /// Smallest Soft SIMD format holding `x_bits`-wide multiplicands.
    pub fn fit_width(x_bits: u32) -> Option<u32> {
        crate::bits::format::FORMATS
            .iter()
            .copied()
            .filter(|&b| b >= x_bits)
            .min()
    }

    /// Run `n_words` packed multiplications (random multiplicand words,
    /// random `y_bits` multipliers) through the gate-level Stage-1
    /// datapath; returns total pJ (datapath + registers + leakage).
    ///
    /// Stage-2 is bypassed/idle during multiplication: its registers are
    /// clock-gated (leakage only) — the pipeline's sequential-multiply
    /// energy story of Section IV-C.
    pub fn word_mult_energy_pj(
        &mut self,
        b: u32,
        x_bits: u32,
        y_bits: u32,
        n_words: usize,
        rng: &mut crate::workload::synth::XorShift64,
    ) -> (f64, u64) {
        use crate::csd::schedule::{schedule, MulOp};
        use crate::rtl::shifter::drive_stage1;
        let fmt = crate::bits::format::SimdFormat::new(b);
        self.stage1.sim.reset_counters();
        let mut reg_pj = 0.0;
        let mut cycles = 0u64;
        let mut prev_x = 0u64;
        let mut prev_acc = 0u64;
        for _ in 0..n_words {
            // Multiplicands: x_bits of information, value-aligned (Q1
            // widening) inside the fitted b-bit lanes.
            let lanes: Vec<i64> = (0..fmt.lanes())
                .map(|_| rng.q_raw(x_bits) << (b - x_bits))
                .collect();
            let x = crate::bits::pack::pack(&lanes, fmt);
            let m = rng.q_raw(y_bits);
            let plan = schedule(m, y_bits);
            // Loading X: one write into the X register.
            let mut x_written = (x ^ prev_x).count_ones();
            prev_x = x;
            let mut acc = 0u64;
            for op in &plan.ops {
                let (k, sign) = match *op {
                    MulOp::AddShift { shift, sign } => (shift, sign),
                    MulOp::Shift { shift } => (shift, 0),
                };
                let out = drive_stage1(&mut self.stage1.sim, &self.stage1.net, acc, x, k, sign, fmt);
                let written = (out ^ prev_acc).count_ones() + x_written;
                x_written = 0; // X loads once per multiplication
                reg_pj += self.s1_regs.cycle_pj(written);
                prev_acc = out;
                acc = out;
                cycles += 1;
            }
        }
        let dyn_pj = self.stage1.take_energy_pj(self.mhz);
        let leak_pj = (self.stage1.leak_pj_per_cycle(self.mhz)
            + self.stage2.leak_pj_per_cycle(self.mhz)
            + self.s1_regs.leak_pj_per_cycle(self.mhz)
            + self.s2_regs.leak_pj_per_cycle(self.mhz))
            * cycles as f64;
        (dyn_pj + reg_pj + leak_pj, cycles)
    }

    /// Energy per sub-word multiplication at operand widths
    /// (x_bits × y_bits); picks the smallest fitting format.
    pub fn subword_mult_energy_pj(
        &mut self,
        x_bits: u32,
        y_bits: u32,
        n_words: usize,
        rng: &mut crate::workload::synth::XorShift64,
    ) -> Option<f64> {
        let b = Self::fit_width(x_bits)?;
        let fmt = crate::bits::format::SimdFormat::new(b);
        let (total, _) = self.word_mult_energy_pj(b, x_bits, y_bits, n_words, rng);
        Some(total / (n_words as f64 * fmt.lanes() as f64))
    }

    /// Run `n_words` Stage-2 repack cycles (random windows) and return
    /// total pJ — the Fig. 5 conversion cost model.
    pub fn repack_energy_pj(
        &mut self,
        cfg: &crate::rtl::crossbar::XbarConfig,
        n_words: usize,
        rng: &mut crate::workload::synth::XorShift64,
    ) -> f64 {
        use crate::rtl::crossbar::drive_crossbar;
        let cfgs = crate::rtl::crossbar::config_table();
        self.stage2.sim.reset_counters();
        let mut reg_pj = 0.0;
        let mut prev_out = 0u64;
        for _ in 0..n_words {
            let window = (rng.word() as u128) | ((rng.word() as u128) << 48);
            let out = drive_crossbar(&mut self.stage2.sim, &self.stage2.net, &cfgs, window, cfg);
            let written = 96 + (out ^ prev_out).count_ones(); // R2:R3 refill + R4
            reg_pj += self.s2_regs.cycle_pj(written);
            prev_out = out;
        }
        let dyn_pj = self.stage2.take_energy_pj(self.mhz);
        let leak = (self.stage2.leak_pj_per_cycle(self.mhz)
            + self.s2_regs.leak_pj_per_cycle(self.mhz))
            * n_words as f64;
        dyn_pj + reg_pj + leak
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtl::crossbar::crossbar_netlist;

    #[test]
    fn crossbar_area_flat_across_frequency() {
        // Fig. 6 discussion: stage 2 is shallow — its area must not grow
        // between 200 MHz and 1 GHz.
        let (net, _) = crossbar_netlist();
        let blk = SynthBlock::new(net, GlitchClass::MuxNetwork);
        let a200 = blk.area_um2(200.0);
        let a1000 = blk.area_um2(1000.0);
        assert!((a1000 / a200 - 1.0).abs() < 0.05, "{a200} vs {a1000}");
    }

    #[test]
    fn stage1_grows_with_frequency() {
        let p200 = SynthesizedSoftPipeline::new(200.0);
        let p1000 = SynthesizedSoftPipeline::new(1000.0);
        let a200 = p200.area();
        let a1000 = p1000.area();
        assert!(
            a1000.stage1_um2 > a200.stage1_um2 * 1.05,
            "{} vs {}",
            a200.stage1_um2,
            a1000.stage1_um2
        );
    }

    #[test]
    fn restructuring_kicks_in_at_high_frequency() {
        assert!(!SynthesizedSoftPipeline::new(200.0).restructured);
        assert!(SynthesizedSoftPipeline::new(1000.0).restructured);
    }

    #[test]
    fn regbank_costs_scale_with_bits() {
        let small = RegBank { bits: 48 };
        let big = RegBank { bits: 144 };
        assert!(big.area_um2(500.0) > 2.9 * small.area_um2(500.0));
        assert!(big.cycle_pj(10) > small.cycle_pj(10));
    }
}
