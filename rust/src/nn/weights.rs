//! Quantized weight storage and the `mlp_weights.txt` loader.

use std::path::Path;

use crate::anyhow;

use crate::csd::schedule::{schedule, MulPlan};

/// One layer's quantized weights (`Q1.(bits-1)` raws) with cached CSD
/// multiply plans (one per distinct weight value — plans are shared).
#[derive(Debug, Clone)]
pub struct QuantLayer {
    /// `[k][n]` raw weights.
    pub w_raw: Vec<Vec<i64>>,
    pub k: usize,
    pub n: usize,
    /// Weight bitwidth.
    pub bits: u32,
}

impl QuantLayer {
    pub fn new(w_raw: Vec<Vec<i64>>, bits: u32) -> Self {
        let k = w_raw.len();
        let n = if k > 0 { w_raw[0].len() } else { 0 };
        for row in &w_raw {
            assert_eq!(row.len(), n, "ragged weight matrix");
        }
        QuantLayer { w_raw, k, n, bits }
    }

    /// Build the layer from float weights.
    pub fn quantize(w: &[Vec<f64>], bits: u32) -> Self {
        let raw = w
            .iter()
            .map(|row| row.iter().map(|&v| crate::bits::fixed::to_q(v, bits)).collect())
            .collect();
        QuantLayer::new(raw, bits)
    }

    /// The multiply plan for weight `(i, j)`.
    pub fn plan(&self, i: usize, j: usize) -> MulPlan {
        schedule(self.w_raw[i][j], self.bits)
    }

    /// Mean Stage-1 cycles per weight (workload statistics for the
    /// energy model).
    pub fn mean_cycles(&self) -> f64 {
        let mut total = 0usize;
        for row in &self.w_raw {
            for &w in row {
                total += schedule(w, self.bits).cycles();
            }
        }
        total as f64 / (self.k * self.n) as f64
    }
}

/// Parse `artifacts/mlp_weights.txt`:
/// `layer <idx> <K> <N>` followed by `K` comma-separated rows.
pub fn load_weight_file(path: impl AsRef<Path>) -> anyhow::Result<Vec<QuantLayer>> {
    let text = std::fs::read_to_string(path.as_ref())?;
    let mut layers = vec![];
    let mut lines = text.lines().peekable();
    while let Some(header) = lines.next() {
        let header = header.trim();
        if header.is_empty() {
            continue;
        }
        let parts: Vec<&str> = header.split_whitespace().collect();
        anyhow::ensure!(
            parts.len() == 4 && parts[0] == "layer",
            "bad layer header: {header}"
        );
        let k: usize = parts[2].parse()?;
        let n: usize = parts[3].parse()?;
        let mut rows = Vec::with_capacity(k);
        for _ in 0..k {
            let row_line = lines
                .next()
                .ok_or_else(|| anyhow::anyhow!("truncated weight file"))?;
            let row: Vec<i64> = row_line
                .trim()
                .split(',')
                .map(|v| v.parse::<i64>())
                .collect::<Result<_, _>>()?;
            anyhow::ensure!(row.len() == n, "row width {} != {n}", row.len());
            rows.push(row);
        }
        layers.push(QuantLayer::new(rows, 8));
    }
    Ok(layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_weight_text() {
        let text = "layer 0 2 3\n1,-2,3\n-4,5,-6\nlayer 1 1 2\n7,-8\n";
        let tmp = std::env::temp_dir().join("softsimd_wtest.txt");
        std::fs::write(&tmp, text).unwrap();
        let layers = load_weight_file(&tmp).unwrap();
        assert_eq!(layers.len(), 2);
        assert_eq!(layers[0].k, 2);
        assert_eq!(layers[0].n, 3);
        assert_eq!(layers[0].w_raw[1], vec![-4, 5, -6]);
        assert_eq!(layers[1].w_raw[0], vec![7, -8]);
    }

    #[test]
    fn quantize_roundtrip() {
        let l = QuantLayer::quantize(&[vec![0.5, -0.25], vec![0.0, 0.99]], 8);
        assert_eq!(l.w_raw, vec![vec![64, -32], vec![0, 127]]);
    }

    #[test]
    fn mean_cycles_sane() {
        let l = QuantLayer::quantize(&[vec![0.5, -0.5, 0.0, 0.93]], 8);
        let mc = l.mean_cycles();
        assert!(mc > 0.0 && mc < 8.0, "{mc}");
    }
}
