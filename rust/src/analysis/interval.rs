//! The abstract interval domain of the lane-safety verifier
//! (DESIGN.md §14).
//!
//! An [`Interval`] `[lo, hi]` abstracts the set of raw (sign-extended)
//! sub-word values a lane can hold at some program point. Every
//! transfer function used by the analyzer is *monotone in the
//! endpoints* — arithmetic shifts, additions, ReLU and the Stage-2
//! format conversions all map the least/greatest concrete value to the
//! least/greatest result — so propagating the two endpoints is a sound
//! over-approximation of propagating every concrete value.
//!
//! The keystone invariant of the accumulator soundness argument
//! (`analysis::verify_with_arena`): every interval the analyzer
//! propagates **contains zero**. Layer-0 inputs span the full two's
//! complement range (which straddles zero), ReLU outputs include zero,
//! format conversions fix zero, and a CSD multiply maps zero to zero —
//! so every per-tap product interval has `lo ≤ 0 ≤ hi`, which is what
//! bounds every *partial* accumulation order by the full-sum interval.

use crate::bits::format::SimdFormat;
use crate::pipeline::stage2::convert_subword;

/// A closed interval `[lo, hi]` of raw sub-word values (`lo ≤ hi`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interval {
    /// Least value the lane can hold.
    pub lo: i64,
    /// Greatest value the lane can hold.
    pub hi: i64,
}

impl Interval {
    /// The singleton interval `[v, v]`.
    #[inline]
    pub fn point(v: i64) -> Interval {
        Interval { lo: v, hi: v }
    }

    /// The full two's-complement range of a `bits`-wide lane:
    /// `[−2^(b−1), 2^(b−1)−1]`.
    #[inline]
    pub fn full(bits: u32) -> Interval {
        let half = 1i64 << (bits - 1);
        Interval { lo: -half, hi: half - 1 }
    }

    /// Smallest interval containing both operands (the domain's join).
    #[inline]
    pub fn hull(self, other: Interval) -> Interval {
        Interval { lo: self.lo.min(other.lo), hi: self.hi.max(other.hi) }
    }

    /// `hi − lo` as an unsigned span (number of values minus one).
    #[inline]
    pub fn width(&self) -> u64 {
        (self.hi as i128 - self.lo as i128) as u64
    }

    /// Does the interval contain `v`?
    #[inline]
    pub fn contains(&self, v: i64) -> bool {
        self.lo <= v && v <= self.hi
    }

    /// Transfer function of the SWAR ReLU: both endpoints clamp at
    /// zero (monotone, and the result always contains zero).
    #[inline]
    pub fn relu(self) -> Interval {
        Interval { lo: self.lo.max(0), hi: self.hi.max(0) }
    }

    /// Transfer function of one Stage-2 crossbar hop: widening is an
    /// exact left shift, narrowing an arithmetic right shift — both
    /// monotone, so mapping the endpoints is exact on the hull.
    #[inline]
    pub fn convert(self, from: SimdFormat, to: SimdFormat) -> Interval {
        Interval {
            lo: convert_subword(self.lo, from, to),
            hi: convert_subword(self.hi, from, to),
        }
    }

    /// Does every value of the interval fit a `bits`-wide two's
    /// complement lane without wrapping?
    #[inline]
    pub fn fits(&self, bits: u32) -> bool {
        let half = 1i64 << (bits - 1);
        self.lo >= -half && self.hi < half
    }
}

impl std::fmt::Display for Interval {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}, {}]", self.lo, self.hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_range_straddles_zero_at_every_format() {
        for fmt in SimdFormat::all() {
            let iv = Interval::full(fmt.bits);
            assert!(iv.contains(0), "{fmt}");
            assert!(iv.fits(fmt.bits), "{fmt}");
            assert_eq!(iv.width(), (1u64 << fmt.bits) - 1, "{fmt}");
        }
    }

    #[test]
    fn hull_and_relu_preserve_zero_membership() {
        let a = Interval { lo: -5, hi: 3 };
        let b = Interval::point(7);
        let h = a.hull(b);
        assert_eq!(h, Interval { lo: -5, hi: 7 });
        assert_eq!(h.relu(), Interval { lo: 0, hi: 7 });
        // ReLU of an all-negative interval collapses to the point zero.
        assert_eq!(Interval { lo: -9, hi: -1 }.relu(), Interval::point(0));
    }

    #[test]
    fn convert_maps_endpoints_exactly() {
        let f8 = SimdFormat::new(8);
        let f16 = SimdFormat::new(16);
        let iv = Interval { lo: -100, hi: 99 };
        assert_eq!(iv.convert(f8, f16), Interval { lo: -100 << 8, hi: 99 << 8 });
        // Narrowing truncates toward −∞ on both ends.
        let wide = Interval { lo: -0x1234, hi: 0x0FFF };
        assert_eq!(wide.convert(f16, f8), Interval { lo: -0x13, hi: 0x0F });
    }

    #[test]
    fn fits_is_the_lane_range_check() {
        assert!(Interval { lo: -128, hi: 127 }.fits(8));
        assert!(!Interval { lo: -129, hi: 0 }.fits(8));
        assert!(!Interval { lo: 0, hi: 128 }.fits(8));
    }
}
