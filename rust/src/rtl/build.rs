//! Netlist construction helpers.

use super::gate::{Cell, CellKind, Netlist, NodeId, NO_NET};

/// Builder enforcing topological order (cells only reference existing
/// nets).
pub struct NetBuilder {
    net: Netlist,
}

impl NetBuilder {
    pub fn new(name: &str) -> Self {
        NetBuilder {
            net: Netlist { name: name.to_string(), ..Default::default() },
        }
    }

    fn push(&mut self, kind: CellKind, a: NodeId, b: NodeId, sel: NodeId) -> NodeId {
        let id = self.net.cells.len() as NodeId;
        debug_assert!(a == NO_NET || a < id);
        debug_assert!(b == NO_NET || b < id);
        debug_assert!(sel == NO_NET || sel < id);
        self.net.cells.push(Cell { kind, a, b, sel });
        id
    }

    pub fn input(&mut self) -> NodeId {
        let id = self.push(CellKind::Input, NO_NET, NO_NET, NO_NET);
        self.net.inputs.push(id);
        id
    }

    /// Declare `n` inputs (LSB-first buses).
    pub fn inputs(&mut self, n: usize) -> Vec<NodeId> {
        (0..n).map(|_| self.input()).collect()
    }

    pub fn zero(&mut self) -> NodeId {
        self.push(CellKind::Const0, NO_NET, NO_NET, NO_NET)
    }

    pub fn one(&mut self) -> NodeId {
        self.push(CellKind::Const1, NO_NET, NO_NET, NO_NET)
    }

    pub fn not(&mut self, a: NodeId) -> NodeId {
        self.push(CellKind::Inv, a, NO_NET, NO_NET)
    }

    pub fn buf(&mut self, a: NodeId) -> NodeId {
        self.push(CellKind::Buf, a, NO_NET, NO_NET)
    }

    pub fn and2(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.push(CellKind::And2, a, b, NO_NET)
    }

    pub fn or2(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.push(CellKind::Or2, a, b, NO_NET)
    }

    pub fn nand2(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.push(CellKind::Nand2, a, b, NO_NET)
    }

    pub fn nor2(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.push(CellKind::Nor2, a, b, NO_NET)
    }

    pub fn xor2(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.push(CellKind::Xor2, a, b, NO_NET)
    }

    pub fn xnor2(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.push(CellKind::Xnor2, a, b, NO_NET)
    }

    /// `sel ? b : a`.
    pub fn mux2(&mut self, sel: NodeId, a: NodeId, b: NodeId) -> NodeId {
        self.push(CellKind::Mux2, a, b, sel)
    }

    /// Full adder: returns (sum, carry).
    pub fn full_adder(&mut self, a: NodeId, b: NodeId, cin: NodeId) -> (NodeId, NodeId) {
        let axb = self.xor2(a, b);
        let sum = self.xor2(axb, cin);
        let t1 = self.and2(a, b);
        let t2 = self.and2(axb, cin);
        let carry = self.or2(t1, t2);
        (sum, carry)
    }

    /// Wide OR of a slice (balanced tree).
    pub fn or_tree(&mut self, nets: &[NodeId]) -> NodeId {
        assert!(!nets.is_empty());
        let mut layer = nets.to_vec();
        while layer.len() > 1 {
            let mut next = Vec::with_capacity(layer.len().div_ceil(2));
            for pair in layer.chunks(2) {
                next.push(if pair.len() == 2 {
                    self.or2(pair[0], pair[1])
                } else {
                    pair[0]
                });
            }
            layer = next;
        }
        layer[0]
    }

    /// Wide AND of a slice (balanced tree).
    pub fn and_tree(&mut self, nets: &[NodeId]) -> NodeId {
        assert!(!nets.is_empty());
        let mut layer = nets.to_vec();
        while layer.len() > 1 {
            let mut next = Vec::with_capacity(layer.len().div_ceil(2));
            for pair in layer.chunks(2) {
                next.push(if pair.len() == 2 {
                    self.and2(pair[0], pair[1])
                } else {
                    pair[0]
                });
            }
            layer = next;
        }
        layer[0]
    }

    /// One-hot select: `Σ sel_i · val_i` (OR of ANDs). Exactly one
    /// `sel_i` must be high in operation.
    pub fn onehot_mux(&mut self, sels: &[NodeId], vals: &[NodeId]) -> NodeId {
        assert_eq!(sels.len(), vals.len());
        let terms: Vec<NodeId> = sels
            .iter()
            .zip(vals)
            .map(|(&s, &v)| self.and2(s, v))
            .collect();
        self.or_tree(&terms)
    }

    pub fn output(&mut self, net: NodeId) {
        self.net.outputs.push(net);
    }

    pub fn outputs(&mut self, nets: &[NodeId]) {
        self.net.outputs.extend_from_slice(nets);
    }

    pub fn finish(self) -> Netlist {
        self.net
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtl::sim::Simulator;

    #[test]
    fn full_adder_truth_table() {
        let mut b = NetBuilder::new("fa");
        let x = b.input();
        let y = b.input();
        let c = b.input();
        let (s, co) = b.full_adder(x, y, c);
        b.output(s);
        b.output(co);
        let net = b.finish();
        let mut sim = Simulator::new(&net);
        for bits in 0..8u8 {
            let ins = [bits & 1 != 0, bits & 2 != 0, bits & 4 != 0];
            sim.set_inputs(&ins);
            sim.eval(&net);
            let total = ins.iter().filter(|&&v| v).count();
            assert_eq!(sim.output(&net, 0), total & 1 == 1, "sum for {bits:03b}");
            assert_eq!(sim.output(&net, 1), total >= 2, "carry for {bits:03b}");
        }
    }

    #[test]
    fn onehot_mux_selects() {
        let mut b = NetBuilder::new("ohm");
        let sels = b.inputs(3);
        let vals = b.inputs(3);
        let o = b.onehot_mux(&sels, &vals);
        b.output(o);
        let net = b.finish();
        let mut sim = Simulator::new(&net);
        for pick in 0..3 {
            for pattern in 0..8u8 {
                let mut ins = vec![false; 6];
                ins[pick] = true;
                for v in 0..3 {
                    ins[3 + v] = pattern & (1 << v) != 0;
                }
                sim.set_inputs(&ins);
                sim.eval(&net);
                assert_eq!(sim.output(&net, 0), ins[3 + pick]);
            }
        }
    }
}
