//! Stage 1 — the Soft SIMD shift-add arithmetic unit (Section III-B,
//! Figs. 3–4).
//!
//! One cycle = one configurable-adder pass (carry-kill at boundaries;
//! `+1`-injected subtraction) fused with a configurable-shifter pass
//! (1..=3 positions, per-sub-word sign replication fed by the adder's
//! carry-out — the overflow-free `(b+1)`-bit intermediate of DESIGN.md
//! §4). The functional semantics live in [`crate::bits::swar`]; this
//! module sequences them into whole multiplications and records
//! per-cycle operand activity for the gate-level energy replay.

use crate::bits::fixed::sign_extend;
use crate::bits::format::SimdFormat;
use crate::bits::swar::{swar_add_sar, swar_sar, swar_sub_sar};
use crate::csd::schedule::{schedule_with, MulOp, MulPlan};

/// Stage-1 datapath state: the accumulator and the multiplicand operand
/// register, plus cycle counters.
#[derive(Debug, Clone)]
pub struct Stage1 {
    pub acc: u64,
    pub x: u64,
    pub fmt: SimdFormat,
    pub cycles: u64,
    pub add_cycles: u64,
}

impl Stage1 {
    pub fn new(fmt: SimdFormat) -> Self {
        Stage1 { acc: 0, x: 0, fmt, cycles: 0, add_cycles: 0 }
    }

    pub fn set_fmt(&mut self, fmt: SimdFormat) {
        self.fmt = fmt;
    }

    pub fn load_x(&mut self, x: u64) {
        self.x = x;
    }

    pub fn clear_acc(&mut self) {
        self.acc = 0;
    }

    /// One pure-shift cycle.
    pub fn shift(&mut self, k: u32) -> u64 {
        self.acc = swar_sar(self.acc, k, self.fmt);
        self.cycles += 1;
        self.acc
    }

    /// One fused add-then-shift cycle: `acc ← (acc ± X) >> k`, with the
    /// `(b+1)`-bit intermediate of DESIGN.md §4 (`k = 0` = final add).
    pub fn shift_add(&mut self, k: u32, sign: i8) -> u64 {
        self.acc = if sign >= 0 {
            swar_add_sar(self.acc, self.x, k, self.fmt)
        } else {
            swar_sub_sar(self.acc, self.x, k, self.fmt)
        };
        self.cycles += 1;
        self.add_cycles += 1;
        self.acc
    }

    /// Execute a full multiplication plan; returns the packed product.
    pub fn run_plan(&mut self, plan: &MulPlan) -> u64 {
        self.clear_acc();
        for op in &plan.ops {
            match *op {
                MulOp::Shift { shift } => self.shift(shift),
                MulOp::AddShift { shift, sign } => self.shift_add(shift, sign),
            };
        }
        self.acc
    }

    /// Load a multiplicand word and execute a plan in one call — the
    /// serving engine's inner loop (one call per packed word per weight).
    #[inline]
    pub fn run_plan_on(&mut self, x: u64, plan: &MulPlan) -> u64 {
        self.load_x(x);
        self.run_plan(plan)
    }

    /// Execute a flattened micro-op slice ([`crate::csd::flat`]) on a
    /// freshly loaded multiplicand word — the allocation-free serving
    /// inner loop. Bit-exact against [`Stage1::run_plan`] on the encoded
    /// form of the same plan (property-tested); no `MulPlan`, no enum
    /// dispatch, no pointer chase: one byte per cycle, branch-lean.
    #[inline]
    pub fn run_flat(&mut self, x: u64, ops: &[u8]) -> u64 {
        use crate::csd::flat::{FLAT_ADD, FLAT_NEG, FLAT_SHIFT_MASK};
        #[cfg(feature = "lanecheck")]
        {
            crate::bits::lanecheck::set_context("stage1::run_flat");
            crate::bits::lanecheck::check_word(x, self.fmt.bits);
        }
        self.x = x;
        self.acc = 0;
        for &op in ops {
            let k = (op & FLAT_SHIFT_MASK) as u32;
            self.acc = if op & FLAT_ADD != 0 {
                self.add_cycles += 1;
                if op & FLAT_NEG == 0 {
                    swar_add_sar(self.acc, self.x, k, self.fmt)
                } else {
                    swar_sub_sar(self.acc, self.x, k, self.fmt)
                }
            } else {
                swar_sar(self.acc, k, self.fmt)
            };
            self.cycles += 1;
        }
        #[cfg(feature = "lanecheck")]
        crate::bits::lanecheck::check_word(self.acc, self.fmt.bits);
        self.acc
    }

    /// Execute a flattened micro-op slice on [`TILE`] multiplicand
    /// words at once through the host-vector backend (`--features
    /// simd`, DESIGN.md §16) — bit-exact per word against
    /// [`Stage1::run_flat`]. The counters are billed from the op
    /// stream itself (`ops.len()` cycles and one add per `FLAT_ADD`
    /// byte, × `TILE` words), which is the same arithmetic the scalar
    /// loop performs — the datapath cycle count stays the one source
    /// of truth for `EngineStats` on either backend.
    ///
    /// [`TILE`]: crate::bits::swarx::TILE
    #[cfg(feature = "simd")]
    #[inline]
    pub fn run_flat_tile(
        &mut self,
        kern: crate::bits::swarx::Kernel,
        x: crate::bits::swarx::Tile,
        ops: &[u8],
    ) -> crate::bits::swarx::Tile {
        use crate::csd::flat::FLAT_ADD;
        let out = crate::bits::swarx::run_flat_tile(kern, x, ops, self.fmt);
        let tile = crate::bits::swarx::TILE as u64;
        self.cycles += ops.len() as u64 * tile;
        self.add_cycles +=
            ops.iter().filter(|&&op| op & FLAT_ADD != 0).count() as u64 * tile;
        out
    }

    /// Read and reset the cycle counters.
    ///
    /// The counters deliberately *accumulate* across `run_plan`/`run_flat`
    /// calls (a multi-word multiply is many calls); the billing layer
    /// drains them here after each plan × word-stream unit, making the
    /// datapath's own cycle count the single source of truth for
    /// `EngineStats` — the engine never re-bills via `plan.cycles()`,
    /// and the counters can no longer grow unbounded over a worker's
    /// lifetime. Returns `(cycles, add_cycles)`.
    #[inline]
    pub fn take_counters(&mut self) -> (u64, u64) {
        let out = (self.cycles, self.add_cycles);
        self.cycles = 0;
        self.add_cycles = 0;
        out
    }

    /// Reset the cycle counters without reading them.
    #[inline]
    pub fn reset_counters(&mut self) {
        self.cycles = 0;
        self.add_cycles = 0;
    }
}

/// Multiply every sub-word of `x_packed` (format `fmt`, `Q1.(b-1)`) by
/// the scalar multiplier `m_raw` (`Q1.(y_bits-1)`), with the paper's
/// `max_shift = 3` coalescing. Pure function used throughout the library
/// and cross-checked against the Pallas kernel.
pub fn mul_packed(x_packed: u64, m_raw: i64, y_bits: u32, fmt: SimdFormat) -> u64 {
    mul_packed_with(x_packed, m_raw, y_bits, fmt, crate::bits::format::MAX_SHIFT)
}

/// As [`mul_packed`] with configurable shifter reach (ablations).
pub fn mul_packed_with(x_packed: u64, m_raw: i64, y_bits: u32, fmt: SimdFormat, max_shift: u32) -> u64 {
    let plan = schedule_with(m_raw, y_bits, max_shift);
    let mut s1 = Stage1::new(fmt);
    s1.load_x(x_packed);
    s1.run_plan(&plan)
}

/// Scalar oracle: the same truncating shift-add algorithm on one
/// sign-extended sub-word value. The packed implementation must agree
/// lane-by-lane with this function — this is the semantic pivot between
/// Rust, the jnp reference and the Pallas kernel.
pub fn mul_scalar(x_raw: i64, m_raw: i64, x_bits: u32, y_bits: u32) -> i64 {
    let plan = schedule_with(m_raw, y_bits, crate::bits::format::MAX_SHIFT);
    mul_scalar_plan(x_raw, &plan, x_bits)
}

/// Scalar oracle over an explicit plan.
///
/// Computed in `i64` (no wrap possible mid-plan: the `(b+1)`-bit sum is
/// shifted back into range every cycle); only the final `k = 0` add may
/// legitimately wrap (the `−1 × −1` corner), matching the hardware.
pub fn mul_scalar_plan(x_raw: i64, plan: &MulPlan, x_bits: u32) -> i64 {
    let mask = (1u64 << x_bits) - 1;
    let mut acc: i64 = 0;
    for op in &plan.ops {
        match *op {
            MulOp::Shift { shift } => {
                acc >>= shift; // arithmetic, truncate toward −∞
            }
            MulOp::AddShift { shift, sign } => {
                acc = if sign >= 0 { acc + x_raw } else { acc - x_raw };
                acc >>= shift;
                // Wrap to the sub-word width exactly as the hardware does
                // (identity except for the final-add overflow corner).
                acc = sign_extend(acc as u64 & mask, x_bits);
            }
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::fixed::from_q;
    use crate::bits::pack::{pack, unpack};

    struct XorShift(u64);
    impl XorShift {
        fn next(&mut self) -> u64 {
            let mut x = self.0;
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            self.0 = x;
            x
        }
        fn lane(&mut self, bits: u32) -> i64 {
            sign_extend(self.next() & ((1u64 << bits) - 1), bits)
        }
    }

    #[test]
    fn packed_matches_scalar_oracle_everywhere() {
        let mut rng = XorShift(0xC0FFEE);
        for fmt in SimdFormat::all() {
            for ybits in [4u32, 8, fmt.bits] {
                for _ in 0..200 {
                    let lanes: Vec<i64> =
                        (0..fmt.lanes()).map(|_| rng.lane(fmt.bits)).collect();
                    let m = rng.lane(ybits);
                    let x = pack(&lanes, fmt);
                    let prod = mul_packed(x, m, ybits, fmt);
                    let got = unpack(prod, fmt);
                    let want: Vec<i64> = lanes
                        .iter()
                        .map(|&l| mul_scalar(l, m, fmt.bits, ybits))
                        .collect();
                    assert_eq!(got, want, "fmt {fmt} y {ybits} m {m}");
                }
            }
        }
    }

    #[test]
    fn multiply_accuracy_about_one_percent_at_8bit() {
        // Section III-B: truncation error ≈ 1% in the 8-bit example.
        // Measure mean relative error over products with |true| ≥ 0.1.
        let _fmt = SimdFormat::new(8);
        let mut rng = XorShift(0xACC0_4ACE);
        let mut total = 0.0f64;
        let mut n = 0usize;
        for _ in 0..4000 {
            let xr = rng.lane(8);
            let mr = rng.lane(8);
            let truth = from_q(xr, 8) * from_q(mr, 8);
            if truth.abs() < 0.1 {
                continue;
            }
            let got = from_q(mul_scalar(xr, mr, 8, 8), 8);
            total += ((got - truth) / truth).abs();
            n += 1;
        }
        let mean_rel = total / n as f64;
        assert!(
            mean_rel < 0.03,
            "mean relative truncation error too large: {mean_rel}"
        );
    }

    #[test]
    fn identity_and_zero_multipliers() {
        let fmt = SimdFormat::new(8);
        let lanes: Vec<i64> = vec![-128, 127, 64, -64, 1, -1];
        let x = pack(&lanes, fmt);
        // m = 0 → 0.
        assert_eq!(mul_packed(x, 0, 8, fmt), 0);
        // m = −1.0 (raw −128 @ Q1.7) → negation (with −128 wrapping to −128).
        let neg = unpack(mul_packed(x, -128, 8, fmt), fmt);
        assert_eq!(neg, vec![-128, -127, -64, 64, -1, 1]);
    }

    #[test]
    fn positive_halving() {
        // m = +0.5 (raw 64 @ Q1.7): product = x/2 truncated toward −∞.
        let fmt = SimdFormat::new(8);
        let lanes: Vec<i64> = vec![100, -100, 3, -3, 127, -128];
        let x = pack(&lanes, fmt);
        let got = unpack(mul_packed(x, 64, 8, fmt), fmt);
        let want: Vec<i64> = lanes.iter().map(|&l| l >> 1).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn cycle_counters_track_plan() {
        let fmt = SimdFormat::new(8);
        let plan = schedule_with(115, 8, 3);
        let mut s1 = Stage1::new(fmt);
        s1.load_x(0x0102_0304_0506);
        s1.run_plan(&plan);
        assert_eq!(s1.cycles as usize, plan.cycles());
        assert_eq!(s1.add_cycles as usize, plan.adds());
    }

    #[test]
    fn run_flat_matches_run_plan_and_counters_drain() {
        // The flat byte-encoded execution path must agree with the
        // MulPlan path on every word, and take_counters must hand the
        // billing layer exactly plan.cycles()/plan.adds() per word —
        // the one-source-of-truth contract (DESIGN.md §11).
        let mut rng = XorShift(0xF1A7);
        for fmt in SimdFormat::all() {
            for ybits in [4u32, 8, fmt.bits] {
                for _ in 0..50 {
                    let m = rng.lane(ybits);
                    let plan = schedule_with(m, ybits, 3);
                    let mut flat = Vec::new();
                    crate::csd::flat::encode_plan(&plan, &mut flat);
                    let mut a = Stage1::new(fmt);
                    let mut b = Stage1::new(fmt);
                    let words = 1 + (rng.next() % 4);
                    for _ in 0..words {
                        let x = rng.next() & crate::bits::format::WORD_MASK;
                        assert_eq!(
                            b.run_flat(x, &flat),
                            a.run_plan_on(x, &plan),
                            "fmt {fmt} m {m}"
                        );
                    }
                    let (cycles, adds) = b.take_counters();
                    assert_eq!(cycles, plan.cycles() as u64 * words);
                    assert_eq!(adds, plan.adds() as u64 * words);
                    // Drained: a second take reads zero.
                    assert_eq!(b.take_counters(), (0, 0));
                }
            }
        }
    }

    #[test]
    fn small_width_products_against_float() {
        // 4-bit lanes: exhaustive x × m check that |error| ≤ 2 ULP + exactness
        // of the wide cases where no truncation can occur.
        let _fmt = SimdFormat::new(4);
        for xr in -8i64..8 {
            for mr in -8i64..8 {
                if xr == -8 && mr == -8 {
                    // −1 × −1 = +1 is unrepresentable in Q1.3 and wraps —
                    // the documented two's-complement corner.
                    continue;
                }
                let got = mul_scalar(xr, mr, 4, 4);
                let truth = from_q(xr, 4) * from_q(mr, 4);
                let err = (from_q(got, 4) - truth).abs();
                // Truncation bound: processed positions each lose <1 ULP;
                // CSD has ≤2 nonzero digits at 4 bits ⇒ ≤ 4 ULP slack.
                assert!(
                    err <= 4.0 * 0.125,
                    "x={xr} m={mr} got={got} truth={truth} err={err}"
                );
            }
        }
    }
}
