//! Stage 2 — the data packing unit (Section III-C, Fig. 5).
//!
//! A crossbar reads sub-words from the 96-bit `R2:R3` input window and
//! writes one 48-bit output word per cycle, converting between Soft SIMD
//! formats. Conversions preserve the `Q1.(b-1)` *value* alignment:
//! widening appends fractional zero bits (exact), narrowing truncates the
//! lowest fractional bits (toward −∞), i.e. sub-word `s` maps to
//! `s << (b2-b1)` or `s >> (b1-b2)`.
//!
//! **Direct hop legality.** One output word needs `S2 = 48/b2`
//! consecutive input sub-words, spanning `S2·b1` input bits; these must
//! fit the 96-bit window, so a conversion is a single crossbar pass iff
//! `48·b1/b2 ≤ 96`, i.e. `b1 ≤ 2·b2`. All widenings qualify; narrowing
//! by more than 2× (e.g. 16→4) is compiled into a chain of direct hops
//! (16→8→4) by [`conversion_chain`]. Fig. 5's legible content is the
//! conversion *set* over {4,6,8,12,16}; the chaining rule is our
//! documented reading of the crossbar's 2-word input port (DESIGN.md §4).

use crate::bits::fixed::{sign_extend, truncate};
use crate::bits::format::{SimdFormat, FORMATS, WORD_MASK};

/// Is `from → to` a single crossbar pass?
pub fn is_direct(from: SimdFormat, to: SimdFormat) -> bool {
    from.bits <= 2 * to.bits
}

/// Number of 48-bit output words produced per *input word* of a direct
/// widening hop (ceiling: the last word of a lone input word may be
/// partially filled). For narrowing hops one output word consumes
/// multiple input words instead; see [`input_words_per_output`].
pub fn output_words_per_input(from: SimdFormat, to: SimdFormat) -> u32 {
    let bits_out = from.lanes() * to.bits; // each input sub-word becomes one output sub-word
    bits_out.div_ceil(48)
}

/// Number of input words needed to fill one output word of a direct
/// narrowing hop (ceiling).
pub fn input_words_per_output(from: SimdFormat, to: SimdFormat) -> u32 {
    let bits_in = to.lanes() * from.bits;
    bits_in.div_ceil(48)
}

/// Convert one sub-word value between formats (raw, sign-extended).
#[inline]
pub fn convert_subword(v: i64, from: SimdFormat, to: SimdFormat) -> i64 {
    if to.bits >= from.bits {
        v << (to.bits - from.bits)
    } else {
        v >> (from.bits - to.bits) // arithmetic: truncate toward −∞
    }
}

/// Shortest chain of direct hops realizing `from → to`. Returns an empty
/// chain when `from == to`. BFS over the supported format set; every
/// pair among {4,6,8,12,16} is reachable in ≤2 hops.
pub fn conversion_chain(from: SimdFormat, to: SimdFormat) -> Vec<(SimdFormat, SimdFormat)> {
    if from == to {
        return vec![];
    }
    if is_direct(from, to) {
        return vec![(from, to)];
    }
    // BFS.
    let mut queue = std::collections::VecDeque::new();
    let mut prev: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
    queue.push_back(from.bits);
    prev.insert(from.bits, from.bits);
    while let Some(b) = queue.pop_front() {
        if b == to.bits {
            break;
        }
        for &nb in FORMATS.iter() {
            if nb != b
                && is_direct(SimdFormat::new(b), SimdFormat::new(nb))
                && !prev.contains_key(&nb)
            {
                prev.insert(nb, b);
                queue.push_back(nb);
            }
        }
    }
    let mut chain = vec![];
    let mut cur = to.bits;
    while cur != from.bits {
        let p = prev[&cur];
        chain.push((SimdFormat::new(p), SimdFormat::new(cur)));
        cur = p;
    }
    chain.reverse();
    chain
}

/// Crossbar pass over a 96-bit window: produce the output word whose
/// sub-words come from `S2` consecutive input sub-words starting at
/// window sub-word index `in_skip`. `window` holds R2 in bits 0..48 and
/// R3 in bits 48..96 (u128 carrier).
pub fn crossbar_pass(window: u128, from: SimdFormat, to: SimdFormat, in_skip: u32) -> u64 {
    assert!(is_direct(from, to), "{from}->{to} is not a direct crossbar hop");
    let span_bits = to.lanes() * from.bits;
    assert!(
        in_skip * from.bits + span_bits <= 96,
        "crossbar sources exceed the R2:R3 window"
    );
    let in_mask = (1u128 << from.bits) - 1;
    let mut out = 0u64;
    for lane in 0..to.lanes() {
        let src = (in_skip + lane) * from.bits;
        let s = sign_extend(((window >> src) & in_mask) as u64, from.bits);
        let c = convert_subword(s, from, to);
        out |= truncate(c, to.bits) << (lane * to.bits);
    }
    out & WORD_MASK
}

/// Canonical stream semantics: repack `count` valid sub-words held in
/// `words` (format `from`) into format `to`, chaining hops as required.
/// Output is densely packed; the final word is zero-padded.
pub fn repack_stream(words: &[u64], from: SimdFormat, to: SimdFormat, count: usize) -> Vec<u64> {
    let mut vals = crate::bits::pack::unpack_stream(words, from, count);
    let mut cur = from;
    for (f, t) in conversion_chain(from, to) {
        debug_assert_eq!(f, cur);
        vals = vals.iter().map(|&v| convert_subword(v, f, t)).collect();
        cur = t;
    }
    debug_assert_eq!(cur, to);
    crate::bits::pack::pack_stream(&vals, to)
}

/// Repack a single word (lanes beyond the word are zero-padded).
pub fn repack_word(word: u64, from: SimdFormat, to: SimdFormat) -> Vec<u64> {
    repack_stream(&[word], from, to, from.lanes() as usize)
}

/// One *direct* crossbar hop over a whole packed stream, written into a
/// caller-owned buffer: for each output word, the `S2` source sub-words
/// are gathered straight out of the input words by bit arithmetic — no
/// per-value `Vec` round trip, and with a warmed `dst` no allocation at
/// all. This is the serving engine's batched boundary repack
/// (DESIGN.md §11); it is bit-identical to the canonical
/// [`repack_stream`] for a direct hop (tested below). Chains are run
/// hop-by-hop by the caller (the chain is precompiled in the model).
///
/// `count` is the number of valid sub-words; sub-words past `count` in
/// the final output word pack as zero, matching [`repack_stream`].
pub fn repack_hop_into(
    src: &[u64],
    from: SimdFormat,
    to: SimdFormat,
    count: usize,
    dst: &mut Vec<u64>,
) {
    debug_assert!(is_direct(from, to), "{from}->{to} is not a direct crossbar hop");
    debug_assert!(src.len() * from.lanes() as usize >= count, "source stream too short");
    #[cfg(feature = "lanecheck")]
    crate::bits::lanecheck::set_context("stage2::repack_hop_into");
    dst.clear();
    let out_lanes = to.lanes() as usize;
    let in_lanes = from.lanes() as usize;
    let in_mask = (1u64 << from.bits) - 1;
    let out_words = count.div_ceil(out_lanes);
    for ow in 0..out_words {
        let mut w = 0u64;
        for lane in 0..out_lanes {
            let idx = ow * out_lanes + lane;
            if idx >= count {
                break;
            }
            let s = sign_extend(
                (src[idx / in_lanes] >> ((idx % in_lanes) as u32 * from.bits)) & in_mask,
                from.bits,
            );
            w |= truncate(convert_subword(s, from, to), to.bits) << (lane as u32 * to.bits);
        }
        #[cfg(feature = "lanecheck")]
        crate::bits::lanecheck::check_word(w, to.bits);
        dst.push(w);
    }
}

/// Host-vector form of [`repack_hop_into`] (`--features simd`,
/// DESIGN.md §16): same signature, same output bits, but the gather is
/// specialized to branch-free full output words and `TILE`-unrolled in
/// [`crate::bits::swarx::repack_hop_tiles`]. No `lanecheck` hooks — the
/// engine pins sanitizer builds to the scalar path at compile time.
#[cfg(feature = "simd")]
#[inline]
pub fn repack_hop_into_wide(
    src: &[u64],
    from: SimdFormat,
    to: SimdFormat,
    count: usize,
    dst: &mut Vec<u64>,
) {
    crate::bits::swarx::repack_hop_tiles(src, from, to, count, dst);
}

/// Fast path for the doubling widen `b → 2b` (the multiply→accumulate
/// conversion on the NN hot path): one input word expands into exactly
/// two output words, each sub-word value-aligned (`<< b`) in its slot.
/// Bit-identical to [`repack_word`] for `to = 2·from` (tested below);
/// pure shifts/masks, no per-lane unpacking (DESIGN.md §9).
#[inline]
pub fn widen_double(word: u64, from: SimdFormat) -> (u64, u64) {
    let b = from.bits;
    debug_assert!(FORMATS.contains(&(2 * b)));
    let half = from.lanes() / 2;
    let mask = (1u64 << b) - 1;
    let mut lo = 0u64;
    let mut hi = 0u64;
    for i in 0..half {
        lo |= ((word >> (i * b)) & mask) << (2 * b * i + b);
        hi |= ((word >> ((half + i) * b)) & mask) << (2 * b * i + b);
    }
    (lo, hi)
}

/// Cycle/bookkeeping view of Stage 2 used by the pipeline core: executes
/// crossbar passes and counts them.
#[derive(Debug, Default, Clone)]
pub struct Stage2 {
    pub passes: u64,
    pub bypasses: u64,
}

impl Stage2 {
    /// One crossbar cycle.
    pub fn pass(&mut self, window: u128, from: SimdFormat, to: SimdFormat, in_skip: u32) -> u64 {
        self.passes += 1;
        crossbar_pass(window, from, to, in_skip)
    }

    /// One bypass cycle (R4 ← R2).
    pub fn bypass(&mut self, r2: u64) -> u64 {
        self.bypasses += 1;
        r2
    }

    /// Total cycles (a bypass still occupies the stage for a cycle).
    pub fn cycles(&self) -> u64 {
        self.passes + self.bypasses
    }
}

/// Number of Stage-2 cycles to repack `n_words` stream words from → to
/// (the cost model's view; chains multiply the cost).
pub fn repack_cycles(n_words: usize, from: SimdFormat, to: SimdFormat) -> u64 {
    if from == to {
        return n_words as u64; // bypass cycles
    }
    // Sub-word count is conserved by conversion.
    repack_cycles_exact(n_words * from.lanes() as usize, from, to)
}

/// As [`repack_cycles`], but billed for `count` *valid sub-words* rather
/// than whole input words: the zero-padding lanes of a partial final
/// word cost nothing. This is the serving engine's accounting (its
/// batches are padded to the lane multiple, where the two agree).
pub fn repack_cycles_exact(count: usize, from: SimdFormat, to: SimdFormat) -> u64 {
    if from == to {
        // Bypass: one cycle per occupied word.
        return count.div_ceil(from.lanes() as usize) as u64;
    }
    let mut cycles = 0u64;
    for (_f, t) in conversion_chain(from, to) {
        // One cycle per produced output word of this hop.
        cycles += (count * t.bits as usize).div_ceil(48) as u64;
    }
    cycles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::pack::{pack_stream, unpack_stream};

    fn fmt(b: u32) -> SimdFormat {
        SimdFormat::new(b)
    }

    #[test]
    fn direct_hop_rule() {
        assert!(is_direct(fmt(8), fmt(16)));
        assert!(is_direct(fmt(8), fmt(4))); // 8 ≤ 2·4
        assert!(is_direct(fmt(12), fmt(6))); // 12 ≤ 2·6 (exactly the window)
        assert!(!is_direct(fmt(16), fmt(4)));
        assert!(!is_direct(fmt(12), fmt(4)));
        assert!(!is_direct(fmt(16), fmt(6)));
    }

    #[test]
    fn chains_cover_all_pairs() {
        for a in SimdFormat::all() {
            for b in SimdFormat::all() {
                let chain = conversion_chain(a, b);
                if a == b {
                    assert!(chain.is_empty());
                    continue;
                }
                assert!(!chain.is_empty(), "{a}->{b}");
                assert!(chain.len() <= 2, "{a}->{b} needs {} hops", chain.len());
                assert_eq!(chain[0].0, a);
                assert_eq!(chain.last().unwrap().1, b);
                for hop in &chain {
                    assert!(is_direct(hop.0, hop.1));
                }
            }
        }
    }

    #[test]
    fn widen_is_exact_in_value() {
        // Widening must preserve the represented Q1 value exactly.
        let from = fmt(4);
        let to = fmt(12);
        for v in -8i64..8 {
            let c = convert_subword(v, from, to);
            let val_from = v as f64 / 8.0;
            let val_to = c as f64 / 2048.0;
            assert_eq!(val_from, val_to, "v={v}");
        }
    }

    #[test]
    fn narrow_truncates_toward_neg_inf() {
        let from = fmt(8);
        let to = fmt(4);
        assert_eq!(convert_subword(0b0111_1111, from, to), 0b0111); // 127→7
        assert_eq!(convert_subword(-1, from, to), -1); // −1/128 → −1/8? truncation −∞
        assert_eq!(convert_subword(-128, from, to), -8);
        assert_eq!(convert_subword(17, from, to), 1);
    }

    #[test]
    fn stream_roundtrip_widen_then_narrow_is_identity() {
        // widen b→B then narrow B→b restores the original sub-words.
        let vals: Vec<i64> = (0..24).map(|i| ((i * 29 + 3) % 16) - 8).collect();
        for (a, b) in [(4u32, 8u32), (4, 16), (6, 12), (8, 16), (6, 8), (12, 16)] {
            let (fa, fb) = (fmt(a), fmt(b));
            let w = pack_stream(&vals, fa);
            let wide = repack_stream(&w, fa, fb, vals.len());
            let back = repack_stream(&wide, fb, fa, vals.len());
            assert_eq!(unpack_stream(&back, fa, vals.len()), vals, "{fa}<->{fb}");
        }
    }

    #[test]
    fn crossbar_pass_matches_stream_semantics() {
        // Single-window passes agree with the canonical stream function.
        let from = fmt(8);
        let to = fmt(16);
        let vals: Vec<i64> = vec![-128, 127, -1, 64, -64, 5];
        let w = pack_stream(&vals, from)[0];
        let window = w as u128; // R3 empty
        let out0 = crossbar_pass(window, from, to, 0);
        let out1 = crossbar_pass(window, from, to, 3);
        let stream = repack_stream(&[w], from, to, 6);
        assert_eq!(vec![out0, out1], stream);
    }

    #[test]
    fn narrowing_pass_uses_both_window_words() {
        let from = fmt(8);
        let to = fmt(4);
        let vals: Vec<i64> = (0..12).map(|i| (i * 21 % 256) - 128).collect();
        let ws = pack_stream(&vals, from);
        let window = ws[0] as u128 | ((ws[1] as u128) << 48);
        let out = crossbar_pass(window, from, to, 0);
        let stream = repack_stream(&ws, from, to, 12);
        assert_eq!(out, stream[0]);
    }

    #[test]
    fn sixteen_to_four_chains_through_eight() {
        let chain = conversion_chain(fmt(16), fmt(4));
        assert_eq!(chain.len(), 2);
        assert_eq!(chain[0].0.bits, 16);
        assert_eq!(chain[1].1.bits, 4);
        // And the value semantics still hold.
        let vals: Vec<i64> = vec![0x7FFF, -0x8000, 0x1234];
        let w = pack_stream(&vals, fmt(16));
        let out = repack_stream(&w, fmt(16), fmt(4), 3);
        let got = unpack_stream(&out, fmt(4), 3);
        assert_eq!(got, vec![7, -8, 1]); // top-4-bit truncation
    }

    #[test]
    fn widen_double_matches_repack_word() {
        let mut state = 0x1234_5678_9ABCu64;
        for (a, b) in [(4u32, 8u32), (6, 12), (8, 16)] {
            let (fa, fb) = (fmt(a), fmt(b));
            for _ in 0..200 {
                // xorshift-ish scramble
                state ^= state << 13;
                state ^= state >> 7;
                state ^= state << 17;
                let w = state & crate::bits::format::WORD_MASK;
                let (lo, hi) = widen_double(w, fa);
                let want = repack_word(w, fa, fb);
                assert_eq!(vec![lo, hi], want, "{fa}->{fb} w={w:#x}");
            }
        }
    }

    #[test]
    fn repack_hop_into_matches_canonical_stream_on_every_direct_pair() {
        // The word-level gather must agree with the canonical per-value
        // repack for every direct hop, at full, partial, and multi-word
        // stream lengths.
        let mut state = 0xD00D_F00D_1234u64;
        let mut dst = Vec::new();
        for a in SimdFormat::all() {
            for b in SimdFormat::all() {
                if a == b || !is_direct(a, b) {
                    continue;
                }
                for n_words in [1usize, 2, 5] {
                    let words: Vec<u64> = (0..n_words)
                        .map(|_| {
                            state ^= state << 13;
                            state ^= state >> 7;
                            state ^= state << 17;
                            state & crate::bits::format::WORD_MASK
                        })
                        .collect();
                    let full = n_words * a.lanes() as usize;
                    for count in [full, full - 1, full / 2 + 1] {
                        repack_hop_into(&words, a, b, count, &mut dst);
                        assert_eq!(
                            dst,
                            repack_stream(&words, a, b, count),
                            "{a}->{b} count {count}"
                        );
                    }
                }
            }
        }
    }

    /// The wide gather is a drop-in for the scalar one on every direct
    /// pair (DESIGN.md §16) — full words, tile tails and the zero-padded
    /// partial final word included.
    #[cfg(feature = "simd")]
    #[test]
    fn repack_hop_into_wide_matches_scalar_on_every_direct_pair() {
        let mut state = 0xD00D_F00D_5678u64;
        let mut scalar = Vec::new();
        let mut wide = Vec::new();
        for a in SimdFormat::all() {
            for b in SimdFormat::all() {
                if a == b || !is_direct(a, b) {
                    continue;
                }
                for n_words in [1usize, 2, 5, 9] {
                    let words: Vec<u64> = (0..n_words)
                        .map(|_| {
                            state ^= state << 13;
                            state ^= state >> 7;
                            state ^= state << 17;
                            state & crate::bits::format::WORD_MASK
                        })
                        .collect();
                    let full = n_words * a.lanes() as usize;
                    for count in [full, full - 1, full / 2 + 1, 1] {
                        repack_hop_into(&words, a, b, count, &mut scalar);
                        repack_hop_into_wide(&words, a, b, count, &mut wide);
                        assert_eq!(wide, scalar, "{a}->{b} count {count}");
                    }
                }
            }
        }
    }

    #[test]
    fn repack_cycles_counts_hops() {
        // 8→16 on one word: 6 sub-words → 2 output words → 2 cycles.
        assert_eq!(repack_cycles(1, fmt(8), fmt(16)), 2);
        // bypass: 1 cycle per word.
        assert_eq!(repack_cycles(3, fmt(8), fmt(8)), 3);
        // 16→4 via 8: 3 sub-words: hop1 out = ceil(3·8/48)=1, hop2 out = ceil(3·4/48)=1 → 2.
        assert_eq!(repack_cycles(1, fmt(16), fmt(4)), 2);
    }
}
