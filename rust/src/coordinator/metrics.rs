//! Serving metrics.

use std::sync::atomic::{AtomicU64, Ordering};

/// Shared counters (lock-free; updated by PE workers).
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub rows: AtomicU64,
    pub subword_mults: AtomicU64,
    pub s1_cycles: AtomicU64,
    pub s2_passes: AtomicU64,
    /// Simulated energy, femto-joules (integer for atomic accumulation).
    pub energy_fj: AtomicU64,
    /// Wall time spent in PE compute, nanoseconds.
    pub compute_ns: AtomicU64,
}

impl Metrics {
    pub fn add_batch(&self, rows: u64, stats: crate::coordinator::engine::EngineStats, pj: f64, ns: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(rows, Ordering::Relaxed);
        self.subword_mults.fetch_add(stats.subword_mults, Ordering::Relaxed);
        self.s1_cycles.fetch_add(stats.s1_cycles, Ordering::Relaxed);
        self.s2_passes.fetch_add(stats.s2_passes, Ordering::Relaxed);
        self.energy_fj.fetch_add((pj * 1000.0) as u64, Ordering::Relaxed);
        self.compute_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn report(&self) -> String {
        let rows = self.rows.load(Ordering::Relaxed);
        let mults = self.subword_mults.load(Ordering::Relaxed);
        let cycles = self.s1_cycles.load(Ordering::Relaxed);
        let pj = self.energy_fj.load(Ordering::Relaxed) as f64 / 1000.0;
        let ns = self.compute_ns.load(Ordering::Relaxed).max(1);
        format!(
            "requests={} batches={} rows={} subword_mults={} s1_cycles={} \
             s2_passes={} sim_energy={:.2} nJ mean_pJ/mult={:.3} \
             host_throughput={:.1} Mmult/s",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            rows,
            mults,
            cycles,
            self.s2_passes.load(Ordering::Relaxed),
            pj / 1000.0,
            if mults > 0 { pj / mults as f64 } else { 0.0 },
            mults as f64 / (ns as f64 / 1000.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let m = Metrics::default();
        let stats = crate::coordinator::engine::EngineStats {
            s1_cycles: 10,
            s2_passes: 2,
            acc_adds: 5,
            subword_mults: 60,
        };
        m.add_batch(6, stats, 1.5, 100);
        m.add_batch(6, stats, 1.5, 100);
        assert_eq!(m.rows.load(Ordering::Relaxed), 12);
        assert_eq!(m.subword_mults.load(Ordering::Relaxed), 120);
        assert!(m.report().contains("rows=12"));
    }
}
