//! Lane-safety verifier integration tests (DESIGN.md §14).
//!
//! The static side is exercised unconditionally: the standard serving
//! trio must verify on both synthetic stacks, an under-provisioned
//! schedule must be rejected with a *working* counterexample, and every
//! analyzer-accepted random (stack, schedule) pair must shadow-execute
//! random batches without a single wrap.
//!
//! Under `--features lanecheck` the dynamic sanitizer becomes the
//! oracle for the same claims on the *packed engine itself*: accepted
//! pairs run thousands of rows with zero recorded violations
//! (soundness), and rejected pairs' synthesized counterexamples trip
//! the sanitizer when executed (the rejection is no false alarm).

use softsimd::analysis::{find_first_wrap, verify_stack, AnalysisError, WrapEvent};
use softsimd::coordinator::model::{CompileError, CompiledModel, VariantSpec};
use softsimd::nn::conv::LayerOp;
use softsimd::nn::weights::{uniform_schedule, LayerPrecision, QuantLayer};
use softsimd::testutil::{random_batch, random_schedule};
use softsimd::workload::synth::{synth_cnn_stack, synth_mlp_stack, XorShift64};

/// 32 taps of +0.25 into each of 4 columns: the worst-case widened sum
/// needs 11 bits against the 8 a uniform 8→8 schedule provides, so the
/// verifier must reject it.
fn wide_fanin(sign: i64) -> Vec<LayerOp> {
    vec![LayerOp::Dense(QuantLayer::new(vec![vec![sign * 32; 4]; 32], 8))]
}

/// The same ±0.25 weights at a 4-row fan-in: the worst-case sum uses
/// the 8-bit accumulator exactly (margin 0), so the verifier accepts
/// it — the fixture above is rejected for its fan-in, not its formats.
fn narrow_fanin() -> Vec<LayerOp> {
    vec![LayerOp::Dense(QuantLayer::new(vec![vec![32; 4]; 4], 8))]
}

/// A random sparse-sign dense stack in the synth-workload idiom: per
/// output column, three ±2^(w_bits−3) taps at random rows — the weight
/// family the analyzer accepts across most random schedules.
fn random_sparse_stack(rng: &mut XorShift64, dims: &[usize]) -> Vec<QuantLayer> {
    dims.windows(2)
        .map(|d| {
            let (k, n) = (d[0], d[1]);
            let w_bits = [4u32, 6, 8][(rng.next_u64() % 3) as usize];
            let quarter = 1i64 << (w_bits - 3);
            let mut w = vec![vec![0i64; n]; k];
            for col in 0..n {
                for _ in 0..3 {
                    let row = (rng.next_u64() % k as u64) as usize;
                    w[row][col] =
                        if rng.next_u64() & 1 == 0 { quarter } else { -quarter };
                }
            }
            QuantLayer::new(w, w_bits)
        })
        .collect()
}

#[test]
fn standard_trio_is_proven_safe_on_both_synth_stacks() {
    let stacks = [
        ("synth-mlp", synth_mlp_stack(8)),
        ("synth-cnn", synth_cnn_stack(0x5C4EF, 8)),
    ];
    for (name, stack) in &stacks {
        for spec in VariantSpec::standard_trio(stack.len()) {
            let report = verify_stack(stack, &spec.schedule).unwrap_or_else(|e| {
                panic!("{name} / {} must verify: {e}", spec.name)
            });
            assert_eq!(report.layers.len(), stack.len(), "{name} / {}", spec.name);
            for m in &report.layers {
                assert!(
                    m.needed_bits <= m.precision.acc_bits,
                    "{name} / {} layer {}",
                    spec.name,
                    m.layer
                );
            }
        }
    }
    // The matched-filter MLP margins are pinned: the first layer uses
    // its accumulator exactly (margin 0) and the ×0.5 diagonal head
    // keeps a guard bit at every operating point.
    let mlp = synth_mlp_stack(8);
    for spec in VariantSpec::standard_trio(2) {
        let report = verify_stack(&mlp, &spec.schedule).unwrap();
        assert_eq!(report.layers[0].margin_bits, 0, "{}", spec.name);
        assert_eq!(report.min_margin_bits(), 0, "{}", spec.name);
        assert!(report.layers[1].margin_bits >= 1, "{}", spec.name);
    }
}

#[test]
fn under_provisioned_schedule_is_rejected_with_a_working_counterexample() {
    let hot = wide_fanin(1);
    let sched = uniform_schedule(8, 8, 1);
    let err = verify_stack(&hot, &sched).expect_err("needs 11 bits, got 8");
    match &err {
        AnalysisError::AccumulatorOverflow { layer, acc_bits, needed_bits, .. } => {
            assert_eq!(*layer, 0);
            assert_eq!(*acc_bits, 8);
            assert_eq!(*needed_bits, 11);
        }
        other => panic!("expected AccumulatorOverflow, got {other}"),
    }
    let cx = err.counterexample().expect("layer-0 rejection synthesizes a row");
    assert_eq!(cx.len(), 32);
    match find_first_wrap(&hot, &sched, cx) {
        Some(WrapEvent::Accumulator { layer: 0, .. }) => {}
        other => panic!("counterexample must replay an accumulator wrap, got {other:?}"),
    }
    // No accumulator format rescues this fan-in: Q1 widening is
    // value-preserving (products shift left with the format), so the
    // needed width grows in lockstep with `acc_bits`. What makes the
    // same weights provable is trimming the fan-in.
    assert!(verify_stack(&hot, &uniform_schedule(8, 16, 1)).is_err());
    let ok = verify_stack(&narrow_fanin(), &sched).unwrap();
    assert_eq!(ok.min_margin_bits(), 0, "a 4-tap ±0.25 column fits exactly");
}

#[test]
fn verified_compile_is_a_typed_error_while_plain_compile_defers() {
    let specs = || vec![VariantSpec::new("hot", uniform_schedule(8, 8, 1))];
    match CompiledModel::compile_variants_verified(wide_fanin(1), specs()) {
        Err(CompileError::Unsafe { variant, error }) => {
            assert_eq!(variant, "hot");
            assert_eq!(error.layer(), 0);
            assert!(error.counterexample().is_some());
        }
        Err(other) => panic!("expected Unsafe, got {other}"),
        Ok(_) => panic!("under-provisioned schedule must not verify"),
    }
    // The plain path still compiles it (existing callers are untouched)
    // and reports the verdict lazily.
    let m = CompiledModel::compile_variants(wide_fanin(1), specs()).unwrap();
    assert!(m.lane_safety(0).is_err());
    // A provable fixture passes the verified path end to end.
    let m = CompiledModel::compile_variants_verified(
        narrow_fanin(),
        vec![VariantSpec::new("safe", uniform_schedule(8, 8, 1))],
    )
    .expect("a 4-tap ±0.25 column fits an 8-bit accumulator exactly");
    assert!(m.lane_safety(0).is_ok());
}

#[test]
fn accepted_random_pairs_never_wrap_in_shadow_execution() {
    let mut rng = XorShift64::new(0x1A4E_5AFE);
    let mut accepted = 0usize;
    for _ in 0..60 {
        let layers = random_sparse_stack(&mut rng, &[8, 6, 4]);
        let sched: Vec<LayerPrecision> = random_schedule(&mut rng, layers.len());
        let ops: Vec<LayerOp> = layers.into_iter().map(LayerOp::Dense).collect();
        if verify_stack(&ops, &sched).is_err() {
            continue;
        }
        accepted += 1;
        for row in random_batch(&mut rng, 10, 8, sched[0].in_bits) {
            assert_eq!(
                find_first_wrap(&ops, &sched, &row),
                None,
                "analyzer accepted a pair that wraps on {row:?}"
            );
        }
    }
    assert!(accepted >= 20, "only {accepted}/60 random pairs accepted");
}

/// The dynamic-oracle half: only meaningful when the SWAR primitives
/// are instrumented.
#[cfg(feature = "lanecheck")]
mod lanecheck_oracle {
    use super::*;
    use softsimd::bits::lanecheck;
    use softsimd::coordinator::engine::PackedEngine;
    use softsimd::testutil::{compiled_for, engine_uniform};

    #[test]
    fn accepted_pairs_run_clean_under_the_sanitizer() {
        let mut rng = XorShift64::new(0xC1EA_0A7E);
        let mut accepted = 0usize;
        let mut rows_run = 0usize;
        for _ in 0..60 {
            let layers = random_sparse_stack(&mut rng, &[8, 6, 4]);
            let sched: Vec<LayerPrecision> = random_schedule(&mut rng, layers.len());
            let ops: Vec<LayerOp> =
                layers.iter().cloned().map(LayerOp::Dense).collect();
            if verify_stack(&ops, &sched).is_err() {
                continue;
            }
            accepted += 1;
            let engine = PackedEngine::new(compiled_for(layers, sched.clone()));
            lanecheck::reset();
            for _ in 0..5 {
                let batch = random_batch(&mut rng, 10, 8, sched[0].in_bits);
                rows_run += batch.len();
                engine.forward_batch(&batch);
            }
            assert_eq!(
                lanecheck::count(),
                0,
                "sanitizer tripped on an analyzer-accepted pair: {:?}",
                lanecheck::take()
            );
        }
        assert!(accepted >= 20, "only {accepted}/60 random pairs accepted");
        assert!(rows_run >= 1000, "only {rows_run} rows executed");
    }

    #[test]
    fn rejected_counterexamples_trip_the_sanitizer() {
        for sign in [1i64, -1] {
            let hot = wide_fanin(sign);
            let sched = uniform_schedule(8, 8, 1);
            let err = verify_stack(&hot, &sched).expect_err("unsafe fixture");
            let cx = err.counterexample().expect("synthesized row").to_vec();
            let layers = vec![QuantLayer::new(vec![vec![sign * 32; 4]; 32], 8)];
            let engine = engine_uniform(layers, 8, 8);
            lanecheck::reset();
            engine.forward_batch(&[cx]);
            assert!(
                lanecheck::count() > 0,
                "counterexample (sign {sign}) must wrap a lane in the engine"
            );
            assert!(
                lanecheck::take().iter().any(|v| matches!(
                    v.kind,
                    lanecheck::ViolationKind::AddOverflow
                        | lanecheck::ViolationKind::SubOverflow
                )),
                "the wrap is an accumulate overflow"
            );
        }
    }
}
