//! Coordinator integration: packed serving vs the scalar reference and
//! the AOT model, shared-plan compilation accounting, dispatch policies,
//! deadline flushing, failure injection, and metrics consistency.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use softsimd::coordinator::cost::CostTable;
use softsimd::coordinator::engine::PackedEngine;
use softsimd::coordinator::model::CompiledModel;
use softsimd::coordinator::server::{
    Coordinator, DispatchPolicy, Request, ServeConfig,
};
use softsimd::nn::exec::{mlp_forward_row, mlp_forward_row_planned, precompute_plans};
use softsimd::nn::weights::QuantLayer;
use softsimd::workload::synth::{Digits, XorShift64};

fn cost() -> CostTable {
    CostTable {
        mhz: 1000.0,
        s1_cycle_pj: softsimd::bits::format::FORMATS.iter().map(|&b| (b, 1.0)).collect(),
        s2_pass_pj: 0.5,
        area_um2: 4600.0,
    }
}

fn random_model(rng: &mut XorShift64, dims: &[usize]) -> Vec<QuantLayer> {
    dims.windows(2)
        .map(|w| {
            QuantLayer::new(
                (0..w[0])
                    .map(|_| (0..w[1]).map(|_| rng.q_raw(8)).collect())
                    .collect(),
                8,
            )
        })
        .collect()
}

#[test]
fn coordinator_bit_exact_across_pe_counts_batch_targets_and_policies() {
    let mut rng = XorShift64::new(0xC001);
    let layers = random_model(&mut rng, &[12, 8, 4]);
    let model = CompiledModel::compile(layers.clone(), 8, 16).unwrap();
    let reqs: Vec<Request> = (0..20u64)
        .map(|id| Request {
            id,
            rows: (0..1 + (id as usize % 4))
                .map(|_| (0..12).map(|_| rng.q_raw(8)).collect())
                .collect(),
        })
        .collect();
    let expected: Vec<Vec<Vec<i64>>> = reqs
        .iter()
        .map(|r| r.rows.iter().map(|row| mlp_forward_row(row, &layers, 8, 16)).collect())
        .collect();
    for policy in [DispatchPolicy::RoundRobin, DispatchPolicy::LeastLoaded] {
        for n_pes in [1usize, 2, 4] {
            for target in [1usize, 6, 13, 64] {
                let cfg = ServeConfig::new(n_pes, target).policy(policy);
                let mut coord =
                    Coordinator::start(Arc::clone(&model), cfg, cost()).unwrap();
                for r in &reqs {
                    coord.submit(r.clone()).unwrap();
                }
                let responses = coord.drain().unwrap();
                assert_eq!(
                    responses.len(),
                    reqs.len(),
                    "pes={n_pes} target={target} {policy:?}"
                );
                for resp in &responses {
                    assert_eq!(
                        resp.logits, expected[resp.id as usize],
                        "pes={n_pes} target={target} {policy:?} req={}",
                        resp.id
                    );
                }
                coord.shutdown();
            }
        }
    }
}

#[test]
fn deadline_thread_flushes_stragglers_without_drain() {
    let mut rng = XorShift64::new(0xDEAD1);
    let layers = random_model(&mut rng, &[6, 4]);
    let model = CompiledModel::compile(layers, 8, 16).unwrap();
    // Target far above what we submit: only the deadline can flush.
    let cfg = ServeConfig::new(1, 1000).deadline(Duration::from_millis(5));
    let mut coord = Coordinator::start(model, cfg, cost()).unwrap();
    coord
        .submit(Request {
            id: 1,
            rows: vec![(0..6).map(|_| rng.q_raw(8)).collect()],
        })
        .unwrap();
    // Without calling drain(), the straggler must flush and execute.
    let t0 = Instant::now();
    while coord.metrics.batches.load(Ordering::Relaxed) == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "deadline flush never fired"
        );
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(coord.pending_rows(), 0);
    let responses = coord.drain().unwrap();
    assert_eq!(responses.len(), 1);
    coord.shutdown();
}

#[test]
fn killed_worker_drains_gracefully_and_serving_continues() {
    let mut rng = XorShift64::new(0x5117);
    let layers = random_model(&mut rng, &[8, 5, 3]);
    let model = CompiledModel::compile(layers.clone(), 8, 16).unwrap();
    let mut coord = Coordinator::start(model, ServeConfig::new(2, 4), cost()).unwrap();
    // Kill one of the two PEs up front, then serve a full load.
    coord.kill_worker(0);
    let reqs: Vec<Request> = (0..24u64)
        .map(|id| Request {
            id,
            rows: vec![(0..8).map(|_| rng.q_raw(8)).collect()],
        })
        .collect();
    for r in &reqs {
        coord.submit(r.clone()).unwrap();
    }
    let responses = coord.drain().expect("drain survives a dead worker");
    assert_eq!(responses.len(), reqs.len());
    for resp in &responses {
        let want = mlp_forward_row(&reqs[resp.id as usize].rows[0], &layers, 8, 16);
        assert_eq!(resp.logits[0], want, "req {}", resp.id);
    }
    coord.shutdown();
}

#[test]
fn all_workers_dead_surfaces_error_not_panic() {
    let mut rng = XorShift64::new(0xA11D);
    let layers = random_model(&mut rng, &[4, 2]);
    let model = CompiledModel::compile(layers, 8, 16).unwrap();
    let mut coord = Coordinator::start(model, ServeConfig::new(1, 4), cost()).unwrap();
    coord.kill_worker(0);
    // Submitting below target succeeds (batched); the flush at drain
    // finds no live worker and reports it instead of panicking.
    coord
        .submit(Request {
            id: 1,
            rows: vec![(0..4).map(|_| rng.q_raw(8)).collect()],
        })
        .unwrap();
    let err = coord.drain().expect_err("no live workers");
    let msg = err.to_string();
    assert!(msg.contains("no live PE workers"), "{msg}");
    // The rows were restored, not dropped.
    assert_eq!(coord.pending_rows(), 1);
    coord.shutdown();
}

#[test]
fn kill_revive_serve_round_trip_restores_capacity() {
    // The worker-lifecycle satellite (DESIGN.md §13): kill → revive →
    // serve. A revived slot gets a fresh thread and a fresh bounded
    // queue; responses served after the revive are bit-exact, and
    // reviving a live (or out-of-range) worker is a refused no-op —
    // two workers must never share a slot.
    let mut rng = XorShift64::new(0x4E117E);
    let layers = random_model(&mut rng, &[8, 5, 3]);
    let model = CompiledModel::compile(layers.clone(), 8, 16).unwrap();
    let mut coord = Coordinator::start(model, ServeConfig::new(2, 4), cost()).unwrap();
    assert!(!coord.revive_worker(0), "a live worker must not be revived");
    assert!(!coord.revive_worker(99), "an out-of-range slot is a no-op");
    coord.kill_worker(0);
    // First wave: the surviving PE carries the load.
    let reqs: Vec<Request> = (0..8u64)
        .map(|id| Request {
            id,
            rows: vec![(0..8).map(|_| rng.q_raw(8)).collect()],
        })
        .collect();
    for r in &reqs {
        coord.submit(r.clone()).unwrap();
    }
    let responses = coord.drain().expect("one PE still serves");
    assert_eq!(responses.len(), reqs.len());
    // Rolling restart completes: the dead slot comes back.
    assert!(coord.revive_worker(0), "a killed worker must revive");
    assert!(!coord.revive_worker(0), "the revived worker is live again");
    // Second wave at full capacity, bit-exact.
    let reqs: Vec<Request> = (100..124u64)
        .map(|id| Request {
            id,
            rows: vec![(0..8).map(|_| rng.q_raw(8)).collect()],
        })
        .collect();
    for r in &reqs {
        coord.submit(r.clone()).unwrap();
    }
    let responses = coord.drain().expect("revived pool serves");
    assert_eq!(responses.len(), reqs.len());
    for resp in &responses {
        let want =
            mlp_forward_row(&reqs[(resp.id - 100) as usize].rows[0], &layers, 8, 16);
        assert_eq!(resp.logits[0], want, "req {}", resp.id);
    }
    coord.shutdown();
}

#[test]
fn revive_recovers_a_fully_dead_pool() {
    // All PEs dead surfaces NoLiveWorkers with the rows restored (not
    // dropped); reviving the slot then serves exactly those rows.
    let mut rng = XorShift64::new(0x4E117F);
    let layers = random_model(&mut rng, &[4, 2]);
    let model = CompiledModel::compile(layers.clone(), 8, 16).unwrap();
    let mut coord = Coordinator::start(model, ServeConfig::new(1, 4), cost()).unwrap();
    coord.kill_worker(0);
    let row: Vec<i64> = (0..4).map(|_| rng.q_raw(8)).collect();
    coord.submit(Request { id: 7, rows: vec![row.clone()] }).unwrap();
    let err = coord.drain().expect_err("no live workers");
    assert!(err.to_string().contains("no live PE workers"), "{err}");
    assert_eq!(coord.pending_rows(), 1, "rows restored, not dropped");
    assert!(coord.revive_worker(0));
    let responses = coord.drain().expect("revived pool serves the restored rows");
    assert_eq!(responses.len(), 1);
    assert_eq!(responses[0].logits[0], mlp_forward_row(&row, &layers, 8, 16));
    coord.shutdown();
}

#[test]
fn malformed_requests_are_rejected_not_worker_killing() {
    let mut rng = XorShift64::new(0xBAD1);
    let layers = random_model(&mut rng, &[6, 3]);
    let model = CompiledModel::compile(layers.clone(), 8, 16).unwrap();
    let mut coord = Coordinator::start(model, ServeConfig::new(1, 4), cost()).unwrap();
    // Wrong row width, empty request, and out-of-range raw values must
    // all bounce at submit instead of panicking the PE worker.
    let bad = [
        Request { id: 100, rows: vec![vec![0; 5]] },
        Request { id: 101, rows: vec![] },
        Request { id: 102, rows: vec![vec![0, 0, 0, 0, 0, 200]] },
    ];
    for req in bad {
        let err = coord.submit(req).expect_err("must be rejected");
        assert!(err.to_string().contains("invalid request"), "{err}");
    }
    // The worker is still alive and serves valid traffic afterwards.
    let rows: Vec<i64> = (0..6).map(|_| rng.q_raw(8)).collect();
    coord.submit(Request { id: 0, rows: vec![rows.clone()] }).unwrap();
    let responses = coord.drain().unwrap();
    assert_eq!(responses.len(), 1);
    assert_eq!(responses[0].logits[0], mlp_forward_row(&rows, &layers, 8, 16));
    coord.shutdown();
}

#[test]
fn drain_returns_completed_work_even_with_no_live_workers() {
    let mut rng = XorShift64::new(0xA11E);
    let layers = random_model(&mut rng, &[4, 2]);
    let model = CompiledModel::compile(layers, 8, 16).unwrap();
    // target 1: the first request dispatches and completes immediately.
    let mut coord = Coordinator::start(model, ServeConfig::new(1, 1), cost()).unwrap();
    coord
        .submit(Request {
            id: 1,
            rows: vec![(0..4).map(|_| rng.q_raw(8)).collect()],
        })
        .unwrap();
    // Wait until the worker has finished the dispatched batch.
    let t0 = Instant::now();
    while coord.metrics.batches.load(Ordering::Relaxed) == 0 {
        assert!(t0.elapsed() < Duration::from_secs(5), "batch never ran");
        std::thread::sleep(Duration::from_millis(1));
    }
    coord.kill_worker(0);
    // A second request can only be batched; its flush at drain fails.
    // The completed response from request 1 must still come back.
    let err = coord
        .submit(Request {
            id: 2,
            rows: vec![(0..4).map(|_| rng.q_raw(8)).collect()],
        })
        .err();
    // Depending on timing the submit itself may already see the dead
    // worker (target 1 dispatches immediately); both shapes are valid.
    match err {
        None => {}
        Some(e) => assert!(e.to_string().contains("no live PE workers"), "{e}"),
    }
    match coord.drain() {
        Err(softsimd::coordinator::ServeError::NoLiveWorkers { recovered }) => {
            assert_eq!(recovered.len(), 1, "completed response must be recovered");
            assert_eq!(recovered[0].id, 1);
        }
        Ok(responses) => {
            // If the worker processed request 1's response collection
            // path before dying there is nothing pending: also fine,
            // as long as the completed response is not stranded.
            assert!(responses.iter().any(|r| r.id == 1));
        }
        Err(e) => panic!("unexpected error shape: {e}"),
    }
    coord.shutdown();
}

#[test]
fn engine_handles_singleton_and_ragged_batches() {
    let mut rng = XorShift64::new(0xC002);
    let layers = random_model(&mut rng, &[7, 5, 3]);
    let engine = PackedEngine::new(CompiledModel::compile(layers.clone(), 8, 16).unwrap());
    for m in 1..=13usize {
        let batch: Vec<Vec<i64>> = (0..m)
            .map(|_| (0..7).map(|_| rng.q_raw(8)).collect())
            .collect();
        let (got, _) = engine.forward_batch(&batch);
        for (b, row) in batch.iter().enumerate() {
            assert_eq!(got[b], mlp_forward_row(row, &layers, 8, 16), "m={m} b={b}");
        }
    }
}

#[test]
fn planned_and_unplanned_reference_agree_on_aot_model() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/mlp_weights.txt");
    if !path.exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let layers = softsimd::nn::weights::load_weight_file(&path).unwrap();
    let plans = precompute_plans(&layers);
    let digits = Digits::standard();
    let (xs, _) = digits.sample(8, 0.3, 0xABCD);
    for row in &xs {
        assert_eq!(
            mlp_forward_row(row, &layers, 8, 16),
            mlp_forward_row_planned(row, &layers, &plans, 8, 16)
        );
    }
}

#[test]
fn metrics_account_every_row_mult_and_latency() {
    let mut rng = XorShift64::new(0xC003);
    let layers = random_model(&mut rng, &[6, 4]);
    let model = CompiledModel::compile(layers, 8, 16).unwrap();
    let mut coord = Coordinator::start(model, ServeConfig::new(2, 5), cost()).unwrap();
    let n_rows = 17u64;
    for id in 0..n_rows {
        coord
            .submit(Request {
                id,
                rows: vec![(0..6).map(|_| rng.q_raw(8)).collect()],
            })
            .unwrap();
    }
    let _ = coord.drain().unwrap();
    assert_eq!(coord.metrics.rows.load(Ordering::Relaxed), n_rows);
    assert_eq!(coord.metrics.requests.load(Ordering::Relaxed), n_rows);
    // Energy must be positive and cycles consistent with plan lengths.
    assert!(coord.metrics.energy_fj() > 0.0);
    assert!(coord.metrics.s1_cycles.load(Ordering::Relaxed) > 0);
    // Every request's latency was observed, and the percentiles order.
    let p50 = coord.metrics.latency_quantile_ns(0.50).expect("latencies recorded");
    let p99 = coord.metrics.latency_quantile_ns(0.99).unwrap();
    assert!(p50 <= p99);
    assert!(coord.metrics.rows_per_sec() > 0.0);
    coord.shutdown();
}

#[test]
fn empty_drain_is_safe() {
    let mut rng = XorShift64::new(0xC004);
    let layers = random_model(&mut rng, &[4, 2]);
    let model = CompiledModel::compile(layers, 8, 16).unwrap();
    let mut coord = Coordinator::start(model, ServeConfig::new(1, 4), cost()).unwrap();
    assert!(coord.drain().unwrap().is_empty());
    coord.shutdown();
}

#[test]
fn coordinator_matches_aot_golden_when_artifacts_exist() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let layers = softsimd::nn::weights::load_weight_file(dir.join("mlp_weights.txt")).unwrap();
    // Parse the golden mlp rows.
    let text = std::fs::read_to_string(dir.join("golden.txt")).unwrap();
    let mut inputs: Vec<(usize, Vec<i64>)> = vec![];
    let mut outputs: Vec<(usize, Vec<i64>)> = vec![];
    for line in text.lines() {
        let mut it = line.split_whitespace();
        match it.next() {
            Some("mlp_in") => {
                let row: usize = it.next().unwrap().parse().unwrap();
                inputs.push((
                    row,
                    it.next().unwrap().split(',').map(|v| v.parse().unwrap()).collect(),
                ));
            }
            Some("mlp_out") => {
                let row: usize = it.next().unwrap().parse().unwrap();
                outputs.push((
                    row,
                    it.next().unwrap().split(',').map(|v| v.parse().unwrap()).collect(),
                ));
            }
            _ => {}
        }
    }
    let model = CompiledModel::compile(layers, 8, 16).unwrap();
    let mut coord = Coordinator::start(model, ServeConfig::new(2, 8), cost()).unwrap();
    for (row, vals) in &inputs {
        coord
            .submit(Request { id: *row as u64, rows: vec![vals.clone()] })
            .unwrap();
    }
    for resp in coord.drain().unwrap() {
        let want = &outputs.iter().find(|(r, _)| *r == resp.id as usize).unwrap().1;
        assert_eq!(&resp.logits[0], want, "row {}", resp.id);
    }
    coord.shutdown();
}
