//! The immutable, shareable serving model: weights + precompiled CSD
//! multiply plans + packing metadata, built **once** and handed to every
//! PE worker behind an `Arc` (DESIGN.md §8).
//!
//! This is the schedule-amortization idea of the paper's control path
//! (the CSD plan is a property of the *multiplier value*, not of the
//! operand stream): compiling the per-weight shift-add programs is the
//! expensive, quantization-dependent step, so it must happen off the
//! per-request critical path and exactly once per deployed model — not
//! once per worker, as the original demo loop did.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::bits::format::SimdFormat;
use crate::csd::schedule::MulPlan;
use crate::nn::weights::QuantLayer;

/// Process-wide count of [`CompiledModel::compile`] runs. Exists so
/// tests can assert that plan compilation happens exactly once per
/// model no matter how many PE workers serve it.
pub static PLAN_COMPILATIONS: AtomicU64 = AtomicU64::new(0);

/// An immutable compiled model: quantized layers plus every per-weight
/// [`MulPlan`], shared across all PE workers via [`Arc`].
#[derive(Debug)]
pub struct CompiledModel {
    layers: Vec<QuantLayer>,
    /// `plans[layer][k][n]`, precompiled for every weight.
    plans: Vec<Vec<Vec<MulPlan>>>,
    in_bits: u32,
    acc_bits: u32,
    /// Total Stage-1 cycles of one forward pass per packed word column
    /// (sum of plan cycles over all weights) — scheduling metadata for
    /// load estimates.
    cycles_per_word: u64,
    /// Count of zero weights (zero-skipped at execution).
    zero_weights: u64,
}

impl CompiledModel {
    /// Compile all CSD multiply plans for `layers`. Call once per model;
    /// clone the returned [`Arc`], never the model.
    pub fn compile(layers: Vec<QuantLayer>, in_bits: u32, acc_bits: u32) -> Arc<CompiledModel> {
        assert!(!layers.is_empty(), "model needs at least one layer");
        // Validate the format pair up front so workers never do.
        let _ = SimdFormat::new(in_bits);
        let _ = SimdFormat::new(acc_bits);
        PLAN_COMPILATIONS.fetch_add(1, Ordering::SeqCst);
        let plans = crate::nn::exec::precompute_plans(&layers);
        let mut cycles_per_word = 0u64;
        let mut zero_weights = 0u64;
        for layer_plans in &plans {
            for row in layer_plans {
                for plan in row {
                    if plan.ops.is_empty() {
                        zero_weights += 1;
                    } else {
                        cycles_per_word += plan.cycles() as u64;
                    }
                }
            }
        }
        Arc::new(CompiledModel {
            layers,
            plans,
            in_bits,
            acc_bits,
            cycles_per_word,
            zero_weights,
        })
    }

    pub fn layers(&self) -> &[QuantLayer] {
        &self.layers
    }

    /// The precompiled plan for layer `li`, weight `(k, n)`.
    #[inline]
    pub fn plan(&self, li: usize, k: usize, n: usize) -> &MulPlan {
        &self.plans[li][k][n]
    }

    pub fn in_bits(&self) -> u32 {
        self.in_bits
    }

    pub fn acc_bits(&self) -> u32 {
        self.acc_bits
    }

    pub fn in_fmt(&self) -> SimdFormat {
        SimdFormat::new(self.in_bits)
    }

    pub fn acc_fmt(&self) -> SimdFormat {
        SimdFormat::new(self.acc_bits)
    }

    /// Activation width of the first layer (row length of a request).
    pub fn input_width(&self) -> usize {
        self.layers[0].k
    }

    /// Sub-words per packed activation word (6 at 8-bit).
    pub fn lanes(&self) -> usize {
        self.in_fmt().lanes() as usize
    }

    /// Stage-1 cycles one packed word column costs across the whole
    /// forward pass (load-estimate metadata).
    pub fn cycles_per_word(&self) -> u64 {
        self.cycles_per_word
    }

    pub fn zero_weights(&self) -> u64 {
        self.zero_weights
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layers() -> Vec<QuantLayer> {
        vec![
            QuantLayer::new(vec![vec![64, 0], vec![-32, 127]], 8),
            QuantLayer::new(vec![vec![5], vec![-9]], 8),
        ]
    }

    #[test]
    fn compile_counts_and_metadata() {
        let before = PLAN_COMPILATIONS.load(Ordering::SeqCst);
        let m = CompiledModel::compile(layers(), 8, 16);
        assert_eq!(PLAN_COMPILATIONS.load(Ordering::SeqCst), before + 1);
        assert_eq!(m.input_width(), 2);
        assert_eq!(m.lanes(), 6);
        assert_eq!(m.zero_weights(), 1);
        assert!(m.cycles_per_word() > 0);
        assert_eq!(m.plan(0, 0, 0).ops.len(), m.layers()[0].plan(0, 0).ops.len());
    }

    #[test]
    #[should_panic]
    fn rejects_empty_model() {
        let _ = CompiledModel::compile(vec![], 8, 16);
    }
}
