//! Static cost certification of compiled precision variants
//! (DESIGN.md §15).
//!
//! The paper's headline claims are *cost* claims — cycles and energy
//! versus hardware SIMD — yet the engine's billing counters are
//! asserted only dynamically. This module closes the loop the way the
//! lane-safety verifier (§14) did for values: a static pass over the
//! flat [`PlanArena`] bytecode plus one variant's precision schedule
//! emits, at compile time, a **cost certificate** — per layer, the
//! aggregate Stage-1 cycle/add weight of the nonzero plans, the
//! accumulate and widening work, and the boundary crossbar chain —
//! from which every [`EngineStats`] field of any batch is a closed
//! form in the batch row count `m`.
//!
//! Since activation zero-skipping (DESIGN.md §18) the certificate is a
//! certified **upper bound** with an exact conservation law, not a
//! point prediction: [`CostCertificate::eval_stats`] is the *dense*
//! bill — what the engine bills with skipping disabled, and what it
//! would have billed on a batch with no all-zero operand words. The
//! engine's measured `s1_*` counters can only shrink below it, and
//! shrink by **exactly** the `skipped_*` counters it reports
//! (`dense == executed + skipped`, field by field and bucket by
//! bucket); every other counter stays dense-exact.
//! [`CostCertificate::eval_stats_with_skips`] folds a measured batch's
//! skip counters back in to give the exact sparsity-conditioned
//! prediction, and the [`audit`] oracle enforces the conservation law
//! on every executed batch under `--features billaudit`.
//! [`CostCertificate::energy_pj`] prices the dense stats through the
//! same [`CostTable`] arithmetic the serving loop uses — so predicted
//! and measured energy agree to the attojoule on dense batches, and
//! predicted-given-sparsity energy agrees on every batch.
//!
//! **The affine-in-`m` model.** Batches are padded to the variant's
//! batch quantum, so every counter is a function of
//! `blocks = ceil(m / quantum)`. Per quantum block each layer
//! contributes constants (Stage-1 cycles/adds per block, accumulate
//! adds, widening passes); `subword_mults` alone is affine in the
//! *real* row count `m` (pad lanes are never billed as useful work).
//! Boundary hops are the one ceil term: a hop producing format `t`
//! costs `ceil(rows·t.bits / 48) · cols` passes, which is linear in
//! blocks exactly when `quantum · patch_rows · t.bits` divides 48
//! evenly — `eval_stats` keeps the exact `div_ceil`, and the
//! `CERT_costs.json` export flags each hop's linearity.

use crate::bits::format::{format_index, SimdFormat, FORMATS};
use crate::coordinator::cost::CostTable;
use crate::coordinator::engine::EngineStats;
use crate::coordinator::model::Variant;
use crate::csd::flat::PlanArena;
use crate::nn::conv::LayerOp;

/// One layer's certified cost coefficients: everything the closed-form
/// evaluation needs, read once from the arena headers and the variant's
/// schedule — never from the engine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerCost {
    /// Layer index.
    pub layer: usize,
    /// Activation width the layer executes at.
    pub in_bits: u32,
    /// Accumulator width.
    pub acc_bits: u32,
    /// Packed patch rows per batch row: 1 for dense, `out_pixels` for
    /// conv (DESIGN.md §12).
    pub patch_rows: usize,
    /// Output columns (`n` of the layer's matmul view).
    pub cols: usize,
    /// Nonzero plan headers over the `k × n` weight matrix (zero
    /// weights are zero-skipped and bill nothing).
    pub nonzero_plans: u64,
    /// Σ `header.cycles` over the nonzero plans — Stage-1 cycles per
    /// packed word column, summed over the whole layer.
    pub plan_cycles: u64,
    /// Σ `header.adds` over the nonzero plans (CSD nonzero digits).
    pub plan_adds: u64,
    /// The boundary crossbar chain after this layer (empty for the last
    /// layer, and for a Stage-2 bypass).
    pub boundary: Vec<(SimdFormat, SimdFormat)>,
}

/// A compile-time cost certificate for one `(model, variant)` pair:
/// evaluating it at any batch size `m` reproduces the engine's
/// [`EngineStats`] exactly. Built by [`CostCertificate::certify`];
/// memoized on `CompiledModel` alongside the lane-safety verdicts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CostCertificate {
    /// Name of the certified variant.
    pub variant: String,
    /// The variant's batch quantum (batches pad up to a multiple).
    pub batch_quantum: usize,
    /// Per-layer coefficients, in execution order.
    pub layers: Vec<LayerCost>,
}

impl CostCertificate {
    /// Certify one variant from the compiled artifact: the flat plan
    /// headers (cycle/add weights per nonzero weight, read from the
    /// variant's own plan bank — truncated banks certify strictly
    /// cheaper) and the variant's schedule/boundary metadata. Reads no
    /// engine code and executes nothing.
    pub fn certify(layers: &[LayerOp], arena: &PlanArena, var: &Variant) -> CostCertificate {
        debug_assert_eq!(arena.n_layers(), layers.len());
        let per_layer = layers
            .iter()
            .enumerate()
            .map(|(li, layer)| {
                let w = layer.weights();
                debug_assert_eq!(arena.layer_dims(li), (w.k, w.n));
                let p = var.precision(li);
                let mut nonzero_plans = 0u64;
                let mut plan_cycles = 0u64;
                let mut plan_adds = 0u64;
                for n in 0..w.n {
                    for hdr in arena.column_bank(var.plan_bank(), li, n) {
                        if hdr.is_zero() {
                            continue;
                        }
                        nonzero_plans += 1;
                        plan_cycles += hdr.cycles as u64;
                        plan_adds += hdr.adds as u64;
                    }
                }
                let boundary = if li + 1 < layers.len() {
                    var.boundary_chain(li).to_vec()
                } else {
                    Vec::new()
                };
                LayerCost {
                    layer: li,
                    in_bits: p.in_bits,
                    acc_bits: p.acc_bits,
                    patch_rows: layer.patch_rows(),
                    cols: w.n,
                    nonzero_plans,
                    plan_cycles,
                    plan_adds,
                    boundary,
                }
            })
            .collect();
        CostCertificate {
            variant: var.name().to_string(),
            batch_quantum: var.batch_quantum(),
            layers: per_layer,
        }
    }

    /// The engine's **dense** [`EngineStats`] for a batch of `m` rows —
    /// the closed-form evaluation of the certificate, equal to the
    /// measured stats when zero-skipping is off (or no operand word is
    /// all zero). With skipping on, the measured `s1_*` fields fall
    /// below these by exactly the measured `skipped_*` counters
    /// (conservation; see [`eval_stats_with_skips`]) and everything
    /// else still matches exactly.
    ///
    /// [`eval_stats_with_skips`]: CostCertificate::eval_stats_with_skips
    pub fn eval_stats(&self, m: usize) -> EngineStats {
        assert!(m > 0, "empty batch");
        let mp = m.div_ceil(self.batch_quantum) * self.batch_quantum;
        let mut stats = EngineStats {
            pad_rows: (mp - m) as u64,
            ..EngineStats::default()
        };
        for lc in &self.layers {
            let in_fmt = SimdFormat::new(lc.in_bits);
            // Padded packed rows this layer streams (conv folds its
            // output pixels into the batch dimension).
            let rows = mp * lc.patch_rows;
            let cur_words = (rows / in_fmt.lanes() as usize) as u64;
            let acc_words = (rows * lc.acc_bits as usize / 48) as u64;
            let cycles = lc.plan_cycles * cur_words;
            let adds = lc.plan_adds * cur_words;
            let fi = format_index(lc.in_bits);
            stats.s1_cycles += cycles;
            stats.s1_cycles_by_fmt[fi] += cycles;
            stats.s1_adds += adds;
            stats.s1_adds_by_fmt[fi] += adds;
            // Useful multiplies: real rows only, one per nonzero plan.
            stats.subword_mults += lc.nonzero_plans * (m * lc.patch_rows) as u64;
            // Every accumulate path (doubling, equal-width, generic)
            // performs one add per produced accumulator word.
            stats.acc_adds += lc.nonzero_plans * acc_words;
            // Widening products into the accumulator format is one
            // Stage-2 pass per produced word, billed at the produced
            // format; the equal-width path converts nothing.
            if lc.in_bits != lc.acc_bits {
                let passes = lc.nonzero_plans * acc_words;
                stats.s2_passes += passes;
                stats.s2_passes_by_fmt[format_index(lc.acc_bits)] += passes;
            }
            // Boundary chain: one crossbar cycle per word each hop
            // produces, per output column — the exact `div_ceil` the
            // engine bills (non-linear in blocks when the per-block
            // bit count is not a multiple of 48).
            for &(_, t) in &lc.boundary {
                let passes = (rows * t.bits as usize).div_ceil(48) as u64 * lc.cols as u64;
                stats.s2_passes += passes;
                stats.s2_passes_by_fmt[format_index(t.bits)] += passes;
            }
        }
        stats
    }

    /// The exact **sparsity-conditioned** prediction: the dense
    /// [`eval_stats`] with a measured batch's zero-skip savings folded
    /// back in. Given the engine's own `skipped_*` counters (the only
    /// data-dependent inputs), the result must equal the measured stats
    /// field-for-field — the equality the billing auditor's
    /// conservation checks are equivalent to, and what the serving loop
    /// prices for predicted-vs-measured energy parity under sparsity.
    ///
    /// Uses `saturating_sub` so a corrupted skip counter can never
    /// panic the serving path — the auditor records the divergence
    /// instead.
    ///
    /// [`eval_stats`]: CostCertificate::eval_stats
    pub fn eval_stats_with_skips(&self, m: usize, measured: &EngineStats) -> EngineStats {
        let mut stats = self.eval_stats(m);
        stats.s1_cycles = stats.s1_cycles.saturating_sub(measured.skipped_cycles);
        stats.s1_adds = stats.s1_adds.saturating_sub(measured.skipped_adds);
        for fi in 0..FORMATS.len() {
            stats.s1_cycles_by_fmt[fi] =
                stats.s1_cycles_by_fmt[fi].saturating_sub(measured.skipped_cycles_by_fmt[fi]);
            stats.s1_adds_by_fmt[fi] =
                stats.s1_adds_by_fmt[fi].saturating_sub(measured.skipped_adds_by_fmt[fi]);
        }
        stats.skipped_plans = measured.skipped_plans;
        stats.skipped_cycles = measured.skipped_cycles;
        stats.skipped_adds = measured.skipped_adds;
        stats.skipped_cycles_by_fmt = measured.skipped_cycles_by_fmt;
        stats.skipped_adds_by_fmt = measured.skipped_adds_by_fmt;
        stats
    }

    /// Total (nonzero plan × packed word) executions a dense run of `m`
    /// rows performs — the hard cap on [`EngineStats::skipped_plans`]
    /// the auditor enforces.
    pub fn plan_words(&self, m: usize) -> u64 {
        let mp = m.div_ceil(self.batch_quantum) * self.batch_quantum;
        self.layers
            .iter()
            .map(|lc| {
                let rows = mp * lc.patch_rows;
                let cur_words = rows / SimdFormat::new(lc.in_bits).lanes() as usize;
                lc.nonzero_plans * cur_words as u64
            })
            .sum()
    }

    /// Certified batch energy: the predicted stats priced through the
    /// **same** [`CostTable`] arithmetic the serving loop applies to
    /// measured stats — identical floating-point operation sequence,
    /// so equal stats give bit-identical pJ and attojoule-identical
    /// metrics accumulation. This is the **dense** (upper-bound)
    /// figure; for sparsity-conditioned parity price
    /// [`eval_stats_with_skips`] through the table instead.
    ///
    /// [`eval_stats_with_skips`]: CostCertificate::eval_stats_with_skips
    pub fn energy_pj(&self, m: usize, cost: &CostTable) -> f64 {
        cost.batch_energy_pj(&self.eval_stats(m))
    }

    /// Certified energy per row (pJ) at one full batch quantum — the
    /// steady-state figure the predictive governor consults.
    pub fn pj_per_row(&self, cost: &CostTable) -> f64 {
        self.energy_pj(self.batch_quantum, cost) / self.batch_quantum as f64
    }

    /// Certified Stage-1 + Stage-2 datapath cycles per row at one full
    /// batch quantum (the serial drain-time coefficient).
    pub fn cycles_per_row(&self) -> f64 {
        let stats = self.eval_stats(self.batch_quantum);
        (stats.s1_cycles + stats.s2_passes) as f64 / self.batch_quantum as f64
    }
}

/// Differential billing auditor — the dynamic oracle of the static
/// cost certifier (`--features billaudit`; sibling of
/// [`crate::bits::lanecheck`]).
///
/// When enabled, the engine checks **every executed batch's**
/// [`EngineStats`] field-by-field (aggregates and per-format buckets)
/// against the certificate evaluated at that batch's row count, and
/// records each mismatch to a thread-local divergence log —
/// *recorded, never raised*, so a billing drift shows up as auditable
/// evidence instead of a panic inside a PE worker. Tests bracket a
/// region with [`reset`]/[`count`] and assert zero divergences; the
/// mutation test perturbs one counter and asserts the auditor trips.
///
/// [`reset`]: audit::reset
/// [`count`]: audit::count
#[cfg(feature = "billaudit")]
pub mod audit {
    use std::cell::{Cell, RefCell};

    use super::{CostCertificate, EngineStats, FORMATS};

    /// Maximum number of [`Divergence`] records retained per thread;
    /// the total count keeps incrementing past the cap.
    pub const LOG_CAP: usize = 1024;

    /// One billing counter that disagreed with the certificate.
    #[derive(Debug, Clone)]
    pub struct Divergence {
        /// Name of the certified variant the batch executed at.
        pub variant: String,
        /// The `EngineStats` field (or per-format bucket) that diverged.
        pub field: String,
        /// Real row count of the audited batch.
        pub m: usize,
        /// The certificate's value.
        pub expected: u64,
        /// The engine's value.
        pub got: u64,
    }

    thread_local! {
        static DIVERGENCES: RefCell<Vec<Divergence>> = const { RefCell::new(Vec::new()) };
        static TOTAL: Cell<u64> = const { Cell::new(0) };
    }

    /// Clear this thread's divergence log and counter.
    pub fn reset() {
        DIVERGENCES.with(|d| d.borrow_mut().clear());
        TOTAL.with(|t| t.set(0));
    }

    /// Total divergences recorded on this thread since the last
    /// [`reset`] (not capped).
    pub fn count() -> u64 {
        TOTAL.with(|t| t.get())
    }

    /// Drain this thread's detailed divergence log (at most
    /// [`LOG_CAP`] entries; the counter is left untouched).
    pub fn take() -> Vec<Divergence> {
        DIVERGENCES.with(|d| std::mem::take(&mut *d.borrow_mut()))
    }

    fn note(d: Divergence) {
        TOTAL.with(|t| t.set(t.get() + 1));
        DIVERGENCES.with(|log| {
            let mut log = log.borrow_mut();
            if log.len() < LOG_CAP {
                log.push(d);
            }
        });
    }

    /// Differentially check one executed batch's stats against the
    /// certificate at that batch's row count, recording every
    /// divergent field. Never panics.
    ///
    /// **The upper-bound contract (DESIGN.md §18).** Zero-skipping
    /// makes the Stage-1 fields data-dependent, so they are checked via
    /// the conservation law `executed + skipped == dense certificate`
    /// (a `u64` equality, so `measured ≤ predicted` is implied — no
    /// separate inequality check can be laundered past it); every
    /// value-independent field keeps the strict equality. Skip-counter
    /// self-consistency is audited too: the by-format skip buckets must
    /// sum to the aggregates, and `skipped_plans` can never exceed the
    /// dense (plan × word) count.
    pub fn check_batch(cert: &CostCertificate, stats: &EngineStats, m: usize) {
        let want = cert.eval_stats(m);
        let mut check = |field: String, expected: u64, got: u64| {
            if expected != got {
                note(Divergence { variant: cert.variant.clone(), field, m, expected, got });
            }
        };
        // Stage-1: conservation against the dense certificate.
        check(
            "s1_cycles".into(),
            want.s1_cycles,
            stats.s1_cycles + stats.skipped_cycles,
        );
        check("s1_adds".into(), want.s1_adds, stats.s1_adds + stats.skipped_adds);
        // Value-independent counters: strict equality, as before.
        check("s2_passes".into(), want.s2_passes, stats.s2_passes);
        check("acc_adds".into(), want.acc_adds, stats.acc_adds);
        check("subword_mults".into(), want.subword_mults, stats.subword_mults);
        check("pad_rows".into(), want.pad_rows, stats.pad_rows);
        for (i, &bits) in FORMATS.iter().enumerate() {
            check(
                format!("s1_cycles_by_fmt[{bits}b]"),
                want.s1_cycles_by_fmt[i],
                stats.s1_cycles_by_fmt[i] + stats.skipped_cycles_by_fmt[i],
            );
            check(
                format!("s1_adds_by_fmt[{bits}b]"),
                want.s1_adds_by_fmt[i],
                stats.s1_adds_by_fmt[i] + stats.skipped_adds_by_fmt[i],
            );
            check(
                format!("s2_passes_by_fmt[{bits}b]"),
                want.s2_passes_by_fmt[i],
                stats.s2_passes_by_fmt[i],
            );
        }
        // Skip-counter self-consistency: buckets sum to the aggregates…
        check(
            "skipped_cycles_sum".into(),
            stats.skipped_cycles,
            stats.skipped_cycles_by_fmt.iter().sum(),
        );
        check(
            "skipped_adds_sum".into(),
            stats.skipped_adds,
            stats.skipped_adds_by_fmt.iter().sum(),
        );
        // …and no more plan executions can be skipped than a dense run
        // performs.
        let cap = cert.plan_words(m);
        if stats.skipped_plans > cap {
            note(Divergence {
                variant: cert.variant.clone(),
                field: "skipped_plans".into(),
                m,
                expected: cap,
                got: stats.skipped_plans,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::model::{CompiledModel, VariantSpec};
    use crate::nn::weights::LayerPrecision;
    use crate::testutil::{flat_cost, random_dense_stack_uniform};
    use crate::workload::synth::XorShift64;

    #[test]
    fn certificate_is_schedule_aware_and_counts_nonzero_plans_once() {
        let mut rng = XorShift64::new(0xCE47);
        let mut layers = random_dense_stack_uniform(&mut rng, &[5, 4, 3], 8);
        layers[0].w_raw[0][0] = 0; // at least one zero-skip
        let ops: Vec<LayerOp> = layers.into_iter().map(LayerOp::Dense).collect();
        let model =
            CompiledModel::compile_variants(ops, VariantSpec::standard_trio(2)).unwrap();
        for v in 0..model.n_variants() {
            let cert = CostCertificate::certify(model.layers(), model.flat(), model.variant(v));
            assert_eq!(cert.variant, model.variant(v).name());
            assert_eq!(cert.batch_quantum, model.variant(v).batch_quantum());
            assert_eq!(cert.layers.len(), 2);
            for (li, lc) in cert.layers.iter().enumerate() {
                let p = model.variant(v).precision(li);
                assert_eq!((lc.in_bits, lc.acc_bits), (p.in_bits, p.acc_bits));
                let w = model.layers()[li].weights();
                let nonzero = (0..w.k)
                    .flat_map(|k| (0..w.n).map(move |n| (k, n)))
                    .filter(|&(k, n)| w.w_raw[k][n] != 0)
                    .count() as u64;
                assert_eq!(lc.nonzero_plans, nonzero, "variant {v} layer {li}");
                assert!(lc.plan_adds <= lc.plan_cycles);
            }
            // The memoized accessor returns the same certificate.
            assert_eq!(model.cost_certificate(v), &cert);
        }
    }

    #[test]
    fn eval_is_exact_at_every_quantum_phase() {
        // Stats must be a pure function of ceil(m/quantum) except for
        // subword_mults/pad_rows, which are affine in the real m.
        let mut rng = XorShift64::new(0xCE48);
        let layers = random_dense_stack_uniform(&mut rng, &[4, 3], 8);
        let ops: Vec<LayerOp> = layers.into_iter().map(LayerOp::Dense).collect();
        let model = CompiledModel::compile_variants(
            ops,
            vec![VariantSpec::new("u8", vec![LayerPrecision::new(8, 16)])],
        )
        .unwrap();
        let cert = model.cost_certificate(0);
        let q = cert.batch_quantum;
        let full = cert.eval_stats(q);
        for m in 1..=q {
            let s = cert.eval_stats(m);
            assert_eq!(s.s1_cycles, full.s1_cycles, "m={m}");
            assert_eq!(s.acc_adds, full.acc_adds, "m={m}");
            assert_eq!(s.s2_passes, full.s2_passes, "m={m}");
            assert_eq!(s.pad_rows, (q - m) as u64, "m={m}");
            assert_eq!(
                s.subword_mults,
                cert.layers.iter().map(|l| l.nonzero_plans * m as u64).sum::<u64>(),
                "m={m}"
            );
        }
        let two = cert.eval_stats(q + 1);
        assert_eq!(two.s1_cycles, 2 * full.s1_cycles, "second block doubles S1");
    }

    #[test]
    fn skip_conditioned_eval_reconstructs_measured_stats_exactly() {
        use crate::coordinator::engine::PackedEngine;
        let mut rng = XorShift64::new(0xCE50);
        let layers = random_dense_stack_uniform(&mut rng, &[4, 3], 8);
        let ops: Vec<LayerOp> = layers.into_iter().map(LayerOp::Dense).collect();
        let model = CompiledModel::compile_variants(
            ops,
            vec![VariantSpec::new("u8", vec![LayerPrecision::new(8, 16)])],
        )
        .unwrap();
        let cert = model.cost_certificate(0).clone();
        let engine = PackedEngine::new(model.clone());
        // Rows 6..12 are all zero: one of the two packed words per
        // input column skips.
        let batch: Vec<Vec<i64>> = (0..12)
            .map(|i| {
                (0..4)
                    .map(|_| if i < 6 { rng.q_raw(8) } else { 0 })
                    .collect()
            })
            .collect();
        let (_, stats) = engine.forward_batch(&batch);
        assert!(stats.skipped_plans > 0, "half the batch words are zero");
        assert!(stats.skipped_plans <= cert.plan_words(12));
        // Conservation: the dense certificate is exactly executed +
        // skipped on the Stage-1 fields…
        let dense = cert.eval_stats(12);
        assert_eq!(dense.s1_cycles, stats.s1_cycles + stats.skipped_cycles);
        assert_eq!(dense.s1_adds, stats.s1_adds + stats.skipped_adds);
        assert!(stats.s1_cycles < dense.s1_cycles, "upper bound is strict here");
        // …and therefore the sparsity-conditioned prediction is the
        // measured stats, field for field.
        assert_eq!(cert.eval_stats_with_skips(12, &stats), stats);
        // A dense (no-skip) engine matches eval_stats directly.
        let dense_engine = PackedEngine::new(model).with_zero_skip(false);
        let (_, dense_stats) = dense_engine.forward_batch(&batch);
        assert_eq!(dense_stats, dense);
        assert_eq!(cert.eval_stats_with_skips(12, &dense_stats), dense_stats);
    }

    #[test]
    fn per_row_figures_price_through_the_shared_cost_table() {
        let mut rng = XorShift64::new(0xCE49);
        let layers = random_dense_stack_uniform(&mut rng, &[4, 4], 8);
        let ops: Vec<LayerOp> = layers.into_iter().map(LayerOp::Dense).collect();
        let model = CompiledModel::compile_variants(
            ops,
            vec![VariantSpec::new("u8", vec![LayerPrecision::new(8, 16)])],
        )
        .unwrap();
        let cert = model.cost_certificate(0);
        let cost = flat_cost();
        let q = cert.batch_quantum;
        let stats = cert.eval_stats(q);
        // flat_cost: 1 pJ per S1 cycle, 0.5 per S2 pass.
        let want = stats.s1_cycles as f64 + stats.s2_passes as f64 * 0.5;
        assert_eq!(cert.energy_pj(q, &cost), want);
        assert_eq!(cert.pj_per_row(&cost), want / q as f64);
        assert_eq!(
            cert.cycles_per_row(),
            (stats.s1_cycles + stats.s2_passes) as f64 / q as f64
        );
    }
}
