//! Quickstart: the Soft SIMD pipeline in five minutes.
//!
//! Packs Q1.7 values into a 48-bit word, multiplies them all by one
//! CSD-coded multiplier through the two-stage pipeline, repacks the
//! products to 16-bit sub-words, and prices the whole thing with the
//! 28nm cost model.
//!
//! Run: `cargo run --release --example quickstart`

use softsimd::bits::{from_q, to_q, SimdFormat};
use softsimd::csd::encode::{csd_encode, csd_string};
use softsimd::csd::schedule::schedule;
use softsimd::energy::model::SynthesizedSoftPipeline;
use softsimd::isa::{assemble_mul_repack, Instr, Reg};
use softsimd::pipeline::{PipelineSim, RunResult};
use softsimd::workload::synth::XorShift64;

fn main() {
    // 1. Quantize six values to Q1.7 and pack them (8-bit sub-words).
    let fmt = SimdFormat::new(8);
    let values = [0.5f64, -0.25, 0.9, -0.75, 0.1, -0.05];
    let raws: Vec<i64> = values.iter().map(|&v| to_q(v, 8)).collect();
    let word = softsimd::bits::pack(&raws, fmt);
    println!("packed {values:?}\n  -> raws {raws:?}\n  -> word {word:#014x}");

    // 2. CSD-encode a multiplier and look at its cycle schedule.
    let m = to_q(0.8984375, 8); // 115/128, the Fig. 3 multiplier
    let digits = csd_encode(m, 8);
    let plan = schedule(m, 8);
    println!(
        "multiplier {m} (binary {:08b}) -> CSD {} -> {} cycles ({} adds)",
        m,
        csd_string(&digits),
        plan.cycles(),
        plan.adds()
    );

    // 3. Run multiply-then-repack(8→16) as a micro-op program on the
    //    cycle-accurate pipeline.
    let mut prog = assemble_mul_repack(m, 8, fmt, SimdFormat::new(16), 3);
    prog.instrs.insert(1, Instr::Load(Reg::X, word));
    println!("\nprogram:\n{}", prog.disasm());
    let mut sim = PipelineSim::new(fmt);
    let mut res = RunResult::default();
    sim.run(&prog, &mut res);
    println!(
        "elapsed {} cycles (stage1 {} / stage2 {})",
        res.elapsed_cycles, res.s1_busy, res.s2_busy
    );
    for (i, out) in res.outputs.iter().enumerate() {
        let lanes = softsimd::bits::unpack(*out, SimdFormat::new(16));
        let vals: Vec<f64> = lanes.iter().map(|&l| from_q(l, 16)).collect();
        println!("out[{i}] = {out:#014x} -> {vals:?}");
    }
    println!(
        "expected  -> {:?}",
        values.iter().map(|v| v * from_q(m, 8)).collect::<Vec<_>>()
    );

    // 4. Price it: synthesize the pipeline at 1 GHz and measure energy.
    let mut pipe = SynthesizedSoftPipeline::new(1000.0);
    let area = pipe.area();
    println!(
        "\n28nm @1GHz: area {:.0} µm² (stage1 {:.0} + stage2 {:.0} + regs {:.0})",
        area.total(),
        area.stage1_um2,
        area.stage2_um2,
        area.regs_um2
    );
    let mut rng = XorShift64::new(42);
    let pj = pipe.subword_mult_energy_pj(8, 8, 200, &mut rng).unwrap();
    println!("energy: {pj:.3} pJ per 8×8 sub-word multiplication");
}
