//! Conv serving integration properties (DESIGN.md §12), hand-rolled
//! generators (proptest is unavailable offline).
//!
//! The acceptance invariant: the packed engine's conv forward is
//! **bit-exact** against the scalar oracles over randomized shapes,
//! strides, paddings, layer interleavings and precision schedules —
//! `nn::conv::conv_forward_row` for a single conv layer and
//! `nn::exec::stack_forward_row` for whole interleaved stacks.

use softsimd::coordinator::engine::{EngineScratch, PackedEngine};
use softsimd::coordinator::model::CompiledModel;
use softsimd::nn::conv::{conv_forward_row, ConvLayer, ConvShape, LayerOp};
use softsimd::nn::exec::stack_forward_row;
use softsimd::nn::weights::LayerPrecision;
use softsimd::nn::weights::QuantLayer;
use softsimd::testutil::{random_conv_layer as random_conv, random_precision};
use softsimd::workload::synth::XorShift64;

#[test]
fn prop_single_conv_layer_is_bit_exact_over_random_shapes_and_precisions() {
    let mut rng = XorShift64::new(0xC2121);
    let mut scratch = EngineScratch::new();
    let mut out = Vec::new();
    for case in 0..60 {
        let w_bits = [4u32, 6, 8][(rng.next_u64() % 3) as usize];
        let cin = 1 + (rng.next_u64() % 2) as usize;
        let conv = random_conv(&mut rng, cin, w_bits);
        let p = random_precision(&mut rng);
        let shape = conv.shape;
        let model = CompiledModel::compile_stack(
            vec![LayerOp::Conv(conv.clone())],
            vec![p],
        )
        .unwrap_or_else(|e| panic!("case {case}: compile failed: {e}"));
        let engine = PackedEngine::new(model);
        let batch_size = 1 + (rng.next_u64() % 9) as usize;
        let batch: Vec<Vec<i64>> = (0..batch_size)
            .map(|_| (0..shape.in_len()).map(|_| rng.q_raw(p.in_bits)).collect())
            .collect();
        let stats = engine.forward_batch_into(&batch, 0, &mut scratch, &mut out);
        assert_eq!(out.len(), batch_size, "case {case}: pad images dropped");
        for (b, row) in batch.iter().enumerate() {
            let want = conv_forward_row(row, &conv, p);
            assert_eq!(
                out[b], want,
                "case {case}: shape {shape} precision {p} image {b}"
            );
        }
        // Useful multiplies are the real images' patch rows only.
        let nonzero = conv
            .w
            .w_raw
            .iter()
            .flatten()
            .filter(|&&v| v != 0)
            .count() as u64;
        assert_eq!(
            stats.subword_mults,
            batch_size as u64 * shape.out_pixels() as u64 * nonzero,
            "case {case}: conv useful-work billing"
        );
    }
}

#[test]
fn prop_interleaved_stacks_are_bit_exact_over_random_schedules() {
    // Random conv/dense interleavings (conv first, conv mid, conv last)
    // under random precision schedules, one scratch reused across every
    // case — the serving shape.
    let mut rng = XorShift64::new(0xC2122);
    let mut scratch = EngineScratch::new();
    let mut out = Vec::new();
    for case in 0..40 {
        let w_bits = [4u32, 6, 8][(rng.next_u64() % 3) as usize];
        let mut ops: Vec<LayerOp> = Vec::new();
        let mut width; // flattened feature length flowing through
        match rng.next_u64() % 3 {
            // conv → dense
            0 => {
                let c = random_conv(&mut rng, 1 + (rng.next_u64() % 2) as usize, w_bits);
                width = c.shape.out_len();
                ops.push(LayerOp::Conv(c));
                let n = 1 + (rng.next_u64() % 4) as usize;
                ops.push(LayerOp::Dense(QuantLayer::new(
                    (0..width)
                        .map(|_| (0..n).map(|_| rng.q_raw(w_bits)).collect())
                        .collect(),
                    w_bits,
                )));
            }
            // conv → conv → dense (channel-chained)
            1 => {
                let c1 = random_conv(&mut rng, 1, w_bits);
                let cout1 = c1.shape.cout;
                let (oh1, ow1) = (c1.shape.out_h(), c1.shape.out_w());
                ops.push(LayerOp::Conv(c1));
                // Second conv consumes the first's spatial output.
                let mut s2 = ConvShape {
                    cin: cout1,
                    h: oh1,
                    w: ow1,
                    cout: 1 + (rng.next_u64() % 2) as usize,
                    kh: 1 + (rng.next_u64() % 2) as usize,
                    kw: 1 + (rng.next_u64() % 2) as usize,
                    stride: 1,
                    pad: 0,
                };
                if s2.validate().is_err() {
                    s2.kh = 1;
                    s2.kw = 1;
                }
                let w2 = QuantLayer::new(
                    (0..s2.patch_len())
                        .map(|_| (0..s2.cout).map(|_| rng.q_raw(w_bits)).collect())
                        .collect(),
                    w_bits,
                );
                let c2 = ConvLayer::new(w2, s2).unwrap();
                width = c2.shape.out_len();
                ops.push(LayerOp::Conv(c2));
                ops.push(LayerOp::Dense(QuantLayer::new(
                    (0..width).map(|_| vec![rng.q_raw(w_bits)]).collect(),
                    w_bits,
                )));
            }
            // dense → conv (the dense output reshaped into feature maps)
            _ => {
                let c = random_conv(&mut rng, 1, w_bits);
                let k = 2 + (rng.next_u64() % 5) as usize;
                ops.push(LayerOp::Dense(QuantLayer::new(
                    (0..k)
                        .map(|_| (0..c.shape.in_len()).map(|_| rng.q_raw(w_bits)).collect())
                        .collect(),
                    w_bits,
                )));
                ops.push(LayerOp::Conv(c));
            }
        }
        let sched: Vec<LayerPrecision> =
            (0..ops.len()).map(|_| random_precision(&mut rng)).collect();
        let model = CompiledModel::compile_stack(ops.clone(), sched.clone())
            .unwrap_or_else(|e| panic!("case {case}: compile failed: {e}"));
        let engine = PackedEngine::new(model);
        let batch_size = 1 + (rng.next_u64() % 7) as usize;
        let k0 = ops[0].in_len();
        let batch: Vec<Vec<i64>> = (0..batch_size)
            .map(|_| (0..k0).map(|_| rng.q_raw(sched[0].in_bits)).collect())
            .collect();
        engine.forward_batch_into(&batch, 0, &mut scratch, &mut out);
        for (b, row) in batch.iter().enumerate() {
            let want = stack_forward_row(row, &ops, &sched);
            assert_eq!(out[b], want, "case {case}: sched {sched:?} image {b}");
        }
    }
}

#[test]
fn conv_serving_round_trip_through_the_coordinator() {
    // End to end: the synthetic CNN served through submit → batcher →
    // PE workers → drain, responses bit-exact against the stack oracle.
    use softsimd::coordinator::server::{Coordinator, Request, ServeConfig};
    use softsimd::nn::weights::uniform_schedule;
    use softsimd::testutil::flat_cost;
    use softsimd::workload::synth::{synth_cnn_stack, ImageSet};
    let stack = synth_cnn_stack(0xC2123, 8);
    let sched = uniform_schedule(8, 16, stack.len());
    let model = CompiledModel::compile_stack(stack.clone(), sched.clone()).unwrap();
    let mut coord = Coordinator::start(model, ServeConfig::new(2, 6), flat_cost()).unwrap();
    let (xs, _ys) = ImageSet::standard().sample(9, 0.3, 0xC2124, 8);
    for (id, row) in xs.iter().enumerate() {
        coord
            .submit(Request { id: id as u64, rows: vec![row.clone()] })
            .unwrap();
    }
    let responses = coord.drain().unwrap();
    assert_eq!(responses.len(), 9);
    for resp in &responses {
        let want = stack_forward_row(&xs[resp.id as usize], &stack, &sched);
        assert_eq!(resp.logits[0], want, "request {}", resp.id);
        assert_eq!(resp.logits[0].len(), 10);
    }
    coord.shutdown();
}
