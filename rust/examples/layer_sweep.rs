//! Mixed-precision layer sweep: the run-time reconfigurability story
//! (Section I: "adapting to the robustness of different layers").
//!
//! For each layer of a CNN-like stack, sweeps the Soft SIMD sub-word
//! width, measuring (a) the quantization SQNR the width sustains and
//! (b) the energy per multiply — then picks the cheapest width meeting
//! a 20 dB target and shows the Stage-2 repack plan that stitches the
//! chosen formats together at run time.
//!
//! Run: `cargo run --release --example layer_sweep`

use softsimd::bits::format::{SimdFormat, FORMATS};
use softsimd::energy::model::SynthesizedSoftPipeline;
use softsimd::pipeline::stage2::{conversion_chain, repack_cycles};
use softsimd::quant::sqnr_db;
use softsimd::workload::synth::XorShift64;

struct Layer {
    name: &'static str,
    mults: u64,
    /// Activation distribution spread (σ of a clipped gaussian-ish mix).
    spread: f64,
}

fn main() {
    let layers = [
        Layer { name: "conv1 (robust)", mults: 4096, spread: 0.6 },
        Layer { name: "conv2", mults: 8192, spread: 0.35 },
        Layer { name: "conv3", mults: 8192, spread: 0.2 },
        Layer { name: "fc (sensitive)", mults: 1024, spread: 0.08 },
    ];
    let target_db = 20.0;
    let mut pipe = SynthesizedSoftPipeline::new(1000.0);
    let mut rng = XorShift64::new(0x5EEE);

    // Characterize energy per width once.
    let mut width_pj = vec![];
    for &b in &FORMATS {
        let pj = pipe.subword_mult_energy_pj(b, b, 150, &mut rng).unwrap();
        width_pj.push((b, pj));
    }
    println!("energy per mult @1GHz: {width_pj:?}\n");

    let mut chosen: Vec<u32> = vec![];
    let mut total_pj = 0.0;
    let mut uniform16_pj = 0.0;
    println!(
        "{:<16} {:>7} {:>9} {:>9} {:>10}",
        "layer", "width", "SQNR dB", "pJ/mult", "layer nJ"
    );
    for layer in &layers {
        // Synthesize an activation sample with the layer's spread.
        let sample: Vec<f64> = (0..4000)
            .map(|_| {
                let u = rng.uniform() * 2.0 - 1.0;
                (u * layer.spread * 3.0).clamp(-0.99, 0.99)
            })
            .collect();
        let mut pick = 16u32;
        for &b in &FORMATS {
            if sqnr_db(&sample, b) >= target_db {
                pick = b;
                break;
            }
        }
        let snr = sqnr_db(&sample, pick);
        let pj = width_pj.iter().find(|&&(b, _)| b == pick).unwrap().1;
        let pj16 = width_pj.iter().find(|&&(b, _)| b == 16).unwrap().1;
        total_pj += pj * layer.mults as f64;
        uniform16_pj += pj16 * layer.mults as f64;
        println!(
            "{:<16} {:>7} {:>9.1} {:>9.3} {:>10.2}",
            layer.name,
            format!("{pick}b"),
            snr,
            pj,
            pj * layer.mults as f64 / 1000.0
        );
        chosen.push(pick);
    }
    println!(
        "\nmixed-precision total: {:.2} nJ vs uniform-16b {:.2} nJ  ({:.1}% saved)",
        total_pj / 1000.0,
        uniform16_pj / 1000.0,
        (1.0 - total_pj / uniform16_pj) * 100.0
    );

    // Show the Stage-2 plumbing between consecutive layers.
    println!("\nStage-2 repack plan between layers (48 words of activations):");
    for w in chosen.windows(2) {
        let (a, b) = (SimdFormat::new(w[0]), SimdFormat::new(w[1]));
        let chain = conversion_chain(a, b);
        let cycles = repack_cycles(48, a, b);
        println!(
            "  {a} -> {b}: {} hop(s) {:?}, {cycles} crossbar cycles",
            chain.len(),
            chain
                .iter()
                .map(|(f, t)| format!("{}→{}", f.bits, t.bits))
                .collect::<Vec<_>>(),
        );
    }
}
