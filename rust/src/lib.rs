//! # softsimd — A Soft SIMD Based Energy Efficient Computing Microarchitecture
//!
//! Reproduction of Yu et al., *"A Soft SIMD Based Energy Efficient
//! Computing Microarchitecture"* (cs.AR 2022): a bit-accurate and
//! cycle-accurate model of the paper's two-stage pipeline (Soft SIMD
//! shift-add arithmetic with CSD-coded multipliers + a repacking
//! crossbar), a gate-level 28nm cost substrate replacing the paper's
//! synthesis flow, the two Hard SIMD baselines, the complete evaluation
//! harness for Figs. 6–10, and a near-memory coordinator that runs
//! quantized NN workloads on arrays of simulated pipelines.
//!
//! The functional golden model of the arithmetic is authored in JAX +
//! Pallas (`python/compile/`), AOT-lowered to HLO text at build time and
//! executed from Rust through PJRT (`runtime`) — Python is never on the
//! request path.
//!
//! ## Layer map
//! * [`bits`], [`csd`], [`isa`], [`pipeline`] — the architecture model.
//! * [`rtl`], [`energy`], [`hardsimd`] — the synthesis/cost substrate.
//! * [`eval`] — regenerates every figure of the paper's evaluation.
//! * [`coordinator`], [`nn`], [`quant`], [`workload`] — the near-memory
//!   accelerator runtime and its ML workloads.
//! * [`runtime`] — PJRT loader for the AOT JAX/Pallas artifacts.

pub mod anyhow;
pub mod bits;
pub mod coordinator;
pub mod csd;
pub mod energy;
pub mod eval;
pub mod hardsimd;
pub mod isa;
pub mod nn;
pub mod pipeline;
pub mod quant;
pub mod rtl;
pub mod runtime;
pub mod testutil;
pub mod workload;
