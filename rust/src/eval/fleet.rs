//! `eval fleet` — the fleet front end's acceptance scenario
//! (DESIGN.md §17): two hosted models (the synthetic-digits MLP and
//! the synthetic CNN) served behind one admission layer to three
//! tenant SLO classes through a light → burst → light arrival trace.
//!
//! What it demonstrates, end to end:
//!
//! * **Routing + replicated pools** — both models run two PE pools
//!   each; every request is routed by model id and sharded to the
//!   least-loaded pool.
//! * **Certified-cost admission** — the `bulk` class carries a
//!   deliberately tiny drain budget, so during the burst its
//!   back-to-back oversized requests are shed with a typed
//!   [`ServeError::Shed`] the moment its queue is non-empty, while the
//!   `interactive` class (generous budget, tiny batch target, priority
//!   0) keeps flowing.
//! * **Bit-exactness under multi-tenancy** — every response is checked
//!   against the scalar oracle of the variant it reports having
//!   executed, and every admitted request is answered exactly once.
//!
//! The scenario body lives in [`run_scenario`] so `benches/fleet.rs`
//! can drive the identical trace and emit `BENCH_fleet.json` from the
//! same [`PhaseStat`] rows this eval prints.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::anyhow;
use crate::coordinator::cost::CostTable;
use crate::coordinator::fleet::{Fleet, FleetConfig, ModelConfig};
use crate::coordinator::governor::SloClass;
use crate::coordinator::model::{CompiledModel, VariantSpec};
use crate::coordinator::server::{Request, Response, ServeConfig, ServeError};
use crate::energy::report::table;
use crate::nn::conv::LayerOp;
use crate::nn::exec::stack_forward_row;
use crate::workload::synth::{
    light_burst_light, synth_cnn_stack, synth_mlp_stack, BurstPhase, Digits, ImageSet,
};

use super::autoscale::mlp_specs;

/// Tenant ids, in priority order (must match [`scenario_fleet`]).
const INTERACTIVE: usize = 0;
const STANDARD: usize = 1;
const BULK: usize = 2;

/// Per-(phase, tenant) outcome of one scenario run: the numbers the
/// eval tabulates and `BENCH_fleet.json` records.
#[derive(Debug, Clone)]
pub struct PhaseStat {
    pub phase: &'static str,
    pub tenant: String,
    /// Requests admitted during the phase.
    pub requests: u64,
    /// Requests shed (typed, certified-cost admission) during the phase.
    pub shed: u64,
    /// Rows completed during the phase.
    pub rows: u64,
    /// Completed-row throughput over the phase wall clock.
    pub rows_per_s: f64,
    /// Windowed p99 latency over the phase, in microseconds.
    pub p99_us: f64,
    /// Billed energy per completed row over the phase.
    pub pj_per_row: f64,
    /// shed / (admitted + shed) over the phase.
    pub shed_rate: f64,
}

/// One hosted model plus what the oracle needs to re-derive its
/// outputs per variant.
struct ScenarioModel {
    name: &'static str,
    stack: Vec<LayerOp>,
    model: Arc<CompiledModel>,
}

/// Everything needed to re-check one admitted request when its
/// response comes back (possibly out of order, from any pool).
struct PendingReq {
    model: usize,
    rows: Vec<Vec<i64>>,
}

/// Build the scenario fleet: MLP (3 variants) and CNN (3 variants),
/// two pools of two PEs each, three tenant classes.
fn scenario_fleet() -> anyhow::Result<(Fleet, Vec<ScenarioModel>)> {
    let mlp = synth_mlp_stack(8);
    let mlp_model = CompiledModel::compile_variants(mlp.clone(), mlp_specs())?;
    let cnn = synth_cnn_stack(0xF1EE7, 8);
    let cnn_model = CompiledModel::compile_variants(cnn.clone(), VariantSpec::standard_trio(3))?;

    // A long flush deadline keeps the background tick out of the
    // trace: every dispatch below happens at an explicit `tick_now`,
    // quiesce or drain point, so the admission decisions (and the
    // sheds the burst asserts on) are deterministic.
    let pool = ServeConfig::new(2, 12).deadline(Duration::from_millis(400));
    let cfg = FleetConfig::new()
        .model(
            ModelConfig::new(
                Arc::clone(&mlp_model),
                CostTable::characterize(1000.0),
                pool.clone(),
            )
            .pools(2),
        )
        .model(
            ModelConfig::new(Arc::clone(&cnn_model), CostTable::characterize(1000.0), pool)
                .pools(2),
        )
        // Interactive: tight p99 objective, generous admission budget
        // (4× objective = 80 ms — never breached here), 2-row batch
        // target so its submits dispatch immediately even mid-burst.
        .tenant(
            SloClass::new("interactive", Duration::from_millis(20), 64, 8)
                .priority(0)
                .target_rows(2),
        )
        // Standard: pool defaults, middle priority.
        .tenant(SloClass::new("standard", Duration::from_millis(50), 96, 16).priority(1))
        // Bulk: big batches, lowest priority, and a 1 ns drain budget —
        // any non-empty queue sheds the next request. The light phases
        // quiesce between rounds, so bulk still gets served there; the
        // burst does not, so its flood is shed by admission.
        .tenant(
            SloClass::new("bulk", Duration::from_millis(10), 256, 32)
                .priority(2)
                .drain_budget(Duration::from_nanos(1))
                .target_rows(48),
        );
    let fleet = Fleet::start(cfg).map_err(|e| anyhow::anyhow!("fleet start: {e}"))?;
    Ok((
        fleet,
        vec![
            ScenarioModel { name: "mlp", stack: mlp, model: mlp_model },
            ScenarioModel { name: "cnn", stack: cnn, model: cnn_model },
        ],
    ))
}

/// Submit one request, recording it for the oracle when admitted and
/// insisting any rejection is a *typed shed* — every other error fails
/// the scenario.
fn submit_checked(
    fleet: &Fleet,
    pending: &mut HashMap<u64, PendingReq>,
    next_id: &mut u64,
    model: usize,
    tenant: usize,
    rows: Vec<Vec<i64>>,
) -> anyhow::Result<bool> {
    let id = *next_id;
    *next_id += 1;
    match fleet.submit(model, tenant, Request { id, rows: rows.clone() }) {
        Ok(()) => {
            pending.insert(id, PendingReq { model, rows });
            Ok(true)
        }
        Err(ServeError::Shed { tenant: t, reason }) => {
            anyhow::ensure!(
                t == tenant && !reason.is_empty(),
                "shed mis-attributed: tenant {t} vs {tenant} ({reason})"
            );
            Ok(false)
        }
        Err(e) => anyhow::bail!("unexpected serve error on submit {id}: {e}"),
    }
}

/// Check a batch of responses against the per-variant scalar oracle
/// and the exactly-once ledger.
fn check_responses(
    models: &[ScenarioModel],
    pending: &mut HashMap<u64, PendingReq>,
    responses: &[Response],
) -> anyhow::Result<()> {
    for resp in responses {
        let req = pending
            .remove(&resp.id)
            .ok_or_else(|| anyhow::anyhow!("response {} unknown or duplicated", resp.id))?;
        anyhow::ensure!(
            resp.model == req.model,
            "response {} routed to model {} but submitted to {}",
            resp.id,
            resp.model,
            req.model
        );
        let sm = &models[req.model];
        let var = sm.model.variant(resp.variant);
        anyhow::ensure!(
            resp.logits.len() == req.rows.len(),
            "response {} has {} logit rows for {} request rows",
            resp.id,
            resp.logits.len(),
            req.rows.len()
        );
        for (b, row) in req.rows.iter().enumerate() {
            let want = stack_forward_row(&var.quantize_row(row), &sm.stack, var.schedule());
            anyhow::ensure!(
                resp.logits[b] == want,
                "{}/{}: response {} row {b} diverges from the scalar oracle",
                sm.name,
                var.name(),
                resp.id
            );
        }
    }
    Ok(())
}

/// Drive one trace phase through the fleet, returning the per-tenant
/// window over it.
#[allow(clippy::too_many_arguments)]
fn run_phase(
    fleet: &mut Fleet,
    models: &[ScenarioModel],
    phase: &BurstPhase,
    xs_mlp: &[Vec<i64>],
    xs_cnn: &[Vec<i64>],
    pending: &mut HashMap<u64, PendingReq>,
    next_id: &mut u64,
    cursor: &mut usize,
) -> anyhow::Result<Vec<PhaseStat>> {
    let n_tenants = fleet.n_tenants();
    let before: Vec<_> = (0..n_tenants).map(|t| fleet.tenant_metrics(t).snapshot()).collect();
    let t0 = Instant::now();

    let mut take = |pool: &[Vec<i64>], n: usize| -> Vec<Vec<i64>> {
        (0..n)
            .map(|_| {
                let row = pool[*cursor % pool.len()].clone();
                *cursor += 1;
                row
            })
            .collect()
    };

    let mut burst_sheds = 0u64;
    for _ in 0..phase.rounds {
        for model in 0..models.len() {
            let xs = if model == 0 { xs_mlp } else { xs_cnn };
            // Foreground tenants: one small request each, per model.
            for tenant in [INTERACTIVE, STANDARD] {
                let rows = take(xs, phase.fg_rows);
                anyhow::ensure!(
                    submit_checked(fleet, pending, next_id, model, tenant, rows)?,
                    "foreground tenant {tenant} shed — its budget should never trip"
                );
            }
            // Bulk: `bulk_reqs` oversized requests back-to-back. In
            // quiescing phases the queue is empty at each round start,
            // so the single request is admitted; in the burst the
            // follow-ups land on a non-empty queue and must shed.
            for _ in 0..phase.bulk_reqs {
                let rows = take(xs, phase.bulk_rows);
                if !submit_checked(fleet, pending, next_id, model, BULK, rows)? {
                    burst_sheds += 1;
                }
            }
        }
        let got = if phase.quiesce {
            fleet.drain().map_err(|e| anyhow::anyhow!("drain: {e}"))?
        } else {
            fleet.tick_now();
            fleet.try_collect()
        };
        check_responses(models, pending, &got)?;
    }
    // Phase boundary: flush and answer everything still in flight.
    let got = fleet.drain().map_err(|e| anyhow::anyhow!("drain: {e}"))?;
    check_responses(models, pending, &got)?;
    anyhow::ensure!(
        pending.is_empty(),
        "{} admitted requests left unanswered after `{}`",
        pending.len(),
        phase.name
    );
    if !phase.quiesce {
        anyhow::ensure!(
            burst_sheds > 0,
            "burst phase produced no bulk sheds — admission control is not engaging"
        );
    }

    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    Ok((0..n_tenants)
        .map(|t| {
            let now = fleet.tenant_metrics(t).snapshot();
            let rows = now.window_rows(&before[t]);
            let requests = now.window_requests(&before[t]);
            let shed = now.window_shed(&before[t]);
            let pj = now.window_pj(&before[t]);
            PhaseStat {
                phase: phase.name,
                tenant: fleet.tenant_class(t).name.clone(),
                requests,
                shed,
                rows,
                rows_per_s: rows as f64 / wall_s,
                p99_us: now
                    .window_latency_quantile_ns(&before[t], 0.99)
                    .map(|ns| ns as f64 / 1e3)
                    .unwrap_or(0.0),
                pj_per_row: if rows > 0 { pj / rows as f64 } else { 0.0 },
                shed_rate: if requests + shed > 0 {
                    shed as f64 / (requests + shed) as f64
                } else {
                    0.0
                },
            }
        })
        .collect())
}

/// Run the full light → burst → light scenario, returning one
/// [`PhaseStat`] per (phase, tenant). Fails on any oracle divergence,
/// any silent drop or duplicate, any untyped rejection, any
/// foreground shed, or a burst without bulk sheds.
pub fn run_scenario() -> anyhow::Result<Vec<PhaseStat>> {
    let (mut fleet, models) = scenario_fleet()?;
    let digits = Digits::standard();
    let (xs_mlp, _) = digits.sample(64, 0.10, 0xFEE7_0001);
    let images = ImageSet::standard();
    let (xs_cnn, _) = images.sample(64, 0.10, 0xFEE7_0002, 8);

    let mut pending: HashMap<u64, PendingReq> = HashMap::new();
    let mut next_id = 0u64;
    let mut cursor = 0usize;
    let mut stats = Vec::new();
    for phase in light_burst_light() {
        stats.extend(run_phase(
            &mut fleet,
            &models,
            &phase,
            &xs_mlp,
            &xs_cnn,
            &mut pending,
            &mut next_id,
            &mut cursor,
        )?);
    }

    // Global conservation: every id admitted was answered exactly once.
    anyhow::ensure!(pending.is_empty(), "admitted requests left unanswered");
    anyhow::ensure!(fleet.pending_rows() == 0, "fleet not quiescent after the trace");
    let shed_total: u64 = (0..fleet.n_tenants())
        .map(|t| fleet.tenant_metrics(t).snapshot().shed_requests)
        .sum();
    anyhow::ensure!(shed_total > 0, "scenario never exercised admission shedding");
    fleet.shutdown();
    Ok(stats)
}

/// Print the per-tenant, per-phase serving report.
pub fn run() -> anyhow::Result<()> {
    println!("== eval fleet: 2 models x 3 tenant classes, light -> burst -> light ==");
    println!("   (every response checked bit-exact against its executed variant's oracle)");
    let stats = run_scenario()?;
    let headers = [
        "phase", "tenant", "admitted", "shed", "rows", "rows/s", "p99 us", "pJ/row",
        "shed rate",
    ];
    let rows: Vec<Vec<String>> = stats
        .iter()
        .map(|s| {
            vec![
                s.phase.to_string(),
                s.tenant.clone(),
                s.requests.to_string(),
                s.shed.to_string(),
                s.rows.to_string(),
                format!("{:.0}", s.rows_per_s),
                format!("{:.1}", s.p99_us),
                format!("{:.1}", s.pj_per_row),
                format!("{:.2}", s.shed_rate),
            ]
        })
        .collect();
    println!("{}", table(&headers, &rows));
    let burst_bulk = stats
        .iter()
        .find(|s| s.phase == "burst" && s.tenant == "bulk")
        .expect("burst/bulk row");
    let burst_inter = stats
        .iter()
        .find(|s| s.phase == "burst" && s.tenant == "interactive")
        .expect("burst/interactive row");
    println!(
        "   burst: bulk shed rate {:.2} ({} typed sheds), interactive shed rate {:.2} \
         with p99 {:.1} us — admission isolates the classes",
        burst_bulk.shed_rate, burst_bulk.shed, burst_inter.shed_rate, burst_inter.p99_us
    );
    Ok(())
}
