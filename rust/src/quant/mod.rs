//! Quantization helpers: float ↔ Q1.X conversion for tensors, per-layer
//! bitwidth selection, and truncation-error analysis (the paper's ~1%
//! claim, Section III-B).

pub mod error;

pub use error::{mul_error_stats, ErrorStats};

use crate::bits::fixed::{from_q, to_q};

/// Quantize a float slice to Q1.(bits-1) raws.
pub fn quantize(vals: &[f64], bits: u32) -> Vec<i64> {
    vals.iter().map(|&v| to_q(v, bits)).collect()
}

/// Dequantize raws back to floats.
pub fn dequantize(raws: &[i64], bits: u32) -> Vec<f64> {
    raws.iter().map(|&r| from_q(r, bits)).collect()
}

/// Signal-to-quantization-noise ratio (dB) of representing `vals` at
/// `bits` — used by the layer-sweep example to pick per-layer widths.
pub fn sqnr_db(vals: &[f64], bits: u32) -> f64 {
    let mut sig = 0.0f64;
    let mut noise = 0.0f64;
    for &v in vals {
        let q = from_q(to_q(v, bits), bits);
        sig += v * v;
        noise += (v - q) * (v - q);
    }
    if noise == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (sig / noise).log10()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_dequantize_roundtrip_error() {
        let vals: Vec<f64> = (0..100).map(|i| (i as f64 / 100.0) * 1.9 - 0.95).collect();
        for bits in [4u32, 8, 16] {
            let q = quantize(&vals, bits);
            let d = dequantize(&q, bits);
            let ulp = 2f64.powi(-(bits as i32 - 1));
            for (v, r) in vals.iter().zip(&d) {
                assert!((v - r).abs() <= ulp / 2.0 + 1e-12);
            }
        }
    }

    #[test]
    fn sqnr_improves_with_bits() {
        let vals: Vec<f64> = (0..500).map(|i| ((i * 37 % 199) as f64 / 100.0) - 0.99).collect();
        let s4 = sqnr_db(&vals, 4);
        let s8 = sqnr_db(&vals, 8);
        let s16 = sqnr_db(&vals, 16);
        assert!(s4 < s8 && s8 < s16, "{s4} {s8} {s16}");
        // ~6 dB per bit.
        assert!((s8 - s4) > 15.0 && (s8 - s4) < 33.0);
    }
}
