//! Digit-density statistics backing the paper's claim that ~2/3 of CSD
//! digits are zero (Section II-B), and the expected cycle counts the
//! energy model consumes.

use super::encode::{csd_encode, nonzero_count};
use super::schedule::schedule_with;


/// Aggregate CSD statistics over all multipliers of a given width.
#[derive(Debug, Clone, Copy)]
pub struct DensityStats {
    pub y_bits: u32,
    /// Fraction of zero digits over all values of the width.
    pub zero_fraction: f64,
    /// Mean nonzero digits (= add/sub cycles) per multiplier.
    pub mean_adds: f64,
    /// Mean Stage-1 cycles per multiplication at max_shift = 3.
    pub mean_cycles: f64,
    /// Worst-case cycles.
    pub max_cycles: usize,
}

/// Exhaustive statistics over every `y_bits`-wide multiplier (cheap up
/// to 16 bits: 65536 values).
pub fn density(y_bits: u32) -> DensityStats {
    density_with(y_bits, crate::bits::format::MAX_SHIFT)
}

/// Same, with a configurable per-cycle shifter reach (ablation support).
pub fn density_with(y_bits: u32, max_shift: u32) -> DensityStats {
    let half = 1i64 << (y_bits - 1);
    let total_values = (2 * half) as f64;
    let mut zeros = 0usize;
    let mut adds = 0usize;
    let mut cycles = 0usize;
    let mut max_cycles = 0usize;
    for m in -half..half {
        let d = csd_encode(m, y_bits);
        let nz = nonzero_count(&d);
        zeros += d.len() - nz;
        adds += nz;
        let c = schedule_with(m, y_bits, max_shift).cycles();
        cycles += c;
        max_cycles = max_cycles.max(c);
    }
    DensityStats {
        y_bits,
        zero_fraction: zeros as f64 / (total_values * y_bits as f64),
        mean_adds: adds as f64 / total_values,
        mean_cycles: cycles as f64 / total_values,
        max_cycles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_thirds_zero_density() {
        // Section II-B: ~2/3 of CSD digits are zeros. Asymptotically the
        // density of nonzeros is 1/3; at small widths it is slightly
        // below. Accept [0.60, 0.75].
        for y in [8u32, 12, 16] {
            let s = density(y);
            assert!(
                s.zero_fraction > 0.60 && s.zero_fraction < 0.75,
                "y={y} zero fraction {}",
                s.zero_fraction
            );
        }
    }

    #[test]
    fn mean_cycles_well_below_width() {
        // Shift coalescing must beat one-cycle-per-bit substantially.
        for y in [8u32, 16] {
            let s = density(y);
            assert!(
                s.mean_cycles < 0.62 * y as f64,
                "y={y} mean cycles {}",
                s.mean_cycles
            );
        }
    }

    #[test]
    fn max_cycles_bounded_by_width() {
        for y in [4u32, 8, 16] {
            let s = density(y);
            assert!(s.max_cycles <= y as usize);
        }
    }

    #[test]
    fn wider_shifter_reduces_mean_cycles() {
        let s1 = density_with(8, 1);
        let s2 = density_with(8, 2);
        let s3 = density_with(8, 3);
        assert!(s1.mean_cycles > s2.mean_cycles);
        assert!(s2.mean_cycles > s3.mean_cycles);
    }
}
