//! Static lane-safety verification of precision schedules
//! (DESIGN.md §14).
//!
//! The engine packs activations into sub-word lanes and trusts the
//! software carry-kill masks to keep them isolated — but a schedule
//! that under-provisions an accumulator makes a lane wrap silently:
//! the masks still hold, the *values* are garbage. This module proves,
//! at compile time, that a given `(stack, schedule)` pair can never
//! wrap a lane for **any** input, or rejects it with a synthesized
//! concrete input that demonstrably does.
//!
//! The verifier is an abstract interpreter over the flat CSD micro-op
//! bytecode ([`crate::csd::flat::PlanArena`]) in the interval domain
//! ([`interval::Interval`]):
//!
//! * **Multiply plans.** Each weight's shift/add stream is either
//!   brute-forced over the (small) input lane domain — exact, and
//!   yielding a witness input on wrap — or, for wide lanes, run through
//!   per-micro-op interval transfer functions (`AddShift`/`Shift`),
//!   exploiting the hardware invariant that only the final shift-0 add
//!   of a plan can wrap (any mid-plan `(b+1)`-bit intermediate is
//!   restored to lane range by its `>> k`).
//! * **Accumulates.** Per output column, the widened per-tap product
//!   intervals are summed exactly in `i128` and checked against the
//!   accumulator width. Because every product interval contains zero
//!   (zero input ⇒ zero product), every *partial* sum is bounded by
//!   the full-sum interval — so acceptance is independent of the
//!   engine's accumulation order.
//! * **Boundaries.** Between layers the SWAR ReLU and each Stage-2
//!   crossbar hop are applied to the intervals with the exact monotone
//!   endpoint maps the engine applies to values.
//!
//! Accepted schedules come with a per-layer bit-headroom margin
//! ([`LayerMargin`]); rejected ones with a typed [`AnalysisError`]
//! carrying, where the bound is exact, a concrete counterexample input
//! that the scalar shadow executor ([`find_first_wrap`]) — and, under
//! `--features lanecheck`, the runtime lane sanitizer — confirms.

pub mod cost;
pub mod interval;

pub use interval::Interval;

use crate::csd::flat::PlanArena;
use crate::csd::schedule::{MulOp, MulPlan};
use crate::nn::conv::LayerOp;
use crate::nn::exec::requantize_activation;
use crate::nn::weights::LayerPrecision;
use crate::pipeline::stage2::conversion_chain;

/// Input-domain size up to which a multiply plan is brute-forced
/// (exact ranges and wrap witnesses); wider domains use the interval
/// transfer functions. Covers every 4/6/8/12-bit lane domain.
const BRUTE_MAX_WIDTH: u64 = 4096;

/// Why a `(stack, schedule)` pair was rejected.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AnalysisError {
    /// A single multiply plan's final shift-0 add can wrap its lane
    /// for some reachable input value.
    ProductWrap {
        /// Layer index of the offending weight.
        layer: usize,
        /// Input (im2col patch) index of the weight.
        tap: usize,
        /// Output column of the weight.
        column: usize,
        /// Raw two's-complement weight value.
        weight: i64,
        /// Lane width the plan executes at.
        in_bits: u32,
        /// A concrete input lane value that wraps the plan (present
        /// when the plan was brute-forced, i.e. the bound is exact).
        witness: Option<i64>,
        /// A full model input row reproducing the wrap, confirmed
        /// against [`find_first_wrap`] (layer-0 rejections only).
        counterexample: Option<Vec<i64>>,
    },
    /// An output column's worst-case accumulated sum does not fit the
    /// scheduled accumulator width.
    AccumulatorOverflow {
        /// Layer index of the offending column.
        layer: usize,
        /// Output column whose sum overflows.
        column: usize,
        /// Scheduled accumulator width.
        acc_bits: u32,
        /// Worst-case low end of the column's exact widened sum.
        lo: i128,
        /// Worst-case high end of the column's exact widened sum.
        hi: i128,
        /// Narrowest accumulator that would hold the range.
        needed_bits: u32,
        /// A full model input row reproducing the overflow, confirmed
        /// against [`find_first_wrap`] (layer-0 rejections only).
        counterexample: Option<Vec<i64>>,
    },
}

impl AnalysisError {
    /// Layer index the rejection points at.
    pub fn layer(&self) -> usize {
        match self {
            AnalysisError::ProductWrap { layer, .. }
            | AnalysisError::AccumulatorOverflow { layer, .. } => *layer,
        }
    }

    /// The synthesized counterexample input row, when one exists.
    pub fn counterexample(&self) -> Option<&[i64]> {
        match self {
            AnalysisError::ProductWrap { counterexample, .. }
            | AnalysisError::AccumulatorOverflow { counterexample, .. } => {
                counterexample.as_deref()
            }
        }
    }
}

impl std::fmt::Display for AnalysisError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AnalysisError::ProductWrap {
                layer,
                tap,
                column,
                weight,
                in_bits,
                witness,
                counterexample,
            } => {
                write!(
                    f,
                    "layer {layer}: multiply plan of weight {weight} \
                     (tap {tap} -> column {column}) can wrap its \
                     {in_bits}-bit lane"
                )?;
                if let Some(x) = witness {
                    write!(f, "; witness input {x}")?;
                }
                if counterexample.is_some() {
                    write!(f, " (concrete overflowing input synthesized)")?;
                }
                Ok(())
            }
            AnalysisError::AccumulatorOverflow {
                layer,
                column,
                acc_bits,
                lo,
                hi,
                needed_bits,
                counterexample,
            } => {
                write!(
                    f,
                    "layer {layer}, column {column}: worst-case accumulator \
                     range [{lo}, {hi}] needs {needed_bits} bits but the \
                     schedule provides {acc_bits}"
                )?;
                match counterexample {
                    Some(_) => write!(f, " (concrete overflowing input synthesized)"),
                    None => write!(
                        f,
                        " (bound certified from abstract ranges; no concrete \
                         counterexample synthesized)"
                    ),
                }
            }
        }
    }
}

impl std::error::Error for AnalysisError {}

/// One layer's verdict inside an accepted report: the worst-case
/// accumulator range over its columns and the bit headroom left.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerMargin {
    /// Layer index.
    pub layer: usize,
    /// The precision pair the layer was verified at.
    pub precision: LayerPrecision,
    /// Least worst-case accumulated sum over the layer's columns.
    pub acc_lo: i128,
    /// Greatest worst-case accumulated sum over the layer's columns.
    pub acc_hi: i128,
    /// Narrowest accumulator that holds the worst column.
    pub needed_bits: u32,
    /// `acc_bits − needed_bits`: guard bits to spare.
    pub margin_bits: u32,
}

/// A proven-safe verdict: one [`LayerMargin`] per layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LaneSafetyReport {
    /// Per-layer margins, in execution order.
    pub layers: Vec<LayerMargin>,
}

impl LaneSafetyReport {
    /// The tightest margin across the stack (0 = proven safe with no
    /// guard bit to spare).
    pub fn min_margin_bits(&self) -> u32 {
        self.layers.iter().map(|l| l.margin_bits).min().unwrap_or(0)
    }
}

/// Scalar shadow-execution of one multiply plan with wrap detection:
/// the exact semantics of [`crate::pipeline::stage1::Stage1::run_flat`]
/// on one lane, except that instead of wrapping (`sign_extend` of the
/// masked accumulator) an out-of-range final add returns `Err`.
fn eval_ops_checked(
    ops: impl Iterator<Item = MulOp>,
    x: i64,
    x_bits: u32,
) -> Result<i64, ()> {
    let half = 1i64 << (x_bits - 1);
    let mut acc = 0i64;
    for op in ops {
        match op {
            MulOp::Shift { shift } => acc >>= shift,
            MulOp::AddShift { shift, sign } => {
                acc = if sign >= 0 { acc + x } else { acc - x };
                acc >>= shift;
                if acc < -half || acc >= half {
                    return Err(());
                }
            }
        }
    }
    Ok(acc)
}

/// Worst-case product range of one multiply plan over an input
/// interval.
///
/// Small domains (≤ [`BRUTE_MAX_WIDTH`]) are brute-forced — the result
/// interval is exact and a wrap returns `Err(Some(witness))`. Wider
/// domains run the micro-ops through interval transfer functions:
/// sound but conservative, so a potential wrap returns `Err(None)`
/// (no witness). Mid-plan adds (`shift ≥ 1`) cannot wrap — their
/// `(b+1)`-bit intermediate is restored to lane range by the shift —
/// so their result interval is soundly intersected with the lane
/// range; only the final shift-0 add is checked.
pub fn plan_product_range(
    ops: impl Iterator<Item = MulOp> + Clone,
    xs: Interval,
    x_bits: u32,
) -> Result<Interval, Option<i64>> {
    if xs.width() <= BRUTE_MAX_WIDTH {
        let mut lo = i64::MAX;
        let mut hi = i64::MIN;
        for x in xs.lo..=xs.hi {
            match eval_ops_checked(ops.clone(), x, x_bits) {
                Ok(v) => {
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
                Err(()) => return Err(Some(x)),
            }
        }
        return Ok(Interval { lo, hi });
    }
    let lane = Interval::full(x_bits);
    let mut acc = Interval::point(0);
    for op in ops {
        match op {
            MulOp::Shift { shift } => {
                acc = Interval { lo: acc.lo >> shift, hi: acc.hi >> shift };
            }
            MulOp::AddShift { shift, sign } => {
                let (lo, hi) = if sign >= 0 {
                    (acc.lo + xs.lo, acc.hi + xs.hi)
                } else {
                    (acc.lo - xs.hi, acc.hi - xs.lo)
                };
                let sum = Interval { lo: lo >> shift, hi: hi >> shift };
                if shift == 0 {
                    if !sum.fits(x_bits) {
                        return Err(None);
                    }
                    acc = sum;
                } else {
                    // Sound: every concrete mid-plan value is in lane
                    // range, so intersecting the over-approximation
                    // with the lane range keeps all of them.
                    acc = Interval {
                        lo: sum.lo.max(lane.lo),
                        hi: sum.hi.min(lane.hi),
                    };
                }
            }
        }
    }
    Ok(acc)
}

/// Narrowest two's-complement width holding `[lo, hi]` (64 when even
/// an `i64` lane would not).
pub fn bits_needed(lo: i128, hi: i128) -> u32 {
    for b in 1..=63u32 {
        let half = 1i128 << (b - 1);
        if lo >= -half && hi < half {
            return b;
        }
    }
    64
}

/// First lane-wrap event the scalar shadow executor finds when running
/// `row` through the stack — the analyzer's concrete oracle.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WrapEvent {
    /// A multiply plan's final add left the lane range.
    Product {
        /// Layer the wrap occurs in.
        layer: usize,
        /// Output column being accumulated.
        column: usize,
        /// Input (patch) index of the wrapping multiply.
        tap: usize,
        /// The input lane value that wrapped it.
        x: i64,
    },
    /// A column's exact accumulated sum left the accumulator range.
    Accumulator {
        /// Layer the overflow occurs in.
        layer: usize,
        /// Output column (for conv: output channel) that overflowed.
        column: usize,
        /// The exact widened sum that did not fit.
        sum: i128,
    },
}

/// Run `row` through the stack with exact scalar arithmetic and report
/// the first point where the packed engine would wrap a lane — `None`
/// means this input is executed bit-exactly.
///
/// This is the analyzer's replayable oracle: it shares no code with
/// the abstract interpreter (values, not intervals) and mirrors the
/// engine's layer semantics — per-tap CSD multiply at `in_bits`,
/// widened exact accumulate checked against `acc_bits`, ReLU + Stage-2
/// conversion chain between layers.
pub fn find_first_wrap(
    layers: &[LayerOp],
    schedule: &[LayerPrecision],
    row: &[i64],
) -> Option<WrapEvent> {
    assert_eq!(layers.len(), schedule.len(), "one precision per layer");
    let mut h: Vec<i64> = row.to_vec();
    for (li, (layer, p)) in layers.iter().zip(schedule).enumerate() {
        assert_eq!(h.len(), layer.in_len(), "layer {li} input width");
        let w = layer.weights();
        let plans = w.plans();
        let widen = p.acc_bits - p.in_bits;
        let acc_half = 1i128 << (p.acc_bits - 1);
        let mut out = vec![0i64; layer.out_len()];
        match layer {
            LayerOp::Dense(_) => {
                for n in 0..w.n {
                    let mut sum: i128 = 0;
                    for (k, hk) in h.iter().enumerate() {
                        match checked_product(&plans[k][n], *hk, p.in_bits) {
                            Ok(v) => sum += (v as i128) << widen,
                            Err(()) => {
                                return Some(WrapEvent::Product {
                                    layer: li,
                                    column: n,
                                    tap: k,
                                    x: *hk,
                                })
                            }
                        }
                    }
                    if sum < -acc_half || sum >= acc_half {
                        return Some(WrapEvent::Accumulator { layer: li, column: n, sum });
                    }
                    out[n] = sum as i64;
                }
            }
            LayerOp::Conv(c) => {
                let s = &c.shape;
                let (oh, ow) = (s.out_h(), s.out_w());
                for co in 0..s.cout {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let mut sum: i128 = 0;
                            for k in 0..s.patch_len() {
                                let xv = s.src_index(k, oy, ox).map_or(0, |i| h[i]);
                                match checked_product(&plans[k][co], xv, p.in_bits) {
                                    Ok(v) => sum += (v as i128) << widen,
                                    Err(()) => {
                                        return Some(WrapEvent::Product {
                                            layer: li,
                                            column: co,
                                            tap: k,
                                            x: xv,
                                        })
                                    }
                                }
                            }
                            if sum < -acc_half || sum >= acc_half {
                                return Some(WrapEvent::Accumulator {
                                    layer: li,
                                    column: co,
                                    sum,
                                });
                            }
                            out[(co * oh + oy) * ow + ox] = sum as i64;
                        }
                    }
                }
            }
        }
        if li + 1 == layers.len() {
            return None;
        }
        let next_in = schedule[li + 1].in_fmt();
        h = out
            .iter()
            .map(|&v| requantize_activation(v, p.acc_fmt(), next_in))
            .collect();
    }
    None
}

/// [`eval_ops_checked`] over a compiled [`MulPlan`].
fn checked_product(plan: &MulPlan, x: i64, x_bits: u32) -> Result<i64, ()> {
    eval_ops_checked(plan.ops.iter().copied(), x, x_bits)
}

/// Verify a stack against a schedule using an already-built
/// [`PlanArena`] (the form [`crate::coordinator::model::CompiledModel`]
/// holds), reading plan bank 0 (the exact plans) — see [`verify_stack`]
/// for the standalone entry point and [`verify_with_arena_bank`] for
/// the truncated banks.
pub fn verify_with_arena(
    layers: &[LayerOp],
    arena: &PlanArena,
    schedule: &[LayerPrecision],
) -> Result<LaneSafetyReport, AnalysisError> {
    verify_with_arena_bank(layers, arena, 0, schedule)
}

/// As [`verify_with_arena`], analyzing plan bank `bank` — truncated
/// (approximate) plan banks need their own verification pass because a
/// truncated plan's kept value can *exceed* the magnitude of the weight
/// it came from (dropping `−2^0` from `+2^7 − 2^0` leaves `+2^7`), so
/// exact-bank safety does not imply truncated-bank safety.
pub fn verify_with_arena_bank(
    layers: &[LayerOp],
    arena: &PlanArena,
    bank: usize,
    schedule: &[LayerPrecision],
) -> Result<LaneSafetyReport, AnalysisError> {
    assert_eq!(layers.len(), schedule.len(), "one precision per layer");
    debug_assert_eq!(arena.n_layers(), layers.len());
    let mut feat: Vec<Interval> =
        vec![Interval::full(schedule[0].in_bits); layers[0].in_len()];
    let mut margins = Vec::with_capacity(layers.len());
    for (li, (layer, p)) in layers.iter().zip(schedule).enumerate() {
        let w = layer.weights();
        debug_assert_eq!(arena.layer_dims(li), (w.k, w.n));
        // The layer's matmul view: per-tap input intervals. Conv taps
        // hull their interval over every output pixel (plus the
        // zero-padding point where the window hangs off the image).
        let tap_iv: Vec<Interval> = match layer {
            LayerOp::Dense(_) => feat.clone(),
            LayerOp::Conv(c) => {
                let s = &c.shape;
                (0..s.patch_len())
                    .map(|k| {
                        let mut iv: Option<Interval> = None;
                        for oy in 0..s.out_h() {
                            for ox in 0..s.out_w() {
                                let v = match s.src_index(k, oy, ox) {
                                    Some(f) => feat[f],
                                    None => Interval::point(0),
                                };
                                iv = Some(match iv {
                                    Some(a) => a.hull(v),
                                    None => v,
                                });
                            }
                        }
                        iv.expect("conv layer has at least one output pixel")
                    })
                    .collect()
            }
        };
        let widen = p.acc_bits - p.in_bits;
        let mut out_iv = Vec::with_capacity(w.n);
        let mut worst_needed = 1u32;
        let mut layer_lo = 0i128;
        let mut layer_hi = 0i128;
        for n in 0..w.n {
            let mut lo = 0i128;
            let mut hi = 0i128;
            for (k, hd) in arena.column_bank(bank, li, n).iter().enumerate() {
                if hd.is_zero() {
                    continue;
                }
                let prod = plan_product_range(arena.walk(*hd), tap_iv[k], p.in_bits)
                    .map_err(|witness| AnalysisError::ProductWrap {
                        layer: li,
                        tap: k,
                        column: n,
                        weight: w.w_raw[k][n],
                        in_bits: p.in_bits,
                        witness,
                        counterexample: witness.and_then(|x| {
                            synth_product_counterexample(layers, schedule, li, k, x)
                        }),
                    })?;
                lo += (prod.lo as i128) << widen;
                hi += (prod.hi as i128) << widen;
            }
            let needed = bits_needed(lo, hi);
            if needed > p.acc_bits {
                return Err(AnalysisError::AccumulatorOverflow {
                    layer: li,
                    column: n,
                    acc_bits: p.acc_bits,
                    lo,
                    hi,
                    needed_bits: needed,
                    counterexample: synth_acc_counterexample(
                        layers,
                        schedule,
                        arena,
                        bank,
                        li,
                        n,
                        hi >= (1i128 << (p.acc_bits - 1)),
                    ),
                });
            }
            worst_needed = worst_needed.max(needed);
            layer_lo = layer_lo.min(lo);
            layer_hi = layer_hi.max(hi);
            // Safe narrowing: the sum fits acc_bits ≤ 16.
            out_iv.push(Interval { lo: lo as i64, hi: hi as i64 });
        }
        margins.push(LayerMargin {
            layer: li,
            precision: *p,
            acc_lo: layer_lo,
            acc_hi: layer_hi,
            needed_bits: worst_needed,
            margin_bits: p.acc_bits - worst_needed,
        });
        if li + 1 < layers.len() {
            let next_in = schedule[li + 1].in_fmt();
            let col_out: Vec<Interval> = out_iv
                .iter()
                .map(|iv| {
                    let mut v = iv.relu();
                    for (from, to) in conversion_chain(p.acc_fmt(), next_in) {
                        v = v.convert(from, to);
                    }
                    v
                })
                .collect();
            feat = match layer {
                LayerOp::Dense(_) => col_out,
                LayerOp::Conv(c) => {
                    let pixels = c.shape.out_pixels();
                    (0..c.shape.out_len()).map(|f| col_out[f / pixels]).collect()
                }
            };
        }
    }
    Ok(LaneSafetyReport { layers: margins })
}

/// Verify a `(stack, schedule)` pair from scratch: compile the CSD
/// plans, flatten them, and run [`verify_with_arena`].
pub fn verify_stack(
    layers: &[LayerOp],
    schedule: &[LayerPrecision],
) -> Result<LaneSafetyReport, AnalysisError> {
    let plans: Vec<_> = layers.iter().map(|l| l.weights().plans()).collect();
    let arena = PlanArena::build(&plans);
    verify_with_arena(layers, &arena, schedule)
}

/// Build a full input row that reproduces a product wrap found at
/// layer 0: zeros everywhere except the witness value at (one feature
/// read by) the offending tap. Deeper layers return `None` — their
/// input ranges are abstract, not directly controllable.
fn synth_product_counterexample(
    layers: &[LayerOp],
    schedule: &[LayerPrecision],
    li: usize,
    tap: usize,
    witness: i64,
) -> Option<Vec<i64>> {
    if li != 0 {
        return None;
    }
    let mut row = vec![0i64; layers[0].in_len()];
    let feature = match &layers[0] {
        LayerOp::Dense(_) => Some(tap),
        LayerOp::Conv(c) => {
            let s = &c.shape;
            let mut found = None;
            'pixels: for oy in 0..s.out_h() {
                for ox in 0..s.out_w() {
                    if let Some(f) = s.src_index(tap, oy, ox) {
                        found = Some(f);
                        break 'pixels;
                    }
                }
            }
            found
        }
    }?;
    row[feature] = witness;
    find_first_wrap(layers, schedule, &row).is_some().then_some(row)
}

/// Build a full input row that reproduces an accumulator overflow
/// found at layer 0 by driving every tap of the offending column to
/// its extreme product (maximized when `maximize`, else minimized),
/// then confirming against the shadow executor. Deeper layers return
/// `None`.
fn synth_acc_counterexample(
    layers: &[LayerOp],
    schedule: &[LayerPrecision],
    arena: &PlanArena,
    bank: usize,
    li: usize,
    column: usize,
    maximize: bool,
) -> Option<Vec<i64>> {
    // The shadow executor replays the exact plans, so only bank-0
    // verdicts get a concrete confirmed witness; a truncated bank's
    // abstract verdict stands on its own.
    if li != 0 || bank != 0 {
        return None;
    }
    let p = schedule[0];
    let xs = Interval::full(p.in_bits);
    let d: i64 = if maximize { 1 } else { -1 };
    let col = arena.column(0, column);
    let mut best_x = vec![0i64; col.len()];
    let mut best_v = vec![0i64; col.len()];
    for (k, hd) in col.iter().enumerate() {
        if hd.is_zero() {
            continue;
        }
        let mut bx = 0i64;
        let mut score = i64::MIN;
        for x in xs.lo..=xs.hi {
            if let Ok(v) = eval_ops_checked(arena.walk(*hd), x, p.in_bits) {
                if d * v > score {
                    score = d * v;
                    bx = x;
                }
            }
        }
        if score == i64::MIN {
            return None;
        }
        best_x[k] = bx;
        best_v[k] = d * score;
    }
    let row = match &layers[0] {
        LayerOp::Dense(_) => best_x,
        LayerOp::Conv(c) => {
            // Pick the output pixel whose reachable taps drive the sum
            // furthest (padding zeroes the taps that hang off the
            // image), then place each tap's extreme input at the
            // feature that pixel reads — src_index is injective over
            // taps for a fixed pixel, so assignments never collide.
            let s = &c.shape;
            let widen = p.acc_bits - p.in_bits;
            let mut best_pixel = None;
            let mut best_total = i128::MIN;
            for oy in 0..s.out_h() {
                for ox in 0..s.out_w() {
                    let total: i128 = (0..s.patch_len())
                        .filter(|&k| s.src_index(k, oy, ox).is_some())
                        .map(|k| (d as i128) * ((best_v[k] as i128) << widen))
                        .sum();
                    if total > best_total {
                        best_total = total;
                        best_pixel = Some((oy, ox));
                    }
                }
            }
            let (oy, ox) = best_pixel?;
            let mut row = vec![0i64; s.in_len()];
            for (k, &x) in best_x.iter().enumerate() {
                if let Some(f) = s.src_index(k, oy, ox) {
                    row[f] = x;
                }
            }
            row
        }
    };
    find_first_wrap(layers, schedule, &row).is_some().then_some(row)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::csd::schedule::schedule;
    use crate::pipeline::stage1::mul_scalar_plan;
    use crate::workload::synth::XorShift64;

    #[test]
    fn checked_eval_matches_the_scalar_oracle_when_no_wrap() {
        let mut rng = XorShift64::new(0xA11CE);
        for _ in 0..2000 {
            let bits = [4u32, 6, 8][(rng.next_u64() % 3) as usize];
            let m = rng.q_raw(bits);
            let x = rng.q_raw(bits);
            let plan = schedule(m, bits);
            match checked_product(&plan, x, bits) {
                Ok(v) => assert_eq!(v, mul_scalar_plan(x, &plan, bits), "m={m} x={x}"),
                Err(()) => {
                    // The checked eval rejects exactly when the engine's
                    // wrapping (masked) result diverges from unbounded
                    // arithmetic — recompute without the mask to prove
                    // a wrap really happened.
                    let mut exact = 0i64;
                    for op in &plan.ops {
                        match *op {
                            MulOp::Shift { shift } => exact >>= shift,
                            MulOp::AddShift { shift, sign } => {
                                exact = if sign >= 0 { exact + x } else { exact - x };
                                exact >>= shift;
                            }
                        }
                    }
                    assert_ne!(
                        exact,
                        mul_scalar_plan(x, &plan, bits),
                        "m={m} x={x}: rejected but the engine agrees with exact arithmetic"
                    );
                }
            }
        }
    }

    #[test]
    fn minus_one_times_lane_minimum_wraps_and_is_witnessed() {
        // m = −1.0 (raw −128 @ Q1.7): the final shift-0 add computes
        // −x, which for x = −128 is +128 — out of the 8-bit lane.
        let plan = schedule(-128, 8);
        assert!(checked_product(&plan, -128, 8).is_err());
        let err = plan_product_range(plan.ops.iter().copied(), Interval::full(8), 8)
            .expect_err("must wrap");
        assert_eq!(err, Some(-128), "brute force names the witness");
    }

    #[test]
    fn brute_force_range_is_exact_for_every_8_bit_weight() {
        for m in -127i64..128 {
            let plan = schedule(m, 8);
            let got = plan_product_range(plan.ops.iter().copied(), Interval::full(8), 8)
                .unwrap_or_else(|w| panic!("m={m} wrapped (witness {w:?})"));
            let mut lo = i64::MAX;
            let mut hi = i64::MIN;
            for x in -128i64..128 {
                let v = mul_scalar_plan(x, &plan, 8);
                lo = lo.min(v);
                hi = hi.max(v);
            }
            assert_eq!(got, Interval { lo, hi }, "m={m}");
            assert!(got.contains(0), "m={m}: product interval must straddle 0");
        }
    }

    #[test]
    fn interval_transfer_is_sound_on_wide_lanes() {
        // 16-bit lanes exceed BRUTE_MAX_WIDTH, so this exercises the
        // abstract path; sampled concrete products must fall inside.
        let mut rng = XorShift64::new(0x16B17);
        for _ in 0..50 {
            let m = rng.q_raw(16);
            let plan = schedule(m, 16);
            if let Ok(iv) =
                plan_product_range(plan.ops.iter().copied(), Interval::full(16), 16)
            {
                for _ in 0..500 {
                    let x = rng.q_raw(16);
                    if let Ok(v) = checked_product(&plan, x, 16) {
                        assert!(iv.contains(v), "m={m} x={x} v={v} not in {iv}");
                    }
                }
            }
        }
    }

    #[test]
    fn bits_needed_is_the_tight_twos_complement_width() {
        assert_eq!(bits_needed(0, 0), 1);
        assert_eq!(bits_needed(-1, 0), 1);
        assert_eq!(bits_needed(-2, 0), 2);
        assert_eq!(bits_needed(0, 1), 2);
        assert_eq!(bits_needed(-128, 127), 8);
        assert_eq!(bits_needed(-129, 0), 9);
        assert_eq!(bits_needed(0, 128), 9);
        assert_eq!(bits_needed(-1024, 992), 11);
    }
}
