//! Property tests for the static cost certifier (DESIGN.md §15, §18).
//!
//! The certificate claims to be an *exact upper bound* of the engine's
//! billing: for any model (interleaved conv + dense), any variant of
//! the standard trio, and any batch size, the dense
//! `CostCertificate::eval_stats` minus the batch's own zero-skip
//! counters (`eval_stats_with_skips`) must equal the runtime
//! `EngineStats` on **every** field — aggregates and per-format
//! buckets, the conservation law `dense == executed + skipped` holding
//! as a `u64` equality — and the certified energy must be
//! bit-identical to the measured bill under a cost table with distinct
//! per-format rates. Under `--features billaudit` the differential
//! auditor is additionally checked in both directions: silent on real
//! batches, tripped by a single perturbed counter (the mutation test),
//! including the laundering move that shifts cycles between the
//! executed and skipped columns.

use softsimd::bits::format::FORMATS;
use softsimd::coordinator::cost::CostTable;
use softsimd::coordinator::engine::{EngineScratch, PackedEngine};
use softsimd::coordinator::model::{CompiledModel, VariantSpec};
use softsimd::nn::conv::{ConvShape, LayerOp};
use softsimd::testutil::{
    random_batch, random_conv_for_shape, random_conv_shape, random_dense,
};
use softsimd::workload::synth::XorShift64;

/// A cost table with a *distinct* Stage-1 rate per format — a billing
/// bug that books cycles into the wrong format bucket changes the
/// energy here, which the flat 1-pJ table would mask.
fn spiky_cost() -> CostTable {
    CostTable {
        mhz: 1000.0,
        s1_cycle_pj: FORMATS.iter().map(|&b| (b, 0.125 * b as f64 + 0.011)).collect(),
        s2_pass_pj: 0.37,
        area_um2: 1000.0,
    }
}

/// A valid conv geometry over a *fixed* input tensor `(cin, h, w)` —
/// random kernel/stride/padding, falling back to the always-valid 1×1
/// kernel (any nonzero input admits it).
fn conv_shape_from(rng: &mut XorShift64, cin: usize, h: usize, w: usize) -> ConvShape {
    for _ in 0..64 {
        let kh = 1 + (rng.next_u64() % 3) as usize;
        let kw = 1 + (rng.next_u64() % 3) as usize;
        let shape = ConvShape {
            cin,
            h,
            w,
            cout: 1 + (rng.next_u64() % 3) as usize,
            kh,
            kw,
            stride: 1 + (rng.next_u64() % 2) as usize,
            pad: (rng.next_u64() % kh.min(kw) as u64) as usize,
        };
        if shape.validate().is_ok() {
            return shape;
        }
    }
    ConvShape { cin, h, w, cout: 1, kh: 1, kw: 1, stride: 1, pad: 0 }
}

/// A random interleaved conv + dense stack with chaining widths. Conv
/// input geometry is decided one layer ahead: a dense layer feeding a
/// conv picks that conv's shape first and sizes its own output to the
/// shape's flattened input; a conv feeding a conv reuses its output
/// feature map's geometry.
fn random_mixed_stack(rng: &mut XorShift64, n_layers: usize, w_bits: u32) -> Vec<LayerOp> {
    let kinds: Vec<bool> = (0..n_layers).map(|_| rng.next_u64() % 2 == 0).collect();
    let mut ops: Vec<LayerOp> = Vec::new();
    let mut pending: Option<ConvShape> = None;
    let mut width = 0usize;
    for i in 0..n_layers {
        if kinds[i] {
            let shape = match pending.take() {
                Some(s) => s,
                None => match ops.last() {
                    // Conv after conv: the previous output feature map
                    // is this layer's input tensor.
                    Some(LayerOp::Conv(c)) => {
                        let p = c.shape;
                        conv_shape_from(rng, p.cout, p.out_h(), p.out_w())
                    }
                    Some(LayerOp::Dense(_)) => {
                        unreachable!("dense-before-conv always sets `pending`")
                    }
                    // Conv-first model.
                    None => random_conv_shape(rng, 1 + (rng.next_u64() % 2) as usize),
                },
            };
            width = shape.out_len();
            ops.push(LayerOp::Conv(random_conv_for_shape(rng, shape, w_bits)));
        } else {
            let out = if i + 1 < n_layers && kinds[i + 1] {
                let s = random_conv_shape(rng, 1 + (rng.next_u64() % 2) as usize);
                pending = Some(s);
                s.in_len()
            } else {
                1 + (rng.next_u64() % 5) as usize
            };
            let k = if i == 0 { 2 + (rng.next_u64() % 5) as usize } else { width };
            let mut dense = random_dense(rng, k, out, w_bits);
            // Sprinkle exact zeros so the zero-skip is always exercised.
            for row in &mut dense.w_raw {
                for w in row.iter_mut() {
                    if rng.next_u64() % 5 == 0 {
                        *w = 0;
                    }
                }
            }
            ops.push(LayerOp::Dense(dense));
            width = out;
        }
    }
    ops
}

#[test]
fn certificate_equals_engine_stats_on_random_conv_dense_stacks() {
    let mut rng = XorShift64::new(0xC057_CE21);
    let cost = spiky_cost();
    let mut scratch = EngineScratch::new();
    let mut out = Vec::new();
    for case in 0..25 {
        let n_layers = 1 + (rng.next_u64() % 4) as usize;
        let ops = random_mixed_stack(&mut rng, n_layers, 8);
        let model =
            CompiledModel::compile_variants(ops, VariantSpec::standard_trio(n_layers))
                .unwrap_or_else(|e| panic!("case {case}: compile failed: {e}"));
        let in_width = model.input_width();
        let engine = PackedEngine::new(model);
        for v in 0..engine.model().n_variants() {
            let var = engine.model().variant(v);
            let cert = engine.model().cost_certificate(v);
            let q = cert.batch_quantum;
            let ms = [1, 1 + (rng.next_u64() % 20) as usize, q, q + 1];
            for m in ms {
                let batch: Vec<Vec<i64>> = random_batch(&mut rng, m, in_width, 8)
                    .iter()
                    .map(|r| var.quantize_row(r))
                    .collect();
                let stats = engine.forward_batch_into(&batch, v, &mut scratch, &mut out);
                // Field-exact, bucket-exact reconstruction under the
                // skip-conditioned upper-bound contract.
                let conditioned = cert.eval_stats_with_skips(m, &stats);
                assert_eq!(
                    conditioned,
                    stats,
                    "case {case} variant {v} ({}) m={m}",
                    var.name()
                );
                // Conservation: executed + skipped is the dense bill,
                // which also bounds the measured work from above.
                let dense = cert.eval_stats(m);
                assert_eq!(
                    stats.s1_cycles + stats.skipped_cycles,
                    dense.s1_cycles,
                    "case {case} variant {v} m={m}: conservation"
                );
                assert_eq!(stats.s1_adds + stats.skipped_adds, dense.s1_adds);
                // Energy: same stats priced through the same table is
                // the same float — bit-identical, hence aJ-identical
                // after the metrics rounding.
                let measured = cost.batch_energy_pj(&stats);
                let predicted = cost.batch_energy_pj(&conditioned);
                assert_eq!(
                    measured.to_bits(),
                    predicted.to_bits(),
                    "case {case} variant {v} m={m}: {measured} vs {predicted} pJ"
                );
                assert_eq!(
                    (measured * 1e6).round() as u64,
                    (predicted * 1e6).round() as u64
                );
            }
        }
    }
}

#[test]
fn dense_billing_is_value_independent_and_skipping_conserves_it() {
    // With zero-skipping forced off, billing depends on (model,
    // variant, m) only and the dense certificate is field-exact. With
    // it on (the default), an all-zero batch elides every Stage-1 plan
    // while the value-independent fields stay untouched, and the
    // conservation law reconstructs the dense bill exactly.
    let mut rng = XorShift64::new(0xC057_CE22);
    let ops = random_mixed_stack(&mut rng, 3, 8);
    let model = CompiledModel::compile_variants(ops, VariantSpec::standard_trio(3))
        .expect("valid stack");
    let in_width = model.input_width();
    let dense_engine = PackedEngine::new(model.clone()).with_zero_skip(false);
    let engine = PackedEngine::new(model);
    let cert = engine.model().cost_certificate(0);
    let m = 5;
    let zeros = vec![vec![0i64; in_width]; m];
    let batch = random_batch(&mut rng, m, in_width, 8);
    let (_, d_zero) = dense_engine.forward_batch_variant(&zeros, 0);
    let (_, d_rand) = dense_engine.forward_batch_variant(&batch, 0);
    assert_eq!(d_zero, d_rand, "dense path must be value-independent");
    assert_eq!(cert.eval_stats(m), d_rand);
    assert_eq!(d_rand.skipped_cycles, 0);
    let (_, s_zero) = engine.forward_batch_variant(&zeros, 0);
    assert_eq!(s_zero.s1_cycles, 0, "all-zero batch executes no Stage-1 work");
    assert_eq!(s_zero.skipped_cycles, d_rand.s1_cycles);
    assert_eq!(s_zero.skipped_adds, d_rand.s1_adds);
    // Value-independent fields are billed identically either way.
    assert_eq!(s_zero.s2_passes, d_rand.s2_passes);
    assert_eq!(s_zero.acc_adds, d_rand.acc_adds);
    assert_eq!(s_zero.subword_mults, d_rand.subword_mults);
    assert_eq!(s_zero.pad_rows, d_rand.pad_rows);
    assert_eq!(cert.eval_stats_with_skips(m, &s_zero), s_zero);
}

#[cfg(feature = "billaudit")]
mod billaudit {
    use super::*;
    use softsimd::analysis::cost::audit;
    use softsimd::coordinator::engine::EngineStats;

    #[test]
    fn auditor_is_silent_across_real_batches_and_variants() {
        let mut rng = XorShift64::new(0xB111_0001);
        audit::reset();
        for _ in 0..5 {
            let n_layers = 1 + (rng.next_u64() % 3) as usize;
            let ops = random_mixed_stack(&mut rng, n_layers, 8);
            let model =
                CompiledModel::compile_variants(ops, VariantSpec::standard_trio(n_layers))
                    .expect("valid stack");
            let in_width = model.input_width();
            let engine = PackedEngine::new(model);
            for v in 0..engine.model().n_variants() {
                let var = engine.model().variant(v);
                let m = 1 + (rng.next_u64() % 15) as usize;
                let batch: Vec<Vec<i64>> = random_batch(&mut rng, m, in_width, 8)
                    .iter()
                    .map(|r| var.quantize_row(r))
                    .collect();
                // The engine checks every batch against the certificate
                // on its own under `billaudit`.
                let _ = engine.forward_batch_variant(&batch, v);
            }
        }
        assert_eq!(audit::count(), 0, "divergences: {:?}", audit::take());
    }

    /// The mutation test the certifier is graded on: perturb each
    /// billing counter by one and prove the auditor trips on exactly
    /// that field — so a real billing regression cannot slip past it.
    #[test]
    fn auditor_trips_on_each_perturbed_counter() {
        let mut rng = XorShift64::new(0xB111_0002);
        let ops = random_mixed_stack(&mut rng, 3, 8);
        let model = CompiledModel::compile_variants(ops, VariantSpec::standard_trio(3))
            .expect("valid stack");
        let engine = PackedEngine::new(model);
        let cert = engine.model().cost_certificate(1);
        let m = 7;
        let good = cert.eval_stats(m);
        audit::reset();
        audit::check_batch(cert, &good, m);
        assert_eq!(audit::count(), 0, "unperturbed stats must be silent");

        let cases: [(&str, fn(&mut EngineStats)); 9] = [
            ("s1_cycles", |s| s.s1_cycles += 1),
            ("s1_adds", |s| s.s1_adds += 1),
            ("s2_passes", |s| s.s2_passes += 1),
            ("acc_adds", |s| s.acc_adds += 1),
            ("subword_mults", |s| s.subword_mults += 1),
            ("pad_rows", |s| s.pad_rows += 1),
            ("s1_cycles_by_fmt[4b]", |s| s.s1_cycles_by_fmt[0] += 1),
            ("s1_adds_by_fmt[4b]", |s| s.s1_adds_by_fmt[0] += 1),
            ("s2_passes_by_fmt[4b]", |s| s.s2_passes_by_fmt[0] += 1),
        ];
        for (field, mutate) in cases {
            let mut bad = good;
            mutate(&mut bad);
            audit::reset();
            audit::check_batch(cert, &bad, m);
            assert_eq!(audit::count(), 1, "mutating {field} must trip once");
            let log = audit::take();
            assert_eq!(log[0].field, field);
            assert_eq!(log[0].m, m);
            assert_eq!(log[0].got, log[0].expected + 1, "{field}");
            assert_eq!(log[0].variant, engine.model().variant(1).name());
        }

        // Laundering: moving a cycle from the executed column to the
        // skipped column keeps the conservation sum intact, so only
        // the skip-consistency check (aggregate skipped vs its by-fmt
        // sum) can catch it — and it must.
        let mut laundered = good;
        laundered.s1_cycles -= 1;
        laundered.skipped_cycles += 1;
        audit::reset();
        audit::check_batch(cert, &laundered, m);
        assert_eq!(audit::count(), 1, "laundering must trip exactly once");
        let log = audit::take();
        assert_eq!(log[0].field, "skipped_cycles_sum");
        assert_eq!(log[0].expected, 1);
        assert_eq!(log[0].got, 0);

        // Over-claiming skips: more skipped plans than the model has
        // packed operand words is structurally impossible and trips
        // the plan-count cap.
        let mut inflated = good;
        inflated.skipped_plans = cert.plan_words(m) + 1;
        audit::reset();
        audit::check_batch(cert, &inflated, m);
        assert_eq!(audit::count(), 1, "skip over-claim must trip exactly once");
        let log = audit::take();
        assert_eq!(log[0].field, "skipped_plans");
        assert_eq!(log[0].expected, cert.plan_words(m));
        assert_eq!(log[0].got, cert.plan_words(m) + 1);
    }
}
