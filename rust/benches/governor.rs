//! Governor step-load benchmark (DESIGN.md §13): drive the serving
//! coordinator through light → burst → light phases under the default
//! SLO hysteresis policy and record, per phase, throughput, windowed
//! p99 latency and how the executed rows split across the precision
//! variants — the machine-readable trace of the governor shedding
//! precision under overload and recovering afterwards.
//!
//! Every cell goes to `BENCH_governor.json` (hand-rolled JSON — serde
//! is unavailable offline) so CI archives the governor's behavior
//! alongside the other perf artifacts.

#[path = "benchkit.rs"]
mod benchkit;
use benchkit::write_cells;

use std::sync::Arc;
use std::time::{Duration, Instant};

use softsimd::coordinator::governor::SloPolicy;
use softsimd::coordinator::model::{CompiledModel, VariantSpec};
use softsimd::coordinator::server::{Coordinator, Request, ServeConfig};
use softsimd::nn::conv::LayerOp;
use softsimd::testutil::{flat_cost, random_dense_stack_uniform};
use softsimd::workload::synth::XorShift64;

struct PhaseCell {
    phase: &'static str,
    requests: usize,
    rows: u64,
    rows_per_s: f64,
    p99_us: f64,
    /// Rows executed per variant during this phase.
    variant_rows: Vec<u64>,
    end_variant: usize,
}

impl PhaseCell {
    fn json(&self) -> String {
        let vr: Vec<String> = self.variant_rows.iter().map(u64::to_string).collect();
        format!(
            "{{\"phase\":\"{}\",\"requests\":{},\"rows\":{},\"rows_per_s\":{:.1},\
             \"p99_us\":{:.1},\"variant_rows\":[{}],\"end_variant\":{}}}",
            self.phase,
            self.requests,
            self.rows,
            self.rows_per_s,
            self.p99_us,
            vr.join(","),
            self.end_variant
        )
    }
}

/// Serve one phase: `reqs` requests of `rows_per_req` rows, optionally
/// paced, then drain; measure everything from metric-snapshot deltas.
fn phase(
    coord: &mut Coordinator,
    rng: &mut XorShift64,
    name: &'static str,
    reqs: usize,
    rows_per_req: usize,
    pace: Option<Duration>,
) -> PhaseCell {
    let before = coord.metrics.snapshot();
    let t0 = Instant::now();
    for id in 0..reqs {
        let req = Request {
            id: id as u64,
            rows: (0..rows_per_req)
                .map(|_| (0..64).map(|_| rng.q_raw(8)).collect())
                .collect(),
        };
        coord.submit(req).expect("live workers");
        if let Some(gap) = pace {
            std::thread::sleep(gap);
        }
    }
    let responses = coord.drain().expect("drain");
    assert_eq!(responses.len(), reqs);
    let wall = t0.elapsed().as_secs_f64();
    let after = coord.metrics.snapshot();
    let rows = after.window_rows(&before);
    let variant_rows: Vec<u64> = after
        .per_variant
        .iter()
        .zip(&before.per_variant)
        .map(|(a, b)| a.rows - b.rows)
        .collect();
    PhaseCell {
        phase: name,
        requests: reqs,
        rows,
        rows_per_s: rows as f64 / wall.max(1e-9),
        p99_us: after.window_latency_quantile_ns(&before, 0.99).unwrap_or(0) as f64 / 1e3,
        variant_rows,
        end_variant: coord.active_variant(),
    }
}

fn main() {
    println!("== governor: step-load precision shedding ==");
    let mut rng = XorShift64::new(0x90EB);
    let layers = random_dense_stack_uniform(&mut rng, &[64, 48, 24, 10], 8);
    let ops: Vec<LayerOp> = layers.into_iter().map(LayerOp::Dense).collect();
    let model =
        CompiledModel::compile_variants(ops, VariantSpec::standard_trio(3)).expect("trio");
    // Queue-depth hysteresis: shed past two batches' worth of backlog,
    // recover below half a batch, after two calm decisions.
    let policy = SloPolicy::new(Duration::from_millis(5), 48, 8).patience(2);
    let cfg = ServeConfig::new(2, 24)
        .deadline(Duration::from_millis(2))
        .queue_depth(1);
    let mut coord =
        Coordinator::start_with_policy(Arc::clone(&model), cfg, flat_cost(), Box::new(policy))
            .expect("start");

    let cells = vec![
        // Light open-loop traffic: the governor should hold hi-fi.
        phase(&mut coord, &mut rng, "light-1", 64, 1, Some(Duration::from_micros(300))),
        // Step overload: a closed-loop burst of full batches.
        phase(&mut coord, &mut rng, "burst", 48, 24, None),
        // Light again: the governor should walk back to hi-fi.
        phase(&mut coord, &mut rng, "light-2", 64, 1, Some(Duration::from_micros(300))),
    ];

    println!(
        "{:<10} {:>6} {:>8} {:>12} {:>10} {:>24} {:>12}",
        "phase", "reqs", "rows", "rows/s", "p99 us", "rows by variant", "end variant"
    );
    for c in &cells {
        println!(
            "{:<10} {:>6} {:>8} {:>12.0} {:>10.1} {:>24} {:>12}",
            c.phase,
            c.requests,
            c.rows,
            c.rows_per_s,
            c.p99_us,
            format!("{:?}", c.variant_rows),
            c.end_variant
        );
    }
    let burst = &cells[1];
    let recovered = &cells[2];
    if burst.variant_rows[1..].iter().sum::<u64>() == 0 {
        println!("NOTE: burst never shed precision (machine outpaced the load)");
    }
    if recovered.end_variant != 0 {
        println!("NOTE: governor had not recovered hi-fi by the end of light-2");
    }
    println!("\n{}", coord.metrics.report());
    coord.shutdown();

    let cell_json: Vec<String> = cells.iter().map(PhaseCell::json).collect();
    write_cells("governor", "BENCH_governor.json", &cell_json);
}
