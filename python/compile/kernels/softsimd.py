"""Pallas kernels — the Soft SIMD compute hot-spots (L1).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's ASIC
bit-slice muxes and carry-kill gates become per-format *mask vectors*
applied with lane-parallel bitwise ops; `BlockSpec` expresses the
HBM↔VMEM schedule over blocks of packed words (multiples of 128 lanes
for the VPU), and the digit plan — tiny and scalar — rides along in
VMEM. `interpret=True` everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls; real-TPU lowering is compile-only (see DESIGN.md).
"""

from __future__ import annotations

import functools

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import defs
from . import ref

# Block of packed words processed per grid step: 2 VPU sublane-rows of
# 128 lanes. The mul artifact's word count must be a multiple of this.
MUL_BLOCK = 256


def _mul_kernel(x_ref, shifts_ref, signs_ref, h_ref, l_ref, o_ref):
    """Packed Soft SIMD multiply over one block of words.

    x_ref: u64[B]  packed multiplicands        (VMEM block)
    shifts_ref, signs_ref: i32[OPS]            (whole, VMEM)
    h_ref, l_ref: u64[1]                       MSB / LSB masks (the V_x vector)
    o_ref: u64[B] packed products
    """
    x = x_ref[...]
    h = h_ref[0]
    l = l_ref[0]
    ops = shifts_ref.shape[0]

    def body(o, acc):
        return ref.dynamic_mul_step(acc, x, shifts_ref[o], signs_ref[o], h, l)

    acc = jax.lax.fori_loop(0, ops, body, jnp.zeros_like(x))
    o_ref[...] = acc


def mul_packed_pallas(x_words, shifts, signs, h_mask, l_mask, block: int = MUL_BLOCK):
    """Packed multiply of `x_words: u64[N]` (N a multiple of `block`) by
    the runtime digit plan; `h_mask`/`l_mask` are u64[1] format masks."""
    n = x_words.shape[0]
    assert n % block == 0, f"word count {n} not a multiple of block {block}"
    grid = (n // block,)
    return pl.pallas_call(
        _mul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec(shifts.shape, lambda i: (0,)),
            pl.BlockSpec(signs.shape, lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint64),
        interpret=True,
    )(x_words, shifts, signs, h_mask, l_mask)


# --------------------------------------------------------------------------
# Quantized layer kernel (scalar semantics, tiled over output neurons)
# --------------------------------------------------------------------------

LAYER_TILE_N = 8  # output-neuron tile per grid step


def _layer_kernel(x_ref, shifts_ref, signs_ref, o_ref, *, in_bits: int, acc_bits: int):
    """One tile of a quantized linear layer.

    x_ref:      int32[M, K]        activations (whole, VMEM)
    shifts_ref: int32[K, Tn, O]    plan tile
    signs_ref:  int32[K, Tn, O]
    o_ref:      int32[M, Tn]       pre-activation accumulators
    """
    x = x_ref[...][:, :, None]  # [M, K, 1]
    ops = shifts_ref.shape[-1]
    m, k = x_ref.shape
    tn = shifts_ref.shape[1]
    mask = jnp.int32((1 << in_bits) - 1)
    half = jnp.int32(1 << (in_bits - 1))

    def body(o, acc):
        s = shifts_ref[:, :, o][None, :, :]
        g = signs_ref[:, :, o][None, :, :]
        a = acc + g * x
        a = jnp.right_shift(a, s)
        w = a & mask
        return w - ((w & half) << 1)

    acc = jax.lax.fori_loop(0, ops, body, jnp.zeros((m, k, tn), jnp.int32))
    prod_wide = acc << (acc_bits - in_bits)
    total = jnp.sum(prod_wide, axis=1, dtype=jnp.int32)
    wmask = jnp.int32((1 << acc_bits) - 1)
    whalf = jnp.int32(1 << (acc_bits - 1))
    tw = total & wmask
    o_ref[...] = tw - ((tw & whalf) << 1)


def layer_pallas(x_q, shifts, signs, in_bits: int = 8, acc_bits: int = 16,
                 tile_n: int = LAYER_TILE_N):
    """Quantized linear layer on the Soft SIMD multiply semantics,
    tiled over output neurons. Must match `ref.layer_ref` bit-exactly."""
    m, k = x_q.shape
    k2, n, ops = shifts.shape
    assert k == k2 and signs.shape == shifts.shape
    assert n % tile_n == 0, f"N={n} not a multiple of tile {tile_n}"
    kern = functools.partial(_layer_kernel, in_bits=in_bits, acc_bits=acc_bits)
    return pl.pallas_call(
        kern,
        grid=(n // tile_n,),
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),
            pl.BlockSpec((k, tile_n, ops), lambda i: (0, i, 0)),
            pl.BlockSpec((k, tile_n, ops), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((m, tile_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(x_q, shifts, signs)
