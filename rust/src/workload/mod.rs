//! Synthetic workload generation (the paper's ML-at-the-edge context).

pub mod synth;

pub use synth::{Digits, LayerSpec, Scenario, XorShift64};
