//! End-to-end tests of the SLO-driven precision governor
//! (DESIGN.md §13) — the acceptance scenario of the multi-variant
//! serving stack:
//!
//! 1. **Step load.** Under a burst that saturates the single PE, the
//!    governor sheds precision to the cheapest variant; under a light
//!    trickle it recovers to full fidelity — observed through
//!    `Coordinator::active_variant`, the per-variant metrics buckets
//!    and each `Response`'s variant tag.
//! 2. **Billing exactness.** Every executed batch is billed by the
//!    *single-variant* formulas of the variant that executed it:
//!    per-variant cycle/energy buckets equal a direct engine run of
//!    the same rows at that variant, and every response is bit-exact
//!    against the per-variant scalar oracle (reference rows
//!    requantized by the variant's `in_shift`).
//!
//! Determinism notes: the step-load test drives decisions purely from
//! queue depth (the p99 target is set far out of reach), uses one PE
//! with queue depth 1 so backpressure serializes the burst, and a
//! deadline long enough that only submit-path and drain-path
//! dispatches ever happen.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use softsimd::coordinator::engine::PackedEngine;
use softsimd::coordinator::governor::{PinnedVariant, SloPolicy};
use softsimd::coordinator::model::{CompiledModel, VariantSpec};
use softsimd::coordinator::server::{Coordinator, Request, ServeConfig};
use softsimd::nn::conv::LayerOp;
use softsimd::nn::exec::mlp_forward_row_mixed;
use softsimd::nn::weights::QuantLayer;
use softsimd::testutil::{flat_cost, random_dense_stack_uniform};
use softsimd::workload::synth::XorShift64;

/// The shared step-load model: a 3-layer MLP heavy enough that one
/// batch outlasts the whole submit loop, carrying the standard
/// hi-fi / balanced / turbo trio.
fn trio_model(rng: &mut XorShift64) -> (Vec<QuantLayer>, Arc<CompiledModel>) {
    let layers = random_dense_stack_uniform(rng, &[64, 48, 24, 10], 8);
    let ops: Vec<LayerOp> = layers.iter().cloned().map(LayerOp::Dense).collect();
    let model = CompiledModel::compile_variants(ops, VariantSpec::standard_trio(3)).unwrap();
    (layers, model)
}

/// The per-variant scalar oracle: requantize the reference-precision
/// row exactly like the serving loop, then run the variant's schedule.
fn variant_oracle(model: &CompiledModel, layers: &[QuantLayer], v: usize, row: &[i64]) -> Vec<i64> {
    let var = model.variant(v);
    mlp_forward_row_mixed(&var.quantize_row(row), layers, var.schedule())
}

#[test]
fn step_load_sheds_precision_under_overload_and_recovers_when_calm() {
    let mut rng = XorShift64::new(0x90E40001);
    let (layers, model) = trio_model(&mut rng);
    assert_eq!(model.n_variants(), 3);
    // Queue-depth-driven policy: the high watermark is exactly one
    // burst batch's rows, so the first burst dispatch (nothing else
    // outstanding) holds hi-fi and every later one — which sees at
    // least the previous batch still outstanding — sheds a step; the
    // p99 objective is far out of reach so latency never triggers.
    let policy = SloPolicy::new(Duration::from_secs(300), 24, 4).patience(2);
    let cfg = ServeConfig::new(1, 12)
        .deadline(Duration::from_secs(60))
        .queue_depth(1);
    let mut coord =
        Coordinator::start_with_policy(Arc::clone(&model), cfg, flat_cost(), Box::new(policy))
            .unwrap();
    assert_eq!(coord.active_variant(), 0);

    // --- Step up: a burst of full batches, submitted far faster than
    // one PE can clear them. Each submit forms and dispatches one
    // 24-row batch; from the second dispatch on the previous batches
    // are still outstanding, so the governor sheds one step per
    // dispatch down to the cheapest variant.
    let burst: Vec<Request> = (0..8u64)
        .map(|id| Request {
            id,
            rows: (0..24).map(|_| (0..64).map(|_| rng.q_raw(8)).collect()).collect(),
        })
        .collect();
    for r in &burst {
        coord.submit(r.clone()).unwrap();
    }
    let responses = coord.drain().unwrap();
    assert_eq!(responses.len(), burst.len());
    assert_eq!(
        coord.active_variant(),
        2,
        "sustained overload must shed to the cheapest variant"
    );
    // Every response is bit-exact against the oracle of the variant
    // that *actually executed* it — whichever that was.
    for resp in &responses {
        for (i, row) in burst[resp.id as usize].rows.iter().enumerate() {
            let want = variant_oracle(&model, &layers, resp.variant, row);
            assert_eq!(resp.logits[i], want, "req {} row {i} (variant {})", resp.id, resp.variant);
        }
    }
    // The burst demonstrably executed across the shed: fidelity first,
    // turbo by the end.
    assert_eq!(responses.iter().find(|r| r.id == 0).unwrap().variant, 0);
    assert_eq!(responses.iter().find(|r| r.id == 7).unwrap().variant, 2);
    let m = &coord.metrics;
    assert!(m.per_variant[0].rows.load(Ordering::Relaxed) > 0);
    assert!(
        m.per_variant[2].rows.load(Ordering::Relaxed) > 0,
        "turbo bucket must have executed rows"
    );
    assert!(
        m.variant_switches.load(Ordering::Relaxed) >= 2,
        "0→1→2 is at least two switches"
    );

    // --- Step down: a light trickle (one straggler per drain, queue
    // empty at every decision). With patience 2 the governor walks
    // back 2→1→0 over four calm dispatches and stays there.
    let mut last_variant = usize::MAX;
    for i in 0..6u64 {
        let req = Request {
            id: 100 + i,
            rows: vec![(0..64).map(|_| rng.q_raw(8)).collect()],
        };
        let rows = req.rows.clone();
        coord.submit(req).unwrap();
        let responses = coord.drain().unwrap();
        assert_eq!(responses.len(), 1);
        let want = variant_oracle(&model, &layers, responses[0].variant, &rows[0]);
        assert_eq!(responses[0].logits[0], want, "trickle {i}");
        last_variant = responses[0].variant;
    }
    assert_eq!(coord.active_variant(), 0, "calm traffic must recover full fidelity");
    assert_eq!(last_variant, 0, "the last trickle batch executed at hi-fi");
    coord.shutdown();
}

#[test]
fn overload_sheds_down_the_five_rung_ladder_into_approximate_serving() {
    // DESIGN.md §18: `standard_ladder` appends two truncated-CSD rungs
    // (approx-t2, approx-d1) below the exact trio, and the governor's
    // shed walk must reach them under sustained overload — approximate
    // serving is an *operating point*, not a separate code path.
    let mut rng = XorShift64::new(0x90E40004);
    let layers = random_dense_stack_uniform(&mut rng, &[64, 48, 24, 10], 8);
    let ops: Vec<LayerOp> = layers.iter().cloned().map(LayerOp::Dense).collect();
    let model = CompiledModel::compile_variants(ops, VariantSpec::standard_ladder(3)).unwrap();
    assert_eq!(model.n_variants(), 5);
    assert!(
        model.variant(3).is_approximate() && model.variant(4).is_approximate(),
        "the bottom two rungs are the truncated banks"
    );
    let engine = PackedEngine::new(Arc::clone(&model));
    let policy = SloPolicy::new(Duration::from_secs(300), 24, 4).patience(2);
    let cfg = ServeConfig::new(1, 12)
        .deadline(Duration::from_secs(60))
        .queue_depth(1);
    let mut coord =
        Coordinator::start_with_policy(Arc::clone(&model), cfg, flat_cost(), Box::new(policy))
            .unwrap();
    // Same step-load shape as the trio test, two bursts longer: one
    // shed step per overloaded dispatch walks 0→1→2→3→4 and pins there.
    let burst: Vec<Request> = (0..10u64)
        .map(|id| Request {
            id,
            rows: (0..24).map(|_| (0..64).map(|_| rng.q_raw(8)).collect()).collect(),
        })
        .collect();
    for r in &burst {
        coord.submit(r.clone()).unwrap();
    }
    let responses = coord.drain().unwrap();
    assert_eq!(responses.len(), burst.len());
    assert_eq!(
        coord.active_variant(),
        4,
        "sustained overload must bottom out at the cheapest approximate rung"
    );
    assert_eq!(responses.iter().find(|r| r.id == 0).unwrap().variant, 0);
    assert_eq!(
        responses.iter().find(|r| r.id == 9).unwrap().variant,
        4,
        "the tail of the burst executed at approx-d1"
    );
    // Every response — approximate rungs included — is bit-exact
    // against a direct engine run at the variant that executed it:
    // shedding into a truncated bank changes *which* plans run, never
    // how the chosen plans compute.
    for resp in &responses {
        let rows: Vec<Vec<i64>> = burst[resp.id as usize]
            .rows
            .iter()
            .map(|r| model.variant(resp.variant).quantize_row(r))
            .collect();
        let (want, _) = engine.forward_batch_variant(&rows, resp.variant);
        assert_eq!(resp.logits, want, "req {} (variant {})", resp.id, resp.variant);
    }
    // Both approximate buckets demonstrably served rows.
    let m = &coord.metrics;
    assert!(m.per_variant[3].rows.load(Ordering::Relaxed) > 0, "approx-t2 bucket");
    assert!(m.per_variant[4].rows.load(Ordering::Relaxed) > 0, "approx-d1 bucket");
    coord.shutdown();
}

#[test]
fn per_variant_billing_is_pinned_to_the_single_variant_formulas() {
    // The acceptance billing criterion: serve one deterministic batch
    // per pinned variant and require the executed variant's metrics
    // bucket to equal — exactly — a direct engine run of the same rows
    // at that variant (which tests/flat_kernel.rs in turn pins to the
    // pre-refactor single-variant formulas), with the energy billed at
    // the cost table's figure for precisely those stats and all other
    // variants' buckets untouched.
    let mut rng = XorShift64::new(0x90E40002);
    let (layers, model) = trio_model(&mut rng);
    let engine = PackedEngine::new(Arc::clone(&model));
    let rows: Vec<Vec<i64>> = (0..24)
        .map(|_| (0..64).map(|_| rng.q_raw(8)).collect())
        .collect();
    for v in 0..model.n_variants() {
        let cfg = ServeConfig::new(1, 24).deadline(Duration::from_secs(60));
        let mut coord = Coordinator::start_with_policy(
            Arc::clone(&model),
            cfg,
            flat_cost(),
            Box::new(PinnedVariant(v)),
        )
        .unwrap();
        coord.submit(Request { id: 0, rows: rows.clone() }).unwrap();
        let responses = coord.drain().unwrap();
        let metrics = Arc::clone(&coord.metrics);
        coord.shutdown();
        assert_eq!(responses.len(), 1);
        assert_eq!(responses[0].variant, v);
        assert_eq!(
            metrics.batches.load(Ordering::Relaxed),
            1,
            "variant {v}: the 24-row request must serve as one batch"
        );
        // The worker's transform, replayed: requantize, then execute
        // the variant directly on a fresh engine.
        let shifted: Vec<Vec<i64>> =
            rows.iter().map(|r| model.variant(v).quantize_row(r)).collect();
        let (want_out, want_stats) = engine.forward_batch_variant(&shifted, v);
        assert_eq!(responses[0].logits, want_out, "variant {v} logits");
        for (b, row) in rows.iter().enumerate() {
            assert_eq!(
                responses[0].logits[b],
                variant_oracle(&model, &layers, v, row),
                "variant {v} row {b} vs scalar oracle"
            );
        }
        let vb = &metrics.per_variant[v];
        assert_eq!(vb.batches.load(Ordering::Relaxed), 1);
        assert_eq!(vb.rows.load(Ordering::Relaxed), 24);
        assert_eq!(vb.pad_rows.load(Ordering::Relaxed), want_stats.pad_rows);
        assert_eq!(vb.subword_mults.load(Ordering::Relaxed), want_stats.subword_mults);
        assert_eq!(vb.s1_cycles.load(Ordering::Relaxed), want_stats.s1_cycles);
        assert_eq!(vb.s2_passes.load(Ordering::Relaxed), want_stats.s2_passes);
        let want_pj = flat_cost().batch_energy_pj(&want_stats);
        assert_eq!(
            vb.energy_aj.load(Ordering::Relaxed),
            (want_pj * 1e6).round() as u64,
            "variant {v}: energy must be the single-variant figure, exactly"
        );
        // Aggregates equal the single bucket; every other bucket is
        // empty — nothing was billed to a variant that didn't execute.
        assert_eq!(
            metrics.s1_cycles.load(Ordering::Relaxed),
            want_stats.s1_cycles
        );
        for (u, ub) in metrics.per_variant.iter().enumerate() {
            if u != v {
                assert_eq!(ub.batches.load(Ordering::Relaxed), 0, "variant {u} bucket");
                assert_eq!(ub.energy_aj.load(Ordering::Relaxed), 0, "variant {u} bucket");
            }
        }
    }
}

#[test]
fn cheaper_variants_cost_less_energy_per_row_on_the_same_traffic() {
    // The reason the governor exists: for the same request stream the
    // turbo variant must bill strictly less Stage-1 energy per row
    // than hi-fi (more sub-words per 48-bit word → fewer words → fewer
    // cycles), using the real characterized cost relation only through
    // the flat table (1 pJ/cycle at every width) so the comparison is
    // purely about cycle counts.
    let mut rng = XorShift64::new(0x90E40003);
    let (_layers, model) = trio_model(&mut rng);
    let engine = PackedEngine::new(Arc::clone(&model));
    let rows: Vec<Vec<i64>> = (0..24)
        .map(|_| (0..64).map(|_| rng.q_raw(8)).collect())
        .collect();
    let mut s1_by_variant = vec![];
    for v in 0..model.n_variants() {
        let shifted: Vec<Vec<i64>> =
            rows.iter().map(|r| model.variant(v).quantize_row(r)).collect();
        let (_, stats) = engine.forward_batch_variant(&shifted, v);
        s1_by_variant.push(stats.s1_cycles);
    }
    assert!(
        s1_by_variant[2] < s1_by_variant[1] && s1_by_variant[1] < s1_by_variant[0],
        "turbo < balanced < hi-fi Stage-1 cycles, got {s1_by_variant:?}"
    );
}
