//! Dynamic batching: group inference requests into packed batches.
//!
//! Soft SIMD packs the batch dimension into sub-words, so the natural
//! batch quantum is a multiple of the model's per-layer lane counts
//! (`CompiledModel::batch_quantum`; 6 for the uniform 8→16 schedule) —
//! the engine pads the remainder with zero rows (DESIGN.md §8). The batcher
//! accumulates requests until it can fill `target_rows` rows or a flush
//! is forced; starvation is prevented by the coordinator's deadline
//! thread, which drives [`Batcher::tick`] at a fixed period so
//! stragglers flush without an explicit `drain()` — the classic
//! latency/throughput dial of serving systems.

use std::time::Instant;

use super::server::Request;

/// A request stamped with its arrival time (for latency percentiles).
#[derive(Debug)]
pub struct TrackedRequest {
    pub req: Request,
    pub submitted_at: Instant,
}

impl TrackedRequest {
    pub fn now(req: Request) -> Self {
        TrackedRequest { req, submitted_at: Instant::now() }
    }
}

/// A formed batch: requests plus the row span each owns.
#[derive(Debug)]
pub struct Batch {
    pub entries: Vec<TrackedRequest>,
    pub rows: usize,
}

/// Row-count batcher.
#[derive(Debug)]
pub struct Batcher {
    pending: Vec<TrackedRequest>,
    pending_rows: usize,
    pub target_rows: usize,
    pub max_wait_polls: u32,
    idle_polls: u32,
}

impl Batcher {
    pub fn new(target_rows: usize, max_wait_polls: u32) -> Self {
        Batcher {
            pending: vec![],
            pending_rows: 0,
            target_rows: target_rows.max(1),
            max_wait_polls: max_wait_polls.max(1),
            idle_polls: 0,
        }
    }

    pub fn pending_rows(&self) -> usize {
        self.pending_rows
    }

    /// Offer a request; returns a formed batch when the target fills.
    pub fn push(&mut self, tr: TrackedRequest) -> Option<Batch> {
        self.pending_rows += tr.req.rows.len();
        self.pending.push(tr);
        self.idle_polls = 0;
        if self.pending_rows >= self.target_rows {
            return self.flush();
        }
        None
    }

    /// Put a formed batch back (dispatch failed); it will flush again on
    /// the next tick or drain rather than being dropped.
    pub fn restore(&mut self, batch: Batch) {
        self.pending_rows += batch.rows;
        let mut entries = batch.entries;
        entries.append(&mut self.pending);
        self.pending = entries;
    }

    /// Poll tick with no arrivals; flushes after `max_wait_polls` idle
    /// ticks so stragglers are not starved.
    pub fn tick(&mut self) -> Option<Batch> {
        if self.pending.is_empty() {
            return None;
        }
        self.idle_polls += 1;
        if self.idle_polls >= self.max_wait_polls {
            self.flush()
        } else {
            None
        }
    }

    /// Force out whatever is queued.
    pub fn flush(&mut self) -> Option<Batch> {
        if self.pending.is_empty() {
            return None;
        }
        self.idle_polls = 0;
        let entries = std::mem::take(&mut self.pending);
        let rows = std::mem::take(&mut self.pending_rows);
        Some(Batch { entries, rows })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, rows: usize) -> TrackedRequest {
        TrackedRequest::now(Request { id, rows: vec![vec![0i64; 4]; rows] })
    }

    #[test]
    fn fills_to_target() {
        let mut b = Batcher::new(6, 4);
        assert!(b.push(req(1, 2)).is_none());
        assert!(b.push(req(2, 2)).is_none());
        let batch = b.push(req(3, 2)).expect("target reached");
        assert_eq!(batch.rows, 6);
        assert_eq!(batch.entries.len(), 3);
        assert_eq!(b.pending_rows(), 0);
    }

    #[test]
    fn deadline_flush_prevents_starvation() {
        let mut b = Batcher::new(6, 3);
        assert!(b.push(req(1, 1)).is_none());
        assert!(b.tick().is_none());
        assert!(b.tick().is_none());
        let batch = b.tick().expect("deadline flush");
        assert_eq!(batch.rows, 1);
    }

    #[test]
    fn oversized_request_flushes_immediately() {
        let mut b = Batcher::new(4, 3);
        let batch = b.push(req(1, 9)).expect("flush");
        assert_eq!(batch.rows, 9);
    }

    #[test]
    fn empty_tick_is_noop() {
        let mut b = Batcher::new(4, 1);
        assert!(b.tick().is_none());
        assert!(b.flush().is_none());
    }

    #[test]
    fn restore_requeues_without_loss() {
        let mut b = Batcher::new(4, 2);
        let batch = b.push(req(1, 5)).expect("flush");
        assert!(b.push(req(2, 1)).is_none());
        b.restore(batch);
        assert_eq!(b.pending_rows(), 6);
        let again = b.flush().expect("restored rows flush");
        assert_eq!(again.rows, 6);
        assert_eq!(again.entries[0].req.id, 1, "restored batch goes first");
    }
}
