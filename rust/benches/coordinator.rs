//! Coordinator serving benchmarks: packed-engine layer throughput and
//! the full submit→batch→PE→drain loop.

#[path = "benchkit.rs"]
mod benchkit;
use benchkit::{bench, throughput};

use softsimd::coordinator::cost::CostTable;
use softsimd::coordinator::engine::PackedMlpEngine;
use softsimd::coordinator::server::{Coordinator, Request};
use softsimd::nn::weights::QuantLayer;
use softsimd::workload::synth::XorShift64;

fn model(rng: &mut XorShift64) -> Vec<QuantLayer> {
    let mk = |k: usize, n: usize, rng: &mut XorShift64| {
        QuantLayer::new(
            (0..k).map(|_| (0..n).map(|_| rng.q_raw(8)).collect()).collect(),
            8,
        )
    };
    vec![mk(64, 32, rng), mk(32, 16, rng)]
}

fn main() {
    println!("== coordinator: packed NN serving ==");
    let mut rng = XorShift64::new(0xC0BE);
    let layers = model(&mut rng);
    let mults_per_row: u64 = layers.iter().map(|l| (l.k * l.n) as u64).sum();

    // Engine-only: packed forward of a 12-row batch.
    let engine = PackedMlpEngine::new(layers.clone(), 8, 16);
    let batch: Vec<Vec<i64>> = (0..12)
        .map(|_| (0..64).map(|_| rng.q_raw(8)).collect())
        .collect();
    let r = bench("PackedMlpEngine forward (12-row batch)", 60, || {
        std::hint::black_box(engine.forward_batch(&batch));
    });
    throughput(&r, (12 * mults_per_row) as f64, "subword-mults");

    // Full coordinator loop, 2 PEs.
    let cost = CostTable {
        mhz: 1000.0,
        s1_cycle_pj: softsimd::bits::format::FORMATS.iter().map(|&b| (b, 1.0)).collect(),
        s2_pass_pj: 0.5,
        area_um2: 4600.0,
    };
    let rows: Vec<Vec<i64>> = (0..96)
        .map(|_| (0..64).map(|_| rng.q_raw(8)).collect())
        .collect();
    let r = bench("coordinator submit+drain (96 requests, 2 PEs)", 120, || {
        let mut coord = Coordinator::start(layers.clone(), 8, 16, 2, 12, cost.clone());
        for (id, row) in rows.iter().enumerate() {
            coord.submit(Request { id: id as u64, rows: vec![row.clone()] });
        }
        std::hint::black_box(coord.drain());
        coord.shutdown();
    });
    throughput(&r, (96 * mults_per_row) as f64, "subword-mults");
}
