//! Fig. 6 — area of the Soft SIMD and Hard SIMD pipelines at 200 MHz
//! and 1 GHz timing constraints, with the stage-level split the paper
//! discusses (Stage-2 ~flat across frequency; Stage-1/registers grow).

use crate::anyhow;
use crate::energy::model::{PipelineArea, SynthesizedSoftPipeline};
use crate::energy::report::{table, um2};
use crate::hardsimd::pipeline::{HardSimdPipeline, HARD_FLEX, HARD_TWO};

pub fn areas() -> Vec<PipelineArea> {
    let mut rows = vec![];
    for &mhz in &[200.0, 1000.0] {
        rows.push(SynthesizedSoftPipeline::new(mhz).area());
        rows.push(HardSimdPipeline::new(HARD_FLEX, mhz).area());
        rows.push(HardSimdPipeline::new(HARD_TWO, mhz).area());
    }
    rows
}

pub fn run() -> anyhow::Result<()> {
    println!("== Fig. 6: pipeline area vs timing constraint (µm², 28nm model) ==");
    let rows = areas();
    let trows: Vec<Vec<String>> = rows
        .iter()
        .map(|a| {
            vec![
                a.name.clone(),
                format!("{} MHz", a.mhz),
                um2(a.stage1_um2),
                um2(a.stage2_um2),
                um2(a.regs_um2),
                um2(a.total()),
            ]
        })
        .collect();
    println!(
        "{}",
        table(
            &["design", "constraint", "stage1/mult", "stage2(pack)", "registers", "total"],
            &trows
        )
    );
    // The paper's observations, checked numerically:
    let soft200 = &rows[0];
    let flex200 = &rows[1];
    let soft1000 = &rows[3];
    let flex1000 = &rows[4];
    let two1000 = &rows[5];
    println!(
        "soft vs Hard(4,6,8,12,16): {:.1}% smaller @200MHz, {:.1}% smaller @1GHz",
        (1.0 - soft200.total() / flex200.total()) * 100.0,
        (1.0 - soft1000.total() / flex1000.total()) * 100.0,
    );
    println!(
        "Hard(8,16) vs soft: {:.1}% larger @1GHz (paper: >10% in all cases)",
        (two1000.total() / soft1000.total() - 1.0) * 100.0
    );
    println!(
        "stage2 growth 200MHz→1GHz: {:.1}% (paper: ~constant) | stage1: {:.1}%\n",
        (soft1000.stage2_um2 / soft200.stage2_um2 - 1.0) * 100.0,
        (soft1000.stage1_um2 / soft200.stage1_um2 - 1.0) * 100.0,
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_paper_shape_holds() {
        let rows = areas();
        // Row order: [soft, flex, two] × [200, 1000].
        for chunk in rows.chunks(3) {
            let (soft, flex, two) = (&chunk[0], &chunk[1], &chunk[2]);
            assert!(
                soft.total() < 0.5 * flex.total(),
                "soft must be <half of flexible hard @{} MHz",
                soft.mhz
            );
            assert!(
                two.total() > 1.1 * soft.total(),
                "Hard(8,16) must be >10% larger than soft @{} MHz",
                soft.mhz
            );
            assert!(flex.total() > two.total(), "flex must exceed two-format");
        }
        // Stage 2 flat, stage 1 grows.
        let (s200, s1000) = (&rows[0], &rows[3]);
        assert!((s1000.stage2_um2 / s200.stage2_um2 - 1.0).abs() < 0.05);
        assert!(s1000.stage1_um2 > 1.05 * s200.stage1_um2);
    }
}
