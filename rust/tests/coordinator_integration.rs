//! Coordinator integration: packed serving vs the scalar reference and
//! the AOT model, failure-injection on batching edges, and metrics
//! consistency.

use std::sync::atomic::Ordering;

use softsimd::coordinator::cost::CostTable;
use softsimd::coordinator::engine::PackedMlpEngine;
use softsimd::coordinator::server::{Coordinator, Request};
use softsimd::nn::exec::{mlp_forward_row, precompute_plans, mlp_forward_row_planned};
use softsimd::nn::weights::QuantLayer;
use softsimd::workload::synth::{Digits, XorShift64};

fn cost() -> CostTable {
    CostTable {
        mhz: 1000.0,
        s1_cycle_pj: softsimd::bits::format::FORMATS.iter().map(|&b| (b, 1.0)).collect(),
        s2_pass_pj: 0.5,
        area_um2: 4600.0,
    }
}

fn random_model(rng: &mut XorShift64, dims: &[usize]) -> Vec<QuantLayer> {
    dims.windows(2)
        .map(|w| {
            QuantLayer::new(
                (0..w[0])
                    .map(|_| (0..w[1]).map(|_| rng.q_raw(8)).collect())
                    .collect(),
                8,
            )
        })
        .collect()
}

#[test]
fn coordinator_bit_exact_across_pe_counts_and_batch_targets() {
    let mut rng = XorShift64::new(0xC001);
    let layers = random_model(&mut rng, &[12, 8, 4]);
    let reqs: Vec<Request> = (0..20u64)
        .map(|id| Request {
            id,
            rows: (0..1 + (id as usize % 4))
                .map(|_| (0..12).map(|_| rng.q_raw(8)).collect())
                .collect(),
        })
        .collect();
    let expected: Vec<Vec<Vec<i64>>> = reqs
        .iter()
        .map(|r| r.rows.iter().map(|row| mlp_forward_row(row, &layers, 8, 16)).collect())
        .collect();
    for n_pes in [1usize, 2, 4] {
        for target in [1usize, 6, 13, 64] {
            let mut coord =
                Coordinator::start(layers.clone(), 8, 16, n_pes, target, cost());
            for r in &reqs {
                coord.submit(r.clone());
            }
            let responses = coord.drain();
            assert_eq!(responses.len(), reqs.len(), "pes={n_pes} target={target}");
            for resp in &responses {
                assert_eq!(
                    resp.logits, expected[resp.id as usize],
                    "pes={n_pes} target={target} req={}",
                    resp.id
                );
            }
            coord.shutdown();
        }
    }
}

#[test]
fn engine_handles_singleton_and_ragged_batches() {
    let mut rng = XorShift64::new(0xC002);
    let layers = random_model(&mut rng, &[7, 5, 3]);
    let engine = PackedMlpEngine::new(layers.clone(), 8, 16);
    for m in 1..=13usize {
        let batch: Vec<Vec<i64>> = (0..m)
            .map(|_| (0..7).map(|_| rng.q_raw(8)).collect())
            .collect();
        let (got, _) = engine.forward_batch(&batch);
        for (b, row) in batch.iter().enumerate() {
            assert_eq!(got[b], mlp_forward_row(row, &layers, 8, 16), "m={m} b={b}");
        }
    }
}

#[test]
fn planned_and_unplanned_reference_agree_on_aot_model() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/mlp_weights.txt");
    if !path.exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let layers = softsimd::nn::weights::load_weight_file(&path).unwrap();
    let plans = precompute_plans(&layers);
    let digits = Digits::standard();
    let (xs, _) = digits.sample(8, 0.3, 0xABCD);
    for row in &xs {
        assert_eq!(
            mlp_forward_row(row, &layers, 8, 16),
            mlp_forward_row_planned(row, &layers, &plans, 8, 16)
        );
    }
}

#[test]
fn metrics_account_every_row_and_mult() {
    let mut rng = XorShift64::new(0xC003);
    let layers = random_model(&mut rng, &[6, 4]);
    let mut coord = Coordinator::start(layers.clone(), 8, 16, 2, 5, cost());
    let n_rows = 17u64;
    for id in 0..n_rows {
        coord.submit(Request {
            id,
            rows: vec![(0..6).map(|_| rng.q_raw(8)).collect()],
        });
    }
    let _ = coord.drain();
    assert_eq!(coord.metrics.rows.load(Ordering::Relaxed), n_rows);
    assert_eq!(coord.metrics.requests.load(Ordering::Relaxed), n_rows);
    // Energy must be positive and cycles consistent with plan lengths.
    assert!(coord.metrics.energy_fj.load(Ordering::Relaxed) > 0);
    assert!(coord.metrics.s1_cycles.load(Ordering::Relaxed) > 0);
    coord.shutdown();
}

#[test]
fn empty_drain_is_safe() {
    let mut rng = XorShift64::new(0xC004);
    let layers = random_model(&mut rng, &[4, 2]);
    let mut coord = Coordinator::start(layers, 8, 16, 1, 4, cost());
    assert!(coord.drain().is_empty());
    coord.shutdown();
}

#[test]
fn coordinator_matches_aot_golden_when_artifacts_exist() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let layers = softsimd::nn::weights::load_weight_file(dir.join("mlp_weights.txt")).unwrap();
    // Parse the golden mlp rows.
    let text = std::fs::read_to_string(dir.join("golden.txt")).unwrap();
    let mut inputs: Vec<(usize, Vec<i64>)> = vec![];
    let mut outputs: Vec<(usize, Vec<i64>)> = vec![];
    for line in text.lines() {
        let mut it = line.split_whitespace();
        match it.next() {
            Some("mlp_in") => {
                let row: usize = it.next().unwrap().parse().unwrap();
                inputs.push((
                    row,
                    it.next().unwrap().split(',').map(|v| v.parse().unwrap()).collect(),
                ));
            }
            Some("mlp_out") => {
                let row: usize = it.next().unwrap().parse().unwrap();
                outputs.push((
                    row,
                    it.next().unwrap().split(',').map(|v| v.parse().unwrap()).collect(),
                ));
            }
            _ => {}
        }
    }
    let mut coord = Coordinator::start(layers, 8, 16, 2, 8, cost());
    for (row, vals) in &inputs {
        coord.submit(Request { id: *row as u64, rows: vec![vals.clone()] });
    }
    for resp in coord.drain() {
        let want = &outputs.iter().find(|(r, _)| *r == resp.id as usize).unwrap().1;
        assert_eq!(&resp.logits[0], want, "row {}", resp.id);
    }
    coord.shutdown();
}
