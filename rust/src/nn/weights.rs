//! Quantized weight storage, per-layer serving precision, and the
//! `mlp_weights.txt` loader.

use std::path::Path;

use crate::anyhow;

use crate::bits::format::{SimdFormat, FORMATS};
use crate::csd::schedule::{schedule, MulPlan};

/// One layer's serving precision: the Soft SIMD format its input
/// activations are packed at and the format its accumulators are
/// produced at. A model's *precision schedule* is one of these per
/// layer; between layers the Stage-2 crossbar repacks the activation
/// stream from the producing layer's `acc_bits` into the consuming
/// layer's `in_bits` (DESIGN.md §10).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerPrecision {
    /// Activation sub-word width the layer's inputs arrive packed at.
    pub in_bits: u32,
    /// Accumulator sub-word width the layer's outputs leave at.
    pub acc_bits: u32,
}

impl LayerPrecision {
    pub fn new(in_bits: u32, acc_bits: u32) -> LayerPrecision {
        LayerPrecision { in_bits, acc_bits }
    }

    /// Check the pair against the hardware: both widths must be
    /// supported Soft SIMD formats and the accumulator must not be
    /// narrower than the activations (products are widened
    /// `<< (acc−in)` into it).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            FORMATS.contains(&self.in_bits),
            "activation width {} is not a Soft SIMD format (supported: {FORMATS:?})",
            self.in_bits
        );
        anyhow::ensure!(
            FORMATS.contains(&self.acc_bits),
            "accumulator width {} is not a Soft SIMD format (supported: {FORMATS:?})",
            self.acc_bits
        );
        anyhow::ensure!(
            self.acc_bits >= self.in_bits,
            "accumulator width {} narrower than activation width {}",
            self.acc_bits,
            self.in_bits
        );
        Ok(())
    }

    pub fn in_fmt(&self) -> SimdFormat {
        SimdFormat::new(self.in_bits)
    }

    pub fn acc_fmt(&self) -> SimdFormat {
        SimdFormat::new(self.acc_bits)
    }
}

impl std::fmt::Display for LayerPrecision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}->{}", self.in_bits, self.acc_bits)
    }
}

/// The single-format schedule every layer of the seed engine ran at
/// (`in_bits` activations, `acc_bits` accumulators, all layers).
pub fn uniform_schedule(in_bits: u32, acc_bits: u32, n_layers: usize) -> Vec<LayerPrecision> {
    vec![LayerPrecision::new(in_bits, acc_bits); n_layers]
}

/// One layer's quantized weights (`Q1.(bits-1)` raws) with cached CSD
/// multiply plans (one per distinct weight value — plans are shared).
#[derive(Debug, Clone)]
pub struct QuantLayer {
    /// `[k][n]` raw weights.
    pub w_raw: Vec<Vec<i64>>,
    pub k: usize,
    pub n: usize,
    /// Weight bitwidth.
    pub bits: u32,
}

impl QuantLayer {
    pub fn new(w_raw: Vec<Vec<i64>>, bits: u32) -> Self {
        let k = w_raw.len();
        let n = if k > 0 { w_raw[0].len() } else { 0 };
        for row in &w_raw {
            assert_eq!(row.len(), n, "ragged weight matrix");
        }
        QuantLayer { w_raw, k, n, bits }
    }

    /// Build the layer from float weights.
    pub fn quantize(w: &[Vec<f64>], bits: u32) -> Self {
        let raw = w
            .iter()
            .map(|row| row.iter().map(|&v| crate::bits::fixed::to_q(v, bits)).collect())
            .collect();
        QuantLayer::new(raw, bits)
    }

    /// The multiply plan for weight `(i, j)`.
    pub fn plan(&self, i: usize, j: usize) -> MulPlan {
        schedule(self.w_raw[i][j], self.bits)
    }

    /// Every weight's multiply plan, `[k][n]` — the one enumeration the
    /// model compiler and the scalar planned path both build from.
    pub fn plans(&self) -> Vec<Vec<MulPlan>> {
        (0..self.k)
            .map(|i| (0..self.n).map(|j| self.plan(i, j)).collect())
            .collect()
    }

    /// Mean Stage-1 cycles per weight (workload statistics for the
    /// energy model).
    pub fn mean_cycles(&self) -> f64 {
        let mut total = 0usize;
        for row in &self.w_raw {
            for &w in row {
                total += schedule(w, self.bits).cycles();
            }
        }
        total as f64 / (self.k * self.n) as f64
    }
}

/// Quantize a float MLP with one weight width per layer (the
/// mixed-precision companion of [`QuantLayer::quantize`]). Widths must
/// be Soft SIMD formats; layer output/input widths must chain.
pub fn quantize_stack(w: &[Vec<Vec<f64>>], bits: &[u32]) -> anyhow::Result<Vec<QuantLayer>> {
    anyhow::ensure!(!w.is_empty(), "model needs at least one layer");
    anyhow::ensure!(
        w.len() == bits.len(),
        "{} float layers but {} weight widths",
        w.len(),
        bits.len()
    );
    let mut layers = Vec::with_capacity(w.len());
    for (li, (wl, &b)) in w.iter().zip(bits).enumerate() {
        anyhow::ensure!(
            FORMATS.contains(&b),
            "layer {li}: weight width {b} is not a Soft SIMD format"
        );
        let layer = QuantLayer::quantize(wl, b);
        if let Some(prev) = layers.last() {
            anyhow::ensure!(
                prev.n == layer.k,
                "layer {li}: input width {} != previous layer's output width {}",
                layer.k,
                prev.n
            );
        }
        layers.push(layer);
    }
    Ok(layers)
}

/// Parse `artifacts/mlp_weights.txt`:
/// `layer <idx> <K> <N>` followed by `K` comma-separated rows.
pub fn load_weight_file(path: impl AsRef<Path>) -> anyhow::Result<Vec<QuantLayer>> {
    let text = std::fs::read_to_string(path.as_ref())?;
    let mut layers = vec![];
    let mut lines = text.lines().peekable();
    while let Some(header) = lines.next() {
        let header = header.trim();
        if header.is_empty() {
            continue;
        }
        let parts: Vec<&str> = header.split_whitespace().collect();
        anyhow::ensure!(
            parts.len() == 4 && parts[0] == "layer",
            "bad layer header: {header}"
        );
        let k: usize = parts[2].parse()?;
        let n: usize = parts[3].parse()?;
        let mut rows = Vec::with_capacity(k);
        for _ in 0..k {
            let row_line = lines
                .next()
                .ok_or_else(|| anyhow::anyhow!("truncated weight file"))?;
            let row: Vec<i64> = row_line
                .trim()
                .split(',')
                .map(|v| v.parse::<i64>())
                .collect::<Result<_, _>>()?;
            anyhow::ensure!(row.len() == n, "row width {} != {n}", row.len());
            rows.push(row);
        }
        layers.push(QuantLayer::new(rows, 8));
    }
    Ok(layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_weight_text() {
        let text = "layer 0 2 3\n1,-2,3\n-4,5,-6\nlayer 1 1 2\n7,-8\n";
        let tmp = std::env::temp_dir().join("softsimd_wtest.txt");
        std::fs::write(&tmp, text).unwrap();
        let layers = load_weight_file(&tmp).unwrap();
        assert_eq!(layers.len(), 2);
        assert_eq!(layers[0].k, 2);
        assert_eq!(layers[0].n, 3);
        assert_eq!(layers[0].w_raw[1], vec![-4, 5, -6]);
        assert_eq!(layers[1].w_raw[0], vec![7, -8]);
    }

    #[test]
    fn quantize_roundtrip() {
        let l = QuantLayer::quantize(&[vec![0.5, -0.25], vec![0.0, 0.99]], 8);
        assert_eq!(l.w_raw, vec![vec![64, -32], vec![0, 127]]);
    }

    #[test]
    fn layer_precision_validation() {
        assert!(LayerPrecision::new(8, 16).validate().is_ok());
        assert!(LayerPrecision::new(4, 4).validate().is_ok());
        // Unsupported widths and inverted pairs are rejected.
        assert!(LayerPrecision::new(5, 16).validate().is_err());
        assert!(LayerPrecision::new(8, 10).validate().is_err());
        assert!(LayerPrecision::new(16, 8).validate().is_err());
        let sched = uniform_schedule(8, 16, 3);
        assert_eq!(sched.len(), 3);
        assert!(sched.iter().all(|p| *p == LayerPrecision::new(8, 16)));
    }

    #[test]
    fn quantize_stack_checks_widths_and_chaining() {
        let w = vec![
            vec![vec![0.5, -0.25], vec![0.0, 0.99]],
            vec![vec![0.5], vec![-0.5]],
        ];
        let layers = quantize_stack(&w, &[8, 4]).unwrap();
        assert_eq!(layers[0].bits, 8);
        assert_eq!(layers[1].bits, 4);
        assert_eq!(layers[1].w_raw, vec![vec![4], vec![-4]]);
        assert!(quantize_stack(&w, &[8]).is_err(), "width-count mismatch");
        assert!(quantize_stack(&w, &[8, 5]).is_err(), "bad format");
        assert!(quantize_stack(&[], &[]).is_err(), "empty stack");
        let ragged = vec![w[0].clone(), vec![vec![0.5]]]; // 2-wide into 1-in
        assert!(quantize_stack(&ragged, &[8, 8]).is_err(), "non-chaining dims");
    }

    #[test]
    fn mean_cycles_sane() {
        let l = QuantLayer::quantize(&[vec![0.5, -0.5, 0.0, 0.93]], 8);
        let mc = l.mean_cycles();
        assert!(mc > 0.0 && mc < 8.0, "{mc}");
    }
}
