//! Micro-op definitions.

use crate::bits::format::SimdFormat;


/// Architectural registers of the pipeline (Fig. 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reg {
    /// Multiplicand operand register feeding Stage 1.
    X,
    /// Stage-1 accumulator.
    Acc,
    /// Stage-2 input pair (96-bit window R2:R3).
    R2,
    R3,
    /// Stage-2 output register.
    R4,
}

/// One micro-instruction. The controller issues one per cycle to each
/// stage; `Stage1*` and `Stage2*` ops of independent programs can be
/// co-issued by the pipeline model (the two stages are pipelined).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Instr {
    /// Set the Stage-1 Soft SIMD format (reprograms the V_x vector).
    SetFmt(SimdFormat),
    /// Load an immediate packed word into a register.
    Load(Reg, u64),
    /// Clear the accumulator.
    ClearAcc,
    /// Stage-1 cycle: `Acc ← sar(Acc, k)` then `Acc ← Acc ± X`.
    AddShift { k: u32, sign: i8 },
    /// Stage-1 cycle: `Acc ← sar(Acc, k)` only.
    Shift { k: u32 },
    /// Register move (e.g. Acc → R2 to hand a result to Stage 2).
    Mov(Reg, Reg),
    /// Stage-2 cycle: produce output word `out_idx` of the direct
    /// conversion `from → to`, reading sub-words from the R2:R3 window;
    /// `in_skip` sub-words of the window are consumed by earlier output
    /// words of the same conversion.
    Pack {
        from: SimdFormat,
        to: SimdFormat,
        in_skip: u32,
    },
    /// Stage-2 cycle: R4 ← R2 unchanged (format bypass, Section III-A).
    Bypass,
    /// Emit R4 to the output stream (write-back to memory in the real
    /// design).
    Store,
    /// End of program.
    Halt,
}

impl Instr {
    /// Does this op occupy Stage 1 for a cycle?
    pub fn uses_stage1(self) -> bool {
        matches!(self, Instr::AddShift { .. } | Instr::Shift { .. })
    }

    /// Does this op occupy Stage 2 for a cycle?
    pub fn uses_stage2(self) -> bool {
        matches!(self, Instr::Pack { .. } | Instr::Bypass)
    }

    /// Human-readable disassembly.
    pub fn disasm(self) -> String {
        match self {
            Instr::SetFmt(f) => format!("setfmt   {f}"),
            Instr::Load(r, w) => format!("load     {r:?}, {w:#014x}"),
            Instr::ClearAcc => "clracc".to_string(),
            Instr::AddShift { k, sign } => {
                format!("sar{k}{}x", if sign > 0 { "+" } else { "-" })
            }
            Instr::Shift { k } => format!("sar{k}"),
            Instr::Mov(d, s) => format!("mov      {d:?}, {s:?}"),
            Instr::Pack { from, to, in_skip } => {
                format!("pack     {from} -> {to} (skip {in_skip})")
            }
            Instr::Bypass => "bypass".to_string(),
            Instr::Store => "store".to_string(),
            Instr::Halt => "halt".to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_occupancy() {
        assert!(Instr::AddShift { k: 2, sign: 1 }.uses_stage1());
        assert!(Instr::Shift { k: 3 }.uses_stage1());
        assert!(!Instr::Shift { k: 3 }.uses_stage2());
        let f = SimdFormat::new(8);
        let t = SimdFormat::new(16);
        assert!(Instr::Pack { from: f, to: t, in_skip: 0 }.uses_stage2());
        assert!(Instr::Bypass.uses_stage2());
        assert!(!Instr::Bypass.uses_stage1());
    }

    #[test]
    fn disasm_is_stable() {
        assert_eq!(Instr::AddShift { k: 3, sign: -1 }.disasm(), "sar3-x");
        assert_eq!(Instr::Shift { k: 1 }.disasm(), "sar1");
    }
}
