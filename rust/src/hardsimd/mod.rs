//! The Hard SIMD baseline pipelines of Section IV-A: combinational SIMD
//! multiplier datapaths supporting fixed sub-word sets — one with
//! {4, 6, 8, 12, 16} and one with {8, 16}.

pub mod pipeline;

pub use pipeline::{HardSimdPipeline, HARD_FLEX, HARD_TWO};
