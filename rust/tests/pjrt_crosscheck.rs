//! Integration: the AOT HLO artifacts, executed through PJRT, must agree
//! bit-exactly with the Rust architecture model — closing the loop
//! Pallas kernel → HLO text → PJRT execution → Rust simulator.
//!
//! Skips (with a message) when `artifacts/` has not been built yet.

use softsimd::bits::format::SimdFormat;
use softsimd::bits::pack::{pack_stream, unpack_stream};
use softsimd::nn::exec::mlp_forward_row;
use softsimd::nn::weights::load_weight_file;
use softsimd::pipeline::stage1::mul_packed;
use softsimd::runtime::Engine;
use softsimd::workload::synth::{Digits, XorShift64};

fn engine() -> Option<Engine> {
    let dir = Engine::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::load(dir).expect("artifact load"))
}

#[test]
fn mul_artifact_matches_simulator_all_formats() {
    let Some(eng) = engine() else { return };
    let mut rng = XorShift64::new(0x7E57_0001);
    for fmt in SimdFormat::all() {
        for y_bits in [4u32, 8, fmt.bits] {
            let m = rng.q_raw(y_bits);
            let words: Vec<u64> = (0..eng.manifest.mul_words).map(|_| rng.word()).collect();
            let got = eng
                .mul_packed(&words, m, y_bits, fmt)
                .expect("artifact exec");
            let want: Vec<u64> = words
                .iter()
                .map(|&w| mul_packed(w, m, y_bits, fmt))
                .collect();
            assert_eq!(got, want, "fmt {fmt} y {y_bits} m {m}");
        }
    }
}

#[test]
fn mul_artifact_edge_multipliers() {
    let Some(eng) = engine() else { return };
    let fmt = SimdFormat::new(8);
    let mut rng = XorShift64::new(0x7E57_0002);
    let words: Vec<u64> = (0..eng.manifest.mul_words).map(|_| rng.word()).collect();
    for m in [-128i64, -127, -1, 0, 1, 64, 127] {
        let got = eng.mul_packed(&words, m, 8, fmt).unwrap();
        let want: Vec<u64> = words.iter().map(|&w| mul_packed(w, m, 8, fmt)).collect();
        assert_eq!(got, want, "m={m}");
    }
}

#[test]
fn mul_artifact_lane_isolation() {
    // A word with one hot lane: products must stay confined to that lane.
    let Some(eng) = engine() else { return };
    let fmt = SimdFormat::new(12);
    let mut words = vec![0u64; eng.manifest.mul_words];
    let vals: Vec<i64> = vec![0, -2048, 0, 0];
    words[0] = pack_stream(&vals, fmt)[0];
    let got = eng.mul_packed(&words, 1365, 12, fmt).unwrap();
    let lanes = unpack_stream(&got[..1], fmt, 4);
    assert_eq!(lanes[0], 0);
    assert_eq!(lanes[2], 0);
    assert_eq!(lanes[3], 0);
    assert_ne!(lanes[1], 0);
    assert!(got[1..].iter().all(|&w| w == 0));
}

#[test]
fn mlp_artifact_matches_rust_reference_and_golden() {
    let Some(eng) = engine() else { return };
    let layers = load_weight_file(eng.dir.join("mlp_weights.txt")).expect("weights");

    // The exact batch the golden file pins.
    let digits = Digits::standard();
    let (xs, _ys) = digits.sample(eng.manifest.mlp_batch, 0.3, 0xBA7C4);
    let flat: Vec<i32> = xs.iter().flatten().map(|&v| v as i32).collect();
    let logits = eng.mlp_forward(&flat).expect("mlp exec");

    for (b, row) in xs.iter().enumerate() {
        let want = mlp_forward_row(row, &layers, eng.manifest.in_bits, eng.manifest.acc_bits);
        let got: Vec<i64> = logits
            [b * eng.manifest.mlp_out..(b + 1) * eng.manifest.mlp_out]
            .iter()
            .map(|&v| v as i64)
            .collect();
        assert_eq!(got, want, "batch row {b}");
    }
}

#[test]
fn mlp_artifact_classifies_above_chance() {
    let Some(eng) = engine() else { return };
    let digits = Digits::standard();
    let (xs, ys) = digits.sample(eng.manifest.mlp_batch, 0.3, 0xBA7C4);
    let flat: Vec<i32> = xs.iter().flatten().map(|&v| v as i32).collect();
    let logits = eng.mlp_forward(&flat).unwrap();
    let mut correct = 0;
    for b in 0..eng.manifest.mlp_batch {
        let row: Vec<i64> = logits[b * eng.manifest.mlp_out..(b + 1) * eng.manifest.mlp_out]
            .iter()
            .map(|&v| v as i64)
            .collect();
        if softsimd::nn::exec::argmax_class(&row, eng.manifest.mlp_classes) == ys[b] {
            correct += 1;
        }
    }
    let acc = correct as f64 / eng.manifest.mlp_batch as f64;
    assert!(acc >= 0.5, "PJRT MLP accuracy {acc} (chance 0.1)");
}

#[test]
fn golden_file_validates_end_to_end() {
    let dir = Engine::default_dir();
    let golden = dir.join("golden.txt");
    if !golden.exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    // The mlp rows in check_file need the weights path relative to cwd;
    // run from the crate root.
    std::env::set_current_dir(env!("CARGO_MANIFEST_DIR")).unwrap();
    let rep = softsimd::runtime::golden::check_file(&golden).expect("golden parse");
    assert!(rep.ok(), "{rep}");
    assert!(rep.swar > 500 && rep.mul > 100 && rep.repack >= 20 && rep.mlp_rows >= 16);
}
