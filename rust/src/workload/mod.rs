//! Synthetic workload generation (the paper's ML-at-the-edge context):
//! deterministic PRNG, labeled digit/image datasets, the synthetic CNN
//! classification scenario of the conv serving path (DESIGN.md §12),
//! and the Fig. 10 bitwidth-mix scenarios.

pub mod synth;

pub use synth::{synth_cnn_stack, Digits, ImageSet, LayerSpec, Scenario, XorShift64};
