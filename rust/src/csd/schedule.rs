//! Digit → cycle scheduling for the sequential Soft SIMD multiplier
//! (Section III-B, Fig. 3).
//!
//! Digits are processed least-significant first (descending position
//! `j`, weight `2^-j`). Each clock cycle retires one nonzero digit plus
//! up to `MAX_SHIFT − 1` zero positions above it as a fused
//! add-then-shift (`acc ← (acc ± X) >> k`, the "10"/"100" patterns of
//! Section III-B); zero runs longer than the shifter's reach become
//! pure-shift cycles. The digit at position 0 (weight `2^0`) is retired
//! with no trailing shift (`k = 0`).
//!
//! Zero-skipping: digit positions *below* the least-significant nonzero
//! digit would shift an all-zero accumulator, so the controller skips
//! them outright — they cost no cycles at all. A zero multiplier costs
//! zero cycles.

use super::encode::{csd_encode, Digit};
use crate::bits::format::MAX_SHIFT;

/// One Stage-1 cycle of a multiplication.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MulOp {
    /// `acc ← (acc + X·sign) >>_arith shift`. `shift = 0` only for the
    /// final position-0 digit (plain add, no shift).
    AddShift { shift: u32, sign: i8 },
    /// `acc ← acc >>_arith shift` (zero-run cycle), `shift ∈ 1..=MAX`.
    Shift { shift: u32 },
}

impl MulOp {
    pub fn shift(self) -> u32 {
        match self {
            MulOp::AddShift { shift, .. } | MulOp::Shift { shift } => shift,
        }
    }
    pub fn is_add(self) -> bool {
        matches!(self, MulOp::AddShift { .. })
    }
}

/// A complete cycle-schedule for one multiplier value.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MulPlan {
    /// Raw two's-complement multiplier the plan was derived from.
    pub m_raw: i64,
    /// Multiplier bitwidth (`Q1.(y_bits-1)`).
    pub y_bits: u32,
    /// Cycle operations, in issue order.
    pub ops: Vec<MulOp>,
}

impl MulPlan {
    /// Number of Stage-1 cycles the multiplication takes.
    pub fn cycles(&self) -> usize {
        self.ops.len()
    }

    /// Number of add/sub cycles (the rest are pure shifts).
    pub fn adds(&self) -> usize {
        self.ops.iter().filter(|o| o.is_add()).count()
    }

    /// Total shift distance — equals the position (weight `2^-j`) of the
    /// least-significant nonzero digit: every processed position below
    /// the top is crossed by exactly one shift unit.
    pub fn total_shift(&self) -> u32 {
        self.ops.iter().map(|o| o.shift()).sum()
    }
}

/// Lower a list of CSD nonzero digit positions into the fused
/// add-then-shift cycle sequence. `nz` must be ordered descending in
/// `j` (least-significant digit first — the order the sequential
/// multiplier retires them); any *suffix* of a valid CSD digit list is
/// itself a valid input, which is what truncated plans exploit.
fn ops_from_nz(nz: &[(u32, i8)], max_shift: u32) -> Vec<MulOp> {
    let mut ops = Vec::with_capacity(nz.len() + 2);
    for (idx, &(j, sign)) in nz.iter().enumerate() {
        if j == 0 {
            // Weight-2^0 digit: plain add, no trailing shift.
            ops.push(MulOp::AddShift { shift: 0, sign });
            continue;
        }
        // After this add the accumulator must move down j − t positions
        // before the next retired digit (or the final resting position 0).
        let t = nz.get(idx + 1).map(|&(tj, _)| tj).unwrap_or(0);
        let dist = j - t;
        let k = dist.min(max_shift);
        ops.push(MulOp::AddShift { shift: k, sign });
        let mut rem = dist - k;
        while rem > 0 {
            let s = rem.min(max_shift);
            ops.push(MulOp::Shift { shift: s });
            rem -= s;
        }
    }
    ops
}

/// The CSD nonzero digit positions of `m_raw`, descending in `j`
/// (least-significant first — schedule retirement order). Entry `(j,
/// sign)` has fractional weight `sign · 2^-j`, raw weight
/// `sign · 2^(y_bits-1-j)`.
fn nonzero_digits(m_raw: i64, y_bits: u32) -> Vec<(u32, i8)> {
    let digits = csd_encode(m_raw, y_bits); // MSB-first: digits[j] has weight 2^-j
    (0..y_bits)
        .rev()
        .filter_map(|j| match digits[j as usize] {
            Digit::Z => None,
            Digit::P => Some((j, 1i8)),
            Digit::N => Some((j, -1i8)),
        })
        .collect()
}

/// Build the cycle schedule for multiplier `m_raw` at width `y_bits`,
/// with per-cycle shifter reach `max_shift` (the paper's design point is
/// 3; the ablation harness sweeps it).
pub fn schedule_with(m_raw: i64, y_bits: u32, max_shift: u32) -> MulPlan {
    assert!(max_shift >= 1);
    let nz = nonzero_digits(m_raw, y_bits);
    let ops = ops_from_nz(&nz, max_shift);
    MulPlan { m_raw, y_bits, ops }
}

/// Build the cycle schedule at the paper's design point (`max_shift = 3`).
pub fn schedule(m_raw: i64, y_bits: u32) -> MulPlan {
    schedule_with(m_raw, y_bits, MAX_SHIFT)
}

/// A truncation policy for approximate CSD plans: which least-significant
/// nonzero digits of a multiplier's CSD string are *dropped* before the
/// cycle schedule is built. CSD digit lists are significance-sorted, so
/// dropping a least-significant prefix leaves a valid (non-adjacent)
/// signed-digit string — the truncated plan is the **exact** plan of the
/// kept value, strictly fewer cycles whenever anything drops, with a
/// per-multiplier error `|m − m_kept|` bounded analytically by
/// [`naf_max_below`].
///
/// Both knobs compose (drop-below first, then the digit-count cap):
/// `Truncation::NONE` keeps every digit and compiles bit-identical plans
/// to [`schedule_with`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord)]
pub struct Truncation {
    /// Drop nonzero digits whose **raw** weight is below `2^drop_below`
    /// (raw position `y_bits − 1 − j < drop_below`). 0 = keep all.
    pub drop_below: u32,
    /// Keep at most this many most-significant nonzero digits
    /// (`None` = no cap).
    pub max_digits: Option<u32>,
}

impl Truncation {
    /// Keep everything — the exact-plan policy.
    pub const NONE: Truncation = Truncation { drop_below: 0, max_digits: None };

    /// Does this policy drop nothing (exact plans)?
    pub fn is_none(&self) -> bool {
        *self == Truncation::NONE
    }

    /// Drop digits of raw weight below `2^t`.
    pub fn drop_least(t: u32) -> Truncation {
        Truncation { drop_below: t, max_digits: None }
    }

    /// Keep only the `d` most-significant nonzero digits.
    pub fn keep_digits(d: u32) -> Truncation {
        Truncation { drop_below: 0, max_digits: Some(d) }
    }
}

impl std::fmt::Display for Truncation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match (self.drop_below, self.max_digits) {
            (0, None) => write!(f, "exact"),
            (t, None) => write!(f, "t{t}"),
            (0, Some(d)) => write!(f, "d{d}"),
            (t, Some(d)) => write!(f, "t{t}d{d}"),
        }
    }
}

/// Build the **truncated** cycle schedule: CSD-encode `m_raw`, drop the
/// least-significant nonzero digits per `trunc`, and schedule the kept
/// suffix. The returned plan's `m_raw` is the **kept** raw value (the
/// plan computes `x · m_kept` exactly, never an inexact `x · m_raw`) —
/// the caller owns the original weight; `|m_raw − plan.m_raw|` is the
/// introduced error, bounded by [`naf_max_below`] of the first kept raw
/// position. The kept digits are never re-encoded: a truncated CSD
/// value can exceed the `Q1.(y_bits-1)` range (e.g. dropping `−2^0`
/// from `+2^7 − 2^0` leaves `+128`), which re-encoding would reject.
pub fn schedule_truncated_with(
    m_raw: i64,
    y_bits: u32,
    trunc: Truncation,
    max_shift: u32,
) -> MulPlan {
    assert!(max_shift >= 1);
    let nz = nonzero_digits(m_raw, y_bits);
    // Both knobs drop from the least-significant end, which is the
    // *front* of `nz` (largest j = lowest raw position y_bits-1-j).
    let mut start = nz
        .iter()
        .position(|&(j, _)| y_bits - 1 - j >= trunc.drop_below)
        .unwrap_or(nz.len());
    if let Some(d) = trunc.max_digits {
        let keep = (nz.len() - start).min(d as usize);
        start = nz.len() - keep;
    }
    let kept = &nz[start..];
    let m_kept: i64 = kept
        .iter()
        .map(|&(j, sign)| (sign as i64) << (y_bits - 1 - j))
        .sum();
    MulPlan { m_raw: m_kept, y_bits, ops: ops_from_nz(kept, max_shift) }
}

/// [`schedule_truncated_with`] at the paper's `max_shift = 3`.
pub fn schedule_truncated(m_raw: i64, y_bits: u32, trunc: Truncation) -> MulPlan {
    schedule_truncated_with(m_raw, y_bits, trunc, MAX_SHIFT)
}

/// Maximum absolute value of a non-adjacent signed-digit string confined
/// to raw positions `0..t` — the analytic bound on the raw-weight error
/// a [`Truncation`] with `drop_below = t` can introduce (the dropped
/// digits are a suffix of a CSD string, so they are themselves
/// non-adjacent). `B(0)=0, B(1)=1, B(2)=2, B(3)=5, B(4)=10, …` — the
/// greedy `2^(t-1) + 2^(t-3) + …` pattern, closed form
/// `(2^(t+1) − 2 + (t mod 2)) / 3`.
pub fn naf_max_below(t: u32) -> i64 {
    ((1i64 << (t + 1)) - 2 + (t as i64 & 1)) / 3
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Exact (unbounded-precision) replay of a plan: with the multiplicand
    /// scaled so shifts never truncate, the plan must compute exactly
    /// `x · m / 2^(y-1)`.
    fn exact_eval(plan: &MulPlan, x: i128) -> i128 {
        let mut acc: i128 = 0;
        for op in &plan.ops {
            match *op {
                MulOp::Shift { shift } => acc >>= shift,
                MulOp::AddShift { shift, sign } => {
                    acc += sign as i128 * x;
                    acc >>= shift;
                }
            }
        }
        acc
    }

    #[test]
    fn plans_compute_exact_products() {
        for y in [4u32, 6, 8] {
            let half = 1i64 << (y - 1);
            for m in -half..half {
                let plan = schedule(m, y);
                let x: i128 = 12345i128 << 32; // headroom: shifts stay exact
                assert_eq!(
                    exact_eval(&plan, x),
                    (x * m as i128) >> (y - 1),
                    "m={m} y={y}"
                );
            }
        }
    }

    #[test]
    fn total_shift_is_lowest_nonzero_position() {
        for y in [4u32, 8, 16] {
            let half = 1i64 << (y - 1);
            let mut m = -half;
            while m < half {
                let plan = schedule(m, y);
                if m == 0 {
                    assert_eq!(plan.cycles(), 0, "0 multiplier costs nothing");
                } else {
                    let digits = csd_encode(m, y);
                    let lowest_nz = (0..y)
                        .rev()
                        .find(|&j| !matches!(digits[j as usize], Digit::Z))
                        .unwrap();
                    assert_eq!(plan.total_shift(), lowest_nz, "m={m} y={y}");
                }
                m += if y == 16 { 37 } else { 1 };
            }
        }
    }

    #[test]
    fn shifts_bounded_and_zero_only_on_final_add() {
        for m in -128i64..128 {
            let plan = schedule(m, 8);
            for (i, op) in plan.ops.iter().enumerate() {
                match *op {
                    MulOp::Shift { shift } => assert!(shift >= 1 && shift <= 3),
                    MulOp::AddShift { shift, .. } => {
                        assert!(shift <= 3);
                        if shift == 0 {
                            assert_eq!(i, plan.ops.len() - 1, "k=0 only final, m={m}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn add_count_equals_nonzero_digits() {
        for m in -128i64..128 {
            let plan = schedule(m, 8);
            let digits = csd_encode(m, 8);
            let nz = digits.iter().filter(|d| !matches!(d, Digit::Z)).count();
            assert_eq!(plan.adds(), nz, "m={m}");
        }
    }

    #[test]
    fn paper_example_few_adds() {
        // Fig. 3's multiplier 0.1110011 (raw 115 @ Q1.7, "01110011 before
        // CSD"): plain binary needs 5 add cycles; CSD needs ≤4 and the
        // whole multiplication fits in ≤5 cycles thanks to coalescing.
        let plan = schedule(115, 8);
        assert!(plan.adds() <= 4, "adds = {}", plan.adds());
        assert!(plan.cycles() <= 5, "cycles = {}", plan.cycles());
    }

    #[test]
    fn cycles_monotone_in_max_shift() {
        for m in -128i64..128 {
            let c1 = schedule_with(m, 8, 1).cycles();
            let c2 = schedule_with(m, 8, 2).cycles();
            let c3 = schedule_with(m, 8, 3).cycles();
            let c4 = schedule_with(m, 8, 4).cycles();
            assert!(c1 >= c2 && c2 >= c3 && c3 >= c4, "m={m}");
        }
    }

    #[test]
    fn minus_one_is_single_add_cycle() {
        // m = −1.0: CSD "-0000000" → one AddShift{0, −} cycle: acc = −X.
        let plan = schedule(-128, 8);
        assert_eq!(plan.ops, vec![MulOp::AddShift { shift: 0, sign: -1 }]);
    }

    #[test]
    fn max_shift_one_still_exact() {
        for m in [-128i64, -37, -1, 1, 64, 115, 127] {
            let plan = schedule_with(m, 8, 1);
            let x: i128 = 999i128 << 32;
            assert_eq!(exact_eval(&plan, x), (x * m as i128) >> 7);
        }
    }

    #[test]
    fn naf_max_below_matches_greedy_pattern() {
        // B(t) = 2^(t-1) + 2^(t-3) + … — the densest non-adjacent
        // string below position t.
        let mut want = vec![0i64];
        for t in 1..=16u32 {
            let mut v = 0i64;
            let mut p = t as i64 - 1;
            while p >= 0 {
                v += 1 << p;
                p -= 2;
            }
            want.push(v);
            assert_eq!(naf_max_below(t), v, "t={t}");
        }
        assert_eq!(&want[..5], &[0, 1, 2, 5, 10]);
    }

    #[test]
    fn none_truncation_is_bit_identical_to_exact_schedule() {
        for y in [4u32, 6, 8] {
            let half = 1i64 << (y - 1);
            for m in -half..half {
                assert_eq!(
                    schedule_truncated(m, y, Truncation::NONE),
                    schedule(m, y),
                    "m={m} y={y}"
                );
            }
        }
    }

    #[test]
    fn truncated_plans_compute_the_kept_value_exactly() {
        // The truncated plan is an *exact* plan for its kept multiplier:
        // unbounded-precision replay must land on (x · m_kept) >> (y−1).
        for y in [4u32, 6, 8] {
            let half = 1i64 << (y - 1);
            for t in 0..y {
                for m in -half..half {
                    let plan = schedule_truncated(m, y, Truncation::drop_least(t));
                    let x: i128 = 777i128 << 32;
                    assert_eq!(
                        exact_eval(&plan, x),
                        (x * plan.m_raw as i128) >> (y - 1),
                        "m={m} y={y} t={t}"
                    );
                }
            }
        }
    }

    #[test]
    fn drop_least_error_is_bounded_by_naf_max_below() {
        for y in [4u32, 6, 8] {
            let half = 1i64 << (y - 1);
            for t in 0..=y {
                let bound = naf_max_below(t);
                for m in -half..half {
                    let plan = schedule_truncated(m, y, Truncation::drop_least(t));
                    assert!(
                        (m - plan.m_raw).abs() <= bound,
                        "m={m} y={y} t={t}: kept {} err {} > bound {bound}",
                        plan.m_raw,
                        (m - plan.m_raw).abs()
                    );
                }
            }
        }
    }

    #[test]
    fn truncation_strictly_reduces_cycles_when_digits_drop() {
        for y in [4u32, 6, 8] {
            let half = 1i64 << (y - 1);
            for m in -half..half {
                let exact = schedule(m, y);
                for t in 1..y {
                    let plan = schedule_truncated(m, y, Truncation::drop_least(t));
                    if plan.m_raw == m {
                        assert_eq!(plan.ops, exact.ops, "m={m} t={t}: nothing dropped");
                    } else {
                        assert!(
                            plan.cycles() < exact.cycles(),
                            "m={m} y={y} t={t}: {} !< {}",
                            plan.cycles(),
                            exact.cycles()
                        );
                    }
                    assert!(plan.adds() <= exact.adds());
                }
            }
        }
    }

    #[test]
    fn keep_digits_caps_add_count_and_keeps_most_significant() {
        for m in -128i64..128 {
            let exact = schedule(m, 8);
            for d in 0..=4u32 {
                let plan = schedule_truncated(m, 8, Truncation::keep_digits(d));
                assert!(plan.adds() <= d as usize, "m={m} d={d}");
                // One kept digit = the most-significant one: the kept
                // value's magnitude is at least half the original's.
                if d == 1 && m != 0 {
                    assert!(plan.m_raw != 0, "m={m}");
                    assert!(2 * plan.m_raw.abs() >= m.abs(), "m={m} kept {}", plan.m_raw);
                }
                if d as usize >= exact.adds() {
                    assert_eq!(plan.ops, exact.ops, "m={m} d={d}: cap above digit count");
                    assert_eq!(plan.m_raw, m);
                }
            }
        }
    }

    #[test]
    fn truncation_display_names_are_stable() {
        assert_eq!(Truncation::NONE.to_string(), "exact");
        assert_eq!(Truncation::drop_least(2).to_string(), "t2");
        assert_eq!(Truncation::keep_digits(1).to_string(), "d1");
        let both = Truncation { drop_below: 3, max_digits: Some(2) };
        assert_eq!(both.to_string(), "t3d2");
    }
}
