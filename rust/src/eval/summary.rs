//! Headline numbers (abstract / Section IV summary): maximum area
//! saving vs the equivalent Hard SIMD (paper: 53.1%) and maximum
//! per-multiplication energy saving (paper: 88.8%).

use crate::anyhow;

use super::{fig6, fig9};

pub struct Headlines {
    pub max_area_saving: f64,
    pub max_energy_saving: f64,
    pub hard_two_overhead_min: f64,
}

pub fn headlines() -> Headlines {
    let areas = fig6::areas();
    let mut max_area_saving: f64 = 0.0;
    let mut hard_two_overhead_min = f64::INFINITY;
    for chunk in areas.chunks(3) {
        let (soft, flex, two) = (&chunk[0], &chunk[1], &chunk[2]);
        max_area_saving = max_area_saving.max(1.0 - soft.total() / flex.total());
        hard_two_overhead_min = hard_two_overhead_min.min(two.total() / soft.total() - 1.0);
    }
    let (a, b) = fig9::grids();
    let mut max_energy_saving: f64 = 0.0;
    for grid in [&a, &b] {
        for row in &grid.gains {
            for g in row.iter().flatten() {
                max_energy_saving = max_energy_saving.max(*g);
            }
        }
    }
    Headlines { max_area_saving, max_energy_saving, hard_two_overhead_min }
}

pub fn run() -> anyhow::Result<()> {
    println!("== Headline numbers (paper: 53.1% area, 88.8% energy) ==");
    let h = headlines();
    println!(
        "max area saving vs Hard SIMD (4,6,8,12,16): {:.1}%  (paper: up to 53.1%)",
        h.max_area_saving * 100.0
    );
    println!(
        "max energy saving per multiplication:       {:.1}%  (paper: up to 88.8%)",
        h.max_energy_saving * 100.0
    );
    println!(
        "Hard SIMD (8,16) area overhead vs soft:     {:.1}%  (paper: >10% in all cases)\n",
        h.hard_two_overhead_min * 100.0
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headlines_in_paper_ballpark() {
        let h = headlines();
        assert!(h.max_area_saving > 0.5, "area saving {}", h.max_area_saving);
        assert!(
            h.max_energy_saving > 0.7,
            "energy saving {}",
            h.max_energy_saving
        );
        assert!(h.hard_two_overhead_min > 0.1);
    }
}
