//! Quantized Conv2D layers and the im2col lowering (DESIGN.md §12).
//!
//! A convolution is served on the same packed matmul hot path as a
//! dense layer: each output pixel of each image is one *patch row* of
//! the im2col matrix (`patch_len = cin·kh·kw` activations), and the
//! kernel tensor is the `[patch_len][cout]` weight matrix — so the CSD
//! multiply plan of a kernel weight is compiled **once** and shared
//! across every output pixel of every image, exactly the paper's "one
//! multiplier value, several multiplicands" pattern with the patch
//! dimension folded into the packed batch dimension.
//!
//! [`conv_forward_row`] is the scalar oracle for one image: the serving
//! engine must match it bit-exactly at every layer boundary (the conv
//! integration tests randomize shapes, strides and precision schedules
//! to enforce it). [`LayerOp`] is the layer algebra the compiled model
//! executes — interleaved conv + dense stacks.

use crate::anyhow;
use crate::bits::fixed::sign_extend;
use crate::pipeline::stage1::mul_scalar;

use super::weights::{LayerPrecision, QuantLayer};

/// The spatial geometry of one Conv2D layer. Tensor layouts are
/// channel-major and flattened: inputs `[cin][h][w]`, outputs
/// `[cout][out_h][out_w]`, and the im2col patch index runs
/// `k = (ci·kh + ky)·kw + kx` — the same order the weight matrix rows
/// use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvShape {
    /// Input channels.
    pub cin: usize,
    /// Input height / width (pixels).
    pub h: usize,
    pub w: usize,
    /// Output channels (kernel count).
    pub cout: usize,
    /// Kernel height / width.
    pub kh: usize,
    pub kw: usize,
    /// Stride (both axes).
    pub stride: usize,
    /// Zero padding (both axes, both sides).
    pub pad: usize,
}

impl ConvShape {
    pub fn out_h(&self) -> usize {
        (self.h + 2 * self.pad - self.kh) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.w + 2 * self.pad - self.kw) / self.stride + 1
    }

    /// Output pixels per image — the im2col patch rows one image
    /// expands into.
    pub fn out_pixels(&self) -> usize {
        self.out_h() * self.out_w()
    }

    /// im2col row width: `cin·kh·kw` (the lowered matmul's `k`).
    pub fn patch_len(&self) -> usize {
        self.cin * self.kh * self.kw
    }

    /// Flattened input feature length (`cin·h·w`).
    pub fn in_len(&self) -> usize {
        self.cin * self.h * self.w
    }

    /// Flattened output feature length (`cout·out_h·out_w`).
    pub fn out_len(&self) -> usize {
        self.cout * self.out_pixels()
    }

    /// Structural validity: nonzero dims, stride ≥ 1, and a kernel that
    /// fits the padded input with at least one output pixel.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.cin > 0 && self.h > 0 && self.w > 0 && self.cout > 0,
            "degenerate conv tensor {self:?}"
        );
        anyhow::ensure!(
            self.kh > 0 && self.kw > 0 && self.stride > 0,
            "degenerate conv kernel {self:?}"
        );
        anyhow::ensure!(
            self.kh <= self.h + 2 * self.pad && self.kw <= self.w + 2 * self.pad,
            "kernel {}x{} larger than padded input {}x{}",
            self.kh,
            self.kw,
            self.h + 2 * self.pad,
            self.w + 2 * self.pad
        );
        anyhow::ensure!(
            self.pad < self.kh && self.pad < self.kw,
            "padding {} would produce all-zero patches (kernel {}x{})",
            self.pad,
            self.kh,
            self.kw
        );
        Ok(())
    }

    /// The flattened input index a patch element reads, or `None` when
    /// the element falls in the zero padding. `k` is the im2col patch
    /// index, `(oy, ox)` the output pixel.
    #[inline]
    pub fn src_index(&self, k: usize, oy: usize, ox: usize) -> Option<usize> {
        let kx = k % self.kw;
        let ky = (k / self.kw) % self.kh;
        let ci = k / (self.kw * self.kh);
        let iy = (oy * self.stride + ky) as isize - self.pad as isize;
        let ix = (ox * self.stride + kx) as isize - self.pad as isize;
        if iy < 0 || iy >= self.h as isize || ix < 0 || ix >= self.w as isize {
            return None;
        }
        Some(ci * self.h * self.w + iy as usize * self.w + ix as usize)
    }
}

impl std::fmt::Display for ConvShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}x{}x{} -> {}ch {}x{} s{} p{}",
            self.cin, self.h, self.w, self.cout, self.kh, self.kw, self.stride, self.pad
        )
    }
}

/// One quantized Conv2D layer: the kernel tensor stored as its im2col
/// weight matrix (`[patch_len][cout]` raws, row `k = (ci·kh + ky)·kw +
/// kx`) plus the spatial geometry.
#[derive(Debug, Clone)]
pub struct ConvLayer {
    /// The lowered `[patch_len][cout]` weight matrix — CSD plans, weight
    /// width and the flat arena all come from here, unchanged.
    pub w: QuantLayer,
    pub shape: ConvShape,
}

impl ConvLayer {
    /// Build from the lowered weight matrix; shape and matrix dims must
    /// agree.
    pub fn new(w: QuantLayer, shape: ConvShape) -> anyhow::Result<ConvLayer> {
        shape.validate()?;
        anyhow::ensure!(
            w.k == shape.patch_len() && w.n == shape.cout,
            "conv weight matrix {}x{} does not match shape {shape} \
             (want {}x{})",
            w.k,
            w.n,
            shape.patch_len(),
            shape.cout
        );
        Ok(ConvLayer { w, shape })
    }

    /// Quantize a float kernel tensor `[cout][cin][kh][kw]` at `bits`.
    pub fn quantize(
        kernel: &[Vec<Vec<Vec<f64>>>],
        shape: ConvShape,
        bits: u32,
    ) -> anyhow::Result<ConvLayer> {
        shape.validate()?;
        anyhow::ensure!(kernel.len() == shape.cout, "kernel cout mismatch");
        let mut rows = vec![vec![0i64; shape.cout]; shape.patch_len()];
        for (co, ker) in kernel.iter().enumerate() {
            anyhow::ensure!(ker.len() == shape.cin, "kernel cin mismatch");
            for (ci, plane) in ker.iter().enumerate() {
                anyhow::ensure!(plane.len() == shape.kh, "kernel kh mismatch");
                for (ky, row) in plane.iter().enumerate() {
                    anyhow::ensure!(row.len() == shape.kw, "kernel kw mismatch");
                    for (kx, &v) in row.iter().enumerate() {
                        let k = (ci * shape.kh + ky) * shape.kw + kx;
                        rows[k][co] = crate::bits::fixed::to_q(v, bits);
                    }
                }
            }
        }
        ConvLayer::new(QuantLayer::new(rows, bits), shape)
    }
}

/// One layer of a servable stack: a dense matmul or a Conv2D lowered to
/// one. Both execute on the same packed matmul core; conv layers fold
/// their output pixels into the packed batch dimension.
#[derive(Debug, Clone)]
pub enum LayerOp {
    Dense(QuantLayer),
    Conv(ConvLayer),
}

impl LayerOp {
    /// The layer's matmul view — the weight matrix the CSD plans and
    /// the flat arena are compiled from (`[k][n]`; for conv,
    /// `k = patch_len`, `n = cout`).
    #[inline]
    pub fn weights(&self) -> &QuantLayer {
        match self {
            LayerOp::Dense(q) => q,
            LayerOp::Conv(c) => &c.w,
        }
    }

    /// Flattened input feature length (dense: `k`; conv: `cin·h·w`).
    pub fn in_len(&self) -> usize {
        match self {
            LayerOp::Dense(q) => q.k,
            LayerOp::Conv(c) => c.shape.in_len(),
        }
    }

    /// Flattened output feature length (dense: `n`; conv:
    /// `cout·out_h·out_w`).
    pub fn out_len(&self) -> usize {
        match self {
            LayerOp::Dense(q) => q.n,
            LayerOp::Conv(c) => c.shape.out_len(),
        }
    }

    /// Packed rows one image contributes at this layer: 1 for dense,
    /// `out_h·out_w` im2col patch rows for conv.
    #[inline]
    pub fn patch_rows(&self) -> usize {
        match self {
            LayerOp::Dense(_) => 1,
            LayerOp::Conv(c) => c.shape.out_pixels(),
        }
    }

    pub fn is_conv(&self) -> bool {
        matches!(self, LayerOp::Conv(_))
    }
}

/// Scalar Conv2D oracle for one image: `x_q` is the flattened
/// `[cin][h][w]` input at `Q1.(in_bits-1)`; returns the flattened
/// `[cout][out_h][out_w]` pre-activation accumulators at
/// `Q1.(acc_bits-1)`. Semantics per output value are exactly one dense
/// layer applied to the im2col patch row: products at `in_bits` via the
/// Soft SIMD shift-add multiply, widened `<< (acc−in)`, summed with
/// wrapping `acc_bits` adds — padding reads as the zero activation.
pub fn conv_forward_row(x_q: &[i64], layer: &ConvLayer, p: LayerPrecision) -> Vec<i64> {
    let s = &layer.shape;
    assert_eq!(x_q.len(), s.in_len(), "conv input length");
    assert!(p.acc_bits >= p.in_bits, "conv precision {p}");
    let (oh, ow) = (s.out_h(), s.out_w());
    let mask = (1u64 << p.acc_bits) - 1;
    let mut out = vec![0i64; s.out_len()];
    for co in 0..s.cout {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = 0i64;
                for k in 0..s.patch_len() {
                    let xv = s.src_index(k, oy, ox).map_or(0, |i| x_q[i]);
                    let prod = mul_scalar(xv, layer.w.w_raw[k][co], p.in_bits, layer.w.bits);
                    acc += prod << (p.acc_bits - p.in_bits);
                }
                out[(co * oh + oy) * ow + ox] = sign_extend(acc as u64 & mask, p.acc_bits);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape_3x3() -> ConvShape {
        ConvShape { cin: 1, h: 4, w: 4, cout: 1, kh: 3, kw: 3, stride: 1, pad: 1 }
    }

    #[test]
    fn shape_arithmetic() {
        let s = shape_3x3();
        assert_eq!((s.out_h(), s.out_w()), (4, 4));
        assert_eq!(s.patch_len(), 9);
        assert_eq!(s.in_len(), 16);
        assert_eq!(s.out_len(), 16);
        let strided = ConvShape { stride: 2, ..s };
        assert_eq!((strided.out_h(), strided.out_w()), (2, 2));
        let valid = ConvShape { pad: 0, ..s };
        assert_eq!((valid.out_h(), valid.out_w()), (2, 2));
    }

    #[test]
    fn shape_validation_rejects_degenerates() {
        assert!(shape_3x3().validate().is_ok());
        assert!(ConvShape { stride: 0, ..shape_3x3() }.validate().is_err());
        assert!(ConvShape { kh: 7, pad: 0, ..shape_3x3() }.validate().is_err());
        assert!(ConvShape { cout: 0, ..shape_3x3() }.validate().is_err());
        assert!(ConvShape { pad: 3, ..shape_3x3() }.validate().is_err());
    }

    #[test]
    fn src_index_handles_padding_and_stride() {
        let s = shape_3x3();
        // Output pixel (0,0), patch element (ky=0,kx=0) reads the
        // padding ring; the center tap (ky=1,kx=1) reads input (0,0).
        assert_eq!(s.src_index(0, 0, 0), None);
        assert_eq!(s.src_index(4, 0, 0), Some(0));
        // Bottom-right corner, bottom-right tap: padding again.
        assert_eq!(s.src_index(8, 3, 3), None);
        // ky=1,kx=1 at (3,3) reads input (3,3) = index 15.
        assert_eq!(s.src_index(4, 3, 3), Some(15));
    }

    #[test]
    fn identity_kernel_convolves_to_relocated_input() {
        // A center-tap 0.5 kernel with pad 1 reproduces the input
        // halved: out(y,x) = mul(in(y,x), 64@Q1.7).
        let mut w = vec![vec![0i64]; 9];
        w[4][0] = 64; // center tap 0.5 @ Q1.7
        let layer = ConvLayer::new(QuantLayer::new(w, 8), shape_3x3()).unwrap();
        let x: Vec<i64> = (0..16).map(|i| i as i64 * 8 - 60).collect();
        let out = conv_forward_row(&x, &layer, LayerPrecision::new(8, 16));
        for (i, (&o, &xi)) in out.iter().zip(&x).enumerate() {
            let want = mul_scalar(xi, 64, 8, 8) << 8;
            assert_eq!(o, want, "pixel {i}");
        }
    }

    #[test]
    fn conv_oracle_matches_im2col_dense_oracle() {
        // The lowering identity: conv(x) == dense(im2col patch row) for
        // every output pixel, including stride 2 and zero padding.
        use crate::nn::exec::mlp_forward_row_mixed;
        use crate::workload::synth::XorShift64;
        let mut rng = XorShift64::new(0xC0211);
        let shape =
            ConvShape { cin: 2, h: 5, w: 4, cout: 3, kh: 3, kw: 2, stride: 2, pad: 1 };
        let w = QuantLayer::new(
            (0..shape.patch_len())
                .map(|_| (0..shape.cout).map(|_| rng.q_raw(8)).collect())
                .collect(),
            8,
        );
        let layer = ConvLayer::new(w.clone(), shape).unwrap();
        let x: Vec<i64> = (0..shape.in_len()).map(|_| rng.q_raw(8)).collect();
        let p = LayerPrecision::new(8, 16);
        let got = conv_forward_row(&x, &layer, p);
        let (oh, ow) = (shape.out_h(), shape.out_w());
        for oy in 0..oh {
            for ox in 0..ow {
                let patch: Vec<i64> = (0..shape.patch_len())
                    .map(|k| shape.src_index(k, oy, ox).map_or(0, |i| x[i]))
                    .collect();
                let want = mlp_forward_row_mixed(&patch, &[w.clone()], &[p]);
                for co in 0..shape.cout {
                    assert_eq!(got[(co * oh + oy) * ow + ox], want[co], "({oy},{ox},{co})");
                }
            }
        }
    }

    #[test]
    fn conv_layer_rejects_mismatched_weight_matrix() {
        let w = QuantLayer::new(vec![vec![1, 2]; 4], 8); // 4x2, want 9x1
        assert!(ConvLayer::new(w, shape_3x3()).is_err());
    }

    #[test]
    fn quantize_lowering_orders_rows_ci_ky_kx() {
        // One 1-channel 2x2 kernel, distinct values per tap.
        let shape =
            ConvShape { cin: 1, h: 3, w: 3, cout: 1, kh: 2, kw: 2, stride: 1, pad: 0 };
        let kernel = vec![vec![vec![vec![0.5, -0.25], vec![0.125, 0.75]]]];
        let layer = ConvLayer::quantize(&kernel, shape, 8).unwrap();
        assert_eq!(
            layer.w.w_raw,
            vec![vec![64], vec![-32], vec![16], vec![96]],
            "rows must run (ky, kx) within a channel"
        );
    }
}
