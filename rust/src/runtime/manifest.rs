//! `artifacts/manifest.txt` — shapes and metadata of the AOT artifacts.

use std::collections::HashMap;
use std::path::Path;

use crate::anyhow;

/// Parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub mul_words: usize,
    pub ops_max: usize,
    pub mlp_batch: usize,
    pub mlp_in: usize,
    pub mlp_hidden: usize,
    pub mlp_out: usize,
    pub mlp_classes: usize,
    pub in_bits: u32,
    pub acc_bits: u32,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.txt"))?;
        let mut kv = HashMap::new();
        for line in text.lines() {
            if let Some((k, v)) = line.split_once('=') {
                kv.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
        let get = |k: &str| -> anyhow::Result<usize> {
            kv.get(k)
                .ok_or_else(|| anyhow::anyhow!("manifest missing key {k}"))?
                .parse()
                .map_err(|e| anyhow::anyhow!("manifest key {k}: {e}"))
        };
        Ok(Manifest {
            mul_words: get("mul_words")?,
            ops_max: get("ops_max")?,
            mlp_batch: get("mlp_batch")?,
            mlp_in: get("mlp_in")?,
            mlp_hidden: get("mlp_hidden")?,
            mlp_out: get("mlp_out")?,
            mlp_classes: get("mlp_classes")?,
            in_bits: get("in_bits")? as u32,
            acc_bits: get("acc_bits")? as u32,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_when_artifacts_exist() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.in_bits, 8);
        assert!(m.mul_words >= 64);
        assert_eq!(m.mlp_in, 64);
    }
}
