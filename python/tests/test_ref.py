"""jnp SWAR reference vs the plain-int pinned semantics."""

import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # offline image — deterministic fallback
    from _hypothesis_compat import given, settings, st

from compile import defs
from compile.kernels import ref

FORMATS = list(defs.FORMATS)
words = st.integers(min_value=0, max_value=defs.WORD_MASK)


def u64(x):
    return jnp.asarray(np.uint64(x))


def lanes(word, bits):
    return defs.unpack(word, defs.SimdFormat(bits))


def wrap(v, bits):
    return defs.sign_extend(v, bits)


class TestSwarVsInt:
    @given(st.sampled_from(FORMATS), words, words)
    @settings(max_examples=300, deadline=None)
    def test_add(self, bits, a, c):
        fmt = defs.SimdFormat(bits)
        got = int(ref.swar_add(u64(a), u64(c), u64(fmt.msb_mask)))
        want = defs.pack(
            [wrap(x + y, bits) for x, y in zip(lanes(a, bits), lanes(c, bits))], fmt
        )
        assert got == want

    @given(st.sampled_from(FORMATS), words, words)
    @settings(max_examples=300, deadline=None)
    def test_sub(self, bits, a, c):
        fmt = defs.SimdFormat(bits)
        got = int(ref.swar_sub(u64(a), u64(c), u64(fmt.msb_mask), u64(fmt.lsb_mask)))
        want = defs.pack(
            [wrap(x - y, bits) for x, y in zip(lanes(a, bits), lanes(c, bits))], fmt
        )
        assert got == want

    @given(st.sampled_from(FORMATS), words, st.integers(1, 3))
    @settings(max_examples=300, deadline=None)
    def test_sar(self, bits, a, k):
        fmt = defs.SimdFormat(bits)
        got = int(ref.swar_sar(u64(a), k, u64(fmt.msb_mask)))
        want = defs.pack([x >> k for x in lanes(a, bits)], fmt)
        assert got == want

    @given(st.sampled_from(FORMATS), words, words, st.integers(0, 3))
    @settings(max_examples=400, deadline=None)
    def test_fused_add_sar(self, bits, a, c, k):
        fmt = defs.SimdFormat(bits)
        got = int(ref.swar_add_sar(u64(a), u64(c), k, u64(fmt.msb_mask)))
        if k == 0:
            want = defs.pack(
                [wrap(x + y, bits) for x, y in zip(lanes(a, bits), lanes(c, bits))], fmt
            )
        else:
            # (b+1)-bit sum, then arithmetic shift — exact in python ints.
            want = defs.pack(
                [(x + y) >> k for x, y in zip(lanes(a, bits), lanes(c, bits))], fmt
            )
        assert got == want

    @given(st.sampled_from(FORMATS), words, words, st.integers(0, 3))
    @settings(max_examples=400, deadline=None)
    def test_fused_sub_sar(self, bits, a, c, k):
        fmt = defs.SimdFormat(bits)
        got = int(
            ref.swar_sub_sar(u64(a), u64(c), k, u64(fmt.msb_mask), u64(fmt.lsb_mask))
        )
        if k == 0:
            want = defs.pack(
                [wrap(x - y, bits) for x, y in zip(lanes(a, bits), lanes(c, bits))], fmt
            )
        else:
            want = defs.pack(
                [(x - y) >> k for x, y in zip(lanes(a, bits), lanes(c, bits))], fmt
            )
        assert got == want


class TestMulPackedRef:
    @given(st.sampled_from(FORMATS), st.data())
    @settings(max_examples=150, deadline=None)
    def test_static_matches_scalar_oracle(self, bits, data):
        fmt = defs.SimdFormat(bits)
        y = data.draw(st.sampled_from([4, 8, bits]))
        half = 1 << (y - 1)
        m = data.draw(st.integers(-half, half - 1))
        ws = [data.draw(words) for _ in range(4)]
        got = ref.mul_packed_ref(jnp.asarray(np.array(ws, dtype=np.uint64)), m, y, bits)
        for wi, w in enumerate(ws):
            want = [defs.mul_scalar(v, m, bits, y) for v in lanes(w, bits)]
            assert lanes(int(got[wi]), bits) == want

    @given(st.sampled_from(FORMATS), st.data())
    @settings(max_examples=150, deadline=None)
    def test_dynamic_matches_static(self, bits, data):
        fmt = defs.SimdFormat(bits)
        y = data.draw(st.sampled_from([4, 8, bits]))
        half = 1 << (y - 1)
        m = data.draw(st.integers(-half, half - 1))
        ws = np.array([data.draw(words) for _ in range(4)], dtype=np.uint64)
        shifts, signs = defs.plan_arrays(m, y)
        got = ref.mul_packed_dynamic_ref(
            jnp.asarray(ws),
            jnp.asarray(np.array(shifts, dtype=np.int32)),
            jnp.asarray(np.array(signs, dtype=np.int32)),
            u64(fmt.msb_mask),
            u64(fmt.lsb_mask),
        )
        want = ref.mul_packed_ref(jnp.asarray(ws), m, y, bits)
        assert np.array_equal(np.asarray(got), np.asarray(want))


class TestLayerRef:
    @given(st.data())
    @settings(max_examples=40, deadline=None)
    def test_layer_matches_scalar_semantics(self, data):
        M, K, N = 3, 5, 4
        x = np.array(
            [[data.draw(st.integers(-128, 127)) for _ in range(K)] for _ in range(M)],
            dtype=np.int32,
        )
        w = np.array(
            [[data.draw(st.integers(-128, 127)) for _ in range(N)] for _ in range(K)],
            dtype=np.int64,
        )
        shifts = np.zeros((K, N, defs.OPS_MAX), dtype=np.int32)
        signs = np.zeros((K, N, defs.OPS_MAX), dtype=np.int32)
        for i in range(K):
            for j in range(N):
                s, g = defs.plan_arrays(int(w[i, j]), 8)
                shifts[i, j], signs[i, j] = s, g
        got = np.asarray(ref.layer_ref(jnp.asarray(x), jnp.asarray(shifts), jnp.asarray(signs)))
        for b in range(M):
            for j in range(N):
                acc = 0
                for i in range(K):
                    p = defs.mul_scalar(int(x[b, i]), int(w[i, j]), 8, 8)
                    acc += p << 8
                assert got[b, j] == defs.sign_extend(acc, 16), (b, j)
