//! Property tests for the flattened execution core (DESIGN.md §11),
//! hand-rolled generators (proptest is unavailable offline).
//!
//! Two invariants are pinned here:
//!
//! 1. **Bit-exactness.** The flat micro-op kernel (`Stage1::run_flat`
//!    over the model's `PlanArena`) agrees lane-by-lane with the scalar
//!    oracles — `mul_scalar_plan` for single multiplies (including the
//!    zero-weight skip and the `−1 × −1` wrap corner) and
//!    `nn::exec::mlp_forward_row_mixed` for whole forward passes over
//!    random precision schedules.
//!
//! 2. **Billing independence.** `EngineStats` must equal the static
//!    cost certificate's closed-form evaluation conditioned on the
//!    batch's own zero-skip counters (`eval_stats_with_skips`,
//!    DESIGN.md §15, §18) — the execution strategy (flat ops, scratch
//!    reuse, word-level boundaries) must be invisible to the counters,
//!    down to the per-format buckets, and every elided Stage-1 plan
//!    must be accounted for in the skipped columns. The certificate
//!    itself is pinned against the pre-refactor hand formulas in one
//!    legacy regression case, so it can never drift silently.

use softsimd::bits::format::{format_index, SimdFormat};
use softsimd::bits::pack::{pack, unpack};
use softsimd::coordinator::engine::{EngineScratch, EngineStats, PackedEngine};
use softsimd::coordinator::model::{CompiledModel, VariantSpec};
use softsimd::csd::flat::encode_plan;
use softsimd::csd::schedule::schedule;
use softsimd::nn::exec::mlp_forward_row_mixed;
use softsimd::nn::weights::{LayerPrecision, QuantLayer};
use softsimd::pipeline::stage1::{mul_scalar_plan, Stage1};
use softsimd::testutil::{random_dense_stack, random_schedule};
use softsimd::workload::synth::XorShift64;

fn random_layers(rng: &mut XorShift64, dims: &[usize], w_bits: &[u32]) -> Vec<QuantLayer> {
    random_dense_stack(rng, dims, w_bits)
}

#[test]
fn flat_kernel_matches_scalar_plan_oracle_across_formats() {
    // Random plans × all formats × random packed words, plus the two
    // documented corners: the zero multiplier (empty plan → product 0,
    // zero cycles) and −1 × −1 (the two's-complement wrap).
    let mut rng = XorShift64::new(0xF1A7_0001);
    let mut flat = Vec::new();
    for fmt in SimdFormat::all() {
        let mut s1 = Stage1::new(fmt);
        for y_bits in [4u32, 6, 8, fmt.bits] {
            let half = 1i64 << (y_bits - 1);
            for trial in 0..80 {
                // Sweep the corners deterministically, then random.
                let m_raw = match trial {
                    0 => 0,
                    1 => -half, // −1.0: the −1 × −1 wrap partner
                    2 => half - 1,
                    _ => (rng.next_u64() % (2 * half as u64)) as i64 - half,
                };
                let plan = schedule(m_raw, y_bits);
                flat.clear();
                encode_plan(&plan, &mut flat);
                // Include the −1 multiplicand lane explicitly.
                let lanes: Vec<i64> = (0..fmt.lanes())
                    .map(|i| {
                        if i == 0 {
                            -(1i64 << (fmt.bits - 1)) // −1.0 in Q1.(b−1)
                        } else {
                            rng.q_raw(fmt.bits)
                        }
                    })
                    .collect();
                let x = pack(&lanes, fmt);
                let got = unpack(s1.run_flat(x, &flat), fmt);
                let want: Vec<i64> = lanes
                    .iter()
                    .map(|&l| mul_scalar_plan(l, &plan, fmt.bits))
                    .collect();
                assert_eq!(got, want, "fmt {fmt} y {y_bits} m {m_raw}");
                let (cycles, adds) = s1.take_counters();
                assert_eq!(cycles, plan.cycles() as u64, "fmt {fmt} m {m_raw}");
                assert_eq!(adds, plan.adds() as u64, "fmt {fmt} m {m_raw}");
                if m_raw == 0 {
                    assert_eq!((cycles, adds), (0, 0), "zero weight costs nothing");
                }
            }
        }
    }
}

#[test]
fn stage1_counters_never_diverge_from_plan_billing() {
    // Regression for the unbounded-counter bug: the engine bills
    // Stage-1 cycles by draining the datapath's counters; those drains
    // must equal the plan-formula billing (`plan.cycles() × words`)
    // for every plan, format and stream length — the two sources can
    // never diverge, because only one exists.
    let mut rng = XorShift64::new(0xF1A7_0002);
    let mut flat = Vec::new();
    for fmt in SimdFormat::all() {
        let mut s1 = Stage1::new(fmt);
        for _ in 0..60 {
            let m_raw = rng.q_raw(8);
            let plan = schedule(m_raw, 8);
            flat.clear();
            encode_plan(&plan, &mut flat);
            let words = 1 + rng.next_u64() % 7;
            for _ in 0..words {
                s1.run_flat(rng.next_u64() & softsimd::bits::format::WORD_MASK, &flat);
            }
            let (cycles, adds) = s1.take_counters();
            assert_eq!(cycles, plan.cycles() as u64 * words, "m={m_raw} fmt {fmt}");
            assert_eq!(adds, plan.adds() as u64 * words, "m={m_raw} fmt {fmt}");
        }
    }
}

/// The pre-refactor billing formulas, computed from the `MulPlan`
/// tables and one variant's schedule — what the per-op engine counted
/// for that schedule. Kept as the one **legacy regression oracle** the
/// cost certificate is pinned against
/// (`certificate_matches_the_legacy_prerefactor_formulas`); everything
/// else bills through `CompiledModel::cost_certificate`.
fn legacy_expected_stats(model: &CompiledModel, variant: usize, m: usize) -> EngineStats {
    let var = model.variant(variant);
    let quantum = var.batch_quantum();
    let mp = m.div_ceil(quantum) * quantum;
    let mut want = EngineStats {
        pad_rows: (mp - m) as u64,
        ..EngineStats::default()
    };
    for (li, layer) in model.layers().iter().enumerate() {
        let layer = layer.weights();
        let p = var.precision(li);
        let words = (mp / p.in_fmt().lanes() as usize) as u64;
        let acc_words = (mp * p.acc_bits as usize / 48) as u64;
        for k in 0..layer.k {
            for n in 0..layer.n {
                let plan = model.plan(li, k, n);
                if plan.ops.is_empty() {
                    continue;
                }
                let cycles = plan.cycles() as u64 * words;
                want.s1_cycles += cycles;
                want.s1_cycles_by_fmt[format_index(p.in_bits)] += cycles;
                let adds = plan.adds() as u64 * words;
                want.s1_adds += adds;
                want.s1_adds_by_fmt[format_index(p.in_bits)] += adds;
                want.subword_mults += m as u64;
                want.acc_adds += acc_words;
                if p.in_bits != p.acc_bits {
                    want.s2_passes += acc_words;
                    want.s2_passes_by_fmt[format_index(p.acc_bits)] += acc_words;
                }
            }
        }
        if li + 1 < model.layers().len() {
            for &(_, t) in var.boundary_chain(li) {
                let passes = (mp * t.bits as usize).div_ceil(48) as u64 * layer.n as u64;
                want.s2_passes += passes;
                want.s2_passes_by_fmt[format_index(t.bits)] += passes;
            }
        }
    }
    want
}

fn assert_stats_eq(got: &EngineStats, want: &EngineStats, ctx: &str) {
    assert_eq!(got.s1_cycles, want.s1_cycles, "{ctx}: s1_cycles");
    assert_eq!(got.s1_adds, want.s1_adds, "{ctx}: s1_adds");
    assert_eq!(got.s2_passes, want.s2_passes, "{ctx}: s2_passes");
    assert_eq!(got.acc_adds, want.acc_adds, "{ctx}: acc_adds");
    assert_eq!(got.subword_mults, want.subword_mults, "{ctx}: subword_mults");
    assert_eq!(got.pad_rows, want.pad_rows, "{ctx}: pad_rows");
    assert_eq!(got.s1_cycles_by_fmt, want.s1_cycles_by_fmt, "{ctx}: s1 by fmt");
    assert_eq!(got.s1_adds_by_fmt, want.s1_adds_by_fmt, "{ctx}: s1 adds by fmt");
    assert_eq!(got.s2_passes_by_fmt, want.s2_passes_by_fmt, "{ctx}: s2 by fmt");
}

#[test]
fn prop_flat_engine_is_bit_exact_and_bills_the_prerefactor_formulas() {
    // Random models × random schedules × random batch sizes, one
    // scratch reused across every case (the serving shape): results
    // must match the scalar mixed-precision oracle row-by-row and the
    // stats must equal the pre-refactor formulas field-by-field.
    let mut rng = XorShift64::new(0xF1A7_0003);
    let mut scratch = EngineScratch::new();
    let mut out = Vec::new();
    for case in 0..50 {
        let n_layers = 1 + (rng.next_u64() % 3) as usize;
        let dims: Vec<usize> = (0..=n_layers)
            .map(|_| 1 + (rng.next_u64() % 6) as usize)
            .collect();
        let w_bits: Vec<u32> = (0..n_layers)
            .map(|_| [4u32, 6, 8][(rng.next_u64() % 3) as usize])
            .collect();
        // Sprinkle exact zero weights so the zero-skip path is always
        // exercised.
        let mut layers = random_layers(&mut rng, &dims, &w_bits);
        for layer in &mut layers {
            for row in &mut layer.w_raw {
                for w in row.iter_mut() {
                    if rng.next_u64() % 5 == 0 {
                        *w = 0;
                    }
                }
            }
        }
        let sched = random_schedule(&mut rng, n_layers);
        let model = CompiledModel::compile_scheduled(layers.clone(), sched.clone())
            .unwrap_or_else(|e| panic!("case {case}: compile failed: {e}"));
        let engine = PackedEngine::new(model);
        let batch_size = 1 + (rng.next_u64() % 40) as usize;
        let batch: Vec<Vec<i64>> = (0..batch_size)
            .map(|_| (0..dims[0]).map(|_| rng.q_raw(sched[0].in_bits)).collect())
            .collect();
        let stats = engine.forward_batch_into(&batch, 0, &mut scratch, &mut out);
        assert_eq!(out.len(), batch_size, "case {case}: pad rows must be dropped");
        for (b, row) in batch.iter().enumerate() {
            let want = mlp_forward_row_mixed(row, &layers, &sched);
            assert_eq!(
                out[b], want,
                "case {case}: sched {sched:?} dims {dims:?} w_bits {w_bits:?} row {b}"
            );
        }
        let cert = engine.model().cost_certificate(0);
        let want = cert.eval_stats_with_skips(batch_size, &stats);
        assert_stats_eq(&stats, &want, &format!("case {case} (sched {sched:?})"));
        // Conservation: the skipped columns reconstruct the dense bill.
        let dense = cert.eval_stats(batch_size);
        assert_eq!(
            stats.s1_cycles + stats.skipped_cycles,
            dense.s1_cycles,
            "case {case}: s1 conservation"
        );
        assert_eq!(
            stats.s1_adds + stats.skipped_adds,
            dense.s1_adds,
            "case {case}: s1 adds conservation"
        );
    }
}

#[test]
fn prop_variant_switching_bills_each_batch_by_its_own_variants_formulas() {
    // The §13 billing pin: one multi-variant model, variants switched
    // batch-to-batch on one scratch — every batch's stats must equal
    // the single-variant pre-refactor formulas of the variant that
    // executed it, field-by-field and bucket-by-bucket, and the logits
    // must match that variant's scalar oracle. The execution history
    // (which variant ran before, warmed buffers, shrunk batches) must
    // be invisible to both results and billing.
    let mut rng = XorShift64::new(0xF1A7_0004);
    let mut scratch = EngineScratch::new();
    let mut out = Vec::new();
    for case in 0..20 {
        let n_layers = 1 + (rng.next_u64() % 3) as usize;
        let dims: Vec<usize> = (0..=n_layers)
            .map(|_| 1 + (rng.next_u64() % 6) as usize)
            .collect();
        let w_bits: Vec<u32> = (0..n_layers)
            .map(|_| [4u32, 6, 8][(rng.next_u64() % 3) as usize])
            .collect();
        let layers = random_layers(&mut rng, &dims, &w_bits);
        // Reference variant first (widest first layer), then random
        // narrower-or-equal variants.
        let mut specs = vec![VariantSpec::new(
            "ref",
            (0..n_layers).map(|_| LayerPrecision::new(8, 16)).collect(),
        )];
        for v in 0..2 {
            let sched = random_schedule(&mut rng, n_layers);
            if sched[0].in_bits <= 8 {
                specs.push(VariantSpec::new(format!("alt{v}"), sched));
            }
        }
        let ops = layers
            .iter()
            .cloned()
            .map(softsimd::nn::conv::LayerOp::Dense)
            .collect();
        let model = CompiledModel::compile_variants(ops, specs.clone())
            .unwrap_or_else(|e| panic!("case {case}: compile failed: {e}"));
        let engine = PackedEngine::new(model);
        for step in 0..6 {
            let v = (rng.next_u64() % specs.len() as u64) as usize;
            let sched = &specs[v].schedule;
            let batch_size = 1 + (rng.next_u64() % 30) as usize;
            let batch: Vec<Vec<i64>> = (0..batch_size)
                .map(|_| (0..dims[0]).map(|_| rng.q_raw(sched[0].in_bits)).collect())
                .collect();
            let stats = engine.forward_batch_into(&batch, v, &mut scratch, &mut out);
            for (b, row) in batch.iter().enumerate() {
                let want = mlp_forward_row_mixed(row, &layers, sched);
                assert_eq!(out[b], want, "case {case} step {step} variant {v} row {b}");
            }
            let cert = engine.model().cost_certificate(v);
            let want = cert.eval_stats_with_skips(batch_size, &stats);
            assert_stats_eq(
                &stats,
                &want,
                &format!("case {case} step {step} variant {v}"),
            );
            assert_eq!(
                stats.s1_cycles + stats.skipped_cycles,
                cert.eval_stats(batch_size).s1_cycles,
                "case {case} step {step} variant {v}: s1 conservation"
            );
        }
    }
}

#[test]
fn certificate_matches_the_legacy_prerefactor_formulas() {
    // The anti-drift pin: the static cost certificate (DESIGN.md §15)
    // must reproduce the pre-refactor hand formulas exactly — random
    // multi-variant dense models, batch sizes straddling each quantum.
    // Every other billing test trusts the certificate; this one is the
    // independent derivation that keeps it honest.
    let mut rng = XorShift64::new(0xF1A7_0005);
    for case in 0..20 {
        let n_layers = 1 + (rng.next_u64() % 3) as usize;
        let dims: Vec<usize> = (0..=n_layers)
            .map(|_| 1 + (rng.next_u64() % 6) as usize)
            .collect();
        let w_bits: Vec<u32> = (0..n_layers)
            .map(|_| [4u32, 6, 8][(rng.next_u64() % 3) as usize])
            .collect();
        let mut layers = random_layers(&mut rng, &dims, &w_bits);
        for layer in &mut layers {
            for row in &mut layer.w_raw {
                for w in row.iter_mut() {
                    if rng.next_u64() % 5 == 0 {
                        *w = 0;
                    }
                }
            }
        }
        let mut specs = vec![VariantSpec::new(
            "ref",
            (0..n_layers).map(|_| LayerPrecision::new(8, 16)).collect(),
        )];
        // The alt variant's first layer may not exceed the reference
        // width (requests can only be narrowed at dispatch).
        let alt = loop {
            let sched = random_schedule(&mut rng, n_layers);
            if sched[0].in_bits <= 8 {
                break sched;
            }
        };
        specs.push(VariantSpec::new("alt", alt));
        let ops = layers
            .into_iter()
            .map(softsimd::nn::conv::LayerOp::Dense)
            .collect();
        let model = CompiledModel::compile_variants(ops, specs)
            .unwrap_or_else(|e| panic!("case {case}: compile failed: {e}"));
        for v in 0..model.n_variants() {
            let cert = model.cost_certificate(v);
            let q = cert.batch_quantum;
            for m in [1, q, q + 1, 3 * q - 1] {
                assert_stats_eq(
                    &cert.eval_stats(m),
                    &legacy_expected_stats(&model, v, m),
                    &format!("case {case} variant {v} m={m}"),
                );
            }
        }
    }
}

#[test]
fn minus_one_times_minus_one_wraps_identically_end_to_end() {
    // The documented two's-complement corner: a −1.0 weight times a
    // −1.0 activation wraps to −1.0 (Q1.(b−1) cannot represent +1.0).
    // The packed engine must reproduce the oracle's wrap bit-exactly at
    // an equal-width accumulate, where nothing re-widens the product.
    for bits in [4u32, 8] {
        let half = 1i64 << (bits - 1);
        let layers = vec![QuantLayer::new(vec![vec![-half]], bits)];
        let sched = vec![LayerPrecision::new(bits, bits)];
        let model = CompiledModel::compile_scheduled(layers.clone(), sched.clone()).unwrap();
        let engine = PackedEngine::new(model);
        let lanes = (48 / bits) as usize;
        let batch: Vec<Vec<i64>> = (0..lanes).map(|_| vec![-half]).collect();
        let (got, _) = engine.forward_batch(&batch);
        for (b, row) in batch.iter().enumerate() {
            let want = mlp_forward_row_mixed(row, &layers, &sched);
            assert_eq!(got[b], want, "bits {bits} row {b}");
            assert_eq!(got[b], vec![-half], "−1 × −1 must wrap to −1 at {bits}b");
        }
    }
}
