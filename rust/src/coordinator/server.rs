//! The coordinator: request intake → dynamic batcher → PE worker pool.
//!
//! Serving shape (DESIGN.md §8): the submitting thread and a deadline
//! thread share the batcher and the router; each PE worker owns one
//! [`PackedEngine`] bound to the single shared [`CompiledModel`].
//! Dispatch routes formed batches over *bounded* per-worker queues —
//! least-outstanding-rows by default, round-robin for comparison — so a
//! slow PE exerts backpressure instead of growing an unbounded mailbox.
//! The deadline thread drives [`Batcher::tick`] so straggler requests
//! flush without an explicit [`Coordinator::drain`]. Worker death is
//! surfaced as [`ServeError`], never a panic in the coordinator, and a
//! dead PE can be respawned in place with
//! [`Coordinator::revive_worker`] (rolling restarts must not
//! permanently shrink capacity).
//!
//! When the served model carries several precision variants
//! (DESIGN.md §13), every dispatch consults the installed
//! [`GovernorPolicy`] with the live load signals (queued rows + the
//! windowed p99 from the metrics histogram); the chosen variant is
//! stamped on the batch, the batcher's alignment quantum follows it,
//! and the PE worker requantizes the batch's rows
//! ([`Variant::in_shift`]) and bills cycles/energy to the variant it
//! **actually executed** — never to a later decision.
//!
//! [`Variant::in_shift`]: super::model::Variant::in_shift

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{Batch, Batcher, TrackedRequest};
use super::cost::CostTable;
use super::engine::PackedEngine;
use super::governor::{GovernorPolicy, LoadSignals, PinnedVariant};
use super::metrics::{Metrics, MetricsSnapshot};
use super::model::CompiledModel;

/// An inference request: rows of quantized activations at the model's
/// reference precision ([`CompiledModel::in_bits`]), whichever variant
/// ends up executing them.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub rows: Vec<Vec<i64>>,
}

/// Its response: per-row logits at the executing variant's final
/// accumulator format, tagged with the variant that produced them so
/// callers can check against the right per-variant oracle.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<Vec<i64>>,
    /// The precision variant that executed this request's batch.
    pub variant: usize,
}

/// How formed batches are routed to PE workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Rotate over live workers regardless of their backlog.
    RoundRobin,
    /// Send to the live worker with the fewest outstanding rows.
    LeastLoaded,
}

/// Coordinator deployment knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Number of PE worker threads.
    pub n_pes: usize,
    /// Rows the batcher tries to fill before forming a batch.
    pub target_rows: usize,
    /// Bounded depth (in batches) of each worker's queue.
    pub queue_depth: usize,
    /// Straggler flush deadline: a pending sub-target batch is flushed
    /// at most ~this long after its last arrival.
    pub deadline: Duration,
    pub policy: DispatchPolicy,
}

impl ServeConfig {
    pub fn new(n_pes: usize, target_rows: usize) -> ServeConfig {
        ServeConfig {
            n_pes: n_pes.max(1),
            target_rows: target_rows.max(1),
            queue_depth: 2,
            deadline: Duration::from_millis(2),
            policy: DispatchPolicy::LeastLoaded,
        }
    }

    pub fn policy(mut self, policy: DispatchPolicy) -> ServeConfig {
        self.policy = policy;
        self
    }

    pub fn deadline(mut self, deadline: Duration) -> ServeConfig {
        self.deadline = deadline;
        self
    }

    pub fn queue_depth(mut self, depth: usize) -> ServeConfig {
        self.queue_depth = depth.max(1);
        self
    }
}

/// Serving failures surfaced to the caller (instead of the seed's
/// `expect("worker alive")` panics).
#[derive(Debug)]
pub enum ServeError {
    /// The request doesn't fit the model (wrong row width, no rows, or
    /// out-of-range raw values); nothing was enqueued. Rejecting at
    /// submit keeps a malformed request from panicking a PE worker.
    InvalidRequest { id: u64, reason: String },
    /// Every PE worker is dead; the offending rows were restored to the
    /// batcher, not dropped. `recovered` carries any responses that
    /// were still collected (empty on the submit path).
    NoLiveWorkers { recovered: Vec<Response> },
    /// One or more workers died holding dispatched work; `recovered`
    /// carries every response the remaining workers still produced.
    WorkerLost {
        workers: Vec<usize>,
        lost_rows: usize,
        recovered: Vec<Response>,
    },
    /// A shared lock was poisoned by a panicking holder. Submit-path
    /// callers get this instead of a propagated panic; `recovered`
    /// carries any responses `drain` still collected. Observability
    /// and teardown paths (`pending_rows`, `kill_worker`, `shutdown`,
    /// the deadline tick) recover the lock instead — they must make
    /// progress even after a panic elsewhere.
    LockPoisoned {
        what: &'static str,
        recovered: Vec<Response>,
    },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::InvalidRequest { id, reason } => {
                write!(f, "invalid request {id}: {reason}")
            }
            ServeError::NoLiveWorkers { recovered } => write!(
                f,
                "no live PE workers ({} responses recovered)",
                recovered.len()
            ),
            ServeError::WorkerLost { workers, lost_rows, recovered } => write!(
                f,
                "PE worker(s) {workers:?} died holding {lost_rows} dispatched \
                 rows ({} responses recovered)",
                recovered.len()
            ),
            ServeError::LockPoisoned { what, recovered } => write!(
                f,
                "{what} lock poisoned by a panicking holder ({} responses \
                 recovered)",
                recovered.len()
            ),
        }
    }
}

impl std::error::Error for ServeError {}

/// Recover a mutex regardless of poisoning — for paths that must make
/// progress after a panic elsewhere (teardown, observability, the
/// deadline tick, writing off dead workers' counters). The guarded
/// state is counters and queues that stay consistent across a holder's
/// panic; the submit paths use [`lock_or`] instead and surface the
/// poisoning as a typed error.
fn relock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Acquire a mutex or surface the poisoning as
/// [`ServeError::LockPoisoned`] — the submit-path counterpart of
/// [`relock`]: a caller handing in new work can be refused cleanly.
fn lock_or<'a, T>(
    m: &'a Mutex<T>,
    what: &'static str,
) -> Result<std::sync::MutexGuard<'a, T>, ServeError> {
    m.lock()
        .map_err(|_| ServeError::LockPoisoned { what, recovered: vec![] })
}

enum WorkerMsg {
    Work(Batch),
    Stop,
}

/// Leader-side view of one PE worker.
struct WorkerPort {
    tx: SyncSender<WorkerMsg>,
    /// Rows dispatched to this worker and not yet completed.
    outstanding_rows: Arc<AtomicUsize>,
    /// Batches dispatched to this worker and not yet completed.
    outstanding_batches: Arc<AtomicUsize>,
    alive: bool,
}

/// Load-aware batch router over the worker ports.
struct Router {
    ports: Vec<WorkerPort>,
    policy: DispatchPolicy,
    next_rr: usize,
}

impl Router {
    /// Candidate workers, best first, per the policy. Only live ports.
    fn candidates(&mut self) -> Vec<usize> {
        let live: Vec<usize> = (0..self.ports.len())
            .filter(|&i| self.ports[i].alive)
            .collect();
        if live.is_empty() {
            return live;
        }
        match self.policy {
            DispatchPolicy::RoundRobin => {
                let start = self.next_rr % live.len();
                self.next_rr = self.next_rr.wrapping_add(1);
                let mut order = Vec::with_capacity(live.len());
                for off in 0..live.len() {
                    order.push(live[(start + off) % live.len()]);
                }
                order
            }
            DispatchPolicy::LeastLoaded => {
                let mut order = live;
                order.sort_by_key(|&i| {
                    self.ports[i].outstanding_rows.load(Ordering::Relaxed)
                });
                order
            }
        }
    }

    /// Route one batch. Tries every live worker without blocking; if all
    /// bounded queues are full, blocks on the preferred worker
    /// (backpressure). `Err(batch)` iff no live worker remains.
    fn dispatch(&mut self, batch: Batch) -> Result<usize, Batch> {
        let mut batch = batch;
        loop {
            let order = self.candidates();
            if order.is_empty() {
                return Err(batch);
            }
            // Non-blocking pass in preference order.
            for &w in &order {
                self.charge(w, &batch);
                match self.ports[w].tx.try_send(WorkerMsg::Work(batch)) {
                    Ok(()) => return Ok(w),
                    Err(TrySendError::Full(msg)) => {
                        batch = self.uncharge(w, msg);
                    }
                    Err(TrySendError::Disconnected(msg)) => {
                        batch = self.uncharge(w, msg);
                        self.ports[w].alive = false;
                    }
                }
            }
            // All live queues full: block on the preferred one.
            let w = match self.candidates().first() {
                Some(&w) => w,
                None => return Err(batch),
            };
            self.charge(w, &batch);
            match self.ports[w].tx.send(WorkerMsg::Work(batch)) {
                Ok(()) => return Ok(w),
                Err(std::sync::mpsc::SendError(msg)) => {
                    batch = self.uncharge(w, msg);
                    self.ports[w].alive = false;
                    // Retry the remaining live workers.
                }
            }
        }
    }

    fn charge(&self, w: usize, batch: &Batch) {
        self.ports[w]
            .outstanding_rows
            .fetch_add(batch.rows, Ordering::Relaxed);
        self.ports[w]
            .outstanding_batches
            .fetch_add(1, Ordering::Relaxed);
    }

    fn uncharge(&self, w: usize, msg: WorkerMsg) -> Batch {
        let batch = match msg {
            WorkerMsg::Work(b) => b,
            WorkerMsg::Stop => unreachable!("router only routes work"),
        };
        self.ports[w]
            .outstanding_rows
            .fetch_sub(batch.rows, Ordering::Relaxed);
        self.ports[w]
            .outstanding_batches
            .fetch_sub(1, Ordering::Relaxed);
        batch
    }
}

/// The governor's mutable half: the installed policy plus the metrics
/// snapshot its last decision was taken at (windowed p99 = the
/// histogram delta between two consecutive decisions).
struct GovernorState {
    policy: Box<dyn GovernorPolicy>,
    last_snap: MetricsSnapshot,
}

/// State shared between the submit path, the deadline thread, and the
/// PE workers.
struct Shared {
    batcher: Mutex<Batcher>,
    router: Mutex<Router>,
    /// Batches dispatched and not yet collected by the leader.
    in_flight: AtomicUsize,
    stop_deadline: AtomicBool,
    metrics: Arc<Metrics>,
    /// The precision governor, consulted once per dispatched batch.
    governor: Mutex<GovernorState>,
    /// Each worker slot's outstanding-row counter (shared with the
    /// router's ports) — readable without the router lock, so the
    /// governor's queue-depth signal never nests router inside batcher
    /// beyond the dispatch itself.
    port_loads: Vec<Arc<AtomicUsize>>,
    /// Per-variant batch quanta (index = variant id); also the variant
    /// count — single-entry for a single-variant model.
    quanta: Vec<usize>,
    /// Most recently chosen variant (observability; billing follows
    /// each batch's own tag, not this).
    active_variant: AtomicUsize,
}

impl Shared {
    /// Count and route one formed batch while still holding the batcher
    /// lock. Holding the lock keeps the invariant that whenever the
    /// batcher is observable, every formed batch is either counted in
    /// `in_flight` or restored as pending — so `drain` can never slip
    /// between "batch left the batcher" and "batch became in-flight".
    /// Lock order is always batcher → governor → router; never any
    /// reverse.
    fn dispatch_locked(
        &self,
        batcher: &mut Batcher,
        mut batch: Batch,
    ) -> Result<(), ServeError> {
        // Governor decision (DESIGN.md §13): sample the live load —
        // this batch's rows, everything still pending, and every row
        // dispatched-but-not-done — plus the windowed p99 since the
        // previous decision; stamp the batch and re-arm the batcher's
        // alignment quantum for the *next* batch. A restored batch
        // passes through here again on retry and may legitimately be
        // re-tagged: it has not executed yet. A single-variant model
        // has no decision to make: skip the snapshot/quantile work
        // entirely rather than tax every dispatch of the common case
        // with a heap allocation under the batcher lock.
        // A poisoned governor degrades gracefully: the batch keeps its
        // current variant tag and dispatch proceeds — precision
        // adaptation pauses, serving does not.
        if self.quanta.len() > 1 {
            if let Ok(mut gov) = self.governor.lock() {
                self.govern(&mut gov, batcher, &mut batch);
            }
        }
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        let result = match self.router.lock() {
            Ok(mut router) => router.dispatch(batch),
            Err(_) => {
                // Poisoned router: restore the batch (it was never
                // dispatched) and refuse the submit.
                self.in_flight.fetch_sub(1, Ordering::SeqCst);
                batcher.restore(batch);
                return Err(ServeError::LockPoisoned {
                    what: "router",
                    recovered: vec![],
                });
            }
        };
        match result {
            Ok(_) => Ok(()),
            Err(batch) => {
                self.in_flight.fetch_sub(1, Ordering::SeqCst);
                batcher.restore(batch);
                Err(ServeError::NoLiveWorkers { recovered: vec![] })
            }
        }
    }

    /// The governor decision of [`dispatch_locked`], split out so a
    /// poisoned governor lock can skip it wholesale.
    fn govern(&self, gov: &mut GovernorState, batcher: &mut Batcher, batch: &mut Batch) {
        let queued_rows = batch.rows
            + batcher.pending_rows()
            + self
                .port_loads
                .iter()
                .map(|l| l.load(Ordering::Relaxed))
                .sum::<usize>();
        let snap = self.metrics.snapshot();
        let window_p99_ns = snap.window_latency_quantile_ns(&gov.last_snap, 0.99);
        let chosen = gov.policy.choose(&LoadSignals {
            queued_rows,
            window_p99_ns,
            n_variants: self.quanta.len(),
        });
        gov.last_snap = snap;
        let v = chosen.min(self.quanta.len() - 1);
        if v != self.active_variant.swap(v, Ordering::Relaxed) {
            self.metrics.note_variant_switch();
        }
        batch.variant = v;
        batcher.set_quantum(self.quanta[v]);
    }

    /// Submit path: offer a request; dispatch if the target fills.
    fn push_and_dispatch(&self, tr: TrackedRequest) -> Result<(), ServeError> {
        let mut batcher = lock_or(&self.batcher, "batcher")?;
        match batcher.push(tr) {
            Some(batch) => self.dispatch_locked(&mut batcher, batch),
            None => Ok(()),
        }
    }

    /// Deadline-thread path: poll tick; dispatch a straggler flush.
    /// Recovers a poisoned batcher — the deadline thread must keep
    /// ticking (and must never panic itself) after a panic elsewhere.
    fn tick_and_dispatch(&self) {
        let mut batcher = relock(&self.batcher);
        if let Some(batch) = batcher.tick() {
            // Total dispatch failure restores the rows; the next
            // drain() surfaces the error.
            let _ = self.dispatch_locked(&mut batcher, batch);
        }
    }

    /// Drain path: force out whatever is pending.
    fn flush_and_dispatch(&self) -> Result<(), ServeError> {
        let mut batcher = lock_or(&self.batcher, "batcher")?;
        match batcher.flush() {
            Some(batch) => self.dispatch_locked(&mut batcher, batch),
            None => Ok(()),
        }
    }
}

/// The running coordinator.
pub struct Coordinator {
    shared: Arc<Shared>,
    rx_done: Receiver<(usize, Vec<Response>)>,
    workers: Vec<JoinHandle<()>>,
    deadline_thread: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    /// Model row width, for request validation at submit.
    input_width: usize,
    /// Half-range of the reference variant's input format
    /// (`2^(in_bits-1)`), for validation.
    in_half: i64,
    /// Worker (re)spawn context, kept for [`Coordinator::revive_worker`].
    model: Arc<CompiledModel>,
    cost: Arc<CostTable>,
    tx_done: Sender<(usize, Vec<Response>)>,
    queue_depth: usize,
}

/// Spawn one PE worker thread bound to slot `worker_id`, reusing the
/// slot's outstanding-work counters (they outlive any one incarnation
/// of the worker — the router and the governor read them by slot).
#[allow(clippy::too_many_arguments)]
fn spawn_worker(
    worker_id: usize,
    model: &Arc<CompiledModel>,
    cost: &Arc<CostTable>,
    tx_done: &Sender<(usize, Vec<Response>)>,
    metrics: &Arc<Metrics>,
    queue_depth: usize,
    outstanding_rows: Arc<AtomicUsize>,
    outstanding_batches: Arc<AtomicUsize>,
) -> (WorkerPort, JoinHandle<()>) {
    let (tx, rx) = sync_channel::<WorkerMsg>(queue_depth.max(1));
    let port = WorkerPort {
        tx,
        outstanding_rows: Arc::clone(&outstanding_rows),
        outstanding_batches: Arc::clone(&outstanding_batches),
        alive: true,
    };
    let done = tx_done.clone();
    let m = Arc::clone(metrics);
    let c = Arc::clone(cost);
    let engine = PackedEngine::new(Arc::clone(model));
    let handle = std::thread::spawn(move || {
        worker_loop(
            worker_id,
            engine,
            rx,
            done,
            m,
            c,
            outstanding_rows,
            outstanding_batches,
        );
    });
    (port, handle)
}

impl Coordinator {
    /// Spawn `cfg.n_pes` worker PEs serving the shared compiled model
    /// at its reference variant, with no precision governor (a
    /// multi-variant model serves variant 0 until a policy is installed
    /// via [`Coordinator::start_with_policy`]). Plans are compiled by
    /// [`CompiledModel::compile`], exactly once, before this call;
    /// workers only clone the `Arc`.
    pub fn start(model: Arc<CompiledModel>, cfg: ServeConfig, cost: CostTable) -> Coordinator {
        Coordinator::start_with_policy(model, cfg, cost, Box::new(PinnedVariant(0)))
    }

    /// As [`Coordinator::start`], with a precision-governor policy
    /// consulted at every batch dispatch (DESIGN.md §13).
    pub fn start_with_policy(
        model: Arc<CompiledModel>,
        cfg: ServeConfig,
        cost: CostTable,
        policy: Box<dyn GovernorPolicy>,
    ) -> Coordinator {
        let names: Vec<String> =
            model.variants().iter().map(|v| v.name().to_string()).collect();
        let metrics = Arc::new(Metrics::with_variant_names(&names));
        let (tx_done, rx_done) = channel::<(usize, Vec<Response>)>();
        let cost = Arc::new(cost);
        let queue_depth = cfg.queue_depth.max(1);
        let mut ports = vec![];
        let mut workers = vec![];
        let mut port_loads = vec![];
        for worker_id in 0..cfg.n_pes.max(1) {
            let outstanding_rows = Arc::new(AtomicUsize::new(0));
            let outstanding_batches = Arc::new(AtomicUsize::new(0));
            port_loads.push(Arc::clone(&outstanding_rows));
            let (port, handle) = spawn_worker(
                worker_id,
                &model,
                &cost,
                &tx_done,
                &metrics,
                queue_depth,
                outstanding_rows,
                outstanding_batches,
            );
            ports.push(port);
            workers.push(handle);
        }
        let quanta: Vec<usize> =
            model.variants().iter().map(|v| v.batch_quantum()).collect();
        let mut batcher = Batcher::new(cfg.target_rows, 2);
        batcher.set_quantum(quanta[0]);
        let shared = Arc::new(Shared {
            batcher: Mutex::new(batcher),
            router: Mutex::new(Router {
                ports,
                policy: cfg.policy,
                next_rr: 0,
            }),
            in_flight: AtomicUsize::new(0),
            stop_deadline: AtomicBool::new(false),
            metrics: Arc::clone(&metrics),
            governor: Mutex::new(GovernorState {
                policy,
                last_snap: MetricsSnapshot::empty(quanta.len()),
            }),
            port_loads,
            quanta,
            active_variant: AtomicUsize::new(0),
        });
        // Deadline thread: tick at half the deadline so a straggler
        // flushes within (0.5, 1.0]× the configured deadline.
        let tick_period = (cfg.deadline / 2).max(Duration::from_micros(200));
        let shared_bg = Arc::clone(&shared);
        let deadline_thread = std::thread::spawn(move || {
            while !shared_bg.stop_deadline.load(Ordering::Acquire) {
                std::thread::park_timeout(tick_period);
                shared_bg.tick_and_dispatch();
            }
        });
        Coordinator {
            shared,
            rx_done,
            workers,
            deadline_thread: Some(deadline_thread),
            metrics,
            input_width: model.input_width(),
            in_half: 1i64 << (model.in_bits() - 1),
            model,
            cost,
            tx_done,
            queue_depth,
        }
    }

    /// The variant the governor chose at the most recent dispatch
    /// (observability; per-batch billing follows each batch's own tag).
    pub fn active_variant(&self) -> usize {
        self.shared.active_variant.load(Ordering::Relaxed)
    }

    /// Submit a request (may trigger a batch dispatch). Shape and range
    /// are validated here so a malformed request is an error for its
    /// sender, never a panic inside a PE worker.
    pub fn submit(&mut self, req: Request) -> Result<(), ServeError> {
        self.validate(&req)?;
        self.metrics.note_submit();
        self.shared.push_and_dispatch(TrackedRequest::now(req))
    }

    fn validate(&self, req: &Request) -> Result<(), ServeError> {
        let invalid = |reason: String| ServeError::InvalidRequest { id: req.id, reason };
        if req.rows.is_empty() {
            return Err(invalid("request has no rows".to_string()));
        }
        for (i, row) in req.rows.iter().enumerate() {
            if row.len() != self.input_width {
                return Err(invalid(format!(
                    "row {i} width {} != model input width {}",
                    row.len(),
                    self.input_width
                )));
            }
            if let Some(&v) = row.iter().find(|&&v| v < -self.in_half || v >= self.in_half) {
                return Err(invalid(format!(
                    "row {i} value {v} outside Q range [{}, {})",
                    -self.in_half, self.in_half
                )));
            }
        }
        Ok(())
    }

    /// Rows batched but not yet dispatched (waiting on the deadline).
    /// Observability must survive a poisoned lock.
    pub fn pending_rows(&self) -> usize {
        relock(&self.shared.batcher).pending_rows()
    }

    /// Fault injection / rolling restart: stop worker `idx` after it
    /// finishes its queued work. Routing avoids it immediately; its
    /// in-queue work still completes and is collected by `drain`.
    pub fn kill_worker(&mut self, idx: usize) {
        let tx = {
            let mut router = relock(&self.shared.router);
            match router.ports.get_mut(idx) {
                Some(port) => {
                    port.alive = false;
                    port.tx.clone()
                }
                None => return,
            }
        };
        // Deliver Stop without holding the router lock and without
        // blocking the caller: behind a full queue the send parks on a
        // helper thread until the worker drains its backlog.
        std::thread::spawn(move || {
            let _ = tx.send(WorkerMsg::Stop);
        });
    }

    /// Rolling-restart companion of [`kill_worker`]: respawn a dead
    /// PE in its slot — fresh thread, fresh bounded queue, same
    /// outstanding-work counters — and re-arm routing to it. Returns
    /// `false` (and does nothing) for an out-of-range slot or a worker
    /// that is still alive; a killed worker is first joined, so any
    /// work still in its old queue completes and is collected before
    /// the replacement takes over. Without this, every
    /// [`kill_worker`] permanently shrank serving capacity.
    ///
    /// [`kill_worker`]: Coordinator::kill_worker
    pub fn revive_worker(&mut self, idx: usize) -> bool {
        if idx >= self.workers.len() {
            return false;
        }
        {
            let router = relock(&self.shared.router);
            if router.ports[idx].alive {
                return false;
            }
        }
        // The old incarnation exits once its queued work (and the
        // pending Stop) drains; joining here is what makes "revive"
        // safe — two workers never share a slot.
        let (mut port, handle) = spawn_worker(
            idx,
            &self.model,
            &self.cost,
            &self.tx_done,
            &self.metrics,
            self.queue_depth,
            Arc::clone(&self.shared.port_loads[idx]),
            {
                let router = relock(&self.shared.router);
                Arc::clone(&router.ports[idx].outstanding_batches)
            },
        );
        let old = std::mem::replace(&mut self.workers[idx], handle);
        let _ = old.join();
        // Install the new port only after the old worker is gone: its
        // leftover counters were either drained by the worker itself or
        // written off by `drain`.
        let mut router = relock(&self.shared.router);
        std::mem::swap(&mut router.ports[idx], &mut port);
        // `port` now holds the dead incarnation's channel; dropping it
        // closes that queue for good.
        true
    }

    /// Flush stragglers and wait for every response. On failure the
    /// error still carries whatever responses could be collected —
    /// completed work is never stranded behind an error.
    pub fn drain(&mut self) -> Result<Vec<Response>, ServeError> {
        // Collect in-flight work even if the flush finds no live
        // workers: earlier batches may already have completed.
        let flush_err = self.shared.flush_and_dispatch().err();
        let mut out = vec![];
        let mut lost_workers: Vec<usize> = vec![];
        let mut lost_rows = 0usize;
        // Write off work held by workers that exited without answering.
        let write_off = |lost_workers: &mut Vec<usize>, lost_rows: &mut usize| {
            let mut router = relock(&self.shared.router);
            for (i, port) in router.ports.iter_mut().enumerate() {
                if !self.workers[i].is_finished() {
                    continue;
                }
                port.alive = false;
                let batches = port.outstanding_batches.swap(0, Ordering::SeqCst);
                if batches == 0 {
                    continue;
                }
                let rows = port.outstanding_rows.swap(0, Ordering::SeqCst);
                self.shared.in_flight.fetch_sub(batches, Ordering::SeqCst);
                self.metrics
                    .dropped_rows
                    .fetch_add(rows as u64, Ordering::Relaxed);
                lost_workers.push(i);
                *lost_rows += rows;
            }
        };
        while self.shared.in_flight.load(Ordering::SeqCst) > 0 {
            match self.rx_done.recv_timeout(Duration::from_millis(50)) {
                Ok((_, mut rs)) => {
                    self.shared.in_flight.fetch_sub(1, Ordering::SeqCst);
                    out.append(&mut rs);
                }
                // Disconnected is unreachable while the coordinator
                // holds its respawn sender (kept for `revive_worker`);
                // both arms mean "no response right now" — write off
                // work held by exited workers and keep collecting. The
                // loop ends when `in_flight` reaches zero: every
                // dispatched batch is either answered on `rx_done` or
                // counted in some port's outstanding batches.
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {
                    write_off(&mut lost_workers, &mut lost_rows);
                }
            }
        }
        out.sort_by_key(|r| r.id);
        if !lost_workers.is_empty() {
            return Err(ServeError::WorkerLost {
                workers: lost_workers,
                lost_rows,
                recovered: out,
            });
        }
        match flush_err {
            Some(ServeError::LockPoisoned { what, .. }) => {
                Err(ServeError::LockPoisoned { what, recovered: out })
            }
            Some(_) => Err(ServeError::NoLiveWorkers { recovered: out }),
            None => Ok(out),
        }
    }

    /// Stop the deadline thread and workers, then join them.
    pub fn shutdown(mut self) {
        self.shared.stop_deadline.store(true, Ordering::Release);
        if let Some(t) = self.deadline_thread.take() {
            t.thread().unpark();
            let _ = t.join();
        }
        {
            let router = relock(&self.shared.router);
            for port in &router.ports {
                // Blocking send so Stop lands even behind a full queue;
                // a dead worker just returns SendError.
                let _ = port.tx.send(WorkerMsg::Stop);
            }
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    worker_id: usize,
    engine: PackedEngine,
    rx: Receiver<WorkerMsg>,
    done: Sender<(usize, Vec<Response>)>,
    metrics: Arc<Metrics>,
    cost: Arc<CostTable>,
    outstanding_rows: Arc<AtomicUsize>,
    outstanding_batches: Arc<AtomicUsize>,
) {
    // Steady-state serving allocates nothing in the engine: the worker
    // owns one EngineScratch plus gather/output buffers for its whole
    // lifetime, warmed by the first batch and reused across requests
    // (DESIGN.md §11). Only the Response assembly below allocates.
    // Under `--features simd` the engine picks the host-vector backend
    // inside `forward_batch_into` with no scratch-shape change: the
    // batch quantum already yields whole packed words and sub-tile
    // tails are handled in the engine's MAC loops, so the worker (and
    // the billing it reports) sees only real words either way
    // (DESIGN.md §16).
    let mut scratch = crate::coordinator::engine::EngineScratch::new();
    let mut logits: Vec<Vec<i64>> = Vec::new();
    let mut rows_buf: Vec<Vec<i64>> = Vec::new();
    while let Ok(msg) = rx.recv() {
        let batch = match msg {
            WorkerMsg::Work(b) => b,
            WorkerMsg::Stop => break,
        };
        let t0 = Instant::now();
        // The variant this batch was tagged with at dispatch is the
        // variant that executes — and the variant that gets billed.
        let variant = batch.variant.min(engine.model().n_variants() - 1);
        let in_shift = engine.model().variant(variant).in_shift();
        // Gather rows into the reusable buffer (rows keep their
        // capacity; `n_rows` tracks the live prefix), requantizing
        // reference-precision request values into the executing
        // variant's first-layer format (arithmetic right shift — the
        // per-variant oracle applies the same transform), run packed,
        // scatter back per request.
        let mut n_rows = 0usize;
        for entry in &batch.entries {
            for row in &entry.req.rows {
                if n_rows == rows_buf.len() {
                    rows_buf.push(Vec::new());
                }
                rows_buf[n_rows].clear();
                if in_shift == 0 {
                    rows_buf[n_rows].extend_from_slice(row);
                } else {
                    rows_buf[n_rows].extend(row.iter().map(|&v| v >> in_shift));
                }
                n_rows += 1;
            }
        }
        let stats =
            engine.forward_batch_into(&rows_buf[..n_rows], variant, &mut scratch, &mut logits);
        let ns = t0.elapsed().as_nanos() as u64;
        // Exact per-format billing: with a mixed-precision schedule the
        // layers run at different widths, so the worker hands the cost
        // table the by-format cycle breakdown, not one format — and the
        // whole batch lands in the executed variant's metrics bucket.
        let pj = cost.batch_energy_pj(&stats);
        // The static cost certificate's prediction for this batch,
        // priced through the same table (DESIGN.md §15): a correct
        // certificate makes the predicted and measured figures agree to
        // the attojoule, and `report()` surfaces the delta.
        let predicted_pj = engine.model().cost_certificate(variant).energy_pj(n_rows, &cost);
        metrics.add_batch_predicted(n_rows as u64, variant, stats, pj, predicted_pj, ns);
        let mut responses = vec![];
        let mut offset = 0;
        for entry in &batch.entries {
            let n = entry.req.rows.len();
            responses.push(Response {
                id: entry.req.id,
                logits: logits[offset..offset + n].to_vec(),
                variant,
            });
            offset += n;
            metrics.observe_latency_ns(entry.submitted_at.elapsed().as_nanos() as u64);
        }
        outstanding_rows.fetch_sub(batch.rows, Ordering::SeqCst);
        outstanding_batches.fetch_sub(1, Ordering::SeqCst);
        if done.send((worker_id, responses)).is_err() {
            break; // leader gone
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::exec::mlp_forward_row;
    use crate::nn::weights::QuantLayer;
    use crate::testutil::{flat_cost as tiny_cost, random_dense_stack_uniform};
    use crate::workload::synth::XorShift64;

    fn layers(rng: &mut XorShift64) -> Vec<QuantLayer> {
        random_dense_stack_uniform(rng, &[8, 5, 3], 8)
    }

    #[test]
    fn coordinator_round_trip_matches_reference() {
        let mut rng = XorShift64::new(0xC00D);
        let ls = layers(&mut rng);
        let model = CompiledModel::compile(ls.clone(), 8, 16).unwrap();
        let mut coord = Coordinator::start(model, ServeConfig::new(2, 6), tiny_cost());
        let reqs: Vec<Request> = (0..9u64)
            .map(|id| Request {
                id,
                rows: (0..(1 + (id as usize % 3)))
                    .map(|_| (0..8).map(|_| rng.q_raw(8)).collect())
                    .collect(),
            })
            .collect();
        let expected: Vec<Vec<Vec<i64>>> = reqs
            .iter()
            .map(|r| r.rows.iter().map(|row| mlp_forward_row(row, &ls, 8, 16)).collect())
            .collect();
        for r in reqs {
            coord.submit(r).unwrap();
        }
        let responses = coord.drain().unwrap();
        assert_eq!(responses.len(), 9);
        for resp in &responses {
            assert_eq!(resp.logits, expected[resp.id as usize], "request {}", resp.id);
        }
        assert!(coord.metrics.subword_mults.load(Ordering::Relaxed) > 0);
        coord.shutdown();
    }

    #[test]
    fn mixed_precision_model_serves_bit_exactly() {
        use crate::nn::exec::mlp_forward_row_mixed;
        use crate::nn::weights::LayerPrecision;
        let mut rng = XorShift64::new(0x417C0DE);
        let ls = layers(&mut rng);
        // 4-bit first layer, 8-bit second — with a direct 8→8 bypass
        // boundary; requests arrive quantized at 4 bits.
        let sched = vec![LayerPrecision::new(4, 8), LayerPrecision::new(8, 16)];
        let model = CompiledModel::compile_scheduled(ls.clone(), sched.clone()).unwrap();
        let mut coord = Coordinator::start(model, ServeConfig::new(2, 6), tiny_cost());
        let reqs: Vec<Request> = (0..7u64)
            .map(|id| Request {
                id,
                rows: vec![(0..8).map(|_| rng.q_raw(4)).collect()],
            })
            .collect();
        for r in &reqs {
            coord.submit(r.clone()).unwrap();
        }
        let responses = coord.drain().unwrap();
        assert_eq!(responses.len(), 7);
        for resp in &responses {
            let want = mlp_forward_row_mixed(&reqs[resp.id as usize].rows[0], &ls, &sched);
            assert_eq!(resp.logits[0], want, "request {}", resp.id);
        }
        // An out-of-range 8-bit value is invalid against a 4-bit input
        // layer: the submit-time Q-range check tracks the schedule.
        let err = coord
            .submit(Request { id: 99, rows: vec![vec![100, 0, 0, 0, 0, 0, 0, 0]] })
            .expect_err("out of 4-bit range");
        assert!(err.to_string().contains("outside Q range"), "{err}");
        coord.shutdown();
    }

    #[test]
    fn batching_groups_requests() {
        let mut rng = XorShift64::new(0xBA7);
        let ls = layers(&mut rng);
        let model = CompiledModel::compile(ls, 8, 16).unwrap();
        // A generous deadline so the batcher, not the deadline thread,
        // forms the batches in this test.
        let cfg = ServeConfig::new(1, 12).deadline(Duration::from_secs(5));
        let mut coord = Coordinator::start(model, cfg, tiny_cost());
        for id in 0..12u64 {
            coord
                .submit(Request {
                    id,
                    rows: vec![(0..8).map(|_| rng.q_raw(8)).collect()],
                })
                .unwrap();
        }
        let responses = coord.drain().unwrap();
        assert_eq!(responses.len(), 12);
        let batches = coord.metrics.batches.load(Ordering::Relaxed);
        assert!(batches <= 2, "expected ≤2 batches, got {batches}");
        coord.shutdown();
    }

    #[test]
    fn poisoned_batcher_degrades_to_typed_errors_not_panics() {
        let mut rng = XorShift64::new(0xDEAD10);
        let ls = layers(&mut rng);
        let model = CompiledModel::compile(ls, 8, 16).unwrap();
        let cfg = ServeConfig::new(1, 4).deadline(Duration::from_secs(5));
        let mut coord = Coordinator::start(model, cfg, tiny_cost());
        // Poison the batcher lock: a thread panics while holding it.
        let shared = Arc::clone(&coord.shared);
        let _ = std::thread::spawn(move || {
            let _guard = shared.batcher.lock().unwrap();
            panic!("deliberate poison (test)");
        })
        .join();
        // Submits are refused with a typed error, not a propagated
        // panic…
        let req = Request { id: 1, rows: vec![vec![0i64; 8]] };
        match coord.submit(req) {
            Err(ServeError::LockPoisoned { what: "batcher", .. }) => {}
            other => panic!("expected LockPoisoned, got {other:?}"),
        }
        // …observability recovers the lock…
        assert_eq!(coord.pending_rows(), 0);
        // …drain surfaces the same condition, with whatever completed…
        match coord.drain() {
            Err(ServeError::LockPoisoned { what: "batcher", recovered }) => {
                assert!(recovered.is_empty());
            }
            other => panic!("expected LockPoisoned from drain, got {other:?}"),
        }
        // …and teardown still joins every thread.
        coord.shutdown();
    }

    #[test]
    fn round_robin_rotates_and_least_loaded_prefers_idle() {
        let mut rng = XorShift64::new(0xD15);
        let ls = layers(&mut rng);
        let model = CompiledModel::compile(ls, 8, 16).unwrap();
        for policy in [DispatchPolicy::RoundRobin, DispatchPolicy::LeastLoaded] {
            let cfg = ServeConfig::new(3, 1).policy(policy);
            let mut coord = Coordinator::start(Arc::clone(&model), cfg, tiny_cost());
            for id in 0..30u64 {
                coord
                    .submit(Request {
                        id,
                        rows: vec![(0..8).map(|_| rng.q_raw(8)).collect()],
                    })
                    .unwrap();
            }
            let responses = coord.drain().unwrap();
            assert_eq!(responses.len(), 30, "{policy:?}");
            coord.shutdown();
        }
    }
}
