//! Multi-word (host-vector) SWAR primitives — the `simd` execution
//! backend of the flat core (DESIGN.md §16).
//!
//! The paper's Soft SIMD already packs sub-words *inside* one 48-bit
//! word; this module packs **several such words across host vector
//! lanes** and executes a flat micro-op stream
//! ([`crate::csd::flat`]) on all of them per instruction. One [`Tile`]
//! is `TILE = 4` packed `u64` words — a 256-bit host vector, the widest
//! path stable x86 offers (AVX2); wider units compose by streaming
//! tiles back to back.
//!
//! Three implementations share one semantics:
//! * **portable** — safe unrolled-scalar loops over the tile, built
//!   from the same raw identities as [`crate::bits::swar`]
//!   (`add_wrapped`/`neg_wrapped`/`sar_with_sign`). The compiler
//!   autovectorizes the element-wise loops; this is the stable-Rust
//!   fallback and the only path on non-x86 hosts.
//! * **AVX2** — explicit `core::arch::x86_64` intrinsics behind
//!   run-time `is_x86_feature_detected!` dispatch, in the one narrowly
//!   `allow(unsafe_code)` module of the crate (see `lib.rs`).
//! * **`std::simd`** — under the nightly-only `simd-nightly` feature
//!   the portable implementation switches to `core::simd` vectors
//!   (`u64x4`); same element-wise identities, target-independent.
//!
//! Every function here is **bit-exact** against its scalar sibling
//! (property-tested below) and performs **no heap allocation**. None of
//! them carries `lanecheck` sanitizer hooks — the per-lane overflow
//! masks are defined word-at-a-time — so the engine forces the scalar
//! path under `--features lanecheck` via a compile-time `cfg` guard
//! (`coordinator::engine`). Billing never happens here either: callers
//! bill cycles from the micro-op stream itself, which is why the wide
//! backend cannot perturb `EngineStats` (DESIGN.md §16).

use super::format::{SimdFormat, WORD_MASK};
use super::swar::{add_wrapped, sar_with_sign, swar_relu};
#[cfg(not(feature = "simd-nightly"))]
use super::swar::neg_wrapped;
use crate::bits::fixed::{sign_extend, truncate};
use crate::csd::flat::{FLAT_ADD, FLAT_NEG, FLAT_SHIFT_MASK};
use crate::pipeline::stage2::convert_subword;

/// Packed words processed per vector instruction (`u64x4` — one AVX2
/// register; the portable path unrolls by the same factor so tails and
/// billing are backend-independent).
pub const TILE: usize = 4;

/// One tile of packed datapath words.
pub type Tile = [u64; TILE];

/// Which multi-word implementation executes. Opaque: the only
/// constructors are [`kernel`] (runtime detection) and
/// [`Kernel::portable`], so an `Avx2` kernel can exist only after
/// `is_x86_feature_detected!("avx2")` returned true — the safety
/// invariant the `avx2` module's safe wrappers rely on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Kernel(Which);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Which {
    Portable,
    #[cfg(target_arch = "x86_64")]
    Avx2,
}

impl Kernel {
    /// The portable (unrolled-scalar / `std::simd`) kernel — always
    /// available; the in-process reference the explicit paths are
    /// tested against.
    pub fn portable() -> Kernel {
        Kernel(Which::Portable)
    }

    /// Human-readable backend name (bench/report labels).
    pub fn name(self) -> &'static str {
        match self.0 {
            Which::Portable => {
                if cfg!(feature = "simd-nightly") {
                    "portable-simd"
                } else {
                    "portable"
                }
            }
            #[cfg(target_arch = "x86_64")]
            Which::Avx2 => "avx2",
        }
    }
}

/// The best kernel for this host, detected once per process. On x86-64
/// with AVX2 this is the intrinsics path; everywhere else the portable
/// tile kernel.
pub fn kernel() -> Kernel {
    static KERNEL: std::sync::OnceLock<Kernel> = std::sync::OnceLock::new();
    *KERNEL.get_or_init(detect)
}

/// Every kernel available on this host (tests sweep all of them).
pub fn kernels() -> Vec<Kernel> {
    let mut all = vec![Kernel::portable()];
    if kernel() != Kernel::portable() {
        all.push(kernel());
    }
    all
}

fn detect() -> Kernel {
    #[cfg(target_arch = "x86_64")]
    if std::arch::is_x86_feature_detected!("avx2") {
        return Kernel(Which::Avx2);
    }
    Kernel(Which::Portable)
}

/// Execute a flat micro-op slice on `TILE` packed multiplicand words at
/// once: the multi-word form of
/// [`crate::pipeline::stage1::Stage1::run_flat`], bit-exact per word
/// against it for any op stream produced by
/// [`crate::csd::flat::encode_plan`].
///
/// Counters are *not* kept here — [`Stage1::run_flat_tile`] bills the
/// executed op bytes itself, so the datapath cycle count stays the one
/// source of truth regardless of backend.
///
/// [`Stage1::run_flat_tile`]: crate::pipeline::stage1::Stage1::run_flat_tile
#[inline]
pub fn run_flat_tile(kern: Kernel, x: Tile, ops: &[u8], fmt: SimdFormat) -> Tile {
    match kern.0 {
        Which::Portable => portable::run_flat_tile(x, ops, fmt),
        #[cfg(target_arch = "x86_64")]
        Which::Avx2 => avx2::run_flat_tile(x, ops, fmt),
    }
}

/// Word-level ReLU over a whole accumulator stream — the vectorized
/// [`swar_relu`]: full tiles go through the wide kernel, the tail words
/// through the scalar primitive. Bit-exact against mapping `swar_relu`
/// over the slice.
#[inline]
pub fn relu_slice(kern: Kernel, words: &mut [u64], fmt: SimdFormat) {
    let mut chunks = words.chunks_exact_mut(TILE);
    for chunk in &mut chunks {
        let t: Tile = [chunk[0], chunk[1], chunk[2], chunk[3]];
        let r = match kern.0 {
            Which::Portable => portable::relu_tile(t, fmt),
            #[cfg(target_arch = "x86_64")]
            Which::Avx2 => avx2::relu_tile(t, fmt),
        };
        chunk.copy_from_slice(&r);
    }
    for w in chunks.into_remainder() {
        *w = swar_relu(*w, fmt);
    }
}

/// One scalar flat micro-op step without sanitizer hooks: the exact
/// per-word semantics of `Stage1::run_flat`'s loop body, with the
/// multiplicand's wrapped negation `nx` precomputed (it is loop
/// invariant — `x` never changes during a plan). The tile kernels run
/// this per vector lane. (Under `simd-nightly` the portable kernel is
/// the `core::simd` one instead, leaving this helper unreferenced.)
#[cfg_attr(feature = "simd-nightly", allow(dead_code))]
#[inline]
fn flat_step(acc: u64, x: u64, nx: u64, op: u8, fmt: SimdFormat) -> u64 {
    let k = (op & FLAT_SHIFT_MASK) as u32;
    let h = fmt.msb_mask();
    if op & FLAT_ADD != 0 {
        if op & FLAT_NEG == 0 {
            let w = add_wrapped(acc, x, fmt);
            if k == 0 {
                w
            } else {
                // Add overflow: operands agree in sign, sum does not.
                let ovf = !(acc ^ x) & (acc ^ w) & h;
                sar_with_sign(w, (w & h) ^ ovf, k, fmt)
            }
        } else {
            let w = add_wrapped(acc, nx, fmt);
            if k == 0 {
                w
            } else {
                // Subtract overflow is detected on the *original*
                // operand (`x`), not its negation — the lane-minimum
                // corner (`-2^(b-1)` negates to itself) makes the two
                // formulations differ; this matches `swar_sub_sar`.
                let ovf = (acc ^ x) & (acc ^ w) & h;
                sar_with_sign(w, (w & h) ^ ovf, k, fmt)
            }
        }
    } else {
        // Pure shift cycle (encoder guarantees k ≥ 1 here).
        sar_with_sign(acc, acc & h, k, fmt)
    }
}

/// Gather-vectorized [`repack_hop_into`]: one *direct* crossbar hop
/// over a whole packed stream, specialized to full output words. Every
/// output word except possibly the last has all `to.lanes()` sub-words
/// valid, so the gather runs branch-free (no per-lane bounds check) and
/// `TILE`-unrolled; only the final partial word takes the guarded
/// scalar path, with lanes past `count` packed as zero — bit-identical
/// to [`repack_hop_into`] (property-tested).
///
/// The hop is memory-gather-bound, so the win here is the branch-free
/// full-word inner loop the compiler can autovectorize, not explicit
/// intrinsics: sub-word extraction needs per-lane variable bit shifts,
/// which the portable form expresses directly.
///
/// [`repack_hop_into`]: crate::pipeline::stage2::repack_hop_into
pub fn repack_hop_tiles(
    src: &[u64],
    from: SimdFormat,
    to: SimdFormat,
    count: usize,
    dst: &mut Vec<u64>,
) {
    debug_assert!(
        crate::pipeline::stage2::is_direct(from, to),
        "{from}->{to} is not a direct crossbar hop"
    );
    debug_assert!(src.len() * from.lanes() as usize >= count, "source stream too short");
    dst.clear();
    let out_lanes = to.lanes() as usize;
    let in_lanes = from.lanes() as usize;
    let in_mask = (1u64 << from.bits) - 1;
    let out_words = count.div_ceil(out_lanes);
    let full_words = count / out_lanes;
    // Branch-free gather of one fully-valid output word.
    let gather_full = |ow: usize| -> u64 {
        let base = ow * out_lanes;
        let mut w = 0u64;
        for lane in 0..out_lanes {
            let idx = base + lane;
            let s = sign_extend(
                (src[idx / in_lanes] >> ((idx % in_lanes) as u32 * from.bits)) & in_mask,
                from.bits,
            );
            w |= truncate(convert_subword(s, from, to), to.bits) << (lane as u32 * to.bits);
        }
        w
    };
    let mut ow = 0usize;
    while ow + TILE <= full_words {
        let t: Tile = [
            gather_full(ow),
            gather_full(ow + 1),
            gather_full(ow + 2),
            gather_full(ow + 3),
        ];
        dst.extend_from_slice(&t);
        ow += TILE;
    }
    while ow < full_words {
        dst.push(gather_full(ow));
        ow += 1;
    }
    if full_words < out_words {
        // Final partial word: valid lanes gathered, the rest zero.
        let mut w = 0u64;
        for lane in 0..(count - full_words * out_lanes) {
            let idx = full_words * out_lanes + lane;
            let s = sign_extend(
                (src[idx / in_lanes] >> ((idx % in_lanes) as u32 * from.bits)) & in_mask,
                from.bits,
            );
            w |= truncate(convert_subword(s, from, to), to.bits) << (lane as u32 * to.bits);
        }
        dst.push(w);
    }
}

/// The portable tile kernel: safe element-wise loops over `[u64; TILE]`
/// that the compiler unrolls/autovectorizes on stable Rust; under the
/// nightly `simd-nightly` feature the same identities run on
/// `core::simd` `u64x4` vectors instead.
mod portable {
    use super::*;

    #[cfg(not(feature = "simd-nightly"))]
    pub(super) fn run_flat_tile(x: Tile, ops: &[u8], fmt: SimdFormat) -> Tile {
        let mut nx = [0u64; TILE];
        for (n, &xi) in nx.iter_mut().zip(x.iter()) {
            *n = neg_wrapped(xi, fmt);
        }
        let mut acc = [0u64; TILE];
        for &op in ops {
            for i in 0..TILE {
                acc[i] = flat_step(acc[i], x[i], nx[i], op, fmt);
            }
        }
        acc
    }

    #[cfg(feature = "simd-nightly")]
    pub(super) fn run_flat_tile(x: Tile, ops: &[u8], fmt: SimdFormat) -> Tile {
        nightly::run_flat_tile(x, ops, fmt)
    }

    pub(super) fn relu_tile(t: Tile, fmt: SimdFormat) -> Tile {
        let mut r = [0u64; TILE];
        for (dst, &w) in r.iter_mut().zip(t.iter()) {
            *dst = swar_relu(w, fmt);
        }
        r
    }
}

/// The nightly `core::simd` implementation of the portable kernel
/// (`--features simd-nightly`, requires a nightly toolchain for
/// `#![feature(portable_simd)]` — see `lib.rs`). Never built by CI;
/// kept bit-equation-identical to `flat_step` by construction.
#[cfg(feature = "simd-nightly")]
mod nightly {
    use super::*;
    use std::simd::Simd;

    const _: () = assert!(TILE == 4, "u64x4 vectors assume TILE == 4");
    type V = Simd<u64, 4>;

    #[inline]
    fn add_wrapped_v(a: V, c: V, h: V, nh: V, wm: V) -> V {
        (((a & nh) + (c & nh)) ^ ((a ^ c) & h)) & wm
    }

    #[inline]
    fn sar_v(w: V, signs: V, k: u32, keep: V) -> V {
        let mut fill = signs;
        let mut part = signs;
        for _ in 1..k {
            part = part >> V::splat(1);
            fill |= part;
        }
        ((w >> V::splat(k as u64)) & keep) | fill
    }

    pub(super) fn run_flat_tile(x: Tile, ops: &[u8], fmt: SimdFormat) -> Tile {
        let wm = V::splat(WORD_MASK);
        let h = V::splat(fmt.msb_mask());
        let nh = V::splat(WORD_MASK & !fmt.msb_mask());
        let lsb = V::splat(fmt.lsb_mask());
        let xv = V::from_array(x);
        // neg_wrapped(x): complement within the datapath, +1 at lane LSBs.
        let nxv = add_wrapped_v(xv ^ wm, lsb, h, nh, wm);
        let mut acc = V::splat(0);
        for &op in ops {
            let k = (op & FLAT_SHIFT_MASK) as u32;
            acc = if op & FLAT_ADD != 0 {
                let sub = op & FLAT_NEG != 0;
                let c = if sub { nxv } else { xv };
                let w = add_wrapped_v(acc, c, h, nh, wm);
                if k == 0 {
                    w
                } else {
                    let diff = if sub { acc ^ xv } else { !(acc ^ xv) };
                    let ovf = diff & (acc ^ w) & h;
                    sar_v(w, (w & h) ^ ovf, k, V::splat(fmt.keep_mask(k)))
                }
            } else {
                sar_v(acc, acc & h, k, V::splat(fmt.keep_mask(k)))
            };
        }
        acc.to_array()
    }
}

/// The explicit AVX2 path: the flat micro-op interpreter and word-level
/// ReLU on 256-bit vectors (`u64x4`), selected at run time by
/// [`kernel`].
///
/// **Unsafe allowlist entry** (see `lib.rs`): this module is the one
/// place outside `testutil::CountingAlloc` where `unsafe` is permitted,
/// and it contains exactly two kinds of unsafe — `#[target_feature
/// (enable = "avx2")]` functions built from stable Intel intrinsics,
/// and the safe wrappers' calls into them. The safety argument is
/// confinement: [`Kernel`] is opaque and `Which::Avx2` is only ever
/// constructed after `is_x86_feature_detected!("avx2")` succeeded, so
/// the target-feature functions cannot be reached on hardware without
/// AVX2. No raw pointers escape; loads/stores are the unaligned
/// `loadu`/`storeu` on stack arrays.
#[cfg(target_arch = "x86_64")]
#[allow(unsafe_code)]
mod avx2 {
    use super::*;
    use std::arch::x86_64::{
        __m256i, _mm256_add_epi64, _mm256_and_si256, _mm256_andnot_si256,
        _mm256_loadu_si256, _mm256_or_si256, _mm256_set1_epi64x, _mm256_srl_epi64,
        _mm256_storeu_si256, _mm256_xor_si256, _mm_cvtsi32_si128,
    };

    const _: () = assert!(TILE == 4, "__m256i tiles assume TILE == 4");

    /// Safe wrapper; see the module docs for the AVX2-availability
    /// invariant carried by [`Kernel`].
    pub(super) fn run_flat_tile(x: Tile, ops: &[u8], fmt: SimdFormat) -> Tile {
        // SAFETY: only reachable through `Which::Avx2`, which `detect`
        // constructs after `is_x86_feature_detected!("avx2")`.
        unsafe { run_flat_tile_impl(x, ops, fmt) }
    }

    /// Safe wrapper over the AVX2 word-level ReLU.
    pub(super) fn relu_tile(t: Tile, fmt: SimdFormat) -> Tile {
        // SAFETY: as `run_flat_tile`.
        unsafe { relu_tile_impl(t, fmt) }
    }

    /// `sar_with_sign` on a vector: OR together `signs >> j` for
    /// `j ∈ 0..k` (the sign-replication fill), then mask-and-merge.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn sar_v(w: __m256i, signs: __m256i, k: u32, keep: __m256i) -> __m256i {
        let one = _mm_cvtsi32_si128(1);
        let mut fill = signs;
        let mut part = signs;
        let mut j = 1;
        while j < k {
            part = _mm256_srl_epi64(part, one);
            fill = _mm256_or_si256(fill, part);
            j += 1;
        }
        let shifted = _mm256_srl_epi64(w, _mm_cvtsi32_si128(k as i32));
        _mm256_or_si256(_mm256_and_si256(shifted, keep), fill)
    }

    /// `add_wrapped` on a vector: kill carries at lane MSBs, add, then
    /// restore the true MSB sum — the scalar identity verbatim.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn add_wrapped_v(
        a: __m256i,
        c: __m256i,
        h: __m256i,
        nh: __m256i,
        wm: __m256i,
    ) -> __m256i {
        let sum = _mm256_add_epi64(_mm256_and_si256(a, nh), _mm256_and_si256(c, nh));
        let msb = _mm256_and_si256(_mm256_xor_si256(a, c), h);
        _mm256_and_si256(_mm256_xor_si256(sum, msb), wm)
    }

    #[target_feature(enable = "avx2")]
    unsafe fn run_flat_tile_impl(x: Tile, ops: &[u8], fmt: SimdFormat) -> Tile {
        let wm = _mm256_set1_epi64x(WORD_MASK as i64);
        let h = _mm256_set1_epi64x(fmt.msb_mask() as i64);
        let nh = _mm256_set1_epi64x((WORD_MASK & !fmt.msb_mask()) as i64);
        let lsb = _mm256_set1_epi64x(fmt.lsb_mask() as i64);
        let xv = _mm256_loadu_si256(x.as_ptr().cast());
        // neg_wrapped(x), loop-invariant: x ^ WORD_MASK == !x & WORD_MASK.
        let nxv = add_wrapped_v(_mm256_xor_si256(xv, wm), lsb, h, nh, wm);
        let mut acc = _mm256_set1_epi64x(0);
        for &op in ops {
            let k = (op & FLAT_SHIFT_MASK) as u32;
            acc = if op & FLAT_ADD != 0 {
                let sub = op & FLAT_NEG != 0;
                let c = if sub { nxv } else { xv };
                let w = add_wrapped_v(acc, c, h, nh, wm);
                if k == 0 {
                    w
                } else {
                    // Overflow on the *original* operand, as `flat_step`:
                    // add: !(acc^x) & (acc^w); sub: (acc^x) & (acc^w).
                    let ax = _mm256_xor_si256(acc, xv);
                    let aw = _mm256_xor_si256(acc, w);
                    let diff = if sub {
                        _mm256_and_si256(ax, aw)
                    } else {
                        _mm256_andnot_si256(ax, aw)
                    };
                    let ovf = _mm256_and_si256(diff, h);
                    let signs = _mm256_xor_si256(_mm256_and_si256(w, h), ovf);
                    let keep = _mm256_set1_epi64x(fmt.keep_mask(k) as i64);
                    sar_v(w, signs, k, keep)
                }
            } else {
                let keep = _mm256_set1_epi64x(fmt.keep_mask(k) as i64);
                sar_v(acc, _mm256_and_si256(acc, h), k, keep)
            };
        }
        let mut out = [0u64; TILE];
        _mm256_storeu_si256(out.as_mut_ptr().cast(), acc);
        out
    }

    /// `swar_relu` on a vector. AVX2 has no 64-bit multiply, so instead
    /// of the scalar's mask-spread-by-multiply this replicates each
    /// lane's sign bit downward by an OR-shift cascade (shift distances
    /// sum to `bits - 1`, so spreads never cross into the lane below),
    /// then clears the negative lanes.
    #[target_feature(enable = "avx2")]
    unsafe fn relu_tile_impl(t: Tile, fmt: SimdFormat) -> Tile {
        let h = _mm256_set1_epi64x(fmt.msb_mask() as i64);
        let a = _mm256_loadu_si256(t.as_ptr().cast());
        let mut mask = _mm256_and_si256(a, h);
        let mut covered = 1u32;
        while covered < fmt.bits {
            let s = covered.min(fmt.bits - covered);
            mask = _mm256_or_si256(mask, _mm256_srl_epi64(mask, _mm_cvtsi32_si128(s as i32)));
            covered += s;
        }
        // a & !mask: negative lanes (now full-lane masks) become zero.
        let r = _mm256_andnot_si256(mask, a);
        let mut out = [0u64; TILE];
        _mm256_storeu_si256(out.as_mut_ptr().cast(), r);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::csd::flat::encode_plan;
    use crate::csd::schedule::schedule_with;
    use crate::pipeline::stage1::Stage1;
    use crate::pipeline::stage2::{is_direct, repack_hop_into, repack_stream};
    use crate::workload::synth::XorShift64;

    fn random_tile(rng: &mut XorShift64) -> Tile {
        [rng.word(), rng.word(), rng.word(), rng.word()]
    }

    #[test]
    fn run_flat_tile_matches_scalar_run_flat_on_every_kernel() {
        // Every available kernel, every format, random CSD plans: the
        // tile interpreter must agree word-for-word with Stage1's
        // scalar loop (which is itself pinned against run_plan).
        let mut rng = XorShift64::new(0x51D0_0001);
        for kern in kernels() {
            for fmt in SimdFormat::all() {
                for ybits in [4u32, 8, fmt.bits] {
                    for _ in 0..60 {
                        let m = rng.q_raw(ybits);
                        let plan = schedule_with(m, ybits, 3);
                        let mut ops = Vec::new();
                        encode_plan(&plan, &mut ops);
                        let x = random_tile(&mut rng);
                        let got = run_flat_tile(kern, x, &ops, fmt);
                        let mut s1 = Stage1::new(fmt);
                        for (i, &xi) in x.iter().enumerate() {
                            assert_eq!(
                                got[i],
                                s1.run_flat(xi, &ops),
                                "kernel {} fmt {fmt} m {m} word {i}",
                                kern.name()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn relu_slice_matches_scalar_relu_including_tails() {
        let mut rng = XorShift64::new(0x51D0_0002);
        for kern in kernels() {
            for fmt in SimdFormat::all() {
                for len in [0usize, 1, 3, 4, 5, 8, 11] {
                    let mut words: Vec<u64> = (0..len).map(|_| rng.word()).collect();
                    let want: Vec<u64> =
                        words.iter().map(|&w| swar_relu(w, fmt)).collect();
                    relu_slice(kern, &mut words, fmt);
                    assert_eq!(words, want, "kernel {} fmt {fmt} len {len}", kern.name());
                }
            }
        }
    }

    #[test]
    fn repack_hop_tiles_matches_canonical_on_every_direct_pair() {
        // Full multi-tile streams, tile tails, partial final words and
        // the count-zero-padding contract — all against both the
        // canonical per-value repack and the scalar gather.
        let mut rng = XorShift64::new(0x51D0_0003);
        let mut wide = Vec::new();
        let mut scalar = Vec::new();
        for a in SimdFormat::all() {
            for b in SimdFormat::all() {
                if a == b || !is_direct(a, b) {
                    continue;
                }
                for n_words in [1usize, 4, 5, 9] {
                    let words: Vec<u64> = (0..n_words).map(|_| rng.word()).collect();
                    let full = n_words * a.lanes() as usize;
                    for count in [full, full - 1, full / 2 + 1, 1] {
                        repack_hop_tiles(&words, a, b, count, &mut wide);
                        assert_eq!(
                            wide,
                            repack_stream(&words, a, b, count),
                            "{a}->{b} count {count}"
                        );
                        repack_hop_into(&words, a, b, count, &mut scalar);
                        assert_eq!(wide, scalar, "{a}->{b} count {count} vs scalar");
                    }
                }
            }
        }
    }

    #[test]
    fn detected_kernel_is_stable_and_named() {
        assert_eq!(kernel(), kernel(), "detection must be cached");
        assert!(!kernel().name().is_empty());
        assert!(kernels().contains(&Kernel::portable()));
    }
}
