//! Deterministic synthetic workloads.
//!
//! `XorShift64` is bit-identical to `python/compile/model.py::XorShift`
//! so the Rust side regenerates the exact dataset the AOT model was
//! validated on — no files needed beyond the baked weights.

use crate::bits::fixed::to_q;

/// xorshift64 PRNG (Marsaglia), the repo-wide deterministic source.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    s: u64,
}

impl XorShift64 {
    pub fn new(seed: u64) -> Self {
        assert_ne!(seed, 0, "xorshift seed must be nonzero");
        XorShift64 { s: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.s = x;
        x
    }

    /// Uniform in [0, 1) from the top 53 bits (same as the Python mirror).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform raw value in the `Q1.(bits-1)` range.
    #[inline]
    pub fn q_raw(&mut self, bits: u32) -> i64 {
        crate::bits::fixed::sign_extend(self.next_u64() & ((1u64 << bits) - 1), bits)
    }

    /// A random 48-bit packed word.
    #[inline]
    pub fn word(&mut self) -> u64 {
        self.next_u64() & crate::bits::format::WORD_MASK
    }
}

/// The synthetic "digit glyph" dataset of the AOT model (10 classes of
/// 8×8 images; see `python/compile/model.py`).
pub struct Digits {
    pub templates: Vec<Vec<f64>>, // [classes][pixels]
    pub classes: usize,
    pub pixels: usize,
}

impl Digits {
    pub const TEMPLATE_SEED: u64 = 0xD161;

    pub fn new(classes: usize, pixels: usize, seed: u64) -> Self {
        let mut rng = XorShift64::new(seed);
        let templates = (0..classes)
            .map(|_| (0..pixels).map(|_| rng.uniform() * 2.0 - 1.0).collect())
            .collect();
        Digits { templates, classes, pixels }
    }

    /// The exact dataset the AOT model bakes (10 × 64, seed 0xD161).
    pub fn standard() -> Self {
        Digits::new(10, 64, Self::TEMPLATE_SEED)
    }

    /// Sample `n` noisy examples: returns (quantized Q1.7 rows, labels).
    /// Bit-identical to `model.sample_batch` + `quantize_inputs`.
    pub fn sample(&self, n: usize, noise: f64, seed: u64) -> (Vec<Vec<i64>>, Vec<usize>) {
        let mut rng = XorShift64::new(seed);
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let c = (rng.next_u64() % self.classes as u64) as usize;
            ys.push(c);
            let row: Vec<i64> = (0..self.pixels)
                .map(|p| {
                    let v = self.templates[c][p] + (rng.uniform() * 2.0 - 1.0) * noise;
                    to_q(v.clamp(-1.0, 1.0 - 1.0 / 128.0), 8)
                })
                .collect();
            xs.push(row);
        }
        (xs, ys)
    }
}

/// A labeled synthetic image-classification workload: per-class
/// template images of `cin` channels × `h`×`w` pixels, sampled with
/// additive noise — [`Digits`] generalized to multi-channel spatial
/// tensors, the input side of the Conv2D serving path (DESIGN.md §12).
/// Rows are flattened `[cin][h][w]`, the layout `nn::conv` consumes.
pub struct ImageSet {
    /// `[classes][cin·h·w]` float templates in [−1, 1).
    pub templates: Vec<Vec<f64>>,
    pub classes: usize,
    pub cin: usize,
    pub h: usize,
    pub w: usize,
}

impl ImageSet {
    pub fn new(classes: usize, cin: usize, h: usize, w: usize, seed: u64) -> Self {
        let mut rng = XorShift64::new(seed);
        let pixels = cin * h * w;
        let templates = (0..classes)
            .map(|_| (0..pixels).map(|_| rng.uniform() * 2.0 - 1.0).collect())
            .collect();
        ImageSet { templates, classes, cin, h, w }
    }

    /// The standard conv workload: 10 classes of 1×8×8 images — the
    /// [`Digits`] geometry reinterpreted as single-channel images (same
    /// seed, so the templates are the familiar glyphs).
    pub fn standard() -> Self {
        ImageSet::new(10, 1, 8, 8, Digits::TEMPLATE_SEED)
    }

    /// Flattened image length (`cin·h·w`), the serving row width.
    pub fn pixels(&self) -> usize {
        self.cin * self.h * self.w
    }

    /// Sample `n` noisy examples quantized to `Q1.(in_bits-1)`:
    /// returns (flattened rows, labels). The quantization width is a
    /// parameter so low-precision-first conv schedules can be fed at
    /// their native activation format.
    pub fn sample(
        &self,
        n: usize,
        noise: f64,
        seed: u64,
        in_bits: u32,
    ) -> (Vec<Vec<i64>>, Vec<usize>) {
        let mut rng = XorShift64::new(seed);
        let half = (1i64 << (in_bits - 1)) as f64;
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let c = (rng.next_u64() % self.classes as u64) as usize;
            ys.push(c);
            let row: Vec<i64> = (0..self.pixels())
                .map(|p| {
                    let v = self.templates[c][p] + (rng.uniform() * 2.0 - 1.0) * noise;
                    to_q(v.clamp(-1.0, 1.0 - 1.0 / half), in_bits)
                })
                .collect();
            xs.push(row);
        }
        (xs, ys)
    }
}

/// The standard synthetic CNN over [`ImageSet::standard`] images —
/// the image-classification scenario the conv serving path is
/// exercised on (eval sweep, engine bench, the `cnn_serve` example):
/// conv 1×8×8 → 4ch 3×3 s1 p1 (64 patch rows per image), conv 4ch →
/// 4ch 3×3 s2 p1 (16 patch rows), dense 64 → 10 logits.
///
/// Like [`synth_mlp_stack`], every output column is a *sparse sign
/// filter*: the three largest-magnitude taps of a seeded random draw,
/// snapped to ±0.25 (`±2^(w_bits-3)` raw), the rest zeroed. Sparsity
/// is load-bearing for the accumulator range, not cosmetic: each
/// nonzero tap's truncated product can reach a full negative ULP even
/// for tiny weights, so a dense random 3×3×4 = 36-tap patch at 4-bit
/// activations can wrap an 8-bit `Q1.7` accumulator no matter how
/// small the draws are. Three ±0.25 taps keep the worst-case partial
/// sums provably inside every schedule of the standard trio — the
/// static verifier (`analysis`, DESIGN.md §14) proves it per variant
/// and `eval verify` prints the margins.
pub fn synth_cnn_stack(seed: u64, w_bits: u32) -> Vec<crate::nn::conv::LayerOp> {
    use crate::nn::conv::{ConvLayer, ConvShape, LayerOp};
    use crate::nn::weights::QuantLayer;
    assert!(w_bits >= 4, "sparse sign filters need ±2^(w_bits-3) weights");
    let quarter = 1i64 << (w_bits - 3);
    let mut rng = XorShift64::new(seed);
    let mut mk = |k: usize, n: usize| {
        let raw: Vec<Vec<i64>> = (0..k)
            .map(|_| (0..n).map(|_| rng.q_raw(w_bits)).collect())
            .collect();
        let mut w = vec![vec![0i64; n]; k];
        for col in 0..n {
            let mut idx: Vec<usize> = (0..k).collect();
            idx.sort_by_key(|&i| (std::cmp::Reverse(raw[i][col].abs()), i));
            for &i in idx.iter().take(3.min(k)) {
                w[i][col] = if raw[i][col] >= 0 { quarter } else { -quarter };
            }
        }
        QuantLayer::new(w, w_bits)
    };
    let s1 = ConvShape { cin: 1, h: 8, w: 8, cout: 4, kh: 3, kw: 3, stride: 1, pad: 1 };
    let s2 = ConvShape { cin: 4, h: 8, w: 8, cout: 4, kh: 3, kw: 3, stride: 2, pad: 1 };
    let c1 = ConvLayer::new(mk(s1.patch_len(), s1.cout), s1).expect("valid shape");
    let c2 = ConvLayer::new(mk(s2.patch_len(), s2.cout), s2).expect("valid shape");
    let head = mk(s2.out_len(), 10);
    vec![LayerOp::Conv(c1), LayerOp::Conv(c2), LayerOp::Dense(head)]
}

/// The standard synthetic MLP workload over [`Digits::standard`]
/// glyphs — the dense companion of [`synth_cnn_stack`] and the
/// accuracy-bearing workload of the `eval autoscale` Pareto sweep
/// (DESIGN.md §13): a 64→10 *sparse sign matched filter* (each class's
/// three strongest template pixels at weight ±0.25) behind a ×0.5
/// diagonal 10→10 head that adds one more layer boundary for a
/// precision schedule to cross.
///
/// The construction is deliberate: ±0.25 and 0.5 are powers of two, so
/// every product is an exact arithmetic shift at *any* activation
/// width (no CSD approximation error muddying the precision
/// comparison), and a 3-tap correlation stays inside the wrapping
/// `Q1.(acc−1)` accumulator range at every supported format. Unlike
/// random weights, classification accuracy is therefore meaningful —
/// and degrades gracefully rather than catastrophically as the serving
/// precision drops, which is exactly the accuracy/energy trade the
/// autoscale governor exists to exploit.
pub fn synth_mlp_stack(w_bits: u32) -> Vec<crate::nn::conv::LayerOp> {
    use crate::nn::conv::LayerOp;
    use crate::nn::weights::QuantLayer;
    assert!(w_bits >= 4, "matched filter needs ±2^(w_bits-3) weights");
    let digits = Digits::standard();
    let quarter = 1i64 << (w_bits - 3);
    let mut w0 = vec![vec![0i64; digits.classes]; digits.pixels];
    for (c, template) in digits.templates.iter().enumerate() {
        let mut idx: Vec<usize> = (0..digits.pixels).collect();
        idx.sort_by(|&a, &b| {
            template[b].abs().partial_cmp(&template[a].abs()).expect("finite")
        });
        for &k in idx.iter().take(3) {
            w0[k][c] = if template[k] > 0.0 { quarter } else { -quarter };
        }
    }
    let head: Vec<Vec<i64>> = (0..digits.classes)
        .map(|i| {
            (0..digits.classes)
                .map(|j| if i == j { 1i64 << (w_bits - 2) } else { 0 })
                .collect()
        })
        .collect();
    vec![
        LayerOp::Dense(QuantLayer::new(w0, w_bits)),
        LayerOp::Dense(QuantLayer::new(head, w_bits)),
    ]
}

/// One phase of the fleet scenario's arrival trace (DESIGN.md §17):
/// how many submit rounds, how much work each tenant class offers per
/// round, and whether the phase quiesces (drains to empty) between
/// rounds or keeps its backlog — the knob that separates "light" from
/// "burst".
#[derive(Debug, Clone, Copy)]
pub struct BurstPhase {
    pub name: &'static str,
    /// Submit rounds in this phase.
    pub rounds: usize,
    /// Rows per interactive/standard request.
    pub fg_rows: usize,
    /// Bulk requests offered back-to-back per round per model — the
    /// excess above the bulk class's admission budget is shed.
    pub bulk_reqs: usize,
    /// Rows per bulk request.
    pub bulk_rows: usize,
    /// `true`: drain to empty after each round (light traffic).
    /// `false`: only tick and collect, keeping the backlog (burst).
    pub quiesce: bool,
}

/// The standard fleet acceptance trace: light → burst → light. The
/// light phases quiesce every round, so every class's queue is empty
/// at each admission decision; the burst offers several oversized bulk
/// requests back-to-back without quiescing, so the bulk class's
/// certified-drain budget deterministically sheds the excess while the
/// interactive class keeps its small paced batches flowing.
pub fn light_burst_light() -> Vec<BurstPhase> {
    vec![
        BurstPhase {
            name: "light-1",
            rounds: 12,
            fg_rows: 2,
            bulk_reqs: 1,
            bulk_rows: 4,
            quiesce: true,
        },
        BurstPhase {
            name: "burst",
            rounds: 12,
            fg_rows: 2,
            bulk_reqs: 3,
            bulk_rows: 16,
            quiesce: false,
        },
        BurstPhase {
            name: "light-2",
            rounds: 12,
            fg_rows: 2,
            bulk_reqs: 1,
            bulk_rows: 4,
            quiesce: true,
        },
    ]
}

/// A layer of a quantization scenario (Fig. 10 workloads): how many
/// multiplications at which operand widths.
#[derive(Debug, Clone, Copy)]
pub struct LayerSpec {
    pub mults: u64,
    pub x_bits: u32,
    pub y_bits: u32,
}

/// An application scenario: a named mix of per-layer bitwidths, used by
/// the Fig. 10 harness ("average energy per sub-word multiplication
/// across different scenarios").
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: &'static str,
    pub layers: Vec<LayerSpec>,
}

impl Scenario {
    /// The scenario set evaluated in `eval::fig10`: a uniformly-low-
    /// precision network, a mixed-precision CNN-like stack (robust early
    /// layers at 4–6 bits, sensitive late layers at 8–12), a
    /// conservative 8-bit network, and a high-precision pipeline.
    pub fn standard_set() -> Vec<Scenario> {
        vec![
            Scenario {
                name: "uniform-4b",
                layers: vec![LayerSpec { mults: 4096, x_bits: 4, y_bits: 4 }],
            },
            Scenario {
                name: "mixed-cnn",
                layers: vec![
                    LayerSpec { mults: 2048, x_bits: 4, y_bits: 4 },
                    LayerSpec { mults: 1024, x_bits: 6, y_bits: 6 },
                    LayerSpec { mults: 512, x_bits: 8, y_bits: 8 },
                    LayerSpec { mults: 256, x_bits: 12, y_bits: 12 },
                ],
            },
            Scenario {
                name: "uniform-8b",
                layers: vec![LayerSpec { mults: 4096, x_bits: 8, y_bits: 8 }],
            },
            Scenario {
                name: "hi-fi-16b",
                layers: vec![
                    LayerSpec { mults: 2048, x_bits: 16, y_bits: 16 },
                    LayerSpec { mults: 2048, x_bits: 12, y_bits: 12 },
                ],
            },
        ]
    }

    pub fn total_mults(&self) -> u64 {
        self.layers.iter().map(|l| l.mults).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xorshift_is_deterministic() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xorshift_matches_python_mirror() {
        // First three values for seed 0xD161 — pinned so the Python
        // mirror (model.XorShift) and this must agree forever.
        let mut rng = XorShift64::new(0xD161);
        let v1 = rng.next_u64();
        let v2 = rng.next_u64();
        // Recompute independently.
        let mut x = 0xD161u64;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        assert_eq!(v1, x);
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        assert_eq!(v2, x);
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = XorShift64::new(7);
        for _ in 0..1000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn digits_sample_shapes_and_range() {
        let d = Digits::standard();
        let (xs, ys) = d.sample(10, 0.3, 0xBA7C4);
        assert_eq!(xs.len(), 10);
        assert_eq!(ys.len(), 10);
        for row in &xs {
            assert_eq!(row.len(), 64);
            for &v in row {
                assert!((-128..=127).contains(&v));
            }
        }
        for &y in &ys {
            assert!(y < 10);
        }
    }

    #[test]
    fn image_set_samples_flattened_quantized_rows() {
        let im = ImageSet::standard();
        assert_eq!(im.pixels(), 64);
        for in_bits in [4u32, 8] {
            let half = 1i64 << (in_bits - 1);
            let (xs, ys) = im.sample(6, 0.3, 0xC4A5, in_bits);
            assert_eq!(xs.len(), 6);
            for row in &xs {
                assert_eq!(row.len(), 64);
                assert!(row.iter().all(|&v| (-half..half).contains(&v)), "{in_bits}b");
            }
            assert!(ys.iter().all(|&y| y < 10));
        }
        // Single-channel 8×8 templates match the Digits glyphs exactly.
        let d = Digits::standard();
        assert_eq!(im.templates, d.templates);
    }

    #[test]
    fn synth_cnn_stack_chains_and_ends_in_ten_logits() {
        let stack = synth_cnn_stack(0xC9A17, 8);
        assert_eq!(stack.len(), 3);
        assert_eq!(stack[0].in_len(), 64);
        for w in stack.windows(2) {
            assert_eq!(w[0].out_len(), w[1].in_len(), "flattened chaining");
        }
        assert_eq!(stack[2].out_len(), 10);
        assert_eq!(stack[0].patch_rows(), 64, "8×8 output pixels per image");
        assert_eq!(stack[1].patch_rows(), 16, "stride-2 4×4 output pixels");
        // Every output column of every layer is a 3-tap ±0.25 filter.
        for (li, op) in stack.iter().enumerate() {
            let w = op.weights();
            for n in 0..w.n {
                let taps: Vec<i64> =
                    (0..w.k).map(|k| w.w_raw[k][n]).filter(|&v| v != 0).collect();
                assert_eq!(taps.len(), 3, "layer {li} col {n}");
                assert!(taps.iter().all(|&v| v.abs() == 32), "layer {li} col {n}");
            }
        }
    }

    #[test]
    fn synth_mlp_stack_classifies_its_own_noisy_digits() {
        use crate::nn::exec::{argmax_class, stack_forward_row};
        use crate::nn::weights::uniform_schedule;
        let stack = synth_mlp_stack(8);
        assert_eq!(stack.len(), 2);
        assert_eq!(stack[0].in_len(), 64);
        assert_eq!(stack[1].out_len(), 10);
        // Each class's filter has exactly 3 taps, at ±0.25.
        let w0 = stack[0].weights();
        for c in 0..10 {
            let taps: Vec<i64> =
                (0..64).map(|k| w0.w_raw[k][c]).filter(|&v| v != 0).collect();
            assert_eq!(taps.len(), 3, "class {c}");
            assert!(taps.iter().all(|&v| v == 32 || v == -32), "class {c}");
        }
        // The matched filter classifies its own noisy samples well at
        // the hi-fi schedule (96/100 at this seed by construction).
        let sched = uniform_schedule(8, 16, 2);
        let d = Digits::standard();
        let (xs, ys) = d.sample(100, 0.3, 0xA5C4);
        let correct = xs
            .iter()
            .zip(&ys)
            .filter(|(x, &y)| argmax_class(&stack_forward_row(x, &stack, &sched), 10) == y)
            .count();
        assert!(correct >= 90, "matched filter got {correct}/100 at 8-bit");
    }

    #[test]
    fn scenarios_nonempty() {
        let set = Scenario::standard_set();
        assert_eq!(set.len(), 4);
        for s in set {
            assert!(s.total_mults() > 0);
        }
    }
}
