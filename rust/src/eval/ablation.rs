//! Ablations of the design choices the paper fixes without sweeping:
//!
//! * CSD vs plain-binary multiplier recoding (Section II-B's
//!   justification: CSD maximizes zero runs → fewer cycles).
//! * Shifter reach (max coalesced positions per cycle): the paper picks
//!   3 ("more extensive sequences … are rare and do not justify the
//!   additional logic").
//! * Stage-2 bypass (Section III-A): pipelines with format conversion
//!   disabled vs always-through.

use crate::anyhow;
use crate::bits::format::SimdFormat;

use crate::csd::schedule::{MulOp, MulPlan};
use crate::csd::stats::density_with;
use crate::energy::report::table;
use crate::pipeline::stage2::repack_cycles;

/// Binary (non-CSD) schedule: one add per set bit of the positive
/// magnitude + sign fixup — the recoding the paper replaces.
pub fn schedule_binary(m_raw: i64, y_bits: u32, max_shift: u32) -> MulPlan {
    // Two's-complement binary digits: value = Σ bit_j·2^-j − msb·2^0…
    // Use the straightforward signed-digit view: digits d_j ∈ {0,1}
    // except the top digit which weighs −1 (standard two's complement).
    let mut digits: Vec<i64> = (0..y_bits)
        .map(|j| (m_raw >> (y_bits - 1 - j)) & 1)
        .collect();
    if digits[0] == 1 {
        digits[0] = -1; // sign position
    }
    let nz: Vec<(u32, i8)> = (0..y_bits)
        .rev()
        .filter_map(|j| match digits[j as usize] {
            0 => None,
            d => Some((j, d as i8)),
        })
        .collect();
    let mut ops = vec![];
    for (idx, &(j, sign)) in nz.iter().enumerate() {
        if j == 0 {
            ops.push(MulOp::AddShift { shift: 0, sign });
            continue;
        }
        let t = nz.get(idx + 1).map(|&(tj, _)| tj).unwrap_or(0);
        let dist = j - t;
        let k = dist.min(max_shift);
        ops.push(MulOp::AddShift { shift: k, sign });
        let mut rem = dist - k;
        while rem > 0 {
            let s = rem.min(max_shift);
            ops.push(MulOp::Shift { shift: s });
            rem -= s;
        }
    }
    MulPlan { m_raw, y_bits, ops }
}

/// Mean cycles for binary recoding over all multipliers of a width.
pub fn binary_mean_cycles(y_bits: u32, max_shift: u32) -> f64 {
    let half = 1i64 << (y_bits - 1);
    let mut total = 0usize;
    for m in -half..half {
        total += schedule_binary(m, y_bits, max_shift).cycles();
    }
    total as f64 / (2 * half) as f64
}

pub fn run() -> anyhow::Result<()> {
    println!("== Ablation 1: CSD vs binary recoding (mean Stage-1 cycles) ==");
    let mut rows = vec![];
    for y in [4u32, 6, 8, 12, 16] {
        let csd = density_with(y, 3).mean_cycles;
        let bin = binary_mean_cycles(y, 3);
        rows.push(vec![
            format!("{y}-bit multiplier"),
            format!("{bin:.2}"),
            format!("{csd:.2}"),
            format!("{:.1}%", (1.0 - csd / bin) * 100.0),
        ]);
    }
    println!(
        "{}",
        table(&["multiplier width", "binary", "CSD", "cycle saving"], &rows)
    );

    println!("== Ablation 2: shifter reach (max coalesced positions/cycle) ==");
    let mut rows = vec![];
    for reach in 1..=5u32 {
        let mut cols = vec![format!("reach {reach}")];
        for y in [8u32, 16] {
            cols.push(format!("{:.2}", density_with(y, reach).mean_cycles));
        }
        // Extra shifter stages cost mux levels: reach r needs r stages.
        cols.push(format!("{} mux stages", reach));
        rows.push(cols);
    }
    println!(
        "{}",
        table(&["design", "cycles @8b", "cycles @16b", "shifter cost"], &rows)
    );
    let d3 = density_with(8, 3).mean_cycles;
    let d4 = density_with(8, 4).mean_cycles;
    println!(
        "reach 3→4 saves only {:.1}% cycles @8b — the paper's choice of 3 holds\n",
        (1.0 - d4 / d3) * 100.0
    );

    println!("== Ablation 3: Stage-2 bypass vs always-convert ==");
    let f8 = SimdFormat::new(8);
    let f16 = SimdFormat::new(16);
    let n = 64usize;
    let bypass = repack_cycles(n, f8, f8);
    let convert = repack_cycles(n, f8, f16);
    let chain = repack_cycles(n, f16, SimdFormat::new(4));
    println!("  {n} words same-format (bypass): {bypass} cycles");
    println!("  {n} words 8→16 (direct hop):    {convert} cycles");
    println!("  {n} words 16→4 (2-hop chain):   {chain} cycles\n");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::csd::encode::csd_encode;
    use crate::csd::schedule::schedule_with;
    use crate::pipeline::stage1::mul_scalar_plan;

    #[test]
    fn binary_schedule_is_correct() {
        // The binary plan must compute the same products as CSD.
        for m in -128i64..128 {
            let pb = schedule_binary(m, 8, 3);
            let pc = schedule_with(m, 8, 3);
            // Compare on a truncation-free multiplicand.
            let x = 1i64 << 20;
            let exact = |p: &MulPlan| {
                let mut acc: i64 = 0;
                for op in &p.ops {
                    match *op {
                        MulOp::Shift { shift } => acc >>= shift,
                        MulOp::AddShift { shift, sign } => {
                            acc += sign as i64 * x;
                            acc >>= shift;
                        }
                    }
                }
                acc
            };
            assert_eq!(exact(&pb), exact(&pc), "m={m}");
            let _ = mul_scalar_plan;
        }
    }

    #[test]
    fn csd_beats_binary_on_average() {
        for y in [8u32, 16] {
            let csd = density_with(y, 3).mean_cycles;
            let bin = binary_mean_cycles(y, 3);
            assert!(csd < bin, "y={y}: csd {csd} vs binary {bin}");
        }
    }

    #[test]
    fn reach_three_captures_most_of_the_benefit() {
        let d1 = density_with(8, 1).mean_cycles;
        let d3 = density_with(8, 3).mean_cycles;
        let d5 = density_with(8, 5).mean_cycles;
        // Reach 3 gets ≥80% of the cycle reduction available up to reach 5.
        let frac = (d1 - d3) / (d1 - d5);
        assert!(frac > 0.8, "frac {frac}");
    }

    #[test]
    fn csd_digit_density_claim() {
        // Section II-B: ~2/3 of CSD digits are zero.
        for y in [8u32, 16] {
            let half = 1i64 << (y - 1);
            let mut zeros = 0usize;
            let mut total = 0usize;
            for m in -half..half {
                let d = csd_encode(m, y);
                zeros += d.iter().filter(|&&x| x == crate::csd::encode::Digit::Z).count();
                total += d.len();
            }
            let frac = zeros as f64 / total as f64;
            assert!(frac > 0.6 && frac < 0.78, "y={y} zero fraction {frac}");
        }
    }
}
