//! Gate-level structural substrate — the stand-in for the paper's RTL +
//! 28nm synthesis flow (DESIGN.md §2).
//!
//! Every datapath block of both the Soft SIMD pipeline and the Hard SIMD
//! baselines is built as an explicit gate netlist (`build`), evaluated
//! with a levelized zero-delay simulator that counts per-cell output
//! toggles (`sim`), and characterized for depth (`timing`). The `energy`
//! module turns cell counts into µm² and toggle counts into pJ.

pub mod adder;
pub mod build;
pub mod crossbar;
pub mod gate;
pub mod multiplier;
pub mod shifter;
pub mod sim;
pub mod timing;

pub use build::NetBuilder;
pub use gate::{Cell, CellKind, Netlist, NodeId};
pub use sim::Simulator;
