//! Proof that the serving hot path is allocation-free in steady state
//! (DESIGN.md §11): after the first batch has warmed an
//! [`EngineScratch`], every subsequent `forward_batch_into` call on the
//! same shapes performs **zero** heap allocations.
//!
//! This lives in its own integration-test binary because the counting
//! `#[global_allocator]` is process-wide: a single `#[test]` runs every
//! scenario sequentially so no concurrent test can perturb the counter.
//!
//! [`EngineScratch`]: softsimd::coordinator::engine::EngineScratch

use softsimd::coordinator::engine::{EngineScratch, PackedEngine};
use softsimd::coordinator::model::CompiledModel;
use softsimd::nn::weights::{LayerPrecision, QuantLayer};
use softsimd::testutil::CountingAlloc;
use softsimd::workload::synth::XorShift64;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn random_layers(rng: &mut XorShift64, dims: &[usize]) -> Vec<QuantLayer> {
    dims.windows(2)
        .map(|w| {
            QuantLayer::new(
                (0..w[0])
                    .map(|_| (0..w[1]).map(|_| rng.q_raw(8)).collect())
                    .collect(),
                8,
            )
        })
        .collect()
}

/// Warm the scratch with one batch, then assert that `steady` further
/// batches of the same shape allocate nothing at all.
fn assert_steady_state_alloc_free(
    name: &str,
    layers: Vec<QuantLayer>,
    sched: Vec<LayerPrecision>,
    batch_rows: usize,
    rng: &mut XorShift64,
) {
    assert_steady_state_alloc_free_stack(
        name,
        layers.into_iter().map(softsimd::nn::conv::LayerOp::Dense).collect(),
        sched,
        batch_rows,
        rng,
    )
}

fn assert_steady_state_alloc_free_stack(
    name: &str,
    ops: Vec<softsimd::nn::conv::LayerOp>,
    sched: Vec<LayerPrecision>,
    batch_rows: usize,
    rng: &mut XorShift64,
) {
    let model = CompiledModel::compile_stack(ops, sched.clone()).unwrap();
    let engine = PackedEngine::new(model);
    let k0 = engine.model().input_width();
    let batch: Vec<Vec<i64>> = (0..batch_rows)
        .map(|_| (0..k0).map(|_| rng.q_raw(sched[0].in_bits)).collect())
        .collect();
    let mut scratch = EngineScratch::new();
    let mut out = Vec::new();
    // First batch: allowed (and expected) to allocate — it warms every
    // scratch buffer and the output rows.
    let warm_stats = engine.forward_batch_into(&batch, 0, &mut scratch, &mut out);
    let warm_out = out.clone();
    // Second and subsequent batches: zero allocations, bit-identical
    // results, identical billing.
    for i in 2..=6 {
        let before = CountingAlloc::count();
        let stats = engine.forward_batch_into(&batch, 0, &mut scratch, &mut out);
        let after = CountingAlloc::count();
        assert_eq!(
            after - before,
            0,
            "{name}: batch {i} performed {} heap allocation(s)",
            after - before
        );
        assert_eq!(out, warm_out, "{name}: batch {i} diverged");
        assert_eq!(stats.s1_cycles, warm_stats.s1_cycles, "{name}: billing drifted");
        assert_eq!(stats.subword_mults, warm_stats.subword_mults);
    }
}

#[test]
fn forward_batch_is_allocation_free_after_warmup() {
    let mut rng = XorShift64::new(0xA110C);

    // Uniform 8-8: every layer consumes and produces 8-bit sub-words
    // (the equal-width accumulate path, historically the worst
    // offender: one product Vec per weight-column pair).
    assert_steady_state_alloc_free(
        "uniform-8-8",
        random_layers(&mut rng, &[16, 12, 8]),
        vec![LayerPrecision::new(8, 8), LayerPrecision::new(8, 8)],
        24,
        &mut rng,
    );

    // Mixed 4-6-8: a 4-bit generic-widening layer (4→12), a 6-bit
    // doubling layer (6→12) and an 8-bit doubling layer (8→16), with
    // narrowing boundary hops 12→6 and 12→8 — every engine path plus
    // the batched word-level boundary repack.
    let mut rng2 = XorShift64::new(0xA110D);
    assert_steady_state_alloc_free(
        "mixed-4-6-8",
        random_layers(&mut rng2, &[16, 12, 8, 4]),
        vec![
            LayerPrecision::new(4, 12),
            LayerPrecision::new(6, 12),
            LayerPrecision::new(8, 16),
        ],
        24,
        &mut rng2,
    );

    // Conv schedule (DESIGN.md §12): the synthetic CNN — two im2col
    // gather stages (64 and 16 patch rows per image), two scalar-staged
    // boundaries through `fmap`, and the conv untranspose — must be
    // just as allocation-free once warmed as the dense paths above.
    let mut rng_c = XorShift64::new(0xA110F);
    assert_steady_state_alloc_free_stack(
        "conv-cnn-8-8-8",
        softsimd::workload::synth::synth_cnn_stack(0xA1110, 8),
        vec![
            LayerPrecision::new(8, 16),
            LayerPrecision::new(8, 16),
            LayerPrecision::new(8, 16),
        ],
        9,
        &mut rng_c,
    );
    // And a mixed-precision conv schedule: 4-bit first conv (doubling),
    // 6-bit second conv with a narrowing 8→6 boundary, 8-bit dense head
    // behind a 12→8 boundary.
    let mut rng_c2 = XorShift64::new(0xA1111);
    assert_steady_state_alloc_free_stack(
        "conv-cnn-4-6-8",
        softsimd::workload::synth::synth_cnn_stack(0xA1112, 8),
        vec![
            LayerPrecision::new(4, 8),
            LayerPrecision::new(6, 12),
            LayerPrecision::new(8, 16),
        ],
        9,
        &mut rng_c2,
    );

    // Varying batch sizes after warmup must also be allocation-free —
    // including shrink-then-grow, the normal load-dependent serving
    // pattern: a smaller batch parks its surplus warmed output rows in
    // the scratch and a later larger batch re-adopts them.
    let mut rng3 = XorShift64::new(0xA110E);
    let layers = random_layers(&mut rng3, &[10, 6, 4]);
    let sched = vec![LayerPrecision::new(8, 16), LayerPrecision::new(8, 16)];
    let model = CompiledModel::compile_scheduled(layers, sched).unwrap();
    let engine = PackedEngine::new(model);
    let big: Vec<Vec<i64>> = (0..24)
        .map(|_| (0..10).map(|_| rng3.q_raw(8)).collect())
        .collect();
    let mut scratch = EngineScratch::new();
    let mut out = Vec::new();
    engine.forward_batch_into(&big, 0, &mut scratch, &mut out);
    for &rows in &[6usize, 24, 1, 17, 24] {
        let before = CountingAlloc::count();
        engine.forward_batch_into(&big[..rows], 0, &mut scratch, &mut out);
        let after = CountingAlloc::count();
        assert_eq!(after - before, 0, "batch of {rows} rows allocated after warmup");
        assert_eq!(out.len(), rows);
    }

    // Run-time variant switching (DESIGN.md §13): a multi-variant model
    // served with one scratch. After one warm batch *per variant* (each
    // variant's lane occupancy sizes the buffers differently), any
    // interleaving of variants and batch sizes must allocate nothing —
    // the governor switches precision mid-stream, so a switch that
    // touched the allocator would put the hot path back on the heap.
    use softsimd::coordinator::model::VariantSpec;
    let mut rng4 = XorShift64::new(0xA1113);
    let layers = random_layers(&mut rng4, &[16, 12, 8, 4]);
    let ops: Vec<softsimd::nn::conv::LayerOp> =
        layers.into_iter().map(softsimd::nn::conv::LayerOp::Dense).collect();
    let model =
        CompiledModel::compile_variants(ops, VariantSpec::standard_trio(3)).unwrap();
    let n_variants = model.n_variants();
    let engine = PackedEngine::new(model);
    let mut scratch = EngineScratch::new();
    let mut out = Vec::new();
    // Reference-precision rows, requantized per variant exactly like
    // the serving loop does.
    let raw: Vec<Vec<i64>> = (0..24)
        .map(|_| (0..16).map(|_| rng4.q_raw(8)).collect())
        .collect();
    let quantize = |v: usize, rows: usize| -> Vec<Vec<i64>> {
        raw[..rows]
            .iter()
            .map(|r| engine.model().variant(v).quantize_row(r))
            .collect()
    };
    for v in 0..n_variants {
        let batch = quantize(v, 24);
        engine.forward_batch_into(&batch, v, &mut scratch, &mut out);
    }
    for &(v, rows) in &[(0usize, 24usize), (2, 12), (1, 24), (0, 5), (2, 24), (1, 1)] {
        let batch = quantize(v, rows);
        let before = CountingAlloc::count();
        engine.forward_batch_into(&batch, v, &mut scratch, &mut out);
        let after = CountingAlloc::count();
        assert_eq!(
            after - before,
            0,
            "variant {v} batch of {rows} rows allocated after warmup"
        );
        assert_eq!(out.len(), rows);
    }

    // Host-vector backend (`--features simd`, DESIGN.md §16): the wide
    // MAC tile loops, the vectorized boundary ReLU and the wide repack
    // must all run out of the same warmed scratch — zero steady-state
    // allocations through the wide entry point *and* the forced-scalar
    // baseline, interleaved on one scratch (the bench's differencing
    // pattern). The mixed 4-12 / 6-12 / 8-16 schedule covers all three
    // MAC paths; 96 rows gives every layer at least two full tiles
    // plus a tail word.
    #[cfg(feature = "simd")]
    {
        let mut rng5 = XorShift64::new(0xA1114);
        let layers = random_layers(&mut rng5, &[16, 12, 8, 4]);
        let sched = vec![
            LayerPrecision::new(4, 12),
            LayerPrecision::new(6, 12),
            LayerPrecision::new(8, 16),
        ];
        let model = CompiledModel::compile_scheduled(layers, sched).unwrap();
        let engine = PackedEngine::new(model);
        let batch: Vec<Vec<i64>> = (0..96)
            .map(|_| (0..16).map(|_| rng5.q_raw(4)).collect())
            .collect();
        let mut scratch = EngineScratch::new();
        let mut out = Vec::new();
        engine.forward_batch_into(&batch, 0, &mut scratch, &mut out);
        engine.forward_batch_into_scalar(&batch, 0, &mut scratch, &mut out);
        for &rows in &[96usize, 24, 1, 96] {
            let before = CountingAlloc::count();
            engine.forward_batch_into(&batch[..rows], 0, &mut scratch, &mut out);
            engine.forward_batch_into_scalar(&batch[..rows], 0, &mut scratch, &mut out);
            let after = CountingAlloc::count();
            assert_eq!(
                after - before,
                0,
                "simd backend: batch of {rows} rows allocated after warmup"
            );
            assert_eq!(out.len(), rows);
        }
    }
}
