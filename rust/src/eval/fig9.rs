//! Fig. 9 — energy gain (%) of Soft SIMD over (a) Hard SIMD
//! (4,6,8,12,16) and (b) Hard SIMD (8,16), sweeping the multiplicand
//! width 4..16 for multiplier widths {4, 8, 12, 16}, at 1 GHz.
//!
//! The paper highlights the discontinuities where the multiplicand
//! width crosses a Hard SIMD sub-word boundary (8→9 bits in panel b).

use crate::anyhow;
use crate::energy::model::SynthesizedSoftPipeline;
use crate::energy::report::table;
use crate::hardsimd::pipeline::{HardSimdPipeline, HARD_FLEX, HARD_TWO};
use crate::workload::synth::XorShift64;

pub const MHZ: f64 = 1000.0;
pub const N_WORDS: usize = 200;
pub const Y_SERIES: [u32; 4] = [4, 8, 12, 16];

/// gain[y][x-4] = 1 − soft/hard, or None when the baseline can't fit.
pub struct GainGrid {
    pub baseline: String,
    pub gains: Vec<Vec<Option<f64>>>,
}

pub fn grids() -> (GainGrid, GainGrid) {
    let mut soft = SynthesizedSoftPipeline::new(MHZ);
    let mut flex = HardSimdPipeline::new(HARD_FLEX, MHZ);
    let mut two = HardSimdPipeline::new(HARD_TWO, MHZ);
    let mut rng = XorShift64::new(0xF16_9);
    let mut g_flex = vec![];
    let mut g_two = vec![];
    for &y in &Y_SERIES {
        let mut row_f = vec![];
        let mut row_t = vec![];
        for x in 4..=16u32 {
            let s = soft.subword_mult_energy_pj(x, y, N_WORDS, &mut rng).unwrap();
            row_f.push(
                flex.subword_mult_energy_pj(x, y, N_WORDS, &mut rng)
                    .map(|h| 1.0 - s / h),
            );
            row_t.push(
                two.subword_mult_energy_pj(x, y, N_WORDS, &mut rng)
                    .map(|h| 1.0 - s / h),
            );
        }
        g_flex.push(row_f);
        g_two.push(row_t);
    }
    (
        GainGrid { baseline: "Hard SIMD (4,6,8,12,16)".into(), gains: g_flex },
        GainGrid { baseline: "Hard SIMD (8,16)".into(), gains: g_two },
    )
}

fn print_grid(g: &GainGrid) {
    println!("-- energy gain of Soft SIMD vs {} @1GHz --", g.baseline);
    let mut rows = vec![];
    for (yi, &y) in Y_SERIES.iter().enumerate() {
        let mut row = vec![format!("y={y}b")];
        for xi in 0..13 {
            row.push(match g.gains[yi][xi] {
                Some(v) => format!("{:.1}", v * 100.0),
                None => "-".into(),
            });
        }
        rows.push(row);
    }
    let mut headers: Vec<String> = vec!["mult\\x".into()];
    headers.extend((4..=16).map(|x| format!("{x}")));
    let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    println!("{}", table(&hdr_refs, &rows));
}

pub fn run() -> anyhow::Result<()> {
    println!("== Fig. 9: Soft SIMD energy gain (%) vs multiplicand width ==");
    let (a, b) = grids();
    print_grid(&a);
    print_grid(&b);
    // Quantify the 8→9 discontinuity on panel (b).
    let y8 = &b.gains[1];
    if let (Some(g8), Some(g9)) = (y8[4], y8[5]) {
        println!(
            "panel (b) discontinuity at multiplicand 8→9 (y=8): gain {:.1}% → {:.1}%\n",
            g8 * 100.0,
            g9 * 100.0
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_shape() {
        let (a, b) = grids();
        // Gains are large at small multiplicand widths...
        assert!(a.gains[0][0].unwrap() > 0.6, "4×4 vs flex");
        assert!(b.gains[0][0].unwrap() > 0.6, "4×4 vs two");
        // ...and positive-but-smaller at 16 (documented deviation:
        // the paper's crossover at 16×16 is not reproduced, see
        // DESIGN.md §5).
        let g16 = a.gains[3][12].unwrap();
        assert!(g16 < a.gains[0][0].unwrap());
        // Discontinuity: on panel (b), y=8 series jumps upward at x=9
        // (hard must switch from 8-bit to 16-bit lanes).
        let y8 = &b.gains[1];
        assert!(
            y8[5].unwrap() > y8[4].unwrap() + 0.02,
            "8→9 jump: {:?} -> {:?}",
            y8[4],
            y8[5]
        );
        // Flexible baseline loses by more than the lean one at the
        // smallest widths (its gating overhead dominates there).
        assert!(a.gains[0][0].unwrap() > b.gains[0][0].unwrap());
    }
}
