//! Packed MLP execution on a simulated PE.
//!
//! Layer semantics are pinned in DESIGN.md §4 and must match
//! `nn::exec::mlp_forward_row` bit-exactly — the integration tests
//! enforce it. The engine packs the *batch* dimension into sub-words:
//! every sample's activation `x[m][k]` for a fixed `k` shares the same
//! weight multiplier `w[k][n]`, which is exactly the "one multiplier,
//! several multiplicands" pattern of Section III-B.
//!
//! The engine owns no weights and compiles no plans: it executes a
//! shared immutable [`CompiledModel`] (DESIGN.md §8). Batches are padded
//! with zero rows up to the lane multiple (6 at 8-bit) so every packed
//! word runs full; pad rows are dropped before returning and tallied in
//! [`EngineStats::pad_rows`].

use std::sync::Arc;

use crate::bits::pack::{pack_stream, unpack_stream};
use crate::bits::swar::swar_add;
use crate::pipeline::stage1::Stage1;
use crate::pipeline::stage2::{repack_cycles_exact, repack_stream};

use super::model::CompiledModel;

/// Cycle/energy tallies of one engine run.
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    pub s1_cycles: u64,
    pub s2_passes: u64,
    pub acc_adds: u64,
    pub subword_mults: u64,
    /// Zero rows appended to fill the last packed word of the batch.
    pub pad_rows: u64,
}

/// A packed-execution engine bound to one PE, sharing one compiled model.
pub struct PackedMlpEngine {
    model: Arc<CompiledModel>,
}

impl PackedMlpEngine {
    /// Bind a PE to a shared compiled model. Cheap: no plan compilation
    /// and no weight copies happen here.
    pub fn new(model: Arc<CompiledModel>) -> Self {
        PackedMlpEngine { model }
    }

    pub fn model(&self) -> &CompiledModel {
        &self.model
    }

    /// Forward a batch (rows of `Q1.(in_bits-1)` raws) through all
    /// layers using packed arithmetic; returns final accumulators
    /// (`Q1.(acc_bits-1)`) per row, plus tallies.
    pub fn forward_batch(&self, batch: &[Vec<i64>]) -> (Vec<Vec<i64>>, EngineStats) {
        let model = &*self.model;
        let m = batch.len();
        assert!(m > 0, "empty batch");
        let in_fmt = model.in_fmt();
        let acc_fmt = model.acc_fmt();
        let in_bits = model.in_bits();
        let acc_bits = model.acc_bits();
        let lanes = model.lanes();
        // Pad the batch dimension to the lane multiple: packed words run
        // full and the accumulator stream has no partial final word.
        let mp = m.div_ceil(lanes) * lanes;
        let mut stats = EngineStats {
            pad_rows: (mp - m) as u64,
            ..EngineStats::default()
        };
        let layers = model.layers();
        // h[k][mp] activations, column-major for packing across batch.
        let mut h: Vec<Vec<i64>> = (0..batch[0].len())
            .map(|k| {
                let mut col: Vec<i64> = batch.iter().map(|row| row[k]).collect();
                col.resize(mp, 0);
                col
            })
            .collect();
        let mut s1 = Stage1::new(in_fmt);
        for (li, layer) in layers.iter().enumerate() {
            assert_eq!(h.len(), layer.k, "layer {li} input width");
            // Pack each activation column across the batch.
            let packed_cols: Vec<Vec<u64>> =
                h.iter().map(|col| pack_stream(col, in_fmt)).collect();
            let acc_words_per_n = (mp * acc_bits as usize).div_ceil(48);
            // Fast path: the accumulate format is exactly double the
            // input format (8→16 here) — use the SWAR widen instead of
            // the generic stream repack (DESIGN.md §9).
            let doubling = acc_bits == 2 * in_bits;
            let mut out_cols: Vec<Vec<i64>> = Vec::with_capacity(layer.n);
            let mut acc16 = vec![0u64; acc_words_per_n];
            for n in 0..layer.n {
                acc16.iter_mut().for_each(|w| *w = 0);
                for k in 0..layer.k {
                    let plan = model.plan(li, k, n);
                    if plan.ops.is_empty() {
                        continue; // zero weight: zero-skipped entirely
                    }
                    if doubling {
                        for (wi, &word) in packed_cols[k].iter().enumerate() {
                            let prod = s1.run_plan_on(word, plan);
                            let (lo, hi) = crate::pipeline::stage2::widen_double(prod, in_fmt);
                            // One accumulate add and one widen pass per
                            // produced output word — the hi word exists
                            // only when the accumulator stream extends
                            // that far (always, once the batch is padded
                            // to the lane multiple).
                            acc16[2 * wi] = swar_add(acc16[2 * wi], lo, acc_fmt);
                            stats.acc_adds += 1;
                            stats.s2_passes += 1;
                            if 2 * wi + 1 < acc16.len() {
                                acc16[2 * wi + 1] =
                                    swar_add(acc16[2 * wi + 1], hi, acc_fmt);
                                stats.acc_adds += 1;
                                stats.s2_passes += 1;
                            }
                        }
                    } else {
                        // Generic path through the canonical stream
                        // repack; Stage-2 passes are charged for the
                        // sub-words actually converted, chained hops
                        // included.
                        let mut products = Vec::with_capacity(packed_cols[k].len());
                        for &word in &packed_cols[k] {
                            products.push(s1.run_plan_on(word, plan));
                        }
                        let wide = repack_stream(&products, in_fmt, acc_fmt, mp);
                        stats.s2_passes += repack_cycles_exact(mp, in_fmt, acc_fmt);
                        for (w, &p) in acc16.iter_mut().zip(wide.iter()) {
                            *w = swar_add(*w, p, acc_fmt);
                            stats.acc_adds += 1;
                        }
                    }
                    stats.s1_cycles +=
                        plan.cycles() as u64 * packed_cols[k].len() as u64;
                    stats.subword_mults +=
                        in_fmt.lanes() as u64 * packed_cols[k].len() as u64;
                }
                out_cols.push(unpack_stream(&acc16, acc_fmt, mp));
            }
            if li + 1 < layers.len() {
                // ReLU + requantize (activation unit, scalar glue).
                h = out_cols
                    .iter()
                    .map(|col| {
                        col.iter()
                            .map(|&v| v.max(0) >> (acc_bits - in_bits))
                            .collect()
                    })
                    .collect();
            } else {
                // Transpose back to row-major, dropping the pad rows.
                let out: Vec<Vec<i64>> = (0..m)
                    .map(|b| out_cols.iter().map(|col| col[b]).collect())
                    .collect();
                return (out, stats);
            }
        }
        unreachable!("empty layer stack")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::exec::mlp_forward_row;
    use crate::nn::weights::QuantLayer;
    use crate::workload::synth::XorShift64;

    fn random_layers(rng: &mut XorShift64) -> Vec<QuantLayer> {
        let mk = |k: usize, n: usize, rng: &mut XorShift64| {
            QuantLayer::new(
                (0..k)
                    .map(|_| (0..n).map(|_| rng.q_raw(8)).collect())
                    .collect(),
                8,
            )
        };
        vec![mk(10, 6, rng), mk(6, 4, rng)]
    }

    #[test]
    fn packed_engine_matches_scalar_reference() {
        let mut rng = XorShift64::new(0xE8E8);
        let layers = random_layers(&mut rng);
        let model = CompiledModel::compile(layers.clone(), 8, 16);
        let engine = PackedMlpEngine::new(model);
        for batch_size in [1usize, 3, 6, 16, 17] {
            let batch: Vec<Vec<i64>> = (0..batch_size)
                .map(|_| (0..10).map(|_| rng.q_raw(8)).collect())
                .collect();
            let (got, stats) = engine.forward_batch(&batch);
            assert_eq!(got.len(), batch_size, "pad rows must be dropped");
            for (b, row) in batch.iter().enumerate() {
                let want = mlp_forward_row(row, &layers, 8, 16);
                assert_eq!(got[b], want, "batch row {b} (size {batch_size})");
            }
            assert!(stats.s1_cycles > 0);
            assert!(stats.s2_passes > 0);
            assert_eq!(
                stats.pad_rows as usize,
                batch_size.div_ceil(6) * 6 - batch_size
            );
        }
    }

    #[test]
    fn zero_weights_cost_nothing() {
        let layers = vec![QuantLayer::new(vec![vec![0, 64], vec![0, -32]], 8)];
        let engine = PackedMlpEngine::new(CompiledModel::compile(layers, 8, 16));
        let batch = vec![vec![100i64, -50], vec![25, 77]];
        let (_, stats) = engine.forward_batch(&batch);
        // Column n=0 is all-zero weights: only n=1's two weights run.
        let plan_cycles: u64 = [64i64, -32]
            .iter()
            .map(|&w| crate::csd::schedule::schedule(w, 8).cycles() as u64)
            .sum();
        assert_eq!(stats.s1_cycles, plan_cycles); // one packed word per column
    }

    #[test]
    fn stats_scale_with_batch_words() {
        let mut rng = XorShift64::new(0x57A7);
        let layers = random_layers(&mut rng);
        let engine = PackedMlpEngine::new(CompiledModel::compile(layers, 8, 16));
        let mk_batch = |n: usize, rng: &mut XorShift64| -> Vec<Vec<i64>> {
            (0..n).map(|_| (0..10).map(|_| rng.q_raw(8)).collect()).collect()
        };
        let (_, s6) = engine.forward_batch(&mk_batch(6, &mut rng));
        let (_, s12) = engine.forward_batch(&mk_batch(12, &mut rng));
        // 6 rows = 1 packed word per column; 12 rows = 2 words.
        assert_eq!(s12.s1_cycles, 2 * s6.s1_cycles);
        assert_eq!(s12.s2_passes, 2 * s6.s2_passes);
        assert_eq!(s12.acc_adds, 2 * s6.acc_adds);
    }

    #[test]
    fn stats_count_produced_acc_words_on_doubling_path() {
        // 1-layer 1×1 model, weight 64 (1-cycle plan): a 6-row batch
        // packs into one input word → two 16-bit accumulator words →
        // exactly 2 widen passes and 2 accumulate adds.
        let layers = vec![QuantLayer::new(vec![vec![64]], 8)];
        let engine = PackedMlpEngine::new(CompiledModel::compile(layers, 8, 16));
        let batch: Vec<Vec<i64>> = (0..6).map(|i| vec![i as i64 * 10 - 25]).collect();
        let (_, stats) = engine.forward_batch(&batch);
        assert_eq!(stats.acc_adds, 2);
        assert_eq!(stats.s2_passes, 2);
        // A 3-row batch pads to the same single full word: same tallies.
        let (_, s3) = engine.forward_batch(&batch[..3].to_vec());
        assert_eq!(s3.acc_adds, 2);
        assert_eq!(s3.s2_passes, 2);
        assert_eq!(s3.pad_rows, 3);
    }
}
