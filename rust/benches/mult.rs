//! Hot-path microbenchmarks: the packed multiply (the L3 request path's
//! inner loop), CSD scheduling, SWAR primitives, and repacking.

#[path = "benchkit.rs"]
mod benchkit;
use benchkit::{bench, throughput};

use softsimd::bits::format::SimdFormat;
use softsimd::bits::swar::{swar_add, swar_add_sar};
use softsimd::csd::schedule::schedule;
use softsimd::pipeline::stage1::{mul_packed, mul_scalar_plan, Stage1};
use softsimd::pipeline::stage2::repack_stream;
use softsimd::workload::synth::XorShift64;

fn main() {
    println!("== mult: packed-arithmetic hot paths ==");
    let fmt = SimdFormat::new(8);
    let mut rng = XorShift64::new(0xBE4C);
    let words: Vec<u64> = (0..1024).map(|_| rng.word()).collect();

    let mut acc = 0u64;
    let r = bench("swar_add 8b (1024 words)", 20, || {
        for &w in &words {
            acc = swar_add(acc, w, fmt);
        }
    });
    throughput(&r, 1024.0 * 6.0, "lane-adds");

    let r = bench("swar_add_sar k=3 (1024 words)", 20, || {
        for &w in &words {
            acc = swar_add_sar(acc, w, 3, fmt);
        }
    });
    throughput(&r, 1024.0 * 6.0, "lane-ops");

    let r = bench("csd schedule (256 multipliers, 8-bit)", 20, || {
        for m in -128i64..128 {
            std::hint::black_box(schedule(m, 8));
        }
    });
    throughput(&r, 256.0, "plans");

    // The inner loop of the coordinator: plan reuse + packed multiply.
    let plan = schedule(115, 8);
    let mut s1 = Stage1::new(fmt);
    let r = bench("packed mul via precompiled plan (1024 words)", 50, || {
        for &w in &words {
            s1.load_x(w);
            std::hint::black_box(s1.run_plan(&plan));
        }
    });
    throughput(&r, 1024.0 * 6.0, "subword-mults");

    let r = bench("mul_packed incl. scheduling (per word)", 20, || {
        std::hint::black_box(mul_packed(words[0], 115, 8, fmt));
    });
    throughput(&r, 6.0, "subword-mults");

    let r = bench("scalar oracle (per value)", 20, || {
        std::hint::black_box(mul_scalar_plan(100, &plan, 8));
    });
    throughput(&r, 1.0, "mults");

    let r = bench("repack_stream 8->16 (64 words)", 20, || {
        std::hint::black_box(repack_stream(&words[..64], fmt, SimdFormat::new(16), 384));
    });
    throughput(&r, 384.0, "subword-converts");
    std::hint::black_box(acc);
}
