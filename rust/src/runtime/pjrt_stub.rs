//! API-compatible stub for [`super::pjrt`] when the `pjrt` cargo feature
//! is disabled (the offline image carries no `xla` crate to execute the
//! AOT artifacts with). Every entry point that would touch PJRT returns
//! an error; shape/metadata helpers still work so callers can compile
//! unconditionally and probe availability at run time.

use std::path::{Path, PathBuf};

use crate::anyhow;
use crate::bits::format::SimdFormat;
use crate::runtime::manifest::Manifest;

const UNAVAILABLE: &str =
    "PJRT execution is unavailable: softsimd was built without the `pjrt` \
     cargo feature (the offline image has no `xla` crate). Rebuild with \
     `--features pjrt` and a vendored xla dependency; see DESIGN.md §7.";

/// Stub of the compiled artifact bundle. Never constructed: [`Engine::load`]
/// always fails in this build.
#[derive(Debug)]
pub struct Engine {
    pub manifest: Manifest,
    pub dir: PathBuf,
}

impl Engine {
    /// Always fails in a non-`pjrt` build (after validating that the
    /// artifact directory at least exists, for a friendlier message).
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Engine> {
        let _ = Manifest::load(dir.as_ref())?;
        anyhow::bail!("{UNAVAILABLE}")
    }

    /// Default artifact location relative to the crate root.
    pub fn default_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn platform(&self) -> String {
        "unavailable (built without the `pjrt` feature)".to_string()
    }

    /// See the real `Engine::mul_packed`; always fails in this build.
    pub fn mul_packed(
        &self,
        _words: &[u64],
        _m_raw: i64,
        _y_bits: u32,
        _fmt: SimdFormat,
    ) -> anyhow::Result<Vec<u64>> {
        anyhow::bail!("{UNAVAILABLE}")
    }

    /// See the real `Engine::mlp_forward`; always fails in this build.
    pub fn mlp_forward(&self, _x_q: &[i32]) -> anyhow::Result<Vec<i32>> {
        anyhow::bail!("{UNAVAILABLE}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature_or_artifacts() {
        let e = Engine::load(std::env::temp_dir().join("no_such_artifacts"))
            .unwrap_err()
            .to_string();
        // Either the manifest is absent (io error) or the stub refuses.
        assert!(!e.is_empty());
    }
}
