//! Hot-path microbenchmarks: the packed multiply (the L3 request path's
//! inner loop), CSD scheduling, SWAR primitives, and repacking — now
//! including the flattened micro-op path (`Stage1::run_flat`) the
//! serving engine executes (DESIGN.md §11).
//!
//! Every cell is also written to `BENCH_mult.json` (hand-rolled JSON —
//! serde is unavailable offline), mirroring `benches/coordinator.rs`,
//! so CI archives the micro-level perf trajectory next to the serving
//! numbers.

#[path = "benchkit.rs"]
mod benchkit;
use benchkit::{bench, throughput, write_cells, BenchResult};

use softsimd::bits::format::SimdFormat;
use softsimd::bits::swar::{swar_add, swar_add_sar, swar_relu};
use softsimd::csd::flat::encode_plan;
use softsimd::csd::schedule::schedule;
use softsimd::pipeline::stage1::{mul_packed, mul_scalar_plan, Stage1};
use softsimd::pipeline::stage2::{repack_hop_into, repack_stream};
use softsimd::workload::synth::XorShift64;

/// One measured cell, JSON-serializable.
struct Cell {
    name: String,
    ns_per_iter: f64,
    munits_per_s: f64,
    unit: &'static str,
}

impl Cell {
    fn measured(r: &BenchResult, units_per_iter: f64, unit: &'static str) -> Cell {
        Cell {
            name: r.name.clone(),
            ns_per_iter: r.ns_per_iter,
            munits_per_s: units_per_iter / (r.ns_per_iter * 1e-9) / 1e6,
            unit,
        }
    }

    fn json(&self) -> String {
        format!(
            "{{\"name\":\"{}\",\"ns_per_iter\":{:.2},\"munits_per_s\":{:.2},\"unit\":\"{}\"}}",
            self.name, self.ns_per_iter, self.munits_per_s, self.unit
        )
    }
}

fn main() {
    println!("== mult: packed-arithmetic hot paths ==");
    let fmt = SimdFormat::new(8);
    let mut rng = XorShift64::new(0xBE4C);
    let words: Vec<u64> = (0..1024).map(|_| rng.word()).collect();
    let mut cells: Vec<Cell> = vec![];

    let mut acc = 0u64;
    let r = bench("swar_add 8b (1024 words)", 20, || {
        for &w in &words {
            acc = swar_add(acc, w, fmt);
        }
    });
    throughput(&r, 1024.0 * 6.0, "lane-adds");
    cells.push(Cell::measured(&r, 1024.0 * 6.0, "lane-adds"));

    let r = bench("swar_add_sar k=3 (1024 words)", 20, || {
        for &w in &words {
            acc = swar_add_sar(acc, w, 3, fmt);
        }
    });
    throughput(&r, 1024.0 * 6.0, "lane-ops");
    cells.push(Cell::measured(&r, 1024.0 * 6.0, "lane-ops"));

    let r = bench("swar_relu 8b (1024 words)", 20, || {
        for &w in &words {
            acc = swar_relu(acc ^ w, fmt);
        }
    });
    throughput(&r, 1024.0 * 6.0, "lane-relus");
    cells.push(Cell::measured(&r, 1024.0 * 6.0, "lane-relus"));

    let r = bench("csd schedule (256 multipliers, 8-bit)", 20, || {
        for m in -128i64..128 {
            std::hint::black_box(schedule(m, 8));
        }
    });
    throughput(&r, 256.0, "plans");
    cells.push(Cell::measured(&r, 256.0, "plans"));

    // The inner loop of the coordinator: plan reuse + packed multiply,
    // first over the MulPlan form, then over the flat byte encoding the
    // serving engine actually executes.
    let plan = schedule(115, 8);
    let mut s1 = Stage1::new(fmt);
    let r = bench("packed mul via precompiled plan (1024 words)", 50, || {
        for &w in &words {
            s1.load_x(w);
            std::hint::black_box(s1.run_plan(&plan));
        }
    });
    throughput(&r, 1024.0 * 6.0, "subword-mults");
    cells.push(Cell::measured(&r, 1024.0 * 6.0, "subword-mults"));

    let mut flat = Vec::new();
    encode_plan(&plan, &mut flat);
    let r = bench("packed mul via flat micro-ops (1024 words)", 50, || {
        for &w in &words {
            std::hint::black_box(s1.run_flat(w, &flat));
        }
        s1.reset_counters();
    });
    throughput(&r, 1024.0 * 6.0, "subword-mults");
    cells.push(Cell::measured(&r, 1024.0 * 6.0, "subword-mults"));

    let r = bench("mul_packed incl. scheduling (per word)", 20, || {
        std::hint::black_box(mul_packed(words[0], 115, 8, fmt));
    });
    throughput(&r, 6.0, "subword-mults");
    cells.push(Cell::measured(&r, 6.0, "subword-mults"));

    let r = bench("scalar oracle (per value)", 20, || {
        std::hint::black_box(mul_scalar_plan(100, &plan, 8));
    });
    throughput(&r, 1.0, "mults");
    cells.push(Cell::measured(&r, 1.0, "mults"));

    let r = bench("repack_stream 8->16 (64 words)", 20, || {
        std::hint::black_box(repack_stream(&words[..64], fmt, SimdFormat::new(16), 384));
    });
    throughput(&r, 384.0, "subword-converts");
    cells.push(Cell::measured(&r, 384.0, "subword-converts"));

    let mut dst = Vec::new();
    let r = bench("repack_hop_into 8->16 (64 words)", 20, || {
        repack_hop_into(&words[..64], fmt, SimdFormat::new(16), 384, &mut dst);
        std::hint::black_box(&dst);
    });
    throughput(&r, 384.0, "subword-converts");
    cells.push(Cell::measured(&r, 384.0, "subword-converts"));
    std::hint::black_box(acc);

    let cell_json: Vec<String> = cells.iter().map(Cell::json).collect();
    write_cells("mult", "BENCH_mult.json", &cell_json);
}
