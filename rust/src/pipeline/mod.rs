//! Cycle-accurate functional model of the two-stage Soft SIMD pipeline
//! (Fig. 2): Stage 1 — shift-add arithmetic; Stage 2 — data repacking.

pub mod core;
pub mod stage1;
pub mod stage2;
pub mod trace;

pub use self::core::{PipelineSim, RunResult};
pub use stage1::{mul_packed, mul_scalar, Stage1};
pub use stage2::{conversion_chain, repack_hop_into, repack_stream, repack_word, Stage2};
pub use trace::{CycleEvent, Trace};
