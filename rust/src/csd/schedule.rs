//! Digit → cycle scheduling for the sequential Soft SIMD multiplier
//! (Section III-B, Fig. 3).
//!
//! Digits are processed least-significant first (descending position
//! `j`, weight `2^-j`). Each clock cycle retires one nonzero digit plus
//! up to `MAX_SHIFT − 1` zero positions above it as a fused
//! add-then-shift (`acc ← (acc ± X) >> k`, the "10"/"100" patterns of
//! Section III-B); zero runs longer than the shifter's reach become
//! pure-shift cycles. The digit at position 0 (weight `2^0`) is retired
//! with no trailing shift (`k = 0`).
//!
//! Zero-skipping: digit positions *below* the least-significant nonzero
//! digit would shift an all-zero accumulator, so the controller skips
//! them outright — they cost no cycles at all. A zero multiplier costs
//! zero cycles.

use super::encode::{csd_encode, Digit};
use crate::bits::format::MAX_SHIFT;

/// One Stage-1 cycle of a multiplication.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MulOp {
    /// `acc ← (acc + X·sign) >>_arith shift`. `shift = 0` only for the
    /// final position-0 digit (plain add, no shift).
    AddShift { shift: u32, sign: i8 },
    /// `acc ← acc >>_arith shift` (zero-run cycle), `shift ∈ 1..=MAX`.
    Shift { shift: u32 },
}

impl MulOp {
    pub fn shift(self) -> u32 {
        match self {
            MulOp::AddShift { shift, .. } | MulOp::Shift { shift } => shift,
        }
    }
    pub fn is_add(self) -> bool {
        matches!(self, MulOp::AddShift { .. })
    }
}

/// A complete cycle-schedule for one multiplier value.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MulPlan {
    /// Raw two's-complement multiplier the plan was derived from.
    pub m_raw: i64,
    /// Multiplier bitwidth (`Q1.(y_bits-1)`).
    pub y_bits: u32,
    /// Cycle operations, in issue order.
    pub ops: Vec<MulOp>,
}

impl MulPlan {
    /// Number of Stage-1 cycles the multiplication takes.
    pub fn cycles(&self) -> usize {
        self.ops.len()
    }

    /// Number of add/sub cycles (the rest are pure shifts).
    pub fn adds(&self) -> usize {
        self.ops.iter().filter(|o| o.is_add()).count()
    }

    /// Total shift distance — equals the position (weight `2^-j`) of the
    /// least-significant nonzero digit: every processed position below
    /// the top is crossed by exactly one shift unit.
    pub fn total_shift(&self) -> u32 {
        self.ops.iter().map(|o| o.shift()).sum()
    }
}

/// Build the cycle schedule for multiplier `m_raw` at width `y_bits`,
/// with per-cycle shifter reach `max_shift` (the paper's design point is
/// 3; the ablation harness sweeps it).
pub fn schedule_with(m_raw: i64, y_bits: u32, max_shift: u32) -> MulPlan {
    assert!(max_shift >= 1);
    let digits = csd_encode(m_raw, y_bits); // MSB-first: digits[j] has weight 2^-j
    // Nonzero positions, processed in descending order (LSB side first).
    let nz: Vec<(u32, i8)> = (0..y_bits)
        .rev()
        .filter_map(|j| match digits[j as usize] {
            Digit::Z => None,
            Digit::P => Some((j, 1i8)),
            Digit::N => Some((j, -1i8)),
        })
        .collect();
    let mut ops = Vec::with_capacity(nz.len() + 2);
    for (idx, &(j, sign)) in nz.iter().enumerate() {
        if j == 0 {
            // Weight-2^0 digit: plain add, no trailing shift.
            ops.push(MulOp::AddShift { shift: 0, sign });
            continue;
        }
        // After this add the accumulator must move down j − t positions
        // before the next retired digit (or the final resting position 0).
        let t = nz.get(idx + 1).map(|&(tj, _)| tj).unwrap_or(0);
        let dist = j - t;
        let k = dist.min(max_shift);
        ops.push(MulOp::AddShift { shift: k, sign });
        let mut rem = dist - k;
        while rem > 0 {
            let s = rem.min(max_shift);
            ops.push(MulOp::Shift { shift: s });
            rem -= s;
        }
    }
    MulPlan { m_raw, y_bits, ops }
}

/// Build the cycle schedule at the paper's design point (`max_shift = 3`).
pub fn schedule(m_raw: i64, y_bits: u32) -> MulPlan {
    schedule_with(m_raw, y_bits, MAX_SHIFT)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Exact (unbounded-precision) replay of a plan: with the multiplicand
    /// scaled so shifts never truncate, the plan must compute exactly
    /// `x · m / 2^(y-1)`.
    fn exact_eval(plan: &MulPlan, x: i128) -> i128 {
        let mut acc: i128 = 0;
        for op in &plan.ops {
            match *op {
                MulOp::Shift { shift } => acc >>= shift,
                MulOp::AddShift { shift, sign } => {
                    acc += sign as i128 * x;
                    acc >>= shift;
                }
            }
        }
        acc
    }

    #[test]
    fn plans_compute_exact_products() {
        for y in [4u32, 6, 8] {
            let half = 1i64 << (y - 1);
            for m in -half..half {
                let plan = schedule(m, y);
                let x: i128 = 12345i128 << 32; // headroom: shifts stay exact
                assert_eq!(
                    exact_eval(&plan, x),
                    (x * m as i128) >> (y - 1),
                    "m={m} y={y}"
                );
            }
        }
    }

    #[test]
    fn total_shift_is_lowest_nonzero_position() {
        for y in [4u32, 8, 16] {
            let half = 1i64 << (y - 1);
            let mut m = -half;
            while m < half {
                let plan = schedule(m, y);
                if m == 0 {
                    assert_eq!(plan.cycles(), 0, "0 multiplier costs nothing");
                } else {
                    let digits = csd_encode(m, y);
                    let lowest_nz = (0..y)
                        .rev()
                        .find(|&j| !matches!(digits[j as usize], Digit::Z))
                        .unwrap();
                    assert_eq!(plan.total_shift(), lowest_nz, "m={m} y={y}");
                }
                m += if y == 16 { 37 } else { 1 };
            }
        }
    }

    #[test]
    fn shifts_bounded_and_zero_only_on_final_add() {
        for m in -128i64..128 {
            let plan = schedule(m, 8);
            for (i, op) in plan.ops.iter().enumerate() {
                match *op {
                    MulOp::Shift { shift } => assert!(shift >= 1 && shift <= 3),
                    MulOp::AddShift { shift, .. } => {
                        assert!(shift <= 3);
                        if shift == 0 {
                            assert_eq!(i, plan.ops.len() - 1, "k=0 only final, m={m}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn add_count_equals_nonzero_digits() {
        for m in -128i64..128 {
            let plan = schedule(m, 8);
            let digits = csd_encode(m, 8);
            let nz = digits.iter().filter(|d| !matches!(d, Digit::Z)).count();
            assert_eq!(plan.adds(), nz, "m={m}");
        }
    }

    #[test]
    fn paper_example_few_adds() {
        // Fig. 3's multiplier 0.1110011 (raw 115 @ Q1.7, "01110011 before
        // CSD"): plain binary needs 5 add cycles; CSD needs ≤4 and the
        // whole multiplication fits in ≤5 cycles thanks to coalescing.
        let plan = schedule(115, 8);
        assert!(plan.adds() <= 4, "adds = {}", plan.adds());
        assert!(plan.cycles() <= 5, "cycles = {}", plan.cycles());
    }

    #[test]
    fn cycles_monotone_in_max_shift() {
        for m in -128i64..128 {
            let c1 = schedule_with(m, 8, 1).cycles();
            let c2 = schedule_with(m, 8, 2).cycles();
            let c3 = schedule_with(m, 8, 3).cycles();
            let c4 = schedule_with(m, 8, 4).cycles();
            assert!(c1 >= c2 && c2 >= c3 && c3 >= c4, "m={m}");
        }
    }

    #[test]
    fn minus_one_is_single_add_cycle() {
        // m = −1.0: CSD "-0000000" → one AddShift{0, −} cycle: acc = −X.
        let plan = schedule(-128, 8);
        assert_eq!(plan.ops, vec![MulOp::AddShift { shift: 0, sign: -1 }]);
    }

    #[test]
    fn max_shift_one_still_exact() {
        for m in [-128i64, -37, -1, 1, 64, 115, 127] {
            let plan = schedule_with(m, 8, 1);
            let x: i128 = 999i128 << 32;
            assert_eq!(exact_eval(&plan, x), (x * m as i128) >> 7);
        }
    }
}
