//! Shared test/bench instrumentation and model-construction scaffolds.
//!
//! The `random_*` builders and the [`engine_for`]/[`flat_cost`]
//! constructors are the one home of the "random stack + schedule +
//! `CompiledModel::compile*(..).unwrap()`" scaffolding that used to be
//! copy-pasted across the engine unit tests and every serving
//! integration test — one implementation, so every test generates
//! models the same way.
//!
//! [`CountingAlloc`] is a counting wrapper around the system allocator
//! used by both the zero-allocation integration test
//! (`tests/alloc_free.rs`) and the engine benchmark
//! (`benches/engine.rs`) — one implementation, so the proof and the
//! reported `allocs_per_batch` always measure the same thing. The
//! consuming binary installs it process-wide:
//!
//! ```ignore
//! use softsimd::testutil::CountingAlloc;
//! #[global_allocator]
//! static GLOBAL: CountingAlloc = CountingAlloc;
//! ```
//!
//! Allocations, zeroed allocations and reallocs are counted;
//! deallocations are free — releasing warmed capacity is never the bug
//! the counter hunts (DESIGN.md §11).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::bits::format::FORMATS;
use crate::coordinator::cost::CostTable;
use crate::coordinator::engine::PackedEngine;
use crate::coordinator::model::CompiledModel;
use crate::nn::conv::{ConvLayer, ConvShape};
use crate::nn::weights::{LayerPrecision, QuantLayer};
use crate::workload::synth::XorShift64;

/// A deterministic random `k×n` quantized dense layer at `bits`-wide
/// weights — the one weight-matrix generator every engine/serving test
/// used to hand-roll.
pub fn random_dense(rng: &mut XorShift64, k: usize, n: usize, bits: u32) -> QuantLayer {
    QuantLayer::new(
        (0..k)
            .map(|_| (0..n).map(|_| rng.q_raw(bits)).collect())
            .collect(),
        bits,
    )
}

/// A chain of dense layers along `dims` (`dims.len() - 1` layers), one
/// weight width per layer.
pub fn random_dense_stack(
    rng: &mut XorShift64,
    dims: &[usize],
    w_bits: &[u32],
) -> Vec<QuantLayer> {
    assert_eq!(dims.len(), w_bits.len() + 1, "one width per layer");
    dims.windows(2)
        .zip(w_bits)
        .map(|(w, &b)| random_dense(rng, w[0], w[1], b))
        .collect()
}

/// [`random_dense_stack`] with one uniform weight width.
pub fn random_dense_stack_uniform(
    rng: &mut XorShift64,
    dims: &[usize],
    bits: u32,
) -> Vec<QuantLayer> {
    let w_bits = vec![bits; dims.len() - 1];
    random_dense_stack(rng, dims, &w_bits)
}

/// A random *valid* conv geometry over `cin` input channels (small
/// spatial sizes, stride 1–2, padding below the kernel).
pub fn random_conv_shape(rng: &mut XorShift64, cin: usize) -> ConvShape {
    loop {
        let h = 3 + (rng.next_u64() % 4) as usize;
        let w = 3 + (rng.next_u64() % 4) as usize;
        let kh = 1 + (rng.next_u64() % 3) as usize;
        let kw = 1 + (rng.next_u64() % 3) as usize;
        let stride = 1 + (rng.next_u64() % 2) as usize;
        let pad = (rng.next_u64() % kh.min(kw) as u64) as usize;
        let shape = ConvShape {
            cin,
            h,
            w,
            cout: 1 + (rng.next_u64() % 3) as usize,
            kh,
            kw,
            stride,
            pad,
        };
        if shape.validate().is_ok() {
            return shape;
        }
    }
}

/// A conv layer with random weights over a given geometry.
pub fn random_conv_for_shape(
    rng: &mut XorShift64,
    shape: ConvShape,
    w_bits: u32,
) -> ConvLayer {
    let w = random_dense(rng, shape.patch_len(), shape.cout, w_bits);
    ConvLayer::new(w, shape).expect("validated shape")
}

/// A conv layer with both geometry and weights randomized.
pub fn random_conv_layer(rng: &mut XorShift64, cin: usize, w_bits: u32) -> ConvLayer {
    let shape = random_conv_shape(rng, cin);
    random_conv_for_shape(rng, shape, w_bits)
}

/// A random *valid* precision pair: any Soft SIMD activation width with
/// an accumulator at least as wide.
pub fn random_precision(rng: &mut XorShift64) -> LayerPrecision {
    let in_bits = FORMATS[(rng.next_u64() % FORMATS.len() as u64) as usize];
    let wider: Vec<u32> = FORMATS.iter().copied().filter(|&b| b >= in_bits).collect();
    let acc_bits = wider[(rng.next_u64() % wider.len() as u64) as usize];
    LayerPrecision::new(in_bits, acc_bits)
}

/// A random valid schedule, one [`random_precision`] pair per layer.
pub fn random_schedule(rng: &mut XorShift64, n_layers: usize) -> Vec<LayerPrecision> {
    (0..n_layers).map(|_| random_precision(rng)).collect()
}

/// A random batch: `rows` rows of `width` raws at `in_bits`.
pub fn random_batch(
    rng: &mut XorShift64,
    rows: usize,
    width: usize,
    in_bits: u32,
) -> Vec<Vec<i64>> {
    (0..rows)
        .map(|_| (0..width).map(|_| rng.q_raw(in_bits)).collect())
        .collect()
}

/// `CompiledModel::compile_scheduled(..).unwrap()` + engine binding —
/// the ubiquitous test scaffold, in one place instead of ~10 copies.
pub fn engine_for(layers: Vec<QuantLayer>, sched: Vec<LayerPrecision>) -> PackedEngine {
    PackedEngine::new(compiled_for(layers, sched))
}

/// The `.unwrap()`ed scheduled compile alone, for tests that also need
/// the shared `Arc`.
pub fn compiled_for(
    layers: Vec<QuantLayer>,
    sched: Vec<LayerPrecision>,
) -> Arc<CompiledModel> {
    CompiledModel::compile_scheduled(layers, sched).expect("valid test model")
}

/// Uniform-precision shorthand for [`engine_for`].
pub fn engine_uniform(layers: Vec<QuantLayer>, in_bits: u32, acc_bits: u32) -> PackedEngine {
    PackedEngine::new(
        CompiledModel::compile(layers, in_bits, acc_bits).expect("valid test model"),
    )
}

/// The flat-rate cost table every serving test used to re-declare
/// inline: 1 pJ per Stage-1 cycle at every format, 0.5 pJ per Stage-2
/// pass — simple enough that expected energies are mental arithmetic.
pub fn flat_cost() -> CostTable {
    CostTable {
        mhz: 1000.0,
        s1_cycle_pj: FORMATS.iter().map(|&b| (b, 1.0)).collect(),
        s2_pass_pj: 0.5,
        area_um2: 1000.0,
    }
}

/// Process-wide allocation counter backing [`CountingAlloc`].
pub static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// When false, the allocator skips the counter RMW entirely (one
/// relaxed bool load per allocation remains). Benchmarks disable
/// counting around *timed* sections so an allocation-heavy baseline is
/// not taxed with an atomic RMW per allocation, which would inflate
/// measured speedups; the zero-allocation proof keeps it enabled.
pub static COUNT_ENABLED: AtomicBool = AtomicBool::new(true);

/// Counting `#[global_allocator]` shim over [`System`].
pub struct CountingAlloc;

impl CountingAlloc {
    /// Current allocation count (monotonic while counting is enabled).
    pub fn count() -> u64 {
        ALLOCS.load(Ordering::SeqCst)
    }

    /// Enable/disable counting (see [`COUNT_ENABLED`]).
    pub fn set_counting(on: bool) {
        COUNT_ENABLED.store(on, Ordering::SeqCst);
    }
}

#[inline]
fn note() {
    if COUNT_ENABLED.load(Ordering::Relaxed) {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
    }
}

// The one `unsafe` exception to the crate-root `deny(unsafe_code)`:
// `GlobalAlloc` is an unsafe trait, so a counting allocator cannot be
// written without it. The impl only bumps an atomic and forwards every
// call verbatim to `System`.
#[allow(unsafe_code)]
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        note();
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        note();
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        note();
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}
