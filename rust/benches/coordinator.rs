//! Coordinator serving benchmarks: packed-engine layer throughput and
//! the full submit→batch→PE→drain loop, comparing round-robin vs
//! least-outstanding-rows dispatch at several PE counts.
//!
//! The serving comparison reports rows/sec and p50/p99 request latency
//! per (policy, PE count) cell. The workload is deliberately skewed
//! (most requests are 1 row, a few are 24-row bulks) — the case where
//! blind round-robin parks small requests behind bulks and load-aware
//! routing should win.

#[path = "benchkit.rs"]
mod benchkit;
use benchkit::{bench, throughput};

use std::sync::Arc;

use softsimd::coordinator::cost::CostTable;
use softsimd::coordinator::engine::PackedMlpEngine;
use softsimd::coordinator::model::CompiledModel;
use softsimd::coordinator::server::{
    Coordinator, DispatchPolicy, Request, ServeConfig,
};
use softsimd::nn::weights::QuantLayer;
use softsimd::workload::synth::XorShift64;

fn model_layers(rng: &mut XorShift64) -> Vec<QuantLayer> {
    let mk = |k: usize, n: usize, rng: &mut XorShift64| {
        QuantLayer::new(
            (0..k).map(|_| (0..n).map(|_| rng.q_raw(8)).collect()).collect(),
            8,
        )
    };
    vec![mk(64, 32, rng), mk(32, 16, rng)]
}

/// Skewed open-loop workload: ~1/8 of requests are 24-row bulks.
fn workload(rng: &mut XorShift64, n: usize) -> Vec<Request> {
    (0..n)
        .map(|id| {
            let rows = if rng.next_u64() % 8 == 0 { 24 } else { 1 };
            Request {
                id: id as u64,
                rows: (0..rows)
                    .map(|_| (0..64).map(|_| rng.q_raw(8)).collect())
                    .collect(),
            }
        })
        .collect()
}

fn main() {
    println!("== coordinator: packed NN serving ==");
    let mut rng = XorShift64::new(0xC0BE);
    let layers = model_layers(&mut rng);
    let mults_per_row: u64 = layers.iter().map(|l| (l.k * l.n) as u64).sum();
    let model = CompiledModel::compile(layers, 8, 16);

    // Engine-only: packed forward of a 12-row batch on the shared model.
    let engine = PackedMlpEngine::new(Arc::clone(&model));
    let batch: Vec<Vec<i64>> = (0..12)
        .map(|_| (0..64).map(|_| rng.q_raw(8)).collect())
        .collect();
    let r = bench("PackedMlpEngine forward (12-row batch)", 60, || {
        std::hint::black_box(engine.forward_batch(&batch));
    });
    throughput(&r, (12 * mults_per_row) as f64, "subword-mults");

    let cost = CostTable {
        mhz: 1000.0,
        s1_cycle_pj: softsimd::bits::format::FORMATS.iter().map(|&b| (b, 1.0)).collect(),
        s2_pass_pj: 0.5,
        area_um2: 4600.0,
    };

    // Full coordinator loop: policy × PE-count grid on a skewed stream.
    let reqs = workload(&mut rng, 256);
    let total_rows: usize = reqs.iter().map(|r| r.rows.len()).sum();
    println!(
        "\n== dispatch policy comparison ({} requests, {} rows, skewed sizes) ==",
        reqs.len(),
        total_rows
    );
    println!(
        "{:<14} {:>4} {:>12} {:>12} {:>12}",
        "policy", "PEs", "rows/s", "p50 us", "p99 us"
    );
    for &policy in &[DispatchPolicy::RoundRobin, DispatchPolicy::LeastLoaded] {
        for &n_pes in &[2usize, 4] {
            let cfg = ServeConfig::new(n_pes, 12).policy(policy);
            let mut coord =
                Coordinator::start(Arc::clone(&model), cfg, cost.clone());
            for req in &reqs {
                coord.submit(req.clone()).expect("live workers");
            }
            let responses = coord.drain().expect("drain");
            assert_eq!(responses.len(), reqs.len());
            let p50 = coord.metrics.latency_quantile_ns(0.50).unwrap_or(0) as f64 / 1e3;
            let p99 = coord.metrics.latency_quantile_ns(0.99).unwrap_or(0) as f64 / 1e3;
            println!(
                "{:<14} {:>4} {:>12.0} {:>12.1} {:>12.1}",
                match policy {
                    DispatchPolicy::RoundRobin => "round-robin",
                    DispatchPolicy::LeastLoaded => "least-loaded",
                },
                n_pes,
                coord.metrics.rows_per_sec(),
                p50,
                p99
            );
            coord.shutdown();
        }
    }

    // The classic single-cell timing view, for regression tracking.
    let rows: Vec<Vec<i64>> = (0..96)
        .map(|_| (0..64).map(|_| rng.q_raw(8)).collect())
        .collect();
    let r = bench("coordinator submit+drain (96 requests, 2 PEs)", 120, || {
        let mut coord = Coordinator::start(
            Arc::clone(&model),
            ServeConfig::new(2, 12),
            cost.clone(),
        );
        for (id, row) in rows.iter().enumerate() {
            coord
                .submit(Request { id: id as u64, rows: vec![row.clone()] })
                .expect("live workers");
        }
        std::hint::black_box(coord.drain().expect("drain"));
        coord.shutdown();
    });
    throughput(&r, (96 * mults_per_row) as f64, "subword-mults");
}
