//! Serving-engine microbenchmarks: the allocation-free flat execution
//! core against the pre-refactor per-op engine, on the same
//! `CompiledModel`, same machine, same run (DESIGN.md §11).
//!
//! The pre-refactor engine is preserved verbatim in [`baseline`] (it
//! only uses public APIs: `MulPlan` tables, `Stage1::run_plan_on`,
//! `pack_stream`/`unpack_stream`, `repack_stream`, per-value boundary
//! conversion) so every cell reports an honest speedup measured in the
//! same process. Outputs are cross-checked bit-exact before timing.
//!
//! Every cell is written to `BENCH_engine.json` (hand-rolled JSON —
//! serde is unavailable offline): rows/s, ns per useful sub-word
//! multiply, steady-state allocations per batch (counted by a process
//! `#[global_allocator]`), and the speedup over the baseline.

#[path = "benchkit.rs"]
mod benchkit;
use benchkit::{bench, write_cells};

use std::sync::Arc;

use softsimd::coordinator::engine::{EngineScratch, PackedEngine};
use softsimd::coordinator::model::CompiledModel;
use softsimd::nn::weights::{LayerPrecision, QuantLayer};
use softsimd::testutil::CountingAlloc;
use softsimd::workload::synth::XorShift64;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// The pre-refactor packed engine, kept as the measured baseline: heap
/// `Vec`s per layer/column/weight, `MulPlan` enum dispatch in the inner
/// loop, and the scalar per-value boundary conversion.
mod baseline {
    use softsimd::bits::pack::{pack_stream, unpack_stream};
    use softsimd::bits::swar::swar_add;
    use softsimd::coordinator::model::CompiledModel;
    use softsimd::pipeline::stage1::Stage1;
    use softsimd::pipeline::stage2::{
        convert_subword, repack_cycles_exact, repack_stream, widen_double,
    };

    /// Work tallies the baseline produces (the subset the bench needs).
    #[derive(Default)]
    pub struct Tally {
        pub s1_cycles: u64,
        pub subword_mults: u64,
        pub s2_passes: u64,
    }

    pub fn forward_batch(model: &CompiledModel, batch: &[Vec<i64>]) -> (Vec<Vec<i64>>, Tally) {
        let m = batch.len();
        assert!(m > 0, "empty batch");
        let quantum = model.batch_quantum();
        let mp = m.div_ceil(quantum) * quantum;
        let mut tally = Tally::default();
        let layers = model.layers();
        let mut h: Vec<Vec<i64>> = (0..batch[0].len())
            .map(|k| {
                let mut col: Vec<i64> = batch.iter().map(|row| row[k]).collect();
                col.resize(mp, 0);
                col
            })
            .collect();
        let mut s1 = Stage1::new(model.precision(0).in_fmt());
        for (li, layer) in layers.iter().enumerate() {
            let layer = layer.weights();
            let prec = model.precision(li);
            let (in_fmt, acc_fmt) = (prec.in_fmt(), prec.acc_fmt());
            let (in_bits, acc_bits) = (prec.in_bits, prec.acc_bits);
            s1.set_fmt(in_fmt);
            let packed_cols: Vec<Vec<u64>> =
                h.iter().map(|col| pack_stream(col, in_fmt)).collect();
            let acc_words_per_n = (mp * acc_bits as usize).div_ceil(48);
            let doubling = acc_bits == 2 * in_bits;
            let mut out_cols: Vec<Vec<i64>> = Vec::with_capacity(layer.n);
            let mut acc = vec![0u64; acc_words_per_n];
            for n in 0..layer.n {
                acc.iter_mut().for_each(|w| *w = 0);
                for k in 0..layer.k {
                    let plan = model.plan(li, k, n);
                    if plan.ops.is_empty() {
                        continue;
                    }
                    if doubling {
                        for (wi, &word) in packed_cols[k].iter().enumerate() {
                            let prod = s1.run_plan_on(word, plan);
                            let (lo, hi) = widen_double(prod, in_fmt);
                            acc[2 * wi] = swar_add(acc[2 * wi], lo, acc_fmt);
                            tally.s2_passes += 1;
                            if 2 * wi + 1 < acc.len() {
                                acc[2 * wi + 1] = swar_add(acc[2 * wi + 1], hi, acc_fmt);
                                tally.s2_passes += 1;
                            }
                        }
                    } else {
                        let mut products = Vec::with_capacity(packed_cols[k].len());
                        for &word in &packed_cols[k] {
                            products.push(s1.run_plan_on(word, plan));
                        }
                        let wide = if in_fmt == acc_fmt {
                            products
                        } else {
                            tally.s2_passes += repack_cycles_exact(mp, in_fmt, acc_fmt);
                            repack_stream(&products, in_fmt, acc_fmt, mp)
                        };
                        for (w, &p) in acc.iter_mut().zip(wide.iter()) {
                            *w = swar_add(*w, p, acc_fmt);
                        }
                    }
                    tally.s1_cycles += plan.cycles() as u64 * packed_cols[k].len() as u64;
                    tally.subword_mults += m as u64;
                }
                out_cols.push(unpack_stream(&acc, acc_fmt, mp));
            }
            if li + 1 < layers.len() {
                let chain = model.boundary_chain(li);
                h = out_cols
                    .iter()
                    .map(|col| {
                        col.iter()
                            .map(|&v| {
                                let mut x = v.max(0);
                                for &(f, t) in chain {
                                    x = convert_subword(x, f, t);
                                }
                                x
                            })
                            .collect()
                    })
                    .collect();
                for &(_, t) in chain {
                    let passes = (mp * t.bits as usize).div_ceil(48) as u64;
                    tally.s2_passes += passes * layer.n as u64;
                }
            } else {
                let out: Vec<Vec<i64>> = (0..m)
                    .map(|b| out_cols.iter().map(|col| col[b]).collect())
                    .collect();
                return (out, tally);
            }
        }
        unreachable!("compile rejects empty layer stacks")
    }
}

/// One measured cell, JSON-serializable. `rows_per_s` is whatever
/// backend `forward_batch_into` resolves to (`backend` names it);
/// `scalar_core_rows_per_s` is the same engine forced onto the scalar
/// core in the same process, and `simd_speedup` is their ratio — 1.0
/// when the crate is built without the `simd` feature. The
/// pre-refactor `baseline_rows_per_s`/`speedup` pair is unchanged.
struct Cell {
    schedule: &'static str,
    batch: usize,
    backend: &'static str,
    rows_per_s: f64,
    ns_per_subword_mult: f64,
    allocs_per_batch: f64,
    baseline_rows_per_s: f64,
    speedup: f64,
    scalar_core_rows_per_s: f64,
    simd_speedup: f64,
}

impl Cell {
    fn json(&self) -> String {
        format!(
            "{{\"schedule\":\"{}\",\"batch\":{},\"backend\":\"{}\",\
             \"rows_per_s\":{:.1},\
             \"ns_per_subword_mult\":{:.3},\"allocs_per_batch\":{:.2},\
             \"baseline_rows_per_s\":{:.1},\"speedup\":{:.2},\
             \"scalar_core_rows_per_s\":{:.1},\"simd_speedup\":{:.2}}}",
            self.schedule,
            self.batch,
            self.backend,
            self.rows_per_s,
            self.ns_per_subword_mult,
            self.allocs_per_batch,
            self.baseline_rows_per_s,
            self.speedup,
            self.scalar_core_rows_per_s,
            self.simd_speedup
        )
    }
}

fn model_layers(rng: &mut XorShift64) -> Vec<QuantLayer> {
    [(64usize, 48usize), (48, 32), (32, 16)]
        .iter()
        .map(|&(k, n)| {
            QuantLayer::new(
                (0..k).map(|_| (0..n).map(|_| rng.q_raw(8)).collect()).collect(),
                8,
            )
        })
        .collect()
}

fn main() {
    println!("== engine: flat allocation-free core vs pre-refactor baseline ==");
    // Counting is opt-in per measurement; timed cells run untaxed.
    CountingAlloc::set_counting(false);
    let mut rng = XorShift64::new(0xE9E1);
    let layers = model_layers(&mut rng);
    let schedules: [(&'static str, Vec<LayerPrecision>); 3] = [
        (
            "uniform-8-8-8",
            vec![
                LayerPrecision::new(8, 8),
                LayerPrecision::new(8, 8),
                LayerPrecision::new(8, 8),
            ],
        ),
        (
            "uniform-8w16",
            vec![
                LayerPrecision::new(8, 16),
                LayerPrecision::new(8, 16),
                LayerPrecision::new(8, 16),
            ],
        ),
        (
            "mixed-4-6-8",
            vec![
                LayerPrecision::new(4, 12),
                LayerPrecision::new(6, 12),
                LayerPrecision::new(8, 16),
            ],
        ),
    ];
    // Which backend `forward_batch_into` resolves to in this build
    // (DESIGN.md §16): the detected host-vector kernel under
    // `--features simd`, the scalar core otherwise.
    #[cfg(feature = "simd")]
    let backend: &'static str = softsimd::bits::swarx::kernel().name();
    #[cfg(not(feature = "simd"))]
    let backend: &'static str = "scalar";
    println!("backend: {backend}");
    let mut cells: Vec<Cell> = vec![];
    println!(
        "{:<16} {:>6} {:>12} {:>10} {:>10} {:>12} {:>8} {:>8}",
        "schedule", "batch", "rows/s", "ns/mult", "allocs/b", "base rows/s", "speedup",
        "simd x"
    );
    for (name, sched) in &schedules {
        let model =
            CompiledModel::compile_scheduled(layers.clone(), sched.clone()).expect("valid");
        let engine = PackedEngine::new(Arc::clone(&model));
        for &batch_rows in &[6usize, 48, 192] {
            let batch: Vec<Vec<i64>> = (0..batch_rows)
                .map(|_| (0..64).map(|_| rng.q_raw(sched[0].in_bits)).collect())
                .collect();
            // Cross-check first: the flat engine and the baseline must
            // agree bit-exactly before either is timed.
            let mut scratch = EngineScratch::new();
            let mut out = Vec::new();
            let stats = engine.forward_batch_into(&batch, 0, &mut scratch, &mut out);
            let (base_out, base_tally) = baseline::forward_batch(&model, &batch);
            assert_eq!(out, base_out, "{name} batch {batch_rows}: engines diverge");
            // The baseline bills dense Stage-1 work; the flat core
            // zero-skips all-zero packed words (pad words below the
            // quantum, post-ReLU zeros), so the conservation law of
            // DESIGN.md §18 is the billing cross-check.
            assert_eq!(
                stats.s1_cycles + stats.skipped_cycles,
                base_tally.s1_cycles,
                "{name}: s1 billing conservation"
            );
            assert_eq!(stats.subword_mults, base_tally.subword_mults);
            assert_eq!(stats.s2_passes, base_tally.s2_passes, "{name}: s2 billing");

            // Steady-state allocations per batch (scratch already warm);
            // counting is enabled only here, so the timed cells below
            // pay no counter RMW per allocation — the alloc-heavy
            // baseline must not be taxed into a flattering speedup.
            CountingAlloc::set_counting(true);
            let trials = 50u64;
            let before = CountingAlloc::count();
            for _ in 0..trials {
                std::hint::black_box(engine.forward_batch_into(
                    &batch,
                    0,
                    &mut scratch,
                    &mut out,
                ));
            }
            let allocs_per_batch = (CountingAlloc::count() - before) as f64 / trials as f64;
            CountingAlloc::set_counting(false);

            let label = format!("flat {name} (batch {batch_rows})");
            let r = bench(&label, 40, || {
                std::hint::black_box(engine.forward_batch_into(
                    &batch,
                    0,
                    &mut scratch,
                    &mut out,
                ));
            });
            let rows_per_s = batch_rows as f64 / (r.ns_per_iter * 1e-9);
            let ns_per_mult = r.ns_per_iter / stats.subword_mults as f64;

            let base_label = format!("baseline {name} (batch {batch_rows})");
            let rb = bench(&base_label, 40, || {
                std::hint::black_box(baseline::forward_batch(&model, &batch));
            });
            let baseline_rows_per_s = batch_rows as f64 / (rb.ns_per_iter * 1e-9);

            // The in-process scalar core (DESIGN.md §16): cross-check
            // the wide path bit-exact and stats-exact against it, then
            // time it on the same warmed scratch for the per-backend
            // speedup column. Without `simd` the two paths are one and
            // the ratio is identically 1.0.
            #[cfg(feature = "simd")]
            let scalar_core_rows_per_s = {
                let mut s_out = Vec::new();
                let s_stats = engine.forward_batch_into_scalar(
                    &batch,
                    0,
                    &mut scratch,
                    &mut s_out,
                );
                assert_eq!(out, s_out, "{name} batch {batch_rows}: wide vs scalar core");
                assert_eq!(
                    stats, s_stats,
                    "{name} batch {batch_rows}: billing wide vs scalar core"
                );
                let s_label = format!("scalar-core {name} (batch {batch_rows})");
                let rs = bench(&s_label, 40, || {
                    std::hint::black_box(engine.forward_batch_into_scalar(
                        &batch,
                        0,
                        &mut scratch,
                        &mut out,
                    ));
                });
                batch_rows as f64 / (rs.ns_per_iter * 1e-9)
            };
            #[cfg(not(feature = "simd"))]
            let scalar_core_rows_per_s = rows_per_s;

            let cell = Cell {
                schedule: *name,
                batch: batch_rows,
                backend,
                rows_per_s,
                ns_per_subword_mult: ns_per_mult,
                allocs_per_batch,
                baseline_rows_per_s,
                speedup: rows_per_s / baseline_rows_per_s,
                scalar_core_rows_per_s,
                simd_speedup: rows_per_s / scalar_core_rows_per_s,
            };
            println!(
                "{:<16} {:>6} {:>12.0} {:>10.3} {:>10.2} {:>12.0} {:>7.2}x {:>7.2}x",
                cell.schedule,
                cell.batch,
                cell.rows_per_s,
                cell.ns_per_subword_mult,
                cell.allocs_per_batch,
                cell.baseline_rows_per_s,
                cell.speedup,
                cell.simd_speedup
            );
            cells.push(cell);
        }
    }

    let mut cell_json: Vec<String> = cells.iter().map(Cell::json).collect();
    cell_json.extend(sparse_cells(&layers, &schedules, backend, &mut rng));
    write_cells("engine", "BENCH_engine.json", &cell_json);

    conv_cells();
}

/// Sparse-activation cells (DESIGN.md §18): the same schedules on
/// post-ReLU-style batches where a tail of whole rows is zero, so at
/// least that fraction of packed activation words is all-zero at every
/// layer. Each cell A/Bs the zero-skipping engine against the same
/// engine with skipping forced off (`with_zero_skip(false)`) on the
/// identical batch — `skip_speedup` is the measured rows/s ratio, and
/// `sparsity` is the engine's own cycle-weighted skip fraction.
fn sparse_cells(
    layers: &[QuantLayer],
    schedules: &[(&'static str, Vec<LayerPrecision>)],
    backend: &'static str,
    rng: &mut XorShift64,
) -> Vec<String> {
    println!("\n== engine: sparse-activation cells (zero-skip on vs off) ==");
    println!(
        "{:<16} {:>6} {:>10} {:>10} {:>12} {:>12} {:>8}",
        "schedule", "batch", "zero rows", "sparsity", "rows/s", "dense rows/s", "skip x"
    );
    let batch_rows = 192usize;
    let mut out_json = vec![];
    for (name, sched) in schedules {
        let model =
            CompiledModel::compile_scheduled(layers.to_vec(), sched.clone()).expect("valid");
        let engine = PackedEngine::new(Arc::clone(&model));
        let dense_engine = PackedEngine::new(Arc::clone(&model)).with_zero_skip(false);
        for &zero_frac in &[0.5f64, 0.75] {
            // A contiguous all-zero tail of whole rows: every packed
            // word it covers is zero in every column of every layer
            // (zero rows stay zero through ReLU), and the live head
            // keeps the lane packing aligned.
            let live = (batch_rows as f64 * (1.0 - zero_frac)).round() as usize;
            let batch: Vec<Vec<i64>> = (0..batch_rows)
                .map(|b| {
                    (0..64)
                        .map(|_| if b < live { rng.q_raw(sched[0].in_bits) } else { 0 })
                        .collect()
                })
                .collect();
            let mut scratch = EngineScratch::new();
            let mut out = Vec::new();
            let stats = engine.forward_batch_into(&batch, 0, &mut scratch, &mut out);
            let mut dense_out = Vec::new();
            let dense_stats =
                dense_engine.forward_batch_into(&batch, 0, &mut scratch, &mut dense_out);
            // Skipping is an execution strategy, not a numeric change:
            // bit-exact outputs, conservation-exact billing.
            assert_eq!(out, dense_out, "{name} {zero_frac}: skip changes outputs");
            assert_eq!(
                stats.s1_cycles + stats.skipped_cycles,
                dense_stats.s1_cycles,
                "{name} {zero_frac}: conservation"
            );
            let sparsity = stats.skip_fraction().unwrap_or(0.0);
            assert!(
                sparsity >= zero_frac,
                "{name}: {zero_frac} zero rows must skip at least that \
                 fraction of Stage-1 cycles, got {sparsity}"
            );

            let label = format!("sparse {name} (zero {zero_frac})");
            let r = bench(&label, 40, || {
                std::hint::black_box(engine.forward_batch_into(
                    &batch,
                    0,
                    &mut scratch,
                    &mut out,
                ));
            });
            let rows_per_s = batch_rows as f64 / (r.ns_per_iter * 1e-9);
            let dense_label = format!("no-skip {name} (zero {zero_frac})");
            let rd = bench(&dense_label, 40, || {
                std::hint::black_box(dense_engine.forward_batch_into(
                    &batch,
                    0,
                    &mut scratch,
                    &mut out,
                ));
            });
            let dense_rows_per_s = batch_rows as f64 / (rd.ns_per_iter * 1e-9);
            let skip_speedup = rows_per_s / dense_rows_per_s;
            println!(
                "{:<16} {:>6} {:>10.2} {:>9.1}% {:>12.0} {:>12.0} {:>7.2}x",
                name,
                batch_rows,
                zero_frac,
                sparsity * 100.0,
                rows_per_s,
                dense_rows_per_s,
                skip_speedup
            );
            out_json.push(format!(
                "{{\"schedule\":\"sparse-{name}\",\"batch\":{batch_rows},\
                 \"backend\":\"{backend}\",\"zero_row_fraction\":{zero_frac},\
                 \"sparsity\":{sparsity:.4},\"rows_per_s\":{rows_per_s:.1},\
                 \"no_skip_rows_per_s\":{dense_rows_per_s:.1},\
                 \"skip_speedup\":{skip_speedup:.2}}}"
            ));
        }
    }
    out_json
}

/// One conv serving cell, JSON-serializable (`BENCH_conv.json`):
/// images/s through the im2col CNN, ns per useful sub-word multiply,
/// and steady-state allocations per batch.
struct ConvCell {
    schedule: &'static str,
    batch: usize,
    patch_rows_per_img: usize,
    /// Images per second. One image is `patch_rows_per_img` packed
    /// rows, so the JSON also carries `rows_per_s` (= imgs_per_s ×
    /// patch_rows_per_img) in the same packed-row unit the other bench
    /// artifacts use — the two keys name their units to keep
    /// cross-file comparisons honest.
    imgs_per_s: f64,
    ns_per_subword_mult: f64,
    allocs_per_batch: f64,
}

impl ConvCell {
    fn json(&self) -> String {
        format!(
            "{{\"schedule\":\"{}\",\"batch\":{},\"patch_rows_per_img\":{},\
             \"imgs_per_s\":{:.1},\"rows_per_s\":{:.1},\
             \"ns_per_subword_mult\":{:.3},\"allocs_per_batch\":{:.2}}}",
            self.schedule,
            self.batch,
            self.patch_rows_per_img,
            self.imgs_per_s,
            self.imgs_per_s * self.patch_rows_per_img as f64,
            self.ns_per_subword_mult,
            self.allocs_per_batch
        )
    }
}

/// Conv serving cells (DESIGN.md §12): the synthetic CNN (conv 1×8×8 →
/// 4ch 3×3 s1 p1 → conv 4ch → 4ch 3×3 s2 p1 → dense 64 → 10) through
/// the flat engine, cross-checked bit-exact against the scalar stack
/// oracle before timing. Emits `BENCH_conv.json`.
fn conv_cells() {
    use softsimd::nn::exec::stack_forward_row;
    use softsimd::workload::synth::{synth_cnn_stack, ImageSet};

    println!("\n== engine: im2col CNN serving cells ==");
    let stack = synth_cnn_stack(0xBE9C4, 8);
    let images = ImageSet::standard();
    let patch_rows_per_img: usize =
        stack.iter().map(softsimd::nn::conv::LayerOp::patch_rows).sum();
    let schedules: [(&'static str, Vec<LayerPrecision>); 2] = [
        (
            "conv-8-8-8",
            vec![
                LayerPrecision::new(8, 16),
                LayerPrecision::new(8, 16),
                LayerPrecision::new(8, 16),
            ],
        ),
        (
            "conv-4-6-8",
            vec![
                LayerPrecision::new(4, 8),
                LayerPrecision::new(6, 12),
                LayerPrecision::new(8, 16),
            ],
        ),
    ];
    let mut cells: Vec<ConvCell> = vec![];
    println!(
        "{:<16} {:>6} {:>12} {:>10} {:>10}",
        "schedule", "batch", "imgs/s", "ns/mult", "allocs/b"
    );
    for (name, sched) in &schedules {
        let model =
            CompiledModel::compile_stack(stack.clone(), sched.clone()).expect("valid");
        let engine = PackedEngine::new(model);
        for &batch_imgs in &[6usize, 24, 96] {
            let (batch, _) =
                images.sample(batch_imgs, 0.25, 0xBE9C5 + batch_imgs as u64, sched[0].in_bits);
            let mut scratch = EngineScratch::new();
            let mut out = Vec::new();
            let stats = engine.forward_batch_into(&batch, 0, &mut scratch, &mut out);
            // Cross-check the head of every batch against the scalar
            // stack oracle before timing anything.
            for (b, row) in batch.iter().take(6).enumerate() {
                let want = stack_forward_row(row, &stack, sched);
                assert_eq!(out[b], want, "{name} batch {batch_imgs}: image {b} diverges");
            }

            CountingAlloc::set_counting(true);
            let trials = 20u64;
            let before = CountingAlloc::count();
            for _ in 0..trials {
                std::hint::black_box(engine.forward_batch_into(
                    &batch,
                    0,
                    &mut scratch,
                    &mut out,
                ));
            }
            let allocs_per_batch = (CountingAlloc::count() - before) as f64 / trials as f64;
            CountingAlloc::set_counting(false);

            let label = format!("conv {name} (batch {batch_imgs})");
            let r = bench(&label, 40, || {
                std::hint::black_box(engine.forward_batch_into(
                    &batch,
                    0,
                    &mut scratch,
                    &mut out,
                ));
            });
            let imgs_per_s = batch_imgs as f64 / (r.ns_per_iter * 1e-9);
            let ns_per_mult = r.ns_per_iter / stats.subword_mults as f64;
            let cell = ConvCell {
                schedule: name,
                batch: batch_imgs,
                patch_rows_per_img,
                imgs_per_s,
                ns_per_subword_mult: ns_per_mult,
                allocs_per_batch,
            };
            println!(
                "{:<16} {:>6} {:>12.0} {:>10.3} {:>10.2}",
                cell.schedule,
                cell.batch,
                cell.imgs_per_s,
                cell.ns_per_subword_mult,
                cell.allocs_per_batch
            );
            cells.push(cell);
        }
    }
    let cell_json: Vec<String> = cells.iter().map(ConvCell::json).collect();
    write_cells("conv", "BENCH_conv.json", &cell_json);
}
