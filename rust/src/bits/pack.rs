//! Packing / unpacking sub-words into 48-bit datapath words.

use super::fixed::{sign_extend, truncate};
use super::format::{SimdFormat, WORD_MASK};

/// A 48-bit datapath word tagged with its Soft SIMD format.
///
/// The carrier is a `u64`; bits 48..64 are always zero (an invariant
/// every SWAR op preserves and `debug_assert`s).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PackedWord {
    pub bits: u64,
    pub fmt: SimdFormat,
}

impl PackedWord {
    pub fn new(bits: u64, fmt: SimdFormat) -> Self {
        debug_assert_eq!(bits & !WORD_MASK, 0, "bits above the 48-bit datapath");
        PackedWord { bits, fmt }
    }

    pub fn zero(fmt: SimdFormat) -> Self {
        PackedWord { bits: 0, fmt }
    }

    /// Pack lane values (two's-complement `Q1.(b-1)` raw integers,
    /// sign-extended `i64`s). Panics if a value does not fit.
    pub fn from_lanes(vals: &[i64], fmt: SimdFormat) -> Self {
        PackedWord::new(pack(vals, fmt), fmt)
    }

    /// Unpack into per-lane sign-extended raw values.
    pub fn lanes(self) -> Vec<i64> {
        unpack(self.bits, self.fmt)
    }

    /// Single lane `i`, sign-extended.
    #[inline]
    pub fn lane(self, i: u32) -> i64 {
        sign_extend((self.bits >> (i * self.fmt.bits)) & ((1u64 << self.fmt.bits) - 1), self.fmt.bits)
    }
}

/// Pack `vals` (one per lane, lane 0 at the least-significant end) into a
/// raw 48-bit word. Panics if `vals.len() != lanes` or a value exceeds
/// the lane's two's-complement range.
pub fn pack(vals: &[i64], fmt: SimdFormat) -> u64 {
    assert_eq!(
        vals.len(),
        fmt.lanes() as usize,
        "expected {} lane values for {fmt}",
        fmt.lanes()
    );
    pack_chunk(vals, fmt)
}

/// The one range-checked lane-packing loop every packing entry point
/// shares: pack a chunk of at most `lanes` values (missing trailing
/// lanes are zero). Panics if a value does not fit its lane.
fn pack_chunk(chunk: &[i64], fmt: SimdFormat) -> u64 {
    debug_assert!(chunk.len() <= fmt.lanes() as usize);
    let half = 1i64 << (fmt.bits - 1);
    let mut w = 0u64;
    for (i, &v) in chunk.iter().enumerate() {
        assert!(
            v >= -half && v < half,
            "lane {i} value {v} out of Q1.{} range [{}, {})",
            fmt.bits - 1,
            -half,
            half
        );
        w |= truncate(v, fmt.bits) << (i as u32 * fmt.bits);
    }
    w
}

/// Unpack a raw 48-bit word into sign-extended lane values (lane 0 first).
pub fn unpack(word: u64, fmt: SimdFormat) -> Vec<i64> {
    debug_assert_eq!(word & !WORD_MASK, 0);
    let mask = (1u64 << fmt.bits) - 1;
    (0..fmt.lanes())
        .map(|i| sign_extend((word >> (i * fmt.bits)) & mask, fmt.bits))
        .collect()
}

/// Pack a slice of raw values into as many words as needed, zero-padding
/// the final partial word.
pub fn pack_stream(vals: &[i64], fmt: SimdFormat) -> Vec<u64> {
    let mut out = Vec::with_capacity(vals.len().div_ceil(fmt.lanes() as usize));
    pack_stream_into(vals, fmt, &mut out);
    out
}

/// As [`pack_stream`], written into a caller-owned buffer (`dst` is
/// cleared and refilled; a warmed buffer makes the call allocation-free
/// — the serving hot path's form, DESIGN.md §11). Missing lanes of a
/// partial final chunk pack as zero, identical to the padded [`pack`].
pub fn pack_stream_into(vals: &[i64], fmt: SimdFormat, dst: &mut Vec<u64>) {
    dst.clear();
    pack_stream_append(vals, fmt, dst);
}

/// As [`pack_stream_into`], but appending to `dst` — the engine packs
/// several activation columns back to back into one buffer.
pub fn pack_stream_append(vals: &[i64], fmt: SimdFormat, dst: &mut Vec<u64>) {
    for chunk in vals.chunks(fmt.lanes() as usize) {
        dst.push(pack_chunk(chunk, fmt));
    }
}

/// Unpack a stream of words, truncating to `count` elements.
pub fn unpack_stream(words: &[u64], fmt: SimdFormat, count: usize) -> Vec<i64> {
    let mut out: Vec<i64> = words.iter().flat_map(|&w| unpack(w, fmt)).collect();
    out.truncate(count);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        for fmt in SimdFormat::all() {
            let half = 1i64 << (fmt.bits - 1);
            let vals: Vec<i64> = (0..fmt.lanes() as i64)
                .map(|i| ((i * 37 + 5) % (2 * half)) - half)
                .collect();
            let w = pack(&vals, fmt);
            assert_eq!(unpack(w, fmt), vals, "fmt {fmt}");
        }
    }

    #[test]
    fn lane_order_is_lsb_first() {
        let fmt = SimdFormat::new(8);
        let mut vals = vec![0i64; 6];
        vals[0] = 1;
        assert_eq!(pack(&vals, fmt), 1);
        vals[0] = 0;
        vals[5] = -1;
        assert_eq!(pack(&vals, fmt), 0xFF_0000_0000_00);
    }

    #[test]
    #[should_panic]
    fn pack_rejects_overflow() {
        let fmt = SimdFormat::new(4);
        pack(&[8, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0], fmt); // 8 > Q1.3 max 7
    }

    #[test]
    fn stream_roundtrip_with_padding() {
        let fmt = SimdFormat::new(12);
        let vals: Vec<i64> = vec![-2048, 2047, 5, -1, 100, 0, -7];
        let words = pack_stream(&vals, fmt);
        assert_eq!(words.len(), 2);
        assert_eq!(unpack_stream(&words, fmt, vals.len()), vals);
    }

    #[test]
    fn pack_stream_into_reuses_buffer_and_matches_pack_stream() {
        let mut dst = Vec::new();
        for fmt in SimdFormat::all() {
            let half = 1i64 << (fmt.bits - 1);
            let vals: Vec<i64> = (0..23).map(|i| ((i * 31 + 7) % (2 * half)) - half).collect();
            pack_stream_into(&vals, fmt, &mut dst);
            assert_eq!(dst, pack_stream(&vals, fmt), "fmt {fmt}");
            // Reuse with a shorter stream: buffer shrinks, not appends.
            pack_stream_into(&vals[..5], fmt, &mut dst);
            assert_eq!(dst, pack_stream(&vals[..5], fmt), "fmt {fmt} short");
        }
    }

    #[test]
    fn packed_word_lane_access() {
        let fmt = SimdFormat::new(6);
        let vals: Vec<i64> = vec![-32, 31, 0, -1, 15, -16, 7, -8];
        let p = PackedWord::from_lanes(&vals, fmt);
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(p.lane(i as u32), v);
        }
    }
}
