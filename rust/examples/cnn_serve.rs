//! CNN serving demo (DESIGN.md §12): the synthetic image-classification
//! scenario — conv 1×8×8 → 4ch 3×3 s1 p1, conv 4ch → 4ch 3×3 s2 p1,
//! dense 64 → 10 — compiled to one im2col-lowered `CompiledModel` and
//! served through the coordinator under a uniform and a
//! low-precision-first schedule. Every response is checked bit-exact
//! against the scalar stack oracle; the metrics report shows the
//! patch-row amplification (one image = 64 + 16 conv patch rows) in the
//! sub-word multiply counts.
//!
//! Needs no AOT artifacts: weights are synthesized locally, so it runs
//! anywhere.
//!
//! Run: `cargo run --release --example cnn_serve`

use softsimd::anyhow;
use softsimd::coordinator::cost::CostTable;
use softsimd::coordinator::model::CompiledModel;
use softsimd::coordinator::server::{Coordinator, Request, ServeConfig};
use softsimd::nn::exec::stack_forward_row;
use softsimd::nn::weights::LayerPrecision;
use softsimd::workload::synth::{synth_cnn_stack, ImageSet};

fn main() -> anyhow::Result<()> {
    let stack = synth_cnn_stack(0xC99E1, 8);
    let images = ImageSet::standard();
    println!(
        "synthetic CNN: {} layers, input {} px, {} logits; one image expands \
         into {} im2col patch rows",
        stack.len(),
        stack[0].in_len(),
        stack[stack.len() - 1].out_len(),
        stack.iter().map(|op| op.patch_rows()).sum::<usize>() - 1,
    );

    println!("characterizing pipeline energy at 1 GHz…");
    let cost = CostTable::characterize(1000.0);

    let schedules: Vec<(&str, Vec<LayerPrecision>)> = vec![
        (
            "uniform 8-8-8",
            vec![
                LayerPrecision::new(8, 16),
                LayerPrecision::new(8, 16),
                LayerPrecision::new(8, 16),
            ],
        ),
        (
            "low-first 4-6-8",
            vec![
                LayerPrecision::new(4, 8),
                LayerPrecision::new(6, 12),
                LayerPrecision::new(8, 16),
            ],
        ),
    ];

    for (name, sched) in schedules {
        let model = CompiledModel::compile_stack(stack.clone(), sched.clone())?;
        println!(
            "\n== {name}: batch quantum {} images, boundaries {} ==",
            model.batch_quantum(),
            (0..sched.len() - 1)
                .map(|li| format!("{} hop(s)", model.boundary_chain(li).len()))
                .collect::<Vec<_>>()
                .join(", ")
        );
        let in_bits = model.in_bits();
        let (xs, _labels) = images.sample(192, 0.25, 0xC99E2, in_bits);
        let mut coord = Coordinator::start(model, ServeConfig::new(2, 12), cost.clone())?;
        for (id, row) in xs.iter().enumerate() {
            coord.submit(Request { id: id as u64, rows: vec![row.clone()] })?;
        }
        let responses = coord.drain()?;
        anyhow::ensure!(responses.len() == xs.len(), "all requests must complete");
        // Spot-check the packed serving result against the scalar stack
        // oracle — the engine must be bit-exact, not approximately right.
        for resp in responses.iter().take(8) {
            let want = stack_forward_row(&xs[resp.id as usize], &stack, &sched);
            anyhow::ensure!(
                resp.logits[0] == want,
                "response {} diverges from the scalar oracle",
                resp.id
            );
        }
        println!("{}", coord.metrics.report());
        coord.shutdown();
    }
    Ok(())
}
