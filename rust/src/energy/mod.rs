//! The 28nm cost model: cells → µm², toggles → pJ, and the
//! synthesis-pressure model that makes area and energy functions of the
//! timing constraint (DESIGN.md §2, §6).

pub mod model;
pub mod report;
pub mod tech;

pub use model::{PipelineArea, SynthBlock, SynthesizedSoftPipeline};
pub use tech::{CellCosts, TechParams, MHZ_POINTS};
