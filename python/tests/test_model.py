"""MLP golden model: pivot chain pallas == jnp ref == plain-int, plus
task accuracy and float-agreement sanity."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import defs, model


@pytest.fixture(scope="module")
def layers():
    return model.build_layers()


@pytest.fixture(scope="module")
def batch():
    templates = model.class_templates()
    xs, ys = model.sample_batch(templates, model.BATCH)
    return model.quantize_inputs(xs), ys, xs


class TestPivotChain:
    def test_ref_matches_int(self, layers, batch):
        x_q, _, _ = batch
        got = np.asarray(model.mlp_forward_ref(jnp.asarray(x_q), layers))
        want = model.mlp_forward_int(x_q, layers)
        assert np.array_equal(got, want.astype(np.int32))

    def test_pallas_matches_ref(self, layers, batch):
        x_q, _, _ = batch
        got = np.asarray(model.mlp_forward_pallas(jnp.asarray(x_q), layers))
        want = np.asarray(model.mlp_forward_ref(jnp.asarray(x_q), layers))
        assert np.array_equal(got, want)


class TestTask:
    def test_classifier_beats_chance(self, layers):
        templates = model.class_templates()
        xs, ys = model.sample_batch(templates, 64, seed=0xFEED5)
        x_q = model.quantize_inputs(xs)
        logits = np.asarray(model.mlp_forward_ref(jnp.asarray(x_q), layers))
        pred = logits[:, : model.CLASSES].argmax(axis=1)
        acc = (pred == ys).mean()
        assert acc >= 0.5, f"matched-filter accuracy {acc} (chance = 0.1)"

    def test_padded_outputs_are_zero_weighted(self, layers):
        w2 = layers[1].w_raw
        assert (w2[:, model.CLASSES :] == 0).all()

    def test_quantized_tracks_float(self, layers, batch):
        """Quantized logits correlate with the float matched filter."""
        x_q, _, xs = batch
        logits = np.asarray(model.mlp_forward_ref(jnp.asarray(x_q), layers)).astype(
            np.float64
        ) / (1 << 15)
        # Float model with the same (dequantized) weights.
        w1 = layers[0].w_raw.astype(np.float64) / 128.0
        w2 = layers[1].w_raw.astype(np.float64) / 128.0
        h = np.maximum(xs @ w1, 0.0)
        ref_logits = h @ w2
        # Compare rankings on the real classes.
        a = logits[:, : model.CLASSES]
        b = ref_logits[:, : model.CLASSES]
        corr = np.corrcoef(a.ravel(), b.ravel())[0, 1]
        assert corr > 0.9, f"quantized/float correlation {corr}"


class TestWeights:
    def test_plans_reconstruct_weights(self, layers):
        """Digit plans must decode back to the quantized weights."""
        for layer in layers:
            k, n = layer.w_raw.shape
            for i in range(0, k, 7):
                for j in range(n):
                    ops = [
                        (int(s), int(g))
                        for s, g in zip(layer.shifts[i, j], layer.signs[i, j])
                    ]
                    # Replay on a headroom multiplicand: exact product.
                    x = 1 << 32
                    acc = 0
                    for shift, sign in ops:
                        acc = (acc + sign * x) >> shift
                    assert acc == (x * int(layer.w_raw[i, j])) >> 7
