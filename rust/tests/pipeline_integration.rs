//! Integration across the architecture model and the gate-level
//! substrate: the same operations computed three ways (functional SWAR,
//! micro-op pipeline, gate netlist) must agree bit-exactly; the cost
//! model's structural claims (Fig. 6 shapes) must hold.

use softsimd::bits::format::SimdFormat;
use softsimd::bits::pack::{pack_stream, unpack, unpack_stream};
use softsimd::energy::model::SynthesizedSoftPipeline;
use softsimd::hardsimd::pipeline::{HardSimdPipeline, HARD_FLEX, HARD_TWO};
use softsimd::isa::{assemble_mul_repack, Instr, Reg};
use softsimd::pipeline::stage1::{mul_packed, mul_scalar};
use softsimd::pipeline::stage2::repack_stream;
use softsimd::pipeline::{PipelineSim, RunResult};
use softsimd::rtl::multiplier::{drive_bank, hard_product, simd_multiplier_bank};
use softsimd::rtl::shifter::{drive_stage1, stage1_datapath};
use softsimd::rtl::Simulator;
use softsimd::workload::synth::XorShift64;

#[test]
fn functional_microop_and_gatelevel_multiplies_agree() {
    let net = stage1_datapath(true);
    let mut gate = Simulator::new(&net);
    let mut rng = XorShift64::new(0x3A3A);
    for fmt in SimdFormat::all() {
        for _ in 0..30 {
            let x = rng.word();
            let m = rng.q_raw(8);
            // Way 1: functional packed multiply.
            let f = mul_packed(x, m, 8, fmt);
            // Way 2: micro-op pipeline program.
            let mut prog = assemble_mul_repack(m, 8, fmt, fmt, 3);
            prog.instrs.insert(1, Instr::Load(Reg::X, x));
            let mut sim = PipelineSim::new(fmt);
            let mut res = RunResult::default();
            sim.run(&prog, &mut res);
            assert_eq!(res.outputs[0], f, "microop vs functional, fmt {fmt} m {m}");
            // Way 3: gate-level replay of the plan.
            let plan = softsimd::csd::schedule::schedule(m, 8);
            let mut acc = 0u64;
            for op in &plan.ops {
                let (k, sign) = match *op {
                    softsimd::csd::schedule::MulOp::AddShift { shift, sign } => (shift, sign),
                    softsimd::csd::schedule::MulOp::Shift { shift } => (shift, 0),
                };
                acc = drive_stage1(&mut gate, &net, acc, x, k, sign, fmt);
            }
            assert_eq!(acc, f, "gate-level vs functional, fmt {fmt} m {m}");
        }
    }
}

#[test]
fn repack_pipeline_roundtrip_all_pairs() {
    // Multiply then convert through every format pair and back;
    // compare against the canonical stream semantics.
    let mut rng = XorShift64::new(0x9C9C);
    for from in SimdFormat::all() {
        for to in SimdFormat::all() {
            let count = from.lanes() as usize * 2;
            let vals: Vec<i64> = (0..count).map(|_| rng.q_raw(from.bits)).collect();
            let words = pack_stream(&vals, from);
            let there = repack_stream(&words, from, to, count);
            let back = repack_stream(&there, to, from, count);
            let got = unpack_stream(&back, from, count);
            for (j, (&v, &g)) in vals.iter().zip(&got).enumerate() {
                if to.bits >= from.bits {
                    assert_eq!(v, g, "{from}->{to} lossless roundtrip idx {j}");
                } else {
                    // Narrowing truncated low bits; the value error is
                    // bounded by one narrow ULP re-expressed at `from`.
                    let dropped = from.bits - to.bits;
                    assert_eq!(g >> dropped << dropped, g, "low bits cleared");
                    assert!((v - g) >= 0 && (v - g) < (1 << dropped), "{from}->{to}");
                }
            }
        }
    }
}

#[test]
fn hard_simd_functional_bank_matches_reference_products() {
    // The dedicated-bank functional netlist (the correctness carrier
    // for Hard SIMD) against `hard_product` across formats.
    let fmts = [4u32, 6, 8, 12, 16];
    let net = simd_multiplier_bank(&fmts, false);
    let mut sim = Simulator::new(&net);
    let mut rng = XorShift64::new(0x4D4D);
    for &b in &fmts {
        let fmt = SimdFormat::new(b);
        for _ in 0..20 {
            let xs: Vec<i64> = (0..fmt.lanes()).map(|_| rng.q_raw(b)).collect();
            let ms: Vec<i64> = (0..fmt.lanes()).map(|_| rng.q_raw(b)).collect();
            let a = softsimd::bits::pack::pack(&xs, fmt);
            let m = softsimd::bits::pack::pack(&ms, fmt);
            let got = unpack(drive_bank(&mut sim, &net, &fmts, a, m, fmt), fmt);
            let want: Vec<i64> = xs
                .iter()
                .zip(&ms)
                .map(|(&x, &mm)| hard_product(x, mm, b))
                .collect();
            assert_eq!(got, want, "fmt {fmt}");
        }
    }
}

#[test]
fn soft_vs_hard_accuracy_comparison() {
    // Both arms compute Q1 products; hard truncates once, soft once per
    // add — soft's error is bounded and the paper's ~1% claim holds.
    let mut rng = XorShift64::new(0xACC2);
    let mut soft_err = 0.0f64;
    let mut hard_err = 0.0f64;
    let n = 20_000;
    for _ in 0..n {
        let x = rng.q_raw(8);
        let m = rng.q_raw(8);
        if x == -128 && m == -128 {
            continue;
        }
        let truth = (x as f64 / 128.0) * (m as f64 / 128.0);
        soft_err += ((mul_scalar(x, m, 8, 8) as f64 / 128.0) - truth).abs();
        hard_err += ((hard_product(x, m, 8) as f64 / 128.0) - truth).abs();
    }
    let (soft_mean, hard_mean) = (soft_err / n as f64, hard_err / n as f64);
    assert!(hard_mean <= soft_mean, "hard should be ≥ as accurate");
    assert!(soft_mean < 0.012, "soft mean abs error {soft_mean} ≈ 1% claim");
}

#[test]
fn fig6_structural_claims_hold_at_all_constraints() {
    for &mhz in &[200.0, 500.0, 1000.0] {
        let soft = SynthesizedSoftPipeline::new(mhz).area();
        let flex = HardSimdPipeline::new(HARD_FLEX, mhz).area();
        let two = HardSimdPipeline::new(HARD_TWO, mhz).area();
        assert!(soft.total() < 0.5 * flex.total(), "@{mhz} MHz");
        assert!(two.total() > 1.1 * soft.total(), "@{mhz} MHz");
        assert!(flex.total() > two.total(), "@{mhz} MHz");
    }
}

#[test]
fn pipeline_overlap_improves_throughput() {
    // Back-to-back multiply+repack programs: the overlapped elapsed
    // time must beat the serial sum by the stage-2 occupancy.
    let fmt = SimdFormat::new(8);
    let mut rng = XorShift64::new(0x0412);
    let progs: Vec<_> = (0..100)
        .map(|_| {
            let mut p = assemble_mul_repack(rng.q_raw(8), 8, fmt, SimdFormat::new(16), 3);
            p.instrs.insert(1, Instr::Load(Reg::X, rng.word()));
            p
        })
        .collect();
    let mut sim = PipelineSim::new(fmt);
    sim.tracing = false;
    let res = sim.run_batch(&progs);
    assert!(res.elapsed_cycles < res.s1_busy + res.s2_busy);
    assert!(res.elapsed_cycles >= res.s1_busy.max(res.s2_busy));
}
