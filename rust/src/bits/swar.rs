//! SWAR (SIMD-within-a-register) primitives implementing the Soft SIMD
//! datapath semantics of Section III-B / Fig. 4.
//!
//! The hardware enforces sub-word isolation with the `V_x` control
//! vector: carry-kill gates at sub-word MSBs (adder, Fig. 4a), `+1`
//! injection at sub-word LSBs (subtraction), and sign-replication muxes
//! at sub-word MSBs (shifter, Fig. 4b). In software these become the
//! classical SWAR identities below; the per-format masks *are* `V_x`.
//!
//! All functions preserve the invariant `result & !WORD_MASK == 0`.
//!
//! Under `--features lanecheck` the standalone add/sub/neg report any
//! lane that actually wrapped to the runtime sanitizer
//! ([`crate::bits::lanecheck`]); the *fused* ops with `k ≥ 1` do not —
//! their `(b+1)`-bit intermediate makes a wrapped sum sign-corrected
//! and information-lossless by construction, so it is not a violation.

use super::format::{SimdFormat, MAX_SHIFT, WORD_MASK};

/// The raw wrapping SWAR add shared by every public entry point (no
/// sanitizer hook — callers that legitimately exploit the wrapped form
/// go through here). `pub(crate)` so the multi-word backend
/// (`bits::swarx`, `--features simd`) reuses the identity verbatim —
/// the wide kernel deliberately bypasses the `lanecheck` hooks, which
/// is why the engine pins `lanecheck` builds to the scalar path.
///
/// Identity: with `H` the MSB mask, `(a&~H) + (c&~H)` can never carry
/// *out* of a lane (the MSBs are zeroed), and the true MSB sum is
/// restored by `^ ((a^c) & H)`.
#[inline]
pub(crate) fn add_wrapped(a: u64, c: u64, fmt: SimdFormat) -> u64 {
    debug_assert_eq!(a & !WORD_MASK, 0);
    debug_assert_eq!(c & !WORD_MASK, 0);
    let h = fmt.msb_mask();
    (((a & !h).wrapping_add(c & !h)) ^ ((a ^ c) & h)) & WORD_MASK
}

/// The raw wrapping SWAR negation (complement, then `+1` injected at
/// every lane LSB); no sanitizer hook. `pub(crate)` for `bits::swarx`,
/// same contract as [`add_wrapped`].
#[inline]
pub(crate) fn neg_wrapped(c: u64, fmt: SimdFormat) -> u64 {
    add_wrapped(!c & WORD_MASK, fmt.lsb_mask(), fmt)
}

/// Per-sub-word add, modulo `2^b` in each lane (carry killed at
/// boundaries — an overflowing lane wraps, it never disturbs its
/// neighbour). Under `lanecheck`, wrapped lanes (`~(a^c) & (a^w)` at
/// the MSB) are reported to the sanitizer.
#[inline]
pub fn swar_add(a: u64, c: u64, fmt: SimdFormat) -> u64 {
    let w = add_wrapped(a, c, fmt);
    #[cfg(feature = "lanecheck")]
    crate::bits::lanecheck::note(
        crate::bits::lanecheck::ViolationKind::AddOverflow,
        fmt.bits,
        !(a ^ c) & (a ^ w) & fmt.msb_mask(),
    );
    w
}

/// Per-sub-word two's-complement negation: bitwise complement then `+1`
/// injected at every lane LSB — exactly the subtraction path of the
/// configurable adder ("provide +1 for the next sub-word in
/// subtractions", Section III-B). Under `lanecheck`, wrapped lanes
/// (negating the lane minimum: `c & w` at the MSB) are reported.
#[inline]
pub fn swar_neg(c: u64, fmt: SimdFormat) -> u64 {
    let w = neg_wrapped(c, fmt);
    #[cfg(feature = "lanecheck")]
    crate::bits::lanecheck::note(
        crate::bits::lanecheck::ViolationKind::NegOverflow,
        fmt.bits,
        c & w & fmt.msb_mask(),
    );
    w
}

/// Per-sub-word subtract `a - c` (mod `2^b` per lane). Under
/// `lanecheck`, wrapped lanes (`(a^c) & (a^w)` at the MSB) are
/// reported.
#[inline]
pub fn swar_sub(a: u64, c: u64, fmt: SimdFormat) -> u64 {
    let w = add_wrapped(a, neg_wrapped(c, fmt), fmt);
    #[cfg(feature = "lanecheck")]
    crate::bits::lanecheck::note(
        crate::bits::lanecheck::ViolationKind::SubOverflow,
        fmt.bits,
        (a ^ c) & (a ^ w) & fmt.msb_mask(),
    );
    w
}

/// Per-sub-word *arithmetic* right shift by `k ∈ {1..=3}` — the
/// configurable shifter of Fig. 4b. Each lane's top `k` bits are refilled
/// with its own sign bit (MSB replication through the `V_x` muxes);
/// bits shifted out of the lane bottom are truncated (toward −∞).
///
/// `fill` is built by OR-ing `k` down-shifted copies of the MSB bits;
/// copies cannot collide across lanes because `k < b` for every format.
#[inline]
pub fn swar_sar(a: u64, k: u32, fmt: SimdFormat) -> u64 {
    debug_assert_eq!(a & !WORD_MASK, 0);
    debug_assert!(k >= 1 && k <= MAX_SHIFT, "shifter supports 1..=3 positions/cycle");
    let signs = a & fmt.msb_mask();
    let mut fill = 0u64;
    for j in 0..k {
        fill |= signs >> j;
    }
    ((a >> k) & fmt.keep_mask(k)) | fill
}

/// Fused per-sub-word add-then-arithmetic-shift with a `(b+1)`-bit
/// intermediate — the multiply-cycle datapath (DESIGN.md §4).
///
/// In hardware the configurable adder's per-sub-word carry-out feeds the
/// shifter's sign-replication mux, so the sum is effectively `b+1` bits
/// wide until the shift drops it back to `b`. In SWAR form: the wrapped
/// sum's low bits are already correct; only the *sign* used for
/// replication must be corrected on overflow. Overflow in lane `i`
/// happened iff the operands agree in sign but the wrapped sum does not:
/// `V = ~(a^c) & (a^w)` at the MSB; the true wide sign is then the
/// wrapped MSB flipped: `(w & H) ^ V`.
///
/// `k = 0` is allowed (plain wrapped add — the multiply's final
/// position-0 digit).
#[inline]
pub fn swar_add_sar(a: u64, c: u64, k: u32, fmt: SimdFormat) -> u64 {
    if k == 0 {
        // The final position-0 digit: a genuinely wrapping add, routed
        // through the sanitizer-visible entry point.
        return swar_add(a, c, fmt);
    }
    let h = fmt.msb_mask();
    let w = add_wrapped(a, c, fmt);
    let ovf = !(a ^ c) & (a ^ w) & h;
    sar_with_sign(w, (w & h) ^ ovf, k, fmt)
}

/// Fused per-sub-word subtract-then-arithmetic-shift; see
/// [`swar_add_sar`]. Subtraction overflow: operands *disagree* in sign
/// and the result disagrees with `a`: `V = (a^c) & (a^w)` at the MSB.
#[inline]
pub fn swar_sub_sar(a: u64, c: u64, k: u32, fmt: SimdFormat) -> u64 {
    if k == 0 {
        return swar_sub(a, c, fmt);
    }
    let h = fmt.msb_mask();
    let w = add_wrapped(a, neg_wrapped(c, fmt), fmt);
    let ovf = (a ^ c) & (a ^ w) & h;
    sar_with_sign(w, (w & h) ^ ovf, k, fmt)
}

/// Shift `w` right by `k` per sub-word, replicating the supplied sign
/// bits (at MSB positions) into the vacated top bits. `pub(crate)` for
/// `bits::swarx`, same contract as [`add_wrapped`].
#[inline]
pub(crate) fn sar_with_sign(w: u64, signs: u64, k: u32, fmt: SimdFormat) -> u64 {
    debug_assert!(k >= 1 && k <= MAX_SHIFT);
    debug_assert_eq!(signs & !fmt.msb_mask(), 0);
    let mut fill = 0u64;
    for j in 0..k {
        fill |= signs >> j;
    }
    ((w >> k) & fmt.keep_mask(k)) | fill
}

/// Per-sub-word logical left shift by one (used by the repack datapath
/// tests and format-alignment helpers; not part of the multiply loop).
#[inline]
pub fn swar_shl1(a: u64, fmt: SimdFormat) -> u64 {
    debug_assert_eq!(a & !WORD_MASK, 0);
    ((a << 1) & WORD_MASK) & !fmt.lsb_mask()
}

/// Per-sub-word ReLU: every lane whose sign bit is set becomes zero,
/// non-negative lanes pass through — the activation unit applied to a
/// whole packed word in one pass (the serving engine's word-level
/// boundary, DESIGN.md §11).
///
/// The sign bits, moved to the lane LSBs, are spread into full-lane
/// masks by one multiply with the all-ones lane pattern; the spreads
/// cannot collide because lane bases are `bits` apart.
#[inline]
pub fn swar_relu(a: u64, fmt: SimdFormat) -> u64 {
    debug_assert_eq!(a & !WORD_MASK, 0);
    let signs = (a & fmt.msb_mask()) >> (fmt.bits - 1);
    let neg_lanes = signs.wrapping_mul((1u64 << fmt.bits) - 1);
    a & !neg_lanes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::fixed::{sign_extend, truncate};
    use crate::bits::pack::{pack, unpack};

    /// Tiny deterministic PRNG so tests need no external crate.
    pub(crate) struct XorShift(pub u64);
    impl XorShift {
        pub fn next(&mut self) -> u64 {
            let mut x = self.0;
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            self.0 = x;
            x
        }
        pub fn word(&mut self) -> u64 {
            self.next() & WORD_MASK
        }
    }

    fn lanes_of(w: u64, fmt: SimdFormat) -> Vec<i64> {
        unpack(w, fmt)
    }

    #[test]
    fn add_matches_per_lane_wrapping() {
        let mut rng = XorShift(0x5EED_0001);
        for fmt in SimdFormat::all() {
            for _ in 0..500 {
                let (a, c) = (rng.word(), rng.word());
                let got = lanes_of(swar_add(a, c, fmt), fmt);
                let want: Vec<i64> = lanes_of(a, fmt)
                    .iter()
                    .zip(lanes_of(c, fmt))
                    .map(|(&x, y)| sign_extend(truncate(x.wrapping_add(y), fmt.bits), fmt.bits))
                    .collect();
                assert_eq!(got, want, "fmt {fmt} a={a:#x} c={c:#x}");
            }
        }
    }

    #[test]
    fn sub_matches_per_lane_wrapping() {
        let mut rng = XorShift(0x5EED_0002);
        for fmt in SimdFormat::all() {
            for _ in 0..500 {
                let (a, c) = (rng.word(), rng.word());
                let got = lanes_of(swar_sub(a, c, fmt), fmt);
                let want: Vec<i64> = lanes_of(a, fmt)
                    .iter()
                    .zip(lanes_of(c, fmt))
                    .map(|(&x, y)| sign_extend(truncate(x.wrapping_sub(y), fmt.bits), fmt.bits))
                    .collect();
                assert_eq!(got, want, "fmt {fmt} a={a:#x} c={c:#x}");
            }
        }
    }

    #[test]
    fn neg_matches_per_lane() {
        let mut rng = XorShift(0x5EED_0003);
        for fmt in SimdFormat::all() {
            for _ in 0..300 {
                let a = rng.word();
                let got = lanes_of(swar_neg(a, fmt), fmt);
                let want: Vec<i64> = lanes_of(a, fmt)
                    .iter()
                    .map(|&x| sign_extend(truncate(x.wrapping_neg(), fmt.bits), fmt.bits))
                    .collect();
                assert_eq!(got, want);
            }
        }
    }

    #[test]
    fn sar_matches_per_lane_floor_shift() {
        let mut rng = XorShift(0x5EED_0004);
        for fmt in SimdFormat::all() {
            for k in 1..=MAX_SHIFT {
                for _ in 0..300 {
                    let a = rng.word();
                    let got = lanes_of(swar_sar(a, k, fmt), fmt);
                    // i64 >> is arithmetic: truncation toward −∞, same as HW.
                    let want: Vec<i64> = lanes_of(a, fmt).iter().map(|&x| x >> k).collect();
                    assert_eq!(got, want, "fmt {fmt} k {k} a={a:#x}");
                }
            }
        }
    }

    #[test]
    fn no_cross_lane_interference_on_overflow() {
        // Lane 0 at max + 1 overflows (wraps) without touching lane 1.
        for fmt in SimdFormat::all() {
            let half = 1i64 << (fmt.bits - 1);
            let mut a = vec![0i64; fmt.lanes() as usize];
            let mut c = vec![0i64; fmt.lanes() as usize];
            a[0] = half - 1;
            c[0] = 1;
            a[1] = 3;
            c[1] = 4;
            let s = swar_add(pack(&a, fmt), pack(&c, fmt), fmt);
            let lanes = lanes_of(s, fmt);
            assert_eq!(lanes[0], -half, "wrap in lane 0");
            assert_eq!(lanes[1], 7, "lane 1 undisturbed");
        }
    }

    #[test]
    fn fused_add_sar_matches_wide_reference() {
        // (a + c) computed at full precision, then arithmetically shifted:
        // the fused SWAR op must agree even when the b-bit sum overflows.
        let mut rng = XorShift(0x5EED_0006);
        for fmt in SimdFormat::all() {
            for k in 0..=MAX_SHIFT {
                for _ in 0..400 {
                    let (a, c) = (rng.word(), rng.word());
                    let got = lanes_of(swar_add_sar(a, c, k, fmt), fmt);
                    let want: Vec<i64> = lanes_of(a, fmt)
                        .iter()
                        .zip(lanes_of(c, fmt))
                        .map(|(&x, y)| {
                            if k == 0 {
                                sign_extend(truncate(x.wrapping_add(y), fmt.bits), fmt.bits)
                            } else {
                                (x + y) >> k // exact in i64: no wrap possible
                            }
                        })
                        .collect();
                    assert_eq!(got, want, "fmt {fmt} k {k} a={a:#x} c={c:#x}");
                }
            }
        }
    }

    #[test]
    fn fused_sub_sar_matches_wide_reference() {
        let mut rng = XorShift(0x5EED_0007);
        for fmt in SimdFormat::all() {
            for k in 0..=MAX_SHIFT {
                for _ in 0..400 {
                    let (a, c) = (rng.word(), rng.word());
                    let got = lanes_of(swar_sub_sar(a, c, k, fmt), fmt);
                    let want: Vec<i64> = lanes_of(a, fmt)
                        .iter()
                        .zip(lanes_of(c, fmt))
                        .map(|(&x, y)| {
                            if k == 0 {
                                sign_extend(truncate(x.wrapping_sub(y), fmt.bits), fmt.bits)
                            } else {
                                (x - y) >> k
                            }
                        })
                        .collect();
                    assert_eq!(got, want, "fmt {fmt} k {k} a={a:#x} c={c:#x}");
                }
            }
        }
    }

    #[test]
    fn fused_ops_overflow_corner() {
        // max + max at 8 bits: wide sum 254, >>1 = 127 (not the wrapped −1).
        let fmt = SimdFormat::new(8);
        let a = pack(&[127, -128, 127, -128, 0, 1], fmt);
        let c = pack(&[127, -128, -128, 127, 0, 1], fmt);
        let got = unpack(swar_add_sar(a, c, 1, fmt), fmt);
        assert_eq!(got, vec![127, -128, -1, -1, 0, 1]);
    }

    #[test]
    fn relu_matches_per_lane_max_zero() {
        let mut rng = XorShift(0x5EED_0008);
        for fmt in SimdFormat::all() {
            for _ in 0..400 {
                let a = rng.word();
                let got = lanes_of(swar_relu(a, fmt), fmt);
                let want: Vec<i64> = lanes_of(a, fmt).iter().map(|&x| x.max(0)).collect();
                assert_eq!(got, want, "fmt {fmt} a={a:#x}");
                assert_eq!(swar_relu(a, fmt) & !WORD_MASK, 0);
            }
            // Idempotent and zero-preserving.
            let a = rng.word();
            let r = swar_relu(a, fmt);
            assert_eq!(swar_relu(r, fmt), r);
            assert_eq!(swar_relu(0, fmt), 0);
        }
    }

    #[cfg(feature = "lanecheck")]
    #[test]
    fn sanitizer_records_wrapped_lanes_but_not_fused_intermediates() {
        use crate::bits::lanecheck::{self, ViolationKind};
        let fmt = SimdFormat::new(8);
        let a = pack(&[127, 0, -128, 1, 0, 0], fmt);
        let c = pack(&[1, 0, -1, 2, 0, 0], fmt);
        lanecheck::reset();
        swar_add(a, c, fmt);
        assert_eq!(lanecheck::count(), 1, "one violating op recorded");
        let log = lanecheck::take();
        assert_eq!(log[0].kind, ViolationKind::AddOverflow);
        // Lanes 0 (127+1) and 2 (−128−1) wrapped: their MSB bits.
        assert_eq!(log[0].lanes, (1u64 << 7) | (1u64 << 23));
        // The same operands through the fused op with k ≥ 1 are
        // information-lossless ((b+1)-bit intermediate): no record.
        lanecheck::reset();
        swar_add_sar(a, c, 1, fmt);
        swar_sub_sar(a, c, 2, fmt);
        assert_eq!(lanecheck::count(), 0);
        // Negating the lane minimum is the one neg overflow.
        swar_neg(pack(&[-128, 1, -1, 0, 0, 0], fmt), fmt);
        assert_eq!(lanecheck::count(), 1);
        assert_eq!(lanecheck::take()[0].kind, ViolationKind::NegOverflow);
        lanecheck::reset();
    }

    #[test]
    fn results_stay_in_datapath() {
        let mut rng = XorShift(0x5EED_0005);
        for fmt in SimdFormat::all() {
            for _ in 0..200 {
                let (a, c) = (rng.word(), rng.word());
                assert_eq!(swar_add(a, c, fmt) & !WORD_MASK, 0);
                assert_eq!(swar_sub(a, c, fmt) & !WORD_MASK, 0);
                assert_eq!(swar_sar(a, 3, fmt) & !WORD_MASK, 0);
            }
        }
    }
}
