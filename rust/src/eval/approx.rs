//! `eval approx` — the truncated-CSD approximation Pareto (DESIGN.md
//! §18).
//!
//! Sweeps a ladder of [`Truncation`] levels (exact, `t1..t3`, `d2`,
//! `d1`) over both synthetic workloads. Each level compiles into the
//! same shared plan arena as an approximate variant riding the exact
//! reference's schedule, so every row of the table is a real operating
//! point the serving governor can shed to: top-1 accuracy, agreement
//! with the exact variant, Stage-1 work and billed energy per row.
//!
//! Two oracles gate the sweep (nonzero exit on violation):
//!
//! 1. **Error-bound oracle** — for *every* weight in *every* layer the
//!    realized per-multiplier error `|m − m_kept|` must stay within
//!    the analytic bound: [`naf_max_below`]`(t)` for a `drop_least(t)`
//!    policy, and `naf_max_below(p)` for digit-capped policies, where
//!    `p` is the first kept raw position (the dropped digits are a CSD
//!    suffix confined below `p`).
//! 2. **Certificate oracle** — each approximate variant's *cheaper*
//!    static cost certificate must reconstruct the measured stats
//!    under the skip-conditioned upper-bound contract, exactly like
//!    the exact variants in `eval certify`.
//!
//! The table is also written to `EVAL_approx.json` (cwd-relative, like
//! `BENCH_*.json`) for CI upload.

use std::sync::Arc;

use crate::anyhow;
use crate::coordinator::cost::CostTable;
use crate::coordinator::engine::PackedEngine;
use crate::coordinator::model::{CompiledModel, VariantSpec};
use crate::csd::schedule::{naf_max_below, schedule_truncated, Truncation};
use crate::energy::report::table;
use crate::nn::conv::LayerOp;
use crate::nn::exec::argmax_class;
use crate::nn::weights::LayerPrecision;
use crate::workload::synth::{synth_cnn_stack, synth_mlp_stack, Digits, ImageSet};

/// Samples per workload (a multiple of every variant's batch quantum).
pub const SAMPLES: usize = 96;

/// The swept truncation ladder, exact first (the reference variant).
pub fn truncation_ladder() -> Vec<Truncation> {
    vec![
        Truncation::NONE,
        Truncation::drop_least(1),
        Truncation::drop_least(2),
        Truncation::drop_least(3),
        Truncation::keep_digits(2),
        Truncation::keep_digits(1),
    ]
}

/// One (workload, truncation level) cell of the approximation Pareto.
#[derive(Debug, Clone)]
pub struct ApproxRow {
    pub workload: &'static str,
    /// Truncation policy name (`exact`, `t1`, …, `d1`).
    pub level: String,
    /// Top-1 accuracy against the workload's labels.
    pub accuracy: f64,
    /// Top-1 agreement with the exact reference variant.
    pub fidelity: f64,
    pub s1_cycles_per_row: f64,
    pub pj_per_row: f64,
    /// Largest realized per-multiplier error `|m − m_kept|` across
    /// every weight of the stack.
    pub max_weight_err: i64,
    /// Largest analytic bound the error oracle held each weight to.
    pub err_bound: i64,
}

/// Analytic per-weight error bound for `trunc` applied to a weight
/// whose kept value is `m_kept` at `y_bits` (see module docs).
fn weight_err_bound(trunc: Truncation, m_kept: i64, y_bits: u32) -> i64 {
    if trunc.max_digits.is_none() {
        return naf_max_below(trunc.drop_below);
    }
    // Digit-capped: the dropped suffix sits strictly below the first
    // kept raw position — the trailing-zero count of the kept value
    // (CSD digits are non-adjacent, so the lowest one is the low bit).
    let p = if m_kept == 0 {
        y_bits
    } else {
        m_kept.unsigned_abs().trailing_zeros()
    };
    naf_max_below(p)
}

/// Check the error-bound oracle over every weight of `stack` at
/// `trunc`; returns (max realized error, max bound applied).
fn check_error_bounds(
    workload: &str,
    level: &str,
    stack: &[LayerOp],
    trunc: Truncation,
) -> anyhow::Result<(i64, i64)> {
    let mut max_err = 0i64;
    let mut max_bound = 0i64;
    for (li, layer) in stack.iter().enumerate() {
        let w = layer.weights();
        for row in &w.w_raw {
            for &m in row {
                let plan = schedule_truncated(m, w.bits, trunc);
                let err = (m - plan.m_raw).abs();
                let bound = weight_err_bound(trunc, plan.m_raw, w.bits);
                anyhow::ensure!(
                    err <= bound,
                    "{workload}/{level}: layer {li} weight {m} truncates to \
                     {} — error {err} exceeds the analytic bound {bound}",
                    plan.m_raw
                );
                max_err = max_err.max(err);
                max_bound = max_bound.max(bound);
            }
        }
    }
    Ok((max_err, max_bound))
}

/// Build the approximate variant set: the exact reference plus one
/// truncated variant per ladder rung, all on the same schedule.
fn approx_specs(schedule: Vec<LayerPrecision>) -> Vec<VariantSpec> {
    truncation_ladder()
        .into_iter()
        .map(|trunc| {
            let name = if trunc.is_none() {
                "exact".to_string()
            } else {
                trunc.to_string()
            };
            VariantSpec::new(name, schedule.clone()).with_truncation(trunc)
        })
        .collect()
}

fn run_workload(
    workload: &'static str,
    stack: Vec<LayerOp>,
    schedule: Vec<LayerPrecision>,
    xs: &[Vec<i64>],
    ys: &[usize],
    classes: usize,
    cost: &CostTable,
    out: &mut Vec<ApproxRow>,
) -> anyhow::Result<()> {
    let model = CompiledModel::compile_variants(stack.clone(), approx_specs(schedule))?;
    let engine = PackedEngine::new(Arc::clone(&model));
    let n = xs.len();
    let mut ref_preds: Vec<usize> = vec![];
    for v in 0..model.n_variants() {
        let var = model.variant(v);
        let (max_err, bound) =
            check_error_bounds(workload, var.name(), &stack, var.truncation())?;
        let batch: Vec<Vec<i64>> = xs.iter().map(|r| var.quantize_row(r)).collect();
        let (got, stats) = engine.forward_batch_variant(&batch, v);
        // Certificate oracle: the variant's own (cheaper, per-bank)
        // certificate must reconstruct the measured stats under the
        // skip-conditioned upper-bound contract.
        let cert = model.cost_certificate(v);
        anyhow::ensure!(
            cert.eval_stats_with_skips(n, &stats) == stats,
            "{workload}/{}: certificate diverges from the engine",
            var.name()
        );
        let preds: Vec<usize> = got.iter().map(|l| argmax_class(l, classes)).collect();
        if v == 0 {
            ref_preds = preds.clone();
        }
        let accuracy =
            preds.iter().zip(ys).filter(|(p, y)| p == y).count() as f64 / n as f64;
        let fidelity =
            preds.iter().zip(&ref_preds).filter(|(p, r)| p == r).count() as f64 / n as f64;
        out.push(ApproxRow {
            workload,
            level: var.name().to_string(),
            accuracy,
            fidelity,
            s1_cycles_per_row: stats.s1_cycles as f64 / n as f64,
            pj_per_row: cost.batch_energy_pj(&stats) / n as f64,
            max_weight_err: max_err,
            err_bound: bound,
        });
    }
    Ok(())
}

/// Every (workload, truncation level) Pareto point, oracle-gated.
pub fn rows(cost: &CostTable) -> anyhow::Result<Vec<ApproxRow>> {
    let mut out = vec![];

    let mlp = synth_mlp_stack(8);
    let digits = Digits::standard();
    let (xs, ys) = digits.sample(SAMPLES, 0.3, 0xA07A5);
    run_workload(
        "mlp-digits",
        mlp,
        vec![LayerPrecision::new(8, 16), LayerPrecision::new(8, 16)],
        &xs,
        &ys,
        10,
        cost,
        &mut out,
    )?;

    let cnn = synth_cnn_stack(0xA07A6, 8);
    let sched = VariantSpec::standard_trio(3).swap_remove(0).schedule;
    let images = ImageSet::standard();
    let (xs, ys) = images.sample(SAMPLES, 0.3, 0xA07A7, 8);
    run_workload("cnn-synth", cnn, sched, &xs, &ys, 10, cost, &mut out)?;

    Ok(out)
}

pub fn run() -> anyhow::Result<()> {
    println!(
        "== eval approx: truncated-CSD approximation Pareto \
         ({SAMPLES} samples per workload, @1GHz) =="
    );
    let cost = CostTable::characterize(1000.0);
    let rs = rows(&cost)?;
    let trows: Vec<Vec<String>> = rs
        .iter()
        .map(|r| {
            vec![
                r.workload.to_string(),
                r.level.clone(),
                format!("{:.1}%", r.accuracy * 100.0),
                format!("{:.1}%", r.fidelity * 100.0),
                format!("{:.1}", r.s1_cycles_per_row),
                format!("{:.2}", r.pj_per_row),
                format!("{}", r.max_weight_err),
                format!("{}", r.err_bound),
            ]
        })
        .collect();
    println!(
        "{}",
        table(
            &[
                "workload",
                "trunc",
                "top-1 acc",
                "vs exact",
                "S1 cyc/row",
                "pJ/row",
                "max |Δm|",
                "bound",
            ],
            &trows
        )
    );
    println!(
        "(every weight's error held to its analytic bound; every variant's \
         certificate reconstructs the measured stats under the upper-bound \
         contract)\n"
    );
    let json_rows: Vec<String> = rs
        .iter()
        .map(|r| {
            format!(
                "    {{\"workload\": \"{}\", \"trunc\": \"{}\", \
                 \"accuracy\": {}, \"fidelity\": {}, \
                 \"s1_cycles_per_row\": {}, \"pj_per_row\": {}, \
                 \"max_weight_err\": {}, \"err_bound\": {}}}",
                r.workload,
                r.level,
                r.accuracy,
                r.fidelity,
                r.s1_cycles_per_row,
                r.pj_per_row,
                r.max_weight_err,
                r.err_bound
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"samples\": {SAMPLES},\n  \"clock_mhz\": {},\n  \"rows\": [\n{}\n  ]\n}}\n",
        cost.mhz,
        json_rows.join(",\n")
    );
    std::fs::write("EVAL_approx.json", &json)?;
    println!("approximation Pareto written to EVAL_approx.json");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approx_pareto_trades_accuracy_for_strictly_less_work() {
        let cost = CostTable::characterize(1000.0);
        let rs = rows(&cost).unwrap();
        let ladder = truncation_ladder();
        for wl in ["mlp-digits", "cnn-synth"] {
            let set: Vec<&ApproxRow> =
                rs.iter().filter(|r| r.workload == wl).collect();
            assert_eq!(set.len(), ladder.len());
            // The exact rung is its own reference: zero error, full
            // fidelity.
            assert_eq!(set[0].level, "exact");
            assert_eq!(set[0].max_weight_err, 0);
            assert_eq!(set[0].fidelity, 1.0);
            for r in &set[1..] {
                // Every approximate rung does no more Stage-1 work
                // than exact, and strictly less by the strongest cap.
                assert!(
                    r.s1_cycles_per_row <= set[0].s1_cycles_per_row,
                    "{wl}/{}: approximate rung must not exceed exact work",
                    r.level
                );
                assert!(r.max_weight_err <= r.err_bound, "{wl}/{}", r.level);
            }
            let d1 = set.last().unwrap();
            assert!(
                d1.s1_cycles_per_row < set[0].s1_cycles_per_row,
                "{wl}: d1 must bill strictly fewer Stage-1 cycles"
            );
        }
    }

    #[test]
    fn digit_cap_bound_uses_the_first_kept_position() {
        // 0b0101_0011 = 83 → CSD +2^6 +2^4 +2^2 −2^0 (all non-adjacent);
        // keep_digits(2) keeps +2^6 +2^4 (m_kept = 80), drops +2^2 −2^0
        // (error 3), and the first kept position is 4 → bound B(4) = 10.
        let plan = schedule_truncated(83, 8, Truncation::keep_digits(2));
        assert_eq!(plan.m_raw, 80);
        assert_eq!(weight_err_bound(Truncation::keep_digits(2), 80, 8), 10);
        // A fully-dropped weight falls back to the whole-word bound.
        let plan = schedule_truncated(1, 8, Truncation::drop_least(3));
        assert_eq!(plan.m_raw, 0);
        assert!(weight_err_bound(Truncation::keep_digits(1), 0, 8) >= 127);
    }
}
