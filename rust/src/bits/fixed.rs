//! Fixed-point `Q1.X` helpers.
//!
//! A `Q1.X` value has one integer (sign) bit and `X` fractional bits,
//! stored two's-complement in `X+1` bits; the representable range is
//! `[-1, 1)` with resolution `2^-X` (Section III-B).

/// A signed fixed-point value together with its total bitwidth.
///
/// `raw` is the two's-complement integer confined to `bits` bits,
/// sign-extended into the `i64`. `value = raw / 2^(bits-1)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Q {
    pub raw: i64,
    pub bits: u32,
}

impl Q {
    /// Quantize a real value to `Q1.(bits-1)` by round-to-nearest,
    /// saturating to the representable range.
    pub fn from_f64(v: f64, bits: u32) -> Q {
        Q { raw: to_q(v, bits), bits }
    }

    /// The real value represented.
    pub fn to_f64(self) -> f64 {
        from_q(self.raw, self.bits)
    }

    /// Resolution (one ULP) of this format.
    pub fn ulp(self) -> f64 {
        (-( (self.bits - 1) as f64 )).exp2()
    }
}

/// Quantize `v` ∈ ℝ to the two's-complement raw integer of `Q1.(bits-1)`,
/// rounding to nearest (ties away from zero) and saturating to
/// `[-2^(bits-1), 2^(bits-1) - 1]`.
pub fn to_q(v: f64, bits: u32) -> i64 {
    debug_assert!(bits >= 2 && bits <= 32);
    let scale = (1i64 << (bits - 1)) as f64;
    let q = (v * scale).round() as i64;
    q.clamp(-(1i64 << (bits - 1)), (1i64 << (bits - 1)) - 1)
}

/// The real value of the raw `Q1.(bits-1)` integer `raw`.
pub fn from_q(raw: i64, bits: u32) -> f64 {
    raw as f64 / (1i64 << (bits - 1)) as f64
}

/// Sign-extend the low `bits` bits of `x` into an `i64`.
#[inline]
pub fn sign_extend(x: u64, bits: u32) -> i64 {
    debug_assert!(bits >= 1 && bits <= 63);
    let shift = 64 - bits;
    ((x << shift) as i64) >> shift
}

/// Confine `x` (possibly negative) to its low `bits` bits (two's complement).
#[inline]
pub fn truncate(x: i64, bits: u32) -> u64 {
    (x as u64) & ((1u64 << bits) - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact_grid() {
        for bits in [4u32, 6, 8, 12, 16] {
            let n = 1i64 << (bits - 1);
            for raw in -n..n {
                let v = from_q(raw, bits);
                assert_eq!(to_q(v, bits), raw, "bits={bits} raw={raw}");
            }
        }
    }

    #[test]
    fn saturates_out_of_range() {
        assert_eq!(to_q(1.5, 8), 127);
        assert_eq!(to_q(-2.0, 8), -128);
        assert_eq!(to_q(0.999999, 4), 7);
    }

    #[test]
    fn sign_extend_truncate_roundtrip() {
        for bits in [4u32, 6, 8, 12, 16] {
            let n = 1i64 << (bits - 1);
            for raw in [-n, -1, 0, 1, n - 1] {
                let t = truncate(raw, bits);
                assert_eq!(sign_extend(t, bits), raw);
            }
        }
    }

    #[test]
    fn q_struct_value() {
        let q = Q::from_f64(0.5, 8);
        assert_eq!(q.raw, 64);
        assert!((q.to_f64() - 0.5).abs() < 1e-12);
    }
}
