//! The SLO-driven precision governor (DESIGN.md §13): the run-time
//! policy that picks which precision [`Variant`] of the served model
//! each dispatched batch executes at.
//!
//! The paper's repacking unit exists so sub-word bitwidth can change
//! *at run time*; precision-scalable accelerators (Moons & Verhelst's
//! 0.3–2.6 TOPS/W ConvNet processor, Ottavi et al.'s mixed-precision
//! RISC-V core) make that trade under load: when the queue grows or the
//! tail latency blows past its objective, shed operand width — each
//! step down packs more rows per 48-bit word, so the same silicon
//! clears the backlog at lower energy per row — and step back to full
//! fidelity once the pressure is gone.
//!
//! The governor is a policy object consulted at every batch dispatch
//! with the current [`LoadSignals`]; [`SloPolicy`] is the default
//! hysteresis implementation, [`PinnedVariant`] the degenerate one
//! (and the default: installing no governor serves the reference
//! variant forever, exactly the pre-§13 behavior). Decisions are
//! *advisory per batch*: the batch is tagged with the chosen variant
//! and the worker bills the variant it actually executed.
//!
//! Since DESIGN.md §15 the governor can also act **predictively**: a
//! [`CertifiedCosts`] table — certified pJ/row and datapath cycles/row
//! per variant, read off each variant's static cost certificate — lets
//! [`SloPolicy`] estimate how long the *current* queue would take to
//! drain at a candidate variant and shed **before** the p99 degrades
//! (or refuse a fidelity step-up that the certified drain time says
//! would immediately breach the objective). Without a table the policy
//! behaves exactly as before: purely reactive.
//!
//! [`Variant`]: super::model::Variant

use std::time::Duration;

use super::cost::CostTable;
use super::model::CompiledModel;

/// Load signals sampled at one dispatch decision.
#[derive(Debug, Clone, Copy)]
pub struct LoadSignals {
    /// Rows visible to the serving loop right now: the batch being
    /// dispatched, everything still pending in the batcher, and every
    /// row dispatched to a PE worker and not yet completed.
    pub queued_rows: usize,
    /// p99 request latency over the window since the previous decision
    /// (`None` when no request completed in the window — treat as "no
    /// pressure signal", not as zero latency).
    pub window_p99_ns: Option<u64>,
    /// How many precision variants the served model carries; choices
    /// are clamped to `0..n_variants` by the caller.
    pub n_variants: usize,
}

/// Per-variant certified cost figures the predictive governor consults
/// (DESIGN.md §15): steady-state pJ/row and serial datapath cycles/row,
/// evaluated from each variant's static [`CostCertificate`] at one full
/// batch quantum — no measurement involved.
///
/// [`CostCertificate`]: crate::analysis::cost::CostCertificate
#[derive(Debug, Clone)]
pub struct CertifiedCosts {
    mhz: f64,
    pj_per_row: Vec<f64>,
    cycles_per_row: Vec<f64>,
}

impl CertifiedCosts {
    /// Build from explicit figures (`cycles_per_row` / `pj_per_row`
    /// indexed by variant id, hi-fidelity first). Test/synthetic entry
    /// point; serving code uses [`CertifiedCosts::from_model`].
    pub fn new(mhz: f64, pj_per_row: Vec<f64>, cycles_per_row: Vec<f64>) -> CertifiedCosts {
        assert!(mhz > 0.0, "clock must be positive");
        assert_eq!(pj_per_row.len(), cycles_per_row.len());
        assert!(!cycles_per_row.is_empty(), "at least one variant");
        CertifiedCosts { mhz, pj_per_row, cycles_per_row }
    }

    /// Evaluate every variant's cost certificate under `cost`'s clock
    /// and energy table.
    pub fn from_model(model: &CompiledModel, cost: &CostTable) -> CertifiedCosts {
        let (pj, cycles) = (0..model.n_variants())
            .map(|v| {
                let cert = model.cost_certificate(v);
                (cert.pj_per_row(cost), cert.cycles_per_row())
            })
            .unzip();
        CertifiedCosts { mhz: cost.mhz, pj_per_row: pj, cycles_per_row: cycles }
    }

    /// Certified steady-state energy per row at variant `v`, pJ.
    pub fn pj_per_row(&self, v: usize) -> f64 {
        self.pj_per_row[v.min(self.pj_per_row.len() - 1)]
    }

    /// Certified estimate of the time to drain `rows` queued rows
    /// serially at variant `v`, nanoseconds. A deliberately simple
    /// first-order model (no parallel PEs, no batching overlap) — what
    /// the hysteresis needs is the correct *ordering* of variants and a
    /// magnitude comparable to the latency objective.
    pub fn est_drain_ns(&self, rows: usize, v: usize) -> u64 {
        let cpr = self.cycles_per_row[v.min(self.cycles_per_row.len() - 1)];
        (rows as f64 * cpr / self.mhz * 1000.0).round() as u64
    }
}

/// One tenant SLO class for fleet serving (DESIGN.md §17): the
/// watermarks and objective its per-(model, tenant) [`SloPolicy`]
/// governor instance runs with, its dispatch priority among the fleet's
/// tenant lanes, and the certified-cost admission budget — a new
/// request is shed ([`ServeError::Shed`]) when
/// [`CertifiedCosts::est_drain_ns`] of the tenant's *already-queued*
/// rows exceeds `drain_budget`. The first request of an idle tenant is
/// therefore always admitted: the budget bounds backlog, not arrival.
///
/// [`ServeError::Shed`]: super::server::ServeError::Shed
#[derive(Debug, Clone)]
pub struct SloClass {
    /// Class name (metrics bucket label, report rows).
    pub name: String,
    /// Lane service order at each deadline tick: lower = served first.
    pub priority: u8,
    /// p99 objective handed to the class's governor instances.
    pub target_p99: Duration,
    /// Shed precision above this many queued rows.
    pub high_rows: usize,
    /// Recover fidelity at or below this many queued rows.
    pub low_rows: usize,
    /// Calm decisions before one fidelity step-up (see [`SloPolicy`]).
    pub patience: u32,
    /// Admission budget: shed new work while the certified drain time
    /// of the tenant's queued rows exceeds this.
    pub drain_budget: Duration,
    /// Per-tenant batcher fill target; `None` inherits the pool's.
    pub target_rows: Option<usize>,
}

impl SloClass {
    /// A class with the given governor watermarks, priority 1, patience
    /// 2, a drain budget of 4× the objective, and the pool's default
    /// batch target.
    pub fn new(
        name: impl Into<String>,
        target_p99: Duration,
        high_rows: usize,
        low_rows: usize,
    ) -> SloClass {
        SloClass {
            name: name.into(),
            priority: 1,
            target_p99,
            high_rows: high_rows.max(1),
            low_rows: low_rows.min(high_rows).max(1),
            patience: 2,
            drain_budget: target_p99.saturating_mul(4),
            target_rows: None,
        }
    }

    /// A class whose admission never sheds and whose governor never
    /// reacts — the single-tenant [`Coordinator`] wraps its one tenant
    /// in this (its explicitly-installed policy replaces the governor).
    ///
    /// [`Coordinator`]: super::server::Coordinator
    pub fn unbounded(name: impl Into<String>) -> SloClass {
        SloClass::new(name, Duration::from_secs(3600), usize::MAX / 2, 1)
            .drain_budget(Duration::MAX)
    }

    /// Override the lane service priority (lower = served first).
    pub fn priority(mut self, priority: u8) -> SloClass {
        self.priority = priority;
        self
    }

    /// Override the governor patience (clamped to ≥ 1 by the policy).
    pub fn patience(mut self, n: u32) -> SloClass {
        self.patience = n;
        self
    }

    /// Override the certified-drain admission budget.
    pub fn drain_budget(mut self, budget: Duration) -> SloClass {
        self.drain_budget = budget;
        self
    }

    /// Override the tenant's batcher fill target.
    pub fn target_rows(mut self, rows: usize) -> SloClass {
        self.target_rows = Some(rows.max(1));
        self
    }

    /// The admission budget in nanoseconds, saturating instead of
    /// truncating (`Duration::MAX` must mean "never shed", not wrap).
    pub fn drain_budget_ns(&self) -> u64 {
        u64::try_from(self.drain_budget.as_nanos()).unwrap_or(u64::MAX)
    }

    /// Build this class's governor instance for one model: the standard
    /// hysteresis armed with that model's certified per-variant costs.
    pub fn policy(&self, certified: CertifiedCosts) -> SloPolicy {
        SloPolicy::new(self.target_p99, self.high_rows, self.low_rows)
            .patience(self.patience.max(1))
            .with_certified_costs(certified)
    }
}

/// A precision-selection policy. Implementations are consulted once
/// per dispatched batch and may keep internal state (hysteresis
/// counters, EWMAs, …). Returned ids out of range are clamped by the
/// coordinator.
pub trait GovernorPolicy: Send {
    /// Variant id the next dispatched batch should execute at.
    fn choose(&mut self, load: &LoadSignals) -> usize;
}

/// Pin one variant forever — the no-governor default, and the
/// deterministic harness for per-variant billing tests.
#[derive(Debug, Clone)]
pub struct PinnedVariant(pub usize);

impl GovernorPolicy for PinnedVariant {
    fn choose(&mut self, _load: &LoadSignals) -> usize {
        self.0
    }
}

/// The default governor: watermark hysteresis over queue depth plus a
/// p99 latency objective.
///
/// Variants are assumed ordered hi-fidelity (0) → cheapest (N−1), the
/// order [`VariantSpec::standard_trio`] produces. One step of
/// precision is shed per overloaded decision (`queued_rows` above the
/// high watermark **or** windowed p99 above the objective); one step
/// is restored only after `patience` consecutive *calm* decisions
/// (`queued_rows` at or below the low watermark **and** windowed p99
/// at or below half the objective — recovering into a still-warm
/// latency tail would oscillate). Between the watermarks the current
/// variant holds: that dead band is the hysteresis that keeps a
/// borderline load from flapping formats every batch.
///
/// [`VariantSpec::standard_trio`]: super::model::VariantSpec::standard_trio
#[derive(Debug, Clone)]
pub struct SloPolicy {
    target_p99: Duration,
    high_rows: usize,
    low_rows: usize,
    patience: u32,
    current: usize,
    calm_streak: u32,
    /// Certified per-variant cost figures for predictive decisions
    /// (`None` → purely reactive, the pre-§15 behavior).
    certified: Option<CertifiedCosts>,
}

impl SloPolicy {
    /// Shed precision above `high_rows` queued rows (or past
    /// `target_p99`); recover at or below `low_rows`. `low_rows` is
    /// clamped to `high_rows`.
    pub fn new(target_p99: Duration, high_rows: usize, low_rows: usize) -> SloPolicy {
        SloPolicy {
            target_p99,
            high_rows: high_rows.max(1),
            low_rows: low_rows.min(high_rows).max(1),
            patience: 2,
            current: 0,
            calm_streak: 0,
            certified: None,
        }
    }

    /// Arm the predictive path: shed when the certified drain time of
    /// the *current* queue at the *current* variant already exceeds the
    /// p99 objective (before any request actually misses it), and block
    /// a fidelity step-up whose certified drain time would land above
    /// half the objective (the same guard the calm condition applies to
    /// the measured tail).
    pub fn with_certified_costs(mut self, certified: CertifiedCosts) -> SloPolicy {
        self.certified = Some(certified);
        self
    }

    /// Consecutive calm decisions required before restoring one step of
    /// fidelity (default 2; clamped to ≥ 1).
    pub fn patience(mut self, n: u32) -> SloPolicy {
        self.patience = n.max(1);
        self
    }

    /// The variant the policy currently considers active.
    pub fn current(&self) -> usize {
        self.current
    }
}

impl GovernorPolicy for SloPolicy {
    fn choose(&mut self, load: &LoadSignals) -> usize {
        let cheapest = load.n_variants.saturating_sub(1);
        let target_ns = self.target_p99.as_nanos() as u64;
        // Predictive breach: the certified drain time of what is queued
        // *right now*, at the variant we are about to run, already
        // exceeds the objective — shed before any request misses it.
        let predicted_breach = self
            .certified
            .as_ref()
            .is_some_and(|c| c.est_drain_ns(load.queued_rows, self.current) > target_ns);
        let overloaded = load.queued_rows > self.high_rows
            || load.window_p99_ns.is_some_and(|p| p > target_ns)
            || predicted_breach;
        let calm = load.queued_rows <= self.low_rows
            && load.window_p99_ns.map_or(true, |p| p <= target_ns / 2);
        if overloaded {
            self.calm_streak = 0;
            if self.current < cheapest {
                self.current += 1;
            }
        } else if calm {
            self.calm_streak = self.calm_streak.saturating_add(1);
            // A step-up must also be certifiably affordable: the queue
            // drained at the *more expensive* candidate has to fit in
            // the same half-objective margin the calm condition demands
            // of the measured tail. The streak is not reset on a
            // blocked step — the moment the queue shrinks enough, the
            // restore goes through without re-serving the patience.
            let up_ok = self.current > 0
                && self.certified.as_ref().map_or(true, |c| {
                    c.est_drain_ns(load.queued_rows, self.current - 1) <= target_ns / 2
                });
            if self.calm_streak >= self.patience && up_ok {
                self.current -= 1;
                self.calm_streak = 0;
            }
        } else {
            // The dead band between the watermarks: hold and restart
            // the calm count — recovery needs *consecutive* calm.
            self.calm_streak = 0;
        }
        self.current.min(cheapest)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(queued: usize, p99_ns: Option<u64>) -> LoadSignals {
        LoadSignals { queued_rows: queued, window_p99_ns: p99_ns, n_variants: 3 }
    }

    #[test]
    fn pinned_never_moves() {
        let mut p = PinnedVariant(1);
        assert_eq!(p.choose(&sig(0, None)), 1);
        assert_eq!(p.choose(&sig(10_000, Some(u64::MAX))), 1);
    }

    #[test]
    fn step_load_sheds_then_recovers_with_hysteresis() {
        // The acceptance trace in miniature: light → overload → light.
        let mut g = SloPolicy::new(Duration::from_millis(1), 100, 20).patience(2);
        // Light load: stays at full fidelity.
        for _ in 0..5 {
            assert_eq!(g.choose(&sig(5, Some(10_000))), 0);
        }
        // Step overload: sheds one step per decision down to cheapest,
        // and no further.
        assert_eq!(g.choose(&sig(500, Some(10_000))), 1);
        assert_eq!(g.choose(&sig(500, None)), 2);
        assert_eq!(g.choose(&sig(500, None)), 2, "clamps at the cheapest variant");
        // Load drops into the dead band: hold (no flapping).
        assert_eq!(g.choose(&sig(50, Some(10_000))), 2);
        assert_eq!(g.choose(&sig(50, None)), 2);
        // Calm: one step of fidelity back per `patience` calm decisions.
        assert_eq!(g.choose(&sig(5, Some(10_000))), 2, "calm 1 of 2");
        assert_eq!(g.choose(&sig(5, None)), 1, "calm 2 of 2 → step up");
        assert_eq!(g.choose(&sig(5, None)), 1, "calm 1 of 2 again");
        assert_eq!(g.choose(&sig(5, None)), 0, "back at full fidelity");
        assert_eq!(g.choose(&sig(5, None)), 0, "and stays there");
    }

    #[test]
    fn latency_breach_sheds_even_with_a_short_queue() {
        let mut g = SloPolicy::new(Duration::from_micros(100), 1_000_000, 10);
        // Queue is empty but the tail blew the objective: shed anyway.
        assert_eq!(g.choose(&sig(0, Some(200_000))), 1);
        // A calm window with p99 ≤ target/2 recovers (after patience).
        assert_eq!(g.choose(&sig(0, Some(40_000))), 1);
        assert_eq!(g.choose(&sig(0, Some(40_000))), 0);
        // p99 in (target/2, target]: dead band — calm streak resets.
        let mut h = SloPolicy::new(Duration::from_micros(100), 1_000_000, 10);
        assert_eq!(h.choose(&sig(0, Some(200_000))), 1);
        assert_eq!(h.choose(&sig(0, Some(40_000))), 1, "calm 1 of 2");
        assert_eq!(h.choose(&sig(0, Some(80_000))), 1, "dead band resets calm");
        assert_eq!(h.choose(&sig(0, Some(40_000))), 1, "calm 1 of 2 again");
        assert_eq!(h.choose(&sig(0, Some(40_000))), 0);
    }

    #[test]
    fn quiet_windows_count_as_calm_on_queue_alone() {
        let mut g = SloPolicy::new(Duration::from_millis(1), 100, 20).patience(1);
        assert_eq!(g.choose(&sig(500, None)), 1);
        // No completions in the window (p99 None) and an empty queue:
        // calm — recovery must not deadlock on a silent window.
        assert_eq!(g.choose(&sig(0, None)), 0);
    }

    #[test]
    fn certified_costs_shed_before_the_tail_degrades() {
        // 50 queued rows at the hi-fi variant's certified 100
        // cycles/row @ 1 GHz = 5 µs of drain against a 2 µs objective:
        // the policy sheds on the *prediction* — the measured p99 is
        // still silent and the queue is far below the high watermark.
        let certified =
            CertifiedCosts::new(1000.0, vec![30.0, 6.0, 1.2], vec![100.0, 20.0, 4.0]);
        assert_eq!(certified.est_drain_ns(50, 0), 5_000);
        assert_eq!(certified.pj_per_row(99), 1.2, "variant ids clamp");
        let mut g = SloPolicy::new(Duration::from_micros(2), 100, 10)
            .with_certified_costs(certified);
        assert_eq!(g.choose(&sig(50, None)), 1, "predictive shed");
        // At the shed variant the same queue drains in 1 µs — no longer
        // a predicted breach, but still above the low watermark: dead
        // band, hold.
        assert_eq!(g.choose(&sig(50, None)), 1);
    }

    #[test]
    fn certified_costs_block_a_step_up_the_queue_cannot_afford() {
        let certified = CertifiedCosts::new(1000.0, vec![30.0, 6.0], vec![100.0, 20.0]);
        let mut g = SloPolicy::new(Duration::from_micros(2), 1000, 100)
            .patience(1)
            .with_certified_costs(certified);
        assert_eq!(g.choose(&sig(50, None)), 1, "predicted breach sheds");
        // Calm by every reactive measure, but 30 rows at the hi-fi
        // variant would drain in 3 µs > target/2: the restore is held.
        assert_eq!(g.choose(&sig(30, None)), 1, "step-up blocked by the certificate");
        // Once the queue shrinks enough the restore goes through
        // immediately — the blocked decisions still counted as calm.
        assert_eq!(g.choose(&sig(5, None)), 0, "affordable step-up proceeds");
    }

    #[test]
    fn from_model_orders_variants_cheapest_last() {
        use crate::nn::conv::LayerOp;
        use crate::testutil::{flat_cost, random_dense_stack_uniform};
        use crate::workload::synth::XorShift64;
        let mut rng = XorShift64::new(0x60BE);
        let layers = random_dense_stack_uniform(&mut rng, &[6, 5, 4], 8);
        let ops: Vec<LayerOp> = layers.into_iter().map(LayerOp::Dense).collect();
        let model = crate::coordinator::model::CompiledModel::compile_variants(
            ops,
            crate::coordinator::model::VariantSpec::standard_trio(2),
        )
        .unwrap();
        let certified = CertifiedCosts::from_model(&model, &flat_cost());
        // hifi-8 runs every lane at 8 bits; turbo packs 4-bit lanes —
        // fewer words per row, so certified pJ/row must strictly drop.
        assert!(
            certified.pj_per_row(0) > certified.pj_per_row(2),
            "hifi {} pJ/row vs turbo {} pJ/row",
            certified.pj_per_row(0),
            certified.pj_per_row(2)
        );
        assert!(certified.est_drain_ns(100, 0) > certified.est_drain_ns(100, 2));
        assert_eq!(certified.est_drain_ns(0, 0), 0);
    }

    #[test]
    fn slo_class_builders_clamp_and_saturate() {
        let c = SloClass::new("bulk", Duration::from_millis(2), 10, 50);
        assert_eq!(c.low_rows, 10, "low watermark clamps to high");
        assert_eq!(c.drain_budget_ns(), 8_000_000, "default budget = 4x objective");
        let u = SloClass::unbounded("default");
        assert_eq!(u.drain_budget_ns(), u64::MAX, "Duration::MAX saturates, never wraps");
        let p = c.clone().priority(0).patience(5).target_rows(0);
        assert_eq!(p.priority, 0);
        assert_eq!(p.target_rows, Some(1), "explicit target clamps to >= 1");
        // The derived policy is the standard hysteresis armed with the
        // model's certified costs.
        let mut pol = p.policy(CertifiedCosts::new(1000.0, vec![1.0], vec![1.0]));
        assert_eq!(pol.choose(&sig(0, None)), 0);
    }

    #[test]
    fn choices_clamp_to_the_variant_count() {
        let mut g = SloPolicy::new(Duration::from_millis(1), 10, 2);
        let two = LoadSignals { queued_rows: 999, window_p99_ns: None, n_variants: 2 };
        assert_eq!(g.choose(&two), 1);
        assert_eq!(g.choose(&two), 1, "never past n_variants - 1");
        let one = LoadSignals { queued_rows: 999, window_p99_ns: None, n_variants: 1 };
        assert_eq!(g.choose(&one), 0, "single-variant models never switch");
    }
}
