//! Levelized zero-delay simulation with toggle counting.
//!
//! One `eval` = one clock cycle's combinational settle. Toggles are
//! counted per evaluation; the energy model multiplies by per-kind
//! switched capacitance and a block-level glitch factor (zero-delay
//! simulation sees no glitches; see `energy::tech`).

use super::gate::{CellKind, Netlist};

/// Simulator state for one netlist instance.
pub struct Simulator {
    values: Vec<bool>,
    pending: Option<Vec<bool>>,
    /// Optional per-cell toggle energies (fJ); accumulate `energy_fj`.
    weights: Option<Vec<f32>>,
    /// Total cell-output toggles since reset.
    pub toggles: u64,
    /// Weighted toggle energy since reset, fJ (0 unless weighted).
    pub energy_fj: f64,
    /// Evaluations performed.
    pub evals: u64,
}

impl Simulator {
    pub fn new(net: &Netlist) -> Self {
        Simulator {
            values: vec![false; net.cells.len()],
            pending: None,
            weights: None,
            toggles: 0,
            energy_fj: 0.0,
            evals: 0,
        }
    }

    /// Simulator that accumulates per-toggle energy with the given
    /// per-cell weights (fJ per output toggle).
    pub fn with_weights(net: &Netlist, weights: Vec<f32>) -> Self {
        assert_eq!(weights.len(), net.cells.len());
        let mut s = Simulator::new(net);
        s.weights = Some(weights);
        s
    }

    /// Drive primary inputs (in declaration order) for the next `eval`.
    pub fn set_inputs(&mut self, ins: &[bool]) {
        self.pending = Some(ins.to_vec());
    }

    /// Drive inputs from u64 buses (LSB-first), concatenated in order.
    pub fn set_inputs_u64(&mut self, buses: &[(u64, u32)]) {
        let mut ins = Vec::new();
        for &(val, width) in buses {
            for i in 0..width {
                ins.push((val >> i) & 1 != 0);
            }
        }
        self.set_inputs(&ins);
    }

    /// Evaluate the netlist; returns this cycle's toggle count.
    ///
    /// Hot path of the figure harness: cell operand indices are
    /// topologically ordered by construction (`NetBuilder` asserts it),
    /// so the indexed reads below never fail their bounds checks
    /// (DESIGN.md §9). Plain indexing — the crate denies `unsafe_code`,
    /// and the predictable in-bounds branches cost little here.
    pub fn eval(&mut self, net: &Netlist) -> u64 {
        let pending = self.pending.take().expect("set_inputs before eval");
        assert_eq!(pending.len(), net.inputs.len(), "input width mismatch");
        assert_eq!(self.values.len(), net.cells.len(), "netlist mismatch");
        let mut cycle_toggles = 0u64;
        let mut in_idx = 0usize;
        let v = &mut self.values;
        for (i, cell) in net.cells.iter().enumerate() {
            // Builder guarantees a/b/sel < i ≤ values.len().
            let rd = |idx: u32| v[idx as usize];
            let new = match cell.kind {
                CellKind::Input => {
                    let x = pending[in_idx];
                    in_idx += 1;
                    x
                }
                CellKind::Const0 => false,
                CellKind::Const1 => true,
                CellKind::Inv => !rd(cell.a),
                CellKind::Buf => rd(cell.a),
                CellKind::And2 => rd(cell.a) & rd(cell.b),
                CellKind::Or2 => rd(cell.a) | rd(cell.b),
                CellKind::Nand2 => !(rd(cell.a) & rd(cell.b)),
                CellKind::Nor2 => !(rd(cell.a) | rd(cell.b)),
                CellKind::Xor2 => rd(cell.a) ^ rd(cell.b),
                CellKind::Xnor2 => !(rd(cell.a) ^ rd(cell.b)),
                CellKind::Mux2 => {
                    if rd(cell.sel) {
                        rd(cell.b)
                    } else {
                        rd(cell.a)
                    }
                }
            };
            if new != v[i] && !matches!(cell.kind, CellKind::Input) {
                cycle_toggles += 1;
                if let Some(w) = &self.weights {
                    self.energy_fj += w[i] as f64;
                }
            }
            v[i] = new;
        }
        self.toggles += cycle_toggles;
        self.evals += 1;
        cycle_toggles
    }

    /// Read output `idx`.
    pub fn output(&self, net: &Netlist, idx: usize) -> bool {
        self.values[net.outputs[idx] as usize]
    }

    /// Read outputs `lo..lo+width` as a u64 bus (LSB-first).
    pub fn output_u64(&self, net: &Netlist, lo: usize, width: u32) -> u64 {
        let mut v = 0u64;
        for i in 0..width as usize {
            if self.values[net.outputs[lo + i] as usize] {
                v |= 1 << i;
            }
        }
        v
    }

    pub fn reset_counters(&mut self) {
        self.toggles = 0;
        self.energy_fj = 0.0;
        self.evals = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtl::build::NetBuilder;

    fn xor_chain(n: usize) -> Netlist {
        let mut b = NetBuilder::new("chain");
        let ins = b.inputs(n);
        let mut acc = ins[0];
        for &i in &ins[1..] {
            acc = b.xor2(acc, i);
        }
        b.output(acc);
        b.finish()
    }

    #[test]
    fn toggle_counting_is_incremental() {
        let net = xor_chain(8);
        let mut sim = Simulator::new(&net);
        sim.set_inputs(&[false; 8]);
        sim.eval(&net); // settle from all-false init: zero toggles
        assert_eq!(sim.toggles, 0);
        sim.set_inputs(&[true, false, false, false, false, false, false, false]);
        let t = sim.eval(&net);
        // Flipping in0 ripples through all 7 XORs.
        assert_eq!(t, 7);
        sim.set_inputs(&[true, false, false, false, false, false, false, false]);
        assert_eq!(sim.eval(&net), 0, "same inputs, no toggles");
    }

    #[test]
    fn bus_io_roundtrip() {
        let mut b = NetBuilder::new("pass");
        let ins = b.inputs(48);
        for &i in &ins {
            let bufed = b.buf(i);
            b.output(bufed);
        }
        let net = b.finish();
        let mut sim = Simulator::new(&net);
        let val = 0xABCD_1234_5678u64 & ((1 << 48) - 1);
        sim.set_inputs_u64(&[(val, 48)]);
        sim.eval(&net);
        assert_eq!(sim.output_u64(&net, 0, 48), val);
    }
}
