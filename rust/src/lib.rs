//! # softsimd — A Soft SIMD Based Energy Efficient Computing Microarchitecture
//!
//! Reproduction of Yu et al., *"A Soft SIMD Based Energy Efficient
//! Computing Microarchitecture"* (cs.AR 2022): a bit-accurate and
//! cycle-accurate model of the paper's two-stage pipeline (Soft SIMD
//! shift-add arithmetic with CSD-coded multipliers + a repacking
//! crossbar), a gate-level 28nm cost substrate replacing the paper's
//! synthesis flow, the two Hard SIMD baselines, the complete evaluation
//! harness for Figs. 6–10, and a near-memory coordinator that runs
//! quantized NN workloads on arrays of simulated pipelines.
//!
//! The functional golden model of the arithmetic is authored in JAX +
//! Pallas (`python/compile/`), AOT-lowered to HLO text at build time and
//! executed from Rust through PJRT (`runtime`) — Python is never on the
//! request path.
//!
//! ## Layer map
//! * [`bits`], [`csd`], [`isa`], [`pipeline`] — the architecture model.
//! * [`rtl`], [`energy`], [`hardsimd`] — the synthesis/cost substrate.
//! * [`eval`] — regenerates every figure of the paper's evaluation.
//! * [`coordinator`], [`nn`], [`quant`], [`workload`] — the near-memory
//!   accelerator runtime and its ML workloads.
//! * [`runtime`] — PJRT loader for the AOT JAX/Pallas artifacts.
//! * [`analysis`] — static lane-safety verification of precision
//!   schedules (DESIGN.md §14).

// The nightly `std::simd` variant of the host-vector backend
// (`--features simd-nightly`; `bits::swarx`) needs the portable_simd
// gate. Stable builds (including `--features simd`) never see this.
#![cfg_attr(feature = "simd-nightly", feature(portable_simd))]
// Lane isolation is enforced by software masks; an `unsafe` block could
// sidestep both them and the verifier, so the crate denies unsafe code.
// Documented allowlist (each site carries its own `allow` + safety
// rationale):
//  * `testutil::CountingAlloc` — implementing `GlobalAlloc` is
//    inherently unsafe;
//  * `bits::swarx::avx2` (`--features simd`) — stable AVX2 intrinsics
//    behind `#[target_feature]`, reachable only after run-time
//    `is_x86_feature_detected!` dispatch.
#![deny(unsafe_code)]
// New modules are fully documented; the pre-existing modules below
// carry per-module `allow`s until their item docs are backfilled
// (tracked in ROADMAP.md). `analysis` is held to the lint;
// `bits::lanecheck` is documented to the same standard but sits under
// `bits`' allow.
#![deny(missing_docs)]

pub mod analysis;
#[allow(missing_docs)]
pub mod anyhow;
#[allow(missing_docs)]
pub mod bits;
#[allow(missing_docs)]
pub mod coordinator;
#[allow(missing_docs)]
pub mod csd;
#[allow(missing_docs)]
pub mod energy;
#[allow(missing_docs)]
pub mod eval;
#[allow(missing_docs)]
pub mod hardsimd;
#[allow(missing_docs)]
pub mod isa;
#[allow(missing_docs)]
pub mod nn;
#[allow(missing_docs)]
pub mod pipeline;
#[allow(missing_docs)]
pub mod quant;
#[allow(missing_docs)]
pub mod rtl;
#[allow(missing_docs)]
pub mod runtime;
#[allow(missing_docs)]
pub mod testutil;
#[allow(missing_docs)]
pub mod workload;
