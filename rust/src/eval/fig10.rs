//! Fig. 10 — average energy per sub-word multiplication across
//! application scenarios at 1 GHz: the flexibility story. Soft SIMD
//! scales gracefully with per-layer bitwidths; the flexible Hard SIMD
//! consistently underperforms even the lean {8,16} one.

use crate::anyhow;
use crate::energy::model::SynthesizedSoftPipeline;
use crate::energy::report::{pj, table};
use crate::hardsimd::pipeline::{HardSimdPipeline, HARD_FLEX, HARD_TWO};
use crate::workload::synth::{Scenario, XorShift64};

pub const MHZ: f64 = 1000.0;
pub const N_WORDS: usize = 150;

/// Scenario-average pJ per sub-word multiplication; None if any layer
/// is unsupported by the design.
pub fn scenario_avg(
    scenario: &Scenario,
    mut energy: impl FnMut(u32, u32) -> Option<f64>,
) -> Option<f64> {
    let mut weighted = 0.0;
    let total: u64 = scenario.total_mults();
    for l in &scenario.layers {
        let e = energy(l.x_bits, l.y_bits)?;
        weighted += e * l.mults as f64;
    }
    Some(weighted / total as f64)
}

pub struct Fig10Row {
    pub scenario: String,
    pub soft: Option<f64>,
    pub flex: Option<f64>,
    pub two: Option<f64>,
}

pub fn rows() -> Vec<Fig10Row> {
    let mut soft = SynthesizedSoftPipeline::new(MHZ);
    let mut flex = HardSimdPipeline::new(HARD_FLEX, MHZ);
    let mut two = HardSimdPipeline::new(HARD_TWO, MHZ);
    let mut rng = XorShift64::new(0xF16_10);
    Scenario::standard_set()
        .iter()
        .map(|sc| Fig10Row {
            scenario: sc.name.to_string(),
            soft: scenario_avg(sc, |x, y| soft.subword_mult_energy_pj(x, y, N_WORDS, &mut rng)),
            flex: scenario_avg(sc, |x, y| flex.subword_mult_energy_pj(x, y, N_WORDS, &mut rng)),
            two: scenario_avg(sc, |x, y| two.subword_mult_energy_pj(x, y, N_WORDS, &mut rng)),
        })
        .collect()
}

pub fn run() -> anyhow::Result<()> {
    println!("== Fig. 10: average energy per sub-word multiplication by scenario (pJ, @1GHz) ==");
    let rs = rows();
    let trows: Vec<Vec<String>> = rs
        .iter()
        .map(|r| {
            let f = |v: Option<f64>| v.map(pj).unwrap_or_else(|| "-".into());
            vec![r.scenario.clone(), f(r.soft), f(r.flex), f(r.two)]
        })
        .collect();
    println!(
        "{}",
        table(
            &["scenario", "Soft SIMD", "Hard(4,6,8,12,16)", "Hard(8,16)"],
            &trows
        )
    );
    println!(
        "(paper: Hard SIMD (4,6,8,12,16) consistently underperforms Hard (8,16);\n\
         Soft SIMD scales gracefully across bitwidths)\n"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_flex_consistently_worse_than_two() {
        for r in rows() {
            let (Some(flex), Some(two)) = (r.flex, r.two) else {
                continue;
            };
            assert!(
                flex > two,
                "scenario {}: flex {flex} must exceed two {two}",
                r.scenario
            );
        }
    }

    #[test]
    fn fig10_soft_wins_low_precision_scenarios() {
        let rs = rows();
        let uniform4 = rs.iter().find(|r| r.scenario == "uniform-4b").unwrap();
        assert!(uniform4.soft.unwrap() < 0.5 * uniform4.two.unwrap());
        assert!(uniform4.soft.unwrap() < 0.5 * uniform4.flex.unwrap());
    }
}
