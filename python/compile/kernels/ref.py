"""Pure-jnp correctness oracle for the Soft SIMD kernels.

Vectorized (non-Pallas) implementation of the packed Stage-1 datapath and
of the scalar-semantics quantized layer. The Pallas kernels in
`softsimd.py` must agree bit-exactly with these functions, which in turn
mirror the plain-int semantics of `..defs` (hypothesis tests sweep both
pivots).

All packed words are `uint64` confined to the low 48 bits.
"""

from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from .. import defs

_WORD_MASK = defs.WORD_MASK  # python int: inlined at trace time (pallas cannot capture outer arrays)


def _u64(x: int) -> jnp.ndarray:
    return jnp.uint64(x)


# --------------------------------------------------------------------------
# SWAR primitives over uint64 words (vectorized over any leading shape)
# --------------------------------------------------------------------------


def swar_add(a, c, h):
    """Per-sub-word add with carry kill at MSB-mask positions `h`."""
    nh = (~h) & _WORD_MASK
    return (((a & nh) + (c & nh)) ^ ((a ^ c) & h)) & _WORD_MASK


def swar_neg(c, h, l):
    """Per-sub-word negation: complement + LSB-mask injection."""
    return swar_add((~c) & _WORD_MASK, l, h)


def swar_sub(a, c, h, l):
    return swar_add(a, swar_neg(c, h, l), h)


def _keep_mask(h, k: int):
    """keep_k = ~OR_{j<k}(h >> j), confined to the datapath."""
    excl = jnp.zeros_like(h)
    for j in range(k):
        excl = excl | (h >> j)
    return (~excl) & _WORD_MASK


def swar_sar(a, k: int, h):
    """Per-sub-word arithmetic shift right by static k ∈ {1..3}."""
    assert 1 <= k <= defs.MAX_SHIFT
    signs = a & h
    fill = jnp.zeros_like(a)
    for j in range(k):
        fill = fill | (signs >> j)
    return ((a >> k) & _keep_mask(h, k)) | fill


def _fused_core(w, true_sign_bits, k: int, h):
    if k == 0:
        return w
    fill = jnp.zeros_like(w)
    for j in range(k):
        fill = fill | (true_sign_bits >> j)
    return ((w >> k) & _keep_mask(h, k)) | fill


def swar_add_sar(a, c, k: int, h):
    """Fused `(a + c) >>_arith k` with (b+1)-bit intermediate (static k)."""
    w = swar_add(a, c, h)
    ovf = (~(a ^ c)) & (a ^ w) & h
    return _fused_core(w, (w & h) ^ ovf, k, h)


def swar_sub_sar(a, c, k: int, h, l):
    w = swar_sub(a, c, h, l)
    ovf = (a ^ c) & (a ^ w) & h
    return _fused_core(w, (w & h) ^ ovf, k, h)


# --------------------------------------------------------------------------
# Packed multiply: reference with *static* plan (host loop over ops)
# --------------------------------------------------------------------------


def mul_packed_ref(x_words, m_raw: int, y_bits: int, fmt_bits: int):
    """Multiply every sub-word of each packed word by the scalar
    multiplier `m_raw` — host-unrolled plan, static shifts."""
    fmt = defs.SimdFormat(fmt_bits)
    h = _u64(fmt.msb_mask)
    l = _u64(fmt.lsb_mask)
    acc = jnp.zeros_like(x_words)
    for shift, sign in defs.schedule(m_raw, y_bits):
        if sign > 0:
            acc = swar_add_sar(acc, x_words, shift, h)
        elif sign < 0:
            acc = swar_sub_sar(acc, x_words, shift, h, l)
        else:
            acc = swar_sar(acc, shift, h)
    return acc


# --------------------------------------------------------------------------
# Packed multiply: reference with *runtime* plan tensors — the exact
# computation the AOT mul artifact performs (dynamic shift/sign selection).
# --------------------------------------------------------------------------


def dynamic_mul_step(acc, x_words, shift, sign, h, l):
    """One uniform multiply cycle `acc ← (acc + sign·X) >>_wide shift`
    with runtime `shift` ∈ 0..3 and `sign` ∈ {−1,0,+1} (branchless)."""
    w_add = swar_add(acc, x_words, h)
    ovf_a = (~(acc ^ x_words)) & (acc ^ w_add) & h
    s_add = (w_add & h) ^ ovf_a
    w_sub = swar_sub(acc, x_words, h, l)
    ovf_s = (acc ^ x_words) & (acc ^ w_sub) & h
    s_sub = (w_sub & h) ^ ovf_s
    w = jnp.where(sign > 0, w_add, jnp.where(sign < 0, w_sub, acc))
    sb = jnp.where(sign > 0, s_add, jnp.where(sign < 0, s_sub, acc & h))
    out = w
    for k in (1, 2, 3):
        out = jnp.where(shift == k, _fused_core(w, sb, k, h), out)
    return out


def mul_packed_dynamic_ref(x_words, shifts, signs, h, l):
    """`x_words: u64[N]`, `shifts: i32[OPS]` ∈ 0..3, `signs: i32[OPS]` ∈
    {-1,0,1}; `h`, `l`: u64 scalar masks. Returns u64[N] products.

    Padding entries (0, 0) are no-ops. This is the computation the AOT
    `mul` artifact performs; the Pallas kernel must match it bit-exactly.
    """

    def step(acc, op):
        shift, sign = op
        return dynamic_mul_step(acc, x_words, shift, sign, h, l), None

    acc0 = jnp.zeros_like(x_words)
    acc, _ = jax.lax.scan(step, acc0, (shifts, signs))
    return acc


# --------------------------------------------------------------------------
# Quantized layer (scalar semantics, vectorized): reference for the MLP
# --------------------------------------------------------------------------


def wrap_to(acc, bits: int):
    """Two's-complement wrap of int32 values to `bits` bits."""
    mask = jnp.int32((1 << bits) - 1)
    half = jnp.int32(1 << (bits - 1))
    w = acc & mask
    return w - ((w & half) << 1)


def layer_ref(x_q, shifts, signs, in_bits: int = 8, acc_bits: int = 16):
    """One quantized linear layer with Soft SIMD multiply semantics.

    x_q:    int32[M, K]    activations, Q1.(in_bits-1) raws
    shifts: int32[K, N, O] per-weight plan shift amounts
    signs:  int32[K, N, O] per-weight plan signs (−1/0/+1)
    Returns int32[M, N] pre-activation accumulators, Q1.(acc_bits-1) raws.

    Products are computed at `in_bits`, repacked (widened) to `acc_bits`
    (exact: `<< (acc_bits − in_bits)`), and accumulated with wrapping
    `acc_bits`-bit adds — the Stage-2 8→16 conversion of DESIGN.md §4.
    """
    O = shifts.shape[-1]
    x = x_q[:, :, None].astype(jnp.int32)  # [M, K, 1]
    acc0 = jnp.zeros(x_q.shape + (shifts.shape[1],), dtype=jnp.int32)  # [M,K,N]

    def step(acc, o):
        s = shifts[:, :, o][None, :, :]
        g = signs[:, :, o][None, :, :]
        a = acc + g * x
        a = jnp.right_shift(a, s)
        return wrap_to(a, in_bits), None

    acc, _ = jax.lax.scan(step, acc0, jnp.arange(O))
    prod_wide = acc << (acc_bits - in_bits)  # widen repack (exact)
    total = jnp.sum(prod_wide, axis=1, dtype=jnp.int32)  # [M, N]
    return wrap_to(total, acc_bits)


def relu_requant_ref(acc16, out_bits: int = 8, acc_bits: int = 16):
    """ReLU then narrow-repack (truncate) `acc_bits → out_bits`."""
    r = jnp.maximum(acc16, 0)
    return jnp.right_shift(r, acc_bits - out_bits)


def mlp_ref(x_q, layer_plans):
    """Full MLP forward; `layer_plans` = [(shifts, signs), ...]. Returns
    int32[M, N_last] Q1.15 logits (no activation on the last layer)."""
    h = x_q
    for i, (shifts, signs) in enumerate(layer_plans):
        acc = layer_ref(h, shifts, signs)
        if i + 1 < len(layer_plans):
            h = relu_requant_ref(acc)
        else:
            return acc
    return h
