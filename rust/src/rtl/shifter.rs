//! The configurable shifter of Fig. 4b and the fused Stage-1 datapath.
//!
//! The shifter is three cascaded shift-by-1 stages ("further
//! combinatorial stages of 1-bit muxes", Section III-B); a thermometer
//! enable `en[0..3]` selects the distance `k = en0+en1+en2`. At sub-word
//! MSB positions a `V_x` mux holds the sign instead of taking the next
//! bit; only bit positions that can be a sub-word MSB in *some*
//! supported format carry that mux ("muxes can be employed selectively",
//! Section III-B) — others hard-wire the shift path.
//!
//! The first stage's sign source is the *carry-corrected* sum
//! (`sum ⊕ ovf`) from the adder — the (b+1)-bit intermediate of
//! DESIGN.md §4; later stages replicate the already-correct MSB.

use super::adder::{self, AdderIo};
use super::build::NetBuilder;
use super::gate::{Netlist, NodeId};
use crate::bits::format::{SimdFormat, DATAPATH_BITS};

/// Bit positions that are a sub-word MSB in at least one supported
/// format — the only positions needing a sign-hold mux.
pub fn msb_capable_positions() -> Vec<usize> {
    let mut set = vec![false; DATAPATH_BITS as usize];
    for fmt in SimdFormat::all() {
        for i in 0..fmt.lanes() {
            set[((i + 1) * fmt.bits - 1) as usize] = true;
        }
    }
    (0..DATAPATH_BITS as usize).filter(|&i| set[i]).collect()
}

/// One shift-by-1 stage. `sign_src[i]` supplies the replicated value at
/// MSB-capable positions (the `V_x` mux input of Fig. 4b).
fn shift_stage(
    b: &mut NetBuilder,
    data: &[NodeId],
    sign_src: &[NodeId],
    m: &[NodeId],
    en: NodeId,
    capable: &[bool],
) -> Vec<NodeId> {
    let w = data.len();
    let mut out = Vec::with_capacity(w);
    for i in 0..w {
        let shifted = if i + 1 < w {
            if capable[i] {
                // At a potential MSB: hold sign when m_i=1, else take bit i+1.
                b.mux2(m[i], data[i + 1], sign_src[i])
            } else {
                data[i + 1]
            }
        } else {
            // Top bit: always an MSB (of the widest lane) — replicate sign.
            sign_src[i]
        };
        out.push(b.mux2(en, data[i], shifted));
    }
    out
}

/// Emit the 3-stage configurable shifter over existing nets.
/// `corrected[i]` is the stage-1 sign source (sum ⊕ ovf); stages 2–3 use
/// their own input's MSB.
pub fn build_shifter(
    b: &mut NetBuilder,
    data: &[NodeId],
    corrected: &[NodeId],
    m: &[NodeId],
    en: &[NodeId; 3],
) -> Vec<NodeId> {
    let w = data.len();
    let mut capable = vec![false; w];
    for p in msb_capable_positions() {
        capable[p] = true;
    }
    let s1 = shift_stage(b, data, corrected, m, en[0], &capable);
    let s2 = shift_stage(b, &s1.clone(), &s1, m, en[1], &capable);
    let s3 = shift_stage(b, &s2.clone(), &s2, m, en[2], &capable);
    s3
}

/// The complete fused Stage-1 datapath netlist (configurable adder →
/// configurable shifter), one clock cycle of the multiply loop.
///
/// Input order: a[48] (acc), c[48] (X), add_en, sub, m[48], l[48],
/// en[3] (thermometer shift enable). Output: out[48].
pub fn stage1_datapath(select_adder: bool) -> Netlist {
    let mut b = NetBuilder::new(if select_adder {
        "softsimd_stage1_cs"
    } else {
        "softsimd_stage1"
    });
    let io: AdderIo = adder::declare_inputs(&mut b, DATAPATH_BITS as usize);
    let en = [b.input(), b.input(), b.input()];
    let (sums, ovfs) = if select_adder {
        adder::build_carry_select(&mut b, &io, 4)
    } else {
        adder::build_ripple(&mut b, &io)
    };
    // Carry-corrected sign at MSB-capable positions: sum ⊕ ovf.
    let capable_pos = msb_capable_positions();
    let mut corrected = sums.clone();
    for &p in &capable_pos {
        corrected[p] = b.xor2(sums[p], ovfs[p]);
    }
    let out = build_shifter(&mut b, &sums, &corrected, &io.m, &en);
    b.outputs(&out);
    b.finish()
}

/// Drive a Stage-1 netlist for one cycle. `sign`: +1 add, −1 sub,
/// 0 shift-only; `k`: shift distance 0..=3.
pub fn drive_stage1(
    sim: &mut super::sim::Simulator,
    net: &Netlist,
    acc: u64,
    x: u64,
    k: u32,
    sign: i8,
    fmt: SimdFormat,
) -> u64 {
    let mut ins = Vec::with_capacity(148 + 3);
    for i in 0..48 {
        ins.push((acc >> i) & 1 != 0);
    }
    for i in 0..48 {
        ins.push((x >> i) & 1 != 0);
    }
    ins.push(sign != 0); // add_en
    ins.push(sign < 0); // sub
    let m = fmt.msb_mask();
    let l = fmt.lsb_mask();
    for i in 0..48 {
        ins.push((m >> i) & 1 != 0);
    }
    for i in 0..48 {
        ins.push((l >> i) & 1 != 0);
    }
    for s in 0..3 {
        ins.push(s < k);
    }
    sim.set_inputs(&ins);
    sim.eval(net);
    sim.output_u64(net, 0, 48)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::swar::{swar_add_sar, swar_sar, swar_sub_sar};
    use crate::rtl::sim::Simulator;
    use crate::rtl::timing::depth;
    use crate::workload::synth::XorShift64;

    #[test]
    fn msb_capable_set_is_union() {
        let pos = msb_capable_positions();
        assert!(pos.contains(&3) && pos.contains(&5) && pos.contains(&7));
        assert!(pos.contains(&47));
        assert!(!pos.contains(&0) && !pos.contains(&1) && !pos.contains(&2));
        // 4k-1, 6k-1, 8k-1, 12k-1, 16k-1 unions: spot-check absence.
        assert!(!pos.contains(&4));
        assert!(!pos.contains(&6));
    }

    #[test]
    fn stage1_matches_fused_swar_everywhere() {
        for select in [false, true] {
            let net = stage1_datapath(select);
            let mut sim = Simulator::new(&net);
            let mut rng = XorShift64::new(0x57A6E1);
            for fmt in SimdFormat::all() {
                for _ in 0..80 {
                    let acc = rng.word();
                    let x = rng.word();
                    for k in 0..=3u32 {
                        for sign in [-1i8, 0, 1] {
                            if sign == 0 && k == 0 {
                                continue; // no-op cycle never issued
                            }
                            let got = drive_stage1(&mut sim, &net, acc, x, k, sign, fmt);
                            let want = match sign {
                                1 => swar_add_sar(acc, x, k, fmt),
                                -1 => swar_sub_sar(acc, x, k, fmt),
                                _ => swar_sar(acc, k, fmt),
                            };
                            assert_eq!(
                                got, want,
                                "select={select} fmt {fmt} k {k} sign {sign} acc {acc:#x} x {x:#x}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn carry_select_variant_is_faster() {
        let slow = stage1_datapath(false);
        let fast = stage1_datapath(true);
        assert!(depth(&fast) < depth(&slow));
        assert!(fast.logic_cells() > slow.logic_cells());
    }
}
