//! Quantized MLP forward passes.
//!
//! Layer semantics (DESIGN.md §4, mirrored by
//! `python/compile/kernels/ref.py::layer_ref`): products at `in_bits`
//! via the Soft SIMD shift-add multiply, widened (`<< acc−in`) to the
//! accumulator format — the Stage-2 8→16 conversion — summed with
//! wrapping `acc_bits` adds; hidden layers apply ReLU then truncate back
//! to `in_bits`.

use crate::bits::fixed::sign_extend;
use crate::pipeline::stage1::{mul_scalar_plan, mul_scalar};

use super::weights::QuantLayer;

/// Forward one input row through all layers; returns the final
/// pre-activation accumulators (`Q1.(acc_bits-1)` raws).
pub fn mlp_forward_row(x_q: &[i64], layers: &[QuantLayer], in_bits: u32, acc_bits: u32) -> Vec<i64> {
    let mut h: Vec<i64> = x_q.to_vec();
    for (li, layer) in layers.iter().enumerate() {
        assert_eq!(h.len(), layer.k, "layer {li} input width");
        let mut out = vec![0i64; layer.n];
        for j in 0..layer.n {
            let mut acc = 0i64;
            for i in 0..layer.k {
                let p = mul_scalar(h[i], layer.w_raw[i][j], in_bits, layer.bits);
                acc += p << (acc_bits - in_bits);
            }
            out[j] = sign_extend(acc as u64 & ((1u64 << acc_bits) - 1), acc_bits);
        }
        if li + 1 < layers.len() {
            h = out
                .iter()
                .map(|&v| v.max(0) >> (acc_bits - in_bits))
                .collect();
        } else {
            return out;
        }
    }
    h
}

/// Batched forward; `x` is row-major `[batch][k]`.
pub fn mlp_forward_batch(
    x: &[Vec<i64>],
    layers: &[QuantLayer],
    in_bits: u32,
    acc_bits: u32,
) -> Vec<Vec<i64>> {
    x.iter()
        .map(|row| mlp_forward_row(row, layers, in_bits, acc_bits))
        .collect()
}

/// Forward with *precomputed plans* (avoids re-encoding CSD per call;
/// the scalar mirror of the packed serving path).
pub fn mlp_forward_row_planned(
    x_q: &[i64],
    layers: &[QuantLayer],
    plans: &[Vec<Vec<crate::csd::schedule::MulPlan>>],
    in_bits: u32,
    acc_bits: u32,
) -> Vec<i64> {
    let mut h: Vec<i64> = x_q.to_vec();
    for (li, layer) in layers.iter().enumerate() {
        let mut out = vec![0i64; layer.n];
        for j in 0..layer.n {
            let mut acc = 0i64;
            for i in 0..layer.k {
                let p = mul_scalar_plan(h[i], &plans[li][i][j], in_bits);
                acc += p << (acc_bits - in_bits);
            }
            out[j] = sign_extend(acc as u64 & ((1u64 << acc_bits) - 1), acc_bits);
        }
        if li + 1 < layers.len() {
            h = out
                .iter()
                .map(|&v| v.max(0) >> (acc_bits - in_bits))
                .collect();
        } else {
            return out;
        }
    }
    h
}

/// Precompute all layer plans for [`mlp_forward_row_planned`]. This is
/// the expensive, quantization-dependent compilation step; the serving
/// stack runs it exactly once per model inside
/// [`crate::coordinator::CompiledModel::compile`] and shares the result
/// across PE workers.
pub fn precompute_plans(
    layers: &[QuantLayer],
) -> Vec<Vec<Vec<crate::csd::schedule::MulPlan>>> {
    layers
        .iter()
        .map(|l| {
            (0..l.k)
                .map(|i| (0..l.n).map(|j| l.plan(i, j)).collect())
                .collect()
        })
        .collect()
}

/// Argmax over the first `classes` outputs (logit decision; first-max
/// wins ties, matching `numpy.argmax`).
pub fn argmax_class(logits: &[i64], classes: usize) -> usize {
    let mut best = 0usize;
    for i in 1..classes.min(logits.len()) {
        if logits[i] > logits[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_layers() -> Vec<QuantLayer> {
        // 2 → 2 → 2 with simple weights.
        vec![
            QuantLayer::new(vec![vec![64, -64], vec![32, 32]], 8), // 0.5/-0.5; 0.25/0.25
            QuantLayer::new(vec![vec![127, 0], vec![0, 127]], 8),
        ]
    }

    #[test]
    fn forward_matches_hand_computation() {
        let layers = tiny_layers();
        let x = vec![64i64, 64]; // 0.5, 0.5
        // Layer 0: n0 = 0.5·0.5 + 0.5·0.25 = 0.375 → raw16 (64·64>>7=32,
        // 64·32>>7=16 → (32+16)<<8 = 12288). n1 = −0.25+0.125 → ((−32)+16)<<8 = −4096.
        // ReLU+requant: h = [12288>>8, 0] = [48, 0].
        // Layer 1 (≈identity·0.992): n0 = mul(48,127)<<8, n1 = 0.
        let out = mlp_forward_row(&x, &layers, 8, 16);
        let p = mul_scalar(48, 127, 8, 8);
        assert_eq!(out, vec![p << 8, 0]);
    }

    #[test]
    fn planned_path_matches_unplanned() {
        let layers = tiny_layers();
        let plans = precompute_plans(&layers);
        for x0 in [-128i64, -5, 0, 99, 127] {
            for x1 in [-77i64, 0, 127] {
                let x = vec![x0, x1];
                assert_eq!(
                    mlp_forward_row(&x, &layers, 8, 16),
                    mlp_forward_row_planned(&x, &layers, &plans, 8, 16)
                );
            }
        }
    }

    #[test]
    fn argmax_first_wins_ties_deterministically() {
        assert_eq!(argmax_class(&[5, 5, 1], 3), 0);
        assert_eq!(argmax_class(&[1, 9, 9], 3), 1);
    }
}
