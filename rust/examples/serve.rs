//! Batched serving demo: drives the coordinator with a bursty open-loop
//! request stream and reports latency percentiles and throughput — the
//! serving-system view of the near-memory accelerator.
//!
//! The stream relies on the coordinator's deadline thread for straggler
//! flushes: requests are submitted in bursts and responses are only
//! collected at the end, yet sub-target batches still execute within the
//! configured deadline (DESIGN.md §8).
//!
//! Run: `make artifacts && cargo run --release --example serve [n_requests]`

use std::time::{Duration, Instant};

use softsimd::anyhow;
use softsimd::coordinator::cost::CostTable;
use softsimd::coordinator::model::CompiledModel;
use softsimd::coordinator::server::{Coordinator, Request, ServeConfig};
use softsimd::nn::weights::load_weight_file;
use softsimd::workload::synth::{Digits, XorShift64};

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(1024);
    let weights = std::path::Path::new("artifacts/mlp_weights.txt");
    anyhow::ensure!(weights.exists(), "run `make artifacts` first");
    let layers = load_weight_file(weights)?;
    let cost = CostTable::characterize(1000.0);
    let model = CompiledModel::compile(layers, 8, 16)?;

    println!(
        "request stream: {n} requests, bursty arrivals, 4 PEs, batch target \
         12 rows, 1 ms straggler deadline, least-loaded dispatch"
    );
    let digits = Digits::standard();
    let mut rng = XorShift64::new(0x5E2E);

    let cfg = ServeConfig::new(4, 12).deadline(Duration::from_millis(1));
    let mut coord = Coordinator::start(model, cfg, cost)?;
    let t_start = Instant::now();
    let mut submitted = 0u64;
    while (submitted as usize) < n {
        // Bursts of 1..8 requests with a small think-time gap.
        let burst = 1 + (rng.next_u64() % 8) as usize;
        for _ in 0..burst.min(n - submitted as usize) {
            let (xs, _) = digits.sample(1, 0.3, 1 + submitted * 7919);
            coord.submit(Request { id: submitted, rows: vec![xs[0].clone()] })?;
            submitted += 1;
        }
        if rng.next_u64() % 4 == 0 {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    let responses = coord.drain()?;
    let wall = t_start.elapsed();

    println!(
        "served {} responses in {:.1} ms → {:.0} req/s",
        responses.len(),
        wall.as_secs_f64() * 1e3,
        responses.len() as f64 / wall.as_secs_f64()
    );
    let pct = |q: f64| coord.metrics.latency_quantile_ns(q).unwrap_or(0) as f64 / 1e3;
    println!(
        "latency µs: p50={:.0} p90={:.0} p99={:.0}",
        pct(0.50),
        pct(0.90),
        pct(0.99)
    );
    println!("{}", coord.metrics.report());
    coord.shutdown();
    Ok(())
}
