//! Flattened CSD micro-op plans — the serving engine's execution form
//! (DESIGN.md §11).
//!
//! [`super::schedule::MulPlan`] is the right *compilation* artifact (one
//! heap `Vec<MulOp>` per weight, easy to inspect and test), but it is a
//! poor *execution* artifact: the engine's inner loop walks thousands of
//! tiny heap allocations per batch, each op an 8-byte enum, with a
//! pointer chase per weight. This module flattens a whole model's plans
//! into one contiguous structure-of-arrays [`PlanArena`]:
//!
//! * every micro-op is **one byte** — shift amount in the low nibble,
//!   op kind / operand sign in the top bits ([`FLAT_ADD`], [`FLAT_NEG`]);
//! * every plan is a `(offset, cycles, adds)` header ([`FlatPlan`]) into
//!   the shared op buffer;
//! * headers are laid out so the `k` plans feeding output column `n` of
//!   a layer are **adjacent** ([`PlanArena::column`]) — the engine's
//!   weight-stationary loop streams them front to back.
//!
//! The encoding is lossless ([`encode_op`]/[`decode_op`] round-trip) and
//! execution over the flat form ([`crate::pipeline::stage1::Stage1::run_flat`])
//! is bit-exact against [`crate::pipeline::stage1::Stage1::run_plan`];
//! the property tests enforce both.
//!
//! The arena is layer-kind-agnostic: a Conv2D layer contributes its
//! im2col weight matrix (`[cin·kh·kw][cout]`, DESIGN.md §12), so one
//! [`FlatPlan`] header per *kernel weight* is shared across every
//! output pixel of every image — the header count scales with the
//! kernel tensor, never with the spatial extent it slides over.
//!
//! The byte stream is also backend-neutral: under `--features simd`
//! the host-vector backend (`bits::swarx`, DESIGN.md §16) executes the
//! *same* headers and bytes on `TILE` packed words per instruction —
//! the engine dispatches whole word tiles over each [`FlatPlan`] and
//! the scalar loop covers the sub-tile tail, so `cycles`/`adds` bill
//! identically on either backend (one op byte = one cycle per word,
//! whatever the dispatch width).

use super::schedule::{MulOp, MulPlan};

/// Low nibble of a flat op: the cycle's shift distance (`0..=MAX_SHIFT`;
/// 0 only on the final add of a plan).
pub const FLAT_SHIFT_MASK: u8 = 0x0F;
/// Set: the cycle adds/subtracts the multiplicand before shifting
/// (`MulOp::AddShift`); clear: a pure-shift zero-run cycle.
pub const FLAT_ADD: u8 = 0x40;
/// Set (only together with [`FLAT_ADD`]): the operand is subtracted
/// (a CSD `−1` digit).
pub const FLAT_NEG: u8 = 0x80;

/// Encode one [`MulOp`] into its one-byte flat form.
#[inline]
pub fn encode_op(op: MulOp) -> u8 {
    match op {
        MulOp::Shift { shift } => {
            debug_assert!(shift <= FLAT_SHIFT_MASK as u32);
            shift as u8
        }
        MulOp::AddShift { shift, sign } => {
            debug_assert!(shift <= FLAT_SHIFT_MASK as u32);
            FLAT_ADD | if sign < 0 { FLAT_NEG } else { 0 } | shift as u8
        }
    }
}

/// Decode a flat op byte back into a [`MulOp`] (inspection/testing; the
/// execution path never decodes).
#[inline]
pub fn decode_op(b: u8) -> MulOp {
    let shift = (b & FLAT_SHIFT_MASK) as u32;
    if b & FLAT_ADD != 0 {
        MulOp::AddShift { shift, sign: if b & FLAT_NEG != 0 { -1 } else { 1 } }
    } else {
        MulOp::Shift { shift }
    }
}

/// Encode a whole plan into flat bytes (appended to `buf`).
pub fn encode_plan(plan: &MulPlan, buf: &mut Vec<u8>) {
    buf.extend(plan.ops.iter().map(|&op| encode_op(op)));
}

/// One plan's header into the arena's shared micro-op buffer. A zero
/// weight compiles to `cycles == 0` — the engine's zero-skip test.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlatPlan {
    /// Byte offset of the plan's first micro-op in [`PlanArena::ops`].
    pub offset: u32,
    /// Stage-1 cycle count == micro-op count (one op per cycle). Also
    /// the slice length: ops are `ops[offset .. offset + cycles]`.
    pub cycles: u16,
    /// Add/sub cycles among them (CSD nonzero digits) — kept in the
    /// header so billing cross-checks never re-scan the op bytes.
    pub adds: u16,
}

impl FlatPlan {
    /// Is this the empty plan of a zero weight?
    #[inline]
    pub fn is_zero(&self) -> bool {
        self.cycles == 0
    }
}

/// A whole model's multiply plans flattened into one contiguous SoA
/// buffer: `ops` holds every layer's micro-ops back to back; `headers`
/// holds one [`FlatPlan`] per weight, laid out n-major per layer so the
/// `k` plans feeding an output column are adjacent.
///
/// Since the truncated-CSD variants (DESIGN.md §18) an arena can carry
/// several plan **banks** over the same layer shapes: bank 0 holds the
/// exact plans, further banks hold approximate (truncated) plans of the
/// same weights — one header block of identical layout per bank, all
/// sharing the one `ops` byte buffer. The bank-less accessors read
/// bank 0, so every pre-§18 caller keeps its exact-plan semantics.
#[derive(Debug)]
pub struct PlanArena {
    ops: Vec<u8>,
    headers: Vec<FlatPlan>,
    /// First header of each layer *within a bank*:
    /// `headers[bank·bank_stride + layer_base[li] + n*k + k_i]`.
    layer_base: Vec<usize>,
    /// Input width `k` of each layer (the column stride).
    layer_k: Vec<usize>,
    /// Headers per bank (all banks share layer shapes, so all have the
    /// same stride).
    bank_stride: usize,
    /// Number of plan banks (≥ 1; bank 0 is exact).
    n_banks: usize,
}

impl PlanArena {
    /// Flatten `plans[layer][k][n]` (the [`CompiledModel`] layout) into
    /// a single-bank arena. Op bytes are emitted in the same n-major
    /// header order so a layer's execution streams the buffer strictly
    /// forward.
    ///
    /// [`CompiledModel`]: crate::coordinator::model::CompiledModel
    pub fn build(plans: &[Vec<Vec<MulPlan>>]) -> PlanArena {
        PlanArena::build_banks(&[plans])
    }

    /// Flatten several plan banks over the **same layer shapes** into
    /// one arena: `banks[b][layer][k][n]`. Bank 0 must be the exact
    /// plans; further banks are approximate variants of the same
    /// weights (every bank must agree on every layer's `(k, n)` dims).
    pub fn build_banks(banks: &[&[Vec<Vec<MulPlan>>]]) -> PlanArena {
        assert!(!banks.is_empty(), "arena needs at least one plan bank");
        let mut arena = PlanArena {
            ops: Vec::new(),
            headers: Vec::new(),
            layer_base: Vec::with_capacity(banks[0].len()),
            layer_k: Vec::with_capacity(banks[0].len()),
            bank_stride: 0,
            n_banks: banks.len(),
        };
        for (bi, &bank) in banks.iter().enumerate() {
            assert_eq!(bank.len(), banks[0].len(), "bank {bi}: layer count");
            for (li, layer_plans) in bank.iter().enumerate() {
                let k = layer_plans.len();
                let n = if k > 0 { layer_plans[0].len() } else { 0 };
                if bi == 0 {
                    arena.layer_base.push(arena.headers.len());
                    arena.layer_k.push(k);
                } else {
                    assert_eq!(k, arena.layer_k[li], "bank {bi} layer {li}: k");
                }
                for ni in 0..n {
                    for row in layer_plans.iter() {
                        let plan = &row[ni];
                        let offset = arena.ops.len() as u32;
                        encode_plan(plan, &mut arena.ops);
                        arena.headers.push(FlatPlan {
                            offset,
                            cycles: plan.cycles() as u16,
                            adds: plan.adds() as u16,
                        });
                    }
                }
            }
            if bi == 0 {
                arena.bank_stride = arena.headers.len();
            } else {
                assert_eq!(
                    arena.headers.len(),
                    (bi + 1) * arena.bank_stride,
                    "bank {bi}: header count must match bank 0's layout"
                );
            }
        }
        arena.ops.shrink_to_fit();
        arena.headers.shrink_to_fit();
        arena
    }

    /// Header of layer `li`'s plan for weight `(k, n)` in bank 0 (the
    /// exact plans).
    #[inline]
    pub fn header(&self, li: usize, k: usize, n: usize) -> FlatPlan {
        self.header_bank(0, li, k, n)
    }

    /// Header of layer `li`'s plan for weight `(k, n)` in plan bank
    /// `bank`.
    #[inline]
    pub fn header_bank(&self, bank: usize, li: usize, k: usize, n: usize) -> FlatPlan {
        self.headers
            [bank * self.bank_stride + self.layer_base[li] + n * self.layer_k[li] + k]
    }

    /// The `k` adjacent headers feeding output column `n` of layer `li`
    /// in bank 0 — index `i` of the slice is input index `k = i`.
    #[inline]
    pub fn column(&self, li: usize, n: usize) -> &[FlatPlan] {
        self.column_bank(0, li, n)
    }

    /// The `k` adjacent headers feeding output column `n` of layer `li`
    /// in plan bank `bank`.
    #[inline]
    pub fn column_bank(&self, bank: usize, li: usize, n: usize) -> &[FlatPlan] {
        let k = self.layer_k[li];
        let base = bank * self.bank_stride + self.layer_base[li] + n * k;
        &self.headers[base..base + k]
    }

    /// Number of plan banks (1 for an exact-only arena).
    #[inline]
    pub fn n_banks(&self) -> usize {
        self.n_banks
    }

    /// The micro-op bytes of one plan.
    #[inline]
    pub fn ops(&self, h: FlatPlan) -> &[u8] {
        &self.ops[h.offset as usize..h.offset as usize + h.cycles as usize]
    }

    /// Number of layers flattened into the arena.
    #[inline]
    pub fn n_layers(&self) -> usize {
        self.layer_base.len()
    }

    /// `(k, n)` dimensions of layer `li`'s header block — the input
    /// width (column stride) and output column count it was built with.
    /// Identical across banks by construction.
    #[inline]
    pub fn layer_dims(&self, li: usize) -> (usize, usize) {
        let base = self.layer_base[li];
        let end = self
            .layer_base
            .get(li + 1)
            .copied()
            .unwrap_or(self.bank_stride);
        let k = self.layer_k[li];
        (k, if k == 0 { 0 } else { (end - base) / k })
    }

    /// Walk one plan's micro-ops decoded back to [`MulOp`]s, in issue
    /// order — the inspection/analysis view of the bytecode (the
    /// execution path stays on the raw bytes). The iterator is `Clone`
    /// so abstract interpreters can replay a plan per input value.
    #[inline]
    pub fn walk(&self, h: FlatPlan) -> impl Iterator<Item = MulOp> + Clone + '_ {
        self.ops(h).iter().map(|&b| decode_op(b))
    }

    /// Total micro-op bytes in the arena, all banks (diagnostics).
    pub fn total_ops(&self) -> usize {
        self.ops.len()
    }

    /// Total plan headers in the arena, all banks (diagnostics).
    pub fn total_plans(&self) -> usize {
        self.headers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::csd::schedule::{schedule, schedule_truncated, Truncation};

    #[test]
    fn op_encoding_round_trips() {
        for shift in 0..=3u32 {
            for op in [
                MulOp::Shift { shift: shift.max(1) },
                MulOp::AddShift { shift, sign: 1 },
                MulOp::AddShift { shift, sign: -1 },
            ] {
                assert_eq!(decode_op(encode_op(op)), op, "{op:?}");
            }
        }
    }

    #[test]
    fn every_eight_bit_plan_round_trips_through_the_arena() {
        let plans: Vec<Vec<MulPlan>> =
            vec![(-128i64..128).map(|m| schedule(m, 8)).collect()];
        // One "layer" with k=1, n=256.
        let arena = PlanArena::build(&[plans.clone()]);
        assert_eq!(arena.total_plans(), 256);
        for (ni, plan) in plans[0].iter().enumerate() {
            let h = arena.header(0, 0, ni);
            assert_eq!(h.cycles as usize, plan.cycles(), "m={}", ni as i64 - 128);
            assert_eq!(h.adds as usize, plan.adds());
            let decoded: Vec<MulOp> =
                arena.ops(h).iter().map(|&b| decode_op(b)).collect();
            assert_eq!(decoded, plan.ops);
            assert_eq!(h.is_zero(), plan.ops.is_empty());
        }
    }

    #[test]
    fn column_slices_are_k_adjacent_plans() {
        // 3×2 layer: column n holds plans for weights (0,n), (1,n), (2,n).
        let w = [[10i64, -20], [0, 115], [64, -1]];
        let plans: Vec<Vec<MulPlan>> = w
            .iter()
            .map(|row| row.iter().map(|&m| schedule(m, 8)).collect())
            .collect();
        let arena = PlanArena::build(&[plans]);
        for n in 0..2 {
            let col = arena.column(0, n);
            assert_eq!(col.len(), 3);
            for (k, h) in col.iter().enumerate() {
                assert_eq!(*h, arena.header(0, k, n));
                assert_eq!(h.cycles as usize, schedule(w[k][n], 8).cycles());
            }
        }
        // The zero weight is a zero-cycle header.
        assert!(arena.header(0, 1, 0).is_zero());
    }

    #[test]
    fn multi_layer_arena_indexes_independently() {
        let l0: Vec<Vec<MulPlan>> = (0..4)
            .map(|i| (0..3).map(|j| schedule(i * 7 + j - 5, 8)).collect())
            .collect();
        let l1: Vec<Vec<MulPlan>> =
            (0..3).map(|i| (0..2).map(|j| schedule(i * j, 8)).collect()).collect();
        let arena = PlanArena::build(&[l0.clone(), l1.clone()]);
        assert_eq!(arena.total_plans(), 12 + 6);
        assert_eq!(arena.n_layers(), 2);
        assert_eq!(arena.layer_dims(0), (4, 3));
        assert_eq!(arena.layer_dims(1), (3, 2));
        // The walker decodes exactly the plan the header was built from.
        let h = arena.header(0, 2, 1);
        let walked: Vec<MulOp> = arena.walk(h).collect();
        assert_eq!(walked, l0[2][1].ops);
        for (k, row) in l0.iter().enumerate() {
            for (n, plan) in row.iter().enumerate() {
                assert_eq!(arena.header(0, k, n).cycles as usize, plan.cycles());
            }
        }
        for (k, row) in l1.iter().enumerate() {
            for (n, plan) in row.iter().enumerate() {
                let h = arena.header(1, k, n);
                assert_eq!(h.cycles as usize, plan.cycles());
                let decoded: Vec<MulOp> =
                    arena.ops(h).iter().map(|&b| decode_op(b)).collect();
                assert_eq!(decoded, plan.ops);
            }
        }
    }

    #[test]
    fn single_bank_build_is_bank_zero_of_build_banks() {
        let plans: Vec<Vec<MulPlan>> = (0..3)
            .map(|k| (0..2).map(|n| schedule(k * 31 + n * 7 - 40, 8)).collect())
            .collect();
        let single = PlanArena::build(&[plans.clone()]);
        assert_eq!(single.n_banks(), 1);
        for k in 0..3 {
            for n in 0..2 {
                assert_eq!(single.header(0, k, n), single.header_bank(0, 0, k, n));
            }
        }
    }

    #[test]
    fn truncated_bank_shares_layout_and_shrinks_cycles() {
        let weights = [[115i64, -77], [0, 127], [64, -3]];
        let trunc = Truncation::drop_least(3);
        let exact: Vec<Vec<MulPlan>> = weights
            .iter()
            .map(|row| row.iter().map(|&m| schedule(m, 8)).collect())
            .collect();
        let approx: Vec<Vec<MulPlan>> = weights
            .iter()
            .map(|row| row.iter().map(|&m| schedule_truncated(m, 8, trunc)).collect())
            .collect();
        let bank0 = [exact.clone()];
        let bank1 = [approx.clone()];
        let arena = PlanArena::build_banks(&[&bank0, &bank1]);
        assert_eq!(arena.n_banks(), 2);
        assert_eq!(arena.total_plans(), 2 * 6);
        assert_eq!(arena.layer_dims(0), (3, 2));
        for k in 0..3 {
            for n in 0..2 {
                // Bank 0 is the exact plan, bank 1 the truncated one —
                // each header decodes back to exactly its source plan.
                let h0 = arena.header_bank(0, 0, k, n);
                let h1 = arena.header_bank(1, 0, k, n);
                assert_eq!(h0, arena.header(0, k, n), "bank 0 is the default");
                let d0: Vec<MulOp> = arena.walk(h0).collect();
                let d1: Vec<MulOp> = arena.walk(h1).collect();
                assert_eq!(d0, exact[k][n].ops, "({k},{n})");
                assert_eq!(d1, approx[k][n].ops, "({k},{n})");
                assert!(h1.cycles <= h0.cycles, "({k},{n})");
                // Column accessors agree with the per-header view.
                assert_eq!(arena.column_bank(1, 0, n)[k], h1);
            }
        }
        // The zero weight stays a zero-cycle header in every bank.
        assert!(arena.header_bank(0, 0, 1, 0).is_zero());
        assert!(arena.header_bank(1, 0, 1, 0).is_zero());
    }
}
