//! Conv precision-schedule sweep — `eval precision`'s companion on the
//! convolution workload (DESIGN.md §12).
//!
//! The standard synthetic CNN (conv 1×8×8 → 4ch 3×3 s1 p1 → conv 4ch →
//! 4ch 3×3 s2 p1 → dense 64 → 10) is compiled under several per-layer
//! precision schedules and a batch of synthetic images is pushed
//! through the packed engine under each; the table reports exact
//! Stage-1/Stage-2 work and pre-characterized energy per *image*, with
//! the packed result checked bit-exactly against the scalar stack
//! oracle first. Convolution is where sub-word SIMD wins compound: one
//! image expands into 64 + 16 im2col patch rows, so the per-word lane
//! count of the early (wide, patch-heavy) layers multiplies straight
//! into multiply volume — the low-precision-first schedule's Stage-1
//! advantage is correspondingly larger than on the MLP sweep.

use crate::anyhow;
use crate::coordinator::cost::CostTable;
use crate::coordinator::engine::PackedEngine;
use crate::coordinator::model::CompiledModel;
use crate::energy::report::table;
use crate::nn::conv::LayerOp;
use crate::nn::exec::stack_forward_row;
use crate::nn::weights::LayerPrecision;
use crate::workload::synth::{synth_cnn_stack, ImageSet};

/// Images per sweep batch (a multiple of every schedule's quantum).
pub const BATCH: usize = 24;

/// One sweep cell: exact work and billed energy per image.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub name: &'static str,
    pub schedule: Vec<LayerPrecision>,
    pub s1_cycles_per_img: f64,
    pub s2_passes_per_img: f64,
    pub s1_pj_per_img: f64,
    pub total_pj_per_img: f64,
}

/// The swept schedules: uniform 8-bit, a 4-bit-first widening schedule,
/// and a 16-bit-first narrowing one whose 16→4 boundary exercises the
/// 2-hop crossbar chain on a conv→dense flatten.
pub fn schedules() -> Vec<(&'static str, Vec<LayerPrecision>)> {
    vec![
        (
            "8-8-8 (uniform)",
            vec![
                LayerPrecision::new(8, 16),
                LayerPrecision::new(8, 16),
                LayerPrecision::new(8, 16),
            ],
        ),
        (
            "4-6-8 (low first)",
            vec![
                LayerPrecision::new(4, 8),
                LayerPrecision::new(6, 12),
                LayerPrecision::new(8, 16),
            ],
        ),
        (
            "16-8-4 (2-hop 16\u{2192}4)",
            vec![
                LayerPrecision::new(16, 16),
                LayerPrecision::new(8, 16),
                LayerPrecision::new(4, 8),
            ],
        ),
    ]
}

/// The fixed CNN under sweep (8-bit weights; see
/// [`synth_cnn_stack`]).
pub fn model_stack() -> Vec<LayerOp> {
    synth_cnn_stack(0x5C4EF, 8)
}

/// Run every schedule; each cell is oracle-verified before being priced.
pub fn rows(cost: &CostTable) -> anyhow::Result<Vec<SweepRow>> {
    let stack = model_stack();
    let images = ImageSet::standard();
    let mut out = vec![];
    for (name, sched) in schedules() {
        let model = CompiledModel::compile_stack(stack.clone(), sched.clone())?;
        let engine = PackedEngine::new(model);
        let seed = 0x5EED0 + sched[0].in_bits as u64;
        let (batch, _labels) = images.sample(BATCH, 0.25, seed, sched[0].in_bits);
        let (got, stats) = engine.forward_batch(&batch);
        for (b, row) in batch.iter().enumerate() {
            let want = stack_forward_row(row, &stack, &sched);
            anyhow::ensure!(
                got[b] == want,
                "schedule `{name}` image {b} diverges from the scalar stack oracle"
            );
        }
        let s1_pj = cost.s1_energy_pj(&stats);
        let total_pj = cost.batch_energy_pj(&stats);
        out.push(SweepRow {
            name,
            schedule: sched,
            s1_cycles_per_img: stats.s1_cycles as f64 / BATCH as f64,
            s2_passes_per_img: stats.s2_passes as f64 / BATCH as f64,
            s1_pj_per_img: s1_pj / BATCH as f64,
            total_pj_per_img: total_pj / BATCH as f64,
        });
    }
    Ok(out)
}

pub fn run() -> anyhow::Result<()> {
    println!(
        "== conv precision sweep: per-layer formats on the im2col CNN serving \
         path ({BATCH}-image batch, @1GHz) =="
    );
    let cost = CostTable::characterize(1000.0);
    let rs = rows(&cost)?;
    let trows: Vec<Vec<String>> = rs
        .iter()
        .map(|r| {
            vec![
                r.name.to_string(),
                r.schedule
                    .iter()
                    .map(|p| p.to_string())
                    .collect::<Vec<_>>()
                    .join(" "),
                format!("{:.1}", r.s1_cycles_per_img),
                format!("{:.1}", r.s2_passes_per_img),
                format!("{:.2}", r.s1_pj_per_img),
                format!("{:.2}", r.total_pj_per_img),
            ]
        })
        .collect();
    println!(
        "{}",
        table(
            &[
                "schedule",
                "layer formats (in->acc)",
                "S1 cyc/img",
                "S2 pass/img",
                "S1 pJ/img",
                "total pJ/img",
            ],
            &trows
        )
    );
    let uniform = &rs[0];
    let low_first = &rs[1];
    println!(
        "(every schedule bit-exact vs the scalar stack oracle; one image is \
         64 + 16 im2col patch rows; 4-6-8 spends {:.1}% of the uniform \
         schedule's Stage-1 energy)\n",
        low_first.s1_pj_per_img / uniform.s1_pj_per_img * 100.0
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_precision_first_schedule_is_cheaper_on_conv_stage1() {
        // The conv acceptance claim: the 4-bit-first schedule packs 12
        // patch rows per word in the patch-heavy first conv (vs 6 at
        // 8-bit), so its Stage-1 energy per image undercuts the uniform
        // schedule.
        let cost = CostTable::characterize(1000.0);
        let rs = rows(&cost).unwrap();
        let uniform = rs.iter().find(|r| r.name.starts_with("8-8-8")).unwrap();
        let low = rs.iter().find(|r| r.name.starts_with("4-6-8")).unwrap();
        assert!(
            low.s1_pj_per_img < uniform.s1_pj_per_img,
            "4-6-8 {} pJ !< 8-8-8 {} pJ",
            low.s1_pj_per_img,
            uniform.s1_pj_per_img
        );
        assert!(
            low.s1_cycles_per_img < uniform.s1_cycles_per_img,
            "cycle count must also drop"
        );
    }

    #[test]
    fn sweep_covers_a_two_hop_conv_boundary() {
        let two_hop = schedules()
            .into_iter()
            .find(|(n, _)| n.starts_with("16-8-4"))
            .unwrap()
            .1;
        let m = CompiledModel::compile_stack(model_stack(), two_hop).unwrap();
        assert_eq!(m.boundary_chain(1).len(), 2, "16→4 must chain via 8");
    }
}
