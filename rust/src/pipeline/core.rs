//! The two-stage pipeline core: executes micro-op [`Program`]s, models
//! stage overlap, and produces activity [`Trace`]s.
//!
//! Timing model: the two stages are a classic in-order pipeline. Within
//! one program, Stage-2 ops depend on the Stage-1 result (through the
//! `Mov R2, Acc`), so they serialize; *across* back-to-back programs the
//! Stage-2 cycles of program *i* overlap the Stage-1 cycles of program
//! *i+1* (Section III-A). `elapsed_cycles` reports the overlapped time,
//! the per-stage busy counts report occupancy/energy.

use super::stage1::Stage1;
use super::stage2::Stage2;
use super::trace::{CycleEvent, S1Event, S2Event, Trace};
use crate::bits::format::SimdFormat;
use crate::isa::instr::{Instr, Reg};
use crate::isa::program::Program;

/// Result of running one or more programs.
#[derive(Debug, Clone, Default)]
pub struct RunResult {
    /// Words written by `Store`.
    pub outputs: Vec<u64>,
    /// Overlapped total cycles.
    pub elapsed_cycles: u64,
    pub s1_busy: u64,
    pub s2_busy: u64,
}

/// The pipeline simulator.
#[derive(Debug, Clone)]
pub struct PipelineSim {
    pub s1: Stage1,
    pub s2: Stage2,
    r2: u64,
    r3: u64,
    r4: u64,
    /// Earliest cycle each stage is free (for overlap accounting).
    t_s1_free: u64,
    t_s2_free: u64,
    /// Cycle at which the current program's Stage-1 result is ready.
    t_result_ready: u64,
    pub trace: Trace,
    /// Record operand-level events (disable for pure-throughput runs).
    pub tracing: bool,
}

impl Default for PipelineSim {
    fn default() -> Self {
        Self::new(SimdFormat::new(8))
    }
}

impl PipelineSim {
    pub fn new(fmt: SimdFormat) -> Self {
        PipelineSim {
            s1: Stage1::new(fmt),
            s2: Stage2::default(),
            r2: 0,
            r3: 0,
            r4: 0,
            t_s1_free: 0,
            t_s2_free: 0,
            t_result_ready: 0,
            trace: Trace::default(),
            tracing: true,
        }
    }

    fn reg_read(&self, r: Reg) -> u64 {
        match r {
            Reg::X => self.s1.x,
            Reg::Acc => self.s1.acc,
            Reg::R2 => self.r2,
            Reg::R3 => self.r3,
            Reg::R4 => self.r4,
        }
    }

    fn reg_write(&mut self, r: Reg, v: u64) {
        match r {
            Reg::X => self.s1.x = v,
            Reg::Acc => self.s1.acc = v,
            Reg::R2 => self.r2 = v,
            Reg::R3 => self.r3 = v,
            Reg::R4 => self.r4 = v,
        }
    }

    fn window(&self) -> u128 {
        self.r2 as u128 | ((self.r3 as u128) << 48)
    }

    /// Execute one program to completion, accumulating outputs and trace.
    pub fn run(&mut self, prog: &Program, result: &mut RunResult) {
        for &ins in &prog.instrs {
            match ins {
                Instr::SetFmt(f) => self.s1.set_fmt(f),
                Instr::Load(r, w) => self.reg_write(r, w),
                Instr::ClearAcc => self.s1.clear_acc(),
                Instr::Shift { k } => {
                    let acc_in = self.s1.acc;
                    let out = self.s1.shift(k);
                    self.t_s1_free += 1;
                    result.s1_busy += 1;
                    if self.tracing {
                        self.trace.events.push(CycleEvent::S1(S1Event {
                            fmt: self.s1.fmt,
                            acc_in,
                            x: self.s1.x,
                            k,
                            sign: 0,
                            acc_out: out,
                        }));
                    }
                }
                Instr::AddShift { k, sign } => {
                    let acc_in = self.s1.acc;
                    let out = self.s1.shift_add(k, sign);
                    self.t_s1_free += 1;
                    result.s1_busy += 1;
                    if self.tracing {
                        self.trace.events.push(CycleEvent::S1(S1Event {
                            fmt: self.s1.fmt,
                            acc_in,
                            x: self.s1.x,
                            k,
                            sign,
                            acc_out: out,
                        }));
                    }
                }
                Instr::Mov(d, s) => {
                    let v = self.reg_read(s);
                    self.reg_write(d, v);
                    if matches!(d, Reg::R2 | Reg::R3) {
                        // Stage-2 consumes the Stage-1 result: dependency edge.
                        self.t_result_ready = self.t_s1_free;
                    }
                }
                Instr::Pack { from, to, in_skip } => {
                    let out = self.s2.pass(self.window(), from, to, in_skip);
                    self.r4 = out;
                    let start = self.t_s2_free.max(self.t_result_ready);
                    self.t_s2_free = start + 1;
                    result.s2_busy += 1;
                    if self.tracing {
                        self.trace.events.push(CycleEvent::S2(S2Event {
                            from,
                            to,
                            window: self.window(),
                            in_skip,
                            out,
                            bypass: false,
                        }));
                    }
                }
                Instr::Bypass => {
                    let out = self.s2.bypass(self.r2);
                    self.r4 = out;
                    let start = self.t_s2_free.max(self.t_result_ready);
                    self.t_s2_free = start + 1;
                    result.s2_busy += 1;
                    if self.tracing {
                        self.trace.events.push(CycleEvent::S2(S2Event {
                            from: self.s1.fmt,
                            to: self.s1.fmt,
                            window: self.window(),
                            in_skip: 0,
                            out,
                            bypass: true,
                        }));
                    }
                }
                Instr::Store => result.outputs.push(self.r4),
                Instr::Halt => break,
            }
        }
        result.elapsed_cycles = self.t_s1_free.max(self.t_s2_free);
        self.trace.elapsed_cycles = result.elapsed_cycles;
    }

    /// Run a batch of programs back-to-back (stage overlap applies).
    pub fn run_batch(&mut self, progs: &[Program]) -> RunResult {
        let mut result = RunResult::default();
        for p in progs {
            self.run(p, &mut result);
        }
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::pack::{pack, unpack};
    use crate::isa::program::{assemble_mul, assemble_mul_repack};
    use crate::pipeline::stage1::mul_scalar;
    use crate::pipeline::stage2::repack_word;

    #[test]
    fn program_multiply_matches_direct_function() {
        let fmt = SimdFormat::new(8);
        let lanes: Vec<i64> = vec![-128, 127, 3, -3, 64, -65];
        let x = pack(&lanes, fmt);
        let m = 115i64;
        let mut prog = assemble_mul(m, 8, fmt, 3);
        prog.instrs.insert(1, Instr::Load(Reg::X, x));
        let mut sim = PipelineSim::new(fmt);
        let mut res = RunResult::default();
        sim.run(&prog, &mut res);
        let want: Vec<i64> = lanes.iter().map(|&l| mul_scalar(l, m, 8, 8)).collect();
        assert_eq!(unpack(sim.s1.acc, fmt), want);
    }

    #[test]
    fn mul_repack_end_to_end() {
        let fmt = SimdFormat::new(8);
        let out_fmt = SimdFormat::new(16);
        let lanes: Vec<i64> = vec![100, -100, 27, -1, 64, -128];
        let x = pack(&lanes, fmt);
        let m = 64i64; // 0.5
        let mut prog = assemble_mul_repack(m, 8, fmt, out_fmt, 3);
        prog.instrs.insert(1, Instr::Load(Reg::X, x));
        let mut sim = PipelineSim::new(fmt);
        let mut res = RunResult::default();
        sim.run(&prog, &mut res);
        let product = pack(
            &lanes.iter().map(|&l| mul_scalar(l, m, 8, 8)).collect::<Vec<_>>(),
            fmt,
        );
        assert_eq!(res.outputs, repack_word(product, fmt, out_fmt));
    }

    #[test]
    fn overlap_makes_batch_faster_than_sum() {
        let fmt = SimdFormat::new(8);
        let progs: Vec<Program> = (1..20)
            .map(|m| {
                let mut p = assemble_mul_repack(m * 11 % 128, 8, fmt, SimdFormat::new(16), 3);
                p.instrs.insert(1, Instr::Load(Reg::X, 0x0102_0304_0506));
                p
            })
            .collect();
        let mut sim = PipelineSim::new(fmt);
        let res = sim.run_batch(&progs);
        // Overlap: elapsed < s1_busy + s2_busy (serial sum), and at least
        // as long as the busier stage.
        assert!(res.elapsed_cycles < res.s1_busy + res.s2_busy);
        assert!(res.elapsed_cycles >= res.s1_busy.max(res.s2_busy));
    }

    #[test]
    fn trace_counts_match_busy_counters() {
        let fmt = SimdFormat::new(4);
        let mut prog = assemble_mul_repack(5, 4, fmt, SimdFormat::new(8), 3);
        prog.instrs.insert(1, Instr::Load(Reg::X, 0x1234_5678_9ABC & 0xFFFF_FFFF_FFFF));
        let mut sim = PipelineSim::new(fmt);
        let mut res = RunResult::default();
        sim.run(&prog, &mut res);
        assert_eq!(sim.trace.s1_cycles(), res.s1_busy);
        assert_eq!(sim.trace.s2_cycles(), res.s2_busy);
    }
}
