//! `softsimd` CLI — evaluation harness, demos and the serving entrypoint.
//!
//! Hand-rolled argument parsing (the build is offline; see Cargo.toml).

use std::process::ExitCode;

use softsimd::anyhow;

const USAGE: &str = "\
softsimd — Soft SIMD microarchitecture reproduction (Yu et al., 2022)

USAGE:
    softsimd <COMMAND> [ARGS]

COMMANDS:
    eval <target>        Regenerate a paper figure: fig6 | fig7 | fig8 |
                         fig9 | fig10 | summary | ablation | precision |
                         conv | autoscale | verify | certify | approx |
                         fleet | all
    csd [bits]           CSD digit-density statistics (default 8)
    disasm <m> [bits]    Disassemble the multiply program for multiplier m
    serve [requests]     Run the near-memory coordinator demo loop
    golden <path>        Validate the simulator against golden vectors
    help                 Show this message
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &[String]) -> anyhow::Result<()> {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "eval" => {
            let target = args.get(1).map(String::as_str).unwrap_or("all");
            softsimd::eval::run(target)?;
        }
        "csd" => {
            let bits: u32 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(8);
            let s = softsimd::csd::density(bits);
            println!(
                "CSD @ {bits} bits: zero digit fraction {:.3}, mean adds {:.2}, \
                 mean cycles {:.2}, max cycles {}",
                s.zero_fraction, s.mean_adds, s.mean_cycles, s.max_cycles
            );
        }
        "disasm" => {
            let m: i64 = args
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("disasm needs a multiplier value"))?
                .parse()?;
            let bits: u32 = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(8);
            let fmt = softsimd::bits::SimdFormat::new(8);
            let p = softsimd::isa::assemble_mul(m, bits, fmt, 3);
            println!("{}", p.disasm());
        }
        "serve" => {
            let n: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(256);
            softsimd::coordinator::demo::serve_demo(n)?;
        }
        "golden" => {
            let path = args
                .get(1)
                .map(String::as_str)
                .unwrap_or("artifacts/golden.jsonl");
            let report = softsimd::runtime::golden::check_file(path)?;
            println!("{report}");
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            anyhow::bail!("unknown command `{other}`\n\n{USAGE}");
        }
    }
    Ok(())
}
