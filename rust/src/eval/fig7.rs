//! Fig. 7 — the paper shows the post-P&R layout; its quantitative
//! content is the per-block area split, which we report as a
//! floorplan-style breakdown (DESIGN.md §2 substitution).

use crate::anyhow;
use crate::energy::model::SynthesizedSoftPipeline;
use crate::energy::report::{pct, table, um2};

pub fn run() -> anyhow::Result<()> {
    println!("== Fig. 7: Soft SIMD floorplan proxy (per-block area @1GHz) ==");
    let p = SynthesizedSoftPipeline::new(1000.0);
    let a = p.area();
    let total = a.total();
    let rows = vec![
        (
            "stage1: configurable adder+shifter",
            a.stage1_um2,
            format!(
                "{} cells, depth {} lvls{}",
                p.stage1.net.logic_cells(),
                p.stage1.depth_levels,
                if p.restructured { " (carry-select)" } else { " (ripple)" }
            ),
        ),
        (
            "stage2: repacking crossbar",
            a.stage2_um2,
            format!(
                "{} cells, depth {} lvls",
                p.stage2.net.logic_cells(),
                p.stage2.depth_levels
            ),
        ),
        (
            "registers (X, Acc, R2-R4, ctrl)",
            a.regs_um2,
            format!("{} flip-flops", p.s1_regs.bits + p.s2_regs.bits),
        ),
    ];
    let trows: Vec<Vec<String>> = rows
        .iter()
        .map(|(n, v, d)| vec![n.to_string(), um2(*v), pct(v / total), d.clone()])
        .collect();
    println!("{}", table(&["block", "µm²", "share", "detail"], &trows));
    // ASCII floorplan sketch scaled by area share.
    println!("floorplan sketch (area-proportional):");
    let bar = |v: f64| "#".repeat((v / total * 60.0).round() as usize);
    for (n, v, _) in &rows {
        println!("  {:<36} |{}", n, bar(*v));
    }
    println!();
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig7_runs() {
        super::run().unwrap();
    }
}
