//! CSD plan compilation must happen exactly once per model regardless
//! of how many PE workers serve it (the tentpole invariant of the
//! shared-plan serving engine; DESIGN.md §8).
//!
//! This lives in its own integration-test binary so the process-global
//! [`PLAN_COMPILATIONS`] counter is not perturbed by unrelated tests
//! compiling models in parallel threads.

use std::sync::atomic::Ordering;

use softsimd::coordinator::cost::CostTable;
use softsimd::coordinator::model::{CompiledModel, PLAN_COMPILATIONS};
use softsimd::coordinator::server::{Coordinator, Request, ServeConfig};
use softsimd::nn::weights::QuantLayer;
use softsimd::workload::synth::XorShift64;

fn cost() -> CostTable {
    CostTable {
        mhz: 1000.0,
        s1_cycle_pj: softsimd::bits::format::FORMATS.iter().map(|&b| (b, 1.0)).collect(),
        s2_pass_pj: 0.5,
        area_um2: 4600.0,
    }
}

#[test]
fn plans_compile_exactly_once_regardless_of_pe_count() {
    let mut rng = XorShift64::new(0xC0117);
    let layers: Vec<QuantLayer> = [(10usize, 6usize), (6, 4)]
        .iter()
        .map(|&(k, n)| {
            QuantLayer::new(
                (0..k)
                    .map(|_| (0..n).map(|_| rng.q_raw(8)).collect())
                    .collect(),
                8,
            )
        })
        .collect();
    for n_pes in [1usize, 2, 8] {
        let before = PLAN_COMPILATIONS.load(Ordering::SeqCst);
        let model = CompiledModel::compile(layers.clone(), 8, 16).unwrap();
        let mut coord = Coordinator::start(model, ServeConfig::new(n_pes, 6), cost()).unwrap();
        for id in 0..8u64 {
            coord
                .submit(Request {
                    id,
                    rows: vec![(0..10).map(|_| rng.q_raw(8)).collect()],
                })
                .unwrap();
        }
        let responses = coord.drain().unwrap();
        assert_eq!(responses.len(), 8);
        coord.shutdown();
        let after = PLAN_COMPILATIONS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            1,
            "expected one plan compilation per model at {n_pes} PEs"
        );
    }

    // The §13 dedup invariant, in the same (single-test) binary so no
    // parallel test perturbs the process-global counter: compiling a
    // whole variant *set* is still exactly one plan compilation — the
    // schedules differ, the weights (and therefore the CSD plans and
    // the flat arena) do not.
    use softsimd::coordinator::model::VariantSpec;
    use softsimd::nn::conv::LayerOp;
    let ops: Vec<LayerOp> = layers.iter().cloned().map(LayerOp::Dense).collect();
    let before = PLAN_COMPILATIONS.load(Ordering::SeqCst);
    let set =
        CompiledModel::compile_variants(ops, VariantSpec::standard_trio(layers.len()))
            .unwrap();
    assert_eq!(
        PLAN_COMPILATIONS.load(Ordering::SeqCst),
        before + 1,
        "a 3-variant set must compile its plans exactly once, not per variant"
    );
    assert_eq!(set.n_variants(), 3);
    // And serving the set still compiles nothing further.
    let mut coord = Coordinator::start(set, ServeConfig::new(2, 6), cost()).unwrap();
    for id in 0..6u64 {
        coord
            .submit(Request {
                id,
                rows: vec![(0..10).map(|_| rng.q_raw(8)).collect()],
            })
            .unwrap();
    }
    let responses = coord.drain().unwrap();
    assert_eq!(responses.len(), 6);
    coord.shutdown();
    assert_eq!(
        PLAN_COMPILATIONS.load(Ordering::SeqCst),
        before + 1,
        "serving a variant set must not recompile plans"
    );
}
