//! End-to-end tests of the fleet front end (DESIGN.md §17): multi-model
//! routing, per-tenant SLO classes, certified-cost admission control,
//! and replicated PE pools behind one submit/collect surface.
//!
//! The acceptance properties:
//!
//! 1. **Exactly-once, bit-exact.** Every admitted request is answered
//!    exactly once, tagged with the (model, tenant) it was served under,
//!    and its logits equal the scalar oracle of the variant the response
//!    reports having executed.
//! 2. **Conservation.** At every post-drain quiescent point, admitted =
//!    completed + nothing (no silent drops), per tenant; shed requests
//!    are typed `ServeError::Shed` and counted in the tenant's metrics
//!    bucket — never silently swallowed.
//! 3. **Isolation.** A tenant flooding past its admission budget is
//!    shed without perturbing a calm tenant's admission or fidelity.
//!
//! Determinism notes: deadlines are set far out (60 s), so batches only
//! move at submit-path dispatches, explicit ticks, and drains — the
//! admission decisions the tests assert on see exactly the queues the
//! test built.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use softsimd::coordinator::fleet::{Fleet, FleetConfig, ModelConfig};
use softsimd::coordinator::governor::SloClass;
use softsimd::coordinator::model::{CompiledModel, VariantSpec};
use softsimd::coordinator::server::{Request, Response, ServeConfig, ServeError};
use softsimd::nn::conv::LayerOp;
use softsimd::nn::exec::mlp_forward_row_mixed;
use softsimd::nn::weights::QuantLayer;
use softsimd::testutil::{flat_cost, random_dense_stack_uniform};
use softsimd::workload::synth::XorShift64;

/// A small 2-layer dense model (input width 8) carrying the standard
/// precision trio — big enough to have distinct variants, small enough
/// that the property test's hundreds of batches stay fast.
fn small_model(rng: &mut XorShift64, widths: &[usize]) -> (Vec<QuantLayer>, Arc<CompiledModel>) {
    let layers = random_dense_stack_uniform(rng, widths, 8);
    let ops: Vec<LayerOp> = layers.iter().cloned().map(LayerOp::Dense).collect();
    let n = layers.len();
    let model = CompiledModel::compile_variants(ops, VariantSpec::standard_trio(n)).unwrap();
    (layers, model)
}

fn random_rows(rng: &mut XorShift64, n: usize, width: usize) -> Vec<Vec<i64>> {
    (0..n).map(|_| (0..width).map(|_| rng.q_raw(8)).collect()).collect()
}

/// The per-variant scalar oracle, as the serving loop applies it:
/// requantize the reference-precision row by the executing variant's
/// input shift, then run that variant's schedule.
fn oracle(model: &CompiledModel, layers: &[QuantLayer], v: usize, row: &[i64]) -> Vec<i64> {
    let var = model.variant(v);
    mlp_forward_row_mixed(&var.quantize_row(row), layers, var.schedule())
}

/// What the tests remember about each admitted request.
struct Sent {
    model: usize,
    tenant: usize,
    rows: Vec<Vec<i64>>,
}

/// Check a batch of responses against the ledger: exactly-once ids,
/// (model, tenant) tag echo, and per-variant bit-exactness.
fn absorb(
    responses: &[Response],
    pending: &mut HashMap<u64, Sent>,
    stacks: &[(Vec<QuantLayer>, Arc<CompiledModel>)],
    done_per_tenant: &mut [u64],
) {
    for resp in responses {
        let sent = pending
            .remove(&resp.id)
            .unwrap_or_else(|| panic!("response {} unknown or duplicated", resp.id));
        assert_eq!(resp.model, sent.model, "response {} model tag", resp.id);
        assert_eq!(resp.tenant, sent.tenant, "response {} tenant tag", resp.id);
        assert_eq!(resp.logits.len(), sent.rows.len(), "response {} row count", resp.id);
        let (layers, model) = &stacks[sent.model];
        for (b, row) in sent.rows.iter().enumerate() {
            let want = oracle(model, layers, resp.variant, row);
            assert_eq!(
                resp.logits[b], want,
                "response {} row {b} diverges from variant {}'s oracle",
                resp.id, resp.variant
            );
        }
        done_per_tenant[sent.tenant] += 1;
    }
}

#[test]
fn two_models_three_tenants_round_trip_bit_exact_with_tag_echo() {
    let mut rng = XorShift64::new(0xF1EE7_0001);
    let stacks = vec![small_model(&mut rng, &[8, 6, 4]), small_model(&mut rng, &[8, 12, 4])];
    let cfg = FleetConfig::new()
        .model(
            ModelConfig::new(
                Arc::clone(&stacks[0].1),
                flat_cost(),
                ServeConfig::new(2, 4).deadline(Duration::from_secs(60)),
            )
            .pools(2),
        )
        .model(ModelConfig::new(
            Arc::clone(&stacks[1].1),
            flat_cost(),
            ServeConfig::new(1, 4).deadline(Duration::from_secs(60)),
        ))
        .tenant(SloClass::new("gold", Duration::from_secs(1), 64, 8).priority(0).target_rows(1))
        .tenant(SloClass::new("silver", Duration::from_secs(1), 64, 8).priority(1))
        .tenant(SloClass::new("bronze", Duration::from_secs(1), 64, 8).priority(2));
    let mut fleet = Fleet::start(cfg).unwrap();
    assert_eq!(fleet.n_models(), 2);
    assert_eq!(fleet.n_tenants(), 3);

    // 24 requests interleaved over every (model, tenant) pair, with
    // varying row counts so entries split across batches.
    let mut pending: HashMap<u64, Sent> = HashMap::new();
    let mut sent_reqs = [0u64; 3];
    let mut sent_rows = [0u64; 3];
    for id in 0..24u64 {
        let model = (id % 2) as usize;
        let tenant = (id % 3) as usize;
        let rows = random_rows(&mut rng, 1 + (id % 3) as usize, 8);
        sent_reqs[tenant] += 1;
        sent_rows[tenant] += rows.len() as u64;
        fleet
            .submit(model, tenant, Request { id, rows: rows.clone() })
            .unwrap_or_else(|e| panic!("submit {id}: {e}"));
        pending.insert(id, Sent { model, tenant, rows });
    }
    let responses = fleet.drain().unwrap();
    assert_eq!(responses.len(), 24, "every admitted request answered");
    let mut done = [0u64; 3];
    absorb(&responses, &mut pending, &stacks, &mut done);
    assert!(pending.is_empty(), "all ids accounted for");
    assert_eq!(fleet.pending_rows(), 0);

    // Per-tenant accounting: the classes' fleet-wide buckets saw
    // exactly the admitted traffic, and nothing was shed.
    for t in 0..3 {
        let snap = fleet.tenant_metrics(t).snapshot();
        assert_eq!(done[t], sent_reqs[t], "tenant {t} responses");
        assert_eq!(snap.requests, sent_reqs[t], "tenant {t} admitted");
        assert_eq!(snap.rows, sent_rows[t], "tenant {t} completed rows");
        assert_eq!(snap.shed_requests, 0, "tenant {t} sheds");
        assert!(snap.energy_aj > 0, "tenant {t} billed energy");
    }
    fleet.shutdown();
}

#[test]
fn random_interleavings_deliver_exactly_once_and_conserve_rows() {
    // Property test: under random submit / tick / collect / drain
    // interleavings — with one tenant whose tiny admission budget sheds
    // whenever its queue is non-empty — every admitted request is
    // answered exactly once, every rejection is a typed shed, and at
    // every post-drain quiescent point the per-tenant ledgers balance.
    for seed in [0xF1EE7_1001u64, 0xF1EE7_1002, 0xF1EE7_1003] {
        let mut rng = XorShift64::new(seed);
        let stacks = vec![small_model(&mut rng, &[8, 6, 4])];
        let cfg = FleetConfig::new()
            .model(
                ModelConfig::new(
                    Arc::clone(&stacks[0].1),
                    flat_cost(),
                    ServeConfig::new(2, 3).deadline(Duration::from_secs(60)).queue_depth(2),
                )
                .pools(2),
            )
            .tenant(SloClass::new("calm", Duration::from_secs(1), 64, 8).priority(0))
            .tenant(SloClass::new("mid", Duration::from_secs(1), 64, 8).priority(1))
            .tenant(
                SloClass::new("greedy", Duration::from_millis(1), 64, 8)
                    .priority(2)
                    .drain_budget(Duration::from_nanos(1))
                    .target_rows(16),
            );
        let mut fleet = Fleet::start(cfg).unwrap();

        let mut pending: HashMap<u64, Sent> = HashMap::new();
        let mut admitted_reqs = [0u64; 3];
        let mut admitted_rows = [0u64; 3];
        let mut shed_reqs = [0u64; 3];
        let mut done = [0u64; 3];
        let mut next_id = 0u64;
        for op in 0..200 {
            match rng.next_u64() % 10 {
                0..=6 => {
                    let tenant = (rng.next_u64() % 3) as usize;
                    let rows = random_rows(&mut rng, 1 + (rng.next_u64() % 3) as usize, 8);
                    let id = next_id;
                    next_id += 1;
                    match fleet.submit(0, tenant, Request { id, rows: rows.clone() }) {
                        Ok(()) => {
                            admitted_reqs[tenant] += 1;
                            admitted_rows[tenant] += rows.len() as u64;
                            pending.insert(id, Sent { model: 0, tenant, rows });
                        }
                        Err(ServeError::Shed { tenant: t, reason }) => {
                            assert_eq!(t, tenant, "shed attribution (op {op})");
                            assert!(
                                reason.contains("budget"),
                                "shed reason names the budget: {reason}"
                            );
                            shed_reqs[tenant] += 1;
                        }
                        Err(e) => panic!("op {op}: untyped rejection {e}"),
                    }
                }
                7 => fleet.tick_now(),
                8 => {
                    let got = fleet.try_collect();
                    absorb(&got, &mut pending, &stacks, &mut done);
                }
                _ => {
                    let got = fleet.drain().unwrap();
                    absorb(&got, &mut pending, &stacks, &mut done);
                    // Quiescent point: everything admitted so far is
                    // answered, nothing is queued, ledgers balance.
                    assert!(pending.is_empty(), "seed {seed:#x} op {op}: unanswered ids");
                    assert_eq!(fleet.pending_rows(), 0);
                    for t in 0..3 {
                        let snap = fleet.tenant_metrics(t).snapshot();
                        assert_eq!(snap.requests, admitted_reqs[t], "tenant {t} admitted");
                        assert_eq!(snap.rows, admitted_rows[t], "tenant {t} rows");
                        assert_eq!(snap.shed_requests, shed_reqs[t], "tenant {t} sheds");
                        assert_eq!(done[t], admitted_reqs[t], "tenant {t} delivered");
                    }
                }
            }
        }
        let got = fleet.drain().unwrap();
        absorb(&got, &mut pending, &stacks, &mut done);
        assert!(pending.is_empty(), "seed {seed:#x}: unanswered ids at the end");
        assert_eq!(fleet.pending_rows(), 0);
        for t in 0..3 {
            let snap = fleet.tenant_metrics(t).snapshot();
            assert_eq!(snap.requests, admitted_reqs[t]);
            assert_eq!(snap.rows, admitted_rows[t]);
            assert_eq!(snap.shed_requests, shed_reqs[t]);
            assert_eq!(done[t], admitted_reqs[t]);
        }
        // The greedy tenant's budget must actually have engaged.
        assert!(shed_reqs[2] > 0, "seed {seed:#x}: greedy tenant never shed");
        fleet.shutdown();
    }
}

#[test]
fn flooding_tenant_is_shed_without_perturbing_the_calm_tenant() {
    let mut rng = XorShift64::new(0xF1EE7_2001);
    let stacks = vec![small_model(&mut rng, &[8, 6, 4])];
    let cfg = FleetConfig::new()
        .model(ModelConfig::new(
            Arc::clone(&stacks[0].1),
            flat_cost(),
            ServeConfig::new(1, 4).deadline(Duration::from_secs(60)),
        ))
        // Interactive: far-out p99 objective (governor never sheds
        // fidelity), generous budget, 1-row target so its submits
        // dispatch immediately.
        .tenant(
            SloClass::new("interactive", Duration::from_secs(300), 64, 8)
                .priority(0)
                .target_rows(1),
        )
        // Bulk: a 1 ns budget and a 32-row fill target. Within a round
        // its first request parks 8 rows in the lane (no dispatch —
        // target unmet, deadline far out), so its second request
        // deterministically lands on a non-empty queue and sheds.
        .tenant(
            SloClass::new("bulk", Duration::from_millis(1), 64, 8)
                .priority(2)
                .drain_budget(Duration::from_nanos(1))
                .target_rows(32),
        );
    let mut fleet = Fleet::start(cfg).unwrap();

    let mut pending: HashMap<u64, Sent> = HashMap::new();
    let mut done = [0u64; 2];
    let mut next_id = 0u64;
    let rounds = 10u64;
    for round in 0..rounds {
        // Bulk floods first: one admitted, one deterministically shed.
        let rows = random_rows(&mut rng, 8, 8);
        fleet
            .submit(0, 1, Request { id: next_id, rows: rows.clone() })
            .unwrap_or_else(|e| panic!("round {round}: first bulk submit: {e}"));
        pending.insert(next_id, Sent { model: 0, tenant: 1, rows });
        next_id += 1;
        let extra = random_rows(&mut rng, 8, 8);
        match fleet.submit(0, 1, Request { id: next_id, rows: extra }) {
            Err(ServeError::Shed { tenant: 1, .. }) => {}
            other => panic!("round {round}: expected a typed bulk shed, got {other:?}"),
        }
        next_id += 1;
        // The calm tenant submits into the same pool, mid-flood — and
        // must be admitted (its own queue is empty; bulk's backlog is
        // not its problem).
        let rows = random_rows(&mut rng, 1, 8);
        fleet
            .submit(0, 0, Request { id: next_id, rows: rows.clone() })
            .unwrap_or_else(|e| panic!("round {round}: interactive submit: {e}"));
        pending.insert(next_id, Sent { model: 0, tenant: 0, rows });
        next_id += 1;
        let got = fleet.drain().unwrap();
        absorb(&got, &mut pending, &stacks, &mut done);
    }
    assert!(pending.is_empty());
    let inter = fleet.tenant_metrics(0).snapshot();
    let bulk = fleet.tenant_metrics(1).snapshot();
    assert_eq!(inter.requests, rounds, "interactive fully admitted");
    assert_eq!(inter.shed_requests, 0, "interactive never shed");
    assert_eq!(done[0], rounds);
    assert_eq!(bulk.requests, rounds, "one bulk request admitted per round");
    assert_eq!(bulk.shed_requests, rounds, "one bulk request shed per round");
    assert_eq!(bulk.shed_rows, rounds * 8, "shed rows counted");
    assert_eq!(done[1], rounds);
    // Isolation of fidelity: interactive's governor saw only its own
    // calm window, so it stayed at the reference variant throughout.
    assert_eq!(fleet.active_variant(0, 0), 0, "interactive stays hi-fi");
    fleet.shutdown();
}

#[test]
fn config_and_routing_errors_are_typed() {
    let mut rng = XorShift64::new(0xF1EE7_3001);
    let (_, model) = small_model(&mut rng, &[8, 6, 4]);
    let pool = ServeConfig::new(1, 2).deadline(Duration::from_secs(60));

    // Structural config errors.
    match Fleet::start(FleetConfig::new().tenant(SloClass::unbounded("t"))) {
        Err(ServeError::InvalidConfig { what }) => assert!(what.contains("model"), "{what}"),
        other => panic!("expected InvalidConfig for a model-less fleet, got {other:?}"),
    }
    match Fleet::start(
        FleetConfig::new().model(ModelConfig::new(Arc::clone(&model), flat_cost(), pool.clone())),
    ) {
        Err(ServeError::InvalidConfig { what }) => assert!(what.contains("tenant"), "{what}"),
        other => panic!("expected InvalidConfig for a tenant-less fleet, got {other:?}"),
    }
    match Fleet::start(
        FleetConfig::new()
            .model(ModelConfig::new(Arc::clone(&model), flat_cost(), pool.clone()).pools(0))
            .tenant(SloClass::unbounded("t")),
    ) {
        Err(ServeError::InvalidConfig { what }) => assert!(what.contains("n_pools"), "{what}"),
        other => panic!("expected InvalidConfig for zero pools, got {other:?}"),
    }
    match Fleet::start(
        FleetConfig::new()
            .model(ModelConfig::new(Arc::clone(&model), flat_cost(), ServeConfig::new(0, 2)))
            .tenant(SloClass::unbounded("t")),
    ) {
        Err(ServeError::InvalidConfig { what }) => assert!(what.contains("n_pes"), "{what}"),
        other => panic!("expected InvalidConfig for zero PEs, got {other:?}"),
    }

    // Routing errors on a live fleet.
    let mut fleet = Fleet::start(
        FleetConfig::new()
            .model(ModelConfig::new(Arc::clone(&model), flat_cost(), pool))
            .tenant(SloClass::unbounded("only"))
            .tenant(
                SloClass::new("tight", Duration::from_millis(1), 64, 8)
                    .drain_budget(Duration::from_nanos(1))
                    .target_rows(32),
            ),
    )
    .unwrap();
    let req = || Request { id: 0, rows: vec![vec![1; 8]] };
    match fleet.submit(7, 0, req()) {
        Err(ServeError::UnknownModel { model: 7 }) => {}
        other => panic!("expected UnknownModel, got {other:?}"),
    }
    match fleet.submit(0, 9, req()) {
        Err(ServeError::UnknownTenant { tenant: 9 }) => {}
        other => panic!("expected UnknownTenant, got {other:?}"),
    }
    assert!(matches!(
        fleet.install_policy(3, 0, Box::new(softsimd::coordinator::governor::PinnedVariant(0))),
        Err(ServeError::UnknownModel { model: 3 })
    ));
    assert!(matches!(
        fleet.install_policy(0, 6, Box::new(softsimd::coordinator::governor::PinnedVariant(0))),
        Err(ServeError::UnknownTenant { tenant: 6 })
    ));

    // The shed error carries the tenant and a reason naming the queue
    // and the class budget.
    fleet.submit(0, 1, Request { id: 1, rows: random_rows(&mut rng, 4, 8) }).unwrap();
    match fleet.submit(0, 1, Request { id: 2, rows: random_rows(&mut rng, 1, 8) }) {
        Err(ServeError::Shed { tenant: 1, reason }) => {
            assert!(reason.contains("queued"), "reason names the backlog: {reason}");
            assert!(reason.contains("budget"), "reason names the budget: {reason}");
            assert!(reason.contains("tight"), "reason names the class: {reason}");
        }
        other => panic!("expected a typed shed, got {other:?}"),
    }
    let responses = fleet.drain().unwrap();
    assert_eq!(responses.len(), 1, "the admitted request still completes");
    assert_eq!(responses[0].id, 1);
    fleet.shutdown();
}
