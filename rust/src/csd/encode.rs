//! Canonical Signed Digit recoding.
//!
//! A multiplier is a `Q1.Y` value, i.e. a `(Y+1)`-bit two's-complement
//! integer `M` representing `M / 2^Y ∈ [-1, 1)`. Its CSD form is the
//! unique radix-2 signed-digit string `d_0 .. d_Y` (digit `d_j` has
//! weight `2^-j`; `d_0` is the integer-position digit) with
//! `M/2^Y = Σ d_j 2^-j`, digits in {-1, 0, +1} and **no two adjacent
//! nonzero digits**. CSD strings average ~2/3 zero digits, which is what
//! the shift-coalescing pipeline exploits (Section II-B).

/// One signed digit. `P` = +1, `Z` = 0, `N` = −1 (printed `1`, `0`, `-`
/// as in the paper's example "0-01").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Digit {
    P,
    Z,
    N,
}

impl Digit {
    #[inline]
    pub fn value(self) -> i64 {
        match self {
            Digit::P => 1,
            Digit::Z => 0,
            Digit::N => -1,
        }
    }

    pub fn from_value(v: i64) -> Digit {
        match v {
            1 => Digit::P,
            0 => Digit::Z,
            -1 => Digit::N,
            _ => panic!("not a signed digit: {v}"),
        }
    }

    pub fn symbol(self) -> char {
        match self {
            Digit::P => '1',
            Digit::Z => '0',
            Digit::N => '-',
        }
    }
}

/// CSD-encode the `(y_bits)`-bit two's-complement raw multiplier `m_raw`
/// (a `Q1.(y_bits-1)` value). Returns digits **most-significant first**:
/// `out[0]` has weight `2^0` (the integer position), `out[j]` weight
/// `2^-j`, `out.len() == y_bits`.
///
/// Classic recoding: scan LSB→MSB over `M`; when a run of ones is found,
/// replace `0111..1` by `1000..0-1`. Implemented arithmetically: digit at
/// position i (LSB-indexed) is nonzero iff bit i of `M' = M + (M<<1)`'s
/// carry structure flips — we use the standard `(m + lsb) ...` loop form
/// for clarity instead.
pub fn csd_encode(m_raw: i64, y_bits: u32) -> Vec<Digit> {
    assert!(y_bits >= 2 && y_bits <= 48);
    let half = 1i64 << (y_bits - 1);
    assert!(
        m_raw >= -half && m_raw < half,
        "multiplier raw {m_raw} out of Q1.{} range",
        y_bits - 1
    );
    // Work LSB-first on a widening copy; CSD of an n-bit two's-complement
    // number never needs a digit above weight 2^(n-1) *for values in
    // [-2^(n-1), 2^(n-1))*: the borrow absorbed by the sign position keeps
    // the string within n digits.
    let mut m = m_raw;
    let mut digits_lsb: Vec<Digit> = Vec::with_capacity(y_bits as usize);
    for _ in 0..y_bits {
        if m & 1 == 0 {
            digits_lsb.push(Digit::Z);
        } else {
            // Choose d = ±1 so that (m − d) is divisible by 4 when
            // possible, i.e. d = 2 − (m mod 4) mapped to {+1, −1}:
            // m ≡ 1 (mod 4) → d = +1 ; m ≡ 3 (mod 4) → d = −1.
            let d = if m & 3 == 1 { Digit::P } else { Digit::N };
            digits_lsb.push(d);
            m -= d.value();
        }
        m >>= 1; // arithmetic
    }
    debug_assert_eq!(m, 0, "CSD residual for {m_raw} @ {y_bits} bits");
    digits_lsb.reverse(); // MSB-first
    digits_lsb
}

/// Decode a MSB-first digit string back to the raw `Q1.(len-1)` integer:
/// `raw = Σ_j d_j · 2^(len-1-j)`.
pub fn csd_decode(digits: &[Digit]) -> i64 {
    let n = digits.len();
    digits
        .iter()
        .enumerate()
        .map(|(j, d)| d.value() << (n - 1 - j))
        .sum()
}

/// Render as the paper's notation, e.g. `0-01` for −3/2^3... (MSB first).
pub fn csd_string(digits: &[Digit]) -> String {
    digits.iter().map(|d| d.symbol()).collect()
}

/// Number of nonzero digits (= number of add/sub operations a
/// shift-add multiplier must perform).
pub fn nonzero_count(digits: &[Digit]) -> usize {
    digits.iter().filter(|d| !matches!(d, Digit::Z)).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encodes_paper_example() {
        // Paper: "0-01" equals (−4) + 1 = −3 (4 digits, MSB first).
        let d = csd_encode(-3, 4);
        assert_eq!(csd_string(&d), "0-01");
        assert_eq!(csd_decode(&d), -3);
    }

    #[test]
    fn roundtrip_all_values_small_widths() {
        for bits in [4u32, 6, 8, 12] {
            let half = 1i64 << (bits - 1);
            for m in -half..half {
                let d = csd_encode(m, bits);
                assert_eq!(d.len(), bits as usize);
                assert_eq!(csd_decode(&d), m, "bits={bits} m={m}");
            }
        }
    }

    #[test]
    fn roundtrip_sampled_16bit() {
        let half = 1i64 << 15;
        let mut m = -half;
        while m < half {
            let d = csd_encode(m, 16);
            assert_eq!(csd_decode(&d), m);
            m += 37;
        }
    }

    #[test]
    fn no_adjacent_nonzero_digits() {
        for bits in [4u32, 6, 8] {
            let half = 1i64 << (bits - 1);
            for m in -half..half {
                let d = csd_encode(m, bits);
                for w in d.windows(2) {
                    assert!(
                        matches!(w[0], Digit::Z) || matches!(w[1], Digit::Z),
                        "adjacent nonzeros in {} for m={m}",
                        csd_string(&d)
                    );
                }
            }
        }
    }

    #[test]
    fn minimality_vs_binary() {
        // CSD has ≤ as many nonzero digits as plain binary for all values.
        for m in -128i64..128 {
            let d = csd_encode(m, 8);
            let bin_ones = (m as u64 & 0xFF).count_ones() as usize;
            // For negative m, binary two's complement nonzero count is a fair proxy.
            assert!(nonzero_count(&d) <= bin_ones.max(1) + 1);
        }
    }

    #[test]
    fn minus_one_is_single_digit() {
        // Q1.7 value −1.0 is raw −128 → CSD "-0000000".
        let d = csd_encode(-128, 8);
        assert_eq!(csd_string(&d), "-0000000");
    }

    #[test]
    fn near_one_uses_top_digit() {
        // 0.1111111 (raw 127) → 1.000000-1 needs weight 2^0 and 2^-7:
        // MSB-first digits: P at j=0, N at j=7.
        let d = csd_encode(127, 8);
        assert_eq!(csd_string(&d), "1000000-");
        assert_eq!(csd_decode(&d), 127);
    }
}
