//! `eval verify` — the static lane-safety margin report (DESIGN.md §14).
//!
//! Runs the abstract interpreter (`crate::analysis`) over the standard
//! serving trio on both synthetic stacks (the matched-filter MLP and
//! the sparse-sign CNN) and prints the per-layer worst-case accumulator
//! ranges and bit margins — every variant must verify, or this command
//! errors. It then demonstrates the rejection path on a deliberately
//! under-provisioned model (a 32-tap fan-in into an equal-width 8-bit
//! accumulator): the verifier must reject it with a synthesized
//! counterexample row, and the counterexample must actually wrap when
//! shadow-executed. The margins are also written to
//! `VERIFY_margins.json` (cwd-relative, like the `BENCH_*.json`
//! artifacts) for CI upload.

use crate::analysis::{find_first_wrap, verify_stack, AnalysisError, LaneSafetyReport};
use crate::anyhow;
use crate::coordinator::model::VariantSpec;
use crate::nn::conv::LayerOp;
use crate::nn::weights::{uniform_schedule, QuantLayer};
use crate::workload::synth::{synth_cnn_stack, synth_mlp_stack};

/// The deliberately lane-unsafe demo model: 32 taps of +0.25 into each
/// of 4 columns, scheduled into an accumulator no wider than the
/// activations — the worst-case sum needs 11 bits against the 8
/// provided.
fn wide_fanin() -> Vec<LayerOp> {
    vec![LayerOp::Dense(QuantLayer::new(vec![vec![32; 4]; 32], 8))]
}

fn print_report(variant: &str, report: &LaneSafetyReport) {
    println!(
        "  {variant:<16} {:>5}  {:>6}  {:>22}  {:>6}  {:>6}",
        "layer", "in/acc", "worst-case acc range", "needed", "margin"
    );
    for m in &report.layers {
        println!(
            "  {:<16} {:>5}  {:>3}/{:<3} {:>21}  {:>6}  {:>6}",
            "",
            m.layer,
            m.precision.in_bits,
            m.precision.acc_bits,
            format!("[{}, {}]", m.acc_lo, m.acc_hi),
            m.needed_bits,
            m.margin_bits
        );
    }
}

/// Minimal JSON string escaping for the error messages embedded in the
/// margin artifact.
fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Run the margin report; errors if any trio variant fails to verify
/// or the rejection demo fails to reject (both would falsify the
/// acceptance claim of DESIGN.md §14).
pub fn run() -> anyhow::Result<()> {
    println!("== eval verify: static lane-safety margins ==\n");
    let stacks: Vec<(&str, Vec<LayerOp>)> = vec![
        ("synth-mlp", synth_mlp_stack(8)),
        ("synth-cnn", synth_cnn_stack(0x5C4EF, 8)),
    ];
    let mut json = String::from("{\n  \"models\": [\n");
    for (si, (name, stack)) in stacks.iter().enumerate() {
        println!("model {name} ({} layers):", stack.len());
        if si > 0 {
            json.push_str(",\n");
        }
        json.push_str(&format!(
            "    {{\"model\": \"{name}\", \"variants\": ["
        ));
        for (vi, spec) in VariantSpec::standard_trio(stack.len()).iter().enumerate() {
            let report = verify_stack(stack, &spec.schedule).map_err(|e| {
                anyhow::anyhow!("{name} variant {} failed to verify: {e}", spec.name)
            })?;
            print_report(&spec.name, &report);
            if vi > 0 {
                json.push_str(", ");
            }
            json.push_str(&format!(
                "{{\"variant\": \"{}\", \"min_margin_bits\": {}, \"layers\": [{}]}}",
                spec.name,
                report.min_margin_bits(),
                report
                    .layers
                    .iter()
                    .map(|m| format!(
                        "{{\"layer\": {}, \"in_bits\": {}, \"acc_bits\": {}, \
                         \"acc_lo\": {}, \"acc_hi\": {}, \"needed_bits\": {}, \
                         \"margin_bits\": {}}}",
                        m.layer,
                        m.precision.in_bits,
                        m.precision.acc_bits,
                        m.acc_lo,
                        m.acc_hi,
                        m.needed_bits,
                        m.margin_bits
                    ))
                    .collect::<Vec<_>>()
                    .join(", ")
            ));
        }
        json.push_str("]}");
        println!();
    }
    json.push_str("\n  ],\n");

    // The rejection demo: the verifier must reject the under-provisioned
    // schedule and hand back a replayable trigger.
    println!("rejection demo: 32-tap fan-in, uniform 8-bit in -> 8-bit acc:");
    let hot = wide_fanin();
    let sched = uniform_schedule(8, 8, 1);
    let err = match verify_stack(&hot, &sched) {
        Err(e) => e,
        Ok(r) => anyhow::bail!(
            "under-provisioned schedule unexpectedly verified (min margin {})",
            r.min_margin_bits()
        ),
    };
    println!("  rejected: {err}");
    anyhow::ensure!(
        matches!(err, AnalysisError::AccumulatorOverflow { .. }),
        "expected an accumulator-overflow rejection, got: {err}"
    );
    let cx = err
        .counterexample()
        .ok_or_else(|| anyhow::anyhow!("rejection carried no counterexample"))?;
    let wrap = find_first_wrap(&hot, &sched, cx).ok_or_else(|| {
        anyhow::anyhow!("synthesized counterexample does not wrap under shadow execution")
    })?;
    println!("  counterexample replays: {wrap:?}");
    println!("  (run `cargo test --features lanecheck` to see the dynamic sanitizer");
    println!("   confirm both directions of this verdict)\n");
    json.push_str(&format!(
        "  \"rejection\": {{\"model\": \"wide-fanin-32x4\", \"schedule\": \"8->8\", \
         \"error\": \"{}\", \"counterexample_len\": {}}}\n}}\n",
        esc(&err.to_string()),
        cx.len()
    ));

    std::fs::write("VERIFY_margins.json", &json)?;
    println!("margins written to VERIFY_margins.json");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wide_fanin_fixture_is_rejected_with_a_replayable_counterexample() {
        let hot = wide_fanin();
        let sched = uniform_schedule(8, 8, 1);
        let err = verify_stack(&hot, &sched).expect_err("32 taps need 11 bits");
        let cx = err.counterexample().expect("layer-0 rejection synthesizes a row");
        assert!(find_first_wrap(&hot, &sched, cx).is_some());
        // No wider accumulator rescues the fan-in — Q1 widening is
        // value-preserving, so the needed width grows with `acc_bits` —
        // which is exactly why the demo rejects on fan-in, not format.
        assert!(verify_stack(&hot, &uniform_schedule(8, 16, 1)).is_err());
    }
}
