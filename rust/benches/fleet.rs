//! Fleet serving benchmark: drives the same 2-model × 3-tenant
//! light → burst → light trace as `eval fleet` (DESIGN.md §17) and
//! records one JSON cell per (phase, tenant) with throughput, windowed
//! p99, billed energy per row and the admission shed rate.
//!
//! Run: `cargo bench --bench fleet` — writes `BENCH_fleet.json`.

#[path = "benchkit.rs"]
mod benchkit;

use benchkit::write_cells;
use softsimd::eval::fleet::run_scenario;

fn main() {
    println!("== fleet serving bench: 2 models x 3 tenant classes ==\n");
    let stats = run_scenario().expect("fleet scenario");
    println!(
        "{:<10} {:<12} {:>9} {:>6} {:>7} {:>10} {:>9} {:>8} {:>10}",
        "phase", "tenant", "admitted", "shed", "rows", "rows/s", "p99 us", "pJ/row", "shed rate"
    );
    let mut cells = Vec::new();
    for s in &stats {
        println!(
            "{:<10} {:<12} {:>9} {:>6} {:>7} {:>10.0} {:>9.1} {:>8.1} {:>10.2}",
            s.phase,
            s.tenant,
            s.requests,
            s.shed,
            s.rows,
            s.rows_per_s,
            s.p99_us,
            s.pj_per_row,
            s.shed_rate
        );
        cells.push(format!(
            "{{\"phase\":\"{}\",\"tenant\":\"{}\",\"admitted\":{},\"shed\":{},\"rows\":{},\
             \"rows_per_s\":{:.1},\"p99_us\":{:.2},\"pj_per_row\":{:.3},\"shed_rate\":{:.4}}}",
            s.phase,
            s.tenant,
            s.requests,
            s.shed,
            s.rows,
            s.rows_per_s,
            s.p99_us,
            s.pj_per_row,
            s.shed_rate
        ));
    }
    write_cells("fleet", "BENCH_fleet.json", &cells);
}
