//! Mixed-precision serving demo: the same quantized MLP deployed under
//! three per-layer precision schedules, served through the coordinator,
//! with exact per-format cycle/energy accounting compared across runs
//! (DESIGN.md §10).
//!
//! Unlike `serve.rs` this needs no AOT artifacts: the model is quantized
//! locally from synthetic float weights, so it runs anywhere.
//!
//! Run: `cargo run --release --example mixed_precision_serve`

use softsimd::anyhow;
use softsimd::coordinator::cost::CostTable;
use softsimd::coordinator::model::CompiledModel;
use softsimd::coordinator::server::{Coordinator, Request, ServeConfig};
use softsimd::nn::weights::{quantize_stack, LayerPrecision};
use softsimd::workload::synth::XorShift64;

fn main() -> anyhow::Result<()> {
    // A 32→24→16→10 float MLP, quantized at 8-bit weights per layer.
    let mut rng = XorShift64::new(0x111D);
    let dims = [32usize, 24, 16, 10];
    let float_w: Vec<Vec<Vec<f64>>> = dims
        .windows(2)
        .map(|w| {
            (0..w[0])
                .map(|_| (0..w[1]).map(|_| rng.uniform() * 2.0 - 1.0).collect())
                .collect()
        })
        .collect();
    let layers = quantize_stack(&float_w, &[8, 8, 8])?;

    println!("characterizing pipeline energy at 1 GHz…");
    let cost = CostTable::characterize(1000.0);

    let schedules: Vec<(&str, Vec<LayerPrecision>)> = vec![
        (
            "uniform 8-8-8",
            vec![
                LayerPrecision::new(8, 16),
                LayerPrecision::new(8, 16),
                LayerPrecision::new(8, 16),
            ],
        ),
        (
            "low-first 4-6-8",
            vec![
                LayerPrecision::new(4, 8),
                LayerPrecision::new(6, 12),
                LayerPrecision::new(8, 16),
            ],
        ),
        (
            "narrowing 16-8-4",
            vec![
                LayerPrecision::new(16, 16),
                LayerPrecision::new(8, 16),
                LayerPrecision::new(4, 8),
            ],
        ),
    ];

    for (name, sched) in schedules {
        let model = CompiledModel::compile_scheduled(layers.clone(), sched.clone())?;
        println!(
            "\n== {name}: batch quantum {} rows, boundaries {} ==",
            model.batch_quantum(),
            (0..sched.len() - 1)
                .map(|li| format!("{} hop(s)", model.boundary_chain(li).len()))
                .collect::<Vec<_>>()
                .join(", ")
        );
        let in_bits = model.in_bits();
        let mut coord = Coordinator::start(model, ServeConfig::new(2, 12), cost.clone())?;
        for id in 0..256u64 {
            coord.submit(Request {
                id,
                rows: vec![(0..dims[0]).map(|_| rng.q_raw(in_bits)).collect()],
            })?;
        }
        let responses = coord.drain()?;
        anyhow::ensure!(responses.len() == 256, "all requests must complete");
        println!("{}", coord.metrics.report());
        coord.shutdown();
    }
    Ok(())
}
