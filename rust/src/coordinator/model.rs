//! The immutable, shareable serving model: weights + precompiled CSD
//! multiply plans + packing metadata, built **once** and handed to every
//! PE worker behind an `Arc` (DESIGN.md §8).
//!
//! This is the schedule-amortization idea of the paper's control path
//! (the CSD plan is a property of the *multiplier value*, not of the
//! operand stream): compiling the per-weight shift-add programs is the
//! expensive, quantization-dependent step, so it must happen off the
//! per-request critical path and exactly once per deployed model — not
//! once per worker, as the original demo loop did.
//!
//! Since DESIGN.md §13 a compiled model is a **variant set**: one
//! `LayerOp` stack carrying one or more precision [`Variant`]s (a full
//! per-layer [`LayerPrecision`] schedule each, with its precomputed
//! Stage-2 boundary conversion chains and batch quantum), so the
//! coordinator can switch the serving precision at run time without
//! touching the weights. The CSD plans depend only on the weight
//! values, never on the schedule, so the plan tables and the flattened
//! [`PlanArena`] are compiled **once** and shared by every variant —
//! `PLAN_COMPILATIONS` counts one compilation per variant *set*, not
//! per variant, and the tests pin that.
//!
//! All structural validation happens here, at compile, so a malformed
//! model (empty stack, non-chaining dims, unsupported or inverted
//! format pair, a variant wider than the reference at the first layer)
//! is an error for its builder — never a panic inside a PE worker.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use crate::analysis::cost::CostCertificate;
use crate::analysis::{AnalysisError, LaneSafetyReport};
use crate::anyhow;
use crate::bits::format::SimdFormat;
use crate::csd::flat::PlanArena;
use crate::csd::schedule::{schedule_truncated, MulPlan, Truncation};
use crate::nn::conv::LayerOp;
use crate::nn::weights::{uniform_schedule, LayerPrecision, QuantLayer};
use crate::pipeline::stage2::conversion_chain;

/// Process-wide count of CSD plan compilations. Exists so tests can
/// assert that plan compilation happens exactly once per model no
/// matter how many PE workers serve it — and exactly once per variant
/// *set* no matter how many precision variants it carries.
pub static PLAN_COMPILATIONS: AtomicU64 = AtomicU64::new(0);

/// A declared precision variant: a display name plus one
/// [`LayerPrecision`] per layer, and optionally a CSD [`Truncation`]
/// policy selecting an **approximate plan bank** (DESIGN.md §18).
/// `specs[0]` of a variant set is the **reference** variant — requests
/// arrive quantized at its first-layer activation width, and every
/// other variant's first layer must be at most that wide (narrower
/// variants consume the same request stream through an arithmetic
/// right shift; [`Variant::in_shift`]); the reference must also execute
/// the exact plans (`Truncation::NONE`).
#[derive(Debug, Clone)]
pub struct VariantSpec {
    pub name: String,
    pub schedule: Vec<LayerPrecision>,
    /// CSD digit truncation this variant executes under —
    /// `Truncation::NONE` (the default) runs the exact plans.
    pub truncation: Truncation,
}

impl VariantSpec {
    pub fn new(name: impl Into<String>, schedule: Vec<LayerPrecision>) -> VariantSpec {
        VariantSpec { name: name.into(), schedule, truncation: Truncation::NONE }
    }

    /// Builder: execute this variant on the truncated plan bank of
    /// policy `trunc` (compiled once per distinct policy and shared by
    /// every variant that names it).
    pub fn with_truncation(mut self, trunc: Truncation) -> VariantSpec {
        self.truncation = trunc;
        self
    }

    /// The standard serving trio over an `n_layers` stack, ordered
    /// hi-fidelity first (the reference variant) to cheapest:
    /// `hifi-8` (uniform 8→16), `balanced-4-6-8` (4-bit first layer,
    /// 6-bit middle, 8-bit last) and `turbo-4-4-8` (4-bit everywhere
    /// but the last layer) — the three operating points the governor
    /// trades between under load.
    pub fn standard_trio(n_layers: usize) -> Vec<VariantSpec> {
        assert!(n_layers > 0, "variant trio needs at least one layer");
        let ramp = |li: usize| -> LayerPrecision {
            if li + 1 == n_layers {
                LayerPrecision::new(8, 16)
            } else if li == 0 {
                LayerPrecision::new(4, 8)
            } else {
                LayerPrecision::new(6, 12)
            }
        };
        let turbo = |li: usize| -> LayerPrecision {
            if li + 1 == n_layers {
                LayerPrecision::new(8, 16)
            } else {
                LayerPrecision::new(4, 8)
            }
        };
        vec![
            VariantSpec::new("hifi-8", uniform_schedule(8, 16, n_layers)),
            VariantSpec::new("balanced-4-6-8", (0..n_layers).map(ramp).collect()),
            VariantSpec::new("turbo-4-4-8", (0..n_layers).map(turbo).collect()),
        ]
    }

    /// The standard trio extended past narrow-width into approximate
    /// serving (DESIGN.md §18): the turbo schedule re-compiled against
    /// truncated-CSD plan banks, still ordered hi-fidelity first to
    /// cheapest — the shed ladder the governor descends under certified
    /// drain-budget pressure. `approx-t2` drops CSD digits of raw
    /// weight < 4 (per-weight error ≤ 2 raw ULPs, [`naf_max_below`]);
    /// `approx-d1` keeps only each weight's most-significant digit
    /// (every multiply ≤ 1 add cycle).
    ///
    /// [`naf_max_below`]: crate::csd::schedule::naf_max_below
    pub fn standard_ladder(n_layers: usize) -> Vec<VariantSpec> {
        let mut specs = VariantSpec::standard_trio(n_layers);
        let turbo_sched = specs[2].schedule.clone();
        specs.push(
            VariantSpec::new("approx-t2", turbo_sched.clone())
                .with_truncation(Truncation::drop_least(2)),
        );
        specs.push(
            VariantSpec::new("approx-d1", turbo_sched)
                .with_truncation(Truncation::keep_digits(1)),
        );
        specs
    }
}

/// One compiled precision variant: the validated schedule plus
/// everything precomputed from it (boundary chains, batch quantum,
/// request requantization shift). Weights and CSD plans live on the
/// owning [`CompiledModel`], shared across all variants.
#[derive(Debug)]
pub struct Variant {
    name: String,
    /// One activation/accumulator format pair per layer.
    schedule: Vec<LayerPrecision>,
    /// `chains[li]`: the crossbar hop chain converting layer `li`'s
    /// accumulator stream into layer `li+1`'s activation format
    /// (`layers.len() - 1` entries; empty chain = Stage-2 bypass).
    chains: Vec<Vec<(SimdFormat, SimdFormat)>>,
    /// Rows per full packed batch: the LCM of every layer's activation
    /// and accumulator lane counts, so no layer ever sees a partial
    /// final word (6 for the uniform 8→16 schedule, up to 24 mixed).
    batch_quantum: usize,
    /// Arithmetic right shift turning a reference-precision request
    /// value into this variant's first-layer activation format (0 for
    /// the reference variant itself).
    in_shift: u32,
    /// The CSD truncation policy this variant executes under
    /// (`Truncation::NONE` for exact variants).
    truncation: Truncation,
    /// Which [`PlanArena`] bank holds this variant's plans: bank 0 is
    /// always the exact plans; truncated policies get one shared bank
    /// each (deduplicated across variants).
    plan_bank: usize,
}

impl Variant {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The full precision schedule, one entry per layer.
    pub fn schedule(&self) -> &[LayerPrecision] {
        &self.schedule
    }

    /// Layer `li`'s activation/accumulator format pair.
    #[inline]
    pub fn precision(&self, li: usize) -> LayerPrecision {
        self.schedule[li]
    }

    /// The precomputed crossbar chain converting layer `li`'s
    /// accumulators into layer `li+1`'s activations (empty = bypass).
    #[inline]
    pub fn boundary_chain(&self, li: usize) -> &[(SimdFormat, SimdFormat)] {
        &self.chains[li]
    }

    /// Rows per full packed batch at this variant's schedule.
    pub fn batch_quantum(&self) -> usize {
        self.batch_quantum
    }

    /// Activation width (bits) of the first layer — what rows handed to
    /// the engine under this variant must be quantized to.
    pub fn in_bits(&self) -> u32 {
        self.schedule[0].in_bits
    }

    /// Accumulator width (bits) of the last layer.
    pub fn acc_bits(&self) -> u32 {
        self.schedule[self.schedule.len() - 1].acc_bits
    }

    pub fn in_fmt(&self) -> SimdFormat {
        self.schedule[0].in_fmt()
    }

    pub fn acc_fmt(&self) -> SimdFormat {
        self.schedule[self.schedule.len() - 1].acc_fmt()
    }

    /// Arithmetic right shift mapping reference-precision request
    /// values into this variant's first-layer format. The serving loop
    /// applies it per value before the engine packs the batch; the
    /// per-variant scalar oracle is `forward(row >> in_shift)`.
    #[inline]
    pub fn in_shift(&self) -> u32 {
        self.in_shift
    }

    /// Requantize one reference-precision row into this variant's
    /// first-layer format (floor / arithmetic-shift rounding — the
    /// exact transform the PE workers apply).
    pub fn quantize_row(&self, row: &[i64]) -> Vec<i64> {
        row.iter().map(|&v| v >> self.in_shift).collect()
    }

    /// The CSD truncation policy this variant executes under
    /// ([`Truncation::NONE`] for exact variants).
    #[inline]
    pub fn truncation(&self) -> Truncation {
        self.truncation
    }

    /// The [`PlanArena`] bank this variant's plans live in (0 = exact).
    #[inline]
    pub fn plan_bank(&self) -> usize {
        self.plan_bank
    }

    /// Whether this variant executes approximate (truncated) plans.
    pub fn is_approximate(&self) -> bool {
        !self.truncation.is_none()
    }
}

/// An immutable compiled model — since DESIGN.md §13 a **variant set**:
/// quantized layers (dense or conv, each lowered to its matmul view)
/// plus every per-weight [`MulPlan`], shared across all PE workers via
/// [`Arc`], carrying one or more precision [`Variant`]s over the same
/// weights. A conv layer contributes exactly one CSD plan per kernel
/// weight — the plan is shared across every output pixel of every image
/// (DESIGN.md §12) and across every variant (§13).
#[derive(Debug)]
pub struct CompiledModel {
    layers: Vec<LayerOp>,
    /// `plans[layer][k][n]`, precompiled for every weight of the
    /// layer's matmul view — the inspectable compilation artifact
    /// (oracles, tests, billing cross-checks). One copy per variant
    /// *set*: plans depend on weight values only, never on a schedule.
    plans: Vec<Vec<Vec<MulPlan>>>,
    /// The same plans flattened into one contiguous SoA micro-op buffer
    /// — the execution artifact the engine's hot loop runs
    /// (DESIGN.md §11). Shared by every variant.
    arena: PlanArena,
    /// The precision variants, reference (hi-fidelity) first.
    variants: Vec<Variant>,
    /// Total Stage-1 cycles of one forward pass per packed word column
    /// (sum of plan cycles over all weights) — scheduling metadata for
    /// load estimates.
    cycles_per_word: u64,
    /// Count of zero weights (zero-skipped at execution).
    zero_weights: u64,
    /// Lazily computed lane-safety verdict per variant (same order as
    /// `variants`). Populated on first [`CompiledModel::lane_safety`]
    /// call; `compile_variants_verified` forces it at compile time.
    lane_safety: OnceLock<Vec<Result<LaneSafetyReport, AnalysisError>>>,
    /// Lazily computed static cost certificate per variant (same order
    /// as `variants`, DESIGN.md §15). Populated on first
    /// [`CompiledModel::cost_certificate`] call.
    costs: OnceLock<Vec<CostCertificate>>,
}

/// A multi-variant [`CompiledModel`] behind its serving `Arc` — the
/// "variant set" the coordinator switches across at run time.
pub type VariantSet = Arc<CompiledModel>;

fn lcm(a: usize, b: usize) -> usize {
    let gcd = |mut x: usize, mut y: usize| {
        while y != 0 {
            (x, y) = (y, x % y);
        }
        x
    };
    a / gcd(a, b) * b
}

impl CompiledModel {
    /// Compile a uniform-precision model (every layer at
    /// `in_bits → acc_bits`, the seed engine's only mode). Call once per
    /// model; clone the returned [`Arc`], never the model.
    pub fn compile(
        layers: Vec<QuantLayer>,
        in_bits: u32,
        acc_bits: u32,
    ) -> anyhow::Result<Arc<CompiledModel>> {
        let schedule = uniform_schedule(in_bits, acc_bits, layers.len());
        CompiledModel::compile_scheduled(layers, schedule)
    }

    /// Compile a mixed-precision dense model: layer `li` consumes
    /// `schedule[li].in_bits` activations and produces
    /// `schedule[li].acc_bits` accumulators. Shorthand for
    /// [`compile_stack`] with every layer dense.
    ///
    /// [`compile_stack`]: CompiledModel::compile_stack
    pub fn compile_scheduled(
        layers: Vec<QuantLayer>,
        schedule: Vec<LayerPrecision>,
    ) -> anyhow::Result<Arc<CompiledModel>> {
        CompiledModel::compile_stack(layers.into_iter().map(LayerOp::Dense).collect(), schedule)
    }

    /// Compile an interleaved conv + dense stack (DESIGN.md §12) under
    /// a single precision schedule — a one-variant variant set.
    pub fn compile_stack(
        layers: Vec<LayerOp>,
        schedule: Vec<LayerPrecision>,
    ) -> anyhow::Result<Arc<CompiledModel>> {
        CompiledModel::compile_variants(layers, vec![VariantSpec::new("default", schedule)])
    }

    /// Compile one `LayerOp` stack under `specs.len()` precision
    /// variants into one shared structure (DESIGN.md §13): the CSD plan
    /// tables and the flattened micro-op arena are built **once** —
    /// plans are a property of the weight values, so recompiling them
    /// per variant would be pure waste (`PLAN_COMPILATIONS` counts one
    /// compilation here regardless of `specs.len()`; the tests pin it).
    /// Per variant, the schedule is validated against the stack and the
    /// boundary conversion chains and batch quantum are precomputed.
    ///
    /// `specs[0]` is the **reference** variant: requests are validated
    /// and quantized at its first-layer activation width, so every
    /// other variant's first layer must be at most that wide (its
    /// [`Variant::in_shift`] bridges the difference at dispatch).
    pub fn compile_variants(
        layers: Vec<LayerOp>,
        specs: Vec<VariantSpec>,
    ) -> anyhow::Result<Arc<CompiledModel>> {
        anyhow::ensure!(!layers.is_empty(), "model needs at least one layer");
        anyhow::ensure!(!specs.is_empty(), "model needs at least one precision variant");
        // Schedule-independent structural validation, once per stack.
        for (li, layer) in layers.iter().enumerate() {
            let w = layer.weights();
            anyhow::ensure!(
                crate::bits::format::FORMATS.contains(&w.bits),
                "layer {li}: weight width {} is not a Soft SIMD format",
                w.bits
            );
            anyhow::ensure!(
                w.k > 0 && w.n > 0,
                "layer {li}: degenerate shape {}x{}",
                w.k,
                w.n
            );
            if let LayerOp::Conv(c) = layer {
                c.shape
                    .validate()
                    .map_err(|e| anyhow::anyhow!("layer {li}: {e}"))?;
                anyhow::ensure!(
                    w.k == c.shape.patch_len() && w.n == c.shape.cout,
                    "layer {li}: conv weight matrix {}x{} does not match shape {}",
                    w.k,
                    w.n,
                    c.shape
                );
            }
            if li > 0 {
                anyhow::ensure!(
                    layers[li - 1].out_len() == layer.in_len(),
                    "layer {li}: input width {} != previous layer's output width {}",
                    layer.in_len(),
                    layers[li - 1].out_len()
                );
            }
        }
        // Per-variant schedule validation and precomputation.
        let ref_in_bits = specs[0].schedule.first().map(|p| p.in_bits).unwrap_or(0);
        anyhow::ensure!(
            specs[0].truncation.is_none(),
            "reference variant ({}) must execute the exact plans, not truncation {}",
            specs[0].name,
            specs[0].truncation
        );
        // Deduplicate truncation policies into plan banks: bank 0 is
        // always the exact plans; each distinct truncated policy gets
        // one bank shared by every variant that names it.
        let mut bank_truncs: Vec<Truncation> = vec![Truncation::NONE];
        let mut variants = Vec::with_capacity(specs.len());
        for (vi, spec) in specs.into_iter().enumerate() {
            let VariantSpec { name, schedule, truncation } = spec;
            anyhow::ensure!(
                layers.len() == schedule.len(),
                "variant {vi} ({name}): {} layers but {} precision entries",
                layers.len(),
                schedule.len()
            );
            let mut batch_quantum = 1usize;
            for (li, p) in schedule.iter().enumerate() {
                p.validate()
                    .map_err(|e| anyhow::anyhow!("variant {vi} ({name}), layer {li}: {e}"))?;
                batch_quantum = lcm(batch_quantum, p.in_fmt().lanes() as usize);
                batch_quantum = lcm(batch_quantum, p.acc_fmt().lanes() as usize);
            }
            anyhow::ensure!(
                schedule[0].in_bits <= ref_in_bits,
                "variant {vi} ({name}): first-layer width {} exceeds the reference \
                 variant's {} — requests arrive at the reference precision and can \
                 only be narrowed at dispatch",
                schedule[0].in_bits,
                ref_in_bits
            );
            let chains = schedule
                .windows(2)
                .map(|w| conversion_chain(w[0].acc_fmt(), w[1].in_fmt()))
                .collect();
            let plan_bank = match bank_truncs.iter().position(|&t| t == truncation) {
                Some(b) => b,
                None => {
                    bank_truncs.push(truncation);
                    bank_truncs.len() - 1
                }
            };
            variants.push(Variant {
                name,
                in_shift: ref_in_bits - schedule[0].in_bits,
                schedule,
                chains,
                batch_quantum,
                truncation,
                plan_bank,
            });
        }
        // One plan compilation per variant *set* — the dedup invariant.
        // Truncated banks are derived from the same per-weight digit
        // streams in the same pass, so they ride the single compilation.
        PLAN_COMPILATIONS.fetch_add(1, Ordering::SeqCst);
        let plans: Vec<Vec<Vec<MulPlan>>> =
            layers.iter().map(|layer| layer.weights().plans()).collect();
        let mut cycles_per_word = 0u64;
        let mut zero_weights = 0u64;
        for layer_plans in &plans {
            for row in layer_plans {
                for plan in row {
                    if plan.ops.is_empty() {
                        zero_weights += 1;
                    } else {
                        cycles_per_word += plan.cycles() as u64;
                    }
                }
            }
        }
        // Approximate banks: recompile each layer's weights under the
        // bank's truncation policy (strictly-fewer-cycle plans; same
        // header layout, so the engine switches banks with one offset).
        let trunc_banks: Vec<Vec<Vec<Vec<MulPlan>>>> = bank_truncs[1..]
            .iter()
            .map(|&trunc| {
                layers
                    .iter()
                    .map(|layer| {
                        let w = layer.weights();
                        w.w_raw
                            .iter()
                            .map(|row| {
                                row.iter()
                                    .map(|&m| schedule_truncated(m, w.bits, trunc))
                                    .collect()
                            })
                            .collect()
                    })
                    .collect()
            })
            .collect();
        let mut banks: Vec<&[Vec<Vec<MulPlan>>]> = vec![&plans];
        banks.extend(trunc_banks.iter().map(|b| b.as_slice()));
        let arena = PlanArena::build_banks(&banks);
        Ok(Arc::new(CompiledModel {
            layers,
            plans,
            arena,
            variants,
            cycles_per_word,
            zero_weights,
            lane_safety: OnceLock::new(),
            costs: OnceLock::new(),
        }))
    }

    /// [`compile_variants`] plus the static lane-safety verifier
    /// (DESIGN.md §14) over **every** variant: a schedule whose
    /// worst-case accumulator range can wrap a lane is a typed
    /// [`CompileError::Unsafe`] carrying the per-layer analysis verdict
    /// and, when the overflow is reachable from the model input, a
    /// synthesized concrete counterexample row.
    ///
    /// [`compile_variants`]: CompiledModel::compile_variants
    pub fn compile_variants_verified(
        layers: Vec<LayerOp>,
        specs: Vec<VariantSpec>,
    ) -> Result<Arc<CompiledModel>, CompileError> {
        let model =
            CompiledModel::compile_variants(layers, specs).map_err(CompileError::Invalid)?;
        for v in 0..model.n_variants() {
            if let Err(e) = model.lane_safety(v) {
                return Err(CompileError::Unsafe {
                    variant: model.variant(v).name().to_string(),
                    error: e.clone(),
                });
            }
        }
        Ok(model)
    }

    pub fn layers(&self) -> &[LayerOp] {
        &self.layers
    }

    /// The precompiled plan for layer `li`, weight `(k, n)`.
    #[inline]
    pub fn plan(&self, li: usize, k: usize, n: usize) -> &MulPlan {
        &self.plans[li][k][n]
    }

    /// The flattened micro-op arena the serving engine executes
    /// (one byte per Stage-1 cycle; column-adjacent plan headers).
    #[inline]
    pub fn flat(&self) -> &PlanArena {
        &self.arena
    }

    /// Every precision variant, reference first.
    pub fn variants(&self) -> &[Variant] {
        &self.variants
    }

    pub fn n_variants(&self) -> usize {
        self.variants.len()
    }

    /// Variant `v`'s compiled schedule metadata.
    #[inline]
    pub fn variant(&self, v: usize) -> &Variant {
        &self.variants[v]
    }

    /// The full precision schedule of the **reference** variant, one
    /// entry per layer.
    pub fn schedule(&self) -> &[LayerPrecision] {
        self.variants[0].schedule()
    }

    /// The reference variant's format pair for layer `li`.
    #[inline]
    pub fn precision(&self, li: usize) -> LayerPrecision {
        self.variants[0].precision(li)
    }

    /// The reference variant's boundary chain after layer `li`.
    #[inline]
    pub fn boundary_chain(&self, li: usize) -> &[(SimdFormat, SimdFormat)] {
        self.variants[0].boundary_chain(li)
    }

    /// Activation width (bits) of the reference variant's first layer —
    /// what requests arrive quantized to, whichever variant executes
    /// them.
    pub fn in_bits(&self) -> u32 {
        self.variants[0].in_bits()
    }

    /// Accumulator width (bits) of the reference variant's last layer.
    pub fn acc_bits(&self) -> u32 {
        self.variants[0].acc_bits()
    }

    pub fn in_fmt(&self) -> SimdFormat {
        self.variants[0].in_fmt()
    }

    pub fn acc_fmt(&self) -> SimdFormat {
        self.variants[0].acc_fmt()
    }

    /// Flattened input length of the first layer (row length of a
    /// request; for a conv-first model this is `cin·h·w`).
    pub fn input_width(&self) -> usize {
        self.layers[0].in_len()
    }

    /// Flattened output length of the last layer (row length of a
    /// response; for a conv-final model this is `cout·out_h·out_w`).
    pub fn output_width(&self) -> usize {
        self.layers[self.layers.len() - 1].out_len()
    }

    /// The reference variant's batch quantum: batches padded to a
    /// multiple of this keep every packed word full at every layer's
    /// format (6 for the uniform 8→16 schedule).
    pub fn batch_quantum(&self) -> usize {
        self.variants[0].batch_quantum()
    }

    /// Stage-1 cycles one packed word column costs across the whole
    /// forward pass (load-estimate metadata).
    pub fn cycles_per_word(&self) -> u64 {
        self.cycles_per_word
    }

    pub fn zero_weights(&self) -> u64 {
        self.zero_weights
    }

    /// Variant `v`'s static lane-safety verdict: the per-layer margin
    /// report when the schedule is proven safe, or the typed analysis
    /// error (with a synthesized counterexample where reachable) when it
    /// is not. Computed once per variant set on first call and cached;
    /// the plain `compile*` paths never force it, so existing unsafe
    /// test fixtures still compile — opt into enforcement with
    /// [`CompiledModel::compile_variants_verified`].
    pub fn lane_safety(&self, v: usize) -> Result<&LaneSafetyReport, &AnalysisError> {
        let all = self.lane_safety.get_or_init(|| {
            self.variants
                .iter()
                .map(|var| {
                    crate::analysis::verify_with_arena_bank(
                        &self.layers,
                        &self.arena,
                        var.plan_bank(),
                        var.schedule(),
                    )
                })
                .collect()
        });
        all[v].as_ref()
    }

    /// Variant `v`'s static cost certificate (DESIGN.md §15): the
    /// closed-form-in-`m` billing model read off the flat plan headers
    /// and the variant's schedule. Computed once per variant set on
    /// first call and cached — cheap enough (one header scan per
    /// variant) that the serving path consults it per batch under
    /// `--features billaudit`.
    pub fn cost_certificate(&self, v: usize) -> &CostCertificate {
        let all = self.costs.get_or_init(|| {
            self.variants
                .iter()
                .map(|var| CostCertificate::certify(&self.layers, &self.arena, var))
                .collect()
        });
        &all[v]
    }
}

/// Error type of [`CompiledModel::compile_variants_verified`]: either
/// the structural validation failure the plain compile paths already
/// produce, or a schedule the lane-safety verifier rejected.
#[derive(Debug)]
pub enum CompileError {
    /// Structural validation failed (empty stack, non-chaining dims,
    /// malformed schedule, ...) — the `compile_variants` error.
    Invalid(anyhow::Error),
    /// A variant's schedule can wrap a lane: the verifier's typed
    /// verdict, naming the offending variant.
    Unsafe {
        /// Display name of the rejected variant.
        variant: String,
        /// The analysis verdict (layer, bound, counterexample).
        error: AnalysisError,
    },
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompileError::Invalid(e) => write!(f, "invalid model: {e}"),
            CompileError::Unsafe { variant, error } => {
                write!(f, "variant '{variant}' is lane-unsafe: {error}")
            }
        }
    }
}

impl std::error::Error for CompileError {}

#[cfg(test)]
mod tests {
    use super::*;

    fn layers() -> Vec<QuantLayer> {
        vec![
            QuantLayer::new(vec![vec![64, 0], vec![-32, 127]], 8),
            QuantLayer::new(vec![vec![5], vec![-9]], 8),
        ]
    }

    #[test]
    fn compile_counts_and_metadata() {
        let before = PLAN_COMPILATIONS.load(Ordering::SeqCst);
        let m = CompiledModel::compile(layers(), 8, 16).unwrap();
        assert_eq!(PLAN_COMPILATIONS.load(Ordering::SeqCst), before + 1);
        assert_eq!(m.input_width(), 2);
        assert_eq!(m.batch_quantum(), 6); // lcm(6 @8b, 3 @16b)
        assert_eq!(m.zero_weights(), 1);
        assert!(m.cycles_per_word() > 0);
        assert_eq!(
            m.plan(0, 0, 0).ops.len(),
            m.layers()[0].weights().plan(0, 0).ops.len()
        );
        assert_eq!(m.boundary_chain(0), &[(SimdFormat::new(16), SimdFormat::new(8))]);
        assert_eq!(m.n_variants(), 1);
        assert_eq!(m.variant(0).in_shift(), 0);
    }

    #[test]
    fn flat_arena_mirrors_the_plan_tables() {
        let m = CompiledModel::compile(layers(), 8, 16).unwrap();
        let arena = m.flat();
        for (li, layer) in m.layers().iter().enumerate() {
            let layer = layer.weights();
            for k in 0..layer.k {
                for n in 0..layer.n {
                    let plan = m.plan(li, k, n);
                    let h = arena.header(li, k, n);
                    assert_eq!(h.cycles as usize, plan.cycles(), "({li},{k},{n})");
                    assert_eq!(h.adds as usize, plan.adds());
                    let decoded: Vec<_> = arena
                        .ops(h)
                        .iter()
                        .map(|&b| crate::csd::flat::decode_op(b))
                        .collect();
                    assert_eq!(decoded, plan.ops);
                }
            }
        }
        // Column adjacency: layer 0 column 0 holds plans (k=0,n=0),(k=1,n=0).
        let col = arena.column(0, 0);
        assert_eq!(col.len(), 2);
        assert_eq!(col[0], arena.header(0, 0, 0));
        assert_eq!(col[1], arena.header(0, 1, 0));
    }

    #[test]
    fn rejects_empty_model_as_error_not_panic() {
        let err = CompiledModel::compile(vec![], 8, 16).expect_err("empty stack");
        assert!(err.to_string().contains("at least one layer"), "{err}");
    }

    #[test]
    fn rejects_malformed_schedules_and_shapes() {
        // Inverted precision pair (accumulator narrower than input).
        let err = CompiledModel::compile(layers(), 16, 8).expect_err("inverted pair");
        assert!(err.to_string().contains("narrower"), "{err}");
        // Schedule length mismatch.
        let err = CompiledModel::compile_scheduled(layers(), uniform_schedule(8, 16, 3))
            .expect_err("length mismatch");
        assert!(err.to_string().contains("precision entries"), "{err}");
        // Non-chaining layer dims.
        let bad = vec![
            QuantLayer::new(vec![vec![64, 0], vec![-32, 127]], 8), // 2 -> 2
            QuantLayer::new(vec![vec![5]], 8),                     // 1 -> 1
        ];
        let err = CompiledModel::compile(bad, 8, 16).expect_err("non-chaining dims");
        assert!(err.to_string().contains("output width"), "{err}");
        // No variants at all.
        let ops: Vec<LayerOp> = layers().into_iter().map(LayerOp::Dense).collect();
        let err = CompiledModel::compile_variants(ops, vec![]).expect_err("no variants");
        assert!(err.to_string().contains("at least one precision variant"), "{err}");
    }

    #[test]
    fn compile_stack_chains_conv_and_dense_by_flattened_lengths() {
        use crate::nn::conv::{ConvLayer, ConvShape};
        // conv 1x4x4 → 2ch 3x3 s1 p1 (out 2x4x4 = 32) then dense 32→3.
        let shape =
            ConvShape { cin: 1, h: 4, w: 4, cout: 2, kh: 3, kw: 3, stride: 1, pad: 1 };
        let cw = QuantLayer::new(vec![vec![5, -9]; 9], 8);
        let conv = ConvLayer::new(cw, shape).unwrap();
        let dense = QuantLayer::new(vec![vec![1, 2, 3]; 32], 8);
        let ops = vec![LayerOp::Conv(conv.clone()), LayerOp::Dense(dense)];
        let m = CompiledModel::compile_stack(ops, uniform_schedule(8, 16, 2)).unwrap();
        assert_eq!(m.input_width(), 16);
        assert_eq!(m.output_width(), 3);
        assert_eq!(m.layers()[0].patch_rows(), 16);
        assert_eq!(m.layers()[1].patch_rows(), 1);
        // The arena holds one plan per kernel weight (9·2) plus the
        // dense plans (32·3) — shared across output pixels, not one per
        // pixel.
        assert_eq!(m.flat().total_plans(), 9 * 2 + 32 * 3);
        // Non-chaining flattened lengths are a compile error.
        let bad_dense = QuantLayer::new(vec![vec![1]; 31], 8);
        let err = CompiledModel::compile_stack(
            vec![LayerOp::Conv(conv), LayerOp::Dense(bad_dense)],
            uniform_schedule(8, 16, 2),
        )
        .expect_err("31 != 32");
        assert!(err.to_string().contains("output width"), "{err}");
    }

    #[test]
    fn mixed_schedule_metadata() {
        let sched = vec![LayerPrecision::new(4, 8), LayerPrecision::new(8, 16)];
        let m = CompiledModel::compile_scheduled(layers(), sched).unwrap();
        // lanes: 12 (4b in) / 6 (8b acc) / 6 (8b in) / 3 (16b acc).
        assert_eq!(m.batch_quantum(), 12);
        assert_eq!(m.in_bits(), 4);
        assert_eq!(m.acc_bits(), 16);
        // Boundary 8→8 is a bypass: empty chain.
        assert!(m.boundary_chain(0).is_empty());
        // A 2-hop boundary is precomputed as such.
        let sched = vec![LayerPrecision::new(8, 16), LayerPrecision::new(4, 8)];
        let m = CompiledModel::compile_scheduled(layers(), sched).unwrap();
        assert_eq!(m.boundary_chain(0).len(), 2, "16→4 chains via 8");
    }

    #[test]
    fn variant_set_shares_one_plan_table_and_computes_per_variant_metadata() {
        // (The "one plan compilation per variant *set*" invariant is
        // pinned in tests/plan_compile_count.rs — its own binary, so
        // the process-global counter isn't raced by parallel tests.)
        let ops: Vec<LayerOp> = layers().into_iter().map(LayerOp::Dense).collect();
        let m = CompiledModel::compile_variants(ops, VariantSpec::standard_trio(2)).unwrap();
        assert_eq!(m.n_variants(), 3);
        assert_eq!(m.variant(0).name(), "hifi-8");
        assert_eq!(m.variant(0).batch_quantum(), 6);
        // balanced: layer 0 at 4→8 (12/6 lanes), layer 1 at 8→16 (6/3).
        assert_eq!(m.variant(1).batch_quantum(), 12);
        assert_eq!(m.variant(2).batch_quantum(), 12);
        // Request precision follows the reference variant; narrower
        // variants bridge it with a right shift.
        assert_eq!(m.in_bits(), 8);
        assert_eq!(m.variant(1).in_shift(), 4);
        assert_eq!(m.variant(2).in_shift(), 4);
        assert_eq!(m.variant(1).quantize_row(&[127, -128, 15]), vec![7, -8, 0]);
        // Reference-variant delegations keep pointing at variant 0.
        assert_eq!(m.schedule(), m.variant(0).schedule());
        assert_eq!(m.batch_quantum(), m.variant(0).batch_quantum());
    }

    #[test]
    fn lane_safety_is_cached_per_variant_and_verified_compile_enforces_it() {
        let ops: Vec<LayerOp> = layers().into_iter().map(LayerOp::Dense).collect();
        let m = CompiledModel::compile_variants(ops.clone(), VariantSpec::standard_trio(2))
            .unwrap();
        for v in 0..m.n_variants() {
            let report = m.lane_safety(v).unwrap_or_else(|e| {
                panic!("variant {} should verify: {e}", m.variant(v).name())
            });
            assert_eq!(report.layers.len(), 2);
        }
        // Cached: the second call returns the same report object.
        assert!(std::ptr::eq(m.lane_safety(0).unwrap(), m.lane_safety(0).unwrap()));
        // The verified compile path accepts the same set…
        CompiledModel::compile_variants_verified(ops, VariantSpec::standard_trio(2))
            .expect("trio is lane-safe on this stack");
        // …and rejects an under-provisioned one: 32 taps of +32/128 into
        // an 8-bit accumulator needs 11 bits of headroom.
        let wide = vec![LayerOp::Dense(QuantLayer::new(vec![vec![32; 4]; 32], 8))];
        let specs = vec![VariantSpec::new("hot", uniform_schedule(8, 8, 1))];
        let err = CompiledModel::compile_variants_verified(wide.clone(), specs.clone())
            .expect_err("wide fan-in into an equal-width accumulator");
        match &err {
            CompileError::Unsafe { variant, error } => {
                assert_eq!(variant, "hot");
                assert_eq!(error.layer(), 0);
            }
            other => panic!("expected Unsafe, got {other}"),
        }
        // The plain compile path still accepts it (opt-in enforcement)
        // but reports the verdict on demand.
        let m = CompiledModel::compile_variants(wide, specs).unwrap();
        assert!(m.lane_safety(0).is_err());
    }

    #[test]
    fn standard_ladder_compiles_approx_variants_into_dedup_banks() {
        let ops: Vec<LayerOp> = layers().into_iter().map(LayerOp::Dense).collect();
        let m = CompiledModel::compile_variants(ops, VariantSpec::standard_ladder(2)).unwrap();
        assert_eq!(m.n_variants(), 5);
        // The trio runs exact plans out of bank 0; the two approximate
        // policies each get their own bank.
        for v in 0..3 {
            assert_eq!(m.variant(v).plan_bank(), 0);
            assert!(!m.variant(v).is_approximate());
        }
        assert_eq!(m.variant(3).name(), "approx-t2");
        assert_eq!(m.variant(3).plan_bank(), 1);
        assert_eq!(m.variant(3).truncation(), Truncation::drop_least(2));
        assert!(m.variant(3).is_approximate());
        assert_eq!(m.variant(4).name(), "approx-d1");
        assert_eq!(m.variant(4).plan_bank(), 2);
        assert_eq!(m.flat().n_banks(), 3);
        // Approx variants ride the turbo schedule, so scheduling
        // metadata matches the turbo variant exactly.
        assert_eq!(m.variant(3).schedule(), m.variant(2).schedule());
        assert_eq!(m.variant(3).batch_quantum(), m.variant(2).batch_quantum());
        assert_eq!(m.variant(3).in_shift(), m.variant(2).in_shift());
        // Truncated banks share the header layout but never cost more
        // cycles than the exact plan of the same weight.
        let arena = m.flat();
        for (li, layer) in m.layers().iter().enumerate() {
            let w = layer.weights();
            for k in 0..w.k {
                for n in 0..w.n {
                    let exact = arena.header_bank(0, li, k, n);
                    for bank in 1..arena.n_banks() {
                        let t = arena.header_bank(bank, li, k, n);
                        assert!(t.cycles <= exact.cycles, "({li},{k},{n}) bank {bank}");
                        assert!(t.adds <= exact.adds);
                        if w.w_raw[k][n] == 0 {
                            assert!(t.is_zero(), "zero weight must stay zero in every bank");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn two_variants_naming_the_same_truncation_share_a_bank() {
        let ops: Vec<LayerOp> = layers().into_iter().map(LayerOp::Dense).collect();
        let sched = uniform_schedule(8, 16, 2);
        let specs = vec![
            VariantSpec::new("exact", sched.clone()),
            VariantSpec::new("a", sched.clone()).with_truncation(Truncation::drop_least(1)),
            VariantSpec::new("b", sched).with_truncation(Truncation::drop_least(1)),
        ];
        let m = CompiledModel::compile_variants(ops, specs).unwrap();
        assert_eq!(m.variant(1).plan_bank(), m.variant(2).plan_bank());
        assert_eq!(m.flat().n_banks(), 2);
    }

    #[test]
    fn truncated_reference_variant_is_a_compile_error() {
        let ops: Vec<LayerOp> = layers().into_iter().map(LayerOp::Dense).collect();
        let specs = vec![VariantSpec::new("ref", uniform_schedule(8, 16, 2))
            .with_truncation(Truncation::drop_least(1))];
        let err = CompiledModel::compile_variants(ops, specs).expect_err("approx reference");
        assert!(err.to_string().contains("exact plans"), "{err}");
    }

    #[test]
    fn standard_ladder_is_lane_safe_on_the_synth_stack() {
        // Truncation can *increase* a kept value's magnitude relative to
        // the weight (dropping a negative correction digit), so approx
        // banks get their own lane-safety verdicts — pin that the stock
        // ladder still verifies on a plain stack.
        let ops: Vec<LayerOp> = layers().into_iter().map(LayerOp::Dense).collect();
        let m = CompiledModel::compile_variants_verified(ops, VariantSpec::standard_ladder(2))
            .expect("ladder is lane-safe on this stack");
        for v in 0..m.n_variants() {
            assert!(m.lane_safety(v).is_ok(), "{}", m.variant(v).name());
        }
    }

    #[test]
    fn variant_wider_than_reference_is_a_compile_error() {
        let ops: Vec<LayerOp> = layers().into_iter().map(LayerOp::Dense).collect();
        let specs = vec![
            VariantSpec::new(
                "narrow-ref",
                vec![LayerPrecision::new(4, 8), LayerPrecision::new(8, 16)],
            ),
            VariantSpec::new(
                "too-wide",
                vec![LayerPrecision::new(8, 16), LayerPrecision::new(8, 16)],
            ),
        ];
        let err = CompiledModel::compile_variants(ops, specs).expect_err("wider variant");
        assert!(err.to_string().contains("exceeds the reference"), "{err}");
    }
}
