//! Per-cycle activity traces.
//!
//! The functional pipeline records, for every datapath cycle, the operand
//! values each stage saw. The energy model (`energy::model`) replays
//! these traces through the gate-level netlists (`rtl`) to obtain real
//! switching activity instead of fixed activity factors.

use crate::bits::format::SimdFormat;


/// One Stage-1 cycle worth of operand activity.
#[derive(Debug, Clone, Copy)]
pub struct S1Event {
    pub fmt: SimdFormat,
    /// Accumulator value entering the cycle.
    pub acc_in: u64,
    /// Multiplicand operand register.
    pub x: u64,
    /// Shift distance (1..=3).
    pub k: u32,
    /// +1 add, −1 subtract, 0 shift-only.
    pub sign: i8,
    /// Accumulator value leaving the cycle.
    pub acc_out: u64,
}

/// One Stage-2 cycle worth of operand activity.
#[derive(Debug, Clone, Copy)]
pub struct S2Event {
    pub from: SimdFormat,
    pub to: SimdFormat,
    /// 96-bit R2:R3 window contents.
    pub window: u128,
    pub in_skip: u32,
    pub out: u64,
    /// True for bypass cycles (crossbar idle, window forwarded).
    pub bypass: bool,
}

/// A cycle event: at most one op per stage (the stages are pipelined, so
/// one `CycleEvent` may carry both).
#[derive(Debug, Clone, Copy)]
pub enum CycleEvent {
    S1(S1Event),
    S2(S2Event),
}

/// An execution trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub events: Vec<CycleEvent>,
    /// Total elapsed cycles under two-stage overlap (≤ events.len()).
    pub elapsed_cycles: u64,
}

impl Trace {
    pub fn s1_events(&self) -> impl Iterator<Item = &S1Event> {
        self.events.iter().filter_map(|e| match e {
            CycleEvent::S1(ev) => Some(ev),
            _ => None,
        })
    }

    pub fn s2_events(&self) -> impl Iterator<Item = &S2Event> {
        self.events.iter().filter_map(|e| match e {
            CycleEvent::S2(ev) => Some(ev),
            _ => None,
        })
    }

    pub fn s1_cycles(&self) -> u64 {
        self.s1_events().count() as u64
    }

    pub fn s2_cycles(&self) -> u64 {
        self.s2_events().count() as u64
    }
}
