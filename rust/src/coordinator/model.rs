//! The immutable, shareable serving model: weights + precompiled CSD
//! multiply plans + packing metadata, built **once** and handed to every
//! PE worker behind an `Arc` (DESIGN.md §8).
//!
//! This is the schedule-amortization idea of the paper's control path
//! (the CSD plan is a property of the *multiplier value*, not of the
//! operand stream): compiling the per-weight shift-add programs is the
//! expensive, quantization-dependent step, so it must happen off the
//! per-request critical path and exactly once per deployed model — not
//! once per worker, as the original demo loop did.
//!
//! Since the engine went format-polymorphic (DESIGN.md §10), the
//! compiled model also carries the *precision schedule* — one
//! [`LayerPrecision`] per layer — together with the precomputed Stage-2
//! conversion chain for every layer boundary, and the batch quantum that
//! keeps every packed word full at every per-layer format. All of it is
//! validated here, at compile, so a malformed model (empty stack,
//! non-chaining dims, unsupported or inverted format pair) is an error
//! for its builder — never a panic inside a PE worker.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::anyhow;
use crate::bits::format::SimdFormat;
use crate::csd::flat::PlanArena;
use crate::csd::schedule::MulPlan;
use crate::nn::conv::LayerOp;
use crate::nn::weights::{uniform_schedule, LayerPrecision, QuantLayer};
use crate::pipeline::stage2::conversion_chain;

/// Process-wide count of [`CompiledModel::compile`] runs. Exists so
/// tests can assert that plan compilation happens exactly once per
/// model no matter how many PE workers serve it.
pub static PLAN_COMPILATIONS: AtomicU64 = AtomicU64::new(0);

/// An immutable compiled model: quantized layers (dense or conv, each
/// lowered to its matmul view), per-layer serving precision, plus every
/// per-weight [`MulPlan`] and per-boundary Stage-2 conversion chain,
/// shared across all PE workers via [`Arc`]. A conv layer contributes
/// exactly one CSD plan per kernel weight — the plan is shared across
/// every output pixel of every image (DESIGN.md §12).
#[derive(Debug)]
pub struct CompiledModel {
    layers: Vec<LayerOp>,
    /// `plans[layer][k][n]`, precompiled for every weight of the
    /// layer's matmul view — the inspectable compilation artifact
    /// (oracles, tests, billing cross-checks).
    plans: Vec<Vec<Vec<MulPlan>>>,
    /// The same plans flattened into one contiguous SoA micro-op buffer
    /// — the execution artifact the engine's hot loop runs
    /// (DESIGN.md §11).
    arena: PlanArena,
    /// One activation/accumulator format pair per layer.
    schedule: Vec<LayerPrecision>,
    /// `chains[li]`: the crossbar hop chain converting layer `li`'s
    /// accumulator stream into layer `li+1`'s activation format
    /// (`layers.len() - 1` entries; empty chain = Stage-2 bypass).
    chains: Vec<Vec<(SimdFormat, SimdFormat)>>,
    /// Rows per full packed batch: the LCM of every layer's activation
    /// and accumulator lane counts, so no layer ever sees a partial
    /// final word (6 for the uniform 8→16 schedule, up to 24 mixed).
    batch_quantum: usize,
    /// Total Stage-1 cycles of one forward pass per packed word column
    /// (sum of plan cycles over all weights) — scheduling metadata for
    /// load estimates.
    cycles_per_word: u64,
    /// Count of zero weights (zero-skipped at execution).
    zero_weights: u64,
}

fn lcm(a: usize, b: usize) -> usize {
    let gcd = |mut x: usize, mut y: usize| {
        while y != 0 {
            (x, y) = (y, x % y);
        }
        x
    };
    a / gcd(a, b) * b
}

impl CompiledModel {
    /// Compile a uniform-precision model (every layer at
    /// `in_bits → acc_bits`, the seed engine's only mode). Call once per
    /// model; clone the returned [`Arc`], never the model.
    pub fn compile(
        layers: Vec<QuantLayer>,
        in_bits: u32,
        acc_bits: u32,
    ) -> anyhow::Result<Arc<CompiledModel>> {
        let schedule = uniform_schedule(in_bits, acc_bits, layers.len());
        CompiledModel::compile_scheduled(layers, schedule)
    }

    /// Compile a mixed-precision dense model: layer `li` consumes
    /// `schedule[li].in_bits` activations and produces
    /// `schedule[li].acc_bits` accumulators. Shorthand for
    /// [`compile_stack`] with every layer dense.
    ///
    /// [`compile_stack`]: CompiledModel::compile_stack
    pub fn compile_scheduled(
        layers: Vec<QuantLayer>,
        schedule: Vec<LayerPrecision>,
    ) -> anyhow::Result<Arc<CompiledModel>> {
        CompiledModel::compile_stack(layers.into_iter().map(LayerOp::Dense).collect(), schedule)
    }

    /// Compile an interleaved conv + dense stack (DESIGN.md §12):
    /// layer `li` consumes its flattened input features at
    /// `schedule[li].in_bits` and produces flattened accumulators at
    /// `schedule[li].acc_bits`; conv layers are lowered to their im2col
    /// matmul (one CSD plan per kernel weight, shared across all output
    /// pixels). Boundary conversion chains are precomputed here so
    /// workers never run the BFS, and all structural validation happens
    /// here (DESIGN.md §10) — a malformed model is its builder's error,
    /// never a PE-worker panic.
    pub fn compile_stack(
        layers: Vec<LayerOp>,
        schedule: Vec<LayerPrecision>,
    ) -> anyhow::Result<Arc<CompiledModel>> {
        anyhow::ensure!(!layers.is_empty(), "model needs at least one layer");
        anyhow::ensure!(
            layers.len() == schedule.len(),
            "{} layers but {} precision entries",
            layers.len(),
            schedule.len()
        );
        let mut batch_quantum = 1usize;
        for (li, (layer, p)) in layers.iter().zip(&schedule).enumerate() {
            p.validate()
                .map_err(|e| anyhow::anyhow!("layer {li}: {e}"))?;
            let w = layer.weights();
            anyhow::ensure!(
                crate::bits::format::FORMATS.contains(&w.bits),
                "layer {li}: weight width {} is not a Soft SIMD format",
                w.bits
            );
            anyhow::ensure!(
                w.k > 0 && w.n > 0,
                "layer {li}: degenerate shape {}x{}",
                w.k,
                w.n
            );
            if let LayerOp::Conv(c) = layer {
                c.shape
                    .validate()
                    .map_err(|e| anyhow::anyhow!("layer {li}: {e}"))?;
                anyhow::ensure!(
                    w.k == c.shape.patch_len() && w.n == c.shape.cout,
                    "layer {li}: conv weight matrix {}x{} does not match shape {}",
                    w.k,
                    w.n,
                    c.shape
                );
            }
            if li > 0 {
                anyhow::ensure!(
                    layers[li - 1].out_len() == layer.in_len(),
                    "layer {li}: input width {} != previous layer's output width {}",
                    layer.in_len(),
                    layers[li - 1].out_len()
                );
            }
            batch_quantum = lcm(batch_quantum, p.in_fmt().lanes() as usize);
            batch_quantum = lcm(batch_quantum, p.acc_fmt().lanes() as usize);
        }
        let chains = schedule
            .windows(2)
            .map(|w| conversion_chain(w[0].acc_fmt(), w[1].in_fmt()))
            .collect();
        PLAN_COMPILATIONS.fetch_add(1, Ordering::SeqCst);
        let plans: Vec<Vec<Vec<MulPlan>>> =
            layers.iter().map(|layer| layer.weights().plans()).collect();
        let mut cycles_per_word = 0u64;
        let mut zero_weights = 0u64;
        for layer_plans in &plans {
            for row in layer_plans {
                for plan in row {
                    if plan.ops.is_empty() {
                        zero_weights += 1;
                    } else {
                        cycles_per_word += plan.cycles() as u64;
                    }
                }
            }
        }
        let arena = PlanArena::build(&plans);
        Ok(Arc::new(CompiledModel {
            layers,
            plans,
            arena,
            schedule,
            chains,
            batch_quantum,
            cycles_per_word,
            zero_weights,
        }))
    }

    pub fn layers(&self) -> &[LayerOp] {
        &self.layers
    }

    /// The precompiled plan for layer `li`, weight `(k, n)`.
    #[inline]
    pub fn plan(&self, li: usize, k: usize, n: usize) -> &MulPlan {
        &self.plans[li][k][n]
    }

    /// The flattened micro-op arena the serving engine executes
    /// (one byte per Stage-1 cycle; column-adjacent plan headers).
    #[inline]
    pub fn flat(&self) -> &PlanArena {
        &self.arena
    }

    /// The full precision schedule, one entry per layer.
    pub fn schedule(&self) -> &[LayerPrecision] {
        &self.schedule
    }

    /// Layer `li`'s activation/accumulator format pair.
    #[inline]
    pub fn precision(&self, li: usize) -> LayerPrecision {
        self.schedule[li]
    }

    /// The precomputed crossbar chain converting layer `li`'s
    /// accumulators into layer `li+1`'s activations (empty = bypass).
    #[inline]
    pub fn boundary_chain(&self, li: usize) -> &[(SimdFormat, SimdFormat)] {
        &self.chains[li]
    }

    /// Activation width (bits) of the first layer — what requests
    /// arrive quantized to.
    pub fn in_bits(&self) -> u32 {
        self.schedule[0].in_bits
    }

    /// Accumulator width (bits) of the last layer — what responses
    /// carry.
    pub fn acc_bits(&self) -> u32 {
        self.schedule[self.schedule.len() - 1].acc_bits
    }

    pub fn in_fmt(&self) -> SimdFormat {
        self.schedule[0].in_fmt()
    }

    pub fn acc_fmt(&self) -> SimdFormat {
        self.schedule[self.schedule.len() - 1].acc_fmt()
    }

    /// Flattened input length of the first layer (row length of a
    /// request; for a conv-first model this is `cin·h·w`).
    pub fn input_width(&self) -> usize {
        self.layers[0].in_len()
    }

    /// Flattened output length of the last layer (row length of a
    /// response; for a conv-final model this is `cout·out_h·out_w`).
    pub fn output_width(&self) -> usize {
        self.layers[self.layers.len() - 1].out_len()
    }

    /// Rows per full packed batch: batches padded to a multiple of this
    /// keep every packed word full at every layer's format (6 for the
    /// uniform 8→16 schedule).
    pub fn batch_quantum(&self) -> usize {
        self.batch_quantum
    }

    /// Stage-1 cycles one packed word column costs across the whole
    /// forward pass (load-estimate metadata).
    pub fn cycles_per_word(&self) -> u64 {
        self.cycles_per_word
    }

    pub fn zero_weights(&self) -> u64 {
        self.zero_weights
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layers() -> Vec<QuantLayer> {
        vec![
            QuantLayer::new(vec![vec![64, 0], vec![-32, 127]], 8),
            QuantLayer::new(vec![vec![5], vec![-9]], 8),
        ]
    }

    #[test]
    fn compile_counts_and_metadata() {
        let before = PLAN_COMPILATIONS.load(Ordering::SeqCst);
        let m = CompiledModel::compile(layers(), 8, 16).unwrap();
        assert_eq!(PLAN_COMPILATIONS.load(Ordering::SeqCst), before + 1);
        assert_eq!(m.input_width(), 2);
        assert_eq!(m.batch_quantum(), 6); // lcm(6 @8b, 3 @16b)
        assert_eq!(m.zero_weights(), 1);
        assert!(m.cycles_per_word() > 0);
        assert_eq!(
            m.plan(0, 0, 0).ops.len(),
            m.layers()[0].weights().plan(0, 0).ops.len()
        );
        assert_eq!(m.boundary_chain(0), &[(SimdFormat::new(16), SimdFormat::new(8))]);
    }

    #[test]
    fn flat_arena_mirrors_the_plan_tables() {
        let m = CompiledModel::compile(layers(), 8, 16).unwrap();
        let arena = m.flat();
        for (li, layer) in m.layers().iter().enumerate() {
            let layer = layer.weights();
            for k in 0..layer.k {
                for n in 0..layer.n {
                    let plan = m.plan(li, k, n);
                    let h = arena.header(li, k, n);
                    assert_eq!(h.cycles as usize, plan.cycles(), "({li},{k},{n})");
                    assert_eq!(h.adds as usize, plan.adds());
                    let decoded: Vec<_> = arena
                        .ops(h)
                        .iter()
                        .map(|&b| crate::csd::flat::decode_op(b))
                        .collect();
                    assert_eq!(decoded, plan.ops);
                }
            }
        }
        // Column adjacency: layer 0 column 0 holds plans (k=0,n=0),(k=1,n=0).
        let col = arena.column(0, 0);
        assert_eq!(col.len(), 2);
        assert_eq!(col[0], arena.header(0, 0, 0));
        assert_eq!(col[1], arena.header(0, 1, 0));
    }

    #[test]
    fn rejects_empty_model_as_error_not_panic() {
        let err = CompiledModel::compile(vec![], 8, 16).expect_err("empty stack");
        assert!(err.to_string().contains("at least one layer"), "{err}");
    }

    #[test]
    fn rejects_malformed_schedules_and_shapes() {
        // Inverted precision pair (accumulator narrower than input).
        let err = CompiledModel::compile(layers(), 16, 8).expect_err("inverted pair");
        assert!(err.to_string().contains("narrower"), "{err}");
        // Schedule length mismatch.
        let err = CompiledModel::compile_scheduled(layers(), uniform_schedule(8, 16, 3))
            .expect_err("length mismatch");
        assert!(err.to_string().contains("precision entries"), "{err}");
        // Non-chaining layer dims.
        let bad = vec![
            QuantLayer::new(vec![vec![64, 0], vec![-32, 127]], 8), // 2 -> 2
            QuantLayer::new(vec![vec![5]], 8),                     // 1 -> 1
        ];
        let err = CompiledModel::compile(bad, 8, 16).expect_err("non-chaining dims");
        assert!(err.to_string().contains("output width"), "{err}");
    }

    #[test]
    fn compile_stack_chains_conv_and_dense_by_flattened_lengths() {
        use crate::nn::conv::{ConvLayer, ConvShape};
        // conv 1x4x4 → 2ch 3x3 s1 p1 (out 2x4x4 = 32) then dense 32→3.
        let shape =
            ConvShape { cin: 1, h: 4, w: 4, cout: 2, kh: 3, kw: 3, stride: 1, pad: 1 };
        let cw = QuantLayer::new(vec![vec![5, -9]; 9], 8);
        let conv = ConvLayer::new(cw, shape).unwrap();
        let dense = QuantLayer::new(vec![vec![1, 2, 3]; 32], 8);
        let ops = vec![LayerOp::Conv(conv.clone()), LayerOp::Dense(dense)];
        let m = CompiledModel::compile_stack(ops, uniform_schedule(8, 16, 2)).unwrap();
        assert_eq!(m.input_width(), 16);
        assert_eq!(m.output_width(), 3);
        assert_eq!(m.layers()[0].patch_rows(), 16);
        assert_eq!(m.layers()[1].patch_rows(), 1);
        // The arena holds one plan per kernel weight (9·2) plus the
        // dense plans (32·3) — shared across output pixels, not one per
        // pixel.
        assert_eq!(m.flat().total_plans(), 9 * 2 + 32 * 3);
        // Non-chaining flattened lengths are a compile error.
        let bad_dense = QuantLayer::new(vec![vec![1]; 31], 8);
        let err = CompiledModel::compile_stack(
            vec![LayerOp::Conv(conv), LayerOp::Dense(bad_dense)],
            uniform_schedule(8, 16, 2),
        )
        .expect_err("31 != 32");
        assert!(err.to_string().contains("output width"), "{err}");
    }

    #[test]
    fn mixed_schedule_metadata() {
        let sched = vec![LayerPrecision::new(4, 8), LayerPrecision::new(8, 16)];
        let m = CompiledModel::compile_scheduled(layers(), sched).unwrap();
        // lanes: 12 (4b in) / 6 (8b acc) / 6 (8b in) / 3 (16b acc).
        assert_eq!(m.batch_quantum(), 12);
        assert_eq!(m.in_bits(), 4);
        assert_eq!(m.acc_bits(), 16);
        // Boundary 8→8 is a bypass: empty chain.
        assert!(m.boundary_chain(0).is_empty());
        // A 2-hop boundary is precomputed as such.
        let sched = vec![LayerPrecision::new(8, 16), LayerPrecision::new(4, 8)];
        let m = CompiledModel::compile_scheduled(layers(), sched).unwrap();
        assert_eq!(m.boundary_chain(0).len(), 2, "16→4 chains via 8");
    }
}
