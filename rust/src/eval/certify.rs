//! `eval certify` — the static cost-certificate report (DESIGN.md §15).
//!
//! For both standard workloads (the matched-filter MLP with its
//! three-point variant list and the synthetic CNN with the standard
//! trio) this command certifies every variant from the compiled
//! artifact alone, prints the per-variant certified figures, and then
//! **differentially checks** the certificate against the running
//! engine at several batch sizes straddling the padding quantum:
//! the dense certificate must be an exact **upper bound** under the
//! conservation law of DESIGN.md §18 (`dense == executed + skipped`,
//! checked field by field through
//! [`CostCertificate::eval_stats_with_skips`]), and the
//! skip-conditioned certified energy must agree with the measured bill
//! to the attojoule — any mismatch errors, so the CI smoke run is a
//! real gate. The certificates are also written to `CERT_costs.json`
//! (cwd-relative, like `BENCH_*.json` and `VERIFY_margins.json`) for
//! CI upload.
//!
//! Stage-2/accumulate billing stays value-independent, but activation
//! zero-skipping makes the Stage-1 figures data-dependent: batch sizes
//! below the padding quantum produce all-zero pad words the engine
//! skips, so even random reference-precision rows exercise the
//! skip-conditioned contract for real. The synth CNN certifies the
//! full standard ladder, truncated-CSD approximate variants included
//! (their *cheaper* plans certify from bank plans alone, exactly like
//! the exact ones).
//!
//! [`CostCertificate::eval_stats_with_skips`]:
//! crate::analysis::cost::CostCertificate::eval_stats_with_skips

use std::sync::Arc;

use crate::anyhow;
use crate::coordinator::cost::CostTable;
use crate::coordinator::engine::PackedEngine;
use crate::coordinator::model::{CompiledModel, VariantSpec};
use crate::eval::autoscale::mlp_specs;
use crate::nn::conv::LayerOp;
use crate::testutil::random_batch;
use crate::workload::synth::{synth_cnn_stack, synth_mlp_stack, XorShift64};

/// Largest differentially-checked batch (a multiple of every variant's
/// quantum, matching the autoscale sample count).
const MAX_ROWS: usize = 96;

fn aj(pj: f64) -> i64 {
    (pj.max(0.0) * 1e6).round() as i64
}

/// Certify, print, differentially check, and JSON-encode one model's
/// variant set; appends the per-variant JSON objects to `json_variants`.
fn certify_model(
    name: &str,
    model: &Arc<CompiledModel>,
    cost: &CostTable,
    json_variants: &mut Vec<String>,
) -> anyhow::Result<()> {
    println!("model {name} ({} layers):", model.layers().len());
    let engine = PackedEngine::new(Arc::clone(model));
    let mut rng = XorShift64::new(0xCE47_1F1C);
    let batch = random_batch(&mut rng, MAX_ROWS, model.input_width(), model.in_bits());
    for v in 0..model.n_variants() {
        let var = model.variant(v);
        let cert = model.cost_certificate(v);
        let q = cert.batch_quantum;
        // Batch sizes straddling the quantum: a lone row, a partial
        // word, one exact quantum, and the full sample block.
        let mut ms = vec![1, q.saturating_sub(1).max(1), q, q + 1, MAX_ROWS];
        ms.sort_unstable();
        ms.dedup();
        let rows: Vec<Vec<i64>> = batch.iter().map(|r| var.quantize_row(r)).collect();
        let mut deltas = vec![];
        for &m in &ms {
            let (_, stats) = engine.forward_batch_variant(&rows[..m], v);
            // Upper-bound contract: the dense certificate minus the
            // batch's own skip counters must reconstruct the measured
            // stats exactly (the conservation law implies measured
            // Stage-1 work never exceeds the dense prediction).
            let conditioned = cert.eval_stats_with_skips(m, &stats);
            anyhow::ensure!(
                conditioned == stats,
                "{name}/{}: certificate diverges from the engine at m={m}:\n  \
                 cert (skip-conditioned) {:?}\n  engine {:?}",
                var.name(),
                conditioned,
                stats
            );
            let dense = cert.eval_stats(m);
            anyhow::ensure!(
                stats.s1_cycles <= dense.s1_cycles && stats.s1_adds <= dense.s1_adds,
                "{name}/{}: measured Stage-1 work exceeds the certified \
                 upper bound at m={m}",
                var.name()
            );
            let delta = aj(cost.batch_energy_pj(&stats))
                - aj(cost.batch_energy_pj(&conditioned));
            anyhow::ensure!(
                delta == 0,
                "{name}/{}: certified energy off by {delta} aJ at m={m}",
                var.name()
            );
            deltas.push(format!("m={m}"));
        }
        println!(
            "  {:<12} quantum={:<3} pJ/row={:<8.2} cyc/row={:<8.1} checked: {} (Δ=0 aJ)",
            var.name(),
            q,
            cert.pj_per_row(cost),
            cert.cycles_per_row(),
            deltas.join(" ")
        );
        let layers_json = cert
            .layers
            .iter()
            .map(|lc| {
                let hops = lc
                    .boundary
                    .iter()
                    .map(|(f, t)| {
                        // Boundary passes are linear in quantum blocks
                        // exactly when a block's produced bit count
                        // divides 48 evenly; otherwise the certificate
                        // keeps the exact ceil.
                        let bits_per_block = q * lc.patch_rows * t.bits as usize;
                        format!(
                            "{{\"from\": {}, \"to\": {}, \"bits_per_block\": {}, \
                             \"linear_in_blocks\": {}}}",
                            f.bits,
                            t.bits,
                            bits_per_block,
                            bits_per_block % 48 == 0
                        )
                    })
                    .collect::<Vec<_>>()
                    .join(", ");
                format!(
                    "{{\"layer\": {}, \"in_bits\": {}, \"acc_bits\": {}, \
                     \"patch_rows\": {}, \"cols\": {}, \"nonzero_plans\": {}, \
                     \"plan_cycles\": {}, \"plan_adds\": {}, \"hops\": [{hops}]}}",
                    lc.layer,
                    lc.in_bits,
                    lc.acc_bits,
                    lc.patch_rows,
                    lc.cols,
                    lc.nonzero_plans,
                    lc.plan_cycles,
                    lc.plan_adds
                )
            })
            .collect::<Vec<_>>()
            .join(", ");
        json_variants.push(format!(
            "    {{\"model\": \"{name}\", \"variant\": \"{}\", \"batch_quantum\": {q}, \
             \"pj_per_row\": {}, \"cycles_per_row\": {}, \"checked_batch_sizes\": [{}], \
             \"max_delta_aj\": 0, \"layers\": [{layers_json}]}}",
            var.name(),
            cert.pj_per_row(cost),
            cert.cycles_per_row(),
            ms.iter().map(|m| m.to_string()).collect::<Vec<_>>().join(", ")
        ));
    }
    println!();
    Ok(())
}

/// Run the certificate report; errors on any certificate/engine or
/// energy divergence.
pub fn run() -> anyhow::Result<()> {
    println!("== eval certify: static cost certificates vs the running engine ==\n");
    let cost = CostTable::characterize(1000.0);
    let mut json_variants = vec![];

    let mlp = synth_mlp_stack(8);
    let model = CompiledModel::compile_variants(mlp, mlp_specs())?;
    certify_model("synth-mlp", &model, &cost, &mut json_variants)?;

    let cnn: Vec<LayerOp> = synth_cnn_stack(0xA07A6, 8);
    let model = CompiledModel::compile_variants(cnn, VariantSpec::standard_ladder(3))?;
    certify_model("synth-cnn", &model, &cost, &mut json_variants)?;

    let json = format!(
        "{{\n  \"clock_mhz\": {},\n  \"certificates\": [\n{}\n  ]\n}}\n",
        cost.mhz,
        json_variants.join(",\n")
    );
    std::fs::write("CERT_costs.json", &json)?;
    println!("certificates written to CERT_costs.json");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_workload_variant_sets_certify_against_the_engine() {
        // The full differential sweep (every variant × batch sizes
        // straddling each quantum), minus the JSON side effect.
        let cost = CostTable::characterize(1000.0);
        let mut sink = vec![];
        let model =
            CompiledModel::compile_variants(synth_mlp_stack(8), mlp_specs()).unwrap();
        certify_model("synth-mlp", &model, &cost, &mut sink).unwrap();
        let model = CompiledModel::compile_variants(
            synth_cnn_stack(0xA07A6, 8),
            VariantSpec::standard_ladder(3),
        )
        .unwrap();
        certify_model("synth-cnn", &model, &cost, &mut sink).unwrap();
        assert_eq!(
            sink.len(),
            8,
            "three MLP variants plus the CNN's five-rung ladder"
        );
        assert!(sink.iter().all(|j| j.contains("\"max_delta_aj\": 0")));
    }
}
