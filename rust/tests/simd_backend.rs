//! Property tests for the host-vector execution backend
//! (`--features simd`, DESIGN.md §16).
//!
//! The backend claims to be **observably identical** to the scalar
//! core: for any interleaved conv + dense stack, any variant of the
//! standard trio, and any batch size — including m = 1 and sizes whose
//! packed word counts straddle the `TILE`-word tile boundary (tile-only,
//! tail-only, and mixed columns) — the vector path must produce
//! bit-exact logits and an `EngineStats` equal on every field to the
//! scalar core — including the zero-skip counters, since the wide tile
//! falls back to per-word skip decisions on mixed tiles — and to the
//! skip-conditioned static cost certificate
//! (`eval_stats_with_skips`, DESIGN.md §18). Under
//! `--features lanecheck,simd` the build must pin the scalar path and
//! record identically to plain `lanecheck`; under `billaudit` the
//! auditor must stay silent over the vector path.

use softsimd::bits::swarx::TILE;
use softsimd::coordinator::engine::{EngineScratch, PackedEngine};
use softsimd::coordinator::model::{CompiledModel, VariantSpec};
use softsimd::nn::conv::{ConvShape, LayerOp};
use softsimd::nn::exec::stack_forward_row;
use softsimd::testutil::{
    random_batch, random_conv_for_shape, random_conv_shape, random_dense,
};
use softsimd::workload::synth::XorShift64;

/// A valid conv geometry over a *fixed* input tensor `(cin, h, w)` —
/// random kernel/stride/padding, falling back to the always-valid 1×1
/// kernel (same generator as tests/cost_cert.rs; integration tests
/// cannot import each other).
fn conv_shape_from(rng: &mut XorShift64, cin: usize, h: usize, w: usize) -> ConvShape {
    for _ in 0..64 {
        let kh = 1 + (rng.next_u64() % 3) as usize;
        let kw = 1 + (rng.next_u64() % 3) as usize;
        let shape = ConvShape {
            cin,
            h,
            w,
            cout: 1 + (rng.next_u64() % 3) as usize,
            kh,
            kw,
            stride: 1 + (rng.next_u64() % 2) as usize,
            pad: (rng.next_u64() % kh.min(kw) as u64) as usize,
        };
        if shape.validate().is_ok() {
            return shape;
        }
    }
    ConvShape { cin, h, w, cout: 1, kh: 1, kw: 1, stride: 1, pad: 0 }
}

/// A random interleaved conv + dense stack with chaining widths (conv
/// input geometry decided one layer ahead) and exact zero weights
/// sprinkled in so the zero-skip runs on both backends.
fn random_mixed_stack(rng: &mut XorShift64, n_layers: usize, w_bits: u32) -> Vec<LayerOp> {
    let kinds: Vec<bool> = (0..n_layers).map(|_| rng.next_u64() % 2 == 0).collect();
    let mut ops: Vec<LayerOp> = Vec::new();
    let mut pending: Option<ConvShape> = None;
    let mut width = 0usize;
    for i in 0..n_layers {
        if kinds[i] {
            let shape = match pending.take() {
                Some(s) => s,
                None => match ops.last() {
                    Some(LayerOp::Conv(c)) => {
                        let p = c.shape;
                        conv_shape_from(rng, p.cout, p.out_h(), p.out_w())
                    }
                    Some(LayerOp::Dense(_)) => {
                        unreachable!("dense-before-conv always sets `pending`")
                    }
                    None => random_conv_shape(rng, 1 + (rng.next_u64() % 2) as usize),
                },
            };
            width = shape.out_len();
            ops.push(LayerOp::Conv(random_conv_for_shape(rng, shape, w_bits)));
        } else {
            let out = if i + 1 < n_layers && kinds[i + 1] {
                let s = random_conv_shape(rng, 1 + (rng.next_u64() % 2) as usize);
                pending = Some(s);
                s.in_len()
            } else {
                1 + (rng.next_u64() % 5) as usize
            };
            let k = if i == 0 { 2 + (rng.next_u64() % 5) as usize } else { width };
            let mut dense = random_dense(rng, k, out, w_bits);
            for row in &mut dense.w_raw {
                for w in row.iter_mut() {
                    if rng.next_u64() % 5 == 0 {
                        *w = 0;
                    }
                }
            }
            ops.push(LayerOp::Dense(dense));
            width = out;
        }
    }
    ops
}

/// Batch sizes that straddle the tile boundary for this variant: m = 1
/// (pad-heavy single row), a sub-quantum size, one exact quantum
/// (usually a sub-tile word count → tail-only columns), quantum + 1,
/// and `2·TILE` quanta ± 1 so per-column word counts cover tile-only,
/// mixed tile + tail, and the off-by-one straddles.
fn straddling_sizes(rng: &mut XorShift64, q: usize) -> [usize; 6] {
    [
        1,
        1 + (rng.next_u64() % 20) as usize,
        q,
        q + 1,
        2 * TILE * q,
        2 * TILE * q + 1,
    ]
}

/// The tentpole contract: vector path ≡ scalar core ≡ certificate, on
/// logits and on every `EngineStats` field, across random stacks ×
/// the standard trio × tile-straddling batch sizes.
#[test]
fn wide_backend_is_bit_exact_and_certificate_exact() {
    let mut rng = XorShift64::new(0x51D0_BEEF);
    let mut scratch = EngineScratch::new();
    let mut wide_out = Vec::new();
    let mut scalar_out = Vec::new();
    for case in 0..10 {
        let n_layers = 1 + (rng.next_u64() % 4) as usize;
        let ops = random_mixed_stack(&mut rng, n_layers, 8);
        let specs = VariantSpec::standard_trio(n_layers);
        let oracle_ops = ops.clone();
        let oracle_scheds: Vec<_> = specs.iter().map(|s| s.schedule.clone()).collect();
        let model = CompiledModel::compile_variants(ops, specs)
            .unwrap_or_else(|e| panic!("case {case}: compile failed: {e}"));
        let in_width = model.input_width();
        let engine = PackedEngine::new(model);
        for v in 0..engine.model().n_variants() {
            let var = engine.model().variant(v);
            let cert = engine.model().cost_certificate(v);
            let q = cert.batch_quantum;
            for m in straddling_sizes(&mut rng, q) {
                let batch: Vec<Vec<i64>> = random_batch(&mut rng, m, in_width, 8)
                    .iter()
                    .map(|r| var.quantize_row(r))
                    .collect();
                let wide_stats =
                    engine.forward_batch_into(&batch, v, &mut scratch, &mut wide_out);
                let scalar_stats = engine.forward_batch_into_scalar(
                    &batch,
                    v,
                    &mut scratch,
                    &mut scalar_out,
                );
                assert_eq!(
                    wide_out, scalar_out,
                    "case {case} variant {v} m={m}: logits diverge from scalar core"
                );
                assert_eq!(
                    wide_stats, scalar_stats,
                    "case {case} variant {v} m={m}: stats diverge from scalar core"
                );
                // Zero-aJ billing delta: the skip-conditioned
                // certificate *is* the scalar core's billing, field-
                // and bucket-exact, and the dense certificate bounds
                // it from above (conservation, DESIGN.md §18).
                assert_eq!(
                    cert.eval_stats_with_skips(m, &wide_stats),
                    wide_stats,
                    "case {case} variant {v} m={m}: stats diverge from certificate"
                );
                let dense = cert.eval_stats(m);
                assert_eq!(
                    wide_stats.s1_cycles + wide_stats.skipped_cycles,
                    dense.s1_cycles,
                    "case {case} variant {v} m={m}: s1 conservation"
                );
                // Ground truth on a head sample of rows (the full batch
                // is already pinned by the scalar-core equality above).
                for (b, row) in batch.iter().enumerate().take(3) {
                    let want = stack_forward_row(row, &oracle_ops, &oracle_scheds[v]);
                    assert_eq!(
                        wide_out[b], want,
                        "case {case} variant {v} m={m} row {b}"
                    );
                }
            }
        }
    }
}

/// Tail coverage at the word level: a batch quantum's worth of rows is
/// often a sub-`TILE` number of packed words per column, and growing
/// the batch one quantum at a time sweeps word counts 1, 2, …, 2·TILE —
/// every split between the tile loop and the scalar tail, on one model.
#[test]
fn every_tile_tail_split_matches_scalar() {
    let mut rng = XorShift64::new(0x51D0_7A11);
    let ops = random_mixed_stack(&mut rng, 2, 8);
    let model = CompiledModel::compile_variants(ops, VariantSpec::standard_trio(2))
        .expect("valid stack");
    let in_width = model.input_width();
    let engine = PackedEngine::new(model);
    let mut scratch = EngineScratch::new();
    let mut wide_out = Vec::new();
    let mut scalar_out = Vec::new();
    for v in 0..engine.model().n_variants() {
        let var = engine.model().variant(v);
        let q = engine.model().cost_certificate(v).batch_quantum;
        for words in 1..=(2 * TILE) {
            let m = words * q;
            let batch: Vec<Vec<i64>> = random_batch(&mut rng, m, in_width, 8)
                .iter()
                .map(|r| var.quantize_row(r))
                .collect();
            let ws = engine.forward_batch_into(&batch, v, &mut scratch, &mut wide_out);
            let ss =
                engine.forward_batch_into_scalar(&batch, v, &mut scratch, &mut scalar_out);
            assert_eq!(wide_out, scalar_out, "variant {v} {words} quanta");
            assert_eq!(ws, ss, "variant {v} {words} quanta");
        }
    }
}

/// `--features lanecheck,simd` must build, pin the scalar path at
/// compile time, and record *identically* through both entry points —
/// same violation count, same outputs (satellite 1).
#[cfg(feature = "lanecheck")]
#[test]
fn lanecheck_pins_scalar_path_and_records_identically() {
    use softsimd::bits::lanecheck;
    let mut rng = XorShift64::new(0x51D0_1A9E);
    let ops = random_mixed_stack(&mut rng, 3, 8);
    let model = CompiledModel::compile_variants(ops, VariantSpec::standard_trio(3))
        .expect("valid stack");
    let in_width = model.input_width();
    let engine = PackedEngine::new(model);
    let mut scratch = EngineScratch::new();
    let mut out_a = Vec::new();
    let mut out_b = Vec::new();
    for v in 0..engine.model().n_variants() {
        let var = engine.model().variant(v);
        let batch: Vec<Vec<i64>> = random_batch(&mut rng, 9, in_width, 8)
            .iter()
            .map(|r| var.quantize_row(r))
            .collect();
        lanecheck::reset();
        let stats_a = engine.forward_batch_into(&batch, v, &mut scratch, &mut out_a);
        let count_a = lanecheck::count();
        lanecheck::reset();
        let stats_b =
            engine.forward_batch_into_scalar(&batch, v, &mut scratch, &mut out_b);
        let count_b = lanecheck::count();
        assert_eq!(out_a, out_b, "variant {v}");
        assert_eq!(stats_a, stats_b, "variant {v}");
        assert_eq!(
            count_a, count_b,
            "variant {v}: the sanitizer must see the same scalar execution \
             through both entry points"
        );
        lanecheck::reset();
    }
}

/// `billaudit` runs unchanged over the vector path: the differential
/// auditor must stay silent on every wide batch (satellite 1) — zero
/// divergences means zero-aJ billing delta, since energy is priced
/// from the very stats the auditor compares.
#[cfg(feature = "billaudit")]
#[test]
fn billing_auditor_is_silent_over_the_wide_path() {
    use softsimd::analysis::cost::audit;
    let mut rng = XorShift64::new(0x51D0_B111);
    audit::reset();
    let mut scratch = EngineScratch::new();
    let mut out = Vec::new();
    for _ in 0..5 {
        let n_layers = 1 + (rng.next_u64() % 3) as usize;
        let ops = random_mixed_stack(&mut rng, n_layers, 8);
        let model =
            CompiledModel::compile_variants(ops, VariantSpec::standard_trio(n_layers))
                .expect("valid stack");
        let in_width = model.input_width();
        let engine = PackedEngine::new(model);
        for v in 0..engine.model().n_variants() {
            let var = engine.model().variant(v);
            let q = engine.model().cost_certificate(v).batch_quantum;
            for m in [1, q * TILE, q * TILE + 1] {
                let batch: Vec<Vec<i64>> = random_batch(&mut rng, m, in_width, 8)
                    .iter()
                    .map(|r| var.quantize_row(r))
                    .collect();
                // The engine audits every batch against the certificate
                // on its own under `billaudit` — on the wide path too.
                let _ = engine.forward_batch_into(&batch, v, &mut scratch, &mut out);
            }
        }
    }
    assert_eq!(audit::count(), 0, "divergences: {:?}", audit::take());
}
