//! Canonical Signed Digit (CSD) encoding and the digit→cycle scheduler
//! (Section II-B, III-B).

pub mod encode;
pub mod flat;
pub mod schedule;
pub mod stats;

pub use encode::{csd_decode, csd_encode, Digit};
pub use flat::{FlatPlan, PlanArena};
pub use schedule::{schedule, MulOp, MulPlan};
pub use stats::{density, DensityStats};
