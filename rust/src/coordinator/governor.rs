//! The SLO-driven precision governor (DESIGN.md §13): the run-time
//! policy that picks which precision [`Variant`] of the served model
//! each dispatched batch executes at.
//!
//! The paper's repacking unit exists so sub-word bitwidth can change
//! *at run time*; precision-scalable accelerators (Moons & Verhelst's
//! 0.3–2.6 TOPS/W ConvNet processor, Ottavi et al.'s mixed-precision
//! RISC-V core) make that trade under load: when the queue grows or the
//! tail latency blows past its objective, shed operand width — each
//! step down packs more rows per 48-bit word, so the same silicon
//! clears the backlog at lower energy per row — and step back to full
//! fidelity once the pressure is gone.
//!
//! The governor is a policy object consulted at every batch dispatch
//! with the current [`LoadSignals`]; [`SloPolicy`] is the default
//! hysteresis implementation, [`PinnedVariant`] the degenerate one
//! (and the default: installing no governor serves the reference
//! variant forever, exactly the pre-§13 behavior). Decisions are
//! *advisory per batch*: the batch is tagged with the chosen variant
//! and the worker bills the variant it actually executed.
//!
//! [`Variant`]: super::model::Variant

use std::time::Duration;

/// Load signals sampled at one dispatch decision.
#[derive(Debug, Clone, Copy)]
pub struct LoadSignals {
    /// Rows visible to the serving loop right now: the batch being
    /// dispatched, everything still pending in the batcher, and every
    /// row dispatched to a PE worker and not yet completed.
    pub queued_rows: usize,
    /// p99 request latency over the window since the previous decision
    /// (`None` when no request completed in the window — treat as "no
    /// pressure signal", not as zero latency).
    pub window_p99_ns: Option<u64>,
    /// How many precision variants the served model carries; choices
    /// are clamped to `0..n_variants` by the caller.
    pub n_variants: usize,
}

/// A precision-selection policy. Implementations are consulted once
/// per dispatched batch and may keep internal state (hysteresis
/// counters, EWMAs, …). Returned ids out of range are clamped by the
/// coordinator.
pub trait GovernorPolicy: Send {
    /// Variant id the next dispatched batch should execute at.
    fn choose(&mut self, load: &LoadSignals) -> usize;
}

/// Pin one variant forever — the no-governor default, and the
/// deterministic harness for per-variant billing tests.
#[derive(Debug, Clone)]
pub struct PinnedVariant(pub usize);

impl GovernorPolicy for PinnedVariant {
    fn choose(&mut self, _load: &LoadSignals) -> usize {
        self.0
    }
}

/// The default governor: watermark hysteresis over queue depth plus a
/// p99 latency objective.
///
/// Variants are assumed ordered hi-fidelity (0) → cheapest (N−1), the
/// order [`VariantSpec::standard_trio`] produces. One step of
/// precision is shed per overloaded decision (`queued_rows` above the
/// high watermark **or** windowed p99 above the objective); one step
/// is restored only after `patience` consecutive *calm* decisions
/// (`queued_rows` at or below the low watermark **and** windowed p99
/// at or below half the objective — recovering into a still-warm
/// latency tail would oscillate). Between the watermarks the current
/// variant holds: that dead band is the hysteresis that keeps a
/// borderline load from flapping formats every batch.
///
/// [`VariantSpec::standard_trio`]: super::model::VariantSpec::standard_trio
#[derive(Debug, Clone)]
pub struct SloPolicy {
    target_p99: Duration,
    high_rows: usize,
    low_rows: usize,
    patience: u32,
    current: usize,
    calm_streak: u32,
}

impl SloPolicy {
    /// Shed precision above `high_rows` queued rows (or past
    /// `target_p99`); recover at or below `low_rows`. `low_rows` is
    /// clamped to `high_rows`.
    pub fn new(target_p99: Duration, high_rows: usize, low_rows: usize) -> SloPolicy {
        SloPolicy {
            target_p99,
            high_rows: high_rows.max(1),
            low_rows: low_rows.min(high_rows).max(1),
            patience: 2,
            current: 0,
            calm_streak: 0,
        }
    }

    /// Consecutive calm decisions required before restoring one step of
    /// fidelity (default 2; clamped to ≥ 1).
    pub fn patience(mut self, n: u32) -> SloPolicy {
        self.patience = n.max(1);
        self
    }

    /// The variant the policy currently considers active.
    pub fn current(&self) -> usize {
        self.current
    }
}

impl GovernorPolicy for SloPolicy {
    fn choose(&mut self, load: &LoadSignals) -> usize {
        let cheapest = load.n_variants.saturating_sub(1);
        let target_ns = self.target_p99.as_nanos() as u64;
        let overloaded = load.queued_rows > self.high_rows
            || load.window_p99_ns.is_some_and(|p| p > target_ns);
        let calm = load.queued_rows <= self.low_rows
            && load.window_p99_ns.map_or(true, |p| p <= target_ns / 2);
        if overloaded {
            self.calm_streak = 0;
            if self.current < cheapest {
                self.current += 1;
            }
        } else if calm {
            self.calm_streak += 1;
            if self.calm_streak >= self.patience && self.current > 0 {
                self.current -= 1;
                self.calm_streak = 0;
            }
        } else {
            // The dead band between the watermarks: hold and restart
            // the calm count — recovery needs *consecutive* calm.
            self.calm_streak = 0;
        }
        self.current.min(cheapest)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(queued: usize, p99_ns: Option<u64>) -> LoadSignals {
        LoadSignals { queued_rows: queued, window_p99_ns: p99_ns, n_variants: 3 }
    }

    #[test]
    fn pinned_never_moves() {
        let mut p = PinnedVariant(1);
        assert_eq!(p.choose(&sig(0, None)), 1);
        assert_eq!(p.choose(&sig(10_000, Some(u64::MAX))), 1);
    }

    #[test]
    fn step_load_sheds_then_recovers_with_hysteresis() {
        // The acceptance trace in miniature: light → overload → light.
        let mut g = SloPolicy::new(Duration::from_millis(1), 100, 20).patience(2);
        // Light load: stays at full fidelity.
        for _ in 0..5 {
            assert_eq!(g.choose(&sig(5, Some(10_000))), 0);
        }
        // Step overload: sheds one step per decision down to cheapest,
        // and no further.
        assert_eq!(g.choose(&sig(500, Some(10_000))), 1);
        assert_eq!(g.choose(&sig(500, None)), 2);
        assert_eq!(g.choose(&sig(500, None)), 2, "clamps at the cheapest variant");
        // Load drops into the dead band: hold (no flapping).
        assert_eq!(g.choose(&sig(50, Some(10_000))), 2);
        assert_eq!(g.choose(&sig(50, None)), 2);
        // Calm: one step of fidelity back per `patience` calm decisions.
        assert_eq!(g.choose(&sig(5, Some(10_000))), 2, "calm 1 of 2");
        assert_eq!(g.choose(&sig(5, None)), 1, "calm 2 of 2 → step up");
        assert_eq!(g.choose(&sig(5, None)), 1, "calm 1 of 2 again");
        assert_eq!(g.choose(&sig(5, None)), 0, "back at full fidelity");
        assert_eq!(g.choose(&sig(5, None)), 0, "and stays there");
    }

    #[test]
    fn latency_breach_sheds_even_with_a_short_queue() {
        let mut g = SloPolicy::new(Duration::from_micros(100), 1_000_000, 10);
        // Queue is empty but the tail blew the objective: shed anyway.
        assert_eq!(g.choose(&sig(0, Some(200_000))), 1);
        // A calm window with p99 ≤ target/2 recovers (after patience).
        assert_eq!(g.choose(&sig(0, Some(40_000))), 1);
        assert_eq!(g.choose(&sig(0, Some(40_000))), 0);
        // p99 in (target/2, target]: dead band — calm streak resets.
        let mut h = SloPolicy::new(Duration::from_micros(100), 1_000_000, 10);
        assert_eq!(h.choose(&sig(0, Some(200_000))), 1);
        assert_eq!(h.choose(&sig(0, Some(40_000))), 1, "calm 1 of 2");
        assert_eq!(h.choose(&sig(0, Some(80_000))), 1, "dead band resets calm");
        assert_eq!(h.choose(&sig(0, Some(40_000))), 1, "calm 1 of 2 again");
        assert_eq!(h.choose(&sig(0, Some(40_000))), 0);
    }

    #[test]
    fn quiet_windows_count_as_calm_on_queue_alone() {
        let mut g = SloPolicy::new(Duration::from_millis(1), 100, 20).patience(1);
        assert_eq!(g.choose(&sig(500, None)), 1);
        // No completions in the window (p99 None) and an empty queue:
        // calm — recovery must not deadlock on a silent window.
        assert_eq!(g.choose(&sig(0, None)), 0);
    }

    #[test]
    fn choices_clamp_to_the_variant_count() {
        let mut g = SloPolicy::new(Duration::from_millis(1), 10, 2);
        let two = LoadSignals { queued_rows: 999, window_p99_ns: None, n_variants: 2 };
        assert_eq!(g.choose(&two), 1);
        assert_eq!(g.choose(&two), 1, "never past n_variants - 1");
        let one = LoadSignals { queued_rows: 999, window_p99_ns: None, n_variants: 1 };
        assert_eq!(g.choose(&one), 0, "single-variant models never switch");
    }
}
