//! Cells and netlists.

/// Index of a net (one driver per net — the output of a cell).
pub type NodeId = u32;

/// Standard-cell kinds. Two-input cells use `a`, `b`; `Mux2` selects
/// `a` when `sel = 0`, `b` when `sel = 1`; `Inv`/`Buf` use `a` only.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CellKind {
    /// Primary input (value supplied by the simulator).
    Input,
    Const0,
    Const1,
    Inv,
    Buf,
    And2,
    Or2,
    Nand2,
    Nor2,
    Xor2,
    Xnor2,
    /// out = sel ? b : a
    Mux2,
}

impl CellKind {
    /// Propagation levels contributed (FO4-normalized; see
    /// `energy::tech::GATE_DELAY_PS`).
    pub fn levels(self) -> u32 {
        match self {
            CellKind::Input | CellKind::Const0 | CellKind::Const1 => 0,
            CellKind::Inv | CellKind::Buf => 1,
            CellKind::And2 | CellKind::Or2 | CellKind::Nand2 | CellKind::Nor2 => 1,
            CellKind::Xor2 | CellKind::Xnor2 => 2,
            CellKind::Mux2 => 2,
        }
    }
}

/// One cell instance.
#[derive(Debug, Clone, Copy)]
pub struct Cell {
    pub kind: CellKind,
    /// Operand nets; unused slots are `u32::MAX`.
    pub a: NodeId,
    pub b: NodeId,
    pub sel: NodeId,
}

pub const NO_NET: NodeId = u32::MAX;

/// A combinational netlist. Cells are stored in topological order by
/// construction (a cell may only reference earlier cells), so a single
/// forward pass evaluates the whole network.
#[derive(Debug, Clone, Default)]
pub struct Netlist {
    pub cells: Vec<Cell>,
    /// Primary inputs, in declaration order.
    pub inputs: Vec<NodeId>,
    /// Primary outputs (nets).
    pub outputs: Vec<NodeId>,
    /// Human-readable block name.
    pub name: String,
}

impl Netlist {
    pub fn num_cells(&self) -> usize {
        self.cells.len()
    }

    /// Count of *logic* cells (excluding inputs/constants) — the area
    /// carrier.
    pub fn logic_cells(&self) -> usize {
        self.cells
            .iter()
            .filter(|c| {
                !matches!(c.kind, CellKind::Input | CellKind::Const0 | CellKind::Const1)
            })
            .count()
    }

    /// Per-kind logic cell histogram.
    pub fn cell_histogram(&self) -> Vec<(CellKind, usize)> {
        use std::collections::HashMap;
        let mut h: HashMap<CellKind, usize> = HashMap::new();
        for c in &self.cells {
            if !matches!(c.kind, CellKind::Input | CellKind::Const0 | CellKind::Const1) {
                *h.entry(c.kind).or_default() += 1;
            }
        }
        let mut v: Vec<_> = h.into_iter().collect();
        v.sort_by_key(|&(k, _)| format!("{k:?}"));
        v
    }
}

#[cfg(test)]
mod tests {
    use crate::rtl::build::NetBuilder;

    #[test]
    fn histogram_counts_logic_only() {
        let mut b = NetBuilder::new("t");
        let x = b.input();
        let y = b.input();
        let g = b.and2(x, y);
        let h = b.xor2(g, x);
        b.output(h);
        let n = b.finish();
        assert_eq!(n.logic_cells(), 2);
        assert_eq!(n.num_cells(), 4);
    }
}
