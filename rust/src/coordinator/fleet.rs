//! The fleet front end (DESIGN.md §17): multi-model, multi-tenant
//! serving over the packed execution core.
//!
//! One [`Fleet`] hosts N compiled models — each with its own variant
//! set, plan arena, and [`Metrics`] — behind a single admission layer.
//! A request names its model and its tenant; admission validates it
//! against that model, then applies the tenant's SLO-class budget:
//! if the certified drain time ([`CertifiedCosts::est_drain_ns`]) of
//! the rows the tenant *already* has queued exceeds the class's
//! `drain_budget`, the request is refused with a typed
//! [`ServeError::Shed`] — never a silent drop, never an unbounded
//! queue. Admitted rows are routed to the least-loaded of the model's
//! replicated PE pools (least-outstanding-rows promoted from
//! per-worker to per-pool), where they land in the tenant's own
//! batcher lane. Lanes keep tenants' batches disjoint, so a batch is
//! always tenant-homogeneous: the PE worker that executes it bills the
//! whole batch — energy, compute time, per-request latency — to that
//! tenant's [`TenantMetrics`] bucket as well as the model's.
//!
//! Each (model, tenant) pair runs its **own** governor instance
//! (default: the class's [`SloPolicy`] armed with the model's certified
//! costs), windowing p99 over the tenant's own latency histogram — one
//! tenant's burst pressures its own governor, not its neighbors'.
//! Deadline ticks and drain flushes serve lanes in class-priority
//! order, so an interactive class's stragglers flush before a bulk
//! class's.
//!
//! The channel boundary is genuinely asynchronous: `submit` never
//! waits for execution, completions arrive tagged with per-request ids
//! in whatever order pools finish them, and [`Fleet::try_collect`] /
//! [`Fleet::collect_timeout`] hand them back without blocking the
//! submit path. [`Fleet::drain`] is the synchronous barrier the
//! single-model [`Coordinator`] wrapper (server.rs) builds on.
//!
//! [`Coordinator`]: super::server::Coordinator

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{Batch, Batcher, TrackedRequest};
use super::cost::CostTable;
use super::engine::{EngineScratch, PackedEngine};
use super::governor::{CertifiedCosts, GovernorPolicy, LoadSignals, SloClass};
use super::metrics::{Metrics, TenantMetrics, TenantSnapshot};
use super::model::CompiledModel;
use super::server::{Request, Response, ServeConfig, ServeError};

/// Recover a mutex regardless of poisoning — for paths that must make
/// progress after a panic elsewhere (teardown, observability, the
/// deadline tick, writing off dead workers' counters). The guarded
/// state is counters and queues that stay consistent across a holder's
/// panic; the submit paths use [`lock_or`] instead and surface the
/// poisoning as a typed error.
pub(crate) fn relock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Acquire a mutex or surface the poisoning as
/// [`ServeError::LockPoisoned`] — the submit-path counterpart of
/// [`relock`]: a caller handing in new work can be refused cleanly.
pub(crate) fn lock_or<'a, T>(
    m: &'a Mutex<T>,
    what: &'static str,
) -> Result<std::sync::MutexGuard<'a, T>, ServeError> {
    m.lock()
        .map_err(|_| ServeError::LockPoisoned { what, recovered: vec![] })
}

/// Decrement an atomic counter, flooring at zero. The fleet's row
/// accounting can legitimately race a drain-time write-off (the worker
/// decrements on completion; `drain` zeroes a dead worker's share), so
/// plain `fetch_sub` could wrap; saturating keeps the counters sane and
/// `recount_loads` repairs any residue at the next quiescent point.
fn sat_sub(counter: &AtomicUsize, rows: usize) {
    let _ = counter.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |x| {
        Some(x.saturating_sub(rows))
    });
}

pub(crate) enum WorkerMsg {
    Work(Batch),
    Stop,
}

/// Leader-side view of one PE worker.
pub(crate) struct WorkerPort {
    pub(crate) tx: SyncSender<WorkerMsg>,
    /// Rows dispatched to this worker and not yet completed.
    pub(crate) outstanding_rows: Arc<AtomicUsize>,
    /// Batches dispatched to this worker and not yet completed.
    pub(crate) outstanding_batches: Arc<AtomicUsize>,
    pub(crate) alive: bool,
}

/// Load-aware batch router over one pool's worker ports.
pub(crate) struct Router {
    pub(crate) ports: Vec<WorkerPort>,
    pub(crate) policy: super::server::DispatchPolicy,
    pub(crate) next_rr: usize,
}

impl Router {
    /// Candidate workers, best first, per the policy. Only live ports.
    fn candidates(&mut self) -> Vec<usize> {
        let live: Vec<usize> = (0..self.ports.len())
            .filter(|&i| self.ports[i].alive)
            .collect();
        if live.is_empty() {
            return live;
        }
        match self.policy {
            super::server::DispatchPolicy::RoundRobin => {
                let start = self.next_rr % live.len();
                self.next_rr = self.next_rr.wrapping_add(1);
                let mut order = Vec::with_capacity(live.len());
                for off in 0..live.len() {
                    order.push(live[(start + off) % live.len()]);
                }
                order
            }
            super::server::DispatchPolicy::LeastLoaded => {
                let mut order = live;
                order.sort_by_key(|&i| {
                    self.ports[i].outstanding_rows.load(Ordering::Relaxed)
                });
                order
            }
        }
    }

    /// Route one batch. Tries every live worker without blocking; if all
    /// bounded queues are full, blocks on the preferred worker
    /// (backpressure). `Err(batch)` iff no live worker remains.
    fn dispatch(&mut self, batch: Batch) -> Result<usize, Batch> {
        let mut batch = batch;
        loop {
            let order = self.candidates();
            if order.is_empty() {
                return Err(batch);
            }
            // Non-blocking pass in preference order.
            for &w in &order {
                self.charge(w, &batch);
                match self.ports[w].tx.try_send(WorkerMsg::Work(batch)) {
                    Ok(()) => return Ok(w),
                    Err(TrySendError::Full(msg)) => {
                        batch = self.uncharge(w, msg);
                    }
                    Err(TrySendError::Disconnected(msg)) => {
                        batch = self.uncharge(w, msg);
                        self.ports[w].alive = false;
                    }
                }
            }
            // All live queues full: block on the preferred one.
            let w = match self.candidates().first() {
                Some(&w) => w,
                None => return Err(batch),
            };
            self.charge(w, &batch);
            match self.ports[w].tx.send(WorkerMsg::Work(batch)) {
                Ok(()) => return Ok(w),
                Err(std::sync::mpsc::SendError(msg)) => {
                    batch = self.uncharge(w, msg);
                    self.ports[w].alive = false;
                    // Retry the remaining live workers.
                }
            }
        }
    }

    fn charge(&self, w: usize, batch: &Batch) {
        self.ports[w]
            .outstanding_rows
            .fetch_add(batch.rows, Ordering::Relaxed);
        self.ports[w]
            .outstanding_batches
            .fetch_add(1, Ordering::Relaxed);
    }

    fn uncharge(&self, w: usize, msg: WorkerMsg) -> Batch {
        let batch = match msg {
            WorkerMsg::Work(b) => b,
            WorkerMsg::Stop => unreachable!("router only routes work"),
        };
        self.ports[w]
            .outstanding_rows
            .fetch_sub(batch.rows, Ordering::Relaxed);
        self.ports[w]
            .outstanding_batches
            .fetch_sub(1, Ordering::Relaxed);
        batch
    }
}

/// One (model, tenant) governor's mutable half: the installed policy
/// plus the tenant snapshot its last decision was taken at (windowed
/// p99 = the tenant's histogram delta between two consecutive
/// decisions — one tenant's tail never pressures another's governor).
pub(crate) struct GovernorState {
    pub(crate) policy: Box<dyn GovernorPolicy>,
    last_snap: TenantSnapshot,
}

/// Per-(model, tenant) governor slot.
struct TenantGov {
    state: Mutex<GovernorState>,
    /// Most recently chosen variant (observability + the admission
    /// check's drain estimate; billing follows each batch's own tag).
    active_variant: AtomicUsize,
}

/// One tenant's batcher lane within a pool. Lanes keep tenants'
/// batches disjoint — a formed batch never mixes SLO classes.
pub(crate) struct Lane {
    pub(crate) batcher: Mutex<Batcher>,
}

/// One replicated PE pool of a model shard.
pub(crate) struct PoolCore {
    /// Per-tenant batcher lanes, indexed by tenant id.
    pub(crate) lanes: Vec<Lane>,
    pub(crate) router: Mutex<Router>,
    /// Batches dispatched from this pool and not yet collected.
    in_flight: AtomicUsize,
    /// Each worker slot's outstanding-row counter (shared with the
    /// router's ports) — readable without the router lock.
    port_loads: Vec<Arc<AtomicUsize>>,
    /// Rows admitted to this pool and not yet completed (lane-pending +
    /// dispatched); the per-pool least-outstanding-rows dispatch key.
    load_rows: Arc<AtomicUsize>,
    /// This pool's first worker's fleet-wide flat slot index — the id
    /// space [`ServeError::WorkerLost`] reports.
    worker_base: usize,
}

/// One hosted model: its compiled plans, pools, per-tenant governors,
/// and billing state.
pub(crate) struct ModelShard {
    model: Arc<CompiledModel>,
    cost: Arc<CostTable>,
    pub(crate) metrics: Arc<Metrics>,
    certified: CertifiedCosts,
    /// Per-variant batch quanta (index = variant id); also the variant
    /// count — single-entry for a single-variant model.
    quanta: Vec<usize>,
    pub(crate) pools: Vec<PoolCore>,
    /// Per-tenant governor slots, indexed by tenant id.
    govs: Vec<TenantGov>,
    /// Rows admitted for each tenant across all of this model's pools
    /// and not yet completed — the admission check's queue estimate.
    tenant_queued: Arc<Vec<AtomicUsize>>,
    /// Model row width, for request validation at submit.
    input_width: usize,
    /// Half-range of the reference variant's input format
    /// (`2^(in_bits-1)`), for validation.
    in_half: i64,
    queue_depth: usize,
}

/// One tenant class and its fleet-wide metrics bucket.
struct TenantState {
    class: SloClass,
    metrics: Arc<TenantMetrics>,
}

/// State shared between the submit path, the deadline thread, and the
/// PE workers.
pub(crate) struct FleetShared {
    pub(crate) models: Vec<ModelShard>,
    tenants: Vec<TenantState>,
    /// Tenant ids sorted by class priority (lower priority value =
    /// served first at ticks and drain flushes).
    priority_order: Vec<usize>,
    stop_deadline: AtomicBool,
}

/// A completion message from one PE worker: which pool finished (for
/// the in-flight ledger) and the responses it produced.
struct Done {
    model: usize,
    pool: usize,
    responses: Vec<Response>,
}

/// Deployment description of one hosted model.
pub struct ModelConfig {
    /// The compiled model (all variants, one plan arena).
    pub model: Arc<CompiledModel>,
    /// Cost table billing this model's cycles.
    pub cost: CostTable,
    /// Replicated PE pools serving this model.
    pub n_pools: usize,
    /// Per-pool knobs (PE count, batch target, queue depth, deadline,
    /// dispatch policy) — identical across the model's pools.
    pub pool: ServeConfig,
}

impl ModelConfig {
    /// One pool of `pool.n_pes` PEs serving `model` billed by `cost`.
    pub fn new(model: Arc<CompiledModel>, cost: CostTable, pool: ServeConfig) -> ModelConfig {
        ModelConfig { model, cost, n_pools: 1, pool }
    }

    /// Replicate the model across `n` identical PE pools.
    pub fn pools(mut self, n: usize) -> ModelConfig {
        self.n_pools = n;
        self
    }
}

/// Deployment description of a whole fleet.
#[derive(Default)]
pub struct FleetConfig {
    /// Hosted models; a request's `model` id indexes this list.
    pub models: Vec<ModelConfig>,
    /// Tenant SLO classes; a request's `tenant` id indexes this list.
    pub tenants: Vec<SloClass>,
}

impl FleetConfig {
    /// An empty fleet description.
    pub fn new() -> FleetConfig {
        FleetConfig::default()
    }

    /// Add a hosted model (its id = position in the add order).
    pub fn model(mut self, model: ModelConfig) -> FleetConfig {
        self.models.push(model);
        self
    }

    /// Add a tenant class (its id = position in the add order).
    pub fn tenant(mut self, class: SloClass) -> FleetConfig {
        self.tenants.push(class);
        self
    }
}

/// Worker (re)spawn context for one (model, pool) slot — everything a
/// PE worker thread needs beyond its own queue and counters.
struct WorkerCtx {
    model_idx: usize,
    pool_idx: usize,
    model: Arc<CompiledModel>,
    cost: Arc<CostTable>,
    metrics: Arc<Metrics>,
    tenant_metrics: Vec<Arc<TenantMetrics>>,
    tenant_queued: Arc<Vec<AtomicUsize>>,
    pool_load: Arc<AtomicUsize>,
    tx_done: Sender<Done>,
    queue_depth: usize,
}

/// Spawn one PE worker thread, reusing the slot's outstanding-work
/// counters (they outlive any one incarnation of the worker — the
/// router and the pool dispatch read them by slot).
fn spawn_worker(
    ctx: &WorkerCtx,
    outstanding_rows: Arc<AtomicUsize>,
    outstanding_batches: Arc<AtomicUsize>,
) -> (WorkerPort, JoinHandle<()>) {
    let (tx, rx) = sync_channel::<WorkerMsg>(ctx.queue_depth.max(1));
    let port = WorkerPort {
        tx,
        outstanding_rows: Arc::clone(&outstanding_rows),
        outstanding_batches: Arc::clone(&outstanding_batches),
        alive: true,
    };
    let engine = PackedEngine::new(Arc::clone(&ctx.model));
    let w = WorkerState {
        model_idx: ctx.model_idx,
        pool_idx: ctx.pool_idx,
        engine,
        done: ctx.tx_done.clone(),
        metrics: Arc::clone(&ctx.metrics),
        tenant_metrics: ctx.tenant_metrics.clone(),
        tenant_queued: Arc::clone(&ctx.tenant_queued),
        pool_load: Arc::clone(&ctx.pool_load),
        cost: Arc::clone(&ctx.cost),
        outstanding_rows,
        outstanding_batches,
    };
    let handle = std::thread::spawn(move || worker_loop(w, rx));
    (port, handle)
}

impl FleetShared {
    /// Count and route one formed batch while still holding the lane's
    /// batcher lock. Holding the lock keeps the invariant that whenever
    /// the lane is observable, every formed batch is either counted in
    /// the pool's `in_flight` or restored as pending — so `drain` can
    /// never slip between "batch left the batcher" and "batch became
    /// in-flight". Lock order is always batcher → governor → router;
    /// never any reverse.
    fn dispatch_locked(
        &self,
        mi: usize,
        pi: usize,
        tenant: usize,
        batcher: &mut Batcher,
        mut batch: Batch,
    ) -> Result<(), ServeError> {
        let shard = &self.models[mi];
        let pool = &shard.pools[pi];
        batch.tenant = tenant;
        // Per-tenant governor decision (DESIGN.md §13/§17): sample the
        // tenant's admitted-not-completed rows plus the windowed p99 of
        // the tenant's own latency histogram; stamp the batch and
        // re-arm this lane's alignment quantum for the *next* batch.
        // A single-variant model has no decision to make, and a
        // poisoned governor degrades gracefully: the batch keeps its
        // current variant tag and dispatch proceeds.
        if shard.quanta.len() > 1 {
            if let Ok(mut gov) = shard.govs[tenant].state.lock() {
                let queued_rows = shard.tenant_queued[tenant].load(Ordering::Relaxed);
                let snap = self.tenants[tenant].metrics.snapshot();
                let window_p99_ns = snap.window_latency_quantile_ns(&gov.last_snap, 0.99);
                let chosen = gov.policy.choose(&LoadSignals {
                    queued_rows,
                    window_p99_ns,
                    n_variants: shard.quanta.len(),
                });
                gov.last_snap = snap;
                let v = chosen.min(shard.quanta.len() - 1);
                if v != shard.govs[tenant].active_variant.swap(v, Ordering::Relaxed) {
                    shard.metrics.note_variant_switch();
                }
                batch.variant = v;
                batcher.set_quantum(shard.quanta[v]);
            }
        }
        pool.in_flight.fetch_add(1, Ordering::SeqCst);
        let result = match pool.router.lock() {
            Ok(mut router) => router.dispatch(batch),
            Err(_) => {
                // Poisoned router: restore the batch (it was never
                // dispatched) and refuse the submit.
                pool.in_flight.fetch_sub(1, Ordering::SeqCst);
                batcher.restore(batch);
                return Err(ServeError::LockPoisoned {
                    what: "router",
                    recovered: vec![],
                });
            }
        };
        match result {
            Ok(_) => Ok(()),
            Err(batch) => {
                pool.in_flight.fetch_sub(1, Ordering::SeqCst);
                batcher.restore(batch);
                Err(ServeError::NoLiveWorkers { recovered: vec![] })
            }
        }
    }

    /// Deadline-thread path: poll every lane's tick (lanes in class
    /// priority order within each pool); dispatch straggler flushes.
    /// Recovers poisoned batchers — the deadline thread must keep
    /// ticking (and must never panic itself) after a panic elsewhere.
    fn tick_all(&self) {
        for (mi, shard) in self.models.iter().enumerate() {
            for (pi, pool) in shard.pools.iter().enumerate() {
                for &t in &self.priority_order {
                    let mut batcher = relock(&pool.lanes[t].batcher);
                    if let Some(batch) = batcher.tick() {
                        // Total dispatch failure restores the rows; the
                        // next drain() surfaces the error.
                        let _ = self.dispatch_locked(mi, pi, t, &mut batcher, batch);
                    }
                }
            }
        }
    }

    /// Rebuild the admitted-row ledgers from ground truth. Only exact
    /// at a quiescent point (nothing in flight): pending lane rows are
    /// the whole tenant backlog and the port counters are settled —
    /// which is exactly when `drain` calls it, repairing whatever a
    /// dead worker's write-off left dangling.
    fn recount_loads(&self) {
        for shard in &self.models {
            let mut queued = vec![0usize; self.tenants.len()];
            for pool in &shard.pools {
                let mut pool_rows = 0usize;
                for (t, lane) in pool.lanes.iter().enumerate() {
                    let pending = relock(&lane.batcher).pending_rows();
                    queued[t] += pending;
                    pool_rows += pending;
                }
                pool_rows += pool
                    .port_loads
                    .iter()
                    .map(|l| l.load(Ordering::SeqCst))
                    .sum::<usize>();
                pool.load_rows.store(pool_rows, Ordering::SeqCst);
            }
            for (t, rows) in queued.iter().enumerate() {
                shard.tenant_queued[t].store(*rows, Ordering::SeqCst);
            }
        }
    }

    fn total_in_flight(&self) -> usize {
        self.models
            .iter()
            .flat_map(|s| s.pools.iter())
            .map(|p| p.in_flight.load(Ordering::SeqCst))
            .sum()
    }
}

/// The running fleet.
pub struct Fleet {
    pub(crate) shared: Arc<FleetShared>,
    rx_done: Receiver<Done>,
    /// Respawn sender, kept for [`Fleet::revive_worker`] (also keeps
    /// `rx_done` connected while every worker is dead).
    tx_done: Sender<Done>,
    /// Worker join handles, `[model][pool][slot]`.
    workers: Vec<Vec<Vec<JoinHandle<()>>>>,
    deadline_thread: Option<JoinHandle<()>>,
}

impl Fleet {
    /// Validate the deployment and spawn every pool's PE workers plus
    /// one deadline thread (ticking at half the shortest configured
    /// deadline). Each (model, tenant) governor starts as the tenant
    /// class's [`SloPolicy`] armed with that model's certified costs;
    /// [`Fleet::install_policy`] can replace any of them.
    pub fn start(cfg: FleetConfig) -> Result<Fleet, ServeError> {
        if cfg.models.is_empty() {
            return Err(ServeError::InvalidConfig { what: "fleet has no models" });
        }
        if cfg.tenants.is_empty() {
            return Err(ServeError::InvalidConfig { what: "fleet has no tenant classes" });
        }
        for mc in &cfg.models {
            mc.pool.validate()?;
            if mc.n_pools == 0 {
                return Err(ServeError::InvalidConfig {
                    what: "n_pools == 0 (a model needs at least one PE pool)",
                });
            }
        }
        let (tx_done, rx_done) = channel::<Done>();
        let tenants: Vec<TenantState> = cfg
            .tenants
            .into_iter()
            .map(|class| TenantState {
                metrics: Arc::new(TenantMetrics::named(class.name.clone())),
                class,
            })
            .collect();
        let tenant_metrics: Vec<Arc<TenantMetrics>> =
            tenants.iter().map(|t| Arc::clone(&t.metrics)).collect();
        let mut priority_order: Vec<usize> = (0..tenants.len()).collect();
        priority_order.sort_by_key(|&i| tenants[i].class.priority);
        let mut models = vec![];
        let mut workers = vec![];
        let mut worker_base = 0usize;
        let mut min_deadline = Duration::MAX;
        for (mi, mc) in cfg.models.into_iter().enumerate() {
            min_deadline = min_deadline.min(mc.pool.deadline);
            let names: Vec<String> =
                mc.model.variants().iter().map(|v| v.name().to_string()).collect();
            let metrics = Arc::new(Metrics::with_variant_names(&names));
            let cost = Arc::new(mc.cost);
            let certified = CertifiedCosts::from_model(&mc.model, &cost);
            let quanta: Vec<usize> =
                mc.model.variants().iter().map(|v| v.batch_quantum()).collect();
            let tenant_queued: Arc<Vec<AtomicUsize>> =
                Arc::new((0..tenants.len()).map(|_| AtomicUsize::new(0)).collect());
            let mut pools = vec![];
            let mut model_workers = vec![];
            for pi in 0..mc.n_pools {
                let pool_load = Arc::new(AtomicUsize::new(0));
                let ctx = WorkerCtx {
                    model_idx: mi,
                    pool_idx: pi,
                    model: Arc::clone(&mc.model),
                    cost: Arc::clone(&cost),
                    metrics: Arc::clone(&metrics),
                    tenant_metrics: tenant_metrics.clone(),
                    tenant_queued: Arc::clone(&tenant_queued),
                    pool_load: Arc::clone(&pool_load),
                    tx_done: tx_done.clone(),
                    queue_depth: mc.pool.queue_depth,
                };
                let mut ports = vec![];
                let mut port_loads = vec![];
                let mut pool_workers = vec![];
                for _slot in 0..mc.pool.n_pes {
                    let outstanding_rows = Arc::new(AtomicUsize::new(0));
                    let outstanding_batches = Arc::new(AtomicUsize::new(0));
                    port_loads.push(Arc::clone(&outstanding_rows));
                    let (port, handle) =
                        spawn_worker(&ctx, outstanding_rows, outstanding_batches);
                    ports.push(port);
                    pool_workers.push(handle);
                }
                let lanes: Vec<Lane> = tenants
                    .iter()
                    .map(|t| {
                        let target =
                            t.class.target_rows.unwrap_or(mc.pool.target_rows);
                        let mut batcher = Batcher::new(target, 2);
                        batcher.set_quantum(quanta[0]);
                        Lane { batcher: Mutex::new(batcher) }
                    })
                    .collect();
                pools.push(PoolCore {
                    lanes,
                    router: Mutex::new(Router {
                        ports,
                        policy: mc.pool.policy,
                        next_rr: 0,
                    }),
                    in_flight: AtomicUsize::new(0),
                    port_loads,
                    load_rows: pool_load,
                    worker_base,
                });
                worker_base += mc.pool.n_pes;
                model_workers.push(pool_workers);
            }
            let govs: Vec<TenantGov> = tenants
                .iter()
                .map(|t| TenantGov {
                    state: Mutex::new(GovernorState {
                        policy: Box::new(t.class.policy(certified.clone())),
                        last_snap: TenantSnapshot::empty(),
                    }),
                    active_variant: AtomicUsize::new(0),
                })
                .collect();
            models.push(ModelShard {
                input_width: mc.model.input_width(),
                in_half: 1i64 << (mc.model.in_bits() - 1),
                model: mc.model,
                cost,
                metrics,
                certified,
                quanta,
                pools,
                govs,
                tenant_queued,
                queue_depth: mc.pool.queue_depth,
            });
            workers.push(model_workers);
        }
        let shared = Arc::new(FleetShared {
            models,
            tenants,
            priority_order,
            stop_deadline: AtomicBool::new(false),
        });
        // Deadline thread: tick at half the shortest deadline so every
        // model's stragglers flush within (0.5, 1.0]× its own deadline.
        let tick_period = (min_deadline / 2).max(Duration::from_micros(200));
        let shared_bg = Arc::clone(&shared);
        let deadline_thread = std::thread::spawn(move || {
            while !shared_bg.stop_deadline.load(Ordering::Acquire) {
                std::thread::park_timeout(tick_period);
                shared_bg.tick_all();
            }
        });
        Ok(Fleet {
            shared,
            rx_done,
            tx_done,
            workers,
            deadline_thread: Some(deadline_thread),
        })
    }

    /// Hosted model count.
    pub fn n_models(&self) -> usize {
        self.shared.models.len()
    }

    /// Tenant class count.
    pub fn n_tenants(&self) -> usize {
        self.shared.tenants.len()
    }

    /// Model `m`'s serving metrics (per-variant billing buckets).
    pub fn model_metrics(&self, m: usize) -> Arc<Metrics> {
        Arc::clone(&self.shared.models[m].metrics)
    }

    /// Tenant `t`'s fleet-wide metrics bucket.
    pub fn tenant_metrics(&self, t: usize) -> Arc<TenantMetrics> {
        Arc::clone(&self.shared.tenants[t].metrics)
    }

    /// Tenant `t`'s SLO class.
    pub fn tenant_class(&self, t: usize) -> &SloClass {
        &self.shared.tenants[t].class
    }

    /// Model `m`'s certified per-variant costs (the figures admission
    /// prices its drain estimates with).
    pub fn certified_costs(&self, m: usize) -> &CertifiedCosts {
        &self.shared.models[m].certified
    }

    /// Replace the governor of one (model, tenant) pair.
    pub fn install_policy(
        &self,
        model: usize,
        tenant: usize,
        policy: Box<dyn GovernorPolicy>,
    ) -> Result<(), ServeError> {
        let shard = self
            .shared
            .models
            .get(model)
            .ok_or(ServeError::UnknownModel { model })?;
        let gov = shard
            .govs
            .get(tenant)
            .ok_or(ServeError::UnknownTenant { tenant })?;
        lock_or(&gov.state, "governor")?.policy = policy;
        Ok(())
    }

    /// The variant the (model, tenant) governor chose at its most
    /// recent dispatch (observability; per-batch billing follows each
    /// batch's own tag).
    pub fn active_variant(&self, model: usize, tenant: usize) -> usize {
        self.shared.models[model].govs[tenant]
            .active_variant
            .load(Ordering::Relaxed)
    }

    /// Admit a request for (`model`, `tenant`): validate its shape and
    /// Q-range against the model, apply the tenant's certified-drain
    /// admission budget, then enqueue it in the tenant's lane of the
    /// least-loaded pool (dispatching immediately if the lane's target
    /// fills). Never blocks on execution.
    pub fn submit(&self, model: usize, tenant: usize, req: Request) -> Result<(), ServeError> {
        let shard = self
            .shared
            .models
            .get(model)
            .ok_or(ServeError::UnknownModel { model })?;
        let tstate = self
            .shared
            .tenants
            .get(tenant)
            .ok_or(ServeError::UnknownTenant { tenant })?;
        validate(shard, &req)?;
        // Admission control (DESIGN.md §17): price the tenant's
        // *already-admitted* backlog at the variant its governor is
        // currently running; if the certified drain time breaches the
        // class budget, refuse the new work with a typed Shed. The
        // incoming rows are not counted — an idle tenant's first
        // request always lands.
        let queued = shard.tenant_queued[tenant].load(Ordering::SeqCst);
        let v = self.active_variant(model, tenant).min(shard.quanta.len() - 1);
        let est = shard.certified.est_drain_ns(queued, v);
        let budget = tstate.class.drain_budget_ns();
        if est > budget {
            tstate.metrics.note_shed(req.rows.len() as u64);
            return Err(ServeError::Shed {
                tenant,
                reason: format!(
                    "certified drain of {queued} queued rows at variant {v} is \
                     {est} ns, over class '{}' budget {budget} ns",
                    tstate.class.name
                ),
            });
        }
        // Least-outstanding-rows across the model's pools.
        let pi = shard
            .pools
            .iter()
            .enumerate()
            .min_by_key(|(_, p)| p.load_rows.load(Ordering::SeqCst))
            .map(|(i, _)| i)
            .unwrap_or(0);
        let pool = &shard.pools[pi];
        // Lock the lane before touching the ledgers: a poisoned lane
        // refuses the request with the counters untouched.
        let mut batcher = lock_or(&pool.lanes[tenant].batcher, "batcher")?;
        let rows = req.rows.len();
        shard.tenant_queued[tenant].fetch_add(rows, Ordering::SeqCst);
        pool.load_rows.fetch_add(rows, Ordering::SeqCst);
        shard.metrics.note_submit();
        tstate.metrics.note_submit();
        match batcher.push(TrackedRequest::now(req)) {
            Some(batch) => {
                self.shared
                    .dispatch_locked(model, pi, tenant, &mut batcher, batch)
            }
            None => Ok(()),
        }
    }

    /// Drive one deadline tick synchronously — deterministic tests and
    /// closed-loop simulations tick here instead of sleeping against
    /// the background thread.
    pub fn tick_now(&self) {
        self.shared.tick_all();
    }

    /// Collect every already-completed response without blocking.
    /// Responses arrive in completion order from whichever pool
    /// finished them; sorted by request id for the caller.
    pub fn try_collect(&mut self) -> Vec<Response> {
        let mut out = vec![];
        while let Ok(d) = self.rx_done.try_recv() {
            self.shared.models[d.model].pools[d.pool]
                .in_flight
                .fetch_sub(1, Ordering::SeqCst);
            out.extend(d.responses);
        }
        out.sort_by_key(|r| r.id);
        out
    }

    /// As [`Fleet::try_collect`], but waits up to `wait` for the first
    /// completion before draining the rest non-blocking.
    pub fn collect_timeout(&mut self, wait: Duration) -> Vec<Response> {
        let mut out = vec![];
        if let Ok(d) = self.rx_done.recv_timeout(wait) {
            self.shared.models[d.model].pools[d.pool]
                .in_flight
                .fetch_sub(1, Ordering::SeqCst);
            out.extend(d.responses);
        }
        out.extend(self.try_collect());
        out.sort_by_key(|r| r.id);
        out
    }

    /// Rows batched in some lane but not yet dispatched (waiting on a
    /// fill target or the deadline). Observability must survive a
    /// poisoned lock.
    pub fn pending_rows(&self) -> usize {
        self.shared
            .models
            .iter()
            .flat_map(|s| s.pools.iter())
            .flat_map(|p| p.lanes.iter())
            .map(|l| relock(&l.batcher).pending_rows())
            .sum()
    }

    /// Fault injection / rolling restart: stop worker `idx` of pool
    /// `pi` of model `mi` after it finishes its queued work. Routing
    /// avoids it immediately; its in-queue work still completes and is
    /// collected by `drain`.
    pub fn kill_worker(&mut self, mi: usize, pi: usize, idx: usize) {
        let Some(shard) = self.shared.models.get(mi) else { return };
        let Some(pool) = shard.pools.get(pi) else { return };
        let tx = {
            let mut router = relock(&pool.router);
            match router.ports.get_mut(idx) {
                Some(port) => {
                    port.alive = false;
                    port.tx.clone()
                }
                None => return,
            }
        };
        // Deliver Stop without holding the router lock and without
        // blocking the caller: behind a full queue the send parks on a
        // helper thread until the worker drains its backlog.
        std::thread::spawn(move || {
            let _ = tx.send(WorkerMsg::Stop);
        });
    }

    /// Rolling-restart companion of [`Fleet::kill_worker`]: respawn a
    /// dead PE in its slot — fresh thread, fresh bounded queue, same
    /// outstanding-work counters — and re-arm routing to it. Returns
    /// `false` (and does nothing) for an out-of-range slot or a worker
    /// that is still alive; a killed worker is first joined, so any
    /// work still in its old queue completes and is collected before
    /// the replacement takes over.
    pub fn revive_worker(&mut self, mi: usize, pi: usize, idx: usize) -> bool {
        let Some(shard) = self.shared.models.get(mi) else { return false };
        let Some(pool) = shard.pools.get(pi) else { return false };
        if idx >= self.workers[mi][pi].len() {
            return false;
        }
        {
            let router = relock(&pool.router);
            if router.ports[idx].alive {
                return false;
            }
        }
        let ctx = WorkerCtx {
            model_idx: mi,
            pool_idx: pi,
            model: Arc::clone(&shard.model),
            cost: Arc::clone(&shard.cost),
            metrics: Arc::clone(&shard.metrics),
            tenant_metrics: self
                .shared
                .tenants
                .iter()
                .map(|t| Arc::clone(&t.metrics))
                .collect(),
            tenant_queued: Arc::clone(&shard.tenant_queued),
            pool_load: Arc::clone(&pool.load_rows),
            tx_done: self.tx_done.clone(),
            queue_depth: shard.queue_depth,
        };
        // The old incarnation exits once its queued work (and the
        // pending Stop) drains; joining here is what makes "revive"
        // safe — two workers never share a slot.
        let (mut port, handle) = spawn_worker(&ctx, Arc::clone(&pool.port_loads[idx]), {
            let router = relock(&pool.router);
            Arc::clone(&router.ports[idx].outstanding_batches)
        });
        let old = std::mem::replace(&mut self.workers[mi][pi][idx], handle);
        let _ = old.join();
        // Install the new port only after the old worker is gone: its
        // leftover counters were either drained by the worker itself or
        // written off by `drain`.
        let mut router = relock(&pool.router);
        std::mem::swap(&mut router.ports[idx], &mut port);
        // `port` now holds the dead incarnation's channel; dropping it
        // closes that queue for good.
        true
    }

    /// Flush every lane (class priority order) and wait for every
    /// response. On failure the error still carries whatever responses
    /// could be collected — completed work is never stranded behind an
    /// error.
    pub fn drain(&mut self) -> Result<Vec<Response>, ServeError> {
        // Collect in-flight work even if a flush finds no live workers
        // or a poisoned lane: earlier batches may already have
        // completed, and the other lanes must still flush.
        let mut flush_err: Option<ServeError> = None;
        for (mi, shard) in self.shared.models.iter().enumerate() {
            for (pi, pool) in shard.pools.iter().enumerate() {
                for &t in &self.shared.priority_order {
                    let res = match lock_or(&pool.lanes[t].batcher, "batcher") {
                        Ok(mut batcher) => match batcher.flush() {
                            Some(batch) => self
                                .shared
                                .dispatch_locked(mi, pi, t, &mut batcher, batch),
                            None => Ok(()),
                        },
                        Err(e) => Err(e),
                    };
                    if let Err(e) = res {
                        flush_err.get_or_insert(e);
                    }
                }
            }
        }
        let mut out = vec![];
        let mut lost_workers: Vec<usize> = vec![];
        let mut lost_rows = 0usize;
        while self.shared.total_in_flight() > 0 {
            match self.rx_done.recv_timeout(Duration::from_millis(50)) {
                Ok(d) => {
                    self.shared.models[d.model].pools[d.pool]
                        .in_flight
                        .fetch_sub(1, Ordering::SeqCst);
                    out.extend(d.responses);
                }
                // Disconnected is unreachable while the fleet holds its
                // respawn sender (kept for `revive_worker`); both arms
                // mean "no response right now" — write off work held by
                // exited workers and keep collecting. The loop ends
                // when every pool's `in_flight` reaches zero: every
                // dispatched batch is either answered on `rx_done` or
                // counted in some port's outstanding batches.
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {
                    self.write_off(&mut lost_workers, &mut lost_rows);
                }
            }
        }
        // Quiescent: repair the admission ledgers (a write-off zeroed
        // port counters without crediting tenants' queued rows).
        self.shared.recount_loads();
        out.sort_by_key(|r| r.id);
        if !lost_workers.is_empty() {
            return Err(ServeError::WorkerLost {
                workers: lost_workers,
                lost_rows,
                recovered: out,
            });
        }
        match flush_err {
            Some(ServeError::LockPoisoned { what, .. }) => {
                Err(ServeError::LockPoisoned { what, recovered: out })
            }
            Some(_) => Err(ServeError::NoLiveWorkers { recovered: out }),
            None => Ok(out),
        }
    }

    /// Write off work held by workers that exited without answering.
    /// Worker ids in `lost_workers` are fleet-wide flat slot indices
    /// (pool `worker_base` + slot).
    fn write_off(&self, lost_workers: &mut Vec<usize>, lost_rows: &mut usize) {
        for (mi, shard) in self.shared.models.iter().enumerate() {
            for (pi, pool) in shard.pools.iter().enumerate() {
                let mut router = relock(&pool.router);
                for (i, port) in router.ports.iter_mut().enumerate() {
                    if !self.workers[mi][pi][i].is_finished() {
                        continue;
                    }
                    port.alive = false;
                    let batches = port.outstanding_batches.swap(0, Ordering::SeqCst);
                    if batches == 0 {
                        continue;
                    }
                    let rows = port.outstanding_rows.swap(0, Ordering::SeqCst);
                    pool.in_flight.fetch_sub(batches, Ordering::SeqCst);
                    shard
                        .metrics
                        .dropped_rows
                        .fetch_add(rows as u64, Ordering::Relaxed);
                    lost_workers.push(pool.worker_base + i);
                    *lost_rows += rows;
                }
            }
        }
    }

    /// Stop the deadline thread and every worker, then join them.
    pub fn shutdown(mut self) {
        self.shared.stop_deadline.store(true, Ordering::Release);
        if let Some(t) = self.deadline_thread.take() {
            t.thread().unpark();
            let _ = t.join();
        }
        for shard in &self.shared.models {
            for pool in &shard.pools {
                let router = relock(&pool.router);
                for port in &router.ports {
                    // Blocking send so Stop lands even behind a full
                    // queue; a dead worker just returns SendError.
                    let _ = port.tx.send(WorkerMsg::Stop);
                }
            }
        }
        for model_workers in self.workers.drain(..) {
            for pool_workers in model_workers {
                for w in pool_workers {
                    let _ = w.join();
                }
            }
        }
    }
}

/// Submit-time request validation against one model shard.
fn validate(shard: &ModelShard, req: &Request) -> Result<(), ServeError> {
    let invalid = |reason: String| ServeError::InvalidRequest { id: req.id, reason };
    if req.rows.is_empty() {
        return Err(invalid("request has no rows".to_string()));
    }
    for (i, row) in req.rows.iter().enumerate() {
        if row.len() != shard.input_width {
            return Err(invalid(format!(
                "row {i} width {} != model input width {}",
                row.len(),
                shard.input_width
            )));
        }
        if let Some(&v) = row.iter().find(|&&v| v < -shard.in_half || v >= shard.in_half) {
            return Err(invalid(format!(
                "row {i} value {v} outside Q range [{}, {})",
                -shard.in_half, shard.in_half
            )));
        }
    }
    Ok(())
}

/// Everything one PE worker thread owns beyond its receive queue.
struct WorkerState {
    model_idx: usize,
    pool_idx: usize,
    engine: PackedEngine,
    done: Sender<Done>,
    metrics: Arc<Metrics>,
    tenant_metrics: Vec<Arc<TenantMetrics>>,
    tenant_queued: Arc<Vec<AtomicUsize>>,
    pool_load: Arc<AtomicUsize>,
    cost: Arc<CostTable>,
    outstanding_rows: Arc<AtomicUsize>,
    outstanding_batches: Arc<AtomicUsize>,
}

fn worker_loop(w: WorkerState, rx: Receiver<WorkerMsg>) {
    // Steady-state serving allocates nothing in the engine: the worker
    // owns one EngineScratch plus gather/output buffers for its whole
    // lifetime, warmed by the first batch and reused across requests
    // (DESIGN.md §11). Only the Response assembly below allocates.
    // Under `--features simd` the engine picks the host-vector backend
    // inside `forward_batch_into` with no scratch-shape change
    // (DESIGN.md §16).
    let mut scratch = EngineScratch::new();
    let mut logits: Vec<Vec<i64>> = Vec::new();
    let mut rows_buf: Vec<Vec<i64>> = Vec::new();
    while let Ok(msg) = rx.recv() {
        let batch = match msg {
            WorkerMsg::Work(b) => b,
            WorkerMsg::Stop => break,
        };
        let t0 = Instant::now();
        // The variant this batch was tagged with at dispatch is the
        // variant that executes — and the variant that gets billed.
        let variant = batch.variant.min(w.engine.model().n_variants() - 1);
        let in_shift = w.engine.model().variant(variant).in_shift();
        // Batches are tenant-homogeneous (lanes are per-tenant): the
        // whole batch bills one tenant bucket.
        let tenant = batch.tenant.min(w.tenant_metrics.len() - 1);
        // Gather rows into the reusable buffer (rows keep their
        // capacity; `n_rows` tracks the live prefix), requantizing
        // reference-precision request values into the executing
        // variant's first-layer format (arithmetic right shift — the
        // per-variant oracle applies the same transform), run packed,
        // scatter back per request.
        let mut n_rows = 0usize;
        for entry in &batch.entries {
            for row in &entry.req.rows {
                if n_rows == rows_buf.len() {
                    rows_buf.push(Vec::new());
                }
                rows_buf[n_rows].clear();
                if in_shift == 0 {
                    rows_buf[n_rows].extend_from_slice(row);
                } else {
                    rows_buf[n_rows].extend(row.iter().map(|&v| v >> in_shift));
                }
                n_rows += 1;
            }
        }
        let stats = w.engine.forward_batch_into(
            &rows_buf[..n_rows],
            variant,
            &mut scratch,
            &mut logits,
        );
        let ns = t0.elapsed().as_nanos() as u64;
        // Exact per-format billing: with a mixed-precision schedule the
        // layers run at different widths, so the worker hands the cost
        // table the by-format cycle breakdown, not one format — and the
        // whole batch lands in the executed variant's metrics bucket
        // AND the executing tenant's.
        let pj = w.cost.batch_energy_pj(&stats);
        // The static cost certificate's prediction for this batch,
        // priced through the same table (DESIGN.md §15). Zero-skipping
        // makes the dense certificate an upper bound, so the exact
        // prediction conditions on the batch's own skip counters
        // (DESIGN.md §18) — predicted equals measured to the attojoule
        // again, at any sparsity.
        let predicted_pj = w.cost.batch_energy_pj(
            &w.engine
                .model()
                .cost_certificate(variant)
                .eval_stats_with_skips(n_rows, &stats),
        );
        w.metrics
            .add_batch_predicted(n_rows as u64, variant, stats, pj, predicted_pj, ns);
        w.tenant_metrics[tenant].add_rows(n_rows as u64, pj, ns);
        w.tenant_metrics[tenant].add_s1_split(stats.s1_cycles, stats.skipped_cycles);
        let mut responses = vec![];
        let mut offset = 0;
        for entry in &batch.entries {
            let n = entry.req.rows.len();
            responses.push(Response {
                id: entry.req.id,
                model: w.model_idx,
                tenant,
                logits: logits[offset..offset + n].to_vec(),
                variant,
            });
            offset += n;
            let lat = entry.submitted_at.elapsed().as_nanos() as u64;
            w.metrics.observe_latency_ns(lat);
            w.tenant_metrics[tenant].observe_latency_ns(lat);
        }
        w.outstanding_rows.fetch_sub(batch.rows, Ordering::SeqCst);
        w.outstanding_batches.fetch_sub(1, Ordering::SeqCst);
        // The admission ledgers floor at zero: a drain-time write-off
        // may already have credited these rows.
        sat_sub(&w.tenant_queued[tenant], batch.rows);
        sat_sub(&w.pool_load, batch.rows);
        if w.done
            .send(Done {
                model: w.model_idx,
                pool: w.pool_idx,
                responses,
            })
            .is_err()
        {
            break; // leader gone
        }
    }
}
