"""AOT compilation: lower the L2/L1 JAX graphs to HLO **text** and emit
the cross-language golden vectors.

HLO text (not `.serialize()`) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Outputs (all under `artifacts/`):
  mul.hlo.txt      packed Soft SIMD multiply (Pallas kernel), u64[256]
                   words × runtime digit plan × runtime format masks
  mlp.hlo.txt      quantized MLP forward (Pallas layer kernels),
                   int32[16, 64] → int32[16, 16]
  golden.txt       cross-language golden vectors (swar / mul / repack / mlp)
  mlp_weights.txt  per-layer raw Q1.7 weights for the Rust coordinator
  manifest.txt     artifact shapes and metadata

Run: `python -m compile.aot --out-dir ../artifacts` (from `python/`).
"""

from __future__ import annotations

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import defs, model
from .kernels import ref, softsimd

MUL_WORDS = 256  # one MUL_BLOCK


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: default printing elides big literals as `constant({...})`,
    # which the text parser silently reads back as zeros — the MLP's baked
    # digit-plan tensors would vanish. Print full constants.
    mod = xc._xla.HloModule.from_serialized_hlo_module_proto(
        comp.as_serialized_hlo_module_proto()
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # jax 0.8 metadata carries attributes (source_end_line, …) the 0.5.1
    # text parser rejects; strip it.
    opts.print_metadata = False
    return mod.to_string(opts)


# --------------------------------------------------------------------------
# Artifact 1: packed multiply
# --------------------------------------------------------------------------


def lower_mul() -> str:
    def fn(x_words, shifts, signs, h_mask, l_mask):
        return (softsimd.mul_packed_pallas(x_words, shifts, signs, h_mask, l_mask),)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((MUL_WORDS,), jnp.uint64),
        jax.ShapeDtypeStruct((defs.OPS_MAX,), jnp.int32),
        jax.ShapeDtypeStruct((defs.OPS_MAX,), jnp.int32),
        jax.ShapeDtypeStruct((1,), jnp.uint64),
        jax.ShapeDtypeStruct((1,), jnp.uint64),
    )
    return to_hlo_text(lowered)


# --------------------------------------------------------------------------
# Artifact 2: MLP forward
# --------------------------------------------------------------------------


def lower_mlp(layers) -> str:
    def fn(x_q):
        return (model.mlp_forward_pallas(x_q, layers),)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((model.BATCH, model.IN_DIM), jnp.int32)
    )
    return to_hlo_text(lowered)


# --------------------------------------------------------------------------
# Golden vectors
# --------------------------------------------------------------------------


def emit_golden(layers, path: str) -> None:
    rng = model.XorShift(0x601D_E27A)
    lines = []

    def word(fmt: defs.SimdFormat) -> int:
        return rng.next_u64() & defs.WORD_MASK

    # SWAR primitive vectors (plain-int semantics from defs → rust must match).
    import_ref_np = lambda w: jnp.asarray(np.uint64(w))
    for fmt_bits in defs.FORMATS:
        fmt = defs.SimdFormat(fmt_bits)
        h, l = fmt.msb_mask, fmt.lsb_mask
        for _ in range(20):
            a, c = word(fmt), word(fmt)
            add = int(ref.swar_add(import_ref_np(a), import_ref_np(c), jnp.uint64(h)))
            sub = int(ref.swar_sub(import_ref_np(a), import_ref_np(c), jnp.uint64(h), jnp.uint64(l)))
            lines.append(f"swar add {fmt_bits} {a:#x} {c:#x} 0 {add:#x}")
            lines.append(f"swar sub {fmt_bits} {a:#x} {c:#x} 0 {sub:#x}")
            for k in (1, 2, 3):
                sar = int(ref.swar_sar(import_ref_np(a), k, jnp.uint64(h)))
                asar = int(ref.swar_add_sar(import_ref_np(a), import_ref_np(c), k, jnp.uint64(h)))
                ssar = int(ref.swar_sub_sar(import_ref_np(a), import_ref_np(c), k, jnp.uint64(h), jnp.uint64(l)))
                lines.append(f"swar sar {fmt_bits} {a:#x} 0x0 {k} {sar:#x}")
                lines.append(f"swar addsar {fmt_bits} {a:#x} {c:#x} {k} {asar:#x}")
                lines.append(f"swar subsar {fmt_bits} {a:#x} {c:#x} {k} {ssar:#x}")

    # Packed multiply vectors (per format × multiplier width).
    for fmt_bits in defs.FORMATS:
        fmt = defs.SimdFormat(fmt_bits)
        for y_bits in (4, 8, fmt_bits):
            half = 1 << (y_bits - 1)
            for _ in range(30):
                x = word(fmt)
                m = defs.sign_extend(rng.next_u64(), y_bits)
                out_lanes = [
                    defs.mul_scalar(v, m, fmt_bits, y_bits) for v in defs.unpack(x, fmt)
                ]
                out = defs.pack(out_lanes, fmt)
                lines.append(f"mul {fmt_bits} {y_bits} {m} {x:#x} {out:#x}")

    # Repack vectors (all ordered format pairs).
    for fb in defs.FORMATS:
        for tb in defs.FORMATS:
            if fb == tb:
                continue
            fmt = defs.SimdFormat(fb)
            count = fmt.lanes * 2
            vals = [defs.sign_extend(rng.next_u64(), fb) for _ in range(count)]
            words = defs.pack_stream(vals, fmt)
            out = defs.repack_stream(words, fb, tb, count)
            iw = ",".join(f"{w:#x}" for w in words)
            ow = ",".join(f"{w:#x}" for w in out)
            lines.append(f"repack {fb} {tb} {count} {iw} {ow}")

    # MLP vectors: the batch the artifact will be checked with.
    templates = model.class_templates()
    xs, ys = model.sample_batch(templates, model.BATCH)
    x_q = model.quantize_inputs(xs)
    logits = model.mlp_forward_int(x_q, layers)
    for b in range(model.BATCH):
        row_in = ",".join(str(int(v)) for v in x_q[b])
        row_out = ",".join(str(int(v)) for v in logits[b])
        lines.append(f"mlp_in {b} {row_in}")
        lines.append(f"mlp_out {b} {row_out}")
        lines.append(f"mlp_label {b} {int(ys[b])}")

    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def emit_weights(layers, path: str) -> None:
    with open(path, "w") as f:
        for idx, layer in enumerate(layers):
            k, n = layer.w_raw.shape
            f.write(f"layer {idx} {k} {n}\n")
            for i in range(k):
                f.write(",".join(str(int(v)) for v in layer.w_raw[i]) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    layers = model.build_layers()

    mul_hlo = lower_mul()
    with open(os.path.join(args.out_dir, "mul.hlo.txt"), "w") as f:
        f.write(mul_hlo)
    print(f"mul.hlo.txt: {len(mul_hlo)} chars")

    mlp_hlo = lower_mlp(layers)
    with open(os.path.join(args.out_dir, "mlp.hlo.txt"), "w") as f:
        f.write(mlp_hlo)
    print(f"mlp.hlo.txt: {len(mlp_hlo)} chars")

    emit_golden(layers, os.path.join(args.out_dir, "golden.txt"))
    emit_weights(layers, os.path.join(args.out_dir, "mlp_weights.txt"))

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write(
            "\n".join(
                [
                    f"mul_words={MUL_WORDS}",
                    f"ops_max={defs.OPS_MAX}",
                    f"mlp_batch={model.BATCH}",
                    f"mlp_in={model.IN_DIM}",
                    f"mlp_hidden={model.HIDDEN}",
                    f"mlp_out={model.OUT_PAD}",
                    f"mlp_classes={model.CLASSES}",
                    f"in_bits={model.IN_BITS}",
                    f"acc_bits={model.ACC_BITS}",
                    "",
                ]
            )
        )
    print("golden.txt, mlp_weights.txt, manifest.txt written")


if __name__ == "__main__":
    main()
