//! Fig. 8 — energy (pJ) per sub-word multiplication for selected
//! configurations (4×4, 8×8, 16×16) across synthesis timing constraints.

use crate::anyhow;
use crate::energy::model::SynthesizedSoftPipeline;
use crate::energy::report::{pj, table};
use crate::energy::tech::MHZ_POINTS;
use crate::hardsimd::pipeline::{HardSimdPipeline, HARD_FLEX, HARD_TWO};
use crate::workload::synth::XorShift64;

pub const N_WORDS: usize = 300;

/// One figure point.
#[derive(Debug, Clone)]
pub struct Point {
    pub design: String,
    pub mhz: f64,
    pub x_bits: u32,
    pub y_bits: u32,
    pub pj_per_subword: Option<f64>,
}

pub fn points() -> Vec<Point> {
    let mut out = vec![];
    for &mhz in &MHZ_POINTS {
        let mut soft = SynthesizedSoftPipeline::new(mhz);
        let mut flex = HardSimdPipeline::new(HARD_FLEX, mhz);
        let mut two = HardSimdPipeline::new(HARD_TWO, mhz);
        let mut rng = XorShift64::new(0xF16_8);
        for &(x, y) in &[(4u32, 4u32), (8, 8), (16, 16)] {
            out.push(Point {
                design: "Soft SIMD".into(),
                mhz,
                x_bits: x,
                y_bits: y,
                pj_per_subword: soft.subword_mult_energy_pj(x, y, N_WORDS, &mut rng),
            });
            out.push(Point {
                design: "Hard SIMD (4,6,8,12,16)".into(),
                mhz,
                x_bits: x,
                y_bits: y,
                pj_per_subword: flex.subword_mult_energy_pj(x, y, N_WORDS, &mut rng),
            });
            out.push(Point {
                design: "Hard SIMD (8,16)".into(),
                mhz,
                x_bits: x,
                y_bits: y,
                pj_per_subword: two.subword_mult_energy_pj(x, y, N_WORDS, &mut rng),
            });
        }
    }
    out
}

pub fn run() -> anyhow::Result<()> {
    println!("== Fig. 8: energy per sub-word multiplication (pJ) ==");
    let pts = points();
    let rows: Vec<Vec<String>> = pts
        .iter()
        .map(|p| {
            vec![
                p.design.clone(),
                format!("{} MHz", p.mhz),
                format!("{}x{}", p.x_bits, p.y_bits),
                p.pj_per_subword.map(pj).unwrap_or_else(|| "-".into()),
            ]
        })
        .collect();
    println!("{}", table(&["design", "constraint", "config", "pJ/mult"], &rows));
    println!(
        "(paper: Soft SIMD wins for widths < 8 bits; flexibility costs the\n\
         Hard SIMD baselines energy at every width — see DESIGN.md §5 for\n\
         the measured-vs-paper discussion)\n"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_shape() {
        let pts = points();
        let find = |d: &str, mhz: f64, x: u32| {
            pts.iter()
                .find(|p| p.design.starts_with(d) && p.mhz == mhz && p.x_bits == x)
                .and_then(|p| p.pj_per_subword)
                .unwrap()
        };
        for &mhz in &MHZ_POINTS {
            // Soft wins clearly at small widths against both baselines.
            assert!(find("Soft", mhz, 4) < 0.5 * find("Hard SIMD (4", mhz, 4));
            assert!(find("Soft", mhz, 4) < 0.5 * find("Hard SIMD (8", mhz, 4));
            // Energy grows with operand width for every design.
            for d in ["Soft", "Hard SIMD (4", "Hard SIMD (8"] {
                assert!(find(d, mhz, 4) < find(d, mhz, 8));
                assert!(find(d, mhz, 8) < find(d, mhz, 16));
            }
        }
    }
}
