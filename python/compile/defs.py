"""Pinned Soft SIMD semantics — Python mirror of `rust/src/{bits,csd}`.

Every constant and algorithm here is bit-identical to the Rust side
(DESIGN.md §4); the cross-language golden vectors emitted by `aot.py`
hold both sides to it. Plain-int implementations only (host/build time).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

DATAPATH_BITS = 48
WORD_MASK = (1 << DATAPATH_BITS) - 1
FORMATS = (4, 6, 8, 12, 16)
MAX_SHIFT = 3
# Maximum multiply-plan length: a 16-bit multiplier retires ≤16 positions,
# one op each in the worst (max_shift=1-equivalent) CSD layout, +1 slack.
OPS_MAX = 17


# --------------------------------------------------------------------------
# Formats and masks
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class SimdFormat:
    bits: int

    def __post_init__(self):
        if self.bits not in FORMATS:
            raise ValueError(f"unsupported sub-word width {self.bits}")

    @property
    def lanes(self) -> int:
        return DATAPATH_BITS // self.bits

    def repeat(self, pattern: int) -> int:
        out = 0
        for i in range(0, DATAPATH_BITS, self.bits):
            out |= pattern << i
        return out & WORD_MASK

    @property
    def msb_mask(self) -> int:
        return self.repeat(1 << (self.bits - 1))

    @property
    def lsb_mask(self) -> int:
        return self.repeat(1)

    def keep_mask(self, k: int) -> int:
        assert 1 <= k <= MAX_SHIFT < self.bits
        return self.repeat((1 << (self.bits - k)) - 1)


def sign_extend(x: int, bits: int) -> int:
    x &= (1 << bits) - 1
    if x & (1 << (bits - 1)):
        x -= 1 << bits
    return x


def truncate(x: int, bits: int) -> int:
    return x & ((1 << bits) - 1)


def to_q(v: float, bits: int) -> int:
    """Round-to-nearest (ties away from zero, matching Rust `f64::round`)
    quantization to Q1.(bits-1), saturating."""
    import math

    half = 1 << (bits - 1)
    s = v * half
    q = int(math.floor(s + 0.5)) if s >= 0 else int(math.ceil(s - 0.5))
    return max(-half, min(half - 1, q))


def from_q(raw: int, bits: int) -> float:
    return raw / (1 << (bits - 1))


# --------------------------------------------------------------------------
# Packing
# --------------------------------------------------------------------------


def pack(vals: List[int], fmt: SimdFormat) -> int:
    assert len(vals) == fmt.lanes
    w = 0
    half = 1 << (fmt.bits - 1)
    for i, v in enumerate(vals):
        assert -half <= v < half, f"lane {i} value {v} out of range"
        w |= truncate(v, fmt.bits) << (i * fmt.bits)
    return w


def unpack(word: int, fmt: SimdFormat) -> List[int]:
    mask = (1 << fmt.bits) - 1
    return [sign_extend((word >> (i * fmt.bits)) & mask, fmt.bits) for i in range(fmt.lanes)]


def pack_stream(vals: List[int], fmt: SimdFormat) -> List[int]:
    lanes = fmt.lanes
    out = []
    for i in range(0, len(vals), lanes):
        chunk = list(vals[i : i + lanes])
        chunk += [0] * (lanes - len(chunk))
        out.append(pack(chunk, fmt))
    return out


def unpack_stream(words: List[int], fmt: SimdFormat, count: int) -> List[int]:
    out: List[int] = []
    for w in words:
        out.extend(unpack(w, fmt))
    return out[:count]


# --------------------------------------------------------------------------
# CSD encoding and multiply scheduling (mirror of rust/src/csd)
# --------------------------------------------------------------------------


def csd_encode(m_raw: int, y_bits: int) -> List[int]:
    """MSB-first digits in {-1, 0, +1}; digits[j] has weight 2^-j."""
    half = 1 << (y_bits - 1)
    assert -half <= m_raw < half, f"multiplier {m_raw} out of Q1.{y_bits-1}"
    m = m_raw
    digits_lsb: List[int] = []
    for _ in range(y_bits):
        if m & 1 == 0:
            digits_lsb.append(0)
        else:
            d = 1 if (m & 3) == 1 else -1
            digits_lsb.append(d)
            m -= d
        m >>= 1
    assert m == 0, f"CSD residual for {m_raw} @ {y_bits}"
    return digits_lsb[::-1]


def csd_decode(digits: List[int]) -> int:
    n = len(digits)
    return sum(d << (n - 1 - j) for j, d in enumerate(digits))


def schedule(m_raw: int, y_bits: int, max_shift: int = MAX_SHIFT) -> List[Tuple[int, int]]:
    """Cycle ops as (shift, sign) pairs, issue order.

    sign ∈ {+1,-1}: fused `acc ← (acc ± X) >> shift` (shift=0 only for the
    final weight-2^0 digit); sign = 0: pure `acc ← acc >> shift`.
    """
    digits = csd_encode(m_raw, y_bits)
    nz = [(j, digits[j]) for j in range(y_bits - 1, -1, -1) if digits[j] != 0]
    ops: List[Tuple[int, int]] = []
    for idx, (j, sign) in enumerate(nz):
        if j == 0:
            ops.append((0, sign))
            continue
        t = nz[idx + 1][0] if idx + 1 < len(nz) else 0
        dist = j - t
        k = min(dist, max_shift)
        ops.append((k, sign))
        rem = dist - k
        while rem > 0:
            s = min(rem, max_shift)
            ops.append((s, 0))
            rem -= s
    return ops


def plan_arrays(m_raw: int, y_bits: int, ops_max: int = OPS_MAX) -> Tuple[List[int], List[int]]:
    """Pad the schedule to fixed length for kernel consumption.

    Padding entries are (0, 0) which the uniform op formula treats as
    no-ops: `acc ← (acc + 0·X) >> 0`.
    """
    ops = schedule(m_raw, y_bits)
    assert len(ops) <= ops_max, f"plan for {m_raw}@{y_bits} exceeds OPS_MAX"
    shifts = [s for s, _ in ops] + [0] * (ops_max - len(ops))
    signs = [g for _, g in ops] + [0] * (ops_max - len(ops))
    return shifts, signs


# --------------------------------------------------------------------------
# Scalar multiply oracle (mirror of rust pipeline::stage1::mul_scalar)
# --------------------------------------------------------------------------


def mul_scalar(x_raw: int, m_raw: int, x_bits: int, y_bits: int) -> int:
    acc = 0
    for shift, sign in schedule(m_raw, y_bits):
        acc = acc + sign * x_raw
        acc >>= shift  # python ints: arithmetic shift, truncation toward −∞
        acc = sign_extend(acc, x_bits)  # wrap (identity except final-add corner)
    return acc


# --------------------------------------------------------------------------
# Repack semantics (mirror of rust pipeline::stage2)
# --------------------------------------------------------------------------


def convert_subword(v: int, from_bits: int, to_bits: int) -> int:
    if to_bits >= from_bits:
        return v << (to_bits - from_bits)
    return v >> (from_bits - to_bits)


def is_direct(from_bits: int, to_bits: int) -> bool:
    return from_bits <= 2 * to_bits


def conversion_chain(from_bits: int, to_bits: int) -> List[Tuple[int, int]]:
    if from_bits == to_bits:
        return []
    if is_direct(from_bits, to_bits):
        return [(from_bits, to_bits)]
    # BFS over the supported widths (mirrors rust conversion_chain).
    from collections import deque

    prev = {from_bits: from_bits}
    q = deque([from_bits])
    while q:
        b = q.popleft()
        if b == to_bits:
            break
        for nb in FORMATS:
            if nb != b and is_direct(b, nb) and nb not in prev:
                prev[nb] = b
                q.append(nb)
    chain = []
    cur = to_bits
    while cur != from_bits:
        chain.append((prev[cur], cur))
        cur = prev[cur]
    return chain[::-1]


def repack_stream(words: List[int], from_bits: int, to_bits: int, count: int) -> List[int]:
    vals = unpack_stream(words, SimdFormat(from_bits), count)
    for f, t in conversion_chain(from_bits, to_bits):
        vals = [convert_subword(v, f, t) for v in vals]
    return pack_stream(vals, SimdFormat(to_bits))
