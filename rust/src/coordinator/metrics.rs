//! Serving metrics: lock-free counters plus a log₂-bucketed latency
//! histogram, updated by PE workers and read by anyone at any time.
//!
//! Two read modes exist (DESIGN.md §13): the cumulative counters, and
//! [`Metrics::snapshot`] — a consistent-enough point-in-time copy that
//! lets a reader (the precision governor) compute **windowed** figures
//! (e.g. the p99 over just the last decision interval) by differencing
//! two snapshots, without consuming or resetting the cumulative totals
//! everyone else reads. [`Metrics::reset`] zeroes everything for
//! harnesses that reuse one `Metrics` across measurement phases.
//!
//! When the served model carries several precision variants, every
//! batch is additionally billed into its **executed variant's** bucket
//! ([`VariantMetrics`]) — rows, cycles, energy and compute time per
//! variant, so `report()` can show per-variant rows/s and pJ/row and
//! the billing-exactness tests can pin each bucket to the
//! single-variant formulas.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::bits::format::FORMATS;

const LAT_BUCKETS: usize = 64;

/// Per-precision-variant billing bucket (lock-free, updated by PE
/// workers with the variant their batch actually executed at).
#[derive(Debug, Default)]
pub struct VariantMetrics {
    pub name: String,
    pub batches: AtomicU64,
    pub rows: AtomicU64,
    pub pad_rows: AtomicU64,
    pub subword_mults: AtomicU64,
    pub s1_cycles: AtomicU64,
    /// Stage-1 cycles saved by activation zero-skipping (DESIGN.md §18)
    /// on this variant's batches — the forgone work the engine tallied.
    pub skipped_cycles: AtomicU64,
    /// (plan × word) executions zero-skipped on this variant's batches.
    pub skipped_plans: AtomicU64,
    pub s2_passes: AtomicU64,
    /// Simulated energy in attojoules (same rounding as the aggregate).
    pub energy_aj: AtomicU64,
    /// Energy the static cost certificate (DESIGN.md §15) predicted for
    /// the same batches, attojoules, same rounding — zero when the
    /// worker bills without a certificate. Must equal `energy_aj`
    /// exactly whenever predictions are recorded.
    pub predicted_energy_aj: AtomicU64,
    /// Wall time spent in PE compute on this variant, nanoseconds.
    pub compute_ns: AtomicU64,
}

impl VariantMetrics {
    fn named(name: String) -> VariantMetrics {
        VariantMetrics { name, ..VariantMetrics::default() }
    }

    /// Billed energy per served row, pJ (0.0 before any rows).
    pub fn pj_per_row(&self) -> f64 {
        let rows = self.rows.load(Ordering::Relaxed);
        if rows == 0 {
            return 0.0;
        }
        self.energy_aj.load(Ordering::Relaxed) as f64 / 1e6 / rows as f64
    }

    /// Certificate-predicted energy per served row, pJ (0.0 before any
    /// rows or without predictions).
    pub fn predicted_pj_per_row(&self) -> f64 {
        let rows = self.rows.load(Ordering::Relaxed);
        if rows == 0 {
            return 0.0;
        }
        self.predicted_energy_aj.load(Ordering::Relaxed) as f64 / 1e6 / rows as f64
    }

    /// Observed activation-sparsity savings share on this variant:
    /// skipped Stage-1 cycles over the dense bill
    /// (`skipped / (executed + skipped)`, cycle-weighted). 0.0 before
    /// any Stage-1 work.
    pub fn skip_rate(&self) -> f64 {
        let skipped = self.skipped_cycles.load(Ordering::Relaxed);
        let total = skipped + self.s1_cycles.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        skipped as f64 / total as f64
    }

    /// Served rows per second of PE *compute* time on this variant —
    /// per-variant wall-clock windows overlap across variants, so the
    /// honest per-variant throughput figure is compute-based.
    pub fn rows_per_compute_sec(&self) -> f64 {
        let ns = self.compute_ns.load(Ordering::Relaxed);
        if ns == 0 {
            return 0.0;
        }
        self.rows.load(Ordering::Relaxed) as f64 / (ns as f64 / 1e9)
    }
}

/// Per-tenant serving bucket for fleet deployments (DESIGN.md §17):
/// the [`VariantMetrics`] idea applied at tenant granularity. Updated
/// lock-free by the admission layer (submits, sheds) and by PE workers
/// (completed rows, energy, latency) with the tenant each batch's lane
/// belongs to; a batch is always tenant-homogeneous, so its whole
/// energy bill lands in one bucket. Carries its own latency histogram
/// so a tenant's governor windows *its own* p99 — one tenant's burst
/// must not pollute another tenant's pressure signal.
#[derive(Debug, Default)]
pub struct TenantMetrics {
    /// Tenant class name (report rows, bench cells).
    pub name: String,
    /// Requests accepted by admission (sheds not included).
    pub requests: AtomicU64,
    /// Requests refused by admission control (typed `Shed` errors).
    pub shed_requests: AtomicU64,
    /// Rows inside shed requests (never enqueued, never executed).
    pub shed_rows: AtomicU64,
    /// Rows completed by PE workers for this tenant.
    pub rows: AtomicU64,
    /// Simulated energy billed to this tenant, attojoules (same
    /// rounding as [`Metrics::add_batch_predicted`]).
    pub energy_aj: AtomicU64,
    /// PE compute time billed to this tenant, nanoseconds.
    pub compute_ns: AtomicU64,
    /// Stage-1 cycles executed for this tenant's batches.
    pub s1_cycles: AtomicU64,
    /// Stage-1 cycles zero-skipping saved on this tenant's batches
    /// (DESIGN.md §18) — the tenant's observed activation sparsity.
    pub skipped_cycles: AtomicU64,
    lat_hist: [AtomicU64; LAT_BUCKETS],
    lat_count: AtomicU64,
}

impl TenantMetrics {
    /// An empty bucket labeled with the tenant class name.
    pub fn named(name: impl Into<String>) -> TenantMetrics {
        TenantMetrics {
            name: name.into(),
            requests: AtomicU64::new(0),
            shed_requests: AtomicU64::new(0),
            shed_rows: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            energy_aj: AtomicU64::new(0),
            compute_ns: AtomicU64::new(0),
            s1_cycles: AtomicU64::new(0),
            skipped_cycles: AtomicU64::new(0),
            lat_hist: std::array::from_fn(|_| AtomicU64::new(0)),
            lat_count: AtomicU64::new(0),
        }
    }

    /// Called by admission on every accepted request.
    pub fn note_submit(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Called by admission on every shed request (`rows` = the rows the
    /// refused request carried).
    pub fn note_shed(&self, rows: u64) {
        self.shed_requests.fetch_add(1, Ordering::Relaxed);
        self.shed_rows.fetch_add(rows, Ordering::Relaxed);
    }

    /// Called by a PE worker after completing a tenant-homogeneous
    /// batch: the batch's rows, its billed energy and its compute time.
    pub fn add_rows(&self, rows: u64, pj: f64, ns: u64) {
        self.rows.fetch_add(rows, Ordering::Relaxed);
        self.energy_aj
            .fetch_add((pj.max(0.0) * 1e6).round() as u64, Ordering::Relaxed);
        self.compute_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Called by a PE worker alongside [`TenantMetrics::add_rows`] with
    /// the batch's Stage-1 cycle split: `executed` cycles actually
    /// spent, `skipped` cycles elided by zero-skipping. Separate from
    /// `add_rows` so pre-skip call sites keep compiling unchanged.
    pub fn add_s1_split(&self, executed: u64, skipped: u64) {
        self.s1_cycles.fetch_add(executed, Ordering::Relaxed);
        self.skipped_cycles.fetch_add(skipped, Ordering::Relaxed);
    }

    /// Fraction of this tenant's dense Stage-1 work that zero-skipping
    /// elided (0.0 before any Stage-1 work) — its observed activation
    /// sparsity, cycle-weighted.
    pub fn skip_rate(&self) -> f64 {
        let skipped = self.skipped_cycles.load(Ordering::Relaxed);
        let total = skipped + self.s1_cycles.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        skipped as f64 / total as f64
    }

    /// Record one request's submit→complete latency for this tenant.
    pub fn observe_latency_ns(&self, ns: u64) {
        let bucket = (64 - ns.leading_zeros() as usize).min(LAT_BUCKETS - 1);
        self.lat_hist[bucket].fetch_add(1, Ordering::Relaxed);
        self.lat_count.fetch_add(1, Ordering::Relaxed);
    }

    /// Energy billed per completed row, pJ (0.0 before any rows).
    pub fn pj_per_row(&self) -> f64 {
        let rows = self.rows.load(Ordering::Relaxed);
        if rows == 0 {
            return 0.0;
        }
        self.energy_aj.load(Ordering::Relaxed) as f64 / 1e6 / rows as f64
    }

    /// Shed requests as a fraction of all arrivals (0.0 before any).
    pub fn shed_rate(&self) -> f64 {
        let shed = self.shed_requests.load(Ordering::Relaxed);
        let total = shed + self.requests.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        shed as f64 / total as f64
    }

    /// Cumulative latency quantile for this tenant (upper bucket bound).
    pub fn latency_quantile_ns(&self, q: f64) -> Option<u64> {
        let mut hist = [0u64; LAT_BUCKETS];
        for (dst, src) in hist.iter_mut().zip(&self.lat_hist) {
            *dst = src.load(Ordering::Relaxed);
        }
        quantile_of(&hist, self.lat_count.load(Ordering::Relaxed), q)
    }

    /// Point-in-time copy — windowed readers (the per-tenant governor,
    /// the fleet bench's phase cells) difference two of these.
    pub fn snapshot(&self) -> TenantSnapshot {
        let mut snap = TenantSnapshot::empty();
        snap.requests = self.requests.load(Ordering::Relaxed);
        snap.shed_requests = self.shed_requests.load(Ordering::Relaxed);
        snap.shed_rows = self.shed_rows.load(Ordering::Relaxed);
        snap.rows = self.rows.load(Ordering::Relaxed);
        snap.energy_aj = self.energy_aj.load(Ordering::Relaxed);
        snap.compute_ns = self.compute_ns.load(Ordering::Relaxed);
        snap.s1_cycles = self.s1_cycles.load(Ordering::Relaxed);
        snap.skipped_cycles = self.skipped_cycles.load(Ordering::Relaxed);
        snap.lat_count = self.lat_count.load(Ordering::Relaxed);
        for (dst, src) in snap.lat_hist.iter_mut().zip(&self.lat_hist) {
            *dst = src.load(Ordering::Relaxed);
        }
        snap
    }
}

/// Plain-value copy of one tenant bucket (see [`TenantMetrics`]).
#[derive(Debug, Clone)]
pub struct TenantSnapshot {
    pub requests: u64,
    pub shed_requests: u64,
    pub shed_rows: u64,
    pub rows: u64,
    pub energy_aj: u64,
    pub compute_ns: u64,
    pub s1_cycles: u64,
    pub skipped_cycles: u64,
    pub lat_count: u64,
    pub lat_hist: [u64; LAT_BUCKETS],
}

impl TenantSnapshot {
    /// The all-zero baseline.
    pub fn empty() -> TenantSnapshot {
        TenantSnapshot {
            requests: 0,
            shed_requests: 0,
            shed_rows: 0,
            rows: 0,
            energy_aj: 0,
            compute_ns: 0,
            s1_cycles: 0,
            skipped_cycles: 0,
            lat_count: 0,
            lat_hist: [0; LAT_BUCKETS],
        }
    }

    /// Latency quantile over the window between `earlier` and this
    /// snapshot (`None` when nothing completed in the window).
    pub fn window_latency_quantile_ns(
        &self,
        earlier: &TenantSnapshot,
        q: f64,
    ) -> Option<u64> {
        let mut hist = [0u64; LAT_BUCKETS];
        let mut count = 0u64;
        for (i, h) in hist.iter_mut().enumerate() {
            *h = self.lat_hist[i].saturating_sub(earlier.lat_hist[i]);
            count += *h;
        }
        quantile_of(&hist, count, q)
    }

    /// Rows completed in the window between `earlier` and this snapshot.
    pub fn window_rows(&self, earlier: &TenantSnapshot) -> u64 {
        self.rows.saturating_sub(earlier.rows)
    }

    /// Requests accepted in the window.
    pub fn window_requests(&self, earlier: &TenantSnapshot) -> u64 {
        self.requests.saturating_sub(earlier.requests)
    }

    /// Requests shed in the window.
    pub fn window_shed(&self, earlier: &TenantSnapshot) -> u64 {
        self.shed_requests.saturating_sub(earlier.shed_requests)
    }

    /// Energy billed in the window, pJ.
    pub fn window_pj(&self, earlier: &TenantSnapshot) -> f64 {
        self.energy_aj.saturating_sub(earlier.energy_aj) as f64 / 1e6
    }
}

/// Plain-value copy of one variant bucket (inside [`MetricsSnapshot`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct VariantCounters {
    pub batches: u64,
    pub rows: u64,
    pub pad_rows: u64,
    pub subword_mults: u64,
    pub s1_cycles: u64,
    pub s2_passes: u64,
    pub skipped_cycles: u64,
    pub skipped_plans: u64,
    pub energy_aj: u64,
    pub predicted_energy_aj: u64,
    pub compute_ns: u64,
}

/// A point-in-time copy of every counter, cheap to take and free of
/// atomics — what windowed readers difference (DESIGN.md §13). Each
/// field is loaded individually (`Relaxed`), so a snapshot taken while
/// workers are mid-update may be skewed by the in-flight batch; the
/// governor's hysteresis absorbs that, and the histogram quantile
/// clamps exactly like [`Metrics::latency_quantile_ns`].
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub rows: u64,
    pub pad_rows: u64,
    pub dropped_rows: u64,
    pub subword_mults: u64,
    pub s1_cycles: u64,
    pub s2_passes: u64,
    pub skipped_cycles: u64,
    pub skipped_plans: u64,
    pub energy_aj: u64,
    pub predicted_energy_aj: u64,
    pub compute_ns: u64,
    pub variant_switches: u64,
    pub lat_count: u64,
    pub lat_sum_ns: u64,
    pub lat_hist: [u64; LAT_BUCKETS],
    pub per_variant: Vec<VariantCounters>,
}

impl MetricsSnapshot {
    /// An all-zero snapshot (the "before anything happened" baseline).
    pub fn empty(n_variants: usize) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: 0,
            batches: 0,
            rows: 0,
            pad_rows: 0,
            dropped_rows: 0,
            subword_mults: 0,
            s1_cycles: 0,
            s2_passes: 0,
            skipped_cycles: 0,
            skipped_plans: 0,
            energy_aj: 0,
            predicted_energy_aj: 0,
            compute_ns: 0,
            variant_switches: 0,
            lat_count: 0,
            lat_sum_ns: 0,
            lat_hist: [0; LAT_BUCKETS],
            per_variant: vec![VariantCounters::default(); n_variants.max(1)],
        }
    }

    /// Latency quantile over this snapshot's cumulative histogram
    /// (upper bucket bound, clamped to `2^(LAT_BUCKETS-1)` ns).
    pub fn latency_quantile_ns(&self, q: f64) -> Option<u64> {
        quantile_of(&self.lat_hist, self.lat_count, q)
    }

    /// Latency quantile over the **window** between `earlier` and this
    /// snapshot — the governor's windowed p99. `None` when no request
    /// completed in the window (the caller should treat that as "no
    /// pressure signal", not as zero latency).
    pub fn window_latency_quantile_ns(
        &self,
        earlier: &MetricsSnapshot,
        q: f64,
    ) -> Option<u64> {
        let mut hist = [0u64; LAT_BUCKETS];
        let mut count = 0u64;
        for (i, h) in hist.iter_mut().enumerate() {
            // saturating: a racing reader can see bucket updates out of
            // order across two snapshots.
            *h = self.lat_hist[i].saturating_sub(earlier.lat_hist[i]);
            count += *h;
        }
        quantile_of(&hist, count, q)
    }

    /// Rows completed in the window between `earlier` and this snapshot.
    pub fn window_rows(&self, earlier: &MetricsSnapshot) -> u64 {
        self.rows.saturating_sub(earlier.rows)
    }
}

fn quantile_of(hist: &[u64; LAT_BUCKETS], count: u64, q: f64) -> Option<u64> {
    if count == 0 {
        return None;
    }
    let target = ((q.clamp(0.0, 1.0) * count as f64).ceil() as u64).max(1);
    let mut seen = 0u64;
    for (i, &b) in hist.iter().enumerate() {
        seen += b;
        if seen >= target {
            return Some(1u64 << i.min(LAT_BUCKETS - 1));
        }
    }
    Some(1u64 << (LAT_BUCKETS - 1))
}

/// Shared counters (lock-free; updated by PE workers).
#[derive(Debug)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub rows: AtomicU64,
    /// Zero rows added by lane padding (not counted in `rows`).
    pub pad_rows: AtomicU64,
    /// Rows dropped because no live worker could take them.
    pub dropped_rows: AtomicU64,
    pub subword_mults: AtomicU64,
    pub s1_cycles: AtomicU64,
    pub s2_passes: AtomicU64,
    /// Stage-1 cycles zero-skipping elided across all batches
    /// (DESIGN.md §18) — together with `s1_cycles` this derives the
    /// fleet's observed activation sparsity.
    pub skipped_cycles: AtomicU64,
    /// Whole packed-column plans elided by zero-skipping.
    pub skipped_plans: AtomicU64,
    /// Stage-1 cycles split by the format they ran at (parallel to
    /// `FORMATS`) — the serving-side view of a mixed-precision schedule.
    pub s1_cycles_by_fmt: [AtomicU64; FORMATS.len()],
    /// Stage-2 passes split by the format they produced.
    pub s2_passes_by_fmt: [AtomicU64; FORMATS.len()],
    /// Simulated energy, *atto*-joules (integer for atomic
    /// accumulation). Per-batch pJ figures are rounded to the nearest
    /// aJ before accumulating, so the worst-case drift is 0.5 aJ
    /// (5·10⁻⁴ fJ) per batch — the pre-fix femtojoule truncation lost
    /// up to a full fJ per batch, which compounds to nonsense totals
    /// over a serving run. Read through [`Metrics::energy_fj`].
    pub energy_aj: AtomicU64,
    /// Certificate-predicted energy for the same batches, attojoules
    /// (DESIGN.md §15) — stays zero when batches are billed without a
    /// prediction ([`Metrics::add_batch`]).
    pub predicted_energy_aj: AtomicU64,
    /// Wall time spent in PE compute, nanoseconds.
    pub compute_ns: AtomicU64,
    /// Per-precision-variant billing buckets (index = variant id).
    pub per_variant: Vec<VariantMetrics>,
    /// Governor decisions that changed the active variant.
    pub variant_switches: AtomicU64,
    /// Request latency histogram: bucket `i` counts latencies in
    /// `[2^(i-1), 2^i)` nanoseconds (bucket 0: `< 1 ns`).
    lat_hist: [AtomicU64; LAT_BUCKETS],
    lat_count: AtomicU64,
    lat_sum_ns: AtomicU64,
    /// Serving-window bounds, nanoseconds since `t0` (for rows/s).
    first_submit_ns: AtomicU64,
    last_done_ns: AtomicU64,
    t0: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::with_variants(1)
    }
}

impl Metrics {
    /// Metrics for a model serving `n_variants` precision variants
    /// (buckets named `v0`, `v1`, …; [`Metrics::with_variant_names`]
    /// attaches the real names).
    pub fn with_variants(n_variants: usize) -> Metrics {
        Metrics::with_variant_names(
            &(0..n_variants.max(1)).map(|v| format!("v{v}")).collect::<Vec<_>>(),
        )
    }

    /// Metrics with one named billing bucket per precision variant.
    pub fn with_variant_names(names: &[String]) -> Metrics {
        let names: Vec<String> = if names.is_empty() {
            vec!["v0".to_string()]
        } else {
            names.to_vec()
        };
        Metrics {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            pad_rows: AtomicU64::new(0),
            dropped_rows: AtomicU64::new(0),
            subword_mults: AtomicU64::new(0),
            s1_cycles: AtomicU64::new(0),
            s2_passes: AtomicU64::new(0),
            skipped_cycles: AtomicU64::new(0),
            skipped_plans: AtomicU64::new(0),
            s1_cycles_by_fmt: std::array::from_fn(|_| AtomicU64::new(0)),
            s2_passes_by_fmt: std::array::from_fn(|_| AtomicU64::new(0)),
            energy_aj: AtomicU64::new(0),
            predicted_energy_aj: AtomicU64::new(0),
            compute_ns: AtomicU64::new(0),
            per_variant: names.into_iter().map(VariantMetrics::named).collect(),
            variant_switches: AtomicU64::new(0),
            lat_hist: std::array::from_fn(|_| AtomicU64::new(0)),
            lat_count: AtomicU64::new(0),
            lat_sum_ns: AtomicU64::new(0),
            first_submit_ns: AtomicU64::new(u64::MAX),
            last_done_ns: AtomicU64::new(0),
            t0: Instant::now(),
        }
    }

    fn now_ns(&self) -> u64 {
        self.t0.elapsed().as_nanos() as u64
    }

    /// Called by the coordinator on every accepted request.
    pub fn note_submit(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.first_submit_ns
            .fetch_min(self.now_ns(), Ordering::Relaxed);
    }

    /// Called by the governor when a dispatch decision changed the
    /// active variant.
    pub fn note_variant_switch(&self) {
        self.variant_switches.fetch_add(1, Ordering::Relaxed);
    }

    /// Called by a PE worker after completing a batch; `variant` is the
    /// precision variant the batch **actually executed at** — billing
    /// follows execution, not whatever was active at submit time.
    pub fn add_batch(
        &self,
        rows: u64,
        variant: usize,
        stats: crate::coordinator::engine::EngineStats,
        pj: f64,
        ns: u64,
    ) {
        self.add_batch_predicted(rows, variant, stats, pj, 0.0, ns);
    }

    /// As [`add_batch`], additionally recording the energy the static
    /// cost certificate predicted for this batch (DESIGN.md §15).
    /// `predicted_pj` goes through the identical attojoule rounding as
    /// the measured figure, so a correct certificate accumulates a
    /// predicted total that equals the measured one *exactly* — the
    /// `eval autoscale`/`eval certify` gates assert a zero-aJ delta.
    ///
    /// [`add_batch`]: Metrics::add_batch
    pub fn add_batch_predicted(
        &self,
        rows: u64,
        variant: usize,
        stats: crate::coordinator::engine::EngineStats,
        pj: f64,
        predicted_pj: f64,
        ns: u64,
    ) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(rows, Ordering::Relaxed);
        self.pad_rows.fetch_add(stats.pad_rows, Ordering::Relaxed);
        self.subword_mults
            .fetch_add(stats.subword_mults, Ordering::Relaxed);
        self.s1_cycles.fetch_add(stats.s1_cycles, Ordering::Relaxed);
        self.s2_passes.fetch_add(stats.s2_passes, Ordering::Relaxed);
        self.skipped_cycles
            .fetch_add(stats.skipped_cycles, Ordering::Relaxed);
        self.skipped_plans
            .fetch_add(stats.skipped_plans, Ordering::Relaxed);
        for (dst, &src) in self.s1_cycles_by_fmt.iter().zip(&stats.s1_cycles_by_fmt) {
            dst.fetch_add(src, Ordering::Relaxed);
        }
        for (dst, &src) in self.s2_passes_by_fmt.iter().zip(&stats.s2_passes_by_fmt) {
            dst.fetch_add(src, Ordering::Relaxed);
        }
        // A batch's energy is a finite, non-negative physical quantity;
        // NaN or a negative figure is a cost-model bug upstream, not
        // something to silently saturate-cast into the counter.
        debug_assert!(
            pj.is_finite() && pj >= 0.0,
            "batch energy must be finite and non-negative, got {pj} pJ"
        );
        // Round to the nearest attojoule (`max` also maps NaN to 0.0 in
        // release builds) — never truncate: sub-unit remainders must
        // not be systematically dropped every batch.
        let aj = (pj.max(0.0) * 1e6).round() as u64;
        let predicted_aj = (predicted_pj.max(0.0) * 1e6).round() as u64;
        self.energy_aj.fetch_add(aj, Ordering::Relaxed);
        self.predicted_energy_aj
            .fetch_add(predicted_aj, Ordering::Relaxed);
        self.compute_ns.fetch_add(ns, Ordering::Relaxed);
        self.last_done_ns.fetch_max(self.now_ns(), Ordering::Relaxed);
        // The executed variant's bucket gets the same figures — the
        // by-variant split must always sum to the aggregates.
        let vb = &self.per_variant[variant.min(self.per_variant.len() - 1)];
        vb.batches.fetch_add(1, Ordering::Relaxed);
        vb.rows.fetch_add(rows, Ordering::Relaxed);
        vb.pad_rows.fetch_add(stats.pad_rows, Ordering::Relaxed);
        vb.subword_mults
            .fetch_add(stats.subword_mults, Ordering::Relaxed);
        vb.s1_cycles.fetch_add(stats.s1_cycles, Ordering::Relaxed);
        vb.s2_passes.fetch_add(stats.s2_passes, Ordering::Relaxed);
        vb.skipped_cycles
            .fetch_add(stats.skipped_cycles, Ordering::Relaxed);
        vb.skipped_plans
            .fetch_add(stats.skipped_plans, Ordering::Relaxed);
        vb.energy_aj.fetch_add(aj, Ordering::Relaxed);
        vb.predicted_energy_aj
            .fetch_add(predicted_aj, Ordering::Relaxed);
        vb.compute_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Accumulated simulated energy in femtojoules.
    pub fn energy_fj(&self) -> f64 {
        self.energy_aj.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Record one request's submit→complete latency.
    pub fn observe_latency_ns(&self, ns: u64) {
        let bucket = (64 - ns.leading_zeros() as usize).min(LAT_BUCKETS - 1);
        self.lat_hist[bucket].fetch_add(1, Ordering::Relaxed);
        self.lat_count.fetch_add(1, Ordering::Relaxed);
        self.lat_sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Point-in-time copy of every counter — windowed readers (the
    /// governor) difference two of these; the cumulative totals are
    /// left untouched for everyone else.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut snap = MetricsSnapshot::empty(self.per_variant.len());
        snap.requests = self.requests.load(Ordering::Relaxed);
        snap.batches = self.batches.load(Ordering::Relaxed);
        snap.rows = self.rows.load(Ordering::Relaxed);
        snap.pad_rows = self.pad_rows.load(Ordering::Relaxed);
        snap.dropped_rows = self.dropped_rows.load(Ordering::Relaxed);
        snap.subword_mults = self.subword_mults.load(Ordering::Relaxed);
        snap.s1_cycles = self.s1_cycles.load(Ordering::Relaxed);
        snap.s2_passes = self.s2_passes.load(Ordering::Relaxed);
        snap.skipped_cycles = self.skipped_cycles.load(Ordering::Relaxed);
        snap.skipped_plans = self.skipped_plans.load(Ordering::Relaxed);
        snap.energy_aj = self.energy_aj.load(Ordering::Relaxed);
        snap.predicted_energy_aj = self.predicted_energy_aj.load(Ordering::Relaxed);
        snap.compute_ns = self.compute_ns.load(Ordering::Relaxed);
        snap.variant_switches = self.variant_switches.load(Ordering::Relaxed);
        snap.lat_count = self.lat_count.load(Ordering::Relaxed);
        snap.lat_sum_ns = self.lat_sum_ns.load(Ordering::Relaxed);
        for (dst, src) in snap.lat_hist.iter_mut().zip(&self.lat_hist) {
            *dst = src.load(Ordering::Relaxed);
        }
        for (dst, src) in snap.per_variant.iter_mut().zip(&self.per_variant) {
            dst.batches = src.batches.load(Ordering::Relaxed);
            dst.rows = src.rows.load(Ordering::Relaxed);
            dst.pad_rows = src.pad_rows.load(Ordering::Relaxed);
            dst.subword_mults = src.subword_mults.load(Ordering::Relaxed);
            dst.s1_cycles = src.s1_cycles.load(Ordering::Relaxed);
            dst.s2_passes = src.s2_passes.load(Ordering::Relaxed);
            dst.skipped_cycles = src.skipped_cycles.load(Ordering::Relaxed);
            dst.skipped_plans = src.skipped_plans.load(Ordering::Relaxed);
            dst.energy_aj = src.energy_aj.load(Ordering::Relaxed);
            dst.predicted_energy_aj = src.predicted_energy_aj.load(Ordering::Relaxed);
            dst.compute_ns = src.compute_ns.load(Ordering::Relaxed);
        }
        snap
    }

    /// Zero every counter (histogram, per-variant buckets and serving
    /// window included) — for harnesses that reuse one `Metrics` across
    /// measurement phases. Not linearizable against concurrent workers;
    /// quiesce first if exact phase boundaries matter.
    pub fn reset(&self) {
        self.requests.store(0, Ordering::Relaxed);
        self.batches.store(0, Ordering::Relaxed);
        self.rows.store(0, Ordering::Relaxed);
        self.pad_rows.store(0, Ordering::Relaxed);
        self.dropped_rows.store(0, Ordering::Relaxed);
        self.subword_mults.store(0, Ordering::Relaxed);
        self.s1_cycles.store(0, Ordering::Relaxed);
        self.s2_passes.store(0, Ordering::Relaxed);
        self.skipped_cycles.store(0, Ordering::Relaxed);
        self.skipped_plans.store(0, Ordering::Relaxed);
        for c in &self.s1_cycles_by_fmt {
            c.store(0, Ordering::Relaxed);
        }
        for c in &self.s2_passes_by_fmt {
            c.store(0, Ordering::Relaxed);
        }
        self.energy_aj.store(0, Ordering::Relaxed);
        self.predicted_energy_aj.store(0, Ordering::Relaxed);
        self.compute_ns.store(0, Ordering::Relaxed);
        self.variant_switches.store(0, Ordering::Relaxed);
        for b in &self.lat_hist {
            b.store(0, Ordering::Relaxed);
        }
        self.lat_count.store(0, Ordering::Relaxed);
        self.lat_sum_ns.store(0, Ordering::Relaxed);
        self.first_submit_ns.store(u64::MAX, Ordering::Relaxed);
        self.last_done_ns.store(0, Ordering::Relaxed);
        for vb in &self.per_variant {
            vb.batches.store(0, Ordering::Relaxed);
            vb.rows.store(0, Ordering::Relaxed);
            vb.pad_rows.store(0, Ordering::Relaxed);
            vb.subword_mults.store(0, Ordering::Relaxed);
            vb.s1_cycles.store(0, Ordering::Relaxed);
            vb.s2_passes.store(0, Ordering::Relaxed);
            vb.skipped_cycles.store(0, Ordering::Relaxed);
            vb.skipped_plans.store(0, Ordering::Relaxed);
            vb.energy_aj.store(0, Ordering::Relaxed);
            vb.predicted_energy_aj.store(0, Ordering::Relaxed);
            vb.compute_ns.store(0, Ordering::Relaxed);
        }
    }

    /// Latency quantile estimate in nanoseconds (upper bucket bound);
    /// `None` until at least one latency is recorded. `q` in [0, 1].
    /// Never exceeds the top bucket's documented upper bound
    /// (`2^(LAT_BUCKETS-1)` ns): the overflow bucket clamps there, and
    /// a racing reader that sees `lat_count` ahead of the histogram
    /// falls through to the same clamp — the old `u64::MAX` sentinel
    /// printed as an ~18-exasecond p99 in `report()`.
    pub fn latency_quantile_ns(&self, q: f64) -> Option<u64> {
        let count = self.lat_count.load(Ordering::Relaxed);
        if count == 0 {
            return None;
        }
        let target = ((q.clamp(0.0, 1.0) * count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.lat_hist.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Some(1u64 << i.min(LAT_BUCKETS - 1));
            }
        }
        Some(1u64 << (LAT_BUCKETS - 1))
    }

    pub fn mean_latency_ns(&self) -> Option<f64> {
        let count = self.lat_count.load(Ordering::Relaxed);
        if count == 0 {
            return None;
        }
        Some(self.lat_sum_ns.load(Ordering::Relaxed) as f64 / count as f64)
    }

    /// Served rows per second over the first-submit → last-completion
    /// window (0.0 before any work completes).
    pub fn rows_per_sec(&self) -> f64 {
        let first = self.first_submit_ns.load(Ordering::Relaxed);
        let last = self.last_done_ns.load(Ordering::Relaxed);
        let rows = self.rows.load(Ordering::Relaxed);
        if first == u64::MAX || last <= first || rows == 0 {
            return 0.0;
        }
        rows as f64 / ((last - first) as f64 / 1e9)
    }

    /// Fleet-wide observed activation sparsity, cycle-weighted: the
    /// fraction of dense Stage-1 work that zero-skipping elided (0.0
    /// before any Stage-1 work).
    pub fn skip_rate(&self) -> f64 {
        let skipped = self.skipped_cycles.load(Ordering::Relaxed);
        let total = skipped + self.s1_cycles.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        skipped as f64 / total as f64
    }

    pub fn report(&self) -> String {
        let rows = self.rows.load(Ordering::Relaxed);
        let mults = self.subword_mults.load(Ordering::Relaxed);
        let cycles = self.s1_cycles.load(Ordering::Relaxed);
        let pj = self.energy_fj() / 1000.0;
        let ns = self.compute_ns.load(Ordering::Relaxed).max(1);
        let p50 = self.latency_quantile_ns(0.50).unwrap_or(0) as f64 / 1e3;
        let p99 = self.latency_quantile_ns(0.99).unwrap_or(0) as f64 / 1e3;
        // Per-format Stage-1 breakdown, formats actually exercised only.
        let by_fmt: String = FORMATS
            .iter()
            .enumerate()
            .filter_map(|(i, &b)| {
                let c = self.s1_cycles_by_fmt[i].load(Ordering::Relaxed);
                (c > 0).then(|| format!("{b}b:{c}"))
            })
            .collect::<Vec<_>>()
            .join(",");
        let mut out = format!(
            "requests={} batches={} rows={} pad_rows={} dropped_rows={} \
             subword_mults={} s1_cycles={} s1_by_fmt=[{}] s2_passes={} \
             sim_energy={:.2} nJ mean_pJ/mult={:.3} \
             host_throughput={:.1} Mmult/s rows/s={:.0} \
             latency_p50={:.0}us latency_p99={:.0}us variant_switches={}",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            rows,
            self.pad_rows.load(Ordering::Relaxed),
            self.dropped_rows.load(Ordering::Relaxed),
            mults,
            cycles,
            by_fmt,
            self.s2_passes.load(Ordering::Relaxed),
            pj / 1000.0,
            if mults > 0 { pj / mults as f64 } else { 0.0 },
            mults as f64 / (ns as f64 / 1000.0),
            self.rows_per_sec(),
            p50,
            p99,
            self.variant_switches.load(Ordering::Relaxed),
        );
        // Zero-skipping savings (DESIGN.md §18), only when any Stage-1
        // work was elided — dense workloads keep the legacy report shape.
        let skipped = self.skipped_cycles.load(Ordering::Relaxed);
        if skipped > 0 {
            out.push_str(&format!(
                " skipped_cycles={} skipped_plans={} sparsity={:.1}%",
                skipped,
                self.skipped_plans.load(Ordering::Relaxed),
                self.skip_rate() * 100.0,
            ));
        }
        // Certificate prediction line, only when workers recorded one:
        // the measured-vs-predicted delta in aJ must read 0 whenever the
        // static cost certificate (DESIGN.md §15) is wired in.
        let predicted_aj = self.predicted_energy_aj.load(Ordering::Relaxed);
        if predicted_aj > 0 {
            let measured_aj = self.energy_aj.load(Ordering::Relaxed);
            out.push_str(&format!(
                " predicted_energy={:.2} nJ predicted_delta_aJ={}",
                predicted_aj as f64 / 1e9,
                measured_aj as i128 - predicted_aj as i128,
            ));
        }
        // Per-variant billing lines, variants actually exercised only
        // (a single-variant deployment prints none — its figures are
        // the aggregates above).
        if self.per_variant.len() > 1 {
            for (v, vb) in self.per_variant.iter().enumerate() {
                let vrows = vb.rows.load(Ordering::Relaxed);
                if vrows == 0 {
                    continue;
                }
                out.push_str(&format!(
                    "\n  variant[{v} {}]: batches={} rows={} rows/s(compute)={:.0} \
                     pJ/row={:.2}",
                    vb.name,
                    vb.batches.load(Ordering::Relaxed),
                    vrows,
                    vb.rows_per_compute_sec(),
                    vb.pj_per_row(),
                ));
                if vb.predicted_energy_aj.load(Ordering::Relaxed) > 0 {
                    out.push_str(&format!(
                        " predicted_pJ/row={:.2}",
                        vb.predicted_pj_per_row()
                    ));
                }
                if vb.skipped_cycles.load(Ordering::Relaxed) > 0 {
                    out.push_str(&format!(
                        " sparsity={:.1}%",
                        vb.skip_rate() * 100.0
                    ));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let m = Metrics::default();
        let mut by_fmt = [0u64; FORMATS.len()];
        by_fmt[crate::bits::format::format_index(8)] = 10;
        let stats = crate::coordinator::engine::EngineStats {
            s1_cycles: 10,
            s1_adds: 6,
            s2_passes: 2,
            acc_adds: 5,
            subword_mults: 60,
            pad_rows: 1,
            s1_cycles_by_fmt: by_fmt,
            s1_adds_by_fmt: [0; FORMATS.len()],
            s2_passes_by_fmt: [0; FORMATS.len()],
            ..Default::default()
        };
        m.add_batch(6, 0, stats, 1.5, 100);
        m.add_batch(6, 0, stats, 1.5, 100);
        assert_eq!(m.rows.load(Ordering::Relaxed), 12);
        assert_eq!(m.pad_rows.load(Ordering::Relaxed), 2);
        assert_eq!(m.subword_mults.load(Ordering::Relaxed), 120);
        let i8 = crate::bits::format::format_index(8);
        assert_eq!(m.s1_cycles_by_fmt[i8].load(Ordering::Relaxed), 20);
        assert!(m.report().contains("rows=12"));
        assert!(m.report().contains("8b:20"), "{}", m.report());
        // No Stage-1 work was skipped, so the report keeps its dense
        // shape — the sparsity fields are gated on nonzero skips.
        assert!(!m.report().contains("sparsity="), "{}", m.report());
    }

    #[test]
    fn skip_counters_accumulate_and_surface_in_the_report() {
        let m = Metrics::with_variant_names(&[
            "hifi".to_string(),
            "turbo".to_string(),
        ]);
        let stats = crate::coordinator::engine::EngineStats {
            s1_cycles: 30,
            skipped_cycles: 10,
            skipped_plans: 2,
            subword_mults: 60,
            ..Default::default()
        };
        m.add_batch(6, 1, stats, 1.0, 100);
        m.add_batch(6, 1, stats, 1.0, 100);
        assert_eq!(m.skipped_cycles.load(Ordering::Relaxed), 20);
        assert_eq!(m.skipped_plans.load(Ordering::Relaxed), 4);
        // 20 skipped of 80 dense cycles, cycle-weighted.
        assert!((m.skip_rate() - 0.25).abs() < 1e-12);
        assert!((m.per_variant[1].skip_rate() - 0.25).abs() < 1e-12);
        assert_eq!(m.per_variant[0].skipped_cycles.load(Ordering::Relaxed), 0);
        let report = m.report();
        assert!(
            report.contains("skipped_cycles=20 skipped_plans=4 sparsity=25.0%"),
            "{report}"
        );
        assert!(report.contains("variant[1 turbo]"), "{report}");
        // Snapshot carries the skip counters; reset zeroes them.
        let snap = m.snapshot();
        assert_eq!(snap.skipped_cycles, 20);
        assert_eq!(snap.per_variant[1].skipped_plans, 4);
        m.reset();
        assert_eq!(m.skipped_cycles.load(Ordering::Relaxed), 0);
        assert_eq!(m.per_variant[1].skipped_cycles.load(Ordering::Relaxed), 0);
        assert_eq!(m.skip_rate(), 0.0);
    }

    #[test]
    fn tenant_s1_split_derives_the_tenant_skip_rate() {
        let t = TenantMetrics::named("batch");
        assert_eq!(t.skip_rate(), 0.0);
        t.add_s1_split(75, 25);
        assert!((t.skip_rate() - 0.25).abs() < 1e-12);
        let snap = t.snapshot();
        assert_eq!(snap.s1_cycles, 75);
        assert_eq!(snap.skipped_cycles, 25);
    }

    #[test]
    fn per_variant_buckets_split_and_sum_to_the_aggregates() {
        let m = Metrics::with_variant_names(&[
            "hifi".to_string(),
            "turbo".to_string(),
        ]);
        let stats = crate::coordinator::engine::EngineStats {
            s1_cycles: 10,
            s2_passes: 4,
            subword_mults: 30,
            ..Default::default()
        };
        m.add_batch(6, 0, stats, 2.0, 1_000);
        m.add_batch(12, 1, stats, 1.0, 500);
        m.add_batch(12, 1, stats, 1.0, 500);
        assert_eq!(m.rows.load(Ordering::Relaxed), 30);
        assert_eq!(m.per_variant[0].rows.load(Ordering::Relaxed), 6);
        assert_eq!(m.per_variant[1].rows.load(Ordering::Relaxed), 24);
        assert_eq!(m.per_variant[1].batches.load(Ordering::Relaxed), 2);
        // Bucket energies sum to the aggregate (2.0 + 1.0 + 1.0 pJ).
        let total: u64 = m
            .per_variant
            .iter()
            .map(|v| v.energy_aj.load(Ordering::Relaxed))
            .sum();
        assert_eq!(total, m.energy_aj.load(Ordering::Relaxed));
        assert_eq!(total, 4_000_000, "4 pJ in aJ");
        // pJ/row per bucket: hifi 2.0/6, turbo 2.0/24.
        assert!((m.per_variant[0].pj_per_row() - 2.0 / 6.0).abs() < 1e-9);
        assert!((m.per_variant[1].pj_per_row() - 2.0 / 24.0).abs() < 1e-9);
        let report = m.report();
        assert!(report.contains("variant[0 hifi]"), "{report}");
        assert!(report.contains("variant[1 turbo]"), "{report}");
        // Out-of-range variant ids clamp to the last bucket instead of
        // panicking a PE worker.
        m.add_batch(1, 99, stats, 0.0, 1);
        assert_eq!(m.per_variant[1].batches.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn predicted_energy_bills_alongside_measured_and_gates_the_report() {
        let m = Metrics::with_variant_names(&["hifi".to_string(), "turbo".to_string()]);
        // Plain add_batch records no prediction: counters stay zero and
        // the report omits the prediction fields entirely.
        m.add_batch(6, 0, Default::default(), 1.5, 100);
        assert_eq!(m.predicted_energy_aj.load(Ordering::Relaxed), 0);
        assert!(!m.report().contains("predicted_energy"), "{}", m.report());
        // An exact prediction accumulates through the identical aJ
        // rounding, so the delta is zero to the attojoule.
        m.add_batch_predicted(6, 1, Default::default(), 1.2345, 1.2345, 100);
        m.add_batch_predicted(6, 1, Default::default(), 0.0007, 0.0007, 100);
        assert_eq!(
            m.per_variant[1].predicted_energy_aj.load(Ordering::Relaxed),
            m.per_variant[1].energy_aj.load(Ordering::Relaxed)
        );
        let report = m.report();
        // The unpredicted first batch shows up as the aggregate delta.
        assert!(report.contains("predicted_delta_aJ=1500000"), "{report}");
        assert!(report.contains("predicted_pJ/row"), "{report}");
        // Snapshot and reset carry the new counter.
        assert_eq!(m.snapshot().predicted_energy_aj, 1_235_200);
        assert_eq!(m.snapshot().per_variant[1].predicted_energy_aj, 1_235_200);
        m.reset();
        assert_eq!(m.predicted_energy_aj.load(Ordering::Relaxed), 0);
        assert_eq!(m.per_variant[1].predicted_energy_aj.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn snapshot_windows_dont_consume_cumulative_totals() {
        let m = Metrics::default();
        for ns in [100u64, 200, 400] {
            m.observe_latency_ns(ns);
        }
        let a = m.snapshot();
        // Cumulative reads still work after a snapshot.
        assert_eq!(m.lat_count.load(Ordering::Relaxed), 3);
        assert_eq!(a.lat_count, 3);
        // A quiet window has no quantile — distinct from "0 ns".
        let b = m.snapshot();
        assert!(b.window_latency_quantile_ns(&a, 0.99).is_none());
        // A window containing only slow requests reports *their* p99,
        // not the cumulative one.
        m.observe_latency_ns(1_000_000);
        m.observe_latency_ns(2_000_000);
        let c = m.snapshot();
        let windowed = c.window_latency_quantile_ns(&b, 0.99).unwrap();
        assert!(windowed >= 1_000_000, "windowed p99 {windowed}");
        let cumulative = m.latency_quantile_ns(0.50).unwrap();
        assert!(cumulative <= 512, "cumulative p50 {cumulative} polluted");
        assert_eq!(c.window_rows(&a), 0);
    }

    #[test]
    fn reset_zeroes_everything() {
        let m = Metrics::with_variants(2);
        m.note_submit();
        m.note_variant_switch();
        m.add_batch(6, 1, Default::default(), 1.0, 100);
        m.observe_latency_ns(500);
        m.reset();
        assert_eq!(m.rows.load(Ordering::Relaxed), 0);
        assert_eq!(m.requests.load(Ordering::Relaxed), 0);
        assert_eq!(m.variant_switches.load(Ordering::Relaxed), 0);
        assert_eq!(m.per_variant[1].rows.load(Ordering::Relaxed), 0);
        assert!(m.latency_quantile_ns(0.5).is_none());
        assert_eq!(m.rows_per_sec(), 0.0);
        // And it keeps working after the reset.
        m.note_submit();
        m.add_batch(3, 0, Default::default(), 0.5, 50);
        assert_eq!(m.rows.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn latency_quantiles_order() {
        let m = Metrics::default();
        assert!(m.latency_quantile_ns(0.5).is_none());
        for ns in [100u64, 200, 400, 800, 100_000] {
            m.observe_latency_ns(ns);
        }
        let p50 = m.latency_quantile_ns(0.50).unwrap();
        let p99 = m.latency_quantile_ns(0.99).unwrap();
        assert!(p50 <= p99, "p50 {p50} > p99 {p99}");
        assert!(p99 >= 100_000, "p99 {p99} below max sample");
        assert!(m.mean_latency_ns().unwrap() > 0.0);
    }

    #[test]
    fn per_batch_energy_sums_match_the_oracle_total_within_a_femtojoule() {
        // Regression (the fJ-truncation bug): 1000 batches of 0.0007 pJ
        // = 0.7 fJ each used to truncate to 0 fJ every single batch,
        // reporting zero total energy for 700 fJ of real work.
        let m = Metrics::default();
        let per_batch_pj = 0.0007;
        let batches = 1000u64;
        for _ in 0..batches {
            m.add_batch(1, 0, Default::default(), per_batch_pj, 1);
        }
        let oracle_fj = per_batch_pj * batches as f64 * 1000.0;
        assert!(
            (m.energy_fj() - oracle_fj).abs() < 1.0,
            "accumulated {} fJ, oracle {} fJ",
            m.energy_fj(),
            oracle_fj
        );
        // And fractional picojoule figures keep their remainders too.
        let m2 = Metrics::default();
        for _ in 0..100 {
            m2.add_batch(1, 0, Default::default(), 1.2345, 1);
        }
        assert!((m2.energy_fj() - 123450.0).abs() < 1.0, "{}", m2.energy_fj());
    }

    #[test]
    fn overflow_latency_bucket_clamps_to_its_documented_upper_bound() {
        // Regression (the u64::MAX sentinel): an astronomically large
        // latency lands in the top bucket and every quantile must clamp
        // to that bucket's upper bound, never the ~18-exasecond
        // sentinel `report()` would print as a p99.
        let m = Metrics::default();
        m.observe_latency_ns(u64::MAX);
        m.observe_latency_ns(u64::MAX - 1);
        for q in [0.0, 0.5, 0.99, 1.0] {
            let v = m.latency_quantile_ns(q).unwrap();
            assert_eq!(v, 1u64 << 63, "q={q} must clamp to the top bucket bound");
            assert_ne!(v, u64::MAX);
        }
        assert!(m.report().contains("latency_p99"), "{}", m.report());
        // The snapshot's windowed quantile clamps identically.
        let s = m.snapshot();
        assert_eq!(
            s.window_latency_quantile_ns(&MetricsSnapshot::empty(1), 0.99),
            Some(1u64 << 63)
        );
    }

    #[test]
    fn rows_per_sec_needs_window() {
        let m = Metrics::default();
        assert_eq!(m.rows_per_sec(), 0.0);
        m.note_submit();
        std::thread::sleep(std::time::Duration::from_millis(2));
        m.add_batch(10, 0, Default::default(), 0.0, 50);
        assert!(m.rows_per_sec() > 0.0);
    }
}
