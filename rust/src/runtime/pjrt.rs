//! PJRT execution engine: compile the HLO-text artifacts once, execute
//! them from the request path.

use std::path::{Path, PathBuf};

use crate::anyhow;

use crate::bits::format::SimdFormat;
use crate::csd::schedule::{schedule_with, MulOp};
use crate::runtime::manifest::Manifest;

/// A compiled artifact bundle on the PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
    mul_exe: xla::PjRtLoadedExecutable,
    mlp_exe: xla::PjRtLoadedExecutable,
    pub manifest: Manifest,
    pub dir: PathBuf,
}

fn compile(client: &xla::PjRtClient, path: &Path) -> anyhow::Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str()
            .ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?,
    )?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

impl Engine {
    /// Load and compile `mul.hlo.txt` + `mlp.hlo.txt` from `dir`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mul_exe = compile(&client, &dir.join("mul.hlo.txt"))?;
        let mlp_exe = compile(&client, &dir.join("mlp.hlo.txt"))?;
        Ok(Engine { client, mul_exe, mlp_exe, manifest, dir })
    }

    /// Default artifact location relative to the crate root.
    pub fn default_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute the packed-multiply artifact: multiply each sub-word of
    /// `words` (format `fmt`) by the `Q1.(y_bits-1)` multiplier `m_raw`.
    ///
    /// `words.len()` must equal the artifact's word count
    /// (`manifest.mul_words`); pad with zeros and slice as needed.
    pub fn mul_packed(
        &self,
        words: &[u64],
        m_raw: i64,
        y_bits: u32,
        fmt: SimdFormat,
    ) -> anyhow::Result<Vec<u64>> {
        anyhow::ensure!(
            words.len() == self.manifest.mul_words,
            "artifact expects {} words, got {}",
            self.manifest.mul_words,
            words.len()
        );
        let plan = schedule_with(m_raw, y_bits, crate::bits::format::MAX_SHIFT);
        anyhow::ensure!(plan.ops.len() <= self.manifest.ops_max, "plan too long");
        let mut shifts = vec![0i32; self.manifest.ops_max];
        let mut signs = vec![0i32; self.manifest.ops_max];
        for (i, op) in plan.ops.iter().enumerate() {
            match *op {
                MulOp::AddShift { shift, sign } => {
                    shifts[i] = shift as i32;
                    signs[i] = sign as i32;
                }
                MulOp::Shift { shift } => shifts[i] = shift as i32,
            }
        }
        let x = xla::Literal::vec1(words);
        let s = xla::Literal::vec1(&shifts);
        let g = xla::Literal::vec1(&signs);
        let h = xla::Literal::vec1(&[fmt.msb_mask()]);
        let l = xla::Literal::vec1(&[fmt.lsb_mask()]);
        let result = self.mul_exe.execute::<xla::Literal>(&[x, s, g, h, l])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<u64>()?)
    }

    /// Execute the MLP artifact on a quantized batch
    /// (`int32[mlp_batch, mlp_in]` raws) → `int32[mlp_batch, mlp_out]`
    /// Q1.15 logits, row-major.
    pub fn mlp_forward(&self, x_q: &[i32]) -> anyhow::Result<Vec<i32>> {
        let (b, k) = (self.manifest.mlp_batch, self.manifest.mlp_in);
        anyhow::ensure!(x_q.len() == b * k, "expected {}x{} inputs", b, k);
        let x = xla::Literal::vec1(x_q).reshape(&[b as i64, k as i64])?;
        let result = self.mlp_exe.execute::<xla::Literal>(&[x])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<i32>()?)
    }
}
