//! Property-based tests over the architecture invariants (hand-rolled
//! generators — proptest is unavailable offline; see Cargo.toml).
//!
//! Each property runs a few thousand randomized cases with a fixed seed
//! (deterministic, reproducible); assertion messages carry the failing
//! inputs.

use softsimd::bits::fixed::{from_q, sign_extend};
use softsimd::bits::format::{SimdFormat, WORD_MASK};
use softsimd::bits::pack::{pack, pack_stream, unpack, unpack_stream};
use softsimd::bits::swar::{swar_add, swar_add_sar, swar_neg, swar_sar, swar_sub, swar_sub_sar};
use softsimd::csd::encode::{csd_decode, csd_encode, Digit};
use softsimd::csd::schedule::{schedule, schedule_with, MulOp};
use softsimd::pipeline::stage1::{mul_packed, mul_packed_with, mul_scalar};
use softsimd::pipeline::stage2::{conversion_chain, repack_stream};
use softsimd::workload::synth::XorShift64;

const CASES: usize = 3000;

fn formats() -> Vec<SimdFormat> {
    SimdFormat::all().collect()
}

#[test]
fn prop_swar_ops_match_lanewise_model() {
    let mut rng = XorShift64::new(0x11);
    for i in 0..CASES {
        let fmt = formats()[i % 5];
        let (a, c) = (rng.word(), rng.word());
        let b = fmt.bits;
        let lanes_a = unpack(a, fmt);
        let lanes_c = unpack(c, fmt);
        let wrap = |v: i64| sign_extend((v as u64) & ((1u64 << b) - 1), b);
        assert_eq!(
            unpack(swar_add(a, c, fmt), fmt),
            lanes_a.iter().zip(&lanes_c).map(|(&x, &y)| wrap(x + y)).collect::<Vec<_>>(),
            "add a={a:#x} c={c:#x} fmt={fmt}"
        );
        assert_eq!(
            unpack(swar_sub(a, c, fmt), fmt),
            lanes_a.iter().zip(&lanes_c).map(|(&x, &y)| wrap(x - y)).collect::<Vec<_>>(),
            "sub a={a:#x} c={c:#x} fmt={fmt}"
        );
        assert_eq!(
            unpack(swar_neg(a, fmt), fmt),
            lanes_a.iter().map(|&x| wrap(-x)).collect::<Vec<_>>(),
            "neg a={a:#x} fmt={fmt}"
        );
        let k = 1 + (i as u32 % 3);
        assert_eq!(
            unpack(swar_add_sar(a, c, k, fmt), fmt),
            lanes_a.iter().zip(&lanes_c).map(|(&x, &y)| (x + y) >> k).collect::<Vec<_>>(),
            "addsar a={a:#x} c={c:#x} k={k} fmt={fmt}"
        );
        assert_eq!(
            unpack(swar_sub_sar(a, c, k, fmt), fmt),
            lanes_a.iter().zip(&lanes_c).map(|(&x, &y)| (x - y) >> k).collect::<Vec<_>>(),
            "subsar fmt={fmt}"
        );
        assert_eq!(
            unpack(swar_sar(a, k, fmt), fmt),
            lanes_a.iter().map(|&x| x >> k).collect::<Vec<_>>(),
            "sar fmt={fmt}"
        );
        assert_eq!(swar_add_sar(a, c, k, fmt) & !WORD_MASK, 0, "datapath overflow");
    }
}

#[test]
fn prop_csd_roundtrip_and_adjacency() {
    let mut rng = XorShift64::new(0x22);
    for i in 0..CASES {
        let y = [4u32, 6, 8, 12, 16][i % 5];
        let m = rng.q_raw(y);
        let d = csd_encode(m, y);
        assert_eq!(d.len(), y as usize, "length m={m} y={y}");
        assert_eq!(csd_decode(&d), m, "roundtrip m={m} y={y}");
        for w in d.windows(2) {
            assert!(
                matches!(w[0], Digit::Z) || matches!(w[1], Digit::Z),
                "adjacent nonzeros m={m} y={y}"
            );
        }
    }
}

#[test]
fn prop_schedule_exactness_under_headroom() {
    // Replaying any plan on a multiplicand with enough trailing zero
    // bits computes x·m exactly — the core shift-add correctness.
    let mut rng = XorShift64::new(0x33);
    for i in 0..CASES {
        let y = [4u32, 6, 8, 12, 16][i % 5];
        let m = rng.q_raw(y);
        let max_shift = 1 + (i as u32 % 4);
        let plan = schedule_with(m, y, max_shift);
        let x: i128 = (rng.q_raw(16) as i128) << 24;
        let mut acc: i128 = 0;
        for op in &plan.ops {
            match *op {
                MulOp::Shift { shift } => acc >>= shift,
                MulOp::AddShift { shift, sign } => {
                    acc += sign as i128 * x;
                    acc >>= shift;
                }
            }
        }
        assert_eq!(acc, (x * m as i128) >> (y - 1), "m={m} y={y} ms={max_shift}");
    }
}

#[test]
fn prop_packed_mul_equals_scalar_oracle() {
    let mut rng = XorShift64::new(0x44);
    for i in 0..CASES / 2 {
        let fmt = formats()[i % 5];
        let y = [4u32, 8, 12, 16][i % 4];
        let m = rng.q_raw(y);
        let x = rng.word();
        let got = unpack(mul_packed(x, m, y, fmt), fmt);
        for (lane, &xv) in unpack(x, fmt).iter().enumerate() {
            assert_eq!(
                got[lane],
                mul_scalar(xv, m, fmt.bits, y),
                "lane {lane} x={xv} m={m} fmt={fmt} y={y}"
            );
        }
    }
}

#[test]
fn prop_mul_invariant_under_shifter_reach() {
    // The shifter reach changes cycle counts, never results.
    let mut rng = XorShift64::new(0x55);
    for i in 0..CASES / 3 {
        let fmt = formats()[i % 5];
        let m = rng.q_raw(8);
        let x = rng.word();
        let r3 = mul_packed_with(x, m, 8, fmt, 3);
        // Reach beyond 3 changes only cycle counts (ablation::density);
        // the datapath executes k ≤ 3 (the paper's shifter).
        for reach in [1u32, 2] {
            assert_eq!(
                mul_packed_with(x, m, 8, fmt, reach),
                r3,
                "reach {reach} m={m} x={x:#x} fmt={fmt}"
            );
        }
    }
}

#[test]
fn prop_mul_accuracy_bound() {
    // |soft product − exact| < cycles(plan)·ULP: each cycle truncates
    // strictly less than one ULP.
    let mut rng = XorShift64::new(0x66);
    for i in 0..CASES {
        let b = [4u32, 6, 8, 12, 16][i % 5];
        let x = rng.q_raw(b);
        let m = rng.q_raw(b);
        if x == -(1 << (b - 1)) && m == -(1 << (b - 1)) {
            continue; // −1 × −1 wrap corner
        }
        let plan_len = schedule(m, b).cycles().max(1) as f64;
        let got = from_q(mul_scalar(x, m, b, b), b);
        let truth = from_q(x, b) * from_q(m, b);
        let ulp = 2f64.powi(-(b as i32 - 1));
        assert!(
            (got - truth).abs() <= plan_len * ulp + 1e-12,
            "x={x} m={m} b={b}: err {} ULPs > {plan_len}",
            (got - truth).abs() / ulp
        );
    }
}

#[test]
fn prop_repack_widen_exact_and_narrow_truncates() {
    let mut rng = XorShift64::new(0x77);
    for i in 0..CASES / 2 {
        let from = formats()[i % 5];
        let to = formats()[(i / 5) % 5];
        let count = 1 + (rng.next_u64() as usize % 30);
        let vals: Vec<i64> = (0..count).map(|_| rng.q_raw(from.bits)).collect();
        let words = pack_stream(&vals, from);
        let out = repack_stream(&words, from, to, count);
        let got = unpack_stream(&out, to, count);
        for (j, (&v, &g)) in vals.iter().zip(&got).enumerate() {
            let vq = from_q(v, from.bits);
            let gq = from_q(g, to.bits);
            if to.bits >= from.bits {
                assert_eq!(vq, gq, "widen exact {from}->{to} idx {j}");
            } else {
                let ulp = 2f64.powi(-(to.bits as i32 - 1));
                assert!(
                    gq <= vq && vq - gq < ulp,
                    "narrow {from}->{to} idx {j}: {vq} -> {gq}"
                );
            }
        }
    }
}

#[test]
fn prop_conversion_chains_are_minimal_and_legal() {
    for a in formats() {
        for b in formats() {
            let chain = conversion_chain(a, b);
            if a == b {
                assert!(chain.is_empty());
                continue;
            }
            assert!(chain.len() <= 2);
            for (f, t) in &chain {
                assert!(f.bits <= 2 * t.bits, "illegal hop {f}->{t}");
            }
        }
    }
}

#[test]
fn prop_pack_roundtrip() {
    let mut rng = XorShift64::new(0x88);
    for i in 0..CASES {
        let fmt = formats()[i % 5];
        let vals: Vec<i64> = (0..fmt.lanes()).map(|_| rng.q_raw(fmt.bits)).collect();
        assert_eq!(unpack(pack(&vals, fmt), fmt), vals, "fmt {fmt}");
    }
}

#[test]
fn prop_repack_roundtrip_nondoubling_pairs_and_odd_counts() {
    // Widen a→b then narrow b→a is the identity for every *non-doubling*
    // widening pair (the generic chained-crossbar path the serving
    // engine's fast path bypasses), including odd/partial-final-word
    // element counts.
    let mut rng = XorShift64::new(0xAA01);
    let pairs = [(4u32, 6u32), (4, 12), (6, 8), (6, 16), (8, 12), (12, 16)];
    for &(a, b) in &pairs {
        let (fa, fb) = (SimdFormat::new(a), SimdFormat::new(b));
        assert_ne!(fb.bits, 2 * fa.bits, "pair {fa}->{fb} must be non-doubling");
        for count in [1usize, 2, 3, 5, 7, 11, 13, 17, 23, 29] {
            let vals: Vec<i64> = (0..count).map(|_| rng.q_raw(a)).collect();
            let words = pack_stream(&vals, fa);
            let wide = repack_stream(&words, fa, fb, count);
            // Densely packed: exactly ceil(count / lanes_b) output words.
            assert_eq!(
                wide.len(),
                count.div_ceil(fb.lanes() as usize),
                "{fa}->{fb} count {count}"
            );
            let back = repack_stream(&wide, fb, fa, count);
            assert_eq!(
                unpack_stream(&back, fa, count),
                vals,
                "{fa}->{fb} count {count}"
            );
        }
    }
}

#[test]
fn prop_repack_stream_padding_lanes_are_zero() {
    // The zero-padding of a partial final word must survive conversion:
    // lanes beyond `count` stay zero so a padded serving batch cannot
    // leak garbage into neighbouring sub-words.
    let mut rng = XorShift64::new(0xAA02);
    for i in 0..400 {
        let from = formats()[i % 5];
        let to = formats()[(i / 5) % 5];
        let lanes = to.lanes() as usize;
        let count = 1 + (rng.next_u64() as usize % (3 * lanes));
        let vals: Vec<i64> = (0..count).map(|_| rng.q_raw(from.bits)).collect();
        let out = repack_stream(&pack_stream(&vals, from), from, to, count);
        let full = unpack_stream(&out, to, out.len() * lanes);
        for (j, &v) in full.iter().enumerate().skip(count) {
            assert_eq!(v, 0, "{from}->{to} count {count} pad lane {j}");
        }
    }
}

#[test]
fn prop_zero_multiplier_and_identity_edges() {
    let mut rng = XorShift64::new(0x99);
    for i in 0..CASES / 3 {
        let fmt = formats()[i % 5];
        let x = rng.word();
        // ×0 → 0 in zero cycles.
        assert_eq!(mul_packed(x, 0, 8, fmt), 0);
        assert_eq!(schedule(0, 8).cycles(), 0);
        // ×(−1) = per-lane negation (mod wrap).
        let neg = unpack(mul_packed(x, -128, 8, fmt), fmt);
        for (lane, &xv) in unpack(x, fmt).iter().enumerate() {
            let want = sign_extend(((-xv) as u64) & ((1u64 << fmt.bits) - 1), fmt.bits);
            assert_eq!(neg[lane], want, "neg lane {lane}");
        }
    }
}
