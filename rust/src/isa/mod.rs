//! Micro-instruction set of the two-stage Soft SIMD pipeline.
//!
//! "Soft" SIMD means the *software* decides sub-word geometry and the
//! multiplication schedule; this module is that software layer: a tiny
//! micro-op ISA, an assembler that compiles (multiplier, formats) into
//! programs, and a disassembler for debugging.

pub mod instr;
pub mod program;

pub use instr::{Instr, Reg};
pub use program::{assemble_mul, assemble_mul_repack, Program};
