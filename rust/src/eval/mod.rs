//! Evaluation harness — regenerates every table/figure of Section IV
//! from the gate-level cost substrate (DESIGN.md §5).
//!
//! Each sub-module prints the same rows/series the paper reports.
//! `summary` derives the two headline numbers (53.1% area, 88.8%
//! energy); `ablation` covers the design choices the paper fixes
//! (CSD vs binary recoding, max coalesced shift, Stage-2 bypass);
//! `precision` sweeps per-layer precision schedules through the serving
//! engine (the run-time repacking story, DESIGN.md §10); `conv` runs
//! the same sweep on the im2col CNN serving path (DESIGN.md §12);
//! `autoscale` prices the accuracy/energy/latency Pareto across a
//! precision-variant set — the operating points the serving governor
//! switches between at run time (DESIGN.md §13); `verify` prints the
//! static lane-safety margins the abstract interpreter proves for the
//! same variant trio (DESIGN.md §14); `certify` prints the static cost
//! certificates and differentially checks them against the running
//! engine (DESIGN.md §15); `fleet` drives a multi-model, multi-tenant
//! bursty-arrival scenario through the fleet front end and reports
//! per-tenant p99 / pJ-per-row / shed rate (DESIGN.md §17); `approx`
//! sweeps the truncated-CSD approximation ladder and gates every rung
//! on its analytic error bound (DESIGN.md §18).

use crate::anyhow;

pub mod ablation;
pub mod approx;
pub mod autoscale;
pub mod certify;
pub mod conv;
pub mod fig10;
pub mod fig6;
pub mod fleet;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod precision;
pub mod summary;
pub mod verify;

pub fn run(target: &str) -> anyhow::Result<()> {
    match target {
        "fig6" | "6" => fig6::run(),
        "fig7" | "7" => fig7::run(),
        "fig8" | "8" => fig8::run(),
        "fig9" | "9" => fig9::run(),
        "fig10" | "10" => fig10::run(),
        "summary" => summary::run(),
        "ablation" => ablation::run(),
        "precision" => precision::run(),
        "conv" => conv::run(),
        "autoscale" => autoscale::run(),
        "verify" => verify::run(),
        "certify" => certify::run(),
        "approx" => approx::run(),
        "fleet" => fleet::run(),
        "all" => {
            fig6::run()?;
            fig7::run()?;
            fig8::run()?;
            fig9::run()?;
            fig10::run()?;
            summary::run()?;
            ablation::run()?;
            precision::run()?;
            conv::run()?;
            autoscale::run()?;
            verify::run()?;
            certify::run()?;
            approx::run()?;
            fleet::run()
        }
        other => anyhow::bail!(
            "unknown eval target `{other}` (fig6..fig10, summary, ablation, \
             precision, conv, autoscale, verify, certify, approx, fleet, all)"
        ),
    }
}
