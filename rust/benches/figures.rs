//! End-to-end benchmark per paper table/figure: times each harness so
//! regressions in the evaluation path are visible, then prints the
//! figure output itself (captured in bench_output.txt at release time).

#[path = "benchkit.rs"]
mod benchkit;
use benchkit::bench;

fn main() {
    println!("== figures: one end-to-end benchmark per paper figure ==");
    // Area harnesses build netlists; energy harnesses run gate-level
    // workloads. min_ms=1 → effectively time one full regeneration.
    bench("fig6 (area vs timing constraint)", 1, || {
        std::hint::black_box(softsimd::eval::fig6::areas());
    });
    bench("fig8 (energy, 3 configs × 3 constraints)", 1, || {
        std::hint::black_box(softsimd::eval::fig8::points());
    });
    bench("fig9 (gain grids, 13×4 sweep × 2 baselines)", 1, || {
        std::hint::black_box(softsimd::eval::fig9::grids());
    });
    bench("fig10 (scenario averages)", 1, || {
        std::hint::black_box(softsimd::eval::fig10::rows());
    });
    bench("summary (headline numbers)", 1, || {
        std::hint::black_box(softsimd::eval::summary::headlines());
    });
    println!("\n-- regenerated figure output --\n");
    softsimd::eval::run("all").expect("eval all");
}
