//! End-to-end driver (DESIGN.md §5): quantized MLP inference on the
//! synthetic-digits workload, executed on the coordinator's PE array,
//! cross-checked bit-exactly against the AOT JAX/Pallas artifact through
//! PJRT, and priced against the Hard SIMD baselines.
//!
//! This is the "all layers compose" proof: L1 Pallas kernel → L2 JAX
//! model → HLO text → PJRT execution (golden) vs L3 packed pipeline
//! execution (system under test), on the same real workload.
//!
//! Run: `make artifacts && cargo run --release --example mlp_inference`

use std::time::Instant;

use softsimd::anyhow;
use softsimd::coordinator::cost::CostTable;
use softsimd::coordinator::model::CompiledModel;
use softsimd::coordinator::server::{Coordinator, Request, ServeConfig};
use softsimd::energy::model::SynthesizedSoftPipeline;
use softsimd::hardsimd::pipeline::{HardSimdPipeline, HARD_FLEX, HARD_TWO};
use softsimd::nn::exec::argmax_class;
use softsimd::nn::weights::load_weight_file;
use softsimd::runtime::Engine;
use softsimd::workload::synth::{Digits, XorShift64};

fn main() -> anyhow::Result<()> {
    let dir = Engine::default_dir();
    anyhow::ensure!(
        dir.join("manifest.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    // ---- golden model via PJRT ------------------------------------
    println!("[1/4] loading AOT artifacts via PJRT…");
    let engine = Engine::load(&dir)?;
    println!("      platform: {}", engine.platform());
    let layers = load_weight_file(dir.join("mlp_weights.txt"))?;

    let digits = Digits::standard();
    let b = engine.manifest.mlp_batch;
    let (xs, _ys) = digits.sample(b, 0.3, 0xBA7C4); // the golden batch
    let flat: Vec<i32> = xs.iter().flatten().map(|&v| v as i32).collect();
    let golden = engine.mlp_forward(&flat)?;

    // ---- system under test: coordinator over packed pipelines -----
    println!("[2/4] running the same batch on the packed PE array…");
    let cost = CostTable::characterize(1000.0);
    let model = CompiledModel::compile(layers.clone(), 8, 16)?;
    let mut coord = Coordinator::start(model, ServeConfig::new(2, b), cost)?;
    for (id, row) in xs.iter().enumerate() {
        coord.submit(Request { id: id as u64, rows: vec![row.clone()] })?;
    }
    let responses = coord.drain()?;

    let out_n = engine.manifest.mlp_out;
    let mut mismatches = 0;
    for resp in &responses {
        let id = resp.id as usize;
        let want: Vec<i64> = golden[id * out_n..(id + 1) * out_n]
            .iter()
            .map(|&v| v as i64)
            .collect();
        if resp.logits[0] != want {
            mismatches += 1;
            eprintln!("row {id}: rust {:?} != pjrt {:?}", resp.logits[0], want);
        }
    }
    println!(
        "      PJRT-vs-pipeline cross-check: {}",
        if mismatches == 0 { "BIT-EXACT across all rows" } else { "MISMATCH" }
    );
    anyhow::ensure!(mismatches == 0, "{mismatches} rows diverged from the artifact");

    // ---- a larger accuracy run ------------------------------------
    println!("[3/4] serving a 512-image accuracy run…");
    let (xl, yl) = digits.sample(512, 0.3, 0xACC);
    let t0 = Instant::now();
    for (id, row) in xl.iter().enumerate() {
        coord.submit(Request { id: (1000 + id) as u64, rows: vec![row.clone()] })?;
    }
    let rs = coord.drain()?;
    let wall = t0.elapsed();
    let correct = rs
        .iter()
        .filter(|r| argmax_class(&r.logits[0], 10) == yl[(r.id - 1000) as usize])
        .count();
    println!(
        "      quantized accuracy {:.1}% over 512 images ({:.0} req/s host)",
        correct as f64 / 512.0 * 100.0,
        512.0 / wall.as_secs_f64()
    );
    // Float matched-filter reference for the accuracy delta.
    let float_correct = {
        let w1: Vec<Vec<f64>> = layers[0]
            .w_raw
            .iter()
            .map(|r| r.iter().map(|&v| v as f64 / 128.0).collect())
            .collect();
        let w2: Vec<Vec<f64>> = layers[1]
            .w_raw
            .iter()
            .map(|r| r.iter().map(|&v| v as f64 / 128.0).collect())
            .collect();
        xl.iter()
            .zip(&yl)
            .filter(|(row, &y)| {
                let x: Vec<f64> = row.iter().map(|&v| v as f64 / 128.0).collect();
                let mut h = vec![0.0f64; layers[0].n];
                for (k, &xv) in x.iter().enumerate() {
                    for (j, hj) in h.iter_mut().enumerate() {
                        *hj += xv * w1[k][j];
                    }
                }
                let mut logits = vec![0.0f64; layers[1].n];
                for (k, &hv) in h.iter().enumerate() {
                    let hv = hv.max(0.0);
                    for (j, lj) in logits.iter_mut().enumerate() {
                        *lj += hv * w2[k][j];
                    }
                }
                let pred = logits[..10]
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                pred == y
            })
            .count()
    };
    println!(
        "      float reference accuracy {:.1}% (quantization delta {:.1} points)",
        float_correct as f64 / 512.0 * 100.0,
        (float_correct as f64 - correct as f64) / 512.0 * 100.0
    );
    println!("      {}", coord.metrics.report());

    // ---- price the model on all three designs ---------------------
    println!("[4/4] pricing one forward pass on the 28nm cost model @1GHz…");
    let mut soft = SynthesizedSoftPipeline::new(1000.0);
    let mut flex = HardSimdPipeline::new(HARD_FLEX, 1000.0);
    let mut two = HardSimdPipeline::new(HARD_TWO, 1000.0);
    let mut rng = XorShift64::new(7);
    let mults_per_pass: u64 = layers.iter().map(|l| (l.k * l.n) as u64).sum();
    let es = soft.subword_mult_energy_pj(8, 8, 200, &mut rng).unwrap();
    let ef = flex.subword_mult_energy_pj(8, 8, 200, &mut rng).unwrap();
    let e2 = two.subword_mult_energy_pj(8, 8, 200, &mut rng).unwrap();
    println!(
        "      {} mults/pass → Soft {:.2} nJ | Hard(4..16) {:.2} nJ | Hard(8,16) {:.2} nJ",
        mults_per_pass,
        es * mults_per_pass as f64 / 1000.0,
        ef * mults_per_pass as f64 / 1000.0,
        e2 * mults_per_pass as f64 / 1000.0,
    );
    coord.shutdown();
    println!("OK");
    Ok(())
}
