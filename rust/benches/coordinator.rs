//! Coordinator serving benchmarks: packed-engine layer throughput and
//! the full submit→batch→PE→drain loop, comparing round-robin vs
//! least-outstanding-rows dispatch at several PE counts and serving the
//! same model under several per-layer precision schedules.
//!
//! The serving comparison reports rows/sec and p50/p99 request latency
//! per (policy, PE count) cell and per precision schedule. The policy
//! workload is deliberately skewed (most requests are 1 row, a few are
//! 24-row bulks) — the case where blind round-robin parks small
//! requests behind bulks and load-aware routing should win.
//!
//! Every cell is also written to `BENCH_coordinator.json` (hand-rolled
//! JSON — serde is unavailable offline) so CI can archive the perf
//! trajectory across PRs as a machine-readable artifact.

#[path = "benchkit.rs"]
mod benchkit;
use benchkit::{bench, throughput, write_cells};

use std::sync::Arc;

use softsimd::coordinator::cost::CostTable;
use softsimd::coordinator::engine::PackedEngine;
use softsimd::coordinator::model::CompiledModel;
use softsimd::coordinator::server::{
    Coordinator, DispatchPolicy, Request, ServeConfig,
};
use softsimd::nn::weights::{LayerPrecision, QuantLayer};
use softsimd::workload::synth::XorShift64;

fn model_layers(rng: &mut XorShift64) -> Vec<QuantLayer> {
    let mk = |k: usize, n: usize, rng: &mut XorShift64| {
        QuantLayer::new(
            (0..k).map(|_| (0..n).map(|_| rng.q_raw(8)).collect()).collect(),
            8,
        )
    };
    vec![mk(64, 32, rng), mk(32, 16, rng)]
}

/// Skewed open-loop workload at the given input quantization: ~1/8 of
/// requests are 24-row bulks.
fn workload(rng: &mut XorShift64, n: usize, in_bits: u32) -> Vec<Request> {
    (0..n)
        .map(|id| {
            let rows = if rng.next_u64() % 8 == 0 { 24 } else { 1 };
            Request {
                id: id as u64,
                rows: (0..rows)
                    .map(|_| (0..64).map(|_| rng.q_raw(in_bits)).collect())
                    .collect(),
            }
        })
        .collect()
}

/// One serving-grid measurement, JSON-serializable.
struct Cell {
    group: &'static str,
    policy: &'static str,
    pes: usize,
    schedule: &'static str,
    rows_per_s: f64,
    p50_us: f64,
    p99_us: f64,
}

impl Cell {
    fn json(&self) -> String {
        format!(
            "{{\"group\":\"{}\",\"policy\":\"{}\",\"pes\":{},\"schedule\":\"{}\",\
             \"rows_per_s\":{:.1},\"p50_us\":{:.1},\"p99_us\":{:.1}}}",
            self.group, self.policy, self.pes, self.schedule,
            self.rows_per_s, self.p50_us, self.p99_us
        )
    }
}

fn policy_name(policy: DispatchPolicy) -> &'static str {
    match policy {
        DispatchPolicy::RoundRobin => "round-robin",
        DispatchPolicy::LeastLoaded => "least-loaded",
    }
}

/// Serve `reqs` once and measure the cell.
fn serve_cell(
    model: &Arc<CompiledModel>,
    cfg: ServeConfig,
    cost: &CostTable,
    reqs: &[Request],
    group: &'static str,
    schedule: &'static str,
) -> Cell {
    let policy = policy_name(cfg.policy);
    let pes = cfg.n_pes;
    let mut coord = Coordinator::start(Arc::clone(model), cfg, cost.clone()).expect("start");
    for req in reqs {
        coord.submit(req.clone()).expect("live workers");
    }
    let responses = coord.drain().expect("drain");
    assert_eq!(responses.len(), reqs.len());
    let cell = Cell {
        group,
        policy,
        pes,
        schedule,
        rows_per_s: coord.metrics.rows_per_sec(),
        p50_us: coord.metrics.latency_quantile_ns(0.50).unwrap_or(0) as f64 / 1e3,
        p99_us: coord.metrics.latency_quantile_ns(0.99).unwrap_or(0) as f64 / 1e3,
    };
    coord.shutdown();
    cell
}

fn main() {
    println!("== coordinator: packed NN serving ==");
    let mut rng = XorShift64::new(0xC0BE);
    let layers = model_layers(&mut rng);
    let mults_per_row: u64 = layers.iter().map(|l| (l.k * l.n) as u64).sum();
    let model = CompiledModel::compile(layers.clone(), 8, 16).expect("valid model");
    let mut cells: Vec<Cell> = vec![];

    // Engine-only: packed forward of a 12-row batch on the shared model.
    let engine = PackedEngine::new(Arc::clone(&model));
    let batch: Vec<Vec<i64>> = (0..12)
        .map(|_| (0..64).map(|_| rng.q_raw(8)).collect())
        .collect();
    let r = bench("PackedEngine forward (12-row batch)", 60, || {
        std::hint::black_box(engine.forward_batch(&batch));
    });
    throughput(&r, (12 * mults_per_row) as f64, "subword-mults");

    let cost = CostTable {
        mhz: 1000.0,
        s1_cycle_pj: softsimd::bits::format::FORMATS.iter().map(|&b| (b, 1.0)).collect(),
        s2_pass_pj: 0.5,
        area_um2: 4600.0,
    };

    // Full coordinator loop: policy × PE-count grid on a skewed stream.
    let reqs = workload(&mut rng, 256, 8);
    let total_rows: usize = reqs.iter().map(|r| r.rows.len()).sum();
    println!(
        "\n== dispatch policy comparison ({} requests, {} rows, skewed sizes) ==",
        reqs.len(),
        total_rows
    );
    println!(
        "{:<14} {:>4} {:>12} {:>12} {:>12}",
        "policy", "PEs", "rows/s", "p50 us", "p99 us"
    );
    for &policy in &[DispatchPolicy::RoundRobin, DispatchPolicy::LeastLoaded] {
        for &n_pes in &[2usize, 4] {
            let cfg = ServeConfig::new(n_pes, 12).policy(policy);
            let cell = serve_cell(&model, cfg, &cost, &reqs, "policy", "uniform-8");
            println!(
                "{:<14} {:>4} {:>12.0} {:>12.1} {:>12.1}",
                cell.policy, cell.pes, cell.rows_per_s, cell.p50_us, cell.p99_us
            );
            cells.push(cell);
        }
    }

    // Precision-schedule grid: the same weights served under different
    // per-layer format pairs (least-loaded, 2 PEs). Lane occupancy per
    // word differs per schedule, so rows/s and latency shift with the
    // schedule — the run-time repacking story on the serving path.
    let schedules: [(&'static str, Vec<LayerPrecision>); 3] = [
        (
            "uniform-8",
            vec![LayerPrecision::new(8, 16), LayerPrecision::new(8, 16)],
        ),
        (
            "low-first-4-8",
            vec![LayerPrecision::new(4, 8), LayerPrecision::new(8, 16)],
        ),
        (
            "narrowing-2hop",
            vec![LayerPrecision::new(8, 16), LayerPrecision::new(4, 8)],
        ),
    ];
    println!("\n== precision schedule comparison (least-loaded, 2 PEs) ==");
    println!(
        "{:<16} {:>12} {:>12} {:>12}",
        "schedule", "rows/s", "p50 us", "p99 us"
    );
    for (name, sched) in &schedules {
        let m = CompiledModel::compile_scheduled(layers.clone(), sched.clone())
            .expect("valid schedule");
        let reqs = workload(&mut rng, 192, sched[0].in_bits);
        let cfg = ServeConfig::new(2, 12);
        let cell = serve_cell(&m, cfg, &cost, &reqs, "schedule", *name);
        println!(
            "{:<16} {:>12.0} {:>12.1} {:>12.1}",
            cell.schedule, cell.rows_per_s, cell.p50_us, cell.p99_us
        );
        cells.push(cell);
    }

    // The classic single-cell timing view, for regression tracking.
    let rows: Vec<Vec<i64>> = (0..96)
        .map(|_| (0..64).map(|_| rng.q_raw(8)).collect())
        .collect();
    let r = bench("coordinator submit+drain (96 requests, 2 PEs)", 120, || {
        let mut coord = Coordinator::start(
            Arc::clone(&model),
            ServeConfig::new(2, 12),
            cost.clone(),
        )
        .expect("start");
        for (id, row) in rows.iter().enumerate() {
            coord
                .submit(Request { id: id as u64, rows: vec![row.clone()] })
                .expect("live workers");
        }
        std::hint::black_box(coord.drain().expect("drain"));
        coord.shutdown();
    });
    throughput(&r, (96 * mults_per_row) as f64, "subword-mults");

    // Machine-readable artifact for CI perf tracking across PRs.
    let cell_json: Vec<String> = cells.iter().map(Cell::json).collect();
    write_cells("coordinator", "BENCH_coordinator.json", &cell_json);
}
