//! Batched serving demo: drives the coordinator with a bursty open-loop
//! request stream and reports latency percentiles and throughput — the
//! serving-system view of the near-memory accelerator.
//!
//! Run: `make artifacts && cargo run --release --example serve [n_requests]`

use std::time::Instant;

use softsimd::coordinator::cost::CostTable;
use softsimd::coordinator::server::{Coordinator, Request};
use softsimd::nn::weights::load_weight_file;
use softsimd::workload::synth::{Digits, XorShift64};

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(1024);
    let weights = std::path::Path::new("artifacts/mlp_weights.txt");
    anyhow::ensure!(weights.exists(), "run `make artifacts` first");
    let layers = load_weight_file(weights)?;
    let cost = CostTable::characterize(1000.0);

    println!("request stream: {n} requests, bursty arrivals, 4 PEs, batch target 12 rows");
    let digits = Digits::standard();
    let mut rng = XorShift64::new(0x5E2E);

    let mut coord = Coordinator::start(layers, 8, 16, 4, 12, cost);
    let mut latencies_us: Vec<f64> = Vec::with_capacity(n);
    let t_start = Instant::now();
    let mut submitted = 0u64;
    let mut submit_times: Vec<Instant> = Vec::with_capacity(n);
    while (submitted as usize) < n {
        // Bursts of 1..8 requests.
        let burst = 1 + (rng.next_u64() % 8) as usize;
        for _ in 0..burst.min(n - submitted as usize) {
            let (xs, _) = digits.sample(1, 0.3, 1 + submitted * 7919);
            submit_times.push(Instant::now());
            coord.submit(Request { id: submitted, rows: vec![xs[0].clone()] });
            submitted += 1;
        }
        // Periodically drain to measure per-request latency.
        if submitted % 64 == 0 || submitted as usize >= n {
            for resp in coord.drain() {
                let lat = submit_times[resp.id as usize].elapsed();
                latencies_us.push(lat.as_secs_f64() * 1e6);
            }
        }
    }
    for resp in coord.drain() {
        let lat = submit_times[resp.id as usize].elapsed();
        latencies_us.push(lat.as_secs_f64() * 1e6);
    }
    let wall = t_start.elapsed();

    latencies_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| latencies_us[(latencies_us.len() as f64 * p) as usize];
    println!(
        "served {} responses in {:.1} ms → {:.0} req/s",
        latencies_us.len(),
        wall.as_secs_f64() * 1e3,
        latencies_us.len() as f64 / wall.as_secs_f64()
    );
    println!(
        "latency µs: p50={:.0} p90={:.0} p99={:.0} max={:.0}",
        pct(0.50),
        pct(0.90),
        pct(0.99),
        latencies_us.last().unwrap()
    );
    println!("{}", coord.metrics.report());
    coord.shutdown();
    Ok(())
}
