//! Gate-level substrate benchmarks: netlist evaluation throughput (the
//! figure harness's cost driver) and pipeline program execution.

#[path = "benchkit.rs"]
mod benchkit;
use benchkit::{bench, throughput};

use softsimd::bits::format::SimdFormat;
use softsimd::isa::assemble_mul_repack;
use softsimd::pipeline::PipelineSim;
use softsimd::rtl::multiplier::divisible_array;
use softsimd::rtl::shifter::{drive_stage1, stage1_datapath};
use softsimd::rtl::Simulator;
use softsimd::workload::synth::XorShift64;

fn main() {
    println!("== pipeline: cycle model + gate-level simulation ==");
    let fmt = SimdFormat::new(8);
    let mut rng = XorShift64::new(0xBEC2);

    // Cycle-accurate micro-op programs (trace recording on/off).
    for tracing in [true, false] {
        let progs: Vec<_> = (0..64)
            .map(|i| {
                let mut p = assemble_mul_repack(
                    (i * 37 % 255) - 127,
                    8,
                    fmt,
                    SimdFormat::new(16),
                    3,
                );
                p.instrs
                    .insert(1, softsimd::isa::Instr::Load(softsimd::isa::Reg::X, rng.word()));
                p
            })
            .collect();
        let r = bench(
            &format!("PipelineSim 64 mul+repack programs (tracing={tracing})"),
            30,
            || {
                let mut sim = PipelineSim::new(fmt);
                sim.tracing = tracing;
                std::hint::black_box(sim.run_batch(&progs));
            },
        );
        throughput(&r, 64.0 * 6.0, "subword-mults");
    }

    // Gate-level stage-1 evaluation (the energy model's inner loop).
    let net = stage1_datapath(true);
    println!(
        "stage1 netlist: {} cells, depth {}",
        net.logic_cells(),
        softsimd::rtl::timing::depth(&net)
    );
    let mut sim = Simulator::new(&net);
    let mut acc = 0u64;
    let r = bench("gate-level stage1 eval (1 cycle)", 30, || {
        acc = drive_stage1(&mut sim, &net, acc, rng.word(), 2, 1, fmt);
    });
    throughput(&r, net.logic_cells() as f64, "gate-evals");

    // The big divisible array.
    let bank = divisible_array(&[4, 6, 8, 12, 16]);
    println!(
        "divisible array: {} cells, depth {}",
        bank.logic_cells(),
        softsimd::rtl::timing::depth(&bank)
    );
    let mut bsim = Simulator::new(&bank);
    let r = bench("gate-level divisible-array eval (1 cycle)", 30, || {
        let mut ins = Vec::with_capacity(101);
        let a = rng.word();
        let m = rng.word();
        for i in 0..48 {
            ins.push((a >> i) & 1 != 0);
        }
        for i in 0..48 {
            ins.push((m >> i) & 1 != 0);
        }
        ins.extend_from_slice(&[false, false, true, false, false]);
        bsim.set_inputs(&ins);
        std::hint::black_box(bsim.eval(&bank));
    });
    throughput(&r, bank.logic_cells() as f64, "gate-evals");
    std::hint::black_box(acc);
}
