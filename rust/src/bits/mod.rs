//! Bit-level foundations of the Soft SIMD datapath.
//!
//! Everything in this module is *semantics-pinned*: the exact same bit
//! behaviour is implemented by the pure-jnp reference (`python/compile/
//! kernels/ref.py`) and the Pallas kernel, and is cross-checked through
//! golden vectors emitted at AOT time (see `runtime::golden`).

pub mod fixed;
pub mod format;
#[cfg(feature = "lanecheck")]
pub mod lanecheck;
pub mod pack;
pub mod swar;
#[cfg(feature = "simd")]
pub mod swarx;

pub use fixed::{from_q, to_q, Q};
pub use format::{SimdFormat, DATAPATH_BITS, FORMATS, WORD_MASK};
pub use pack::{pack, unpack, PackedWord};
pub use swar::{swar_add, swar_add_sar, swar_neg, swar_sar, swar_sub, swar_sub_sar};
