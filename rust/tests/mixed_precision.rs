//! Mixed-precision serving properties (hand-rolled generators —
//! proptest is unavailable offline; see Cargo.toml).
//!
//! The tentpole invariant of the format-polymorphic engine: for *any*
//! per-layer precision schedule the packed execution path — per-layer
//! lane packing, the Stage-1 shift-add at each layer's width, and the
//! Stage-2 boundary repacks, chained hops included — matches the scalar
//! mixed-precision oracle bit-exactly on every row (DESIGN.md §10).

use softsimd::bits::format::format_index;
use softsimd::coordinator::engine::PackedEngine;
use softsimd::coordinator::model::CompiledModel;
use softsimd::coordinator::server::{Coordinator, Request, ServeConfig};
use softsimd::nn::exec::mlp_forward_row_mixed;
use softsimd::nn::weights::{LayerPrecision, QuantLayer};
use softsimd::testutil::{flat_cost, random_dense_stack, random_schedule};
use softsimd::workload::synth::XorShift64;

fn random_layers(rng: &mut XorShift64, dims: &[usize], w_bits: &[u32]) -> Vec<QuantLayer> {
    random_dense_stack(rng, dims, w_bits)
}

#[test]
fn prop_packed_engine_matches_mixed_oracle_over_random_schedules() {
    let mut rng = XorShift64::new(0x517ED);
    for case in 0..60 {
        let n_layers = 1 + (rng.next_u64() % 3) as usize;
        let dims: Vec<usize> = (0..=n_layers)
            .map(|_| 1 + (rng.next_u64() % 7) as usize)
            .collect();
        let w_bits: Vec<u32> = (0..n_layers)
            .map(|_| [4u32, 6, 8][(rng.next_u64() % 3) as usize])
            .collect();
        let layers = random_layers(&mut rng, &dims, &w_bits);
        let sched = random_schedule(&mut rng, n_layers);
        let model = CompiledModel::compile_scheduled(layers.clone(), sched.clone())
            .unwrap_or_else(|e| panic!("case {case}: compile failed: {e}"));
        let engine = PackedEngine::new(model);
        let batch_size = 1 + (rng.next_u64() % 40) as usize;
        let batch: Vec<Vec<i64>> = (0..batch_size)
            .map(|_| (0..dims[0]).map(|_| rng.q_raw(sched[0].in_bits)).collect())
            .collect();
        let (got, stats) = engine.forward_batch(&batch);
        assert_eq!(got.len(), batch_size, "case {case}: pad rows must be dropped");
        for (b, row) in batch.iter().enumerate() {
            let want = mlp_forward_row_mixed(row, &layers, &sched);
            assert_eq!(
                got[b], want,
                "case {case}: sched {sched:?} dims {dims:?} w_bits {w_bits:?} row {b}"
            );
        }
        // Accounting invariants: by-format splits sum to the totals, and
        // useful multiplies never include pad lanes.
        assert_eq!(stats.s1_cycles_by_fmt.iter().sum::<u64>(), stats.s1_cycles);
        assert_eq!(stats.s2_passes_by_fmt.iter().sum::<u64>(), stats.s2_passes);
        let nonzero_weights: u64 = layers
            .iter()
            .map(|l| {
                l.w_raw
                    .iter()
                    .flatten()
                    .filter(|&&w| w != 0)
                    .count() as u64
            })
            .sum();
        assert_eq!(
            stats.subword_mults,
            nonzero_weights * batch_size as u64,
            "case {case}: pad lanes must not be billed as useful multiplies"
        );
    }
}

#[test]
fn two_hop_boundary_schedule_is_bit_exact() {
    // 16-bit accumulators feeding a 4-bit layer force the 16→8→4 chain
    // — the crossbar's 2-word input port can't narrow 4× in one pass.
    let mut rng = XorShift64::new(0x2407);
    let layers = random_layers(&mut rng, &[9, 6, 3], &[8, 8]);
    let sched = vec![LayerPrecision::new(8, 16), LayerPrecision::new(4, 8)];
    let model = CompiledModel::compile_scheduled(layers.clone(), sched.clone()).unwrap();
    assert_eq!(model.boundary_chain(0).len(), 2, "16→4 must be 2 hops");
    let engine = PackedEngine::new(model);
    for batch_size in [1usize, 7, 12, 23, 24] {
        let batch: Vec<Vec<i64>> = (0..batch_size)
            .map(|_| (0..9).map(|_| rng.q_raw(8)).collect())
            .collect();
        let (got, _) = engine.forward_batch(&batch);
        for (b, row) in batch.iter().enumerate() {
            let want = mlp_forward_row_mixed(row, &layers, &sched);
            assert_eq!(got[b], want, "batch {batch_size} row {b}");
        }
    }
}

#[test]
fn acceptance_schedules_serve_bit_exactly_end_to_end() {
    // The three acceptance schedules, through the full coordinator:
    // uniform 8-8, widening 4→6→8, and the 2-hop 16-8-4.
    let mut rng = XorShift64::new(0xACC3);
    let layers = random_layers(&mut rng, &[10, 8, 6, 4], &[8, 8, 8]);
    let schedules: Vec<(&str, Vec<LayerPrecision>)> = vec![
        (
            "uniform-8",
            vec![
                LayerPrecision::new(8, 16),
                LayerPrecision::new(8, 16),
                LayerPrecision::new(8, 16),
            ],
        ),
        (
            "widening-4-6-8",
            vec![
                LayerPrecision::new(4, 8),
                LayerPrecision::new(6, 12),
                LayerPrecision::new(8, 16),
            ],
        ),
        (
            "two-hop-16-8-4",
            vec![
                LayerPrecision::new(16, 16),
                LayerPrecision::new(8, 16),
                LayerPrecision::new(4, 8),
            ],
        ),
    ];
    let cost = flat_cost();
    for (name, sched) in schedules {
        let model =
            CompiledModel::compile_scheduled(layers.clone(), sched.clone()).unwrap();
        let mut coord = Coordinator::start(model, ServeConfig::new(2, 8), cost.clone()).unwrap();
        let reqs: Vec<Request> = (0..15u64)
            .map(|id| Request {
                id,
                rows: (0..1 + (id as usize % 3))
                    .map(|_| (0..10).map(|_| rng.q_raw(sched[0].in_bits)).collect())
                    .collect(),
            })
            .collect();
        for r in &reqs {
            coord.submit(r.clone()).unwrap();
        }
        let responses = coord.drain().unwrap();
        assert_eq!(responses.len(), reqs.len(), "{name}");
        for resp in &responses {
            for (i, row) in reqs[resp.id as usize].rows.iter().enumerate() {
                let want = mlp_forward_row_mixed(row, &layers, &sched);
                assert_eq!(resp.logits[i], want, "{name} req {} row {i}", resp.id);
            }
        }
        // Per-format serving metrics landed in the right buckets.
        use std::sync::atomic::Ordering;
        for p in &sched {
            assert!(
                coord.metrics.s1_cycles_by_fmt[format_index(p.in_bits)]
                    .load(Ordering::Relaxed)
                    > 0,
                "{name}: no Stage-1 cycles recorded at {}b",
                p.in_bits
            );
        }
        coord.shutdown();
    }
}

#[test]
fn malformed_models_surface_as_errors_not_worker_panics() {
    // Empty stacks and invalid schedules must be compile-time errors;
    // nothing reaches a PE worker.
    assert!(CompiledModel::compile(vec![], 8, 16).is_err());
    let mut rng = XorShift64::new(0xBAD2);
    let layers = random_layers(&mut rng, &[4, 2], &[8]);
    assert!(CompiledModel::compile_scheduled(
        layers.clone(),
        vec![LayerPrecision::new(16, 8)]
    )
    .is_err());
    assert!(CompiledModel::compile_scheduled(layers, vec![]).is_err());
}
