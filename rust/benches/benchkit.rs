//! Minimal benchmark harness (criterion is unavailable offline; see
//! Cargo.toml). Measures median-of-runs wall time with warmup, reports
//! ns/iter and derived throughput.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub ns_per_iter: f64,
    pub iters: u64,
}

/// Time `f` adaptively: warm up, then pick an iteration count that runs
/// ≥ `min_ms` per sample, take the median of 5 samples.
pub fn bench<F: FnMut()>(name: &str, min_ms: u64, mut f: F) -> BenchResult {
    // Warmup.
    for _ in 0..3 {
        f();
    }
    // Calibrate.
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().as_nanos().max(1) as u64;
    let iters = ((min_ms * 1_000_000) / one).clamp(1, 1_000_000);
    let mut samples = vec![];
    for _ in 0..5 {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t.elapsed().as_nanos() as f64 / iters as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let ns = samples[2];
    println!("{name:<48} {ns:>12.1} ns/iter   ({iters} iters/sample)");
    BenchResult { name: name.to_string(), ns_per_iter: ns, iters }
}

/// Report a throughput line derived from a result.
pub fn throughput(r: &BenchResult, units_per_iter: f64, unit: &str) {
    let per_sec = units_per_iter / (r.ns_per_iter * 1e-9);
    println!(
        "{:<48} {:>12.2} M{unit}/s",
        format!("  -> {}", r.name),
        per_sec / 1e6
    );
}

/// Short git commit hash of the working tree, or "unknown" outside a
/// repo / without git on PATH — bench artifacts must say what they
/// measured.
#[allow(dead_code)]
fn git_sha() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// UTC `YYYY-MM-DD` from Unix seconds (civil-from-days; no chrono
/// offline).
#[allow(dead_code)]
fn utc_date(secs: u64) -> String {
    let z = (secs / 86_400) as i64 + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1_460 + doe / 36_524 - doe / 146_096) / 365;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let m = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = yoe + era * 400 + i64::from(m <= 2);
    format!("{y:04}-{m:02}-{d:02}")
}

/// Write the machine-readable bench artifact — the shared
/// `{"bench": ..., "meta": {...}, "cells": [...]}` envelope every
/// JSON-emitting bench uses (hand-rolled; serde is unavailable
/// offline). `cells` are the per-bench pre-serialized cell objects.
/// The `meta` block stamps provenance — git sha, UTC date, compiled
/// feature flags and the Stage-1 backend — so a checked-in artifact is
/// attributable to the build that produced it.
#[allow(dead_code)] // not every #[path]-including bench emits JSON
pub fn write_cells(bench: &str, path: &str, cells: &[String]) {
    let unix = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let features: Vec<&str> = [("simd", cfg!(feature = "simd"))]
        .iter()
        .filter_map(|&(name, on)| on.then_some(name))
        .collect();
    let backend = if cfg!(feature = "simd") { "wide" } else { "scalar" };
    let meta = format!(
        "{{\"git_sha\":\"{}\",\"date\":\"{}\",\"unix_time\":{unix},\
         \"features\":[{}],\"backend\":\"{backend}\"}}",
        git_sha(),
        utc_date(unix),
        features
            .iter()
            .map(|f| format!("\"{f}\""))
            .collect::<Vec<_>>()
            .join(",")
    );
    let json = format!(
        "{{\"bench\":\"{bench}\",\"meta\":{meta},\"cells\":[\n  {}\n]}}\n",
        cells.join(",\n  ")
    );
    std::fs::write(path, &json).expect("write bench artifact");
    println!("\nwrote {} {bench} cells to {path}", cells.len());
}
