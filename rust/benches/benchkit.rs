//! Minimal benchmark harness (criterion is unavailable offline; see
//! Cargo.toml). Measures median-of-runs wall time with warmup, reports
//! ns/iter and derived throughput.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub ns_per_iter: f64,
    pub iters: u64,
}

/// Time `f` adaptively: warm up, then pick an iteration count that runs
/// ≥ `min_ms` per sample, take the median of 5 samples.
pub fn bench<F: FnMut()>(name: &str, min_ms: u64, mut f: F) -> BenchResult {
    // Warmup.
    for _ in 0..3 {
        f();
    }
    // Calibrate.
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().as_nanos().max(1) as u64;
    let iters = ((min_ms * 1_000_000) / one).clamp(1, 1_000_000);
    let mut samples = vec![];
    for _ in 0..5 {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t.elapsed().as_nanos() as f64 / iters as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let ns = samples[2];
    println!("{name:<48} {ns:>12.1} ns/iter   ({iters} iters/sample)");
    BenchResult { name: name.to_string(), ns_per_iter: ns, iters }
}

/// Report a throughput line derived from a result.
pub fn throughput(r: &BenchResult, units_per_iter: f64, unit: &str) {
    let per_sec = units_per_iter / (r.ns_per_iter * 1e-9);
    println!(
        "{:<48} {:>12.2} M{unit}/s",
        format!("  -> {}", r.name),
        per_sec / 1e6
    );
}

/// Write the machine-readable bench artifact — the shared
/// `{"bench": ..., "cells": [...]}` envelope every JSON-emitting bench
/// uses (hand-rolled; serde is unavailable offline). `cells` are the
/// per-bench pre-serialized cell objects.
#[allow(dead_code)] // not every #[path]-including bench emits JSON
pub fn write_cells(bench: &str, path: &str, cells: &[String]) {
    let json = format!(
        "{{\"bench\":\"{bench}\",\"cells\":[\n  {}\n]}}\n",
        cells.join(",\n  ")
    );
    std::fs::write(path, &json).expect("write bench artifact");
    println!("\nwrote {} {bench} cells to {path}", cells.len());
}
