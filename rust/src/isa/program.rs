//! The assembler: compiles multiplier values and format changes into
//! micro-op programs — the "software" half of Soft SIMD.

use super::instr::{Instr, Reg};
use crate::bits::format::SimdFormat;
use crate::csd::schedule::{schedule_with, MulOp};


/// A compiled micro-op program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Program {
    pub instrs: Vec<Instr>,
}

impl Program {
    pub fn new(instrs: Vec<Instr>) -> Self {
        Program { instrs }
    }

    /// Stage-1 busy cycles.
    pub fn stage1_cycles(&self) -> usize {
        self.instrs.iter().filter(|i| i.uses_stage1()).count()
    }

    /// Stage-2 busy cycles.
    pub fn stage2_cycles(&self) -> usize {
        self.instrs.iter().filter(|i| i.uses_stage2()).count()
    }

    pub fn disasm(&self) -> String {
        self.instrs
            .iter()
            .enumerate()
            .map(|(pc, i)| format!("{pc:4}: {}", i.disasm()))
            .collect::<Vec<_>>()
            .join("\n")
    }
}

/// Compile `acc ← X * m` for a packed multiplicand already in `X`:
/// clear, then the CSD shift-add schedule.
pub fn assemble_mul(m_raw: i64, y_bits: u32, fmt: SimdFormat, max_shift: u32) -> Program {
    let plan = schedule_with(m_raw, y_bits, max_shift);
    let mut instrs = vec![Instr::SetFmt(fmt), Instr::ClearAcc];
    for op in plan.ops {
        instrs.push(match op {
            MulOp::AddShift { shift, sign } => Instr::AddShift { k: shift, sign },
            MulOp::Shift { shift } => Instr::Shift { k: shift },
        });
    }
    instrs.push(Instr::Halt);
    Program::new(instrs)
}

/// Compile a full multiply-then-repack sequence: multiply in `fmt`, move
/// the product into the Stage-2 window, emit the conversion cycles to
/// `out_fmt` (one `Pack` per output word of each direct hop — see
/// `pipeline::stage2` for hop legality), or a `Bypass` when formats match.
pub fn assemble_mul_repack(
    m_raw: i64,
    y_bits: u32,
    fmt: SimdFormat,
    out_fmt: SimdFormat,
    max_shift: u32,
) -> Program {
    let mut p = assemble_mul(m_raw, y_bits, fmt, max_shift);
    p.instrs.pop(); // drop Halt
    p.instrs.push(Instr::Mov(Reg::R2, Reg::Acc));
    if fmt == out_fmt {
        p.instrs.push(Instr::Bypass);
        p.instrs.push(Instr::Store);
    } else {
        for hop in crate::pipeline::stage2::conversion_chain(fmt, out_fmt) {
            let words_out = crate::pipeline::stage2::output_words_per_input(hop.0, hop.1);
            for w in 0..words_out {
                p.instrs.push(Instr::Pack {
                    from: hop.0,
                    to: hop.1,
                    in_skip: w * (48 / hop.1.bits),
                });
                p.instrs.push(Instr::Store);
            }
        }
    }
    p.instrs.push(Instr::Halt);
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mul_program_shape() {
        let fmt = SimdFormat::new(8);
        let p = assemble_mul(115, 8, fmt, 3);
        assert!(matches!(p.instrs[0], Instr::SetFmt(_)));
        assert!(matches!(p.instrs[1], Instr::ClearAcc));
        assert!(matches!(*p.instrs.last().unwrap(), Instr::Halt));
        // Stage-1 cycles == CSD schedule length.
        let plan = crate::csd::schedule::schedule(115, 8);
        assert_eq!(p.stage1_cycles(), plan.cycles());
    }

    #[test]
    fn zero_multiplier_is_free() {
        let fmt = SimdFormat::new(8);
        let p = assemble_mul(0, 8, fmt, 3);
        assert_eq!(p.stage1_cycles(), 0);
    }

    #[test]
    fn bypass_when_formats_match() {
        let fmt = SimdFormat::new(8);
        let p = assemble_mul_repack(37, 8, fmt, fmt, 3);
        assert!(p.instrs.iter().any(|i| matches!(i, Instr::Bypass)));
        assert_eq!(p.stage2_cycles(), 1);
    }

    #[test]
    fn widen_emits_multiple_pack_cycles() {
        let p = assemble_mul_repack(37, 8, SimdFormat::new(8), SimdFormat::new(16), 3);
        // 8→16 widening: one input word → 2 output words → 2 Pack cycles.
        assert_eq!(p.stage2_cycles(), 2);
    }
}
