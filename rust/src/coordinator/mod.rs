//! The near-memory coordinator (L3): the runtime that turns the
//! simulated Soft SIMD pipelines into a deployable accelerator.
//!
//! Shape: a request router + dynamic batcher in front of a pool of
//! worker threads, each owning one simulated processing element (a
//! [`crate::pipeline::PipelineSim`] bank-attached pipeline). Quantized
//! NN layers execute *packed*: activations are packed across the batch
//! dimension (the sub-words sharing one CSD multiplier — the paper's
//! "multiplier value with several multiplicands"), products are
//! Stage-2-repacked into each layer's accumulator format and accumulated
//! with boundary-killed adds. Conv2D layers serve on the same core via
//! im2col lowering — every output pixel becomes a packed batch row
//! (DESIGN.md §12) — so interleaved CNN + MLP stacks are first-class
//! workloads.
//!
//! The serving engine is built around one immutable [`CompiledModel`]
//! (weights + precompiled CSD multiply plans + the per-layer precision
//! schedule with its boundary conversion chains) shared via `Arc` across
//! every PE worker; dispatch is load-aware over bounded per-worker
//! queues, and a deadline thread flushes straggler batches (DESIGN.md
//! §8). Layers may run at different activation/accumulator widths — the
//! engine switches sub-word bitwidth between layers through the Stage-2
//! crossbar and the cost path bills every cycle at the format it
//! actually ran at (DESIGN.md §10).
//!
//! Since DESIGN.md §13 one served model can carry **several precision
//! variants** over the same weights (one shared CSD plan arena, one
//! schedule + boundary-chain + batch-quantum set per variant), and an
//! SLO-driven [`GovernorPolicy`] picks the executing variant per
//! dispatched batch from queue depth and the windowed p99 — the
//! paper's run-time repacking exercised as load-adaptive serving.
//! Billing always follows the variant a batch *actually executed*.
//!
//! Since DESIGN.md §17 the serving machinery generalizes to a
//! [`Fleet`] front end: N hosted models behind one admission layer,
//! per-tenant SLO classes ([`SloClass`]) with their own governor
//! instances and certified-cost load shedding, and each model's
//! traffic sharded across replicated PE pools. The single-model
//! [`Coordinator`] is its one-model, one-tenant deployment.
//!
//! Offline-image note: the std thread + channel fabric stands in for
//! tokio (DESIGN.md §8); the public API is synchronous `submit`/`drain`
//! on the coordinator, with the fleet adding non-blocking collection.

pub mod batcher;
pub mod cost;
pub mod demo;
pub mod engine;
pub mod fleet;
pub mod governor;
pub mod metrics;
pub mod model;
pub mod server;

pub use batcher::{Batch, Batcher, TrackedRequest};
pub use cost::CostTable;
pub use engine::{EngineScratch, EngineStats, PackedEngine};
pub use fleet::{Fleet, FleetConfig, ModelConfig};
pub use governor::{
    CertifiedCosts, GovernorPolicy, LoadSignals, PinnedVariant, SloClass, SloPolicy,
};
pub use metrics::{Metrics, MetricsSnapshot, TenantMetrics, TenantSnapshot, VariantMetrics};
pub use model::{CompiledModel, Variant, VariantSet, VariantSpec};
pub use server::{
    Coordinator, DispatchPolicy, Request, Response, ServeConfig, ServeError,
};

pub use crate::nn::conv::{ConvLayer, ConvShape, LayerOp};
pub use crate::nn::weights::LayerPrecision;
