//! Shared test/bench instrumentation.
//!
//! [`CountingAlloc`] is a counting wrapper around the system allocator
//! used by both the zero-allocation integration test
//! (`tests/alloc_free.rs`) and the engine benchmark
//! (`benches/engine.rs`) — one implementation, so the proof and the
//! reported `allocs_per_batch` always measure the same thing. The
//! consuming binary installs it process-wide:
//!
//! ```ignore
//! use softsimd::testutil::CountingAlloc;
//! #[global_allocator]
//! static GLOBAL: CountingAlloc = CountingAlloc;
//! ```
//!
//! Allocations, zeroed allocations and reallocs are counted;
//! deallocations are free — releasing warmed capacity is never the bug
//! the counter hunts (DESIGN.md §11).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Process-wide allocation counter backing [`CountingAlloc`].
pub static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// When false, the allocator skips the counter RMW entirely (one
/// relaxed bool load per allocation remains). Benchmarks disable
/// counting around *timed* sections so an allocation-heavy baseline is
/// not taxed with an atomic RMW per allocation, which would inflate
/// measured speedups; the zero-allocation proof keeps it enabled.
pub static COUNT_ENABLED: AtomicBool = AtomicBool::new(true);

/// Counting `#[global_allocator]` shim over [`System`].
pub struct CountingAlloc;

impl CountingAlloc {
    /// Current allocation count (monotonic while counting is enabled).
    pub fn count() -> u64 {
        ALLOCS.load(Ordering::SeqCst)
    }

    /// Enable/disable counting (see [`COUNT_ENABLED`]).
    pub fn set_counting(on: bool) {
        COUNT_ENABLED.store(on, Ordering::SeqCst);
    }
}

#[inline]
fn note() {
    if COUNT_ENABLED.load(Ordering::Relaxed) {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        note();
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        note();
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        note();
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}
