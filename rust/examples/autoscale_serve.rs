//! Autoscale serving demo (DESIGN.md §13): one compiled model carrying
//! the hi-fi / balanced / turbo precision-variant trio, served through
//! the coordinator under the SLO hysteresis governor while the load
//! steps light → burst → light. Watch the active variant shed under
//! the burst and recover afterwards, and the per-variant metrics rows
//! bill each phase to the precision that actually executed it.
//!
//! Needs no AOT artifacts: the model is the synthetic matched-filter
//! MLP, so accuracy stays meaningful at every precision and the demo
//! runs anywhere.
//!
//! Run: `cargo run --release --example autoscale_serve`

use std::sync::Arc;
use std::time::Duration;

use softsimd::anyhow;
use softsimd::coordinator::cost::CostTable;
use softsimd::coordinator::governor::SloPolicy;
use softsimd::coordinator::model::{CompiledModel, VariantSpec};
use softsimd::coordinator::server::{Coordinator, Request, ServeConfig};
use softsimd::nn::exec::argmax_class;
use softsimd::nn::weights::LayerPrecision;
use softsimd::workload::synth::{synth_mlp_stack, Digits};

fn main() -> anyhow::Result<()> {
    let stack = synth_mlp_stack(8);
    let specs = vec![
        VariantSpec::new(
            "hifi-8",
            vec![LayerPrecision::new(8, 16), LayerPrecision::new(8, 16)],
        ),
        VariantSpec::new(
            "balanced-6",
            vec![LayerPrecision::new(6, 12), LayerPrecision::new(8, 16)],
        ),
        VariantSpec::new(
            "turbo-4",
            vec![LayerPrecision::new(4, 8), LayerPrecision::new(8, 16)],
        ),
    ];
    let model = CompiledModel::compile_variants(stack, specs)?;
    println!(
        "variant set: {} (one shared CSD plan arena; quanta {:?})",
        model
            .variants()
            .iter()
            .map(|v| v.name().to_string())
            .collect::<Vec<_>>()
            .join(" / "),
        model.variants().iter().map(|v| v.batch_quantum()).collect::<Vec<_>>(),
    );

    println!("characterizing pipeline energy at 1 GHz…");
    let cost = CostTable::characterize(1000.0);

    // Shed past two batches of backlog or a 5 ms p99; recover below
    // half a batch after two calm dispatch decisions.
    let policy = SloPolicy::new(Duration::from_millis(5), 48, 8).patience(2);
    let cfg = ServeConfig::new(2, 24)
        .deadline(Duration::from_millis(2))
        .queue_depth(1);
    let mut coord =
        Coordinator::start_with_policy(Arc::clone(&model), cfg, cost, Box::new(policy))?;

    let digits = Digits::standard();
    let mut next_id = 0u64;
    let mut serve_phase = |coord: &mut Coordinator,
                           name: &str,
                           reqs: usize,
                           rows_per_req: usize,
                           pace: Option<Duration>|
     -> anyhow::Result<()> {
        let base = next_id;
        let (xs, ys) = digits.sample(reqs * rows_per_req, 0.25, 0xA5_0000 + next_id);
        for chunk in xs.chunks(rows_per_req) {
            coord.submit(Request { id: next_id, rows: chunk.to_vec() })?;
            next_id += 1;
            if let Some(gap) = pace {
                std::thread::sleep(gap);
            }
        }
        let responses = coord.drain()?;
        let mut correct = 0usize;
        let mut by_variant = [0usize; 8];
        for resp in &responses {
            // Requests were submitted in chunk order; recover each
            // row's label from the request id.
            let row_idx = ((resp.id - base) as usize) * rows_per_req;
            for (i, logits) in resp.logits.iter().enumerate() {
                if argmax_class(logits, 10) == ys[row_idx + i] {
                    correct += 1;
                }
            }
            by_variant[resp.variant.min(7)] += resp.logits.len();
        }
        println!(
            "{name}: {} requests, accuracy {:.1}%, rows by variant {:?}, \
             active variant now {}",
            responses.len(),
            correct as f64 / (reqs * rows_per_req) as f64 * 100.0,
            &by_variant[..model.n_variants()],
            coord.active_variant(),
        );
        Ok(())
    };

    println!("\n-- phase 1: light traffic (paced singles) --");
    serve_phase(&mut coord, "light-1", 64, 1, Some(Duration::from_micros(300)))?;
    println!("-- phase 2: overload burst (full batches, no pacing) --");
    serve_phase(&mut coord, "burst", 48, 24, None)?;
    println!("-- phase 3: light traffic again --");
    serve_phase(&mut coord, "light-2", 64, 1, Some(Duration::from_micros(300)))?;

    println!("\n{}", coord.metrics.report());
    anyhow::ensure!(
        coord.active_variant() == 0,
        "governor should have recovered hi-fi under light traffic"
    );
    coord.shutdown();
    Ok(())
}
