//! Serving metrics: lock-free counters plus a log₂-bucketed latency
//! histogram, updated by PE workers and read by anyone at any time.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::bits::format::FORMATS;

const LAT_BUCKETS: usize = 64;

/// Shared counters (lock-free; updated by PE workers).
#[derive(Debug)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub rows: AtomicU64,
    /// Zero rows added by lane padding (not counted in `rows`).
    pub pad_rows: AtomicU64,
    /// Rows dropped because no live worker could take them.
    pub dropped_rows: AtomicU64,
    pub subword_mults: AtomicU64,
    pub s1_cycles: AtomicU64,
    pub s2_passes: AtomicU64,
    /// Stage-1 cycles split by the format they ran at (parallel to
    /// `FORMATS`) — the serving-side view of a mixed-precision schedule.
    pub s1_cycles_by_fmt: [AtomicU64; FORMATS.len()],
    /// Stage-2 passes split by the format they produced.
    pub s2_passes_by_fmt: [AtomicU64; FORMATS.len()],
    /// Simulated energy, *atto*-joules (integer for atomic
    /// accumulation). Per-batch pJ figures are rounded to the nearest
    /// aJ before accumulating, so the worst-case drift is 0.5 aJ
    /// (5·10⁻⁴ fJ) per batch — the pre-fix femtojoule truncation lost
    /// up to a full fJ per batch, which compounds to nonsense totals
    /// over a serving run. Read through [`Metrics::energy_fj`].
    pub energy_aj: AtomicU64,
    /// Wall time spent in PE compute, nanoseconds.
    pub compute_ns: AtomicU64,
    /// Request latency histogram: bucket `i` counts latencies in
    /// `[2^(i-1), 2^i)` nanoseconds (bucket 0: `< 1 ns`).
    lat_hist: [AtomicU64; LAT_BUCKETS],
    lat_count: AtomicU64,
    lat_sum_ns: AtomicU64,
    /// Serving-window bounds, nanoseconds since `t0` (for rows/s).
    first_submit_ns: AtomicU64,
    last_done_ns: AtomicU64,
    t0: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            pad_rows: AtomicU64::new(0),
            dropped_rows: AtomicU64::new(0),
            subword_mults: AtomicU64::new(0),
            s1_cycles: AtomicU64::new(0),
            s2_passes: AtomicU64::new(0),
            s1_cycles_by_fmt: std::array::from_fn(|_| AtomicU64::new(0)),
            s2_passes_by_fmt: std::array::from_fn(|_| AtomicU64::new(0)),
            energy_aj: AtomicU64::new(0),
            compute_ns: AtomicU64::new(0),
            lat_hist: std::array::from_fn(|_| AtomicU64::new(0)),
            lat_count: AtomicU64::new(0),
            lat_sum_ns: AtomicU64::new(0),
            first_submit_ns: AtomicU64::new(u64::MAX),
            last_done_ns: AtomicU64::new(0),
            t0: Instant::now(),
        }
    }
}

impl Metrics {
    fn now_ns(&self) -> u64 {
        self.t0.elapsed().as_nanos() as u64
    }

    /// Called by the coordinator on every accepted request.
    pub fn note_submit(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.first_submit_ns
            .fetch_min(self.now_ns(), Ordering::Relaxed);
    }

    /// Called by a PE worker after completing a batch.
    pub fn add_batch(
        &self,
        rows: u64,
        stats: crate::coordinator::engine::EngineStats,
        pj: f64,
        ns: u64,
    ) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(rows, Ordering::Relaxed);
        self.pad_rows.fetch_add(stats.pad_rows, Ordering::Relaxed);
        self.subword_mults
            .fetch_add(stats.subword_mults, Ordering::Relaxed);
        self.s1_cycles.fetch_add(stats.s1_cycles, Ordering::Relaxed);
        self.s2_passes.fetch_add(stats.s2_passes, Ordering::Relaxed);
        for (dst, &src) in self.s1_cycles_by_fmt.iter().zip(&stats.s1_cycles_by_fmt) {
            dst.fetch_add(src, Ordering::Relaxed);
        }
        for (dst, &src) in self.s2_passes_by_fmt.iter().zip(&stats.s2_passes_by_fmt) {
            dst.fetch_add(src, Ordering::Relaxed);
        }
        // A batch's energy is a finite, non-negative physical quantity;
        // NaN or a negative figure is a cost-model bug upstream, not
        // something to silently saturate-cast into the counter.
        debug_assert!(
            pj.is_finite() && pj >= 0.0,
            "batch energy must be finite and non-negative, got {pj} pJ"
        );
        // Round to the nearest attojoule (`max` also maps NaN to 0.0 in
        // release builds) — never truncate: sub-unit remainders must
        // not be systematically dropped every batch.
        self.energy_aj
            .fetch_add((pj.max(0.0) * 1e6).round() as u64, Ordering::Relaxed);
        self.compute_ns.fetch_add(ns, Ordering::Relaxed);
        self.last_done_ns.fetch_max(self.now_ns(), Ordering::Relaxed);
    }

    /// Accumulated simulated energy in femtojoules.
    pub fn energy_fj(&self) -> f64 {
        self.energy_aj.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Record one request's submit→complete latency.
    pub fn observe_latency_ns(&self, ns: u64) {
        let bucket = (64 - ns.leading_zeros() as usize).min(LAT_BUCKETS - 1);
        self.lat_hist[bucket].fetch_add(1, Ordering::Relaxed);
        self.lat_count.fetch_add(1, Ordering::Relaxed);
        self.lat_sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Latency quantile estimate in nanoseconds (upper bucket bound);
    /// `None` until at least one latency is recorded. `q` in [0, 1].
    /// Never exceeds the top bucket's documented upper bound
    /// (`2^(LAT_BUCKETS-1)` ns): the overflow bucket clamps there, and
    /// a racing reader that sees `lat_count` ahead of the histogram
    /// falls through to the same clamp — the old `u64::MAX` sentinel
    /// printed as an ~18-exasecond p99 in `report()`.
    pub fn latency_quantile_ns(&self, q: f64) -> Option<u64> {
        let count = self.lat_count.load(Ordering::Relaxed);
        if count == 0 {
            return None;
        }
        let target = ((q.clamp(0.0, 1.0) * count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.lat_hist.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Some(1u64 << i.min(LAT_BUCKETS - 1));
            }
        }
        Some(1u64 << (LAT_BUCKETS - 1))
    }

    pub fn mean_latency_ns(&self) -> Option<f64> {
        let count = self.lat_count.load(Ordering::Relaxed);
        if count == 0 {
            return None;
        }
        Some(self.lat_sum_ns.load(Ordering::Relaxed) as f64 / count as f64)
    }

    /// Served rows per second over the first-submit → last-completion
    /// window (0.0 before any work completes).
    pub fn rows_per_sec(&self) -> f64 {
        let first = self.first_submit_ns.load(Ordering::Relaxed);
        let last = self.last_done_ns.load(Ordering::Relaxed);
        let rows = self.rows.load(Ordering::Relaxed);
        if first == u64::MAX || last <= first || rows == 0 {
            return 0.0;
        }
        rows as f64 / ((last - first) as f64 / 1e9)
    }

    pub fn report(&self) -> String {
        let rows = self.rows.load(Ordering::Relaxed);
        let mults = self.subword_mults.load(Ordering::Relaxed);
        let cycles = self.s1_cycles.load(Ordering::Relaxed);
        let pj = self.energy_fj() / 1000.0;
        let ns = self.compute_ns.load(Ordering::Relaxed).max(1);
        let p50 = self.latency_quantile_ns(0.50).unwrap_or(0) as f64 / 1e3;
        let p99 = self.latency_quantile_ns(0.99).unwrap_or(0) as f64 / 1e3;
        // Per-format Stage-1 breakdown, formats actually exercised only.
        let by_fmt: String = FORMATS
            .iter()
            .enumerate()
            .filter_map(|(i, &b)| {
                let c = self.s1_cycles_by_fmt[i].load(Ordering::Relaxed);
                (c > 0).then(|| format!("{b}b:{c}"))
            })
            .collect::<Vec<_>>()
            .join(",");
        format!(
            "requests={} batches={} rows={} pad_rows={} dropped_rows={} \
             subword_mults={} s1_cycles={} s1_by_fmt=[{}] s2_passes={} \
             sim_energy={:.2} nJ mean_pJ/mult={:.3} \
             host_throughput={:.1} Mmult/s rows/s={:.0} \
             latency_p50={:.0}us latency_p99={:.0}us",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            rows,
            self.pad_rows.load(Ordering::Relaxed),
            self.dropped_rows.load(Ordering::Relaxed),
            mults,
            cycles,
            by_fmt,
            self.s2_passes.load(Ordering::Relaxed),
            pj / 1000.0,
            if mults > 0 { pj / mults as f64 } else { 0.0 },
            mults as f64 / (ns as f64 / 1000.0),
            self.rows_per_sec(),
            p50,
            p99,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let m = Metrics::default();
        let mut by_fmt = [0u64; FORMATS.len()];
        by_fmt[crate::bits::format::format_index(8)] = 10;
        let stats = crate::coordinator::engine::EngineStats {
            s1_cycles: 10,
            s2_passes: 2,
            acc_adds: 5,
            subword_mults: 60,
            pad_rows: 1,
            s1_cycles_by_fmt: by_fmt,
            s2_passes_by_fmt: [0; FORMATS.len()],
        };
        m.add_batch(6, stats, 1.5, 100);
        m.add_batch(6, stats, 1.5, 100);
        assert_eq!(m.rows.load(Ordering::Relaxed), 12);
        assert_eq!(m.pad_rows.load(Ordering::Relaxed), 2);
        assert_eq!(m.subword_mults.load(Ordering::Relaxed), 120);
        let i8 = crate::bits::format::format_index(8);
        assert_eq!(m.s1_cycles_by_fmt[i8].load(Ordering::Relaxed), 20);
        assert!(m.report().contains("rows=12"));
        assert!(m.report().contains("8b:20"), "{}", m.report());
    }

    #[test]
    fn latency_quantiles_order() {
        let m = Metrics::default();
        assert!(m.latency_quantile_ns(0.5).is_none());
        for ns in [100u64, 200, 400, 800, 100_000] {
            m.observe_latency_ns(ns);
        }
        let p50 = m.latency_quantile_ns(0.50).unwrap();
        let p99 = m.latency_quantile_ns(0.99).unwrap();
        assert!(p50 <= p99, "p50 {p50} > p99 {p99}");
        assert!(p99 >= 100_000, "p99 {p99} below max sample");
        assert!(m.mean_latency_ns().unwrap() > 0.0);
    }

    #[test]
    fn per_batch_energy_sums_match_the_oracle_total_within_a_femtojoule() {
        // Regression (the fJ-truncation bug): 1000 batches of 0.0007 pJ
        // = 0.7 fJ each used to truncate to 0 fJ every single batch,
        // reporting zero total energy for 700 fJ of real work.
        let m = Metrics::default();
        let per_batch_pj = 0.0007;
        let batches = 1000u64;
        for _ in 0..batches {
            m.add_batch(1, Default::default(), per_batch_pj, 1);
        }
        let oracle_fj = per_batch_pj * batches as f64 * 1000.0;
        assert!(
            (m.energy_fj() - oracle_fj).abs() < 1.0,
            "accumulated {} fJ, oracle {} fJ",
            m.energy_fj(),
            oracle_fj
        );
        // And fractional picojoule figures keep their remainders too.
        let m2 = Metrics::default();
        for _ in 0..100 {
            m2.add_batch(1, Default::default(), 1.2345, 1);
        }
        assert!((m2.energy_fj() - 123450.0).abs() < 1.0, "{}", m2.energy_fj());
    }

    #[test]
    fn overflow_latency_bucket_clamps_to_its_documented_upper_bound() {
        // Regression (the u64::MAX sentinel): an astronomically large
        // latency lands in the top bucket and every quantile must clamp
        // to that bucket's upper bound, never the ~18-exasecond
        // sentinel `report()` would print as a p99.
        let m = Metrics::default();
        m.observe_latency_ns(u64::MAX);
        m.observe_latency_ns(u64::MAX - 1);
        for q in [0.0, 0.5, 0.99, 1.0] {
            let v = m.latency_quantile_ns(q).unwrap();
            assert_eq!(v, 1u64 << 63, "q={q} must clamp to the top bucket bound");
            assert_ne!(v, u64::MAX);
        }
        assert!(m.report().contains("latency_p99"), "{}", m.report());
    }

    #[test]
    fn rows_per_sec_needs_window() {
        let m = Metrics::default();
        assert_eq!(m.rows_per_sec(), 0.0);
        m.note_submit();
        std::thread::sleep(std::time::Duration::from_millis(2));
        m.add_batch(10, Default::default(), 0.0, 50);
        assert!(m.rows_per_sec() > 0.0);
    }
}
